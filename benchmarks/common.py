"""Shared benchmark infrastructure.

Draft/target pairs are *trained* (not random) so acceptance dynamics are
real — DESIGN.md §3.  Two pairs mirror the paper's §4.1/§4.4 regimes:

* ``llama`` pair — strong draft (same data, 60% of target training):
  high-acceptance regime (paper's LLaMA-70B / LLaMA-3.2-1B).
* ``gemma`` pair — weak, divergent draft (narrower, fewer steps, partly
  disjoint data): low-acceptance regime (paper's Gemma-27B / Gemma-2B,
  k_opt = 2).

Synthetic datasets emulate the paper's eight-task heterogeneity through
Markov-chain peakedness (predictability):  code > qa > news > dialogue.

Latency reporting: CPU wall-clock is real but machine-bound, so the
primary cross-policy metric is the hardware-neutral cost model
    latency_units = rounds * C_target + draft_steps * C_draft
with C_draft/C_target from the pair's parameter ratio (the quantity a
fixed-hardware deployment actually saves).
"""
from __future__ import annotations

import dataclasses
import os
import zlib
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.config import (ModelConfig, OptimizerConfig, ServingConfig,
                               SpecDecodeConfig, TrainConfig)
from repro.core.drafters import build_drafter
from repro.models.module import count_params
from repro.models.transformer import model_specs
from repro.serving.engine import ServingEngine
from repro.serving.request import Request
from repro.training.checkpoint import (latest_checkpoint, restore_checkpoint,
                                       save_checkpoint)
from repro.training.data import MarkovTaskCorpus, lm_batches
from repro.training.train import train_loop
from repro.models.module import init_params

CACHE_DIR = os.environ.get("REPRO_BENCH_CACHE", "/tmp/repro_bench_cache")
VOCAB = 512

DATASETS: Dict[str, float] = {   # name -> Markov peakedness
    "code": 3.0,       # HumanEval-like: highly predictable
    "qa": 1.5,         # GSM8K/HotpotQA-like
    "news": 0.8,       # CNNDM/XSum-like
    "dialogue": 0.35,  # ShareGPT-like: high entropy
}


def dataset(name: str) -> MarkovTaskCorpus:
    # crc32, NOT hash(): python randomizes hash() per process, which would
    # give the training and serving processes different corpora
    return MarkovTaskCorpus(VOCAB, peakedness=DATASETS[name],
                            seed=zlib.crc32(name.encode()) % 1000)


def mixed_stream(total_per: int = 150000) -> np.ndarray:
    return np.concatenate([dataset(n).stream(total_per, seed=i)
                           for i, n in enumerate(DATASETS)])


def target_config() -> ModelConfig:
    return get_config("smollm-135m").reduced()


def draft_config(weak: bool = False) -> ModelConfig:
    cfg = target_config()
    if weak:
        return dataclasses.replace(cfg, d_model=64, num_heads=2,
                                   num_kv_heads=1, head_dim=32, d_ff=128,
                                   name="draft-weak")
    return dataclasses.replace(cfg, d_model=128, num_heads=2,
                               num_kv_heads=1, head_dim=64, d_ff=256,
                               name="draft")


def _train_cached(tag: str, cfg: ModelConfig, stream: np.ndarray,
                  steps: int, seed: int = 0):
    path = os.path.join(CACHE_DIR, tag)
    ck = latest_checkpoint(path)
    template = init_params(model_specs(cfg), jax.random.PRNGKey(seed),
                           jnp.float32)
    if ck:
        try:
            params, _ = restore_checkpoint(ck, template)
            return params
        except (KeyError, ValueError):
            pass   # stale cache from an older architecture revision
    tc = TrainConfig(global_batch_size=16, seq_len=64,
                     optimizer=OptimizerConfig(learning_rate=3e-3,
                                               warmup_steps=30,
                                               total_steps=steps,
                                               grad_clip=5.0))
    params, m = train_loop(cfg, tc, lm_batches(stream, 16, 64, seed=seed),
                           num_steps=steps, verbose=False, seed=seed)
    print(f"  [pair] trained {tag}: steps={steps} loss={m['loss']:.3f}")
    save_checkpoint(path, steps, params)
    return params


def untrained_pair():
    """Random-init target/draft pair for smoke lanes: acceptance
    dynamics are noise, but schedule/exactness behaviour is unchanged
    and there is no multi-minute training step.  Same return shape as
    :func:`build_pair`."""
    cfg_t, cfg_d = target_config(), draft_config()
    pt = init_params(model_specs(cfg_t), jax.random.PRNGKey(1), jnp.float32)
    pd = init_params(model_specs(cfg_d), jax.random.PRNGKey(2), jnp.float32)
    return cfg_t, cfg_d, pt, pd, 0.1


def build_pair(regime: str = "llama"):
    """Returns (cfg_t, cfg_d, params_t, params_d, cost_ratio)."""
    os.makedirs(CACHE_DIR, exist_ok=True)
    stream = mixed_stream()
    cfg_t = target_config()
    pt = _train_cached("target", cfg_t, stream, steps=1600)
    if regime == "llama":
        cfg_d = draft_config(weak=False)
        pd = _train_cached("draft_llama", cfg_d, stream, steps=1000, seed=5)
    elif regime == "gemma":
        cfg_d = draft_config(weak=True)
        # divergent training distribution: only half the tasks
        half = np.concatenate([dataset("code").stream(100000, seed=9),
                               dataset("news").stream(100000, seed=10)])
        pd = _train_cached("draft_gemma", cfg_d, half, steps=180, seed=9)
    else:
        raise ValueError(regime)
    # cost ratio for the latency model: emulate the PAPER's deployments
    # (LLaMA-3.2-1B/LLaMA-3.1-70B ~ 0.014; Gemma-2B/27B ~ 0.074).  The CPU
    # miniatures are embedding-dominated, so their parameter ratio (~0.3)
    # wildly overstates what a real draft costs.
    ratio = 0.014 if regime == "llama" else 0.074
    return cfg_t, cfg_d, pt, pd, ratio


def serve(cfg_t, cfg_d, pt, pd, prompts: List[List[int]], *,
          policy: str = "dsde", temperature: float = 0.0,
          max_new: int = 48, batch: int = 8, use_cap: bool = True,
          static_sl: int = 4, sl_max: int = 10, adaedl_base: int = 7,
          adaedl_threshold: float = 0.02, seed: int = 0,
          max_seq_len: int = 512,
          goodput_draft_cost: Optional[float] = None,
          max_new_per_req: Optional[List[int]] = None,
          paged: bool = False, kv_block_size: int = 16,
          num_kv_blocks: Optional[int] = None,
          prefix_caching: bool = False,
          pipelined: bool = False, drafter: str = "model",
          mesh: Optional[str] = None, kv_quant: str = "none"
          ) -> Tuple[Dict, List[Request], ServingEngine]:
    """``mesh``: optional ``DxM`` string ("1x4") — serve under a
    (data, model) mesh (DESIGN.md §5; needs forced host devices)."""
    extra = {}
    if goodput_draft_cost is not None:
        # the goodput controller's cost model should use the same pair
        # cost ratio the latency_units report uses (None = sourced from
        # the drafter's own step_cost())
        extra["goodput_draft_cost"] = goodput_draft_cost
    spec = SpecDecodeConfig(policy=policy, drafter=drafter,
                            temperature=temperature,
                            use_sl_cap=use_cap, static_sl=static_sl,
                            sl_max=sl_max, adaedl_base=adaedl_base,
                            adaedl_threshold=adaedl_threshold,
                            # miniature-regime KLD scales (DESIGN.md §3):
                            # scale-invariant SF keeps Eq. 2's dynamic range
                            sf_normalize=True, **extra)
    if not build_drafter(spec, cfg_t, cfg_d).uses_draft_model():
        pd, cfg_d = None, None   # model-free proposer: no draft params
    mesh_obj = None
    if mesh is not None:
        from repro.launch.mesh import serving_mesh
        mesh_obj = serving_mesh(mesh)
    eng = ServingEngine(pt, cfg_t, pd, cfg_d, spec,
                        ServingConfig(max_batch_size=batch,
                                      max_seq_len=max_seq_len,
                                      paged_kv=paged,
                                      kv_block_size=kv_block_size,
                                      num_kv_blocks=num_kv_blocks,
                                      prefix_caching=prefix_caching,
                                      pipelined=pipelined,
                                      kv_quant=kv_quant),
                        seed=seed, mesh=mesh_obj)
    reqs = [Request(i, prompt=p,
                    max_new_tokens=(max_new_per_req[i]
                                    if max_new_per_req is not None
                                    else max_new))
            for i, p in enumerate(prompts)]
    metrics = eng.run(reqs)
    return metrics, reqs, eng


def dist_stats(values, prefix: str,
               ps: Tuple[int, ...] = (50, 99)) -> Dict[str, float]:
    """Mean + percentile summary of a latency/size distribution, keyed
    ``{prefix}_mean`` / ``{prefix}_p{P}``.  Empty-safe (all-zero) and
    None-filtering, so callers can pass raw per-request metric lists
    (``[r.ttft() for r in reqs]``) without pre-cleaning.  The one shared
    definition keeps every table's \"p99\" the same p99
    (``np.percentile``, linear interpolation)."""
    vals = [v for v in values if v is not None]
    out = {f"{prefix}_mean": float(np.mean(vals)) if vals else 0.0}
    for p in ps:
        out[f"{prefix}_p{p}"] = (float(np.percentile(vals, p))
                                 if vals else 0.0)
    return out


def latency_units(metrics: Dict, cost_ratio: float) -> float:
    """Hardware-neutral serving cost: target rounds + draft-step cost.
    Uses *effective* draft steps (early-stopping policies like AdaEDL skip
    the remaining steps on real dynamic-shape runtimes; our fixed XLA
    bucket masks them instead)."""
    steps = metrics.get("draft_steps_effective", metrics["draft_steps"])
    return metrics["rounds"] + steps * cost_ratio


def row(name: str, wall_us: float, derived: str) -> str:
    return f"{name},{wall_us:.1f},{derived}"
