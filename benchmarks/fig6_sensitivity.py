"""Paper Fig. 6: hyperparameter sensitivity.

Static SL sweeps {2..10} (the U-shaped latency curve) and AdaEDL sweeps its
base {3..10}; DSDE is run once with defaults.  Reproduced claim: static SL
is sharply sensitive, AdaEDL mildly, DSDE needs no per-dataset knob."""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks import common


def run() -> List[str]:
    cfg_t, cfg_d, pt, pd, ratio = common.build_pair("llama")
    prompts = common.dataset("qa").prompts(8, 16, seed=6)
    rows = []
    t0 = time.monotonic()

    static_lu = {}
    for sl in (2, 4, 6, 8, 10):
        m, _, _ = common.serve(cfg_t, cfg_d, pt, pd, prompts,
                               policy="static", static_sl=sl)
        static_lu[sl] = common.latency_units(m, ratio)
    adaedl_lu = {}
    for base in (3, 5, 7, 10):
        m, _, _ = common.serve(cfg_t, cfg_d, pt, pd, prompts,
                               policy="adaedl", adaedl_base=base)
        adaedl_lu[base] = common.latency_units(m, ratio)
    m, _, _ = common.serve(cfg_t, cfg_d, pt, pd, prompts, policy="dsde")
    dsde_lu = common.latency_units(m, ratio)
    wall = (time.monotonic() - t0) * 1e6

    def spread(d):
        v = np.asarray(list(d.values()))
        return float(v.max() / v.min())

    for sl, lu in static_lu.items():
        rows.append(common.row(f"fig6/static_sl{sl}", wall / 10,
                               f"latency_units={lu:.1f}"))
    for b, lu in adaedl_lu.items():
        rows.append(common.row(f"fig6/adaedl_base{b}", wall / 10,
                               f"latency_units={lu:.1f}"))
    rows.append(common.row("fig6/dsde_default", wall / 10,
                           f"latency_units={dsde_lu:.1f}"))
    rows.append(common.row(
        "fig6/sensitivity_spread", 0.0,
        f"static_maxmin={spread(static_lu):.2f};"
        f"adaedl_maxmin={spread(adaedl_lu):.2f};dsde_maxmin=1.00"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
