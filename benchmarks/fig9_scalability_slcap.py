"""Paper Fig. 9: throughput scalability of per-sequence speculation across
batch sizes, with and without SL_cap.

Claim to reproduce: the uncapped per-sequence strategy scales sub-linearly
(straggler problem: one aggressive SL prediction stalls the whole batch —
here: every round runs to K = max_i SL_i, so stragglers inflate total
draft work per emitted token); SL_cap restores scalability.

Throughput proxy: tokens per latency-unit (hardware-neutral; wall-clock is
also reported).
"""
from __future__ import annotations

import time
from typing import List

from benchmarks import common


def run() -> List[str]:
    cfg_t, cfg_d, pt, pd, ratio = common.build_pair("llama")
    rows = []
    for temp in (0.0, 1.0):
        base = {}
        for use_cap in (True, False):
            for batch in (1, 4, 16):
                prompts = []
                for i, name in enumerate(common.DATASETS):
                    prompts += common.dataset(name).prompts(
                        max(batch // 4, 1), 12, seed=7 + i)
                prompts = (prompts * batch)[:batch]
                t0 = time.monotonic()
                m, _, _ = common.serve(cfg_t, cfg_d, pt, pd, prompts,
                                       policy="dsde", temperature=temp,
                                       use_cap=use_cap, batch=batch,
                                       max_new=32)
                wall = (time.monotonic() - t0) * 1e6
                lu = common.latency_units(m, ratio)
                thr = m["tokens_emitted"] / lu
                key = ("cap" if use_cap else "nocap", temp)
                if batch == 1:
                    base[key] = thr
                scale = thr / base[key]
                rows.append(common.row(
                    f"fig9/temp{temp}/{'cap' if use_cap else 'nocap'}"
                    f"/batch{batch}", wall,
                    f"tok_per_lu={thr:.2f};scale_vs_b1={scale:.2f}x;"
                    f"wall_tok_s={m['throughput_tok_s']:.1f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
