"""Perf-trajectory gate: consolidate the CI smoke-benchmark JSONs into one
``BENCH_pr.json`` and fail the fast job when a metric regresses past its
tolerance against the committed ``benchmarks/baseline.json``.

Why a gate and not just artifacts: the fast job has uploaded the table6 /
table7 smoke JSONs since PR 3, but nothing ever *read* them — a PR could
halve block efficiency or double round counts and CI would stay green.
The gate turns the trajectory into a contract:

* ``collect`` flattens the smoke JSONs into a list of entries
  ``{bench, metric, value, tolerance, better, mode}`` —

  - ``better``: ``lower`` | ``higher`` | ``exact`` (regression direction);
  - ``tolerance``: allowed relative drift in the bad direction;
  - ``mode``: ``fail`` (deterministic metrics: round counts, block
    efficiency, acceptance, emitted tokens — the greedy smoke lane is
    seeded, so these are bit-stable across hosts) or ``warn`` — the
    documented 2-core escape hatch for wall-clock-derived numbers
    (``table6/WARN`` in benchmarks/table6_pipeline_overlap.py: host
    python and XLA share saturated cores on CI runners, so overlap wins
    are noise there; the gate reports but never fails on them).

* ``compare`` diffs a PR's ``BENCH_pr.json`` against the committed
  baseline, prints a before/after markdown table (appended to
  ``$GITHUB_STEP_SUMMARY`` when ``--summary`` is given), and exits
  non-zero on any hard regression.  A metric present in the baseline
  but missing from the PR run is a hard failure (a silently dropped
  benchmark is a regression); metrics new in the PR are listed so the
  author remembers to re-seed the baseline.

Re-seeding after an intentional change::

    PYTHONPATH=src python -m benchmarks.table6_pipeline_overlap --smoke \
        --json table6.json
    PYTHONPATH=src python -m benchmarks.table7_drafter_matrix --smoke \
        --json table7.json
    PYTHONPATH=src python -m benchmarks.table8_prefix_cache --smoke \
        --json table8.json
    PYTHONPATH=src python -m benchmarks.table9_quant_kv --smoke \
        --json table9.json
    PYTHONPATH=src python -m benchmarks.table10_saturation --smoke \
        --json table10.json
    PYTHONPATH=src python -m benchmarks.table11_slo --smoke \
        --json table11.json
    PYTHONPATH=src python -m benchmarks.gate collect --table6 table6.json \
        --table7 table7.json --table8 table8.json --table9 table9.json \
        --table10 table10.json --table11 table11.json \
        --out benchmarks/baseline.json
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional

EPS = 1e-12


def _entry(bench: str, metric: str, value, tolerance: float, better: str,
           mode: str = "fail") -> Dict:
    return {"bench": bench, "metric": metric, "value": float(value),
            "tolerance": float(tolerance), "better": better, "mode": mode}


def collect_table6(t6: Dict) -> List[Dict]:
    out = []
    for label in ("sync", "pipelined"):
        m = t6[label]
        # deterministic under the seeded greedy smoke lane
        out.append(_entry("table6", f"{label}.rounds", m["rounds"],
                          0.10, "lower"))
        out.append(_entry("table6", f"{label}.tokens", m["tokens"],
                          0.0, "exact"))
        # wall-derived: the 2-core WARN escape hatch — report, never fail
        out.append(_entry("table6", f"{label}.host_blocked_mean_s",
                          m["host_blocked_mean_s"], 0.50, "lower",
                          mode="warn"))
    out.append(_entry("table6", "streams_identical",
                      1.0 if t6.get("streams_identical") else 0.0,
                      0.0, "exact"))
    out.append(_entry("table6", "speedup", t6["speedup"], 0.25, "higher",
                      mode="warn"))
    return out


def collect_table7(t7: Dict) -> List[Dict]:
    out = []
    for cell, m in sorted(t7.items()):
        out.append(_entry("table7", f"{cell}.rounds", m["rounds"],
                          0.10, "lower"))
        out.append(_entry("table7", f"{cell}.latency_units",
                          m["latency_units"], 0.10, "lower"))
        out.append(_entry("table7", f"{cell}.block_efficiency",
                          m["block_efficiency"], 0.10, "higher"))
        # a zero baseline can never fail a higher-is-better check (the
        # relative delta is >= 0 for any PR value), so emit acceptance
        # only when nonzero — then a PR whose acceptance COLLAPSES to 0
        # omits the entry and trips the hard missing-metric failure,
        # instead of sailing past an unfailable 0-vs-0 comparison
        if m["mean_acceptance"] > 0:
            out.append(_entry("table7", f"{cell}.mean_acceptance",
                              m["mean_acceptance"], 0.15, "higher"))
        out.append(_entry("table7", f"{cell}.requests_finished",
                          m["requests_finished"], 0.0, "exact"))
        # capacity invariant: model-free drafters double the paged pool
        out.append(_entry("table7", f"{cell}.kv_pool_blocks",
                          m["kv_pool_blocks"], 0.0, "exact"))
    return out


def collect_table8(t8: Dict) -> List[Dict]:
    out = []
    for cell, m in sorted(t8.items()):
        if cell == "paged_half_shared":
            out.append(_entry("table8", "half_pool.requests_finished",
                              m["requests_finished"], 0.0, "exact"))
            out.append(_entry("table8", "half_pool.kv_pool_blocks",
                              m["kv_pool_blocks"], 0.0, "exact"))
            out.append(_entry("table8", "half_pool.tok_per_round",
                              m["tok_per_round"], 0.10, "higher"))
            continue
        # prefill token area and dispatch count are deterministic
        # functions of the (seeded) mix and the cache plan — bit-stable
        out.append(_entry("table8", f"{cell}.prefill_tokens_on",
                          m["prefill_tokens_on"], 0.0, "exact"))
        out.append(_entry("table8", f"{cell}.prefill_calls_on",
                          m["prefill_calls_on"], 0.0, "exact"))
        if m["prefix_cache_hit_rate"] > 0:     # see table7's zero note
            out.append(_entry("table8", f"{cell}.prefix_cache_hit_rate",
                              m["prefix_cache_hit_rate"], 0.10, "higher"))
            out.append(_entry("table8", f"{cell}.prefix_cache_hit_blocks",
                              m["prefix_cache_hit_blocks"], 0.0, "exact"))
        # wall-derived: the 2-core WARN escape hatch — report, never fail
        out.append(_entry("table8", f"{cell}.ttft_speedup",
                          m["ttft_speedup"], 0.50, "higher", mode="warn"))
    return out


def collect_table9(t9: Dict) -> List[Dict]:
    out = []
    for cell, m in sorted(t9.items()):
        # completion + pool geometry are deterministic under the seeded
        # greedy smoke lane; byte metrics are pure arithmetic of the
        # config and must never drift silently
        out.append(_entry("table9", f"{cell}.requests_finished",
                          m["requests_finished"], 0.0, "exact"))
        out.append(_entry("table9", f"{cell}.kv_pool_blocks",
                          m["kv_pool_blocks"], 0.0, "exact"))
        out.append(_entry("table9", f"{cell}.kv_block_bytes",
                          m["kv_block_bytes"], 0.0, "exact"))
        out.append(_entry("table9", f"{cell}.rounds", m["rounds"],
                          0.10, "lower"))
        out.append(_entry("table9", f"{cell}.tok_per_round",
                          m["tok_per_round"], 0.10, "higher"))
        out.append(_entry("table9", f"{cell}.kv_bytes_swept",
                          m["kv_bytes_swept"], 0.10, "lower"))
        if "prefix_match_frac" in m:
            # stream divergence vs the fp engine: seeded + greedy, so
            # bit-stable — a drop means storage numerics changed
            out.append(_entry("table9", f"{cell}.prefix_match_frac",
                              m["prefix_match_frac"], 0.0, "exact"))
    return out


def collect_table10(t10: Dict) -> List[Dict]:
    out = []
    for process in ("poisson", "bursty"):
        for point in t10[process]["points"]:
            cell = f"{process}_x{point['load_ratio']}"
            # deterministic under the seeded greedy traces: fixed
            # max_new budgets, no EOS → exact totals whatever the
            # arrival timing did to admission order or preemption
            # (benchmarks/table10_saturation.py asserts them in-run)
            out.append(_entry("table10", f"{cell}.requests_finished",
                              point["requests_finished"], 0.0, "exact"))
            out.append(_entry("table10", f"{cell}.tokens_emitted",
                              point["tokens_emitted"], 0.0, "exact"))
            # wall-derived latency/goodput: the 2-core WARN escape
            # hatch — report, never fail (table6 precedent)
            out.append(_entry("table10", f"{cell}.ttft_s_p50",
                              point["ttft_s_p50"], 0.50, "lower",
                              mode="warn"))
            out.append(_entry("table10", f"{cell}.ttft_s_p99",
                              point["ttft_s_p99"], 0.50, "lower",
                              mode="warn"))
            out.append(_entry("table10", f"{cell}.tpot_s_p50",
                              point["tpot_s_p50"], 0.50, "lower",
                              mode="warn"))
            out.append(_entry("table10", f"{cell}.goodput_tok_s",
                              point["goodput_tok_s"], 0.50, "higher",
                              mode="warn"))
            out.append(_entry("table10", f"{cell}.queue_depth_peak",
                              point["queue_depth_peak"], 0.50, "lower",
                              mode="warn"))
    return out


def collect_table11(t11: Dict) -> List[Dict]:
    out = []
    for cell, policies in sorted(t11["points"].items()):
        for policy, point in sorted(policies.items()):
            # deterministic under the seeded greedy traces (greedy
            # streams are K-invariant and the SLO gate defers but never
            # drops, so totals are exact whatever the timing did —
            # benchmarks/table11_slo.py asserts them in-run)
            out.append(_entry("table11",
                              f"{cell}.{policy}.requests_finished",
                              point["requests_finished"], 0.0, "exact"))
            out.append(_entry("table11", f"{cell}.{policy}.tokens_emitted",
                              point["tokens_emitted"], 0.0, "exact"))
            # the latency model must be FIT by end of run — readiness is
            # deterministic (min_rounds is far below any smoke's round
            # count), only the coefficients are host-dependent
            out.append(_entry("table11",
                              f"{cell}.{policy}.latency_model_ready",
                              point["latency_model_ready"], 0.0, "exact"))
            # wall-derived SLO goodput / attainment: the 2-core WARN
            # escape hatch — report, never fail (table10 precedent)
            out.append(_entry("table11", f"{cell}.{policy}.goodput_tok_s",
                              point["goodput_tok_s"], 0.50, "higher",
                              mode="warn"))
            out.append(_entry("table11",
                              f"{cell}.{policy}.slo_attained_frac",
                              point["slo_attained_frac"], 0.50, "higher",
                              mode="warn"))
            out.append(_entry("table11", f"{cell}.{policy}.ttft_s_p99",
                              point["ttft_s_p99"], 0.50, "lower",
                              mode="warn"))
    return out


def cmd_collect(args) -> int:
    entries: List[Dict] = []
    if args.table6:
        with open(args.table6) as f:
            entries += collect_table6(json.load(f))
    if args.table7:
        with open(args.table7) as f:
            entries += collect_table7(json.load(f))
    if args.table8:
        with open(args.table8) as f:
            entries += collect_table8(json.load(f))
    if args.table9:
        with open(args.table9) as f:
            entries += collect_table9(json.load(f))
    if args.table10:
        with open(args.table10) as f:
            entries += collect_table10(json.load(f))
    if args.table11:
        with open(args.table11) as f:
            entries += collect_table11(json.load(f))
    with open(args.out, "w") as f:
        json.dump(entries, f, indent=2, sort_keys=True)
    print(f"[gate] wrote {len(entries)} metrics -> {args.out}")
    return 0


def _verdict(base: Dict, pr_value: float) -> str:
    """'ok' | 'warn' | 'fail' for one metric against its baseline entry."""
    delta = (pr_value - base["value"]) / max(abs(base["value"]), EPS)
    better, tol = base["better"], base["tolerance"]
    bad = ((better == "lower" and delta > tol)
           or (better == "higher" and delta < -tol)
           or (better == "exact" and abs(delta) > tol + EPS))
    if not bad:
        return "ok"
    return "warn" if base.get("mode") == "warn" else "fail"


def cmd_compare(args) -> int:
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.pr) as f:
        pr = json.load(f)
    pr_by_key = {(e["bench"], e["metric"]): e for e in pr}
    rows: List[str] = ["| bench | metric | baseline | PR | Δ | verdict |",
                       "|---|---|---:|---:|---:|---|"]
    failures: List[str] = []
    warns: List[str] = []
    seen = set()
    for base in baseline:
        key = (base["bench"], base["metric"])
        seen.add(key)
        e = pr_by_key.get(key)
        if e is None:
            failures.append(f"{key[0]}/{key[1]}: missing from PR run")
            rows.append(f"| {key[0]} | {key[1]} | {base['value']:.4g} | "
                        f"— | — | MISSING |")
            continue
        delta = ((e["value"] - base["value"])
                 / max(abs(base["value"]), EPS))
        v = _verdict(base, e["value"])
        if v == "fail":
            failures.append(
                f"{key[0]}/{key[1]}: {base['value']:.4g} -> "
                f"{e['value']:.4g} ({delta:+.1%}, tol "
                f"{base['tolerance']:.0%}, better={base['better']})")
        elif v == "warn":
            warns.append(f"{key[0]}/{key[1]}: {delta:+.1%} "
                         "(warn-only: wall-clock noise escape hatch)")
        mark = {"ok": "✓", "warn": "WARN", "fail": "**FAIL**"}[v]
        rows.append(f"| {key[0]} | {key[1]} | {base['value']:.4g} | "
                    f"{e['value']:.4g} | {delta:+.1%} | {mark} |")
    new = [k for k in pr_by_key if k not in seen]
    table = "\n".join(rows)
    report = ["## Bench gate: PR vs committed baseline", "", table, ""]
    if new:
        report.append(f"**{len(new)} new metric(s)** without a baseline "
                      "(re-seed benchmarks/baseline.json): "
                      + ", ".join(f"{b}/{m}" for b, m in sorted(new)))
    if warns:
        report.append("### Warnings (non-fatal)")
        report += [f"- {w}" for w in warns]
    if failures:
        report.append("### Regressions past tolerance")
        report += [f"- {f}" for f in failures]
    text = "\n".join(report)
    print(text)
    if args.summary:
        with open(args.summary, "a") as f:
            f.write(text + "\n")
    if failures:
        print(f"\n[gate] FAIL: {len(failures)} metric(s) regressed past "
              "tolerance", file=sys.stderr)
        return 1
    print(f"\n[gate] OK: {len(baseline)} metrics within tolerance "
          f"({len(warns)} warn-only)")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    c = sub.add_parser("collect",
                       help="flatten smoke JSONs into BENCH_pr.json")
    c.add_argument("--table6", default=None)
    c.add_argument("--table7", default=None)
    c.add_argument("--table8", default=None)
    c.add_argument("--table9", default=None)
    c.add_argument("--table10", default=None)
    c.add_argument("--table11", default=None)
    c.add_argument("--out", required=True)
    c.set_defaults(fn=cmd_collect)
    d = sub.add_parser("compare", help="diff PR metrics vs the baseline")
    d.add_argument("--baseline", required=True)
    d.add_argument("--pr", required=True)
    d.add_argument("--summary", default=None,
                   help="markdown file to append the table to "
                        "(e.g. $GITHUB_STEP_SUMMARY)")
    d.set_defaults(fn=cmd_compare)
    args = ap.parse_args()
    sys.exit(args.fn(args))


if __name__ == "__main__":
    main()
