"""Trace-replay load generation for the serving front-end (DESIGN.md §14).

A *trace* is a deterministic, seeded, JSON-serializable request set with
arrival offsets — the reproducibility unit for every saturation number
this repo reports.  Format (``version`` 2):

    {"version": 2, "seed": 0, "process": "poisson", "rate_rps": 4.0,
     "requests": [{"request_id": 0, "arrival_s": 0.0,
                   "prompt": [...], "max_new_tokens": 12,
                   "dataset": "code",
                   "slo_deadline_s": 3.5, "priority": 0}, ...]}

``slo_deadline_s`` (completion deadline, seconds from arrival) and
``priority`` are optional per request — version-1 traces (no SLO
fields) still load, with deadlines defaulting to None, and
``make_trace`` emits version 1 unless deadlines are requested, so every
pre-v2 trace and consumer is untouched (DESIGN.md §15).

Arrival processes (both seeded):

* ``poisson`` — exponential interarrivals at ``rate_rps`` (the classic
  open-loop arrival model);
* ``bursty``  — Gamma interarrivals with shape ``BURST_SHAPE`` < 1 and
  the same mean, i.e. the same offered load with coefficient of
  variation 1/sqrt(shape) ≈ 2: arrivals clump into on-off bursts that
  stress admission and the preemption path far harder than Poisson at
  equal rate.

Prompt/output heterogeneity comes from the benchmark corpus mix
(``common.DATASETS``): per-request dataset, prompt length, and
``max_new_tokens`` are drawn from the trace seed, so a trace replays
the exact same workload on any machine.

``replay`` drives a trace through a :class:`ServingFrontend` at real
(optionally time-scaled) arrival times; ``replay_at_zero`` submits
everything up front and single-threaded-drains — the mode whose streams
are byte-identical to ``ServingEngine.run()`` (the exactness bar
tests/test_frontend.py pins).
"""
from __future__ import annotations

import json
import time
import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from benchmarks import common
from repro.serving.frontend import ServingFrontend
from repro.serving.request import Request, RequestState

BURST_SHAPE = 0.25            # Gamma shape: CV = 2 at equal mean rate

# per-dataset (prompt_len_lo, hi), (max_new_lo, hi): code-like traffic
# is short-prompt/short-output, news-like is long-prompt/long-output
MIX: Dict[str, Tuple[Tuple[int, int], Tuple[int, int]]] = {
    "code": ((8, 16), (8, 16)),
    "qa": ((12, 24), (8, 24)),
    "news": ((24, 48), (16, 32)),
    "dialogue": ((8, 32), (8, 32)),
}


def make_trace(n_requests: int, rate_rps: float, process: str = "poisson",
               seed: int = 0, max_new_cap: Optional[int] = None,
               deadline: Optional[Tuple[float, float]] = None) -> Dict:
    """Deterministic trace: same args → same trace, any machine.

    Requests and arrivals come from SEPARATE rng streams, both derived
    from ``seed``: the request set (prompts, budgets) depends only on
    ``(n_requests, seed, max_new_cap)``, so every point of a saturation
    ladder serves the *identical workload* and only the arrival pattern
    varies — the comparison isolates load, and one warmup covers every
    point's prefill shapes.

    ``deadline=(base_s, per_token_s)`` stamps each request with a
    completion deadline ``base_s + per_token_s * max_new_tokens``
    (output-proportional, so long generations get proportionally more
    wall) and bumps the trace to version 2; None (the default) keeps the
    deadline-free version-1 format byte-identical to pre-v2 traces."""
    assert process in ("poisson", "bursty"), process
    rng = np.random.RandomState(seed)
    rng_arr = np.random.RandomState(
        (seed + zlib.crc32(process.encode())) % 2**31)
    if process == "poisson":
        gaps = rng_arr.exponential(1.0 / rate_rps, size=n_requests)
    else:
        gaps = rng_arr.gamma(BURST_SHAPE, 1.0 / (rate_rps * BURST_SHAPE),
                             size=n_requests)
    arrivals = np.cumsum(gaps)
    arrivals[0] = 0.0                       # the trace starts at its head
    names = list(MIX)
    reqs = []
    for i in range(n_requests):
        name = names[rng.randint(len(names))]
        (plo, phi), (nlo, nhi) = MIX[name]
        plen = int(rng.randint(plo, phi + 1))
        max_new = int(rng.randint(nlo, nhi + 1))
        if max_new_cap is not None:
            max_new = min(max_new, max_new_cap)
        prompt = common.dataset(name).prompts(1, plen,
                                              seed=seed * 100003 + i)[0]
        rec = {"request_id": i, "arrival_s": float(arrivals[i]),
               "prompt": [int(t) for t in prompt],
               "max_new_tokens": max_new, "dataset": name}
        if deadline is not None:
            base_s, per_token_s = deadline
            rec["slo_deadline_s"] = float(base_s + per_token_s * max_new)
            rec["priority"] = 0
        reqs.append(rec)
    return {"version": 2 if deadline is not None else 1, "seed": seed,
            "process": process, "rate_rps": rate_rps, "requests": reqs}


def save_trace(trace: Dict, path: str) -> None:
    with open(path, "w") as f:
        json.dump(trace, f)


def load_trace(path: str) -> Dict:
    with open(path) as f:
        trace = json.load(f)
    assert trace.get("version") in (1, 2), "unknown trace version"
    return trace


def _trace_request(r: Dict) -> Request:
    return Request(r["request_id"], prompt=list(r["prompt"]),
                   max_new_tokens=r["max_new_tokens"],
                   slo_deadline_s=r.get("slo_deadline_s"),
                   priority=int(r.get("priority", 0)))


def trace_requests(trace: Dict) -> List[Request]:
    """Materialize the trace as engine Requests (ids from the trace, so
    identity-threaded RNG reproduces stochastic streams exactly).  v2
    SLO fields thread through; v1 requests get deadline None."""
    return [_trace_request(r) for r in trace["requests"]]


def replay(frontend: ServingFrontend, trace: Dict,
           time_scale: float = 1.0, settle_s: float = 120.0) -> Dict:
    """Open-loop replay: submit each request when its (scaled) arrival
    time comes due, against the front-end's already-running driver
    thread, then wait for drain.  Returns the per-point report."""
    t0 = time.monotonic()
    handles = []
    for r in trace["requests"]:
        due = t0 + r["arrival_s"] * time_scale
        delay = due - time.monotonic()
        if delay > 0:
            time.sleep(delay)
        req = _trace_request(r)
        req.arrival_time = time.monotonic()   # deadline clock starts NOW
        handles.append(frontend.submit_request(req))
    idle = frontend.wait_idle(timeout=settle_s)
    assert idle, "replay did not drain within settle_s"
    wall = time.monotonic() - t0
    return report(frontend, [h.request for h in handles], wall,
                  offered_rps=trace["rate_rps"] / time_scale)


def replay_at_zero(frontend: ServingFrontend, trace: Dict) -> Dict:
    """All arrivals at time 0, single-threaded drain — the replay mode
    that is byte-identical to a direct ``run()`` call."""
    t0 = time.monotonic()
    reqs = trace_requests(trace)
    for r in reqs:
        frontend.submit_request(r)
    frontend.run_until_drained()
    return report(frontend, reqs, time.monotonic() - t0,
                  offered_rps=float("inf"))


def report(frontend: ServingFrontend, reqs: List[Request], wall: float,
           offered_rps: float, slo_ttft_s: float = 2.5,
           slo_tpot_s: float = 0.5) -> Dict:
    """Per-load-point serving report: TTFT/TPOT p50/p99, queue depth,
    and goodput — output tokens/s counting ONLY SLO-attaining requests
    (TTFT and TPOT both within bound, plus each request's own
    ``slo_deadline_s`` when the trace carries one — the shared
    ``Request.slo_attained`` definition), the quantity that actually
    saturates when spec-decode wins evaporate under load."""
    fin = [r for r in reqs if r.state is RequestState.FINISHED]
    out = {"offered_rps": float(offered_rps), "wall_s": float(wall),
           "requests": len(reqs), "requests_finished": len(fin),
           "requests_rejected": sum(
               r.state is RequestState.REJECTED for r in reqs),
           "tokens_emitted": int(sum(len(r.output) for r in fin)),
           "preemptions": int(sum(r.preemptions for r in reqs))}
    out.update(common.dist_stats([r.ttft() for r in fin], "ttft_s"))
    out.update(common.dist_stats([r.tpot() for r in fin], "tpot_s"))
    out.update(common.dist_stats([r.queue_wait() for r in fin],
                                 "queue_wait_s"))
    depths = [q + s for _, q, s, _ in frontend.queue_depth_log]
    out.update(common.dist_stats(depths, "queue_depth", ps=(99,)))
    out["queue_depth_peak"] = float(max(depths, default=0))
    out["throughput_tok_s"] = out["tokens_emitted"] / max(wall, 1e-9)
    good = [r for r in fin if r.slo_attained(slo_ttft_s, slo_tpot_s)]
    out["slo_attained_frac"] = len(good) / max(len(fin), 1)
    out["goodput_tok_s"] = (sum(len(r.output) for r in good)
                            / max(wall, 1e-9))
    return out
