"""Roofline table (deliverable g): reads the dry-run JSON and prints the
three-term analysis per (arch x shape) — compute / memory / collective
seconds, dominant bottleneck, MODEL_FLOPS ratio, and a one-line
recommendation for the dominant term.

Run after:  PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun_single_pod.json
"""
from __future__ import annotations

import json
import os
from typing import List

DEFAULT_JSON = os.path.join(os.path.dirname(__file__), "..",
                            "dryrun_single_pod.json")

_RECOMMEND = {
    "compute": ("raise per-chip utilization: larger per-chip tiles / "
                "fewer remat recomputes"),
    "memory": ("raise arithmetic intensity: fuse bandwidth-bound chains "
               "(Pallas), keep accumulators in VMEM, shrink dtype"),
    "collective": ("cut collective volume: better layout (expert/head "
                   "sharding), overlap collectives with compute, "
                   "reduce-scatter instead of all-reduce+slice"),
}


def rows_from_json(path: str = DEFAULT_JSON) -> List[str]:
    if not os.path.exists(path):
        return [f"roofline/missing,0.0,run_dryrun_first:{path}"]
    with open(path) as f:
        recs = json.load(f)
    out = []
    for r in sorted(recs, key=lambda x: (x["arch"], x["shape"])):
        name = f"roofline/{r['arch']}/{r['shape']}"
        if r["status"] == "skipped":
            out.append(f"{name},0.0,skipped:{r['reason'][:60]}")
            continue
        if r["status"] != "ok":
            out.append(f"{name},0.0,ERROR:{r.get('error', '?')[:60]}")
            continue
        rf = r["roofline"]
        ratio = rf.get("useful_flops_ratio")
        out.append(
            f"{name},{r['compile_s'] * 1e6:.0f},"
            f"compute_s={rf['compute_s']:.3e};memory_s={rf['memory_s']:.3e};"
            f"collective_s={rf['collective_s']:.3e};"
            f"bottleneck={rf['bottleneck']};"
            f"useful_flops_ratio={ratio:.3f};"
            f"fix={_RECOMMEND[rf['bottleneck']][:48]}")
    return out


def run() -> List[str]:
    return rows_from_json()


if __name__ == "__main__":
    print("\n".join(run()))
