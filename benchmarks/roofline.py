"""Roofline table (deliverable g): reads the dry-run JSON and prints the
three-term analysis per (arch x shape) — compute / memory / collective
seconds, dominant bottleneck, MODEL_FLOPS ratio, and a one-line
recommendation for the dominant term.

Run after:  PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun_single_pod.json

Plus the KV-sweep section (DESIGN.md §13): decode/verify attention is
bandwidth-bound, so its roofline term is KV bytes streamed per verify
round.  ``kv_sweep_rows`` serves one tiny mix per storage mode and
reports the MODELED bytes/round (mean resident blocks x bytes per block
from ``cache_lib.kv_block_bytes``) against the ACHIEVED bytes/round the
engine telemetry integrates (``kv_bytes_swept / rounds``) — fp32 vs
int8, same block geometry.  The two agree by construction of the
telemetry; the row exists so the fp-vs-int8 bytes ratio (the fused
dequant kernel's bandwidth win) is tracked with the roofline numbers."""
from __future__ import annotations

import json
import os
from typing import List

DEFAULT_JSON = os.path.join(os.path.dirname(__file__), "..",
                            "dryrun_single_pod.json")

_RECOMMEND = {
    "compute": ("raise per-chip utilization: larger per-chip tiles / "
                "fewer remat recomputes"),
    "memory": ("raise arithmetic intensity: fuse bandwidth-bound chains "
               "(Pallas), keep accumulators in VMEM, shrink dtype"),
    "collective": ("cut collective volume: better layout (expert/head "
                   "sharding), overlap collectives with compute, "
                   "reduce-scatter instead of all-reduce+slice"),
}


def rows_from_json(path: str = DEFAULT_JSON) -> List[str]:
    if not os.path.exists(path):
        return [f"roofline/missing,0.0,run_dryrun_first:{path}"]
    with open(path) as f:
        recs = json.load(f)
    out = []
    for r in sorted(recs, key=lambda x: (x["arch"], x["shape"])):
        name = f"roofline/{r['arch']}/{r['shape']}"
        if r["status"] == "skipped":
            out.append(f"{name},0.0,skipped:{r['reason'][:60]}")
            continue
        if r["status"] != "ok":
            out.append(f"{name},0.0,ERROR:{r.get('error', '?')[:60]}")
            continue
        rf = r["roofline"]
        ratio = rf.get("useful_flops_ratio")
        out.append(
            f"{name},{r['compile_s'] * 1e6:.0f},"
            f"compute_s={rf['compute_s']:.3e};memory_s={rf['memory_s']:.3e};"
            f"collective_s={rf['collective_s']:.3e};"
            f"bottleneck={rf['bottleneck']};"
            f"useful_flops_ratio={ratio:.3f};"
            f"fix={_RECOMMEND[rf['bottleneck']][:48]}")
    return out


def kv_sweep_rows() -> List[str]:
    """Achieved vs modeled KV bytes per verify round, fp vs int8 pools."""
    from benchmarks import common
    from repro.models import cache as cache_lib

    cfg_t, cfg_d, pt, pd, _ = common.untrained_pair()
    prompts = common.dataset("code").prompts(4, 16, seed=4)
    out = []
    for kv_quant in ("none", "int8"):
        m, _, _ = common.serve(cfg_t, cfg_d, pt, pd, prompts, max_new=12,
                               max_seq_len=128, batch=4, paged=True,
                               kv_block_size=16, kv_quant=kv_quant)
        block_bytes = cache_lib.kv_block_bytes(cfg_t, 16, kv_quant)
        assert m["kv_block_bytes"] == block_bytes
        rounds = max(m["rounds"], 1)
        achieved = m["kv_bytes_swept"] / rounds
        # model: mean resident blocks/round x bytes per block — resident
        # blocks are what the paged kv-sweep's block-table grid visits
        mean_blocks = (m["kv_pool_utilization_mean"] * m["kv_pool_blocks"])
        modeled = mean_blocks * block_bytes
        tag = "fp" if kv_quant == "none" else kv_quant
        out.append(
            f"roofline/kv_sweep_{tag},0.0,"
            f"modeled_bytes_per_round={modeled:.0f};"
            f"achieved_bytes_per_round={achieved:.0f};"
            f"block_bytes={block_bytes};rounds={rounds:.0f}")
    return out


def run() -> List[str]:
    return rows_from_json() + kv_sweep_rows()


if __name__ == "__main__":
    print("\n".join(run()))
