"""Benchmark harness entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Model training for the
draft/target pairs is cached under $REPRO_BENCH_CACHE (default /tmp), so
the first invocation trains the pairs (~3 min CPU) and later runs reuse
them.

Usage:  PYTHONPATH=src python -m benchmarks.run [table1 table3 ...]
"""
from __future__ import annotations

import sys
import time
import traceback

SUITES = ("table1", "table2", "table3", "table4", "table5", "table6",
          "table7", "table8", "table9", "table10", "table11", "fig6",
          "fig9", "roofline")


def main() -> None:
    want = sys.argv[1:] or list(SUITES)
    print("name,us_per_call,derived")
    failures = 0
    for suite in want:
        t0 = time.monotonic()
        try:
            if suite == "table1":
                from benchmarks.table1_static_heterogeneous import run
            elif suite == "table2":
                from benchmarks.table2_signal_correlation import run
            elif suite == "table3":
                from benchmarks.table3_latency_speedup import run
            elif suite == "table4":
                from benchmarks.table4_low_acceptance import run
            elif suite == "table5":
                from benchmarks.table5_paged_capacity import run
            elif suite == "table6":
                from benchmarks.table6_pipeline_overlap import run
            elif suite == "table7":
                from benchmarks.table7_drafter_matrix import run
            elif suite == "table8":
                from benchmarks.table8_prefix_cache import run
            elif suite == "table9":
                from benchmarks.table9_quant_kv import run
            elif suite == "table10":
                from benchmarks.table10_saturation import run
            elif suite == "table11":
                from benchmarks.table11_slo import run
            elif suite == "fig6":
                from benchmarks.fig6_sensitivity import run
            elif suite == "fig9":
                from benchmarks.fig9_scalability_slcap import run
            elif suite == "roofline":
                from benchmarks.roofline import run
            else:
                raise KeyError(suite)
            for row in run():
                print(row)
        except Exception as e:
            failures += 1
            print(f"{suite}/ERROR,0.0,{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
        finally:
            print(f"{suite}/total,{(time.monotonic() - t0) * 1e6:.0f},done",
                  file=sys.stderr)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
