"""Goodput vs offered load: where do the spec-decode wins evaporate?

"Speculative Decoding: Performance or Illusion?" (PAPERS.md) shows
spec-decode's latency wins shrink — and can invert — as batch load
rises.  This table measures OUR saturation point: seeded traces
(benchmarks/loadgen.py) are replayed open-loop through the serving
front-end (DESIGN.md §14) at a ladder of offered loads under both
arrival processes, and each point reports TTFT/TPOT p50/p99, queue
depth, throughput, and *goodput* — output tokens/s from SLO-attaining
requests only — the curve whose knee IS the serving capacity.

Load points are expressed as multiples of the host's measured closed-
loop capacity (requests/s of an arrival-time-0 replay), so the same
ladder exercises the same relative regimes — comfortable, near-
saturation, overload — on any machine:

* deterministic per point (gate ``mode=fail``): requests_finished and
  tokens_emitted.  Greedy decoding with trace-fixed ``max_new_tokens``
  and no EOS means every request emits exactly its budget regardless
  of admission timing, preemptions, or schedule — the same
  schedule-invariance argument as DESIGN.md §7/§9 — so these counters
  are bit-stable under arbitrary CI timing noise.
* wall-derived per point (gate ``mode=warn``): TTFT/TPOT percentiles,
  goodput, queue depth — real latencies on a shared-core container.

    PYTHONPATH=src python -m benchmarks.table10_saturation
    PYTHONPATH=src python -m benchmarks.table10_saturation \
        --smoke --json /tmp/table10.json    # CI: untrained pair, tiny ladder
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Optional

from benchmarks import common, loadgen
from repro.core.config import ServingConfig, SpecDecodeConfig
from repro.serving.engine import ServingEngine
from repro.serving.frontend import ServingFrontend

BATCH = 4
MAX_SEQ = 256
KV_BLOCK = 16
# offered load as a multiple of measured closed-loop capacity
RATIOS_FULL = (0.5, 0.8, 1.2, 2.0)
RATIOS_SMOKE = (0.6, 1.5)
PROCESSES = ("poisson", "bursty")


def _engine(cfg_t, cfg_d, pt, pd) -> ServingEngine:
    spec = SpecDecodeConfig(policy="dsde", sf_normalize=True)
    sv = ServingConfig(max_batch_size=BATCH, max_seq_len=MAX_SEQ,
                       paged_kv=True, kv_block_size=KV_BLOCK,
                       num_kv_blocks=BATCH * (MAX_SEQ // KV_BLOCK) // 2,
                       pipelined=True)
    return ServingEngine(pt, cfg_t, pd, cfg_d, spec, sv, seed=0)


def run(smoke: bool = False, json_path: Optional[str] = None) -> List[str]:
    if smoke:
        cfg_t, cfg_d, pt, pd, _ = common.untrained_pair()
        n_req, max_new_cap, ratios = 8, 10, RATIOS_SMOKE
    else:
        cfg_t, cfg_d, pt, pd, _ = common.build_pair("llama")
        n_req, max_new_cap, ratios = 24, None, RATIOS_FULL

    # capacity probe doubles as program warmup: closed-loop (all
    # arrivals at 0) replay of a probe trace measures the host's
    # request service rate with zero queueing-from-arrivals.  Same seed
    # as the measurement traces → same request set (loadgen splits the
    # request/arrival rng streams), so this compiles every prefill
    # shape any load point will dispatch.
    probe = loadgen.make_trace(n_req, rate_rps=1.0, process="poisson",
                               seed=11, max_new_cap=max_new_cap)
    fe = ServingFrontend(_engine(cfg_t, cfg_d, pt, pd))
    loadgen.replay_at_zero(fe, probe)           # compile
    fe = ServingFrontend(_engine(cfg_t, cfg_d, pt, pd))
    cap = loadgen.replay_at_zero(fe, probe)
    cap_rps = cap["requests_finished"] / max(cap["wall_s"], 1e-9)

    rows: List[str] = []
    out: Dict[str, object] = {"capacity_rps": cap_rps,
                              "smoke": bool(smoke)}
    for process in PROCESSES:
        points = []
        for ratio in ratios:
            trace = loadgen.make_trace(
                n_req, rate_rps=max(cap_rps * ratio, 1e-3),
                process=process, seed=11, max_new_cap=max_new_cap)
            budget = sum(r["max_new_tokens"] for r in trace["requests"])
            fe = ServingFrontend(_engine(cfg_t, cfg_d, pt, pd)).start()
            t0 = time.monotonic()
            try:
                point = loadgen.replay(fe, trace)
            finally:
                fe.stop()
            # the deterministic counters the gate hard-fails on:
            # greedy + no EOS + trace-fixed budgets → exact totals,
            # whatever the arrival timing did to the schedule
            assert point["requests_finished"] == n_req, point
            assert point["tokens_emitted"] == budget, (
                point["tokens_emitted"], budget)
            point["load_ratio"] = ratio
            points.append(point)
            rows.append(common.row(
                f"table10/{process}_x{ratio}",
                (time.monotonic() - t0) * 1e6,
                f"rps={point['offered_rps']:.2f};"
                f"tok={point['tokens_emitted']};"
                f"ttft_p50_ms={point['ttft_s_p50'] * 1e3:.0f};"
                f"ttft_p99_ms={point['ttft_s_p99'] * 1e3:.0f};"
                f"tpot_p50_ms={point['tpot_s_p50'] * 1e3:.0f};"
                f"qd_peak={point['queue_depth_peak']:.0f};"
                f"goodput_tok_s={point['goodput_tok_s']:.1f};"
                f"slo_frac={point['slo_attained_frac']:.2f}"))
        out[process] = {"points": points}
        # the saturation read-out: overload must queue harder than the
        # comfortable point (arrival pressure is real, not simulated)
        lo, hi = points[0], points[-1]
        if hi["queue_depth_mean"] < lo["queue_depth_mean"]:
            rows.append(common.row(
                f"table10/WARN_{process}", 0.0,
                "overload_queue_not_deeper_than_light_load;"
                "host timing noise suspected"))
    rows.append(common.row("table10/capacity", 0.0,
                           f"closed_loop_rps={cap_rps:.2f}"))
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="untrained pair + tiny ladder (CI lane)")
    ap.add_argument("--json", default=None,
                    help="write the saturation curves as JSON (CI artifact)")
    args = ap.parse_args()
    print("\n".join(run(smoke=args.smoke, json_path=args.json)))


if __name__ == "__main__":
    main()
