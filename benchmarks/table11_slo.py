"""SLO goodput under deadline-carrying load: does the ``slo`` policy's
latency-model arbitration buy attained-deadline tokens/s over dsde and
static speculation? (DESIGN.md §15)

Setup mirrors table10's capacity-relative ladder: a closed-loop probe
measures the host's service rate (and doubles as program warmup AND as
the calibration sweep that warm-starts the analytic per-round latency
model, ``RoundLatencyModel.warm_start_from_rounds``).  Version-2 traces
(benchmarks/loadgen.py) stamp every request with an output-proportional
completion deadline derived from the probe's measured per-token wall,
then each load point replays the identical trace through three
policies:

* ``static``  — fixed-K speculation, deadline-blind;
* ``dsde``    — the paper's KLD controller, deadline-blind;
* ``slo``     — dsde + batch-tightness shrink + SLO admission gating.

Per point the report's ``goodput_tok_s`` counts ONLY requests that met
their own deadline (``Request.slo_attained``) — the SLO goodput the
paper's serving framing optimizes.  Deterministic per point (gate
``mode=fail``): requests_finished / tokens_emitted (greedy + no EOS +
trace-fixed budgets; the SLO gate defers or flags but never drops, and
greedy streams are K-invariant, so totals are bit-stable).  All
latency/goodput numbers are wall-derived (gate ``mode=warn``) — on a
shared-core CI container the slo-vs-baseline comparison is reported as
a WARN row, never hard-asserted.

    PYTHONPATH=src python -m benchmarks.table11_slo
    PYTHONPATH=src python -m benchmarks.table11_slo \
        --smoke --json /tmp/table11.json    # CI: untrained pair, tiny ladder
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Optional

from benchmarks import common, loadgen
from repro.core.config import ServingConfig, SpecDecodeConfig
from repro.serving.engine import ServingEngine
from repro.serving.frontend import ServingFrontend
from repro.serving.latency_model import RoundLatencyModel

BATCH = 4
MAX_SEQ = 256
KV_BLOCK = 16
POLICIES = ("static", "dsde", "slo")
RATIOS_FULL = (0.8, 1.2, 2.0)
RATIOS_SMOKE = (0.8, 1.2)
# the load point the acceptance story reads: near saturation, deadlines
# are tight enough to separate deadline-aware from deadline-blind
HEADLINE_RATIO = 1.2


def _engine(cfg_t, cfg_d, pt, pd, policy: str,
            latency_model: Optional[RoundLatencyModel] = None
            ) -> ServingEngine:
    spec = SpecDecodeConfig(policy=policy, sf_normalize=True)
    sv = ServingConfig(max_batch_size=BATCH, max_seq_len=MAX_SEQ,
                       paged_kv=True, kv_block_size=KV_BLOCK,
                       num_kv_blocks=BATCH * (MAX_SEQ // KV_BLOCK) // 2,
                       pipelined=True)
    return ServingEngine(pt, cfg_t, pd, cfg_d, spec, sv, seed=0,
                         latency_model=latency_model)


def run(smoke: bool = False, json_path: Optional[str] = None) -> List[str]:
    if smoke:
        cfg_t, cfg_d, pt, pd, _ = common.untrained_pair()
        n_req, max_new_cap, ratios = 8, 10, RATIOS_SMOKE
    else:
        cfg_t, cfg_d, pt, pd, _ = common.build_pair("llama")
        n_req, max_new_cap, ratios = 24, None, RATIOS_FULL

    # capacity probe = warmup = latency-model calibration sweep: the
    # closed-loop replay compiles every prefill shape, measures the
    # service rate the ladder is relative to, and its engine round log
    # (per-round wall_s/k/b_eff/prefill_tokens) batch-fits the analytic
    # model the slo runs start from
    probe = loadgen.make_trace(n_req, rate_rps=1.0, process="poisson",
                               seed=13, max_new_cap=max_new_cap)
    fe = ServingFrontend(_engine(cfg_t, cfg_d, pt, pd, "dsde"))
    loadgen.replay_at_zero(fe, probe)           # compile
    eng = _engine(cfg_t, cfg_d, pt, pd, "dsde")
    fe = ServingFrontend(eng)
    cap = loadgen.replay_at_zero(fe, probe)
    cap_rps = cap["requests_finished"] / max(cap["wall_s"], 1e-9)
    calib_rounds = list(eng.round_log)

    # output-proportional deadlines from the measured closed-loop pace:
    # a few batch-rounds of headroom + ~4x the probe's per-token wall,
    # so the light point attains comfortably while overload queueing
    # genuinely misses — tight enough to separate deadline-aware from
    # deadline-blind at the headline ratio
    per_tok_s = cap["wall_s"] / max(cap["tokens_emitted"], 1)
    deadline = (max(8.0 * BATCH * per_tok_s, 0.05), 4.0 * per_tok_s)

    # per-policy warmup on the deadline-stamped probe: policies fork
    # compiled programs (the spec is a static arg), and the slo policy's
    # shrink path visits smaller K buckets than dsde ever picks — replay
    # the deadline trace closed-loop once per policy so no measured
    # point pays a compile
    warm_trace = loadgen.make_trace(n_req, rate_rps=1.0, process="poisson",
                                    seed=13, max_new_cap=max_new_cap,
                                    deadline=deadline)
    paced_warm = loadgen.make_trace(
        n_req, rate_rps=max(cap_rps * ratios[0], 1e-3), process="poisson",
        seed=13, max_new_cap=max_new_cap, deadline=deadline)
    for policy in POLICIES:
        for trace, paced in ((warm_trace, False), (paced_warm, True)):
            lm = RoundLatencyModel()
            if policy == "slo":
                lm.warm_start_from_rounds(calib_rounds)
            fe = ServingFrontend(_engine(cfg_t, cfg_d, pt, pd, policy, lm))
            if paced:
                # timed arrivals visit K buckets the closed-loop drain
                # never composes (partial batches -> different SL maxima)
                fe.start()
                try:
                    loadgen.replay(fe, trace)
                finally:
                    fe.stop()
            else:
                loadgen.replay_at_zero(fe, trace)

    rows: List[str] = []
    out: Dict[str, object] = {"capacity_rps": cap_rps, "smoke": bool(smoke),
                              "deadline_base_s": deadline[0],
                              "deadline_per_token_s": deadline[1],
                              "points": {}}
    for ratio in ratios:
        trace = loadgen.make_trace(
            n_req, rate_rps=max(cap_rps * ratio, 1e-3), process="poisson",
            seed=13, max_new_cap=max_new_cap, deadline=deadline)
        budget = sum(r["max_new_tokens"] for r in trace["requests"])
        cell: Dict[str, Dict] = {}
        for policy in POLICIES:
            lm = RoundLatencyModel()
            if policy == "slo":
                lm.warm_start_from_rounds(calib_rounds)
            fe = ServingFrontend(
                _engine(cfg_t, cfg_d, pt, pd, policy, lm)).start()
            t0 = time.monotonic()
            try:
                point = loadgen.replay(fe, trace)
            finally:
                fe.stop()
            # deterministic totals: greedy + K-invariant streams + a
            # never-drops SLO gate → exact, whatever the timing did
            assert point["requests_finished"] == n_req, point
            assert point["tokens_emitted"] == budget, (
                point["tokens_emitted"], budget)
            summ = fe.summary()
            point["load_ratio"] = ratio
            point["slo_predicted_violations"] = (
                summ["slo_predicted_violations"])
            point["slo_deferrals"] = summ["slo_deferrals"]
            point["latency_model_ready"] = float(
                summ["latency_model_rounds_fit"]
                >= RoundLatencyModel().min_rounds)
            for k, v in summ.items():
                if k.startswith("latency_model_"):
                    point[k] = v
            cell[policy] = point
            rows.append(common.row(
                f"table11/x{ratio}_{policy}",
                (time.monotonic() - t0) * 1e6,
                f"goodput_tok_s={point['goodput_tok_s']:.1f};"
                f"slo_frac={point['slo_attained_frac']:.2f};"
                f"ttft_p99_ms={point['ttft_s_p99'] * 1e3:.0f};"
                f"deferrals={point['slo_deferrals']};"
                f"pred_viol={point['slo_predicted_violations']}"))
        out["points"][f"x{ratio}"] = cell
        best_base = max(cell[p]["goodput_tok_s"]
                        for p in POLICIES if p != "slo")
        if cell["slo"]["goodput_tok_s"] < 0.95 * best_base:
            # wall-derived on a shared-core box: report, never fail
            rows.append(common.row(
                f"table11/WARN_x{ratio}", 0.0,
                f"slo_goodput={cell['slo']['goodput_tok_s']:.1f}<"
                f"best_baseline={best_base:.1f};"
                "host timing noise suspected"))
    lm_fields = out["points"][f"x{ratios[-1]}"]["slo"]
    rows.append(common.row(
        "table11/latency_model", 0.0,
        f"c0={lm_fields['latency_model_c0']:.2e};"
        f"c_prefill={lm_fields['latency_model_c_prefill']:.2e};"
        f"c_draft={lm_fields['latency_model_c_draft']:.2e};"
        f"c_verify={lm_fields['latency_model_c_verify']:.2e};"
        f"rounds_fit={lm_fields['latency_model_rounds_fit']:.0f}"))
    rows.append(common.row("table11/capacity", 0.0,
                           f"closed_loop_rps={cap_rps:.2f}"))
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="untrained pair + tiny ladder (CI lane)")
    ap.add_argument("--json", default=None,
                    help="write the SLO-goodput points as JSON (CI artifact)")
    args = ap.parse_args()
    print("\n".join(run(smoke=args.smoke, json_path=args.json)))


if __name__ == "__main__":
    main()
