"""Paper Table 1: static SL strategies on heterogeneous tasks.

Static-Aggressive (SL=8) vs Static-Conservative (SL=2) on a predictable
("code") and an unpredictable ("dialogue") workload — demonstrating that
no single static SL serves both, the paper's core motivation.

Any registered speculation policy can also be swept by name on the same
heterogeneous workloads:

    PYTHONPATH=src python -m benchmarks.table1_static_heterogeneous \
        --policies dsde goodput adaedl
"""
from __future__ import annotations

import argparse
import time
from typing import List, Optional, Sequence

from benchmarks import common


def run(policies: Optional[Sequence[str]] = None) -> List[str]:
    cfg_t, cfg_d, pt, pd, ratio = common.build_pair("llama")
    rows = []

    def add_row(task, prompts, label, **serve_kw):
        t0 = time.monotonic()
        m, _, _ = common.serve(cfg_t, cfg_d, pt, pd, prompts, **serve_kw)
        wall = (time.monotonic() - t0) * 1e6
        lu = common.latency_units(m, ratio)
        rows.append(common.row(
            f"table1/{task}/{label}", wall,
            f"latency_units={lu:.1f};BE={m['block_efficiency']:.2f};"
            f"acc={m['mean_acceptance']:.2f}"))

    for task in ("code", "dialogue"):
        prompts = common.dataset(task).prompts(8, 16, seed=1)
        for label, sl in (("aggressive_sl8", 8), ("conservative_sl2", 2)):
            add_row(task, prompts, label, policy="static", static_sl=sl)
        # registry-driven sweep: any policy name the registry knows
        for policy in (policies or ()):
            add_row(task, prompts, policy, policy=policy,
                    goodput_draft_cost=ratio)
    return rows


if __name__ == "__main__":
    from repro.core.policies import available_policies
    ap = argparse.ArgumentParser()
    ap.add_argument("--policies", nargs="*", default=[],
                    choices=list(available_policies()),
                    help="additional registered policies to sweep by name")
    args = ap.parse_args()
    print("\n".join(run(args.policies)))
