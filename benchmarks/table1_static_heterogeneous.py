"""Paper Table 1: static SL strategies on heterogeneous tasks.

Static-Aggressive (SL=8) vs Static-Conservative (SL=2) on a predictable
("code") and an unpredictable ("dialogue") workload — demonstrating that
no single static SL serves both, the paper's core motivation.
"""
from __future__ import annotations

import time
from typing import List

from benchmarks import common


def run() -> List[str]:
    cfg_t, cfg_d, pt, pd, ratio = common.build_pair("llama")
    rows = []
    for task in ("code", "dialogue"):
        prompts = common.dataset(task).prompts(8, 16, seed=1)
        for label, sl in (("aggressive_sl8", 8), ("conservative_sl2", 2)):
            t0 = time.monotonic()
            m, _, _ = common.serve(cfg_t, cfg_d, pt, pd, prompts,
                                   policy="static", static_sl=sl)
            wall = (time.monotonic() - t0) * 1e6
            lu = common.latency_units(m, ratio)
            rows.append(common.row(
                f"table1/{task}/{label}", wall,
                f"latency_units={lu:.1f};BE={m['block_efficiency']:.2f};"
                f"acc={m['mean_acceptance']:.2f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
