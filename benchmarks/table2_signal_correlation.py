"""Paper Table 2: Pearson correlation between candidate signals and token
acceptance, at temperatures 0.0 and 1.0.

Signals per proposed position:
  * draft entropy (forward-looking — AdaEDL's input);
  * mean KLD over the previous 10 verification steps (lagging);
  * WVIR at the time of proposal (lagging stability ratio).

The paper's finding to reproduce: all correlations are weak (|r| < ~0.4),
entropy is the strongest, and everything weakens at temperature 1.0 —
motivating DSDE's use of the signals as *regional diagnostics* rather than
token-level predictors.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core.config import SpecDecodeConfig
from repro.core.drafters import build_drafter
from repro.core.rejection import rejection_sample
from repro.core.signals import (KLDHistory, draft_entropy, kld_per_position,
                                wvir)
from repro.core import spec_decode as sd
from repro.models import cache as cache_lib
from repro.models.transformer import forward
from repro.core.sampling import sample_token


def collect_signals(cfg_t, cfg_d, pt, pd, prompts, temperature, sl=4,
                    max_rounds=40, seed=0):
    """Manual speculative loop logging per-position (signal, accept)."""
    b = len(prompts)
    spec = SpecDecodeConfig(policy="static", static_sl=sl,
                            temperature=temperature)
    key, k_first = jax.random.split(jax.random.PRNGKey(seed))
    state = sd.init_round_state(cfg_t, cfg_d, spec, b, 512, key)
    # prefill
    pl = max(len(p) for p in prompts)
    toks = np.zeros((b, pl), np.int32)
    mask = np.zeros((b, pl), bool)
    for i, p in enumerate(prompts):
        toks[i, :len(p)] = p
        mask[i, :len(p)] = True
    lt, tc, _ = forward(pt, cfg_t, jnp.asarray(toks),
                        cache=state.target_cache, mode="prefill",
                        input_mask=jnp.asarray(mask))
    _, dc, _ = forward(pd, cfg_d, jnp.asarray(toks),
                       cache=state.draft_cache, mode="prefill",
                       input_mask=jnp.asarray(mask))
    lens = jnp.asarray([len(p) for p in prompts], jnp.int32)
    tc = dict(tc); tc["length"] = lens
    dc = dict(dc); dc["length"] = lens
    last = lt[jnp.arange(b), lens - 1]
    pend = sample_token(k_first, last, temperature, cfg_t.vocab_size)
    state = state._replace(target_cache=tc, draft_cache=dc,
                           pending=pend.astype(jnp.int32),
                           sl_next=jnp.full((b,), sl, jnp.int32))
    hist = KLDHistory.init(b, 30)
    active = jnp.ones((b,), bool)

    recs = {"entropy": [], "mean_kld10": [], "wvir": [], "accept": []}
    for _ in range(max_rounds):
        # signals available BEFORE this round's verification
        mean_kld10 = np.asarray(hist.chronological(10)[0]).mean(axis=1)
        w = np.asarray(wvir(hist, 10, 30, 0.85))
        state2, out = sd.spec_decode_round(pt, pd, cfg_t,
                                           build_drafter(spec, cfg_t, cfg_d),
                                           spec, sl, state, active)
        # re-derive per-position stats from this round (entropies/accepts)
        acc = np.asarray(out.num_accepted)
        prop = np.asarray(out.num_proposed)
        tel_kld = np.asarray(state2.policy_state.mu_kld_last)
        for i in range(b):
            for j in range(int(prop[i])):
                recs["accept"].append(1.0 if j < acc[i] else 0.0)
                recs["mean_kld10"].append(float(mean_kld10[i]))
                recs["wvir"].append(float(w[i]))
        # entropy per proposed token needs the draft logits — approximate
        # with the round-mean (the paper's token-level entropy uses the
        # same draft pass; we log the per-round mean entropy per position)
        state = state2
        hist = hist.push(state.policy_state.mu_kld_last, active)
    return recs


def collect_entropy_acceptance(cfg_t, cfg_d, pt, pd, prompts, temperature,
                               n_tokens=600, seed=0):
    """Token-level (entropy, acceptance-probability) pairs via teacher-forced
    rollout: acceptance prob = min(1, p_t(x)/q_d(x)) for x ~ draft."""
    key = jax.random.PRNGKey(seed)
    b = len(prompts)
    pl = max(len(p) for p in prompts)
    toks = np.zeros((b, pl), np.int32)
    for i, p in enumerate(prompts):
        toks[i, :len(p)] = p
    cur = jnp.asarray(toks)
    ents, accs = [], []
    for step in range(n_tokens // b):
        tl, _, _ = forward(pt, cfg_t, cur, mode="train")
        dl, _, _ = forward(pd, cfg_d, cur, mode="train")
        tl_last, dl_last = tl[:, -1], dl[:, -1]
        ent = draft_entropy(dl_last[:, None])[:, 0]
        key, k1 = jax.random.split(key)
        d_tok = sample_token(k1, dl_last, max(temperature, 1e-6),
                             cfg_t.vocab_size)
        if temperature <= 0:
            p = jax.nn.one_hot(jnp.argmax(tl_last[..., :cfg_t.vocab_size], -1),
                               tl_last.shape[-1])
            q = jax.nn.one_hot(jnp.argmax(dl_last[..., :cfg_t.vocab_size], -1),
                               dl_last.shape[-1])
        else:
            p = jax.nn.softmax(tl_last / temperature, -1)
            q = jax.nn.softmax(dl_last / temperature, -1)
        p_tok = jnp.take_along_axis(p, d_tok[:, None], -1)[:, 0]
        q_tok = jnp.take_along_axis(q, d_tok[:, None], -1)[:, 0]
        a = jnp.minimum(p_tok / jnp.maximum(q_tok, 1e-30), 1.0)
        ents += np.asarray(ent).tolist()
        accs += np.asarray(a).tolist()
        # continue the target rollout (greedy on target)
        nxt = jnp.argmax(tl_last[..., :cfg_t.vocab_size], -1)
        cur = jnp.concatenate([cur[:, 1:], nxt[:, None]], 1)
    return np.asarray(ents), np.asarray(accs)


def _pearson(x, y):
    x, y = np.asarray(x, float), np.asarray(y, float)
    if len(x) < 3 or x.std() == 0 or y.std() == 0:
        return float("nan")
    return float(np.corrcoef(x, y)[0, 1])


def run() -> List[str]:
    cfg_t, cfg_d, pt, pd, _ = common.build_pair("llama")
    prompts = common.dataset("news").prompts(6, 16, seed=2)
    rows = []
    for temp in (0.0, 1.0):
        t0 = time.monotonic()
        ents, accs = collect_entropy_acceptance(cfg_t, cfg_d, pt, pd,
                                                prompts, temp)
        r_ent = _pearson(ents, accs)
        recs = collect_signals(cfg_t, cfg_d, pt, pd, prompts, temp)
        r_kld = _pearson(recs["mean_kld10"], recs["accept"])
        r_wvir = _pearson(recs["wvir"], recs["accept"])
        wall = (time.monotonic() - t0) * 1e6
        rows.append(common.row(
            f"table2/temp{temp}", wall,
            f"r_entropy={r_ent:.3f};r_mean_kld={r_kld:.3f};"
            f"r_wvir={r_wvir:.3f};n={len(recs['accept'])}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
