"""Paper Table 3: end-to-end latency and speedup vs autoregressive decoding
for all policies, at temperatures 0.0 and 1.0.

The static-opt baseline is obtained the way the paper does (and complains
about): profiling SL in {2,4,6,8,10} per dataset and taking the best —
the cost DSDE's training-free calibration avoids.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks import common


def _mixed_prompts(n_per=3, seed=3):
    out = []
    for name in common.DATASETS:
        out += common.dataset(name).prompts(n_per, 16, seed=seed)
    return out


def static_opt(cfg_t, cfg_d, pt, pd, prompts, ratio, temperature):
    best = None
    for sl in (2, 4, 6, 8, 10):
        m, _, _ = common.serve(cfg_t, cfg_d, pt, pd, prompts,
                               policy="static", static_sl=sl,
                               temperature=temperature)
        lu = common.latency_units(m, ratio)
        if best is None or lu < best[1]:
            best = (sl, lu, m)
    return best


def run() -> List[str]:
    cfg_t, cfg_d, pt, pd, ratio = common.build_pair("llama")
    prompts = _mixed_prompts()
    rows = []
    for temp in (0.0, 1.0):
        t0 = time.monotonic()
        m_ar, _, _ = common.serve(cfg_t, cfg_d, pt, pd, prompts,
                                  policy="autoregressive", temperature=temp)
        lu_ar = common.latency_units(m_ar, ratio)
        sl_opt, lu_opt, m_opt = static_opt(cfg_t, cfg_d, pt, pd, prompts,
                                           ratio, temp)
        results = {"autoregressive": (lu_ar, m_ar),
                   f"static_opt_sl{sl_opt}": (lu_opt, m_opt)}
        for policy in ("dsde", "adaedl", "goodput"):
            m, _, _ = common.serve(cfg_t, cfg_d, pt, pd, prompts,
                                   policy=policy, temperature=temp,
                                   goodput_draft_cost=ratio)
            results[policy] = (common.latency_units(m, ratio), m)
        wall = (time.monotonic() - t0) * 1e6
        for name, (lu, m) in results.items():
            rows.append(common.row(
                f"table3/temp{temp}/{name}", wall / len(results),
                f"latency_units={lu:.1f};speedup={lu_ar / lu:.2f}x;"
                f"BE={m['block_efficiency']:.2f};"
                f"wall_s={m['wall_time_s']:.1f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
