"""Paper Table 4 / Fig. 8: robustness in the low-acceptance regime.

The 'gemma' pair (weak, divergently-trained draft) recreates the paper's
Gemma-27B/2B setting where k_opt collapses to 2.  Claim to reproduce:
entropy-driven AdaEDL degrades substantially more than the KLD/WVIR-based
DSDE, which stays near static-opt."""
from __future__ import annotations

import time
from typing import List

from benchmarks import common
from benchmarks.table3_latency_speedup import static_opt


def run() -> List[str]:
    rows = []
    results = {}
    for regime in ("llama", "gemma"):
        cfg_t, cfg_d, pt, pd, ratio = common.build_pair(regime)
        prompts = []
        for name in ("code", "news", "dialogue"):
            prompts += common.dataset(name).prompts(3, 16, seed=4)
        t0 = time.monotonic()
        sl_opt, lu_opt, m_opt = static_opt(cfg_t, cfg_d, pt, pd, prompts,
                                           ratio, 0.0)
        per = {"static_opt": (lu_opt, m_opt)}
        for policy in ("dsde", "adaedl", "goodput"):
            m, _, _ = common.serve(cfg_t, cfg_d, pt, pd, prompts,
                                   policy=policy, goodput_draft_cost=ratio)
            per[policy] = (common.latency_units(m, ratio), m)
        wall = (time.monotonic() - t0) * 1e6
        results[regime] = per
        for name, (lu, m) in per.items():
            rows.append(common.row(
                f"table4/{regime}/{name}", wall / len(per),
                f"latency_units={lu:.1f};acc={m['mean_acceptance']:.2f};"
                f"k_opt={sl_opt}"))
    # percentile increment (paper Table 4): gemma latency / llama latency
    for name in ("static_opt", "dsde", "adaedl", "goodput"):
        inc = (results["gemma"][name][0] / results["llama"][name][0]) * 100
        rows.append(common.row(f"table4/increment/{name}", 0.0,
                               f"pct_of_llama={inc:.0f}%"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
