"""Paged vs dense KV capacity on a heterogeneous-length workload.

Dense admission plans by pessimism: every slot reserves a full
``max_seq_len`` KV row, so the number of *concurrent* sequences is
``total_kv_bytes / (max_seq_len * row_bytes)`` no matter how short the
requests actually are.  The block-paged data plane charges each sequence
only the blocks it currently needs (``committed + SL_i + 1``, grown per
round from the policy's lookahead), so the same bytes pack far more
in-flight sequences.

Three engines serve the identical request mix (a few long-prompt/long-gen
requests among many short ones, the paper's serving heterogeneity):

* ``dense_full``  — dense rows, batch B             (KV budget = 100%)
* ``paged_half``  — block pool sized at 50% of dense_full's KV bytes,
  same B slots: admits and completes the whole mix concurrently,
  preempting instead of rejecting if pressure spikes
* ``dense_half``  — dense rows at the same 50% byte budget, i.e. B/2
  slots: the only way dense can shed bytes is shedding concurrency, so
  half the mix queues behind the other half

Rows report completed/rejected counts, rounds, per-round batch
efficiency, and the pool telemetry (`kv_blocks_in_use` peaks) that the
round log now records for memory-vs-throughput plots.

    PYTHONPATH=src python -m benchmarks.table5_paged_capacity
"""
from __future__ import annotations

import time
from typing import List

from benchmarks import common

MAX_SEQ = 256
BATCH = 8
BLOCK = 16


def workload():
    """Heterogeneous mix: 4 long-prompt/long-gen + 8 short requests, all
    wanting to run *concurrently* — the regime where dense admission's
    worst-case row reservation, not compute, caps the batch."""
    long_p = common.dataset("news").prompts(4, 96, seed=3)
    short_p = common.dataset("code").prompts(8, 16, seed=4)
    prompts = long_p + short_p
    max_new = [64] * len(long_p) + [32] * len(short_p)
    return prompts, max_new


def run() -> List[str]:
    cfg_t, cfg_d, pt, pd, ratio = common.build_pair("llama")
    prompts, max_new = workload()
    dense_blocks = BATCH * (MAX_SEQ // BLOCK)          # 100% KV budget
    rows = []

    def add_row(label, **kw):
        t0 = time.monotonic()
        m, reqs, eng = common.serve(cfg_t, cfg_d, pt, pd, prompts,
                                    max_new_per_req=max_new,
                                    max_seq_len=MAX_SEQ, **kw)
        wall = (time.monotonic() - t0) * 1e6
        lu = common.latency_units(m, ratio)
        incomplete = sum(1 for r in reqs
                         if len(r.output) < r.max_new_tokens
                         and (r.eos_token_id is None
                              or (r.output and r.output[-1] != r.eos_token_id)))
        rows.append(common.row(
            f"table5/{label}", wall,
            f"finished={m['requests_finished']};"
            f"rejected={m['requests_rejected']};"
            f"preempt={m['preemptions']};rounds={m['rounds']};"
            f"latency_units={lu:.1f};"
            f"tok_per_round={m['batch_tokens_per_round']:.2f};"
            f"kv_blocks={m['kv_blocks_peak']:.0f}/{m['kv_pool_blocks']:.0f};"
            f"incomplete={incomplete}"))
        return m

    add_row(f"dense_full_b{BATCH}", batch=BATCH)
    m_paged = add_row(f"paged_half_b{BATCH}", batch=BATCH, paged=True,
                      kv_block_size=BLOCK, num_kv_blocks=dense_blocks // 2)
    add_row(f"dense_half_b{BATCH // 2}", batch=BATCH // 2)

    # the demonstration the ISSUE asks for: at <= 50% of the dense KV
    # bytes the paged engine still completes the whole mix
    assert m_paged["kv_pool_blocks"] <= dense_blocks / 2
    assert m_paged["requests_finished"] == len(prompts)
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
