"""Sync vs pipelined serving: wall time, host-blocked time, device idle.

The lockstep engine dispatches a round and immediately blocks on its
outputs: every host-side cost — token distribution, EOS bookkeeping,
scheduler/allocator work, the round log — sits on the device's critical
path.  The plan → dispatch → collect pipeline (DESIGN.md §7) enqueues
round N+1 first and reconciles round N while the device is already
computing, so the only host time the device ever waits for is the bucket
pick and dispatch overhead.

Both modes serve the identical heterogeneous mix (all four task
datasets, mixed generation lengths, more requests than slots so
admission churns) on the paged data plane with a small block size — the
regime where per-round host work (allocator growth, block-table
mirroring, shrink-to-committed, token distribution) is substantial, i.e.
exactly the host overhead the paper's serving sections are about.  On a
real deployment the accelerator would idle through all of it; the
pipeline fills that idle time.  Measured per mode:

* wall time (best of ``REPS`` interleaved runs, programs pre-warmed for
  both schedules),
* per-round host-blocked time (mean / p95): how long ``collect`` waited
  on the round's output transfer.  Sync blocks for most of every round;
  pipelined blocks only for whatever compute the host work did not
  already cover — the headline contrast,
* device idle fraction, estimated from the sync run's per-round blocked
  time (which brackets the device's compute time per round, since the
  sync host blocks immediately after dispatch).

Caveat for CPU containers: host python and XLA compute share the same
cores here, so overlap is partially zero-sum and the wall-time gap
understates what a dedicated accelerator would gain; the host-blocked
column is the hardware-neutral signal.

    PYTHONPATH=src python -m benchmarks.table6_pipeline_overlap
    PYTHONPATH=src python -m benchmarks.table6_pipeline_overlap \
        --smoke --json /tmp/table6.json     # CI: untrained pair, tiny mix
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from benchmarks import common

REPS = 3
BATCH = 8
MAX_SEQ = 256
KV_BLOCK = 4      # small blocks = realistic per-round allocator/table work


def workload(smoke: bool) -> Tuple[List[List[int]], List[int]]:
    prompts: List[List[int]] = []
    # enough requests that the pipeline's fixed bubbles (one trailing
    # all-done round + one admission-lag round per batch wave) amortize
    per = 2 if smoke else 6
    for i, name in enumerate(common.DATASETS):
        prompts += common.dataset(name).prompts(per, 16, seed=42 + i)
    rng = np.random.RandomState(0)
    rng.shuffle(prompts)
    max_new = [int(rng.randint(8, 16)) if smoke
               else int(rng.randint(32, 64)) for _ in prompts]
    return prompts, max_new


def _serve_once(cfg_t, cfg_d, pt, pd, prompts, max_new, *, pipelined: bool
                ) -> Tuple[Dict, List[List[int]]]:
    m, reqs, eng = common.serve(
        cfg_t, cfg_d, pt, pd, prompts, policy="dsde",
        max_new_per_req=max_new, batch=BATCH, max_seq_len=MAX_SEQ,
        paged=True, kv_block_size=KV_BLOCK, pipelined=pipelined)
    blocked = [r["host_blocked_s"] for r in eng.round_log]
    m = dict(m)
    st = common.dist_stats(blocked, "blocked", ps=(95,))
    m["blocked_mean_s"] = st["blocked_mean"]
    m["blocked_p95_s"] = st["blocked_p95"]
    return m, [r.output for r in reqs]


def run(smoke: bool = False, json_path: Optional[str] = None) -> List[str]:
    if smoke:
        cfg_t, cfg_d, pt, pd, _ = common.untrained_pair()
    else:
        cfg_t, cfg_d, pt, pd, _ = common.build_pair("llama")
    prompts, max_new = workload(smoke)

    # warm the program caches with BOTH schedules (their K-bucket and
    # prefill-group sequences differ) so no measured run pays compile
    for warm_pipe in (False, True):
        common.serve(cfg_t, cfg_d, pt, pd, prompts, policy="dsde",
                     max_new_per_req=max_new, batch=BATCH,
                     max_seq_len=MAX_SEQ, paged=True,
                     kv_block_size=KV_BLOCK, pipelined=warm_pipe)

    # interleave the repetitions (sync, pipelined, sync, ...) so ambient
    # load drifts hit both modes alike; report each mode's best run.
    # On a noisy box the few-percent wall margin can flip, so the
    # non-smoke lane escalates with extra interleaved pairs before
    # giving a verdict.
    runs: Dict[bool, List[Dict]] = {False: [], True: []}
    streams: Dict[bool, List[List[int]]] = {}

    def best(pipelined):
        return min(runs[pipelined], key=lambda m: m["wall_time_s"])

    reps = REPS
    while True:
        for _ in range(reps):
            for pipelined in (False, True):
                m, s = _serve_once(cfg_t, cfg_d, pt, pd, prompts, max_new,
                                   pipelined=pipelined)
                runs[pipelined].append(m)
                streams[pipelined] = s
        if (smoke or len(runs[True]) >= 3 * REPS
                or best(True)["wall_time_s"] < best(False)["wall_time_s"]):
            break
        reps = REPS                  # escalate: another interleaved batch
    m_sync, m_pipe = best(False), best(True)

    # the schedule must never change the tokens
    assert streams[False] == streams[True], (
        "pipelined stream diverged from sync")

    # device-busy proxy: the sync host blocks right after dispatch, so
    # its per-round blocked time brackets the device's round compute.
    dev_round = m_sync["blocked_mean_s"]
    rows = []
    out: Dict[str, Dict] = {}
    for label, m in (("sync", m_sync), ("pipelined", m_pipe)):
        idle = max(0.0, 1.0 - dev_round * m["rounds"]
                   / max(m["wall_time_s"], 1e-9))
        out[label] = {
            "wall_s": m["wall_time_s"],
            "rounds": m["rounds"],
            "tokens": m["tokens_emitted"],
            "throughput_tok_s": m["throughput_tok_s"],
            "host_blocked_total_s": m["host_blocked_s"],
            "host_blocked_mean_s": m["blocked_mean_s"],
            "host_blocked_p95_s": m["blocked_p95_s"],
            "device_idle_frac_est": idle,
            "ttft_mean_s": m["ttft_mean_s"],
            "queue_wait_mean_s": m["queue_wait_mean_s"],
        }
        rows.append(common.row(
            f"table6/{label}", m["wall_time_s"] * 1e6,
            f"rounds={m['rounds']};tok={m['tokens_emitted']};"
            f"blocked_mean_us={m['blocked_mean_s'] * 1e6:.0f};"
            f"blocked_p95_us={m['blocked_p95_s'] * 1e6:.0f};"
            f"device_idle_frac={idle:.3f};"
            f"ttft_ms={m['ttft_mean_s'] * 1e3:.1f}"))
    speedup = m_sync["wall_time_s"] / max(m_pipe["wall_time_s"], 1e-9)
    out["speedup"] = speedup
    out["pipelined_wins_wall"] = bool(
        m_pipe["wall_time_s"] < m_sync["wall_time_s"])
    out["streams_identical"] = True
    rows.append(common.row("table6/speedup", 0.0,
                           f"sync_over_pipelined={speedup:.3f}x"))
    if not smoke and not out["pipelined_wins_wall"]:
        # the overlap claim did not materialize even after escalation:
        # surface it loudly (the hardware-neutral host_blocked columns
        # above still carry the schedule comparison) without crashing
        # the whole benchmark suite on a noisy or core-starved box
        rows.append(common.row(
            "table6/WARN", 0.0,
            f"pipelined_not_faster_on_this_host={speedup:.3f}x;"
            "host python and XLA may be sharing saturated cores"))
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="untrained pair + tiny mix (CI lane)")
    ap.add_argument("--json", default=None,
                    help="write the comparison as JSON (CI artifact)")
    args = ap.parse_args()
    print("\n".join(run(smoke=args.smoke, json_path=args.json)))


if __name__ == "__main__":
    main()
