"""Drafter × policy matrix: every registered proposer under every
registered SL controller, one serving run per cell (beyond-paper; the
extensibility proof for the drafter seam, DESIGN.md §9).

Each cell serves the same heterogeneous mix and reports the numbers the
two seams trade off against each other:

* ``latency_units`` — rounds + effective draft cost, with the per-cell
  draft-step cost taken from the drafter's OWN ``step_cost()`` (a model
  drafter pays its FLOP ratio per step; lookup drafting is free), so
  cells are comparable on one hardware-neutral axis;
* ``BE`` / acceptance — proposal quality per drafter;
* ``kv_peak`` / ``draft_kv_peak`` — capacity: model-free drafters hold
  ZERO draft-side blocks and the paged pool admits proportionally more
  sequences (the scheduler returns the draft mirror's budget).

Rows print as ``table7/<drafter>/<policy>``.  The whole grid is driven
purely through ``SpecDecodeConfig(policy=..., drafter=...)`` — no
engine-side special cases per cell.

    PYTHONPATH=src python -m benchmarks.table7_drafter_matrix
    PYTHONPATH=src python -m benchmarks.table7_drafter_matrix \
        --smoke --json /tmp/table7.json     # CI: untrained pair, tiny mix
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Optional

import numpy as np

from benchmarks import common
from repro.core.drafters import available_drafters
from repro.core.policies import available_policies

BATCH = 8
MAX_SEQ = 256
KV_BLOCK = 8


def workload(smoke: bool):
    prompts: List[List[int]] = []
    per = 2 if smoke else 4
    for i, name in enumerate(common.DATASETS):
        # repetitive task mixes give lookup drafting something to find;
        # the high-entropy tasks keep it honest
        prompts += common.dataset(name).prompts(per, 16, seed=42 + i)
    rng = np.random.RandomState(0)
    rng.shuffle(prompts)
    return prompts, (10 if smoke else 32)


def run(smoke: bool = False, json_path: Optional[str] = None) -> List[str]:
    if smoke:
        cfg_t, cfg_d, pt, pd, _ = common.untrained_pair()
    else:
        cfg_t, cfg_d, pt, pd, _ = common.build_pair("llama")
    prompts, max_new = workload(smoke)

    rows: List[str] = []
    out: Dict[str, Dict] = {}
    for drafter in available_drafters():
        for policy in available_policies():
            t0 = time.monotonic()
            m, reqs, eng = common.serve(
                cfg_t, cfg_d, pt, pd, prompts, policy=policy,
                drafter=drafter, max_new=max_new, batch=BATCH,
                max_seq_len=MAX_SEQ, paged=True, kv_block_size=KV_BLOCK)
            wall = (time.monotonic() - t0) * 1e6
            # per-cell cost model from the drafter's own step cost — the
            # satellite point: goodput/latency accounting no longer needs
            # a hand-set constant
            lu = common.latency_units(m, m["draft_step_cost"])
            cell = {
                "latency_units": lu,
                "rounds": m["rounds"],
                "block_efficiency": m["block_efficiency"],
                "mean_acceptance": m["mean_acceptance"],
                "draft_step_cost": m["draft_step_cost"],
                "draft_cost_effective": m["draft_cost_effective"],
                "kv_blocks_peak": m["kv_blocks_peak"],
                "kv_pool_blocks": m["kv_pool_blocks"],
                "draft_kv_blocks_peak": m["draft_kv_blocks_peak"],
                "requests_finished": m["requests_finished"],
            }
            out[f"{drafter}/{policy}"] = cell
            rows.append(common.row(
                f"table7/{drafter}/{policy}", wall,
                f"lu={lu:.1f};BE={m['block_efficiency']:.2f};"
                f"acc={m['mean_acceptance']:.2f};"
                f"c_draft={m['draft_step_cost']:.3f};"
                f"kv_peak={m['kv_blocks_peak']:.0f}/"
                f"{m['kv_pool_blocks']:.0f};"
                f"draft_kv_peak={m['draft_kv_blocks_peak']:.0f};"
                f"fin={m['requests_finished']}"))
            assert m["requests_finished"] == len(prompts), (drafter, policy)
    # capacity headline: model-free drafters double the paged pool at
    # identical ServingConfig (the mirror budget returns to the target)
    pools = {d: out[f"{d}/dsde"]["kv_pool_blocks"]
             for d in available_drafters()}
    rows.append(common.row(
        "table7/pool_blocks", 0.0,
        ";".join(f"{d}={int(v)}" for d, v in sorted(pools.items()))))
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="untrained pair + tiny mix (CI lane)")
    ap.add_argument("--json", default=None,
                    help="write the full grid as JSON (CI artifact)")
    args = ap.parse_args()
    print("\n".join(run(smoke=args.smoke, json_path=args.json)))


if __name__ == "__main__":
    main()
