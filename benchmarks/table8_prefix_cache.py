"""Prefix caching: TTFT / prefill-work / capacity vs prefix-share ratio
(beyond-paper; the perf story for DESIGN.md §12's refcounted COW pool).

Serving mixes in the wild share long prompt prefixes (system prompts,
few-shot headers, multi-turn history).  This table sweeps the share
ratio — the fraction of each prompt that is a common prefix — and serves
the identical mix twice per point: paged pool with ``prefix_caching``
off (every admission recomputes the full prompt) vs on (cache-hit
admissions map the shared blocks and prefill only the uncovered tail).

Reported per share point:

* ``ttft`` — mean time-to-first-token of the measured (cache-warm-able)
  requests; the headline: at >= 50% share the cached engine's TTFT is
  >= 2x better (asserted in the full run, reported in smoke);
* ``prefill_tokens`` — total token-positions computed across all
  prefill dispatches (rows x bucket width, the FLOP-side area) and the
  dispatch count: both drop with the share ratio, deterministically;
* ``hit_rate`` / ``hit_blocks`` / ``cow`` — the §12 telemetry;
* ``capacity`` — a half-pool row in table5's style: a pool at 50% of
  the dense KV bytes completes the whole shared-prefix mix (sharing
  returns blocks the dense plane would duplicate).

    PYTHONPATH=src python -m benchmarks.table8_prefix_cache
    PYTHONPATH=src python -m benchmarks.table8_prefix_cache \
        --smoke --json /tmp/table8.json     # CI: untrained pair, tiny mix
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Optional

import numpy as np

from benchmarks import common
from repro.core import prefill as prefill_lib
from repro.core.config import ServingConfig, SpecDecodeConfig
from repro.serving.engine import ServingEngine
from repro.serving.request import Request

MAX_SEQ = 256
BATCH = 4
BLOCK = 16


class _PrefillSpy:
    """Counts prefill dispatches and their token area (rows x width)
    across the paged entry points, cold and tail."""

    def __init__(self):
        self.calls = 0
        self.token_area = 0

    def __enter__(self):
        self._orig = (prefill_lib.prefill_paged_rows,
                      prefill_lib.prefill_paged_tail)

        def spy_rows(params, cfg, pk, pv, kp, rows, tokens, *a, **kw):
            self.calls += 1
            self.token_area += int(tokens.shape[0] * tokens.shape[1])
            return self._orig[0](params, cfg, pk, pv, kp, rows, tokens,
                                 *a, **kw)

        def spy_tail(params, cfg, pk, pv, kp, rows, tokens, *a, **kw):
            self.calls += 1
            self.token_area += int(tokens.shape[0] * tokens.shape[1])
            return self._orig[1](params, cfg, pk, pv, kp, rows, tokens,
                                 *a, **kw)

        prefill_lib.prefill_paged_rows = spy_rows
        prefill_lib.prefill_paged_tail = spy_tail
        return self

    def __exit__(self, *exc):
        (prefill_lib.prefill_paged_rows,
         prefill_lib.prefill_paged_tail) = self._orig
        return False


def workload(share: float, smoke: bool):
    """R prompts of equal length whose first ``share`` fraction is a
    common prefix (block-aligned so the sweep isolates the share ratio,
    not rounding) and whose tails are per-request draws."""
    plen = 64 if smoke else 192
    n_shared = int(share * plen) // BLOCK * BLOCK
    rng = np.random.RandomState(17)
    head = rng.randint(0, common.VOCAB, size=n_shared).tolist()
    prompts = [head + rng.randint(0, common.VOCAB,
                                  size=plen - n_shared).tolist()
               for _ in range(BATCH)]
    return head, prompts, (8 if smoke else 24)


def _engine(cfg_t, cfg_d, pt, pd, *, prefix_caching, num_kv_blocks=None):
    spec = SpecDecodeConfig(policy="dsde", temperature=0.0,
                            sf_normalize=True)
    sv = ServingConfig(max_batch_size=BATCH, max_seq_len=MAX_SEQ,
                       paged_kv=True, kv_block_size=BLOCK,
                       num_kv_blocks=num_kv_blocks,
                       prefix_caching=prefix_caching)
    return ServingEngine(pt, cfg_t, pd, cfg_d, spec, sv, seed=0)


def _serve_point(cfg_t, cfg_d, pt, pd, head, prompts, max_new, *,
                 prefix_caching):
    """Prime the cache with the shared head (one cheap request), then
    serve the measured batch concurrently.  The cache-off engine runs
    the identical schedule so the comparison isolates the cache."""
    eng = _engine(cfg_t, cfg_d, pt, pd, prefix_caching=prefix_caching)
    if head:
        eng.run([Request(1000, prompt=list(head), max_new_tokens=1)])
    reqs = [Request(i, prompt=list(p), max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    with _PrefillSpy() as spy:
        t0 = time.monotonic()
        m = eng.run(reqs)
        wall = time.monotonic() - t0
    ttft = common.dist_stats([r.ttft() for r in reqs], "ttft")["ttft_mean"]
    assert m["requests_finished"] == len(reqs)
    return {
        "ttft_s": ttft,
        "wall_s": wall,
        "prefill_calls": spy.calls,
        "prefill_tokens": spy.token_area,
        "prefix_cache_hit_rate": m["prefix_cache_hit_rate"],
        "prefix_cache_hit_blocks": m["prefix_cache_hit_blocks"],
        "cow_copies": m["cow_copies"],
        "kv_blocks_peak": m["kv_blocks_peak"],
        "throughput_tok_s": m["throughput_tok_s"],
    }


def run(smoke: bool = False, json_path: Optional[str] = None) -> List[str]:
    if smoke:
        cfg_t, cfg_d, pt, pd, _ = common.untrained_pair()
    else:
        cfg_t, cfg_d, pt, pd, _ = common.build_pair("llama")
    shares = (0.0, 0.5) if smoke else (0.0, 0.5, 0.875)
    rows: List[str] = []
    out: Dict[str, Dict] = {}
    for share in shares:
        head, prompts, max_new = workload(share, smoke)

        def point(prefix_caching):
            # run each point twice and keep the second: the first pass
            # absorbs XLA compiles (process-global caches), so the timed
            # pass compares steady-state serving, not compile order
            _serve_point(cfg_t, cfg_d, pt, pd, head, prompts, max_new,
                         prefix_caching=prefix_caching)
            return _serve_point(cfg_t, cfg_d, pt, pd, head, prompts,
                                max_new, prefix_caching=prefix_caching)

        off = point(False)
        on = point(True)
        speedup = off["ttft_s"] / max(on["ttft_s"], 1e-9)
        cell = {
            "share": share,
            "ttft_off_s": off["ttft_s"],
            "ttft_on_s": on["ttft_s"],
            "ttft_speedup": speedup,
            "prefill_tokens_off": off["prefill_tokens"],
            "prefill_tokens_on": on["prefill_tokens"],
            "prefill_calls_on": on["prefill_calls"],
            "prefix_cache_hit_rate": on["prefix_cache_hit_rate"],
            "prefix_cache_hit_blocks": on["prefix_cache_hit_blocks"],
            "cow_copies": on["cow_copies"],
        }
        out[f"share{share:g}"] = cell
        rows.append(common.row(
            f"table8/share{share:g}", on["wall_s"] * 1e6,
            f"ttft_speedup={speedup:.2f};"
            f"prefill_tok={on['prefill_tokens']}/{off['prefill_tokens']};"
            f"hit_rate={on['prefix_cache_hit_rate']:.2f};"
            f"hit_blocks={on['prefix_cache_hit_blocks']:.0f};"
            f"cow={on['cow_copies']:.0f}"))
        # work drop is deterministic: a shared head that covers s of the
        # prompt must cut the measured batch's prefill token area
        if share > 0:
            assert on["prefill_tokens"] < off["prefill_tokens"], share
            assert on["prefix_cache_hit_rate"] > 0.0, share
        else:
            assert on["prefill_tokens"] == off["prefill_tokens"]
        if share >= 0.5 and not smoke:
            # the acceptance headline (wall-derived; smoke lanes only
            # report it — CI boxes are too noisy to gate a hard 2x).
            # The 0.5 point's tail still rounds up a power-of-two
            # bucket, so the full 2x lands at the high-share point.
            assert speedup >= (2.0 if share >= 0.8 else 1.2), (share,
                                                               speedup)
    # capacity row (table5's paged_half shape, plus sharing): a pool at
    # 50% of the dense KV bytes serves the whole shared-prefix mix
    head, prompts, max_new = workload(0.5, smoke)
    dense_blocks = BATCH * (MAX_SEQ // BLOCK)
    eng = _engine(cfg_t, cfg_d, pt, pd, prefix_caching=True,
                  num_kv_blocks=dense_blocks // 2)
    t0 = time.monotonic()
    reqs = [Request(i, prompt=list(p), max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    m = eng.run(reqs)
    wall = (time.monotonic() - t0) * 1e6
    assert m["requests_finished"] == len(prompts)
    assert m["kv_pool_blocks"] <= dense_blocks / 2
    out["paged_half_shared"] = {
        "requests_finished": m["requests_finished"],
        "preemptions": m["preemptions"],
        "tok_per_round": m["batch_tokens_per_round"],
        "kv_blocks_peak": m["kv_blocks_peak"],
        "kv_pool_blocks": m["kv_pool_blocks"],
        "kv_pool_utilization_peak": m["kv_pool_utilization_peak"],
    }
    rows.append(common.row(
        "table8/paged_half_shared", wall,
        f"finished={m['requests_finished']};preempt={m['preemptions']};"
        f"tok_per_round={m['batch_tokens_per_round']:.2f};"
        f"kv_blocks={m['kv_blocks_peak']:.0f}/{m['kv_pool_blocks']:.0f};"
        f"util_peak={m['kv_pool_utilization_peak']:.2f}"))
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="untrained pair + tiny mix (CI lane)")
    ap.add_argument("--json", default=None,
                    help="write the share sweep as JSON (CI artifact)")
    args = ap.parse_args()
    print("\n".join(run(smoke=args.smoke, json_path=args.json)))


if __name__ == "__main__":
    main()
