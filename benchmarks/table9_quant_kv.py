"""Quantized KV serving: int8 per-block pool vs the fp paged pool
(beyond-paper; the perf story for DESIGN.md §13's quantized data plane).

The fp paged pool already packs a heterogeneous mix into half the dense
KV bytes (table5).  Storing the pool int8 with per-slot-per-KV-head amax
scales cuts the remaining bytes by ~4x at the same block count — blocks
just cost fewer bytes — so the same byte budget buys >= 2x the blocks,
and every verify round sweeps proportionally fewer KV bytes through the
memory system (the regime real decode kernels are bound by).

Three engines serve the identical table5-style heterogeneous mix:

* ``fp_paged``     — fp32 pool at N blocks (the table5 paged engine);
* ``int8_paged``   — int8 pool at the SAME N blocks: completes the mix
  at <= 50% (achieved: ~27%) of fp_paged's pool bytes, throughput
  within tolerance — fp_paged IS the fp-at-2x-bytes comparison point;
* ``int8_equal_bytes`` — int8 pool at ``equal_byte_blocks(N)``: the
  capacity row — the fp byte budget re-spent on >= 2x the blocks.

Rows report completion/pressure counters, pool bytes, the per-round KV
bytes-swept reduction (sum over rounds of blocks-in-use x block bytes,
the quantity the fused-dequant kernel actually streams), and stream
divergence stats vs the fp engine (int8 storage legitimately perturbs
greedy streams; serving-level distributional exactness is pinned by
tests/test_kv_quant.py's chi-square, not here).

    PYTHONPATH=src python -m benchmarks.table9_quant_kv
    PYTHONPATH=src python -m benchmarks.table9_quant_kv \
        --smoke --json /tmp/table9.json     # CI: untrained pair, tiny mix
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Optional

from benchmarks import common
from repro.models import cache as cache_lib

MAX_SEQ = 256
BATCH = 8
BLOCK = 16


def workload(smoke: bool):
    """table5's heterogeneous mix: a few long-prompt/long-gen requests
    among many short ones, all wanting to run concurrently."""
    if smoke:
        long_p = common.dataset("news").prompts(2, 48, seed=3)
        short_p = common.dataset("code").prompts(4, 16, seed=4)
        max_new = [24] * len(long_p) + [12] * len(short_p)
    else:
        long_p = common.dataset("news").prompts(4, 96, seed=3)
        short_p = common.dataset("code").prompts(8, 16, seed=4)
        max_new = [64] * len(long_p) + [32] * len(short_p)
    return long_p + short_p, max_new


def _divergence(ref_reqs, reqs) -> Dict[str, float]:
    """Stream-divergence stats vs the fp engine: identical-stream
    fraction and mean common-prefix fraction, by request id."""
    ref = {r.request_id: r.output for r in ref_reqs}
    ident, prefix = 0, 0.0
    for r in reqs:
        a, b = ref[r.request_id], r.output
        ident += a == b
        n = max(len(a), len(b), 1)
        k = 0
        for x, y in zip(a, b):
            if x != y:
                break
            k += 1
        prefix += k / n
    n = max(len(reqs), 1)
    return {"identical_frac": ident / n, "prefix_match_frac": prefix / n}


def run(smoke: bool = False, json_path: Optional[str] = None) -> List[str]:
    if smoke:
        cfg_t, cfg_d, pt, pd, ratio = common.untrained_pair()
    else:
        cfg_t, cfg_d, pt, pd, ratio = common.build_pair("llama")
    prompts, max_new = workload(smoke)
    # table5's paged_half geometry: half the dense byte budget in blocks
    n_blocks = BATCH * (MAX_SEQ // BLOCK) // 2
    eq_blocks = cache_lib.equal_byte_blocks(cfg_t, n_blocks, BLOCK)
    rows: List[str] = []
    out: Dict[str, Dict] = {}

    def add_row(label, *, nblocks, kv_quant, ref_reqs=None):
        t0 = time.monotonic()
        m, reqs, _ = common.serve(cfg_t, cfg_d, pt, pd, prompts,
                                  max_new_per_req=max_new,
                                  max_seq_len=MAX_SEQ, batch=BATCH,
                                  paged=True, kv_block_size=BLOCK,
                                  num_kv_blocks=nblocks, kv_quant=kv_quant)
        wall = (time.monotonic() - t0) * 1e6
        eng_rounds = m["rounds"]
        # KV bytes the verify sweeps actually stream: blocks resident
        # that round x bytes per block, summed over the run
        swept = m["kv_bytes_swept"]
        cell = {
            "requests_finished": m["requests_finished"],
            "requests_rejected": m["requests_rejected"],
            "preemptions": m["preemptions"],
            "rounds": eng_rounds,
            "tok_per_round": m["batch_tokens_per_round"],
            "latency_units": common.latency_units(m, ratio),
            "kv_pool_blocks": m["kv_pool_blocks"],
            "kv_block_bytes": m["kv_block_bytes"],
            "kv_pool_bytes": m["kv_pool_bytes"],
            "kv_bytes_swept": swept,
        }
        # per-request TTFT distribution (shared helper — same p99 as
        # every other table): storage numerics must not shift latency
        cell.update(common.dist_stats([r.ttft() for r in reqs], "ttft_s"))
        div = None
        if ref_reqs is not None:
            div = _divergence(ref_reqs, reqs)
            cell.update(div)
        out[label] = cell
        extra = (f";ident={div['identical_frac']:.2f};"
                 f"pfx={div['prefix_match_frac']:.2f}" if div else "")
        rows.append(common.row(
            f"table9/{label}", wall,
            f"finished={m['requests_finished']};"
            f"preempt={m['preemptions']};rounds={eng_rounds};"
            f"tok_per_round={m['batch_tokens_per_round']:.2f};"
            f"pool_mb={m['kv_pool_bytes'] / 2**20:.2f};"
            f"swept_mb={swept / 2**20:.1f}{extra}"))
        return m, reqs

    m_fp, reqs_fp = add_row(f"fp_paged_n{n_blocks}", nblocks=n_blocks,
                            kv_quant="none")
    m_q8, _ = add_row(f"int8_paged_n{n_blocks}", nblocks=n_blocks,
                      kv_quant="int8", ref_reqs=reqs_fp)
    m_eq, _ = add_row(f"int8_equal_bytes_n{eq_blocks}", nblocks=eq_blocks,
                      kv_quant="int8", ref_reqs=reqs_fp)

    # the demonstration the ISSUE asks for: the int8 pool completes the
    # whole mix at <= 50% of the fp paged pool's KV bytes (same blocks —
    # fp_paged doubles as the fp-at-2x-bytes throughput reference) ...
    assert m_q8["requests_finished"] == len(prompts)
    assert m_q8["kv_pool_bytes"] <= 0.5 * m_fp["kv_pool_bytes"]
    assert m_q8["kv_bytes_swept"] <= 0.5 * m_fp["kv_bytes_swept"]
    # ... with throughput within tolerance of the fp engine (identical
    # schedule shapes; only storage numerics differ)
    assert (m_q8["batch_tokens_per_round"]
            >= 0.7 * m_fp["batch_tokens_per_round"])
    # ... and the equal-byte pool really is >= 2x blocks, <= same bytes
    assert m_eq["kv_pool_blocks"] >= 2 * m_fp["kv_pool_blocks"]
    assert m_eq["kv_pool_bytes"] <= m_fp["kv_pool_bytes"]
    assert m_eq["requests_finished"] == len(prompts)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="untrained pair + tiny mix (CI lane)")
    ap.add_argument("--json", default=None,
                    help="write the comparison as JSON (CI artifact)")
    args = ap.parse_args()
    print("\n".join(run(smoke=args.smoke, json_path=args.json)))


if __name__ == "__main__":
    main()
