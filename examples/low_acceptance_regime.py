"""Paper §4.4 at CPU scale: the low-acceptance (Gemma-27B/2B-like) regime.

A weak, divergently-trained draft makes speculation barely worthwhile
(k_opt collapses toward 2).  The example shows what the paper shows:
entropy-driven adaptation (AdaEDL) degrades, while the post-hoc KLD/WVIR
signal keeps DSDE near the static optimum.

Run:  PYTHONPATH=src python examples/low_acceptance_regime.py
"""
import os
import sys
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import common
from benchmarks.table3_latency_speedup import static_opt


def main():
    for regime in ("llama", "gemma"):
        print(f"== {regime} pair "
              f"({'strong draft' if regime == 'llama' else 'weak, divergent draft'}) ==")
        cfg_t, cfg_d, pt, pd, ratio = common.build_pair(regime)
        prompts = []
        for name in ("code", "news", "dialogue"):
            prompts += common.dataset(name).prompts(3, 16, seed=4)

        sl_opt, lu_opt, m_opt = static_opt(cfg_t, cfg_d, pt, pd, prompts,
                                           ratio, 0.0)
        print(f"  static-opt: k_opt={sl_opt} latency_units={lu_opt:.1f} "
              f"acceptance={m_opt['mean_acceptance']:.2f}")
        for policy in ("dsde", "adaedl"):
            m, _, _ = common.serve(cfg_t, cfg_d, pt, pd, prompts,
                                   policy=policy)
            lu = common.latency_units(m, ratio)
            print(f"  {policy:8s}: latency_units={lu:.1f} "
                  f"(+{(lu / lu_opt - 1) * 100:.0f}% vs static-opt) "
                  f"acceptance={m['mean_acceptance']:.2f} "
                  f"BE={m['block_efficiency']:.2f}")


if __name__ == "__main__":
    main()
