"""Quickstart: DSDE speculative decoding in ~60 lines.

Builds a tiny target/draft pair (random weights, draft = perturbed target
so acceptance is non-trivial), serves a batch of prompts with the DSDE
dynamic-SL policy, and prints the telemetry that matters: block
efficiency, acceptance rate, and per-request outputs.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.config import ServingConfig, SpecDecodeConfig
from repro.models.module import init_params
from repro.models.transformer import model_specs
from repro.serving.engine import ServingEngine
from repro.serving.request import Request


def main():
    # 1. a reduced SmolLM-family target + a correlated draft
    cfg = get_config("smollm-135m").reduced()
    params_t = init_params(model_specs(cfg), jax.random.PRNGKey(1),
                           jnp.float32)
    noise = init_params(model_specs(cfg), jax.random.PRNGKey(7), jnp.float32)
    params_d = jax.tree_util.tree_map(lambda a, b: a + 0.03 * b,
                                      params_t, noise)

    # 2. the DSDE engine: training-free dynamic SL + adaptive SL cap
    spec = SpecDecodeConfig(policy="dsde", temperature=0.0, use_sl_cap=True)
    serving = ServingConfig(max_batch_size=4, max_seq_len=256)
    engine = ServingEngine(params_t, cfg, params_d, cfg, spec, serving)

    # 3. a heterogeneous batch of requests
    rng = np.random.RandomState(0)
    requests = [
        Request(i, prompt=rng.randint(0, cfg.vocab_size,
                                      size=rng.randint(6, 24)).tolist(),
                max_new_tokens=32)
        for i in range(8)
    ]
    metrics = engine.run(requests)

    # 4. what you get
    print(f"tokens emitted      : {metrics['tokens_emitted']}")
    print(f"verification rounds : {metrics['rounds']}")
    print(f"block efficiency    : {metrics['block_efficiency']:.2f} "
          f"(tokens per target verification)")
    print(f"mean acceptance     : {metrics['mean_acceptance']:.2f}")
    print(f"throughput          : {metrics['throughput_tok_s']:.1f} tok/s "
          f"(CPU, reduced model)")
    for r in requests[:3]:
        print(f"  request {r.request_id}: {len(r.output)} tokens, "
              f"BE={r.block_efficiency():.2f}, out[:8]={r.output[:8]}")


if __name__ == "__main__":
    main()
