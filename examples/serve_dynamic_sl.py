"""End-to-end serving driver (deliverable b): train a real target/draft
pair, then serve a heterogeneous request stream with continuous batching,
comparing all five registered SL policies (including the goodput
controller added purely through the SpecPolicy API).

This is the full paper pipeline at CPU scale: training-free calibration,
per-sequence per-iteration SL from KLD-variance stability (WVIR), and the
adaptive SL cap against stragglers.

Run:  PYTHONPATH=src python examples/serve_dynamic_sl.py
      (first run trains the pair, ~3 min on CPU; cached afterwards)
"""
import numpy as np

import os
import sys
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import common


def main():
    print("== building trained target/draft pair (cached) ==")
    cfg_t, cfg_d, pt, pd, ratio = common.build_pair("llama")
    print(f"   draft/target FLOP ratio: {ratio:.3f}")

    # heterogeneous workload: code-like + dialogue-like requests interleaved
    prompts = []
    for i, name in enumerate(common.DATASETS):
        prompts += common.dataset(name).prompts(4, 16, seed=42 + i)
    rng = np.random.RandomState(0)
    rng.shuffle(prompts)

    print(f"== serving {len(prompts)} requests, batch=8, max_new=48 ==")
    header = (f"{'policy':16s} {'rounds':>7s} {'BE':>6s} {'accept':>7s} "
              f"{'latency_units':>14s} {'speedup':>8s}")
    print(header)
    lu_ar = None
    for policy in ("autoregressive", "static", "adaedl", "dsde", "goodput"):
        m, reqs, eng = common.serve(cfg_t, cfg_d, pt, pd, prompts,
                                    policy=policy, max_new=48, batch=8,
                                    goodput_draft_cost=ratio)
        lu = common.latency_units(m, ratio)
        if policy == "autoregressive":   # the speedup baseline row
            lu_ar = lu
        print(f"{policy:16s} {m['rounds']:7d} {m['block_efficiency']:6.2f} "
              f"{m['mean_acceptance']:7.2f} {lu:14.1f} "
              f"{lu_ar / lu:7.2f}x")

    print("\n== DSDE per-round dynamics (first 12 rounds) ==")
    _, _, eng = common.serve(cfg_t, cfg_d, pt, pd, prompts, policy="dsde",
                             max_new=48, batch=8)
    for i, r in enumerate(eng.round_log[:12]):
        print(f"  round {i:2d}: K={r['k']} emitted={r['emitted']:.0f} "
              f"accepted={r['accepted']:.0f}/{r['proposed']:.0f}")


if __name__ == "__main__":
    main()
