"""End-to-end serving driver (deliverable b): train a real target/draft
pair, then serve a heterogeneous request stream with continuous batching,
comparing all five registered SL policies (including the goodput
controller added purely through the SpecPolicy API).

This is the full paper pipeline at CPU scale: training-free calibration,
per-sequence per-iteration SL from KLD-variance stability (WVIR), and the
adaptive SL cap against stragglers.  Both engine schedules are exercised:
the synchronous lockstep loop and the plan → dispatch → collect pipeline
(DESIGN.md §7), which must emit byte-identical greedy streams.

Run:  PYTHONPATH=src python examples/serve_dynamic_sl.py
      (first run trains the pair, ~3 min on CPU; cached afterwards)

      PYTHONPATH=src python examples/serve_dynamic_sl.py --smoke
      (CI lane: untrained pair, tiny mix, seconds not minutes)
"""
import argparse

import numpy as np

import os
import sys
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import common


def build_pair(smoke: bool):
    return common.untrained_pair() if smoke else common.build_pair("llama")


def main():
    from repro.core.drafters import available_drafters

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="untrained pair + tiny mix (CI lane)")
    ap.add_argument("--drafter", default="model",
                    choices=list(available_drafters()),
                    help="proposer for every policy row (DESIGN.md §9); "
                         "model-free drafters serve with ZERO draft "
                         "params and zero draft KV blocks")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="serve under a (data, model) mesh, e.g. 1x4 or "
                         "2x2 (DESIGN.md §5).  Needs DxM visible devices "
                         "— on CPU export XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N first "
                         "(the CI multidevice lane does).  Greedy streams "
                         "are byte-identical to the single-device engine.")
    ap.add_argument("--prefix-share", type=float, default=0.0, metavar="S",
                    help="fraction in [0,1) of every prompt that is a "
                         "common head; >0 serves on the paged pool with "
                         "prefix caching on (DESIGN.md §12) and reports "
                         "the hit/COW telemetry per policy")
    ap.add_argument("--kv-quant", default="none", choices=["none", "int8"],
                    help="paged-pool storage mode (DESIGN.md §13): int8 "
                         "serves off the quantized block pool — same "
                         "block count, under half the KV bytes (implies "
                         "the paged data plane)")
    args = ap.parse_args()
    if not 0.0 <= args.prefix_share < 1.0:
        ap.error("--prefix-share must be in [0, 1)")

    label = "untrained (smoke)" if args.smoke else "trained (cached)"
    print(f"== building target/draft pair: {label} ==")
    cfg_t, cfg_d, pt, pd, ratio = build_pair(args.smoke)
    print(f"   draft/target FLOP ratio: {ratio:.3f}")
    print(f"   drafter: {args.drafter}")
    if args.mesh:
        print(f"   mesh: {args.mesh} (data x model)")

    # heterogeneous workload: code-like + dialogue-like requests interleaved
    per = 2 if args.smoke else 4
    max_new = 12 if args.smoke else 48
    prompts = []
    for i, name in enumerate(common.DATASETS):
        prompts += common.dataset(name).prompts(per, 16, seed=42 + i)
    rng = np.random.RandomState(0)
    rng.shuffle(prompts)

    paged_kw = {}
    batch = 8
    if args.prefix_share > 0:
        # half the slots: the first admission wave is cold (it *creates*
        # the cache entries), later waves hit the registered head — with
        # batch >= len(prompts) every request admits cold simultaneously
        batch = 4
        # shared head sized so head/(head+tail) ~= share, block-aligned
        # so full blocks are hashable; the paged pool + prefix caching
        # turn the repeats into cache hits (DESIGN.md §12)
        bs, tail = 16, 16
        head_len = int(round(args.prefix_share
                             / (1 - args.prefix_share) * tail))
        head_len = max(head_len // bs * bs, bs)
        head = common.dataset("code").prompts(1, head_len, seed=7)[0]
        prompts = [head + p for p in prompts]
        paged_kw = dict(paged=True, kv_block_size=bs, prefix_caching=True)
        print(f"== prefix share {args.prefix_share:.2f}: common head of "
              f"{head_len} tokens, paged pool + prefix caching on ==")
    if args.kv_quant != "none":
        paged_kw.update(paged=True, kv_quant=args.kv_quant)
        paged_kw.setdefault("kv_block_size", 16)
        print(f"== kv_quant {args.kv_quant}: int8 block pool, dequant "
              "fused into the verify kv-sweep (DESIGN.md §13) ==")

    print(f"== serving {len(prompts)} requests, batch={batch}, "
          f"max_new={max_new} ==")
    header = (f"{'policy':16s} {'rounds':>7s} {'BE':>6s} {'accept':>7s} "
              f"{'latency_units':>14s} {'speedup':>8s}")
    print(header)
    lu_ar = None
    # model drafter: the pair's emulated cost ratio; model-free
    # drafters let the engine source the cost from Drafter.step_cost()
    cost_kw = ({"goodput_draft_cost": ratio}
               if args.drafter == "model" else {})
    # "slo" rides with no deadlines set, so its row must equal dsde's —
    # the DESIGN.md §15 no-deadline exactness bar, live in the demo
    for policy in ("autoregressive", "static", "adaedl", "dsde", "goodput",
                   "slo"):
        m, reqs, eng = common.serve(cfg_t, cfg_d, pt, pd, prompts,
                                    policy=policy, max_new=max_new, batch=batch,
                                    drafter=args.drafter, mesh=args.mesh,
                                    **cost_kw, **paged_kw)
        lu = common.latency_units(
            m, ratio if args.drafter == "model" else m["draft_step_cost"])
        if policy == "autoregressive":   # the speedup baseline row
            lu_ar = lu
        cache = ""
        if args.prefix_share > 0:
            cache = (f"  hit_rate={m['prefix_cache_hit_rate']:.2f} "
                     f"hit_blocks={m['prefix_cache_hit_blocks']:.0f} "
                     f"cow={m['cow_copies']:.0f}")
        print(f"{policy:16s} {m['rounds']:7d} {m['block_efficiency']:6.2f} "
              f"{m['mean_acceptance']:7.2f} {lu:14.1f} "
              f"{lu_ar / lu:7.2f}x{cache}")

    print("\n== sync vs pipelined schedule (dsde, identical streams) ==")
    streams = {}
    for pipelined in (False, True):
        m, reqs, eng = common.serve(cfg_t, cfg_d, pt, pd, prompts,
                                    policy="dsde", max_new=max_new, batch=batch,
                                    drafter=args.drafter, mesh=args.mesh,
                                    pipelined=pipelined, **paged_kw)
        streams[pipelined] = [r.output for r in reqs]
        mode = "pipelined" if pipelined else "sync"
        print(f"  {mode:9s}: wall={m['wall_time_s']:.2f}s "
              f"rounds={m['rounds']} "
              f"host_blocked/round={m['host_blocked_per_round_s'] * 1e3:.1f}ms "
              f"ttft_mean={m['ttft_mean_s'] * 1e3:.0f}ms "
              f"queue_wait={m['queue_wait_mean_s'] * 1e3:.0f}ms")
    assert streams[False] == streams[True], "schedules must not change tokens"
    print("  token streams byte-identical across schedules: OK")

    print("\n== DSDE per-round dynamics (first 12 rounds) ==")
    _, _, eng = common.serve(cfg_t, cfg_d, pt, pd, prompts, policy="dsde",
                             drafter=args.drafter, mesh=args.mesh,
                             max_new=max_new, batch=batch, **paged_kw)
    for i, r in enumerate(eng.round_log[:12]):
        print(f"  round {i:2d}: K={r['k']} emitted={r['emitted']:.0f} "
              f"accepted={r['accepted']:.0f}/{r['proposed']:.0f}")


if __name__ == "__main__":
    main()
