"""Async serving front-end demo (DESIGN.md §14): token streaming,
open-loop trace replay, and the replay-at-zero exactness check.

Three acts over one continuous-batching front-end
(``repro.serving.frontend``) wrapping the pipelined engine:

1. **Streaming** — requests submitted from the caller's thread against
   the live driver thread; each consumer iterates its
   :class:`StreamHandle` and sees tokens the moment the host
   reconciles them (per-token callbacks out of collect()).
2. **Trace replay** — a seeded bursty trace (benchmarks/loadgen.py)
   replayed open-loop at its arrival offsets, reporting TTFT/TPOT
   percentiles, queue depth, and goodput.
3. **Exactness** — the same trace with every arrival at t=0 must
   produce byte-identical streams to a direct ``ServingEngine.run()``:
   ``pump()`` is run()'s loop body, so the front-end adds concurrency,
   never different tokens.

Run:  PYTHONPATH=src python examples/stream_serving.py
      (first run trains the pair, ~3 min on CPU; cached afterwards)

      PYTHONPATH=src python examples/stream_serving.py --smoke
      (CI lane: untrained pair, tiny trace, seconds not minutes)

For the HTTP layer over this same front-end, see
``python -m repro.launch.serve --http`` (OpenAI-compatible, SSE).
"""
import argparse
import os
import sys
import threading

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import common, loadgen


def _engine(cfg_t, cfg_d, pt, pd):
    from repro.core.config import ServingConfig, SpecDecodeConfig
    from repro.serving.engine import ServingEngine

    spec = SpecDecodeConfig(policy="dsde", sf_normalize=True)
    sv = ServingConfig(max_batch_size=4, max_seq_len=256, paged_kv=True,
                       kv_block_size=16, pipelined=True)
    return ServingEngine(pt, cfg_t, pd, cfg_d, spec, sv, seed=0)


def main():
    from repro.serving.frontend import ServingFrontend

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="untrained pair + tiny trace (CI lane)")
    args = ap.parse_args()

    label = "untrained (smoke)" if args.smoke else "trained (cached)"
    print(f"== building target/draft pair: {label} ==")
    if args.smoke:
        cfg_t, cfg_d, pt, pd, _ = common.untrained_pair()
        n_req, max_new = 6, 8
    else:
        cfg_t, cfg_d, pt, pd, _ = common.build_pair("llama")
        n_req, max_new = 12, 24

    # -- act 1: live token streaming ------------------------------------
    print("\n== streaming: consumers see tokens as rounds reconcile ==")
    fe = ServingFrontend(_engine(cfg_t, cfg_d, pt, pd)).start()
    prompts = common.dataset("dialogue").prompts(3, 12, seed=4)
    handles = [fe.submit(p, max_new_tokens=max_new) for p in prompts]
    lines = {}

    def _consume(i, handle):
        got = []
        for tok in handle:              # blocks until each token lands
            got.append(tok)
        lines[i] = got

    threads = [threading.Thread(target=_consume, args=(i, h))
               for i, h in enumerate(handles)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for i, h in enumerate(handles):
        print(f"  req {i}: {len(lines[i])} tokens streamed, "
              f"finish={h.request.finish_reason()}  "
              f"ttft={h.request.ttft() * 1e3:.0f}ms")
        assert lines[i] == h.request.output
    fe.stop()

    # -- act 2: open-loop bursty trace replay ---------------------------
    print("\n== trace replay: bursty arrivals, open loop ==")
    trace = loadgen.make_trace(n_req, rate_rps=4.0, process="bursty",
                               seed=13, max_new_cap=max_new)
    fe = ServingFrontend(_engine(cfg_t, cfg_d, pt, pd)).start()
    try:
        point = loadgen.replay(fe, trace)
    finally:
        fe.stop()
    print(f"  finished {point['requests_finished']}/{point['requests']} "
          f"({point['tokens_emitted']} tokens) in {point['wall_s']:.2f}s")
    print(f"  ttft p50/p99 = {point['ttft_s_p50'] * 1e3:.0f}/"
          f"{point['ttft_s_p99'] * 1e3:.0f} ms   "
          f"tpot p50 = {point['tpot_s_p50'] * 1e3:.0f} ms")
    print(f"  queue depth peak = {point['queue_depth_peak']:.0f}   "
          f"goodput = {point['goodput_tok_s']:.1f} tok/s "
          f"(SLO-attained {point['slo_attained_frac']:.0%})")

    # -- act 3: replay-at-zero == run() ---------------------------------
    print("\n== exactness: replay at t=0 vs direct run() ==")
    ref = loadgen.trace_requests(trace)
    _engine(cfg_t, cfg_d, pt, pd).run(ref)
    fe = ServingFrontend(_engine(cfg_t, cfg_d, pt, pd))
    reqs = loadgen.trace_requests(trace)
    for r in reqs:
        fe.submit_request(r)
    fe.run_until_drained()
    assert [r.output for r in reqs] == [r.output for r in ref], \
        "front-end replay diverged from run()"
    print("  token streams byte-identical: OK")


if __name__ == "__main__":
    main()
