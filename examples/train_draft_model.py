"""Train a ~1M-param draft model for a few hundred steps on the synthetic
task mixture, checkpoint it, and measure how its acceptance rate against
the cached target improves with training — the full training substrate
(data pipeline, AdamW, checkpointing) end to end.

Run:  PYTHONPATH=src python examples/train_draft_model.py
"""
import dataclasses

import numpy as np

import os
import sys
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import common
from repro.core.config import OptimizerConfig, TrainConfig
from repro.training.checkpoint import save_checkpoint
from repro.training.data import lm_batches
from repro.training.train import train_loop


def main():
    cfg_t, _, pt, _, _ = common.build_pair("llama")   # cached target
    cfg_d = common.draft_config()
    stream = common.mixed_stream()
    prompts = common.dataset("code").prompts(6, 12, seed=1)

    pd = None
    for steps in (40, 120, 250):
        tc = TrainConfig(global_batch_size=16, seq_len=64,
                         optimizer=OptimizerConfig(learning_rate=3e-3,
                                                   warmup_steps=20,
                                                   total_steps=steps,
                                                   grad_clip=5.0))
        pd, m = train_loop(cfg_d, tc, lm_batches(stream, 16, 64, seed=11),
                           num_steps=steps, verbose=False, seed=11)
        res, _, _ = common.serve(cfg_t, cfg_d, pt, pd, prompts,
                                 policy="static", static_sl=4)
        print(f"draft @ {steps:3d} steps: loss={m['loss']:.3f}  "
              f"acceptance={res['mean_acceptance']:.2f}  "
              f"BE={res['block_efficiency']:.2f}")

    path = save_checkpoint("/tmp/repro_example_draft", 250, pd)
    print(f"checkpointed trained draft to {path}")


if __name__ == "__main__":
    main()
