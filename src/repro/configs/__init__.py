"""Architecture registry.

Every assigned architecture has a ``src/repro/configs/<id>.py`` exporting
``CONFIG``; this package exposes ``get_config(arch_id)`` /
``list_archs()`` used by ``--arch`` flags across the launch scripts.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.core.config import ModelConfig

# assigned architecture ids -> module names
_ARCHS = [
    "qwen3_32b",
    "granite_moe_3b_a800m",
    "mamba2_130m",
    "qwen2_vl_2b",
    "qwen2_5_32b",
    "granite_8b",
    "seamless_m4t_medium",
    "recurrentgemma_2b",
    "mixtral_8x22b",
    "smollm_135m",
    # paper's own experiment pairs (emulated scale)
    "paper_llama_pair",
    "paper_gemma_pair",
]

_ALIAS = {
    "qwen3-32b": "qwen3_32b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "mamba2-130m": "mamba2_130m",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "qwen2.5-32b": "qwen2_5_32b",
    "granite-8b": "granite_8b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "mixtral-8x22b": "mixtral_8x22b",
    "smollm-135m": "smollm_135m",
    "paper-llama-pair": "paper_llama_pair",
    "paper-gemma-pair": "paper_gemma_pair",
}


def list_archs() -> List[str]:
    return [a.replace("_", "-").replace("qwen2-5", "qwen2.5") for a in _ARCHS]


def get_config(arch: str) -> ModelConfig:
    mod_name = _ALIAS.get(arch, arch.replace("-", "_").replace(".", "_"))
    if mod_name not in _ARCHS:
        raise KeyError(f"unknown arch {arch!r}; available: {list_archs()}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in list_archs()}
