"""Mamba2-130M [ssm] — SSD (state-space duality). [arXiv:2405.21060]"""
from repro.core.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=1,          # attention-free; SSD heads derived from SSMConfig
    num_kv_heads=1,
    d_ff=0,               # mamba block replaces attn+mlp
    vocab_size=50280,
    ssm=SSMConfig(state_size=128, head_dim=64, expand=2, chunk_size=256),
    tie_embeddings=True,
    source="arXiv:2405.21060",
)
