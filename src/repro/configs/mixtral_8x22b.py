"""Mixtral-8x22B [moe] — 8 experts top-2, sliding-window attention.
[arXiv:2401.04088]"""
from repro.core.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    head_dim=128,
    rope_theta=1_000_000.0,
    attention_window=4096,     # SWA — makes long_500k natively tractable
    moe=MoEConfig(num_experts=8, top_k=2, expert_d_ff=16384),
    tie_embeddings=False,
    source="arXiv:2401.04088",
)
