"""Paper low-acceptance pair: Gemma-27B target / Gemma-2B draft
(high draft-target divergence regime, paper §4.4)."""
from repro.core.config import ModelConfig

CONFIG = ModelConfig(
    name="paper-gemma-pair",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    d_ff=36864,
    vocab_size=256128,
    head_dim=128,
    rope_theta=10000.0,
    qk_norm=True,
    tie_embeddings=True,
    source="paper §4.4 (Gemma-27B / Gemma-2B)",
)
