"""Paper experiment pair: LLaMA-3.1-70B target / LLaMA-3.2-1B draft,
emulated at reduced scale for CPU experiments (see DESIGN.md §3).
The full-size config is the real 70B geometry for dry-runs."""
from repro.core.config import ModelConfig

CONFIG = ModelConfig(
    name="paper-llama-pair",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    head_dim=128,
    rope_theta=500000.0,
    tie_embeddings=False,
    source="paper §4.1 (LLaMA-3.1-70B-Instruct / LLaMA-3.2-1B-Instruct)",
)
