"""Qwen2-VL-2B [vlm] — M-RoPE, dynamic resolution. Vision frontend is a
stub per assignment: input_specs() provides patch embeddings.
[arXiv:2409.12191]"""
from repro.core.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),   # (temporal, h, w) halves of head_dim/2
    frontend_dim=1536,             # ViT projector output == d_model
    tie_embeddings=True,
    source="arXiv:2409.12191",
)
