"""Qwen3-32B [dense] — qk_norm, GQA. [hf:Qwen/Qwen3-8B]"""
from repro.core.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=64,
    num_kv_heads=8,
    d_ff=25600,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    source="hf:Qwen/Qwen3-8B",
)
