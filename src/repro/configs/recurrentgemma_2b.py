"""RecurrentGemma-2B [hybrid] — RG-LRU + local attention, 1:2 pattern.
[arXiv:2402.19427]"""
from repro.core.config import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,      # MQA for the local-attention layers
    d_ff=7680,
    vocab_size=256000,
    head_dim=256,
    rglru=RGLRUConfig(lru_width=2560, blocks_per_attention=2,
                      local_attention_window=2048),
    tie_embeddings=True,
    source="arXiv:2402.19427",
)
