"""SeamlessM4T-medium [audio] — enc-dec, multimodal. Audio frontend
(mel+conv) is a stub per assignment: input_specs() provides frame
embeddings. [arXiv:2308.11596]"""
from repro.core.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,              # decoder layers
    num_encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,            # MHA
    d_ff=4096,
    vocab_size=256206,
    head_dim=64,
    is_encoder_decoder=True,
    frontend_dim=1024,
    tie_embeddings=True,
    source="arXiv:2308.11596",
)
