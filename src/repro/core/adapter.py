"""The DSDE SL Adapter math (paper §3.1) — Eq. 1-11 + the AdaEDL bound.

This is the *numerical* library; the controller objects that plug it
into the serving round live in :mod:`repro.core.policies` (DESIGN.md §6).

Implements, per sequence and per iteration:

* Eq. (1)  dynamic calibration of SL_max from the pre-processing phase;
* Eq. (3)  SF  = exp(sf_scale * mu_KLD,last) - 1;
* Eq. (4)  WVIR (delegated to :mod:`repro.core.signals`);
* Eq. (2)/(8)  SL-hat = (1 - SF*WVIR) * (SL_max - SL_min) + SL_min, with the
  conservative floor when the penalty signals extreme instability;
* Eq. (11) SL_cap = mean of per-sequence predictions (the MSE-minimizing
  consensus, §3.3) applied batch-wide;
* AdaEDL baseline (entropy-based draft early stopping) and static SL.

State is a :class:`AdapterState` pytree so the whole policy jits into the
serving step (per-step Python recompilation would reintroduce exactly the
eager-mode overhead the paper complains about).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import SpecDecodeConfig
from repro.core.signals import KLDHistory, wvir


class AdapterState(NamedTuple):
    history: KLDHistory
    mu_kld_last: jax.Array          # [B] mean KLD of the last verified step
    sl_max: jax.Array               # [B] calibrated effective max (Eq. 1)
    # calibration statistics (accumulated during the pre-processing phase)
    calib_steps: jax.Array          # [B] steps observed so far
    calib_kld_sum: jax.Array        # [B] sum of token KLDs
    calib_kld_count: jax.Array      # [B] token count
    calib_kld_max: jax.Array        # [B] max single KLD
    calib_acc_max: jax.Array        # [B] SL_{A,max}: max accepted in a step
    # last predicted SL (for telemetry / tests)
    sl_pred: jax.Array              # [B] int32


def init_adapter_state(batch: int, cfg: SpecDecodeConfig) -> AdapterState:
    return AdapterState(
        history=KLDHistory.init(batch, cfg.long_window),
        mu_kld_last=jnp.zeros((batch,), jnp.float32),
        sl_max=jnp.full((batch,), float(cfg.sl_max), jnp.float32),
        calib_steps=jnp.zeros((batch,), jnp.int32),
        calib_kld_sum=jnp.zeros((batch,), jnp.float32),
        calib_kld_count=jnp.zeros((batch,), jnp.float32),
        calib_kld_max=jnp.zeros((batch,), jnp.float32),
        calib_acc_max=jnp.zeros((batch,), jnp.int32),
        sl_pred=jnp.full((batch,), cfg.static_sl, jnp.int32),
    )


def reset_rows(state: AdapterState, rows: jax.Array,
               cfg: SpecDecodeConfig) -> AdapterState:
    """Reset per-sequence adapter state for replaced slots."""
    # lazy import: policies sits above this numerical layer
    from repro.core.policies.base import masked_row_reset
    return masked_row_reset(init_adapter_state(rows.shape[0], cfg),
                            state, rows)


# ---------------------------------------------------------------------------
# Observation update (runs after every verification step)
# ---------------------------------------------------------------------------

def observe(state: AdapterState, cfg: SpecDecodeConfig, *,
            kld: jax.Array,            # [B, T] per-position KL(target||draft)
            proposed_valid: jax.Array,  # [B, T] which positions were proposed
            num_accepted: jax.Array,    # [B] accepted draft tokens this step
            active: Optional[jax.Array] = None) -> AdapterState:
    """Fold one verification step's post-hoc statistics into the state."""
    if kld.shape[-1] == 0:      # autoregressive baseline: nothing proposed
        return state
    v = proposed_valid.astype(jnp.float32)
    tok_count = v.sum(-1)
    step_sum = (kld * v).sum(-1)
    mu_step = step_sum / jnp.maximum(tok_count, 1.0)                # [B]
    step_max = jnp.where(proposed_valid, kld, -jnp.inf).max(-1)
    step_max = jnp.where(jnp.isfinite(step_max), step_max, 0.0)

    in_calib = state.calib_steps < cfg.calibration_steps
    took_step = tok_count > 0
    if active is not None:
        took_step = took_step & active

    upd = took_step & in_calib
    calib_steps = jnp.where(upd, state.calib_steps + 1, state.calib_steps)
    calib_kld_sum = jnp.where(upd, state.calib_kld_sum + step_sum,
                              state.calib_kld_sum)
    calib_kld_count = jnp.where(upd, state.calib_kld_count + tok_count,
                                state.calib_kld_count)
    calib_kld_max = jnp.where(upd, jnp.maximum(state.calib_kld_max, step_max),
                              state.calib_kld_max)
    calib_acc_max = jnp.where(
        upd, jnp.maximum(state.calib_acc_max, num_accepted.astype(jnp.int32)),
        state.calib_acc_max)

    # Eq. (1): once the calibration window closes, freeze SL_max.
    done = calib_steps >= cfg.calibration_steps
    mu_pre = calib_kld_sum / jnp.maximum(calib_kld_count, 1.0)
    sl_a_max = jnp.maximum(calib_acc_max, 1).astype(jnp.float32)
    sl_max_calib = sl_a_max * (1.0 + mu_pre / (calib_kld_max + cfg.eps))
    sl_max_calib = jnp.clip(sl_max_calib, cfg.sl_min + 1, cfg.sl_max)
    sl_max = jnp.where(done, sl_max_calib, state.sl_max)

    history = state.history.push(mu_step, active=took_step)
    mu_last = jnp.where(took_step, mu_step, state.mu_kld_last)

    return state._replace(
        history=history, mu_kld_last=mu_last, sl_max=sl_max,
        calib_steps=calib_steps, calib_kld_sum=calib_kld_sum,
        calib_kld_count=calib_kld_count, calib_kld_max=calib_kld_max,
        calib_acc_max=calib_acc_max)


# ---------------------------------------------------------------------------
# Prediction — Eq. (2)/(3)/(8) + SL_cap Eq. (11)
# ---------------------------------------------------------------------------

def scale_factor(mu_kld_last: jax.Array, cfg: SpecDecodeConfig,
                 mu_calib: Optional[jax.Array] = None) -> jax.Array:
    """Eq. (3); optionally the scale-invariant variant (beyond-paper,
    see SpecDecodeConfig.sf_normalize)."""
    if cfg.sf_normalize and mu_calib is not None:
        rel = mu_kld_last / jnp.maximum(mu_calib, cfg.eps) - 1.0
        return jnp.maximum(jnp.exp(cfg.sf_scale * rel) - 1.0, 0.0)
    return jnp.exp(cfg.sf_scale * mu_kld_last) - 1.0


def predict_sl(state: AdapterState, cfg: SpecDecodeConfig,
               active: Optional[jax.Array] = None
               ) -> Tuple[jax.Array, AdapterState, dict]:
    """Per-sequence SL for the next iteration. Returns (sl [B] int32,
    new_state, telemetry)."""
    mu_calib = state.calib_kld_sum / jnp.maximum(state.calib_kld_count, 1.0)
    sf = scale_factor(state.mu_kld_last, cfg, mu_calib)
    w = wvir(state.history, cfg.short_window, cfg.long_window, cfg.decay,
             cfg.eps)
    penalty = sf * w
    dsl = state.sl_max - float(cfg.sl_min)
    raw = (1.0 - penalty) * dsl + cfg.sl_min
    # Eq. (8): extreme instability -> most conservative strategy.
    sl = jnp.where(penalty >= cfg.penalty_cutoff,
                   float(cfg.sl_min), raw)
    # during calibration, run the fixed calibration SL
    in_calib = state.calib_steps < cfg.calibration_steps
    sl = jnp.where(in_calib, float(cfg.calibration_sl), sl)

    telemetry = {"sf": sf, "wvir": w, "penalty": penalty,
                 "sl_raw": raw, "sl_max": state.sl_max}

    if cfg.use_sl_cap:
        sl, cap = apply_sl_cap(sl, cfg, active)
        telemetry["sl_cap"] = cap
    sl_i = jnp.clip(jnp.round(sl), cfg.sl_min, cfg.sl_max).astype(jnp.int32)
    return sl_i, state._replace(sl_pred=sl_i), telemetry


def apply_sl_cap(sl: jax.Array, cfg: SpecDecodeConfig,
                 active: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Eq. (9)-(11): cap = argmin_c MSE(c, {SL_i}) = mean(SL_i), applied
    uniformly — prevents straggler speculation lengths from stalling the
    batch (§3.3).  Inactive slots are excluded from the consensus."""
    if active is None:
        cap = sl.mean()
    else:
        a = active.astype(jnp.float32)
        cap = (sl * a).sum() / jnp.maximum(a.sum(), 1.0)
    return jnp.minimum(sl, cap), cap


# ---------------------------------------------------------------------------
# Baseline policies
# ---------------------------------------------------------------------------

def static_sl(batch: int, cfg: SpecDecodeConfig) -> jax.Array:
    return jnp.full((batch,), cfg.static_sl, jnp.int32)


def adaedl_stop_threshold(entropy: jax.Array,
                          cfg: SpecDecodeConfig) -> jax.Array:
    """AdaEDL: an entropy-based lower bound on the token acceptance
    probability; drafting stops when the bound drops under the threshold.

        p_accept >= 1 - sqrt(max(0, 1 - exp(-H(q))))   (AdaEDL-style bound)

    Returns a boolean [B] / [B,T] 'keep drafting' indicator."""
    bound = 1.0 - jnp.sqrt(jnp.maximum(0.0, 1.0 - jnp.exp(-entropy)))
    return bound >= cfg.adaedl_threshold
