"""Configuration system for the DSDE reproduction framework.

Every architecture in ``repro/configs/`` builds a :class:`ModelConfig`;
the serving / training / distribution layers consume the sibling configs.

Design notes
------------
* Plain frozen dataclasses — hashable, usable as jit static args.
* ``ModelConfig.reduced()`` derives the CPU smoke-test variant mandated by
  the assignment (<=2 layers, d_model<=512, <=4 experts).
* ``attention_window`` enables the sliding-window variant that makes
  ``long_500k`` tractable for dense architectures (beyond-paper extension,
  see DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Model architecture
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration."""
    num_experts: int
    top_k: int
    # d_ff of each expert (may differ from the dense d_ff).
    expert_d_ff: int
    # Router options.
    router_jitter: float = 0.0
    load_balance_weight: float = 0.01
    # Sharding strategy: "tp" (tensor-parallel experts, baseline) or
    # "ep" (expert-parallel all-to-all, hillclimb variant).
    sharding: str = "tp"


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block configuration."""
    state_size: int = 128
    head_dim: int = 64
    expand: int = 2
    chunk_size: int = 256
    conv_width: int = 4
    # number of SSD heads = expand*d_model // head_dim (derived)


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma RG-LRU block configuration."""
    lru_width: int = 2560
    conv_width: int = 4
    # pattern: how many recurrent blocks per attention block (2 means
    # [rec, rec, attn] repeating — the paper's 1:2 ratio).
    blocks_per_attention: int = 2
    local_attention_window: int = 2048


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description. One instance per assigned architecture."""

    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // num_heads
    # --- attention options ----------------------------------------------
    qk_norm: bool = False            # qwen3
    qkv_bias: bool = False           # qwen2.5 / qwen2-vl
    rope_theta: float = 10000.0
    mrope_sections: Optional[Tuple[int, int, int]] = None   # qwen2-vl M-RoPE
    attention_window: Optional[int] = None   # sliding-window (mixtral SWA,
                                             # dense long-ctx variant)
    # layout optimization (exact, §Perf): physical KV heads in cache/compute
    # replicated up to this count so the kv dim divides the model axis
    kv_head_pad: Optional[int] = None
    # layout optimization (exact, §Perf): query heads padded (extra heads'
    # wo rows zero) so the head dim divides the model axis
    q_head_pad: Optional[int] = None
    # --- block composition ------------------------------------------------
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # --- enc-dec (audio) ---------------------------------------------------
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    # --- embeddings / head --------------------------------------------------
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    # modality frontend stub: if set, inputs are precomputed embeddings of
    # shape [batch, seq, frontend_dim] instead of token ids.
    frontend_dim: Optional[int] = None
    # citation for provenance (hf model card or arXiv id)
    source: str = ""

    # ----------------------------------------------------------------- utils
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if long_500k decode is natively tractable."""
        return (
            self.family in ("ssm", "hybrid")
            or self.attention_window is not None
        )

    def padded_vocab(self, multiple: int = 2048) -> int:
        """Vocab padded so (a) the embedding shards evenly over 16 model
        shards of 128-lane registers (16*128 = 2048) and (b) there is at
        least one spare row serving as the reserved padding token id
        (paper §3.2) — ``pad_id == vocab_size`` always embeds validly."""
        return ((self.vocab_size + multiple) // multiple) * multiple

    def reduced(self) -> "ModelConfig":
        """CPU smoke-test variant of the same family (assignment carve-out:
        <=2 layers, d_model<=512, <=4 experts)."""
        d_model = min(self.d_model, 256)
        num_heads = min(self.num_heads, 4)
        head_dim = max(d_model // num_heads, 16)
        num_kv = max(1, min(self.num_kv_heads, num_heads,
                            max(1, num_heads * self.num_kv_heads // self.num_heads)))
        kw = dict(
            name=self.name + "-reduced",
            num_layers=min(self.num_layers, 2),
            d_model=d_model,
            num_heads=num_heads,
            num_kv_heads=num_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe,
                num_experts=min(self.moe.num_experts, 4),
                top_k=min(self.moe.top_k, 2),
                expert_d_ff=min(self.moe.expert_d_ff, 256),
            )
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(
                self.ssm, state_size=min(self.ssm.state_size, 32),
                head_dim=32, chunk_size=32)
        if self.rglru is not None:
            kw["rglru"] = dataclasses.replace(
                self.rglru, lru_width=d_model, local_attention_window=64)
        if self.is_encoder_decoder:
            kw["num_encoder_layers"] = min(self.num_encoder_layers, 2)
        if self.frontend_dim is not None:
            kw["frontend_dim"] = d_model
        if self.attention_window is not None:
            kw["attention_window"] = min(self.attention_window, 64)
        if self.mrope_sections is not None:
            # keep 3 sections summing to head_dim//2
            h = head_dim // 2
            kw["mrope_sections"] = (h - 2 * (h // 3), h // 3, h // 3)
        return dataclasses.replace(self, **kw)

    def draft(self) -> "ModelConfig":
        """Same-family draft-model config (the paper's small-draft paradigm):
        ~1/4 depth & width of the target, same vocab + tokenizer."""
        d_model = max(128, self.d_model // 4)
        num_heads = max(2, self.num_heads // 4)
        kw = dict(
            name=self.name + "-draft",
            num_layers=max(2, self.num_layers // 4),
            d_model=d_model,
            num_heads=num_heads,
            num_kv_heads=max(1, min(self.num_kv_heads, num_heads)),
            head_dim=max(32, d_model // num_heads),
            d_ff=max(256, self.d_ff // 4) if self.d_ff else 0,
        )
        if self.moe is not None:
            # drafts are dense — standard practice (cheap, stateless router-free)
            kw["moe"] = None
            kw["family"] = "dense"
            kw["d_ff"] = max(256, self.moe.expert_d_ff)
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(self.ssm, head_dim=32)
        if self.rglru is not None:
            kw["rglru"] = dataclasses.replace(self.rglru, lru_width=d_model)
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# DSDE / speculative decoding
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SpecDecodeConfig:
    """DSDE adapter configuration — defaults follow the paper exactly.

    ``policy`` names a registered :class:`repro.core.policies.SpecPolicy`
    ("dsde" | "static" | "adaedl" | "autoregressive" | "goodput" | any
    policy registered via ``repro.core.policies.register``).

    ``drafter`` names a registered :class:`repro.core.drafters.Drafter`
    ("model" | "ngram" | "self" | any drafter registered via
    ``repro.core.drafters.register_drafter``) — the proposer half of a
    speculation round (DESIGN.md §9), orthogonal to the SL policy."""
    policy: str = "dsde"
    drafter: str = "model"
    sl_min: int = 2                    # paper §3.1.2
    sl_max: int = 10                   # bucket upper bound; Eq.(1) calibrates
    static_sl: int = 4                 # for the static baseline
    # Eq. (5): exponential decay for weighted variance.
    decay: float = 0.85
    short_window: int = 10             # N for Var_w(KLD_short)
    long_window: int = 30              # N for Var_w(KLD_long)
    sf_scale: float = 2.0              # Eq. (3): SF = exp(sf_scale*mu)-1
    # Beyond-paper: scale-invariant SF = exp(sf_scale*(mu/mu_calib - 1))-1
    # (clamped at 0).  Eq. (3)'s absolute constant is tuned to real-LLM KLD
    # magnitudes (~0.1-0.5 nats); miniature/CPU pairs sit at 1-3 nats where
    # the raw form saturates the penalty.  Default off = paper-faithful.
    sf_normalize: bool = False
    # Eq. (1) calibration.
    calibration_steps: int = 4
    calibration_sl: int = 5
    eps: float = 1e-6
    # SL_cap (Eq. 11) on/off — Fig. 9 ablation.
    use_sl_cap: bool = True
    # AdaEDL baseline: stop drafting when entropy-based acceptance lower
    # bound drops below threshold; `adaedl_base` is the paper's base=7.
    adaedl_base: int = 7
    adaedl_threshold: float = 0.1
    # Goodput controller (TurboSpec-style acceptance-EMA policy):
    # EMA decay of the per-round acceptance fraction, the per-draft-step
    # cost relative to one verification (in latency units), and the
    # optimistic acceptance prior used before any observation.
    # ``goodput_draft_cost=None`` (the default) sources the cost from the
    # serving drafter's own ``Drafter.step_cost()`` (model drafters:
    # draft/target FLOP ratio; lookup drafters: ~0); a float here is an
    # explicit override.  Contexts with no drafter in scope (direct
    # policy unit use) fall back to the historical 0.08.
    goodput_ema: float = 0.75
    goodput_draft_cost: Optional[float] = None
    goodput_init_acc: float = 0.7
    # --- drafter knobs (DESIGN.md §9) ----------------------------------
    # ngram: prompt-lookup suffix-match length (the "n" of the n-gram)
    ngram_n: int = 3
    # self: how many leading target layers the early-exit self-draft runs
    self_draft_layers: int = 1
    # sampling
    temperature: float = 0.0           # 0.0 = greedy
    # penalty floor condition (Eq. 8): if SF*WVIR >= penalty_cutoff, SL=SL_min
    penalty_cutoff: float = 1.0


@dataclass(frozen=True)
class ServingConfig:
    max_batch_size: int = 64
    max_seq_len: int = 4096
    max_new_tokens: int = 256
    # reserved padding token id (paper §3.2) — defaults to vocab_size.
    pad_token_id: Optional[int] = None
    eos_token_id: int = 1
    # continuous batching: admit new requests when slots free up.
    continuous_batching: bool = True
    # plan -> dispatch -> collect pipeline (DESIGN.md §7): enqueue round
    # N+1 while round N's outputs are still on the wire and reconcile
    # the host one round behind.  Relies on device-side termination in
    # the round, so greedy token streams are byte-identical to the
    # synchronous engine; False keeps the lockstep step() loop.
    pipelined: bool = False
    # --- paged KV cache (DESIGN.md §4) ---------------------------------
    # block-pool KV layout: sequences hold block tables into a shared
    # pool instead of one dense max_seq_len row per slot; admission is
    # by free-block budget and the scheduler preempts (evict + requeue,
    # recompute on readmit) instead of rejecting when the pool runs dry.
    paged_kv: bool = False
    kv_block_size: int = 16
    # pool size in blocks; None = dense-equivalent capacity
    # (max_batch_size rows of max_seq_len).  Size below that to pack
    # more sequences per byte of HBM than dense rows ever could.
    num_kv_blocks: Optional[int] = None
    # prefix caching (DESIGN.md §12): content-hash committed full blocks
    # and share them copy-on-write across sequences with a common prompt
    # prefix; admission charges only the uncovered suffix and prefill
    # skips the covered tokens.  Requires paged_kv and a non-recurrent
    # model family (per-slot lru/conv state cannot be recovered from the
    # block pool); the engine gates on both.
    prefix_caching: bool = False
    # quantized KV storage (DESIGN.md §4, §13): "none" keeps the pool in
    # the compute dtype; "int8" stores K/V as int8 with per-slot-per-KV-
    # head fp32 amax scales, quantized on write and dequantized inside
    # the verify kv-sweep.  Requires paged_kv and a non-recurrent family
    # (the recurrent rows stay fp and the hybrid cache threading is out
    # of scope); the engine validates.  ``num_kv_blocks`` stays a
    # physical block count — blocks just cost fewer bytes, so an
    # equal-byte budget buys >= 2x blocks (``equal_byte_blocks``).
    kv_quant: str = "none"
    # SLO-aware admission (DESIGN.md §15): how many times a fresh
    # deadline-carrying request whose predicted completion already
    # breaches its deadline may be deferred behind later feasible
    # arrivals before it admits unconditionally anyway.  Bounds the
    # aging so predicted violators are surfaced and de-prioritized but
    # never starved or dropped; 0 disables deferral entirely (predicted
    # violations are still surfaced).
    slo_defer_limit: int = 4

    def blocks_per_seq(self) -> int:
        """Block-table width: worst-case blocks one sequence can hold."""
        return -(-self.max_seq_len // self.kv_block_size)

    def pool_blocks(self) -> int:
        """Resolved pool size in blocks."""
        if self.num_kv_blocks is not None:
            return self.num_kv_blocks
        return self.max_batch_size * self.blocks_per_seq()


# ---------------------------------------------------------------------------
# Training
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class OptimizerConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0


@dataclass(frozen=True)
class TrainConfig:
    global_batch_size: int = 256
    seq_len: int = 4096
    microbatch_size: Optional[int] = None   # for gradient accumulation
    remat: bool = True                       # activation checkpointing
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    checkpoint_every: int = 500
    checkpoint_dir: str = "/tmp/repro_ckpt"


# ---------------------------------------------------------------------------
# Distribution / mesh
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MeshConfig:
    data: int = 16
    model: int = 16
    pod: int = 1

    @property
    def multi_pod(self) -> bool:
        return self.pod > 1

    @property
    def num_devices(self) -> int:
        return self.data * self.model * self.pod


@dataclass(frozen=True)
class ShardingConfig:
    """Logical-axis -> mesh-axis rules. None = replicated."""
    batch: Tuple[str, ...] = ("pod", "data")
    heads: Optional[str] = "model"
    mlp: Optional[str] = "model"
    vocab: Optional[str] = "model"
    embed: Optional[str] = None
    cache_seq: Optional[str] = None      # set to "data" for long_500k
    experts: Optional[str] = None        # "model" for expert-parallel variant
    seq: Optional[str] = None            # sequence/context parallel activations


# ---------------------------------------------------------------------------
# Input shapes (assignment block)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str       # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


# TPU v5e hardware constants for the roofline analysis.
@dataclass(frozen=True)
class HardwareSpec:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12      # FLOP/s per chip
    hbm_bandwidth: float = 819e9         # bytes/s per chip
    ici_bandwidth: float = 50e9          # bytes/s per link
    hbm_bytes: float = 16e9              # capacity per chip
    vmem_bytes: float = 128 * 2**20


TPU_V5E = HardwareSpec()
