"""Pluggable speculation drafters (DESIGN.md §9).

Importing this package registers the built-in proposers:

* ``model`` — separate small draft model with a mirrored KV cache (the
  paper's small-draft paradigm; the seed behavior);
* ``ngram`` — prompt-lookup suffix matching over the sequence's own
  generated prefix: zero draft params, zero draft KV blocks;
* ``self``  — early-exit self-speculation: the target truncated to its
  first ``self_draft_layers`` layers, sharing the target cache.

Build one from a config with ``build_drafter(spec, cfg_t, cfg_d)``;
register new ones with ``@register_drafter("name")``.
"""
from repro.core.drafters.base import (DraftProposal, Drafter,
                                      available_drafters, build_drafter,
                                      model_flops_per_token,
                                      register_drafter)
from repro.core.drafters.model import ModelDrafter, autoregressive_draft_loop
from repro.core.drafters.ngram import NGramDrafter
from repro.core.drafters.self_draft import SelfDrafter

__all__ = [
    "DraftProposal", "Drafter", "ModelDrafter", "NGramDrafter",
    "SelfDrafter", "autoregressive_draft_loop", "available_drafters",
    "build_drafter", "model_flops_per_token", "register_drafter",
]
