"""The pluggable drafter interface (DESIGN.md §9).

DSDE's post-hoc KLD signals are a diagnostic layer *above* whatever
produces proposals.  This module defines the seam that makes the
proposer half of a speculation round pluggable — the mirror image of the
:class:`~repro.core.policies.SpecPolicy` seam for the controller half:

* :class:`Drafter` — the interface.  A drafter is a *frozen, hashable*
  object built from ``(SpecDecodeConfig, target ModelConfig, optional
  draft ModelConfig)``, so it rides through ``spec_decode_round`` as a
  jit static argument: drafter dispatch costs nothing at runtime and
  each (drafter-config, K) pair traces exactly one XLA program.
* device-side hooks — the drafter owns proposal generation
  (:meth:`propose`) and its own per-sequence cache/state pytree
  (:meth:`init_cache` / :meth:`prefill` / :meth:`commit` /
  :meth:`reset_rows`), which the round threads through
  ``RoundState.draft_cache``.  ``propose`` returns the proposal
  *distribution* too (:class:`DraftProposal.logits`), so exact
  rejection sampling and the policy's ``PolicyObservation`` stay
  well-defined for every proposer: real logits for model drafters,
  one-hot q for lookup drafters (whose KLD signal degrades gracefully
  to the target's surprise of the proposed token,
  :meth:`observation_kld`).
* host-side hooks — :meth:`uses_draft_model` (does the engine need
  draft params at all), :meth:`mirrors_kv` (does the drafter hold a
  paged KV pool mirroring the target's block ids — model-free drafters
  return False and the scheduler returns the mirror's block budget to
  the target pool), and :meth:`step_cost` (per-draft-step cost in
  target-verification units, sourced by the goodput policy).
* a string registry (:func:`register_drafter` / :func:`build_drafter`)
  keyed by ``SpecDecodeConfig.drafter``.

Writing a new drafter (see DESIGN.md §9 for the full guide)::

    @register_drafter("my_drafter")
    @dataclasses.dataclass(frozen=True)
    class MyDrafter(Drafter):
        def init_cache(self, batch, max_len, dtype, paged=None): ...
        def prefill(self, params_d, cache, idx, tokens, lens, **kw): ...
        def propose(self, params_t, params_d, draft_cache, target_cache,
                    pending, k, sl_i, policy, step_keys, live): ...
        def commit(self, params_d, tokens, snapshot, drafted, n): ...
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple, Type

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig, SpecDecodeConfig
from repro.core.signals import kld_per_position

PyTree = Any


class DraftProposal(NamedTuple):
    """What :meth:`Drafter.propose` hands back to the round."""
    tokens: jax.Array      # [B, K] int32 proposed draft tokens
    logits: jax.Array      # [B, K, V] f32 — the proposal distribution q
    cache: jax.Array       # drafter cache pytree after proposing (pre-commit)
    eff_sl: jax.Array      # [B] int32 — positions actually proposed (<= sl_i)


def model_flops_per_token(cfg: ModelConfig) -> float:
    """Rough decode-time FLOPs/token of one forward — the single source
    for :meth:`Drafter.step_cost` ratios.  An *estimate* (projections +
    MLP/MoE + LM head; attention-score terms omitted as length-dependent
    and common to both sides), good to the factor the goodput controller
    needs, not a roofline."""
    d, dh = cfg.d_model, cfg.resolved_head_dim
    attn = 2 * d * dh * (2 * cfg.num_heads + 2 * cfg.num_kv_heads)
    if cfg.moe is not None:
        mlp = 2 * d * cfg.moe.expert_d_ff * 3 * cfg.moe.top_k
    else:
        mlp = 2 * d * cfg.d_ff * 3
    head = 2 * d * cfg.vocab_size
    return float(cfg.num_layers * (attn + mlp) + head)


@dataclasses.dataclass(frozen=True)
class Drafter:
    """Proposal generator for one speculative round.

    Frozen (hashable) so instances ride as jit static arguments; all
    per-sequence mutable state lives in the cache pytree returned by
    :meth:`init_cache` and threaded through ``RoundState.draft_cache``.
    ``cfg_d`` is None for drafters with no separate draft model.
    """

    spec: SpecDecodeConfig
    cfg_t: ModelConfig
    cfg_d: Optional[ModelConfig] = None

    # --------------------------------------------------------- host-side
    def uses_draft_model(self) -> bool:
        """True => the engine must be handed draft-model params."""
        return False

    def mirrors_kv(self) -> bool:
        """True => the drafter holds a paged KV pool whose block ids
        mirror the target pool's (one allocator decision covers both).
        False => no draft-side KV: the engine skips draft block-table
        mirroring and the scheduler returns the draft mirror's block
        budget to the target pool (DESIGN.md §9)."""
        return False

    def step_cost(self) -> float:
        """Cost of ONE draft step relative to one target verification —
        the quantity the goodput policy charges per speculated token."""
        return 0.0

    # ------------------------------------------------------- device-side
    def init_cache(self, batch: int, max_len: int, dtype=jnp.float32,
                   paged: Optional[Tuple[int, int]] = None,
                   kv_quant: str = "none") -> PyTree:
        """Fresh per-sequence drafter cache (a pytree; ``()`` if
        stateless).  ``paged=(num_blocks, block_size)`` is the target
        pool's geometry — drafters that mirror it build a matching
        pool; everyone else ignores it.  ``kv_quant`` is the target
        pool's storage mode (DESIGN.md §13): mirroring drafters build
        their pool in the same mode so block ids stay interchangeable."""
        return ()

    def prefill(self, params_d: PyTree, cache: PyTree, idx: jax.Array,
                tokens: jax.Array, prompt_lens: jax.Array, *,
                max_len: int, table_rows: Optional[jax.Array] = None,
                plan=None) -> PyTree:
        """Absorb a same-bucket admission group: ``tokens [R, bucket]``
        right-padded prompts landing in batch slots ``idx [R]``.  Must
        fully re-initialize those rows (they may hold a previous
        occupant's state).  ``table_rows [R, max_blocks]`` is set iff
        the serving cache is paged AND the drafter mirrors it.
        ``plan`` is the engine's static serving-mesh plan
        (:class:`repro.launch.sharding.ServeMeshPlan`, or None off-mesh);
        drafters that run jitted prefill programs forward it so their
        mirror rows inherit the target's KV layouts (DESIGN.md §5)."""
        return cache

    def prefill_tail(self, params_d: PyTree, cache: PyTree,
                     idx: jax.Array, tokens: jax.Array,
                     prompt_lens: jax.Array, tail_tokens: jax.Array,
                     start_lens: jax.Array, tail_lens: jax.Array,
                     cow_src: jax.Array, cow_dst: jax.Array, *,
                     max_len: int, table_rows: Optional[jax.Array] = None,
                     plan=None) -> PyTree:
        """Warm (prefix-cache) admission, DESIGN.md §12: the group's
        ``[0, start_lens)`` prefixes are already resident in shared pool
        blocks.  ``tokens`` / ``prompt_lens`` are the FULL prefixes —
        token-history drafters need every token whatever the KV
        coverage — while ``tail_tokens [R, tail_bucket]`` / ``tail_lens``
        hold only the uncovered suffixes and ``cow_src`` / ``cow_dst``
        the group's copy-on-write block pairs (sentinel = pool size).
        The default absorbs the full prefix through :meth:`prefill`,
        which is exact for every drafter without a mirrored KV pool;
        mirroring drafters override this with a tail program over their
        own pools (their shared-prefix KV is already in the shared
        blocks, written by this same drafter when the prefix was first
        committed)."""
        return self.prefill(params_d, cache, idx, tokens, prompt_lens,
                            max_len=max_len, table_rows=None, plan=plan)

    def propose(self, params_t: PyTree, params_d: PyTree,
                draft_cache: PyTree, target_cache: PyTree,
                pending: jax.Array, k: int, sl_i: jax.Array,
                policy: Any, step_keys: jax.Array, live: jax.Array
                ) -> DraftProposal:
        """Generate up to ``k`` proposals per sequence (``sl_i [B]`` the
        per-sequence budget, 0 for dead rows).  ``step_keys [B]`` are
        per-row PRNG keys (already bound to request identity + round
        ordinal — fold in the step index only), so sampled proposals are
        schedule-invariant.  ``policy`` supplies the ``draft_keep``
        early-stop hook.  Must NOT mutate ``target_cache`` semantics:
        verification runs on the unmodified target cache."""
        raise NotImplementedError

    def commit(self, params_d: PyTree, tokens: jax.Array,
               snapshot: PyTree, drafted: PyTree,
               n_committed: jax.Array) -> PyTree:
        """Commit ``n_committed[b]`` of the round's ``tokens [B, K+1]``
        (pending + proposals) into the drafter cache.  ``snapshot`` is
        the pre-round cache, ``drafted`` the one ``propose`` returned."""
        return snapshot

    def reset_rows(self, cache: PyTree, rows: jax.Array) -> PyTree:
        """Clear rows being replaced under continuous batching.  The
        default is identity: every built-in drafter's ``prefill`` fully
        rewrites the rows it lands in, so no separate wipe is needed."""
        return cache

    def observation_kld(self, target_logits: jax.Array,
                        draft_logits: jax.Array, tokens: jax.Array,
                        valid: jax.Array) -> jax.Array:
        """Per-position divergence signal for ``PolicyObservation.kld``.
        Model drafters: KL(p_target ‖ q_draft) — the paper's signal.
        One-hot proposers override with the finite surrogate
        −log p_target(token) (= KL(q ‖ p) for a point-mass q): the
        target's surprise of the proposal, same monotone "how unstable
        is this draft source" semantics, never infinite."""
        return kld_per_position(target_logits, draft_logits, valid)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Type[Drafter]] = {}


def register_drafter(name: str) -> Callable[[Type[Drafter]], Type[Drafter]]:
    """Class decorator: ``@register_drafter("ngram")`` binds the class to
    the ``SpecDecodeConfig.drafter`` string ``"ngram"``."""
    def deco(cls: Type[Drafter]) -> Type[Drafter]:
        _REGISTRY[name] = cls
        return cls
    return deco


def available_drafters() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def build_drafter(spec: SpecDecodeConfig, cfg_t: ModelConfig,
                  cfg_d: Optional[ModelConfig] = None) -> Drafter:
    """Instantiate the drafter named by ``spec.drafter``.

    All three constructor inputs are frozen/hashable, so equal configs
    yield equal (interchangeable) drafters — safe to call at trace time
    inside a jitted function whose static arguments include them."""
    try:
        cls = _REGISTRY[spec.drafter]
    except KeyError:
        raise KeyError(
            f"unknown drafter {spec.drafter!r}; "
            f"registered: {', '.join(available_drafters())}") from None
    return cls(spec, cfg_t, cfg_d)
