"""The classic draft-model proposer (the paper's small-draft paradigm).

``ModelDrafter`` is the seed behavior migrated onto the public
:class:`~repro.core.drafters.Drafter` API: a separate small
autoregressive model proposes K tokens per round from its own KV cache,
which mirrors the target cache's layout (dense rows, or a paged pool
sharing the target's block ids so one allocator decision covers both).

The draft scan loop is shared with :class:`SelfDrafter` (early-exit
self-speculation runs the *same* loop over a truncated view of the
target model), so the K+1-step structure — the final step only writes
the last draft token's KV so the cache is complete on total acceptance —
lives in exactly one place.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import prefill as prefill_lib
from repro.core.config import ModelConfig
from repro.core.drafters.base import (DraftProposal, Drafter,
                                      model_flops_per_token,
                                      register_drafter)
from repro.core.sampling import sample_token
from repro.models import cache as cache_lib
from repro.models.transformer import commit as commit_model
from repro.models.transformer import forward

PyTree = Any


def autoregressive_draft_loop(params: PyTree, cfg: ModelConfig,
                              cache: PyTree, pending: jax.Array, k: int,
                              sl_i: jax.Array, policy: Any,
                              step_keys: jax.Array, active: jax.Array,
                              temperature: float
                              ) -> Tuple[jax.Array, jax.Array, PyTree,
                                         jax.Array]:
    """K+1 single-token decode steps of ``params``/``cfg`` against
    ``cache`` (``lax.scan``; the final step only writes the last draft
    token's KV so the cache is complete on total acceptance).
    Per-sequence validity ``j < sl_i`` implements ragged SL inside the
    fixed bucket; ``policy.draft_keep`` may stop early (trace-time
    branch).  Sampling is per-row keyed (``step_keys [B]``, step index
    folded in), so temperature>0 draws depend only on (request, round
    ordinal, step) — never on batch composition or bucket width.
    Returns (draft_tokens [B,K], draft_logits [B,K,V], drafted_cache,
    eff_sl [B])."""
    b = pending.shape[0]

    def step(carry, j):
        cache, tok, stop, eff = carry
        # paged caches: step j writes position len+j, needed only up to
        # the committed horizon (j <= SL_i); inactive rows never write
        wm = ((j <= sl_i) & active)[:, None]
        logits, cache, _ = forward(params, cfg, tok[:, None],
                                   cache=cache, mode="decode",
                                   write_mask=wm)
        lj = logits[:, 0]
        kjs = jax.vmap(lambda kb: jax.random.fold_in(kb, j))(step_keys)
        nxt = jax.vmap(
            lambda kk, lg: sample_token(kk, lg, temperature,
                                        cfg.vocab_size))(kjs, lj)
        keep = policy.draft_keep(lj)
        if keep is not None:       # in-draft early stop (trace-time branch)
            stop = stop | ~keep
        live = (j < sl_i) & (j < k) & ~stop
        eff = eff + live.astype(jnp.int32)
        # cache length bookkeeping: each step wrote one KV at len + j; the
        # cache's ``length`` field is only advanced at commit time, so we
        # thread an explicit position via a temp length bump.
        cache = dict(cache)
        cache["length"] = cache["length"] + 1
        return (cache, nxt.astype(jnp.int32), stop, eff), (nxt, lj)

    cache0 = dict(cache)
    init = (cache0, pending, jnp.zeros((b,), bool),
            jnp.zeros((b,), jnp.int32))
    (cache_k, _, _, eff), (toks, logits) = jax.lax.scan(
        step, init, jnp.arange(k + 1))
    cache_k = dict(cache_k)
    cache_k["length"] = cache["length"]     # restore; commit later
    draft_tokens = jnp.moveaxis(toks[:k], 0, 1).astype(jnp.int32)  # [B,K]
    draft_logits = jnp.moveaxis(logits[:k], 0, 1)                  # [B,K,V]
    return draft_tokens, draft_logits, cache_k, eff


@register_drafter("model")
@dataclasses.dataclass(frozen=True)
class ModelDrafter(Drafter):
    """Separate small draft model with a mirrored KV cache."""

    # --------------------------------------------------------- host-side
    def uses_draft_model(self) -> bool:
        return True

    def mirrors_kv(self) -> bool:
        return True

    def step_cost(self) -> float:
        assert self.cfg_d is not None
        return (model_flops_per_token(self.cfg_d)
                / max(model_flops_per_token(self.cfg_t), 1.0))

    # ------------------------------------------------------- device-side
    def init_cache(self, batch: int, max_len: int, dtype=jnp.float32,
                   paged: Optional[Tuple[int, int]] = None,
                   kv_quant: str = "none") -> PyTree:
        assert self.cfg_d is not None, "ModelDrafter needs a draft config"
        if paged is not None:
            n_blocks, bs = paged
            # the scheduler owns the pool-vs-max_len feasibility policy
            # (prefix-cached pools may be smaller than one max-len seq);
            # the mirror inherits the target pool's storage mode so
            # shared block ids mean the same bytes on both sides
            return cache_lib.paged_cache_struct(self.cfg_d, batch, max_len,
                                                n_blocks, bs, dtype,
                                                require_full_seq=False,
                                                kv_quant=kv_quant)
        return cache_lib.cache_struct(self.cfg_d, batch, max_len, dtype)

    def prefill(self, params_d: PyTree, cache: PyTree, idx: jax.Array,
                tokens: jax.Array, prompt_lens: jax.Array, *,
                max_len: int, table_rows: Optional[jax.Array] = None,
                plan=None) -> PyTree:
        # module-attribute calls so the engine's batched-prefill program
        # accounting (and its tests) see one program per model per bucket;
        # the mesh plan rides through so mirror rows inherit the target's
        # KV layouts (DESIGN.md §5)
        if table_rows is not None:
            rows, _ = prefill_lib.prefill_paged_rows(
                params_d, self.cfg_d, cache["k"], cache["v"],
                cache["kv_pos"], table_rows, tokens, prompt_lens,
                plan=plan, k_scale=cache.get("k_scale"),
                v_scale=cache.get("v_scale"))
            return prefill_lib.scatter_paged_rows(cache, rows, idx)
        rows, _ = prefill_lib.prefill_rows(params_d, self.cfg_d, tokens,
                                           prompt_lens, max_len, plan=plan)
        return prefill_lib.set_slots(cache, rows, idx)

    def prefill_tail(self, params_d: PyTree, cache: PyTree,
                     idx: jax.Array, tokens: jax.Array,
                     prompt_lens: jax.Array, tail_tokens: jax.Array,
                     start_lens: jax.Array, tail_lens: jax.Array,
                     cow_src: jax.Array, cow_dst: jax.Array, *,
                     max_len: int, table_rows=None, plan=None) -> PyTree:
        # warm admission over the mirrored pool: the draft KV of the
        # shared prefix is already in the shared blocks (written by this
        # drafter when that prefix was first committed), so the mirror
        # runs the same tail program as the target — including the
        # copy-on-write pairs, which name the same block ids on both
        # pools by the mirroring invariant
        assert table_rows is not None, (
            "warm admission requires the paged draft mirror")
        rows, _ = prefill_lib.prefill_paged_tail(
            params_d, self.cfg_d, cache["k"], cache["v"], cache["kv_pos"],
            table_rows, tail_tokens, start_lens, tail_lens, cow_src,
            cow_dst, plan=plan, k_scale=cache.get("k_scale"),
            v_scale=cache.get("v_scale"))
        return prefill_lib.scatter_paged_rows(cache, rows, idx)

    def propose(self, params_t: PyTree, params_d: PyTree,
                draft_cache: PyTree, target_cache: PyTree,
                pending: jax.Array, k: int, sl_i: jax.Array,
                policy: Any, step_keys: jax.Array, live: jax.Array
                ) -> DraftProposal:
        toks, logits, cache, eff = autoregressive_draft_loop(
            params_d, self.cfg_d, draft_cache, pending, k, sl_i, policy,
            step_keys, live, self.spec.temperature)
        return DraftProposal(tokens=toks, logits=logits, cache=cache,
                             eff_sl=eff)

    def commit(self, params_d: PyTree, tokens: jax.Array,
               snapshot: PyTree, drafted: PyTree,
               n_committed: jax.Array) -> PyTree:
        return commit_model(params_d, self.cfg_d, tokens, snapshot,
                            drafted, n_committed)
