"""Prompt-lookup n-gram drafter: zero draft params, zero draft KV.

``NGramDrafter`` proposes by replaying the sequence's own text: find the
most recent earlier occurrence of the trailing ``ngram_n``-gram in the
(prompt + emitted) prefix and propose the tokens that followed it
(Saxena-style prompt lookup decoding).  Its entire per-sequence state is
an int32 token-history buffer — no draft model, no draft KV blocks, so
under the paged layout the scheduler returns the draft mirror's whole
block budget to the target pool (DESIGN.md §9).

Exactness: the proposal distribution handed to rejection sampling is the
point mass q = 1 on the proposed token (the deterministic lookup IS a
sample from that q), so speculative sampling stays exact at every
temperature.  The KLD observation uses the finite one-hot surrogate
−log p_target(token) (see ``Drafter.observation_kld``).

The suffix match runs on a Pallas kernel on TPU
(:mod:`repro.kernels.ngram_match`) with a bit-exact pure-jnp oracle
elsewhere (:func:`repro.kernels.ref.ngram_propose_ref`).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.drafters.base import DraftProposal, Drafter, register_drafter
from repro.kernels import ops as kernel_ops

PyTree = Any

NEG = -1e30


@register_drafter("ngram")
@dataclasses.dataclass(frozen=True)
class NGramDrafter(Drafter):
    """Suffix-match lookup over the sequence's own generated prefix."""

    # --------------------------------------------------------- host-side
    # uses_draft_model / mirrors_kv: base defaults (False / False)

    def step_cost(self) -> float:
        return 0.0          # a table lookup is free next to a verification

    # ------------------------------------------------------- device-side
    def init_cache(self, batch: int, max_len: int, dtype=jnp.float32,
                   paged: Optional[Tuple[int, int]] = None,
                   kv_quant: str = "none") -> PyTree:
        # token history, NOT a KV cache: ``length`` counts committed
        # tokens, mirroring the target cache's commit arithmetic exactly
        return {"tokens": jnp.zeros((batch, max_len), jnp.int32),
                "length": jnp.zeros((batch,), jnp.int32)}

    def prefill(self, params_d: PyTree, cache: PyTree, idx: jax.Array,
                tokens: jax.Array, prompt_lens: jax.Array, *,
                max_len: int, table_rows: Optional[jax.Array] = None,
                plan=None) -> PyTree:
        # plan unused: the history buffer's rows are rewritten with eager
        # scatters, and the round jit's in_shardings keep the buffer
        # data-sharded (DESIGN.md §5)
        r = tokens.shape[0]
        rows = jnp.zeros((r, max_len), jnp.int32)
        rows = rows.at[:, :tokens.shape[1]].set(tokens.astype(jnp.int32))
        # full-row writes: no stale text from a slot's previous occupant
        return {"tokens": cache["tokens"].at[idx].set(rows),
                "length": cache["length"].at[idx].set(
                    prompt_lens.astype(jnp.int32))}

    def propose(self, params_t: PyTree, params_d: PyTree,
                draft_cache: PyTree, target_cache: PyTree,
                pending: jax.Array, k: int, sl_i: jax.Array,
                policy: Any, step_keys: jax.Array, live: jax.Array
                ) -> DraftProposal:
        buf = draft_cache["tokens"]
        ln = draft_cache["length"]
        b, h = buf.shape
        bi = jnp.arange(b)
        # the proposal conditions on committed history + pending token
        work = buf.at[bi, ln].set(pending.astype(jnp.int32), mode="drop")
        ctx = jnp.minimum(ln + 1, h)
        toks, cnt = kernel_ops.ngram_propose(work, ctx,
                                             n=self.spec.ngram_n, k=k)
        v = self.cfg_t.padded_vocab(128)
        onehot = jax.nn.one_hot(toks, v, dtype=jnp.float32)     # [B,K,V]
        logits = jnp.where(onehot > 0, 0.0, NEG)
        return DraftProposal(tokens=toks, logits=logits,
                             cache=draft_cache, eff_sl=cnt)

    def commit(self, params_d: PyTree, tokens: jax.Array,
               snapshot: PyTree, drafted: PyTree,
               n_committed: jax.Array) -> PyTree:
        buf = snapshot["tokens"]
        ln = snapshot["length"]
        b, h = buf.shape
        t = tokens.shape[1]
        bi = jnp.arange(b)
        pos = ln[:, None] + jnp.arange(t)[None]
        keep = (jnp.arange(t)[None] < n_committed[:, None]) & (pos < h)
        tgt = jnp.where(keep, pos, h)      # out-of-range => dropped
        buf = buf.at[bi[:, None], tgt].set(tokens.astype(jnp.int32),
                                           mode="drop")
        return {"tokens": buf,
                "length": ln + n_committed.astype(jnp.int32)}

    def reset_rows(self, cache: PyTree, rows: jax.Array) -> PyTree:
        return {"tokens": jnp.where(rows[:, None],
                                    jnp.zeros_like(cache["tokens"]),
                                    cache["tokens"]),
                "length": jnp.where(rows, 0, cache["length"])}

    def observation_kld(self, target_logits: jax.Array,
                        draft_logits: jax.Array, tokens: jax.Array,
                        valid: jax.Array) -> jax.Array:
        # one-hot q makes KL(p||q) infinite; use the target's surprise of
        # the proposal, −log p(token) = KL(q||p) for point-mass q
        lp = jax.nn.log_softmax(target_logits.astype(jnp.float32), axis=-1)
        lp_tok = jnp.take_along_axis(lp, tokens[..., None].astype(jnp.int32),
                                     axis=-1)[..., 0]
        return jnp.where(valid, -lp_tok, 0.0)
