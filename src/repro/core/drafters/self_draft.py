"""Early-exit self-speculation: the target's own shallow prefix drafts.

``SelfDrafter`` runs the draft scan loop over the target model truncated
to its first ``self_draft_layers`` layers — final norm + LM head applied
to the truncated hidden state (the standard early-exit head) — reading
and writing a *sliced view* of the target cache's leading layer slice.
No second model, no second cache: the drafted KV in those leading layers
is discarded after the loop because verification rewrites positions
``len..len+K`` across ALL layers on the unmodified pre-round target
cache, so the overwrite-or-mask rollback argument (DESIGN.md §4) makes
the slice causally clean again at commit.

Supported families: the scanned homogeneous stacks (dense / moe / vlm)
whose stacked ``layers`` params and ``[L, ...]`` cache pools slice
cleanly along the leading layer axis.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.drafters.base import (DraftProposal, Drafter,
                                      model_flops_per_token,
                                      register_drafter)
from repro.core.drafters.model import autoregressive_draft_loop

PyTree = Any

_SELF_DRAFT_FAMILIES = ("dense", "moe", "vlm")


@register_drafter("self")
@dataclasses.dataclass(frozen=True)
class SelfDrafter(Drafter):
    """Truncated-target early-exit proposer sharing the target cache."""

    def __post_init__(self):
        if self.cfg_t.family not in _SELF_DRAFT_FAMILIES:
            raise ValueError(
                f"self-draft supports scanned stacks {_SELF_DRAFT_FAMILIES}"
                f", not family {self.cfg_t.family!r}")
        n = self.spec.self_draft_layers
        if not 1 <= n < self.cfg_t.num_layers:
            raise ValueError(
                f"self_draft_layers={n} must be in [1, "
                f"{self.cfg_t.num_layers - 1}] for {self.cfg_t.name}")

    # --------------------------------------------------------- host-side
    # uses_draft_model / mirrors_kv: base defaults (False / False) — the
    # draft KV lives inside the target cache's own (already charged)
    # blocks and never outlives the round

    def step_cost(self) -> float:
        return (model_flops_per_token(self._truncated_cfg())
                / max(model_flops_per_token(self.cfg_t), 1.0))

    # ------------------------------------------------------- device-side
    def init_cache(self, batch: int, max_len: int, dtype=jnp.float32,
                   paged: Optional[Tuple[int, int]] = None,
                   kv_quant: str = "none") -> PyTree:
        return ()          # stateless: everything lives in the target cache

    def propose(self, params_t: PyTree, params_d: PyTree,
                draft_cache: PyTree, target_cache: PyTree,
                pending: jax.Array, k: int, sl_i: jax.Array,
                policy: Any, step_keys: jax.Array, live: jax.Array
                ) -> DraftProposal:
        n = self.spec.self_draft_layers
        cfg_s = self._truncated_cfg()
        params_s = {kk: vv for kk, vv in params_t.items() if kk != "layers"}
        params_s["layers"] = jax.tree_util.tree_map(
            lambda a: a[:n], params_t["layers"])
        cache_s = dict(target_cache)
        cache_s["k"] = target_cache["k"][:n]
        cache_s["v"] = target_cache["v"][:n]
        toks, logits, _, eff = autoregressive_draft_loop(
            params_s, cfg_s, cache_s, pending, k, sl_i, policy,
            step_keys, live, self.spec.temperature)
        # the drafted slice is dropped: verification rewrites those
        # positions across all layers from the pre-round target cache
        return DraftProposal(tokens=toks, logits=logits, cache=draft_cache,
                             eff_sl=eff)

    # commit: base default (identity) — nothing persists round-to-round

    # ------------------------------------------------------------- utils
    def _truncated_cfg(self):
        return dataclasses.replace(
            self.cfg_t, num_layers=self.spec.self_draft_layers,
            name=self.cfg_t.name + "-selfdraft")
