"""Pluggable speculation policies (DESIGN.md §6).

Importing this package registers the built-in policies:

* ``dsde``            — paper §3.1-3.3 KLD-variance SL adaptation;
* ``static``          — fixed SL baseline;
* ``adaedl``          — entropy early-stop baseline;
* ``autoregressive``  — no speculation (K = 0);
* ``goodput``         — acceptance-EMA goodput controller (TurboSpec-style,
  beyond-paper; built purely through this public API);
* ``slo``             — DSDE + deadline-aware bucket arbitration from the
  analytic latency model (SpecServe-style, beyond-paper; DESIGN.md §15).

Build one from a config with ``build_policy(spec)``; register new ones
with ``@register("name")``.
"""
from repro.core.policies.base import (HostRoundContext, PolicyObservation,
                                      SpecPolicy, as_host_round_context,
                                      available_policies, build_policy,
                                      register)
from repro.core.policies.adaedl import AdaEDLPolicy
from repro.core.policies.autoregressive import AutoregressivePolicy
from repro.core.policies.dsde import DSDEPolicy
from repro.core.policies.goodput import GoodputPolicy, GoodputState
from repro.core.policies.slo import SLOPolicy
from repro.core.policies.static import KLDTrackingPolicy, StaticPolicy

__all__ = [
    "AdaEDLPolicy", "AutoregressivePolicy", "DSDEPolicy", "GoodputPolicy",
    "GoodputState", "HostRoundContext", "KLDTrackingPolicy",
    "PolicyObservation", "SLOPolicy", "SpecPolicy", "StaticPolicy",
    "as_host_round_context", "available_policies", "build_policy", "register",
]
