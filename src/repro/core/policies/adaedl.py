"""AdaEDL baseline policy: entropy-based draft early stopping.

Fixed base SL per round; drafting stops early when the entropy-based
lower bound on token acceptance drops under the threshold (the only seed
policy that exercises the ``draft_keep`` hook).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import adapter as adapter_lib
from repro.core.policies.base import register
from repro.core.policies.static import KLDTrackingPolicy
from repro.core.signals import draft_entropy

PyTree = Any


@register("adaedl")
@dataclasses.dataclass(frozen=True)
class AdaEDLPolicy(KLDTrackingPolicy):
    def initial_sl_value(self) -> int:
        return self.spec.adaedl_base

    def draft_keep(self, logits: jax.Array) -> jax.Array:
        ent = draft_entropy(logits[:, None])[:, 0]
        return adapter_lib.adaedl_stop_threshold(ent, self.spec)

    def max_lookahead(self) -> int:
        # pick_bucket floors K at sl_min (see StaticPolicy.max_lookahead)
        return max(self.spec.adaedl_base, self.spec.sl_min) + 1

    def predict(self, state: PyTree, active: jax.Array
                ) -> Tuple[jax.Array, PyTree, Dict[str, jax.Array]]:
        b = state.mu_kld_last.shape[0]
        sl = jnp.full((b,), self.spec.adaedl_base, jnp.int32)
        return sl, state, {"mean_kld": state.mu_kld_last}
