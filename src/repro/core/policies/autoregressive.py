"""Autoregressive baseline: no speculation at all (K = 0 every round).

Stateless — the round degenerates to one target decode step per emitted
token, the paper's plain-decoding comparison row.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policies.base import (HostRoundContext, SpecPolicy,
                                      as_host_round_context, register)

PyTree = Any


@register("autoregressive")
@dataclasses.dataclass(frozen=True)
class AutoregressivePolicy(SpecPolicy):
    def initial_sl_value(self) -> int:
        return 0

    def uses_draft(self) -> bool:
        return False

    def lookahead(self, ctx: HostRoundContext) -> np.ndarray:
        # one decode slot per round, no speculative lookahead
        ctx = as_host_round_context(ctx, hook="lookahead")
        return np.ones_like(np.asarray(ctx.sl_next))

    def max_lookahead(self) -> int:
        return 1

    def predict(self, state: PyTree, active: jax.Array
                ) -> Tuple[jax.Array, PyTree, Dict[str, jax.Array]]:
        b = active.shape[0]
        return jnp.zeros((b,), jnp.int32), state, {}
