"""The speculation-policy interface (DESIGN.md §6).

DSDE's KLD-variance SL adaptation (paper §3.1-3.3) is one *policy* among
several the paper benchmarks against.  This module defines the seam that
lets new controllers — goodput-driven (TurboSpec-style), SLO-aware
(SpecServe/AdaSpec-style), bandit-tuned, ... — plug into the serving
stack without touching the jitted round:

* :class:`SpecPolicy` — the interface.  A policy is a *frozen, hashable*
  object built from a :class:`SpecDecodeConfig`, so it can ride along a
  jit static argument: one XLA program per (policy-config, K) bucket,
  never a per-step recompilation.
* device-side hooks (``init_state`` / ``observe`` / ``predict`` /
  ``draft_keep``) are traced into ``spec_decode_round``; the per-sequence
  state they thread through :class:`RoundState` must be a pytree.
* host-side hooks (``pick_bucket`` / ``lookahead`` / ``uses_draft``)
  drive the engine's Python-side bucket choice and the scheduler's
  admission capacity planning.  They consume a :class:`HostRoundContext`
  — the batch-global host view of the round (SL predictions, active
  mask, per-slot deadlines-remaining, the fitted latency model, round
  ordinal) built from **already-materialized numpy arrays**: the engine
  transfers once per round, policies never trigger their own
  device→host syncs.  The old bare-positional form
  (``pick_bucket(sl_next, active)`` / ``lookahead(sl)``) still works
  for one release via :func:`as_host_round_context` but emits a
  ``DeprecationWarning`` (speclint JX008 keeps in-repo callers on the
  context form).
* a string registry (:func:`register` / :func:`build_policy`) keyed by
  ``SpecDecodeConfig.policy`` so existing config strings keep working.

Writing a new policy (see DESIGN.md §6 for the full guide)::

    @register("my_policy")
    @dataclasses.dataclass(frozen=True)
    class MyPolicy(SpecPolicy):
        def initial_sl_value(self):      return self.spec.static_sl
        def init_state(self, batch):     return MyState(...)
        def observe(self, state, obs):   return ...   # fold obs into state
        def predict(self, state, active): return sl, state, telemetry
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple, Type

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import SpecDecodeConfig

PyTree = Any


@dataclasses.dataclass
class HostRoundContext:
    """Batch-global host-side view of one serving round.

    The single argument of the host policy hooks (``pick_bucket`` /
    ``lookahead``).  Everything in it is plain numpy / Python — built by
    ``LookaheadScheduler.host_context`` from arrays the engine already
    materialized, never triggering a device sync of its own.

    ``deadline_remaining_s`` is +inf for slots without a deadline (and
    for empty slots); ``tokens_remaining`` is 0 for empty slots.  Both
    are None when the builder has no per-request view (e.g. the legacy
    positional shim), and policies must treat None as "no deadlines".
    ``latency_model`` is the engine's :class:`RoundLatencyModel` (or
    None); deadline-aware policies must check ``latency_model.ready()``
    before acting on its predictions.
    """

    sl_next: np.ndarray                               # [B] int, SL predictions
    active: np.ndarray                                # [B] bool, live slots
    deadline_remaining_s: Optional[np.ndarray] = None  # [B] float, +inf unset
    tokens_remaining: Optional[np.ndarray] = None      # [B] int, budget left
    latency_model: Optional[Any] = None
    round_ordinal: int = 0

    @classmethod
    def from_arrays(cls, sl_next: np.ndarray,
                    active: Optional[np.ndarray] = None) -> "HostRoundContext":
        """Minimal context over bare arrays (tests, legacy shim).  With
        no ``active`` mask every slot is considered live."""
        sl = np.asarray(sl_next)
        act = (np.ones(sl.shape, bool) if active is None
               else np.asarray(active).astype(bool))
        return cls(sl_next=sl, active=act)

    def _live_deadlines(self) -> Optional[np.ndarray]:
        """Finite, still-attainable (>0) deadlines on active slots.
        Lapsed deadlines (<=0) are excluded everywhere — a deadline
        already missed must not pin the batch to minimum speculation
        forever (it cannot be attained no matter what K does)."""
        if self.deadline_remaining_s is None:
            return None
        act = np.asarray(self.active, bool)
        if not act.any():
            return None
        dl = np.asarray(self.deadline_remaining_s, float)[act]
        dl = dl[np.isfinite(dl) & (dl > 0.0)]
        return dl if dl.size else None

    def has_deadlines(self) -> bool:
        """True iff some *live* slot carries an attainable deadline."""
        return self._live_deadlines() is not None

    def tightest_deadline_s(self) -> Optional[float]:
        """Smallest live attainable deadline-remaining, or None."""
        dl = self._live_deadlines()
        return None if dl is None else float(dl.min())


def as_host_round_context(ctx: Any, active: Optional[np.ndarray] = None,
                          hook: str = "pick_bucket") -> HostRoundContext:
    """Coerce a host-hook argument to :class:`HostRoundContext`.

    One-release back-compat shim: callers still passing the pre-context
    positional form (a bare ``sl`` array, optionally with an ``active``
    mask) get a context built from it plus a ``DeprecationWarning``.
    Context-form calls pass through untouched.
    """
    if isinstance(ctx, HostRoundContext):
        if active is not None:
            raise TypeError(
                f"SpecPolicy.{hook}: pass either a HostRoundContext or the "
                "legacy (sl_next, active) arrays, not both")
        return ctx
    warnings.warn(
        f"SpecPolicy.{hook} with bare numpy positionals is deprecated; "
        "pass a HostRoundContext (e.g. HostRoundContext.from_arrays(sl, "
        "active) or LookaheadScheduler.host_context()). The positional "
        "form will be removed next release.",
        DeprecationWarning, stacklevel=3)
    return HostRoundContext.from_arrays(ctx, active)


def masked_row_reset(fresh: PyTree, state: PyTree, rows: jax.Array) -> PyTree:
    """Replace rows of every leaf of ``state`` with ``fresh`` where the
    [B] bool mask ``rows`` is set (slot replacement under continuous
    batching).  The single implementation behind both
    ``SpecPolicy.reset_rows`` and ``adapter_lib.reset_rows``."""
    return jax.tree_util.tree_map(
        lambda f, s: jnp.where(
            rows.reshape(rows.shape + (1,) * (s.ndim - 1)), f, s),
        fresh, state)


class PolicyObservation(NamedTuple):
    """Post-hoc statistics of one verification step (paper §3.1's lagging
    diagnostic inputs), handed to ``SpecPolicy.observe``."""
    kld: jax.Array             # [B, K]  per-position KL(target || draft)
    proposed_valid: jax.Array  # [B, K]  bool, which positions were proposed
    num_accepted: jax.Array    # [B]     accepted draft tokens this step
    num_proposed: jax.Array    # [B]     proposed draft tokens this step
    active: jax.Array          # [B]     bool, live request slots


@dataclasses.dataclass(frozen=True)
class SpecPolicy:
    """Per-sequence speculation-length controller.

    Frozen (hashable) so instances can be jit static arguments; all
    per-sequence mutable state lives in the pytree returned by
    ``init_state`` and threaded through ``observe``/``predict``.
    """

    spec: SpecDecodeConfig

    # ------------------------------------------------------- device-side
    def init_state(self, batch: int) -> PyTree:
        """Fresh per-sequence policy state (a pytree; ``()`` if stateless)."""
        return ()

    def initial_sl_value(self) -> int:
        """SL a sequence starts with (host-side Python int)."""
        raise NotImplementedError

    def initial_sl(self, batch: int) -> jax.Array:
        """[B] int32 initial SL vector (device-side)."""
        return jnp.full((batch,), self.initial_sl_value(), jnp.int32)

    def reset_rows(self, state: PyTree, rows: jax.Array) -> PyTree:
        """Reset state rows where ``rows`` [B] is True (slot replacement)."""
        return masked_row_reset(self.init_state(rows.shape[0]), state, rows)

    def observe(self, state: PyTree, obs: PolicyObservation) -> PyTree:
        """Fold one verification step's statistics into the state."""
        return state

    def predict(self, state: PyTree, active: jax.Array
                ) -> Tuple[jax.Array, PyTree, Dict[str, jax.Array]]:
        """Per-sequence SL for the next round.  ``active`` [B] bool is
        always supplied by the round (it also fixes the batch size for
        stateless policies).  Returns ``(sl [B] int32, new_state,
        telemetry)``."""
        raise NotImplementedError

    def draft_keep(self, logits: jax.Array) -> Optional[jax.Array]:
        """In-draft early stopping: given this step's draft logits [B, V],
        return a bool [B] 'keep drafting' mask, or None for no early stop
        (the default — the branch then traces away entirely)."""
        return None

    # --------------------------------------------------------- host-side
    def uses_draft(self) -> bool:
        """False => the engine never runs the draft model (K = 0)."""
        return True

    def lookahead(self, ctx: "HostRoundContext") -> np.ndarray:
        """KV slots each sequence needs next round: SL_i + 1 bonus token.
        Consumed by ``LookaheadScheduler`` for per-round capacity planning
        (paper §3.2's vLLM lookahead modification).  ``ctx`` is the
        round's :class:`HostRoundContext`; a bare SL array still works
        for one release (DeprecationWarning)."""
        ctx = as_host_round_context(ctx, hook="lookahead")
        return np.asarray(ctx.sl_next) + 1

    def max_lookahead(self) -> int:
        """Worst-case KV slots any single round can consume under this
        policy — the admission-time reservation.  The default covers
        policies whose prediction can reach ``sl_max``; bounded policies
        (static, adaedl, autoregressive) override with their tighter
        bound."""
        return self.spec.sl_max + 1

    def max_bucket(self) -> int:
        """Largest draft bucket any round can run under this policy —
        ``pick_bucket``'s upper bound.  The pipelined engine dispatches
        stochastic (temperature>0) rounds at this width so a one-round-
        stale bucket pick can never clip a sequence's device-side SL
        below what the synchronous schedule would run (the window match
        that makes sampled streams schedule-invariant, DESIGN.md §7);
        raggedness inside the bucket is masked as usual."""
        if not self.uses_draft():
            return 0
        return self.max_lookahead() - 1

    def pick_bucket(self, ctx: "HostRoundContext",
                    active: Optional[np.ndarray] = None) -> int:
        """Python-side draft bucket choice: K = max active SL prediction
        (the paper's SL_max^(t) = max_i SL_i^(t) verification length).
        ``ctx`` is the round's :class:`HostRoundContext`; the legacy
        ``(sl_next, active)`` array form still works for one release
        (DeprecationWarning)."""
        ctx = as_host_round_context(ctx, active, hook="pick_bucket")
        if not self.uses_draft():
            return 0
        sl = np.asarray(ctx.sl_next)
        act = np.asarray(ctx.active)
        live = sl[act] if act.any() else sl
        return int(max(live.max() if live.size else self.spec.sl_min,
                       self.spec.sl_min))


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Type[SpecPolicy]] = {}


def register(name: str) -> Callable[[Type[SpecPolicy]], Type[SpecPolicy]]:
    """Class decorator: ``@register("dsde")`` binds the class to the
    ``SpecDecodeConfig.policy`` string ``"dsde"``."""
    def deco(cls: Type[SpecPolicy]) -> Type[SpecPolicy]:
        _REGISTRY[name] = cls
        return cls
    return deco


def available_policies() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def build_policy(spec: SpecDecodeConfig) -> SpecPolicy:
    """Instantiate the policy named by ``spec.policy``.

    ``SpecDecodeConfig`` is frozen/hashable and policy classes are frozen
    dataclasses over it, so equal configs yield equal (interchangeable)
    policies — safe to call at trace time inside a jitted function whose
    static arguments include ``spec``."""
    try:
        cls = _REGISTRY[spec.policy]
    except KeyError:
        raise KeyError(
            f"unknown speculation policy {spec.policy!r}; "
            f"registered: {', '.join(available_policies())}") from None
    return cls(spec)
