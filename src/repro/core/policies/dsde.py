"""DSDE policy (paper §3.1-3.3): KLD-variance stability SL adaptation.

The numerical core (Eq. 1-11) lives in :mod:`repro.core.adapter`; this
class adapts it to the :class:`SpecPolicy` interface.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax

from repro.core import adapter as adapter_lib
from repro.core.policies.base import PolicyObservation, SpecPolicy, register

PyTree = Any


@register("dsde")
@dataclasses.dataclass(frozen=True)
class DSDEPolicy(SpecPolicy):
    """Per-sequence per-iteration SL from the WVIR stability penalty."""

    def init_state(self, batch: int) -> PyTree:
        return adapter_lib.init_adapter_state(batch, self.spec)

    def initial_sl_value(self) -> int:
        # calibration phase runs the fixed calibration SL (Eq. 1)
        return self.spec.calibration_sl

    def observe(self, state: PyTree, obs: PolicyObservation) -> PyTree:
        return adapter_lib.observe(
            state, self.spec, kld=obs.kld, proposed_valid=obs.proposed_valid,
            num_accepted=obs.num_accepted, active=obs.active)

    def predict(self, state: PyTree, active: jax.Array
                ) -> Tuple[jax.Array, PyTree, Dict[str, jax.Array]]:
        sl, state, tel = adapter_lib.predict_sl(state, self.spec, active)
        tel = dict(tel)
        # post-observe value: the CURRENT round's mean KLD (the pre-policy
        # round reported the previous round's — consumers of per-round
        # telemetry logs should not expect the one-round lag)
        tel["mean_kld"] = state.mu_kld_last
        return sl, state, tel
