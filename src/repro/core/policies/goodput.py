"""Goodput-driven speculation control (TurboSpec-style, beyond-paper).

A fifth comparable built **entirely through the public SpecPolicy API** —
no change to the jitted round, the engine, or the scheduler was needed to
add it (the extensibility proof for the policy seam, DESIGN.md §6).

Model: track a per-sequence EMA ``a`` of the draft-token acceptance rate.
Under the standard i.i.d.-acceptance approximation (Leviathan et al.),
drafting ``k`` tokens yields

    E[accepted | k]  =  a (1 - a^k) / (1 - a)        (truncated geometric)
    E[emitted  | k]  =  E[accepted | k] + 1          (bonus/recovery token)

and one round costs ``1 + c*k`` in verification-equivalent units, where
``c = goodput_draft_cost`` is the relative cost of a single draft step.
The policy picks, per sequence and per round,

    SL_i  =  argmax_k  E[emitted | k] / (1 + c*k),   k in [sl_min, sl_max]

i.e. it *raises* SL while the running acceptance estimate says marginal
draft tokens still pay for themselves and *lowers* it as acceptance
degrades — goodput-maximizing speculation control in the spirit of
TurboSpec's utilization-aware adjustment.  The argmax over the small
static k-grid is vectorized and jits cleanly; state is a 3-leaf pytree.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import adapter as adapter_lib
from repro.core.config import SpecDecodeConfig
from repro.core.policies.base import PolicyObservation, SpecPolicy, register

PyTree = Any


# historical per-draft-step cost, used only when the config leaves
# ``goodput_draft_cost=None`` AND no drafter resolved it (direct policy
# unit use).  The serving engine resolves None from the configured
# drafter's ``Drafter.step_cost()`` before any policy is built.
FALLBACK_DRAFT_COST = 0.08


def resolved_draft_cost(spec: SpecDecodeConfig) -> float:
    return (spec.goodput_draft_cost
            if spec.goodput_draft_cost is not None else FALLBACK_DRAFT_COST)


def _goodput_curve(spec: SpecDecodeConfig, acc, xp):
    """Goodput G[B, nK] over the static k-grid [sl_min .. sl_max].

    ``xp`` is the array module — jnp inside the traced round, np for the
    host-side initial-SL computation — so both paths share ONE formula."""
    ks = xp.arange(spec.sl_min, spec.sl_max + 1)             # [nK]
    a = xp.clip(acc, 1e-3, 0.999)[:, None]                   # [B, 1]
    e_acc = a * (1.0 - a ** ks[None, :]) / (1.0 - a)         # [B, nK]
    goodput = (1.0 + e_acc) / (1.0 + resolved_draft_cost(spec)
                               * ks[None, :].astype(xp.float32))
    return ks, goodput


@functools.lru_cache(maxsize=None)
def _initial_sl_host(spec: SpecDecodeConfig) -> int:
    """argmax SL at the optimistic acceptance prior — pure numpy (no
    device dispatch: this runs in the admission/prefill hot path)."""
    ks, g = _goodput_curve(
        spec, np.array([spec.goodput_init_acc], np.float32), np)
    return int(ks[int(np.argmax(g[0]))])


class GoodputState(NamedTuple):
    acc_ema: jax.Array    # [B] f32  EMA of per-round acceptance fraction
    obs_count: jax.Array  # [B] int32 rounds folded in (0 = prior only)
    sl_pred: jax.Array    # [B] int32 last prediction (telemetry / tests)


@register("goodput")
@dataclasses.dataclass(frozen=True)
class GoodputPolicy(SpecPolicy):
    def init_state(self, batch: int) -> PyTree:
        return GoodputState(
            acc_ema=jnp.full((batch,), self.spec.goodput_init_acc,
                             jnp.float32),
            obs_count=jnp.zeros((batch,), jnp.int32),
            sl_pred=jnp.full((batch,), self.initial_sl_value(), jnp.int32))

    def initial_sl_value(self) -> int:
        # start from the optimistic prior's own argmax so the first rounds
        # already speculate at the prior-implied depth
        return _initial_sl_host(self.spec)

    def observe(self, state: GoodputState, obs: PolicyObservation
                ) -> GoodputState:
        prop = obs.num_proposed.astype(jnp.float32)
        took = (prop > 0) & obs.active
        a_step = obs.num_accepted.astype(jnp.float32) / jnp.maximum(prop, 1.0)
        d = self.spec.goodput_ema
        ema = jnp.where(took, d * state.acc_ema + (1.0 - d) * a_step,
                        state.acc_ema)
        count = state.obs_count + took.astype(jnp.int32)
        return state._replace(acc_ema=ema, obs_count=count)

    def predict(self, state: GoodputState, active: jax.Array
                ) -> Tuple[jax.Array, GoodputState, Dict[str, jax.Array]]:
        sl = self._argmax_sl(state.acc_ema)
        tel = {"acc_ema": state.acc_ema,
               "goodput_sl_raw": sl.astype(jnp.float32)}
        if self.spec.use_sl_cap:
            capped, cap = adapter_lib.apply_sl_cap(
                sl.astype(jnp.float32), self.spec, active)
            sl = jnp.clip(jnp.round(capped), self.spec.sl_min,
                          self.spec.sl_max).astype(jnp.int32)
            tel["sl_cap"] = cap
        return sl, state._replace(sl_pred=sl), tel

    # ------------------------------------------------------------- internals
    def _argmax_sl(self, acc: jax.Array) -> jax.Array:
        ks, goodput = _goodput_curve(self.spec, acc, jnp)
        return ks[jnp.argmax(goodput, axis=-1)].astype(jnp.int32)
