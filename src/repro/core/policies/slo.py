"""SLO-aware speculation control (DESIGN.md §15).

DSDE's stability penalty caps stragglers *per batch*; real serving
(paper §4's framing, SpecServe/AdaSpec in PAPERS.md) ultimately answers
to per-request *deadlines*.  This policy generalizes the straggler cap
to SLOs: it is DSDE on the device (identical KLD-variance SL
adaptation, byte-identical streams when no deadlines are set) plus a
host-side batch-global arbitration layer that shrinks the draft bucket
when the analytic latency model predicts the next round's cost would
breach the batch's tightest live deadline.

The arbitration is a pure reduction over the :class:`HostRoundContext`:

1. each live deadline-carrying slot i affords a per-round budget
   ``deadline_remaining_i / rounds_remaining_i(K)`` where
   ``rounds_remaining_i(K) = ceil(tokens_remaining_i / (K+1))`` is the
   *best-case* round count at bucket K (every position accepted).  The
   batch tightness scalar is the min over slots;
2. starting from DSDE's K, shrink while the latency model predicts
   ``T_round(K) >`` tightness(K) — both sides move as K shrinks:
   cheaper rounds, but more of them;
3. never below ``sl_min``; an infeasible batch runs at ``sl_min``
   (best effort — admission gating is where infeasibility is surfaced,
   not here).

Slots whose deadline has already lapsed (remaining <= 0) cannot be
saved by any K and are excluded from the tightness reduction rather
than pinning the whole batch at ``sl_min`` forever.

Exactness: for greedy decoding the emitted token stream is invariant
to K (verification accepts the same prefix; the bonus token is the
same argmax), so deadline-driven K changes never alter outputs — only
wall-clock.  With no finite deadlines, or before the latency model is
ready, step 2 is skipped entirely and the policy IS DSDE.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from repro.core.policies.base import (HostRoundContext,
                                      as_host_round_context, register)
from repro.core.policies.dsde import DSDEPolicy


def batch_tightness_s(ctx: HostRoundContext, k: int) -> Optional[float]:
    """The batch's tightest per-round wall budget at bucket ``k``, or
    None when nothing constrains the round (no live finite positive
    deadlines)."""
    if not ctx.has_deadlines():
        return None
    act = np.asarray(ctx.active, bool)
    dl = np.asarray(ctx.deadline_remaining_s, float)[act]
    if ctx.tokens_remaining is not None:
        rem = np.asarray(ctx.tokens_remaining)[act].astype(float)
    else:
        rem = np.ones(dl.shape)
    # lapsed deadlines are unsalvageable at any K; don't let them pin K
    live = np.isfinite(dl) & (dl > 0.0)
    if not live.any():
        return None
    rounds = np.maximum(np.ceil(rem[live] / float(k + 1)), 1.0)
    return float((dl[live] / rounds).min())


@register("slo")
@dataclasses.dataclass(frozen=True)
class SLOPolicy(DSDEPolicy):
    """DSDE + deadline-aware host arbitration of the draft bucket."""

    def pick_bucket(self, ctx: HostRoundContext,
                    active: Optional[np.ndarray] = None) -> int:
        ctx = as_host_round_context(ctx, active, hook="pick_bucket")
        k = super().pick_bucket(ctx)
        lm = ctx.latency_model
        if lm is None or not lm.ready():
            return k
        b_eff = int(np.asarray(ctx.active, bool).sum())
        while k > self.spec.sl_min:
            budget = batch_tightness_s(ctx, k)
            if budget is None or lm.predict_round_s(k, b_eff) <= budget:
                break
            k -= 1
        return k
