"""Static-SL baseline policy (the paper's Static-Aggressive/Conservative).

Keeps the full KLD observation state (``AdapterState``) even though the
prediction is constant: the lagging diagnostics (``mu_kld_last``, WVIR
history) stay available as telemetry, which Table 2's signal-correlation
benchmark and the serving dashboards consume under a static policy.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax

from repro.core import adapter as adapter_lib
from repro.core.policies.base import PolicyObservation, SpecPolicy, register

PyTree = Any


@dataclasses.dataclass(frozen=True)
class KLDTrackingPolicy(SpecPolicy):
    """Shared base for policies that keep the KLD diagnostics updated
    (static, adaedl) without using them for prediction."""

    def init_state(self, batch: int) -> PyTree:
        return adapter_lib.init_adapter_state(batch, self.spec)

    def observe(self, state: PyTree, obs: PolicyObservation) -> PyTree:
        return adapter_lib.observe(
            state, self.spec, kld=obs.kld, proposed_valid=obs.proposed_valid,
            num_accepted=obs.num_accepted, active=obs.active)


@register("static")
@dataclasses.dataclass(frozen=True)
class StaticPolicy(KLDTrackingPolicy):
    def initial_sl_value(self) -> int:
        return self.spec.static_sl

    def max_lookahead(self) -> int:
        # pick_bucket floors K at sl_min, so a round can write that many
        # positions even when static_sl is smaller
        return max(self.spec.static_sl, self.spec.sl_min) + 1

    def predict(self, state: PyTree, active: jax.Array
                ) -> Tuple[jax.Array, PyTree, Dict[str, jax.Array]]:
        sl = adapter_lib.static_sl(state.mu_kld_last.shape[0], self.spec)
        return sl, state, {"mean_kld": state.mu_kld_last}
