"""Shared multi-row prefill programs (DESIGN.md §7).

Used by BOTH sides of a speculation round: the serving engine prefills
the target model with them, and :class:`repro.core.drafters.ModelDrafter`
prefills its draft model through the very same jitted entry points — so
a same-bucket admission group costs exactly one program per model, no
matter which component issues the call.

``prefill_rows`` builds fresh dense cache rows; ``prefill_paged_rows``
writes straight into allocated pool blocks through a multi-row
block-table view (pools donated — admission never copies the pool);
``prefill_paged_tail`` is its prefix-cache sibling — it computes only
the non-cached tail of each row, starting at the cached-coverage
offset, after running the round's batched copy-on-write block copies.
``set_slots`` scatters a batch-R row group into the batched cache at R
slots with one fused scatter per leaf.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.models import cache as cache_lib
from repro.models.transformer import forward

PyTree = Any

# cache leaves whose leading axis is the batch axis (everything else is
# [layers, batch, ...])
BATCH_AXIS0 = ("length", "kv_pos", "enc_valid", "block_table")


def set_slots(big: PyTree, rows: PyTree, idx: jax.Array) -> PyTree:
    """Scatter a batch=R cache-row group into the batched cache at the R
    slots ``idx`` (one fused scatter per leaf, not one per request)."""
    out = {}
    for k, v in big.items():
        r = rows[k]
        if k in BATCH_AXIS0:
            out[k] = v.at[idx].set(r)
        else:
            out[k] = v.at[:, idx].set(r)
    return out


def prefill_forward(params: PyTree, cfg: ModelConfig, cache: PyTree,
                    tokens: jax.Array, prompt_lens: jax.Array
                    ) -> Tuple[PyTree, jax.Array]:
    """Shared multi-row prefill tail: masked forward over the
    right-padded prompts [R, bucket], commit per-row ``length``, pick
    each row's last real token's logits."""
    mask = (jnp.arange(tokens.shape[1])[None] < prompt_lens[:, None])
    logits, cache, _ = forward(params, cfg, tokens, cache=cache,
                               mode="prefill", input_mask=mask)
    cache["length"] = prompt_lens.astype(jnp.int32)
    rows = jnp.arange(tokens.shape[0])
    last = logits[rows, jnp.maximum(prompt_lens - 1, 0)]
    return cache, last


@functools.partial(jax.jit, static_argnames=("cfg", "max_len", "plan"))
def prefill_rows(params: PyTree, cfg: ModelConfig, tokens: jax.Array,
                 prompt_lens: jax.Array, max_len: int,
                 plan=None) -> Tuple[PyTree, jax.Array]:
    """Prefill a same-bucket group of R requests into fresh cache rows in
    one program.  ``tokens [R, bucket]`` is right-padded; the (R, bucket)
    pair keys the compiled-program cache.  ``plan`` is an optional
    static :class:`repro.launch.sharding.ServeMeshPlan`: under a serving
    mesh the fresh rows are sharding-constrained to the §5 layouts at
    the program boundary (KV heads over *model*, rows over *data*), so
    the engine's scatter never round-trips them through replicated
    layouts.  Returns (cache rows [*, R, *], last_logits [R, V])."""
    cache = cache_lib.cache_struct(cfg, tokens.shape[0], max_len,
                                   jnp.float32)
    cache, last = prefill_forward(params, cfg, cache, tokens, prompt_lens)
    if plan is not None:
        cache = plan.cache_constraints(cache)
        last = jax.lax.with_sharding_constraint(last, plan.replicated())
    return cache, last


@functools.partial(jax.jit, static_argnames=("cfg", "plan"),
                   donate_argnames=("pool_k", "pool_v", "kv_pos",
                                    "k_scale", "v_scale"))
def prefill_paged_rows(params: PyTree, cfg: ModelConfig, pool_k: jax.Array,
                       pool_v: jax.Array, kv_pos: jax.Array,
                       table_rows: jax.Array, tokens: jax.Array,
                       prompt_lens: jax.Array, plan=None,
                       k_scale=None, v_scale=None
                       ) -> Tuple[PyTree, jax.Array]:
    """Prefill a same-bucket group of R requests *straight into their
    allocated pool blocks* as one multi-row program: the batch-R cache
    view aliases the shared pools and routes every row's KV writes
    through that row of ``table_rows [R, max_blocks]`` — rows land in
    disjoint blocks by construction.  The pools are donated — the caller
    immediately replaces its references with the returned ones, so
    admission never copies (or transiently doubles) the whole pool.
    Returns (cache view with updated pools + fresh per-row state,
    last_logits [R, V]).  ``plan`` (static) pins the returned pools /
    rows to the serving mesh's §5 layouts, exactly as in
    :func:`prefill_rows`.  ``k_scale``/``v_scale`` (donated) are the
    int8 pool's amax scale arrays — passing them makes the view a
    quantized cache, so the prefill writes quantize on the way in."""
    cache = cache_lib.paged_prefill_view(cfg, pool_k, pool_v, kv_pos,
                                         table_rows, k_scale=k_scale,
                                         v_scale=v_scale)
    cache, last = prefill_forward(params, cfg, cache, tokens, prompt_lens)
    if plan is not None:
        cache = plan.cache_constraints(cache)
        last = jax.lax.with_sharding_constraint(last, plan.replicated())
    return cache, last


@functools.partial(jax.jit, static_argnames=("cfg", "plan"),
                   donate_argnames=("pool_k", "pool_v", "kv_pos",
                                    "k_scale", "v_scale"))
def prefill_paged_tail(params: PyTree, cfg: ModelConfig, pool_k: jax.Array,
                       pool_v: jax.Array, kv_pos: jax.Array,
                       table_rows: jax.Array, tokens: jax.Array,
                       start_lens: jax.Array, tail_lens: jax.Array,
                       cow_src: jax.Array, cow_dst: jax.Array, plan=None,
                       k_scale=None, v_scale=None
                       ) -> Tuple[PyTree, jax.Array]:
    """Partial-prefix prefill (DESIGN.md §12): one multi-row program that
    computes only the non-cached tail of each request.

    Row ``r`` starts at its cached coverage ``start_lens[r]`` — the view
    is built with the per-row length preset, so the decode-mode forward
    positions the ``tokens [R, bucket]`` tail at ``start + arange`` and
    attends over the gathered pool view, i.e. straight THROUGH the
    shared prefix blocks the scheduler mapped into ``table_rows``.
    ``tail_lens`` masks the right padding out of the KV writes
    (``write_mask``), and the batched copy-on-write pairs
    ``cow_src/cow_dst [R]`` (sentinel ``num_blocks`` = no copy) run
    first so a row whose tail rewrites the last position of a shared
    block lands in its private fork.  Cold rows degrade gracefully
    (start 0, tail = full prompt) but the engine keeps them on
    :func:`prefill_paged_rows` so the cold path stays program-identical
    with the pre-cache engine.  Recurrent families never reach here —
    the engine gates prefix caching on attention-only stacks, whose
    cache state is exactly the pool the shared blocks live in.

    The pools are donated and the returned view is scattered back with
    :func:`scatter_paged_rows`, same as the cold entry point.  Under the
    int8 pool (``k_scale``/``v_scale`` given, donated) the COW prologue
    carries the scale arrays with their blocks and the view quantizes
    the tail writes."""
    pool_k, pool_v, kv_pos = cache_lib.copy_blocks(pool_k, pool_v, kv_pos,
                                                   cow_src, cow_dst)
    if k_scale is not None:
        k_scale, v_scale = cache_lib.copy_scales(k_scale, v_scale,
                                                 cow_src, cow_dst)
    cache = cache_lib.paged_prefill_view(cfg, pool_k, pool_v, kv_pos,
                                         table_rows, lengths=start_lens,
                                         k_scale=k_scale, v_scale=v_scale)
    t = tokens.shape[1]
    write_mask = jnp.arange(t)[None] < tail_lens[:, None]
    logits, cache, _ = forward(params, cfg, tokens, cache=cache,
                               mode="decode", write_mask=write_mask)
    cache["length"] = (start_lens + tail_lens).astype(jnp.int32)
    rows = jnp.arange(tokens.shape[0])
    last = logits[rows, jnp.maximum(tail_lens - 1, 0)]
    if plan is not None:
        cache = plan.cache_constraints(cache)
        last = jax.lax.with_sharding_constraint(last, plan.replicated())
    return cache, last


def scatter_paged_rows(big: PyTree, rows: PyTree, idx: jax.Array) -> PyTree:
    """Fold a ``prefill_paged_rows`` result back into the batched paged
    cache: pool leaves are replaced wholesale (the donated pools came
    back updated), per-row leaves (length, hybrid recurrent state) are
    scattered at ``idx``."""
    out = dict(big)
    out["k"], out["v"] = rows["k"], rows["v"]
    out["kv_pos"] = rows["kv_pos"]
    if "k_scale" in big:                 # int8 pool scales travel with it
        out["k_scale"], out["v_scale"] = rows["k_scale"], rows["v_scale"]
    out["length"] = big["length"].at[idx].set(rows["length"])
    for key in ("lru", "conv"):        # hybrid recurrent rows stay dense
        if key in big:
            out[key] = big[key].at[:, idx].set(rows[key])
    return out
