"""Batched ragged rejection sampling (Leviathan et al. / Chen et al.).

Handles per-sequence draft lengths inside one padded [B, K] block — the
"Ragged Q" of paper §3.2.  The sampler is *exact*: the emitted token stream
is distributed identically to sampling the target model autoregressively,
which the property tests verify empirically.

Index convention for one round (sequence-local):
    inputs  t_0 = pending token, t_1..t_K = draft tokens
    target logits  P[:, j]  = p(. | t_0..t_j)           (j = 0..K)
    draft  logits  Q[:, j]  = q(. | t_0..t_j)           (j = 0..K-1)
    draft token d_{j+1} was sampled from Q[:, j].

Acceptance of d_{j+1} tests against P[:, j]; on total acceptance the bonus
token comes from P[:, K]; on first rejection at j the recovery token comes
from the residual ``norm(max(P[:, j] - Q[:, j], 0))``.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.sampling import probs_from_logits, sample_from_probs


class RejectionResult(NamedTuple):
    accept_mask: jax.Array     # [B, K] bool — accepted draft positions
    num_accepted: jax.Array    # [B] int32 — length of accepted prefix
    next_token: jax.Array      # [B] int32 — bonus or recovery token
    emitted: jax.Array         # [B, K+1] int32 — accepted drafts + next_token,
                               #   padded with pad_id beyond num_accepted+1
    num_emitted: jax.Array     # [B] = num_accepted + 1


def rejection_sample(key: jax.Array, draft_tokens: jax.Array,
                     draft_logits: jax.Array, target_logits: jax.Array,
                     draft_len: jax.Array, *, temperature: float,
                     vocab_size: int, pad_id: int,
                     row_keys: Optional[Tuple[jax.Array, jax.Array]] = None
                     ) -> RejectionResult:
    """draft_tokens [B,K]; draft_logits [B,K,V]; target_logits [B,K+1,V];
    draft_len [B] (0..K, ragged).

    ``row_keys=(accept_keys [B], recover_keys [B])`` switches to
    *identity-threaded* RNG (DESIGN.md §7): the acceptance draw at
    position ``j`` of row ``b`` is ``uniform(fold_in(accept_keys[b], j))``
    and the recovery/bonus draw is keyed by ``recover_keys[b]`` alone —
    so each draw depends only on the row's own key and the position,
    never on the batch size or the padded draft width K.  Without it the
    historical single-``key`` path is used (one [B, K] uniform tensor;
    draws shift with batch/bucket shape)."""
    b, k = draft_tokens.shape
    p = probs_from_logits(target_logits, temperature, vocab_size)  # [B,K+1,V]
    q = probs_from_logits(draft_logits, temperature, vocab_size)   # [B,K,V]

    key_acc, key_rec = jax.random.split(key)
    pos = jnp.arange(k)[None, :]
    valid = pos < draft_len[:, None]                               # [B,K]

    if k > 0:
        p_tok = jnp.take_along_axis(p[:, :k], draft_tokens[..., None],
                                    axis=-1)[..., 0]
        q_tok = jnp.take_along_axis(q, draft_tokens[..., None],
                                    axis=-1)[..., 0]
        ratio = p_tok / jnp.maximum(q_tok, 1e-30)
        if row_keys is not None:
            u = jax.vmap(lambda kb: jax.vmap(
                lambda j: jax.random.uniform(
                    jax.random.fold_in(kb, j), ()))(jnp.arange(k)))(
                        row_keys[0])
        else:
            u = jax.random.uniform(key_acc, (b, k))
        accept = (u < jnp.minimum(ratio, 1.0)) & valid
        # accepted prefix: leading run of True
        prefix = jnp.cumprod(accept.astype(jnp.int32), axis=1)
        num_accepted = prefix.sum(axis=1).astype(jnp.int32)
        accept_mask = prefix.astype(bool)
    else:
        accept_mask = jnp.zeros((b, 0), bool)
        num_accepted = jnp.zeros((b,), jnp.int32)

    # next-token distribution:
    #   all accepted (num_accepted == draft_len): bonus ~ P[:, draft_len]
    #   rejected at j = num_accepted:  ~ norm(max(P[:, j] - Q[:, j], 0))
    all_accepted = num_accepted >= draft_len
    j = jnp.minimum(num_accepted, jnp.maximum(k - 1, 0))
    bi = jnp.arange(b)
    p_j = p[bi, jnp.minimum(num_accepted, k)]                      # [B,V]
    if k > 0:
        q_j = q[bi, j]
        residual = jnp.maximum(p[bi, j] - q_j, 0.0)
        residual_sum = residual.sum(-1, keepdims=True)
        # residual can be all-zero when p == q exactly (greedy agree case is
        # excluded because then the token was accepted); fall back to p.
        residual = jnp.where(residual_sum > 1e-30,
                             residual / jnp.maximum(residual_sum, 1e-30),
                             p[bi, j])
        next_dist = jnp.where(all_accepted[:, None], p_j, residual)
    else:
        next_dist = p_j
    if row_keys is not None:
        next_token = jax.vmap(sample_from_probs)(
            row_keys[1], next_dist).astype(jnp.int32)
    else:
        next_token = sample_from_probs(key_rec, next_dist).astype(jnp.int32)

    # emitted stream: accepted drafts then next_token, pad elsewhere
    out = jnp.full((b, k + 1), pad_id, jnp.int32)
    if k > 0:
        keep = jnp.arange(k)[None, :] < num_accepted[:, None]
        out = out.at[:, :k].set(jnp.where(keep, draft_tokens, pad_id))
    out = out.at[bi, num_accepted].set(next_token)
    return RejectionResult(accept_mask=accept_mask,
                           num_accepted=num_accepted,
                           next_token=next_token,
                           emitted=out,
                           num_emitted=num_accepted + 1)
