"""Sampling utilities shared by the engine and the rejection sampler."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def mask_vocab(logits: jax.Array, vocab_size: int) -> jax.Array:
    """Mask padded vocabulary entries (embedding table is padded for
    sharding divisibility — DESIGN.md §5)."""
    v = logits.shape[-1]
    if v == vocab_size:
        return logits
    mask = jnp.arange(v) < vocab_size
    return jnp.where(mask, logits, -1e30)


def probs_from_logits(logits: jax.Array, temperature: float,
                      vocab_size: Optional[int] = None) -> jax.Array:
    """Temperature-adjusted probabilities; temperature 0 -> one-hot argmax
    (the greedy limit used throughout the paper's temp-0.0 tables)."""
    if vocab_size is not None:
        logits = mask_vocab(logits, vocab_size)
    logits = logits.astype(jnp.float32)
    if temperature <= 0.0:
        return jax.nn.one_hot(jnp.argmax(logits, -1), logits.shape[-1],
                              dtype=jnp.float32)
    return jax.nn.softmax(logits / temperature, axis=-1)


def sample_from_probs(key: jax.Array, probs: jax.Array) -> jax.Array:
    """Categorical sampling that is exact for one-hot (greedy) inputs."""
    logp = jnp.log(jnp.maximum(probs, 1e-30))
    return jax.random.categorical(key, logp, axis=-1)


def sample_token(key: jax.Array, logits: jax.Array, temperature: float,
                 vocab_size: Optional[int] = None) -> jax.Array:
    if vocab_size is not None:
        logits = mask_vocab(logits, vocab_size)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)
    return jax.random.categorical(key, logits.astype(jnp.float32) / temperature,
                                  axis=-1)
