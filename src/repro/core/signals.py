"""Post-hoc diagnostic signals for DSDE (paper §3.1).

Everything is vectorized over the batch: signals are ``[B]`` or ``[B, N]``
arrays, histories are fixed-size ring buffers so the whole adapter jits
into the serving step (no per-step recompilation — see DESIGN.md §3).

* ``kld_per_position``  — KL(target ‖ draft) at each proposed position.
* ``draft_entropy``     — forward-looking baseline signal (AdaEDL's input).
* ``weighted_mean/var`` — Eq. (5)–(7): exponential-decay weighting
  ``alpha_i = delta^(i-1)`` with i=1 the most recent step.
* ``KLDHistory``        — per-sequence ring buffer of per-step mean KLDs
  feeding the short (N=10) and long (N=30) WVIR windows (Fig. 5).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


def _log_softmax(logits: jax.Array) -> jax.Array:
    return jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)


def kld_per_position(target_logits: jax.Array, draft_logits: jax.Array,
                     valid: Optional[jax.Array] = None) -> jax.Array:
    """KL(p_target ‖ q_draft) per position.

    target_logits/draft_logits: [B, T, V]; valid: [B, T] bool.
    Returns [B, T] (0 where invalid).
    """
    lp = _log_softmax(target_logits)
    lq = _log_softmax(draft_logits)
    kld = jnp.sum(jnp.exp(lp) * (lp - lq), axis=-1)
    kld = jnp.maximum(kld, 0.0)          # numerical floor
    if valid is not None:
        kld = jnp.where(valid, kld, 0.0)
    return kld


def draft_entropy(draft_logits: jax.Array) -> jax.Array:
    """Shannon entropy of the draft distribution per position. [B, T]."""
    lq = _log_softmax(draft_logits)
    return -jnp.sum(jnp.exp(lq) * lq, axis=-1)


def masked_mean(x: jax.Array, valid: Optional[jax.Array],
                axis: int = -1) -> jax.Array:
    if valid is None:
        return x.mean(axis=axis)
    v = valid.astype(jnp.float32)
    return (x * v).sum(axis=axis) / jnp.maximum(v.sum(axis=axis), 1.0)


# ---------------------------------------------------------------------------
# Weighted statistics — Eq. (5)-(7)
# ---------------------------------------------------------------------------

def decay_weights(n: int, delta: float) -> jax.Array:
    """alpha_i = delta^(i-1), i=1 most recent.  Returned oldest-first so it
    aligns with a chronologically-ordered window [oldest ... newest]."""
    i = jnp.arange(n, 0, -1, dtype=jnp.float32)   # oldest gets largest i
    return delta ** (i - 1.0)


def weighted_mean(x: jax.Array, weights: jax.Array,
                  valid: Optional[jax.Array] = None) -> jax.Array:
    """Eq. (6) over the last axis. x: [..., N], weights [N]."""
    w = weights * (valid.astype(jnp.float32) if valid is not None else 1.0)
    return (x * w).sum(-1) / jnp.maximum(w.sum(-1) if valid is not None
                                         else w.sum(), 1e-9)


def weighted_var(x: jax.Array, weights: jax.Array,
                 valid: Optional[jax.Array] = None) -> jax.Array:
    """Eq. (7) over the last axis."""
    w = weights * (valid.astype(jnp.float32) if valid is not None else 1.0)
    wsum = jnp.maximum(w.sum(-1) if valid is not None else w.sum(), 1e-9)
    mu = (x * w).sum(-1) / wsum
    return (w * jnp.square(x - mu[..., None])).sum(-1) / wsum


# ---------------------------------------------------------------------------
# Per-sequence KLD history (Fig. 5)
# ---------------------------------------------------------------------------

class KLDHistory(NamedTuple):
    """Ring buffer of per-step mean KLD values, one row per sequence.

    ``buf [B, N_long]`` chronological ring; ``count [B]`` number of valid
    entries (saturates at N_long); ``head [B]`` next write slot.
    """
    buf: jax.Array
    count: jax.Array
    head: jax.Array

    @staticmethod
    def init(batch: int, n_long: int = 30) -> "KLDHistory":
        return KLDHistory(
            buf=jnp.zeros((batch, n_long), jnp.float32),
            count=jnp.zeros((batch,), jnp.int32),
            head=jnp.zeros((batch,), jnp.int32))

    def push(self, value: jax.Array,
             active: Optional[jax.Array] = None) -> "KLDHistory":
        """Append one per-step value [B]; ``active`` gates sequences that
        did not take a step this round (finished / not scheduled)."""
        b, n = self.buf.shape
        bi = jnp.arange(b)
        new_buf = self.buf.at[bi, self.head].set(value.astype(jnp.float32))
        new_count = jnp.minimum(self.count + 1, n)
        new_head = (self.head + 1) % n
        if active is not None:
            new_buf = jnp.where(active[:, None], new_buf, self.buf)
            new_count = jnp.where(active, new_count, self.count)
            new_head = jnp.where(active, new_head, self.head)
        return KLDHistory(new_buf, new_count, new_head)

    def chronological(self, n: int) -> Tuple[jax.Array, jax.Array]:
        """Last ``n`` entries, oldest-first: (values [B, n], valid [B, n])."""
        b, n_long = self.buf.shape
        assert n <= n_long
        # entry j (j=0 oldest of the window) lives at head - n + j (mod N)
        offs = jnp.arange(-n, 0)
        idx = (self.head[:, None] + offs[None, :]) % n_long
        vals = jnp.take_along_axis(self.buf, idx, axis=1)
        # validity: the last min(count, n) slots are real
        age = jnp.arange(n, 0, -1)[None, :]          # newest has age 1
        valid = age <= self.count[:, None]
        return vals, valid

    def reset_rows(self, rows: jax.Array) -> "KLDHistory":
        """Clear history for sequences being replaced (continuous batching)."""
        z = jnp.zeros_like(self.count)
        return KLDHistory(
            buf=jnp.where(rows[:, None], jnp.zeros_like(self.buf), self.buf),
            count=jnp.where(rows, z, self.count),
            head=jnp.where(rows, z, self.head))


def wvir(history: KLDHistory, short_n: int, long_n: int, delta: float,
         eps: float = 1e-9) -> jax.Array:
    """Eq. (4): Weighted Variance Intensity Ratio, per sequence [B].

    WVIR > 1 indicates growing instability.  Until the long window has at
    least ``short_n`` entries the ratio is defined as 1 (neutral)."""
    vs, valid_s = history.chronological(short_n)
    vl, valid_l = history.chronological(long_n)
    var_s = weighted_var(vs, decay_weights(short_n, delta), valid_s)
    var_l = weighted_var(vl, decay_weights(long_n, delta), valid_l)
    ratio = var_s / jnp.maximum(var_l, eps)
    enough = history.count >= short_n
    return jnp.where(enough, ratio, 1.0)
