"""One speculative-decoding round, fully jitted (paper §3.2 "Ragged Q").

A round with draft bucket size K (static; the engine picks the bucket from
the per-sequence SL predictions so there are at most ``sl_max - sl_min + 1``
compiled programs — the XLA-native replacement for vLLM's per-step
CUDA-graph recapture problem, DESIGN.md §3):

  1. draft loop   — K single-token decode steps of the draft model
                    (``lax.scan`` with the draft KV/state cache in carry);
                    per-sequence validity ``j < sl_i`` implements ragged SL
                    inside the fixed bucket.  Policies may shrink ``sl_i``
                    dynamically via the ``draft_keep`` hook (AdaEDL's
                    entropy early stop).
  2. verification — ONE target forward over [pending, d_1..d_K]
                    (T = K+1) against the target cache.
  3. rejection    — exact batched ragged rejection sampling.
  4. post-hoc     — KLD per proposed position -> policy.observe
                    (DSDE's lagging diagnostic signal).
  5. commit       — caches advance by exactly 1 + n_accepted tokens
                    (KV: length arithmetic; recurrent: masked re-advance).
  6. predict      — policy.predict (+ SL_cap) for the next round.

All SL-control behaviour is delegated to a :class:`SpecPolicy`
(``repro/core/policies``) resolved from ``spec.policy`` at trace time:
``spec`` is a jit static argument, so each (policy-config, K) pair traces
exactly one XLA program and the policy dispatch costs nothing at runtime.

The engine in ``repro/serving`` strings rounds together and handles
request lifecycles / continuous batching.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import ModelConfig, SpecDecodeConfig
from repro.core.policies import PolicyObservation, SpecPolicy, build_policy
from repro.core.rejection import RejectionResult, rejection_sample
from repro.core.sampling import sample_token
from repro.core.signals import kld_per_position
from repro.models import cache as cache_lib
from repro.models.transformer import commit, forward

PyTree = Any


class RoundState(NamedTuple):
    """Carried across rounds by the serving engine.

    With a paged serving configuration the cache pytrees are block-paged
    (``models/cache.py``): they carry the shared KV pools plus the
    per-sequence ``block_table`` rows the allocator maintains, so block
    tables ride through the jitted round with no extra plumbing —
    rollback stays pure length arithmetic and freed speculative blocks
    simply return to the pool on the host side.

    Termination is *device-side* (DESIGN.md §7): a slot that emits its
    EOS or exhausts ``tokens_budget`` mid-round raises its own ``done``
    flag and stops consuming draft/verify work in every later round, so
    the engine can chain round N+1 onto round N before the host has
    reconciled round N's outputs (the plan → dispatch → collect
    pipeline).  The engine resets all three fields when it prefills a
    new request into a slot."""
    target_cache: PyTree
    draft_cache: PyTree
    policy_state: PyTree       # the SpecPolicy's per-sequence state pytree
    pending: jax.Array         # [B] last emitted token, not yet in caches
    sl_next: jax.Array         # [B] per-sequence SL for the next round
    key: jax.Array
    done: jax.Array            # [B] bool — slot terminated itself in-round
    tokens_budget: jax.Array   # [B] int32 — tokens the slot may still emit
    eos_id: jax.Array          # [B] int32 — per-slot EOS token (-1 = none)


class RoundOutput(NamedTuple):
    emitted: jax.Array         # [B, K+1] new tokens (pad beyond num_emitted)
    num_emitted: jax.Array     # [B] — already truncated to EOS / budget
    num_accepted: jax.Array    # [B]
    num_proposed: jax.Array    # [B]
    finished: jax.Array        # [B] bool — slot terminated THIS round
    live: jax.Array            # [B] bool — slot did real work this round
    telemetry: Dict[str, jax.Array]


def _draft_loop(params_d: PyTree, cfg_d: ModelConfig, state: RoundState,
                k: int, sl_i: jax.Array, policy: SpecPolicy,
                key: jax.Array, active: jax.Array
                ) -> Tuple[jax.Array, jax.Array, PyTree, jax.Array]:
    """K+1 draft decode steps (the final step only writes the last draft
    token's KV so the cache is complete on total acceptance).  Returns
    (draft_tokens [B,K], draft_logits [B,K,V], new_draft_cache, eff_sl)."""
    b = state.pending.shape[0]
    spec = policy.spec

    def step(carry, j):
        cache, tok, stop, eff = carry
        # paged caches: step j writes position len+j, needed only up to
        # the committed horizon (j <= SL_i); inactive rows never write
        wm = ((j <= sl_i) & active)[:, None]
        logits, cache, _ = forward(params_d, cfg_d, tok[:, None],
                                   cache=cache, mode="decode",
                                   write_mask=wm)
        lj = logits[:, 0]
        kj = jax.random.fold_in(key, j)
        nxt = sample_token(kj, lj, spec.temperature, cfg_d.vocab_size)
        keep = policy.draft_keep(lj)
        if keep is not None:       # in-draft early stop (trace-time branch)
            stop = stop | ~keep
        live = (j < sl_i) & (j < k) & ~stop
        eff = eff + live.astype(jnp.int32)
        # cache length bookkeeping: each step wrote one KV at len + j; the
        # cache's ``length`` field is only advanced at commit time, so we
        # thread an explicit position via a temp length bump.
        cache = dict(cache)
        cache["length"] = cache["length"] + 1
        return (cache, nxt.astype(jnp.int32), stop, eff), (nxt, lj)

    cache0 = dict(state.draft_cache)
    init = (cache0, state.pending, jnp.zeros((b,), bool),
            jnp.zeros((b,), jnp.int32))
    (cache_k, _, _, eff), (toks, logits) = jax.lax.scan(
        step, init, jnp.arange(k + 1))
    cache_k = dict(cache_k)
    cache_k["length"] = state.draft_cache["length"]     # restore; commit later
    draft_tokens = jnp.moveaxis(toks[:k], 0, 1).astype(jnp.int32)  # [B,K]
    draft_logits = jnp.moveaxis(logits[:k], 0, 1)                  # [B,K,V]
    return draft_tokens, draft_logits, cache_k, eff


@functools.partial(jax.jit, static_argnames=("cfg_t", "cfg_d", "spec", "k"))
def spec_decode_round(params_t: PyTree, params_d: PyTree,
                      cfg_t: ModelConfig, cfg_d: ModelConfig,
                      spec: SpecDecodeConfig, k: int,
                      state: RoundState, active: jax.Array
                      ) -> Tuple[RoundState, RoundOutput]:
    """One full speculative round with draft bucket size ``k``.

    ``active [B]`` masks occupied request slots (continuous batching);
    the round intersects it with ``~state.done`` so a slot that
    terminated itself device-side in an earlier — possibly not yet
    host-reconciled — round does no draft/verify work and emits
    nothing.  This is what makes back-to-back dispatch sound: the
    engine may enqueue round N+1 before it has looked at round N."""
    policy = build_policy(spec)     # trace-time: spec is static
    key, k_draft, k_rej = jax.random.split(state.key, 3)
    b = state.pending.shape[0]
    pad_id = cfg_t.vocab_size  # reserved padding token id (paper §3.2)

    live = active & ~state.done
    sl_i = jnp.minimum(state.sl_next, k) * live.astype(jnp.int32)

    # --- 1. draft -----------------------------------------------------------
    if k > 0:
        draft_tokens, draft_logits, draft_cache, eff_sl = _draft_loop(
            params_d, cfg_d, state, k, sl_i, policy, k_draft, live)
        sl_i = jnp.minimum(sl_i, eff_sl)  # draft_keep early stop shrinks here
    else:  # no-draft bucket (autoregressive policy, or an all-idle batch)
        draft_tokens = jnp.zeros((b, 0), jnp.int32)
        draft_cache = state.draft_cache
        eff_sl = jnp.zeros((b,), jnp.int32)

    # replace out-of-range draft positions by the reserved pad id so invalid
    # token ids never propagate (paper §3.2); pad_id has a real (padded)
    # embedding row and is masked out of every softmax.
    pos = jnp.arange(k)[None, :]
    proposed = pos < sl_i[:, None]
    safe_drafts = jnp.where(proposed, draft_tokens, pad_id)

    # --- 2. verification ----------------------------------------------------
    verify_tokens = jnp.concatenate(
        [state.pending[:, None], safe_drafts], axis=1)          # [B, K+1]
    # paged caches: verification writes positions len..len+K; only
    # j <= SL_i can ever be committed, so the rest never leaves the
    # sequence's own block budget (dense rings ignore the mask)
    verify_wm = (jnp.arange(k + 1)[None] <= sl_i[:, None]) & live[:, None]
    t_logits, t_cache_v, _ = forward(params_t, cfg_t, verify_tokens,
                                     cache=state.target_cache, mode="decode",
                                     write_mask=verify_wm)

    # --- 3. rejection sampling ----------------------------------------------
    if k > 0:
        dl = draft_logits
    else:
        dl = jnp.zeros((b, 0) + t_logits.shape[-1:], t_logits.dtype)
    rej: RejectionResult = rejection_sample(
        k_rej, safe_drafts, dl, t_logits, sl_i,
        temperature=spec.temperature, vocab_size=cfg_t.vocab_size,
        pad_id=pad_id)

    # --- 4. post-hoc signals --------------------------------------------------
    if k > 0:
        kld = kld_per_position(t_logits[:, :k], dl, proposed)   # [B, K]
    else:
        kld = jnp.zeros((b, 0), jnp.float32)
    obs = PolicyObservation(
        kld=kld, proposed_valid=proposed, num_accepted=rej.num_accepted,
        num_proposed=sl_i, active=live)
    new_pstate = policy.observe(state.policy_state, obs)

    # --- 5. commit ------------------------------------------------------------
    n_committed = (1 + rej.num_accepted) * live.astype(jnp.int32)
    t_cache = commit(params_t, cfg_t, verify_tokens, state.target_cache,
                     t_cache_v, n_committed)
    if k > 0:
        d_cache = commit(params_d, cfg_d, verify_tokens, state.draft_cache,
                         draft_cache, n_committed)
    else:  # the draft model was never consulted
        d_cache = state.draft_cache

    # --- 6. device-side termination -------------------------------------------
    # Truncate the emitted stream exactly the way the host loop used to:
    # walk the tokens in order, stop after the first EOS or once the
    # remaining ``tokens_budget`` is spent, and raise ``done`` so later
    # rounds skip the slot.  The host merely mirrors these decisions at
    # reconciliation — which may be a full round later.
    n_raw = rej.num_emitted                                    # [B]
    pos1 = jnp.arange(k + 1)[None, :]
    in_raw = pos1 < n_raw[:, None]
    is_eos = ((rej.emitted == state.eos_id[:, None])
              & in_raw & (state.eos_id >= 0)[:, None])
    inf = jnp.int32(k + 2)                                     # > any n_raw
    eos_cut = jnp.where(is_eos.any(1),
                        jnp.argmax(is_eos, 1).astype(jnp.int32) + 1, inf)
    n_emit = jnp.minimum(n_raw, jnp.minimum(eos_cut, state.tokens_budget))
    n_emit = jnp.where(live, n_emit, 0)
    finished = live & ((n_emit == eos_cut) | (n_emit == state.tokens_budget))
    new_done = state.done | finished
    new_budget = jnp.maximum(state.tokens_budget - n_emit, 0)

    # --- 7. predict next SL ----------------------------------------------------
    sl_next, new_pstate, telemetry = policy.predict(new_pstate, live)

    new_state = RoundState(
        target_cache=t_cache, draft_cache=d_cache, policy_state=new_pstate,
        pending=jnp.where(live, rej.next_token, state.pending),
        sl_next=sl_next, key=key,
        done=new_done, tokens_budget=new_budget, eos_id=state.eos_id)
    out = RoundOutput(
        emitted=jnp.where(live[:, None] & (pos1 < n_emit[:, None]),
                          rej.emitted, pad_id),
        num_emitted=n_emit,
        num_accepted=rej.num_accepted * live.astype(jnp.int32),
        num_proposed=sl_i,
        finished=finished,
        live=live,
        telemetry=telemetry)
    return new_state, out


def init_round_state(cfg_t: ModelConfig, cfg_d: ModelConfig,
                     spec: SpecDecodeConfig, batch: int, max_len: int,
                     key: jax.Array, dtype=jnp.float32,
                     enc_len: Optional[int] = None,
                     paged: Optional[Tuple[int, int]] = None) -> RoundState:
    """``paged=(num_blocks, block_size)`` builds block-paged caches for
    both models: one allocator decision covers a block id in the target
    pool and the same id in the draft pool (the tables mirror).

    The termination fields default to "never terminate" (``done`` clear,
    effectively infinite ``tokens_budget``, no EOS) so direct round
    drivers — benchmarks, the policy invariant suite — keep the
    pre-pipeline semantics; the serving engine overwrites all three per
    slot at prefill."""
    policy = build_policy(spec)
    no_term = dict(
        done=jnp.zeros((batch,), bool),
        tokens_budget=jnp.full((batch,), jnp.int32(2 ** 30), jnp.int32),
        eos_id=jnp.full((batch,), -1, jnp.int32))
    if paged is not None:
        n_blocks, bs = paged
        t_cache = cache_lib.paged_cache_struct(cfg_t, batch, max_len,
                                               n_blocks, bs, dtype)
        d_cache = cache_lib.paged_cache_struct(cfg_d, batch, max_len,
                                               n_blocks, bs, dtype)
        return RoundState(
            target_cache=t_cache, draft_cache=d_cache,
            policy_state=policy.init_state(batch),
            pending=jnp.zeros((batch,), jnp.int32),
            sl_next=policy.initial_sl(batch),
            key=key, **no_term)
    t_cache = cache_lib.cache_struct(cfg_t, batch, max_len, dtype,
                                     enc_len=enc_len)
    d_cache = cache_lib.cache_struct(cfg_d, batch, max_len, dtype,
                                     enc_len=enc_len)
    return RoundState(
        target_cache=t_cache, draft_cache=d_cache,
        policy_state=policy.init_state(batch),
        pending=jnp.zeros((batch,), jnp.int32),
        sl_next=policy.initial_sl(batch),
        key=key, **no_term)


def pick_bucket(sl_next, spec: SpecDecodeConfig, active) -> int:
    """Python-side bucket choice, delegated to the policy.  Prefer calling
    ``policy.pick_bucket`` directly with pre-materialized host arrays (the
    engine does); this wrapper keeps the historical (sl, spec, active)
    signature for scripts and tests."""
    return build_policy(spec).pick_bucket(np.asarray(sl_next),
                                          np.asarray(active))
