"""One speculative-decoding round, fully jitted (paper §3.2 "Ragged Q").

A round with draft bucket size K (static; the engine picks the bucket from
the per-sequence SL predictions so there are at most ``sl_max - sl_min + 1``
compiled programs — the XLA-native replacement for vLLM's per-step
CUDA-graph recapture problem, DESIGN.md §3):

  1. propose      — delegated to a :class:`~repro.core.drafters.Drafter`
                    (DESIGN.md §9): a separate draft model's decode scan
                    (``model``), prompt-lookup suffix matching
                    (``ngram``), an early-exit slice of the target
                    (``self``), or any registered proposer.  The drafter
                    owns its per-sequence cache pytree and returns the
                    proposal *distribution* alongside the tokens, so
                    steps 3–4 stay proposer-agnostic.  Per-sequence
                    validity ``j < sl_i`` implements ragged SL inside the
                    fixed bucket; policies may shrink ``sl_i``
                    dynamically via the ``draft_keep`` hook.
  2. verification — ONE target forward over [pending, d_1..d_K]
                    (T = K+1) against the target cache.
  3. rejection    — exact batched ragged rejection sampling against the
                    drafter-provided q (real logits for model drafters,
                    one-hot for lookup drafters — exact either way).
  4. post-hoc     — divergence per proposed position -> policy.observe
                    (DSDE's lagging diagnostic signal; the drafter
                    defines the signal so it stays finite for point-mass
                    proposers).
  5. commit       — target cache advances by exactly 1 + n_accepted
                    tokens; the drafter commits its own cache the same
                    way (KV length arithmetic, token-history append, or
                    nothing at all).
  6. predict      — policy.predict (+ SL_cap) for the next round.

All SL-control behaviour is delegated to a :class:`SpecPolicy`
(``repro/core/policies``) and all proposal behaviour to a
:class:`Drafter` (``repro/core/drafters``), both resolved at trace time:
``spec`` and ``drafter`` are jit static arguments, so each
(policy-config, drafter-config, K) triple traces exactly one XLA program
and the dispatch costs nothing at runtime.

RNG is *identity-threaded* (DESIGN.md §7): every random draw in a round
is keyed by (request seed, the request's own round ordinal, purpose,
position) — never by host dispatch order, batch composition, or bucket
width — so temperature>0 token streams are reproducible across engine
schedules, not just greedy ones.

The engine in ``repro/serving`` strings rounds together and handles
request lifecycles / continuous batching.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig, SpecDecodeConfig
from repro.core.drafters import Drafter, build_drafter
from repro.core.policies import PolicyObservation, SpecPolicy, build_policy
from repro.core.rejection import RejectionResult, rejection_sample
from repro.models import cache as cache_lib
from repro.models.transformer import commit, forward

PyTree = Any

# RNG purpose tags: one per independent random decision a request makes.
# The engine uses PURPOSE_PREFILL for the prefill-sampled first token.
PURPOSE_DRAFT = 0
PURPOSE_ACCEPT = 1
PURPOSE_RECOVER = 2
PURPOSE_PREFILL = 3


class RoundState(NamedTuple):
    """Carried across rounds by the serving engine.

    ``draft_cache`` is whatever pytree the configured drafter threads
    round to round: a mirrored KV cache (``model``), a token-history
    buffer (``ngram``), or ``()`` (``self``).  With a paged serving
    configuration the KV pytrees are block-paged (``models/cache.py``):
    they carry the shared pools plus the per-sequence ``block_table``
    rows the allocator maintains, so block tables ride through the
    jitted round with no extra plumbing — rollback stays pure length
    arithmetic and freed speculative blocks simply return to the pool on
    the host side.

    Termination is *device-side* (DESIGN.md §7): a slot that emits its
    EOS or exhausts ``tokens_budget`` mid-round raises its own ``done``
    flag and stops consuming draft/verify work in every later round, so
    the engine can chain round N+1 onto round N before the host has
    reconciled round N's outputs (the plan → dispatch → collect
    pipeline).  The engine resets those fields when it prefills a new
    request into a slot.

    ``key`` is the CONSTANT base key; ``seed [B]`` binds each slot to
    its occupant request and ``round_idx [B]`` counts the occupant's own
    live rounds — together they derive every per-row sampling key, so
    stochastic streams are schedule-invariant (see module docstring)."""
    target_cache: PyTree
    draft_cache: PyTree
    policy_state: PyTree       # the SpecPolicy's per-sequence state pytree
    pending: jax.Array         # [B] last emitted token, not yet in caches
    sl_next: jax.Array         # [B] per-sequence SL for the next round
    key: jax.Array             # base PRNG key (constant across rounds)
    seed: jax.Array            # [B] int32 — per-slot request sampling seed
    round_idx: jax.Array       # [B] int32 — occupant's own round ordinal
    done: jax.Array            # [B] bool — slot terminated itself in-round
    tokens_budget: jax.Array   # [B] int32 — tokens the slot may still emit
    eos_id: jax.Array          # [B] int32 — per-slot EOS token (-1 = none)


class RoundOutput(NamedTuple):
    emitted: jax.Array         # [B, K+1] new tokens (pad beyond num_emitted)
    num_emitted: jax.Array     # [B] — already truncated to EOS / budget
    num_accepted: jax.Array    # [B]
    num_proposed: jax.Array    # [B]
    finished: jax.Array        # [B] bool — slot terminated THIS round
    live: jax.Array            # [B] bool — slot did real work this round
    telemetry: Dict[str, jax.Array]


def row_keys(base_key: jax.Array, seed: jax.Array, round_idx: jax.Array,
             purpose: int) -> jax.Array:
    """[B] per-row PRNG keys bound to (request seed, round ordinal,
    purpose) — the identity-threaded RNG scheme (module docstring)."""
    def one(s, r):
        kk = jax.random.fold_in(base_key, s)
        kk = jax.random.fold_in(kk, r)
        return jax.random.fold_in(kk, purpose)
    return jax.vmap(one)(seed.astype(jnp.uint32),
                         round_idx.astype(jnp.uint32))


def _match_vocab(dl: jax.Array, v: int) -> jax.Array:
    """Pad (with -inf) or slice the proposal logits to the target's
    padded-vocab width — padded entries carry no mass either way."""
    dv = dl.shape[-1]
    if dv == v:
        return dl
    if dv < v:
        return jnp.pad(dl, ((0, 0), (0, 0), (0, v - dv)),
                       constant_values=-1e30)
    return dl[..., :v]


def spec_decode_round_impl(params_t: PyTree, params_d: PyTree,
                           cfg_t: ModelConfig, drafter: Drafter,
                           spec: SpecDecodeConfig, k: int,
                           state: RoundState, active: jax.Array
                           ) -> Tuple[RoundState, RoundOutput]:
    """One full speculative round with draft bucket size ``k``.

    ``drafter`` is the frozen proposer (static — dispatch traces away);
    ``params_d`` is its parameter pytree (``None`` for parameter-free
    drafters).  ``active [B]`` masks occupied request slots (continuous
    batching); the round intersects it with ``~state.done`` so a slot
    that terminated itself device-side in an earlier — possibly not yet
    host-reconciled — round does no draft/verify work and emits
    nothing.  This is what makes back-to-back dispatch sound: the
    engine may enqueue round N+1 before it has looked at round N."""
    # both are static, so this costs nothing: a drafter built from a
    # DIFFERENT config would propose at its own temperature/knobs while
    # rejection and the policy run at ``spec``'s — silently inexact
    assert drafter.spec == spec, (
        "drafter was built from a different SpecDecodeConfig than the "
        "round is running")
    policy = build_policy(spec)     # trace-time: spec is static
    b = state.pending.shape[0]
    pad_id = cfg_t.vocab_size  # reserved padding token id (paper §3.2)

    live = active & ~state.done
    sl_i = jnp.minimum(state.sl_next, k) * live.astype(jnp.int32)
    k_acc = row_keys(state.key, state.seed, state.round_idx, PURPOSE_ACCEPT)
    k_rec = row_keys(state.key, state.seed, state.round_idx, PURPOSE_RECOVER)

    # --- 1. propose ---------------------------------------------------------
    if k > 0:
        k_draft = row_keys(state.key, state.seed, state.round_idx,
                           PURPOSE_DRAFT)
        prop = drafter.propose(params_t, params_d, state.draft_cache,
                               state.target_cache, state.pending, k, sl_i,
                               policy, k_draft, live)
        sl_i = jnp.minimum(sl_i, prop.eff_sl)  # early stop / short lookup
        draft_tokens, drafted_cache = prop.tokens, prop.cache
    else:  # no-draft bucket (autoregressive policy, or an all-idle batch)
        draft_tokens = jnp.zeros((b, 0), jnp.int32)
        drafted_cache = state.draft_cache

    # replace out-of-range draft positions by the reserved pad id so invalid
    # token ids never propagate (paper §3.2); pad_id has a real (padded)
    # embedding row and is masked out of every softmax.
    pos = jnp.arange(k)[None, :]
    proposed = pos < sl_i[:, None]
    safe_drafts = jnp.where(proposed, draft_tokens, pad_id)

    # --- 2. verification ----------------------------------------------------
    verify_tokens = jnp.concatenate(
        [state.pending[:, None], safe_drafts], axis=1)          # [B, K+1]
    # paged caches: verification writes positions len..len+K; only
    # j <= SL_i can ever be committed, so the rest never leaves the
    # sequence's own block budget (dense rings ignore the mask)
    verify_wm = (jnp.arange(k + 1)[None] <= sl_i[:, None]) & live[:, None]
    t_logits, t_cache_v, _ = forward(params_t, cfg_t, verify_tokens,
                                     cache=state.target_cache, mode="decode",
                                     write_mask=verify_wm)

    # --- 3. rejection sampling ----------------------------------------------
    if k > 0:
        dl = _match_vocab(prop.logits, t_logits.shape[-1])
    else:
        dl = jnp.zeros((b, 0) + t_logits.shape[-1:], t_logits.dtype)
    rej: RejectionResult = rejection_sample(
        state.key, safe_drafts, dl, t_logits, sl_i,
        temperature=spec.temperature, vocab_size=cfg_t.vocab_size,
        pad_id=pad_id, row_keys=(k_acc, k_rec))

    # --- 4. post-hoc signals --------------------------------------------------
    if k > 0:
        kld = drafter.observation_kld(t_logits[:, :k], dl, safe_drafts,
                                      proposed)                 # [B, K]
    else:
        kld = jnp.zeros((b, 0), jnp.float32)
    obs = PolicyObservation(
        kld=kld, proposed_valid=proposed, num_accepted=rej.num_accepted,
        num_proposed=sl_i, active=live)
    new_pstate = policy.observe(state.policy_state, obs)

    # --- 5. commit ------------------------------------------------------------
    n_committed = (1 + rej.num_accepted) * live.astype(jnp.int32)
    t_cache = commit(params_t, cfg_t, verify_tokens, state.target_cache,
                     t_cache_v, n_committed)
    if k > 0:
        d_cache = drafter.commit(params_d, verify_tokens, state.draft_cache,
                                 drafted_cache, n_committed)
    else:  # the drafter was never consulted
        d_cache = state.draft_cache

    # --- 6. device-side termination -------------------------------------------
    # Truncate the emitted stream exactly the way the host loop used to:
    # walk the tokens in order, stop after the first EOS or once the
    # remaining ``tokens_budget`` is spent, and raise ``done`` so later
    # rounds skip the slot.  The host merely mirrors these decisions at
    # reconciliation — which may be a full round later.
    n_raw = rej.num_emitted                                    # [B]
    pos1 = jnp.arange(k + 1)[None, :]
    in_raw = pos1 < n_raw[:, None]
    is_eos = ((rej.emitted == state.eos_id[:, None])
              & in_raw & (state.eos_id >= 0)[:, None])
    inf = jnp.int32(k + 2)                                     # > any n_raw
    eos_cut = jnp.where(is_eos.any(1),
                        jnp.argmax(is_eos, 1).astype(jnp.int32) + 1, inf)
    n_emit = jnp.minimum(n_raw, jnp.minimum(eos_cut, state.tokens_budget))
    n_emit = jnp.where(live, n_emit, 0)
    finished = live & ((n_emit == eos_cut) | (n_emit == state.tokens_budget))
    new_done = state.done | finished
    new_budget = jnp.maximum(state.tokens_budget - n_emit, 0)

    # --- 7. predict next SL ----------------------------------------------------
    sl_next, new_pstate, telemetry = policy.predict(new_pstate, live)

    new_state = RoundState(
        target_cache=t_cache, draft_cache=d_cache, policy_state=new_pstate,
        pending=jnp.where(live, rej.next_token, state.pending),
        sl_next=sl_next, key=state.key, seed=state.seed,
        round_idx=state.round_idx + live.astype(jnp.int32),
        done=new_done, tokens_budget=new_budget, eos_id=state.eos_id)
    out = RoundOutput(
        emitted=jnp.where(live[:, None] & (pos1 < n_emit[:, None]),
                          rej.emitted, pad_id),
        num_emitted=n_emit,
        num_accepted=rej.num_accepted * live.astype(jnp.int32),
        num_proposed=sl_i,
        finished=finished,
        live=live,
        telemetry=telemetry)
    return new_state, out


# The default single-device entry point.  The un-jitted body stays
# importable (``spec_decode_round_impl``) so the serving engine's mesh
# path can wrap it in its OWN jit with explicit ``in_shardings`` /
# ``out_shardings`` per draft bucket (DESIGN.md §5) — same trace, pinned
# layouts, no double-jit.
spec_decode_round = jax.jit(
    spec_decode_round_impl,
    static_argnames=("cfg_t", "drafter", "spec", "k"))


def init_round_state(cfg_t: ModelConfig, cfg_d: Optional[ModelConfig],
                     spec: SpecDecodeConfig, batch: int, max_len: int,
                     key: jax.Array, dtype=jnp.float32,
                     enc_len: Optional[int] = None,
                     paged: Optional[Tuple[int, int]] = None,
                     drafter: Optional[Drafter] = None,
                     kv_quant: str = "none") -> RoundState:
    """Fresh round state: target cache (dense, or block-paged when
    ``paged=(num_blocks, block_size)``) plus whatever cache pytree the
    configured drafter owns — built through the same ``paged`` geometry
    when the drafter mirrors the target pool (``model``), or its own
    structure otherwise (token history for ``ngram``, ``()`` for
    ``self``).

    ``key`` becomes the CONSTANT base key of the identity-threaded RNG;
    ``seed`` defaults to ``arange(batch)`` so direct round drivers get
    distinct per-row streams (the engine overwrites it per admission).

    The termination fields default to "never terminate" (``done`` clear,
    effectively infinite ``tokens_budget``, no EOS) so direct round
    drivers — benchmarks, the policy invariant suite — keep the
    pre-pipeline semantics; the serving engine overwrites them per slot
    at prefill."""
    policy = build_policy(spec)
    if drafter is None:
        drafter = build_drafter(spec, cfg_t, cfg_d)
    if kv_quant != "none" and paged is None:
        raise ValueError("kv_quant requires the block-paged cache "
                         "(pass paged=(num_blocks, block_size))")
    no_term = dict(
        done=jnp.zeros((batch,), bool),
        tokens_budget=jnp.full((batch,), jnp.int32(2 ** 30), jnp.int32),
        eos_id=jnp.full((batch,), -1, jnp.int32))
    if paged is not None:
        n_blocks, bs = paged
        # the serving scheduler owns the pool-vs-max_len feasibility
        # policy (prefix-cached pools may be smaller than one max-len
        # sequence); the data plane only needs drop-semantics
        t_cache = cache_lib.paged_cache_struct(cfg_t, batch, max_len,
                                               n_blocks, bs, dtype,
                                               require_full_seq=False,
                                               kv_quant=kv_quant)
    else:
        t_cache = cache_lib.cache_struct(cfg_t, batch, max_len, dtype,
                                         enc_len=enc_len)
    d_cache = drafter.init_cache(batch, max_len, dtype, paged=paged,
                                 kv_quant=kv_quant)
    return RoundState(
        target_cache=t_cache, draft_cache=d_cache,
        policy_state=policy.init_state(batch),
        pending=jnp.zeros((batch,), jnp.int32),
        sl_next=policy.initial_sl(batch),
        key=key,
        seed=jnp.arange(batch, dtype=jnp.int32),
        round_idx=jnp.zeros((batch,), jnp.int32),
        **no_term)
