"""Pallas TPU kernel: fused post-hoc signal extraction.

After each verification the DSDE adapter needs, per proposed position:
KL(p_target ‖ q_draft), the draft entropy, and the token probabilities
p(x)/q(x) for rejection sampling.  A naive jnp implementation reads the two
[B, T, V] logit tensors ~5 times (two log_softmax passes, three reductions)
— at V ≈ 152k this step is purely HBM-bandwidth-bound, so fusing it into a
single streaming pass over the vocabulary is a ~4-5x reduction of the
dominant (memory) roofline term for the adapter stage.

Online accumulation (flash-softmax style, per (b, t) row):

  running  m_p, s_p = sumexp(tl - m_p)           (target logsumexp state)
           m_q, s_q = sumexp(dl - m_q)           (draft  logsumexp state)
           a_pd = sum e^{tl-m_p} (tl - dl)       (-> KL numerator)
           a_qq = sum e^{dl-m_q} dl              (-> entropy numerator)
           p_tok, q_tok: picked up in the block holding ``token``

  finalize:
    lse_p = m_p + log s_p ;  lse_q = m_q + log s_q
    KL    = a_pd / s_p - lse_p + lse_q
    H_q   = lse_q - a_qq / s_q
    p_tok = e^{tl_tok - lse_p} ;  q_tok = e^{dl_tok - lse_q}

Grid: (B*T, V // BV) — vocab blocks innermost, state in SMEM/VMEM scratch.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(tok_ref, tl_ref, dl_ref,
            kld_ref, ent_ref, ptok_ref, qtok_ref,
            state_ref, *, nvb: int, bv: int):
    vb = pl.program_id(1)

    @pl.when(vb == 0)
    def _init():
        state_ref[0] = NEG_INF   # m_p
        state_ref[1] = 0.0       # s_p
        state_ref[2] = 0.0       # a_pd
        state_ref[3] = NEG_INF   # m_q
        state_ref[4] = 0.0       # s_q
        state_ref[5] = 0.0       # a_qq
        state_ref[6] = NEG_INF   # tl[token]
        state_ref[7] = NEG_INF   # dl[token]

    tl = tl_ref[0].astype(jnp.float32)          # [BV]
    dl = dl_ref[0].astype(jnp.float32)          # [BV]
    tok = tok_ref[0]

    # --- target-side online stats -----------------------------------------
    m_p, s_p, a_pd = state_ref[0], state_ref[1], state_ref[2]
    m_new = jnp.maximum(m_p, jnp.max(tl))
    alpha = jnp.exp(m_p - m_new)
    e_p = jnp.exp(tl - m_new)
    state_ref[0] = m_new
    state_ref[1] = s_p * alpha + e_p.sum()
    state_ref[2] = a_pd * alpha + (e_p * (tl - dl)).sum()

    # --- draft-side online stats -------------------------------------------
    m_q, s_q, a_qq = state_ref[3], state_ref[4], state_ref[5]
    mq_new = jnp.maximum(m_q, jnp.max(dl))
    beta = jnp.exp(m_q - mq_new)
    e_q = jnp.exp(dl - mq_new)
    state_ref[3] = mq_new
    state_ref[4] = s_q * beta + e_q.sum()
    state_ref[5] = a_qq * beta + (e_q * dl).sum()

    # --- token pick-up -------------------------------------------------------
    lo = vb * bv
    idx = tok - lo
    in_block = (idx >= 0) & (idx < bv)
    idx_c = jnp.clip(idx, 0, bv - 1)
    state_ref[6] = jnp.where(in_block, tl[idx_c], state_ref[6])
    state_ref[7] = jnp.where(in_block, dl[idx_c], state_ref[7])

    @pl.when(vb == nvb - 1)
    def _finalize():
        s_p_f = jnp.maximum(state_ref[1], 1e-30)
        s_q_f = jnp.maximum(state_ref[4], 1e-30)
        lse_p = state_ref[0] + jnp.log(s_p_f)
        lse_q = state_ref[3] + jnp.log(s_q_f)
        kld_ref[0] = jnp.maximum(state_ref[2] / s_p_f - lse_p + lse_q, 0.0)
        ent_ref[0] = lse_q - state_ref[5] / s_q_f
        ptok_ref[0] = jnp.exp(state_ref[6] - lse_p)
        qtok_ref[0] = jnp.exp(state_ref[7] - lse_q)


@functools.partial(jax.jit, static_argnames=("block_v", "interpret"))
def fused_kld_accept(target_logits: jax.Array, draft_logits: jax.Array,
                     draft_tokens: jax.Array, *, block_v: int = 2048,
                     interpret: bool = False
                     ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """target_logits/draft_logits [B,T,V]; draft_tokens [B,T] int32.
    Returns per [B,T]: (kld, draft_entropy, p_target(tok), q_draft(tok))."""
    b, t, v = target_logits.shape
    n = b * t
    bv = min(block_v, v)
    if v % bv:
        pad = bv - v % bv
        target_logits = jnp.pad(target_logits, ((0, 0), (0, 0), (0, pad)),
                                constant_values=NEG_INF)
        draft_logits = jnp.pad(draft_logits, ((0, 0), (0, 0), (0, pad)),
                               constant_values=NEG_INF)
        v += pad
    nvb = v // bv
    tl = target_logits.reshape(n, v)
    dl = draft_logits.reshape(n, v)
    tok = draft_tokens.reshape(n).astype(jnp.int32)

    shapes = jax.ShapeDtypeStruct((n,), jnp.float32)
    kld, ent, ptok, qtok = pl.pallas_call(
        functools.partial(_kernel, nvb=nvb, bv=bv),
        grid=(n, nvb),
        in_specs=[
            pl.BlockSpec((1,), lambda ni, vi: (ni,)),
            pl.BlockSpec((1, bv), lambda ni, vi: (ni, vi)),
            pl.BlockSpec((1, bv), lambda ni, vi: (ni, vi)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda ni, vi: (ni,)),
            pl.BlockSpec((1,), lambda ni, vi: (ni,)),
            pl.BlockSpec((1,), lambda ni, vi: (ni,)),
            pl.BlockSpec((1,), lambda ni, vi: (ni,)),
        ],
        out_shape=[shapes, shapes, shapes, shapes],
        scratch_shapes=[pltpu.SMEM((8,), jnp.float32)],
        interpret=interpret,
    )(tok, tl, dl)
    return (kld.reshape(b, t), ent.reshape(b, t),
            ptok.reshape(b, t), qtok.reshape(b, t))
