"""Pallas TPU kernel: prompt-lookup suffix matching for the n-gram drafter.

The NGramDrafter (DESIGN.md §9) proposes draft tokens by finding the most
recent earlier occurrence of the sequence's trailing ``n``-gram inside its
own known text and replaying the tokens that followed it — zero draft
parameters, zero draft KV.  The hot loop is a batched windowed
string-match over int32 token buffers ``[B, L]``; on accelerators the
whole row fits in VMEM, so one program per sequence streams the buffer
once and does all ``n`` shifted comparisons on-chip instead of ``n``
separate HBM sweeps of an XLA gather pipeline.

Layout / grid
-------------
  tokens  [B, L] int32   known text per sequence (history + pending)
  ctx     [B, 1] int32   how many leading entries are real
  out     [B, K] int32   proposed continuation (zero-padded)
  cnt     [B, 1] int32   number of real proposals (0 = no match)

  grid = (B,) — one program per sequence; ``n``/``k`` are small static
  constants, so the shifted-equality reduction unrolls fully.  All
  indexing is mask-and-reduce (TPU-safe: no 1-D iota, no dynamic
  gather): the suffix values, the argmax-of-last-match, and the ``k``
  continuation picks are each a broadcast compare + reduction over the
  [1, L] tile.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(ctx_ref, tok_ref, out_ref, cnt_ref, *, n: int, k: int, l: int):
    row = tok_ref[0, :]                                    # [L] int32
    c = ctx_ref[0, 0]                                      # scalar int32
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, l), 1)[0]

    match = jnp.ones((l,), bool)
    for j in range(n):
        # suffix value s_j = row[c - n + j] via masked reduction
        sj = jnp.sum(jnp.where(idx == c - n + j, row, 0))
        # row[i + j] as a static shift padded with -1 (never a token id)
        if j:
            shifted = jnp.concatenate(
                [row[j:], jnp.full((j,), -1, row.dtype)])
        else:
            shifted = row
        match = match & (shifted == sj)
    # >= 1 known continuation (also kills the trivial suffix occurrence)
    match = match & (idx + n <= c - 1) & (c >= n + 1)

    best = jnp.max(jnp.where(match, idx, -1))              # most recent
    found = best >= 0
    cnt = jnp.where(found, jnp.minimum(jnp.int32(k), c - (best + n)),
                    0).astype(jnp.int32)
    cnt_ref[0, 0] = cnt
    for m in range(k):
        tm = jnp.sum(jnp.where(idx == best + n + m, row, 0))
        out_ref[0, m] = jnp.where(m < cnt, tm, 0).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("n", "k", "interpret"))
def ngram_suffix_propose(tokens: jax.Array, ctx_len: jax.Array, *, n: int,
                         k: int, interpret: bool = False
                         ) -> Tuple[jax.Array, jax.Array]:
    """tokens [B, L] int32; ctx_len [B] int32.  Returns
    ``(proposed [B, K] int32 zero-padded, count [B] int32)`` — bit-exact
    against :func:`repro.kernels.ref.ngram_propose_ref`."""
    assert n >= 1, "suffix length must be >= 1"
    b, l = tokens.shape
    if k == 0:
        return (jnp.zeros((b, 0), jnp.int32),
                jnp.zeros((b,), jnp.int32))
    out, cnt = pl.pallas_call(
        functools.partial(_kernel, n=n, k=k, l=l),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda bi: (bi, 0)),
            pl.BlockSpec((1, l), lambda bi: (bi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda bi: (bi, 0)),
            pl.BlockSpec((1, 1), lambda bi: (bi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.int32),
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
        ],
        interpret=interpret,
    )(ctx_len.astype(jnp.int32).reshape(b, 1), tokens.astype(jnp.int32))
    return out, cnt[:, 0]
