"""Backend-dispatching wrappers around the Pallas kernels.

On TPU the Pallas kernels run compiled; everywhere else (this CPU
container, tests) they run with ``interpret=True`` or fall back to the
pure-jnp oracles in :mod:`repro.kernels.ref`.  The model code calls these
wrappers, never the kernels directly.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.kld_accept import fused_kld_accept
from repro.kernels.ngram_match import ngram_suffix_propose
from repro.kernels.ragged_attention import (
    paged_ragged_verify_attention, paged_ragged_verify_attention_quant,
    ragged_verify_attention)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def on_tpu() -> bool:
    """Trace-time backend check the model layer uses to pick between the
    Pallas data plane and the XLA reference path."""
    return _on_tpu()


def ragged_attention(q: jax.Array, k_buf: jax.Array, v_buf: jax.Array,
                     q_pos: jax.Array, kv_pos: jax.Array, *,
                     window: Optional[int] = None,
                     force_kernel: bool = False,
                     interpret: Optional[bool] = None) -> jax.Array:
    """Decode/verify attention against a ring KV cache (ragged lengths)."""
    if _on_tpu() or force_kernel:
        return ragged_verify_attention(
            q, k_buf, v_buf, q_pos, kv_pos, window=window,
            interpret=bool(interpret) if interpret is not None
            else not _on_tpu())
    return ref.ragged_verify_attention_ref(q, k_buf, v_buf, q_pos, kv_pos,
                                           window=window)


def paged_ragged_attention(q: jax.Array, pool_k: jax.Array,
                           pool_v: jax.Array, block_table: jax.Array,
                           q_pos: jax.Array, kv_pos: jax.Array, *,
                           window: Optional[int] = None,
                           force_kernel: bool = False,
                           interpret: Optional[bool] = None) -> jax.Array:
    """Decode/verify attention straight off the block-paged KV pool."""
    if _on_tpu() or force_kernel:
        return paged_ragged_verify_attention(
            q, pool_k, pool_v, block_table, q_pos, kv_pos, window=window,
            interpret=bool(interpret) if interpret is not None
            else not _on_tpu())
    return ref.paged_ragged_verify_attention_ref(q, pool_k, pool_v,
                                                 block_table, q_pos, kv_pos,
                                                 window=window)


def paged_ragged_attention_quant(q: jax.Array, pool_k: jax.Array,
                                 pool_v: jax.Array, k_scale: jax.Array,
                                 v_scale: jax.Array, block_table: jax.Array,
                                 q_pos: jax.Array, kv_pos: jax.Array, *,
                                 window: Optional[int] = None,
                                 force_kernel: bool = False,
                                 interpret: Optional[bool] = None
                                 ) -> jax.Array:
    """Decode/verify attention off the int8 block pool, dequantizing
    in-register inside the kv-sweep (DESIGN.md §13)."""
    if _on_tpu() or force_kernel:
        return paged_ragged_verify_attention_quant(
            q, pool_k, pool_v, k_scale, v_scale, block_table, q_pos,
            kv_pos, window=window,
            interpret=bool(interpret) if interpret is not None
            else not _on_tpu())
    return ref.paged_ragged_verify_attention_quant_ref(
        q, pool_k, pool_v, k_scale, v_scale, block_table, q_pos, kv_pos,
        window=window)


def ngram_propose(tokens: jax.Array, ctx_len: jax.Array, *, n: int, k: int,
                  force_kernel: bool = False,
                  interpret: Optional[bool] = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Prompt-lookup suffix match for the n-gram drafter: most recent
    earlier occurrence of the trailing n-gram + its k-token continuation.
    Returns (proposed [B, K] int32 zero-padded, count [B] int32)."""
    if k == 0:
        b = tokens.shape[0]
        return jnp.zeros((b, 0), jnp.int32), jnp.zeros((b,), jnp.int32)
    if _on_tpu() or force_kernel:
        return ngram_suffix_propose(
            tokens, ctx_len, n=n, k=k,
            interpret=bool(interpret) if interpret is not None
            else not _on_tpu())
    return ref.ngram_propose_ref(tokens, ctx_len, n=n, k=k)


def kld_accept_signals(target_logits: jax.Array, draft_logits: jax.Array,
                       draft_tokens: jax.Array, *,
                       force_kernel: bool = False,
                       interpret: Optional[bool] = None
                       ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused per-position (KL(p||q), H(q), p(tok), q(tok))."""
    if _on_tpu() or force_kernel:
        return fused_kld_accept(
            target_logits, draft_logits, draft_tokens,
            interpret=bool(interpret) if interpret is not None
            else not _on_tpu())
    return ref.kld_accept_ref(target_logits, draft_logits, draft_tokens)
