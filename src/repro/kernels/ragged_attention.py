"""Pallas TPU kernel: ragged decode / verification attention.

This is the TPU-native replacement for the FlashAttention-2 varlen kernel
the paper integrates into vLLM's Target Worker (paper §3 / DESIGN.md §3):
requests with heterogeneous speculative lengths are scored in a single
batch pass.  On TPU the raggedness lives in *masks over a padded
[T = SL_cap+1] query block* — SL_cap bounds the pad waste, which is the
serendipitous synergy between the paper's straggler mitigation and MXU
tiling.

Layout / grid
-------------
  q        [B, KV, G, T, D]   (grouped-query view; T small: 1..SL_max+1)
  k_buf    [B, W, KV, D]      ring-buffer cache, W = window or max_len
  v_buf    [B, W, KV, D]
  kv_pos   [B, W]  int32      absolute position per ring slot (-1 empty)
  q_pos    [B, T]  int32      absolute position per query token
  out      [B, KV, G, T, D]

  grid = (B, KV, W // BK)     — kv blocks innermost, so the (m, l, acc)
  online-softmax state lives in VMEM scratch across the kv sweep
  (flash-decoding structure).  The [G*T, BK] score tile hits the MXU; all
  masking is elementwise on the tile.

Block sizes: BK is the kv tile (default 512 lanes * sublanes aligned);
G*T stays small (<= 8*11 = 88 rows -> padded to sublane multiples by Mosaic).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, kvp_ref, qp_ref, o_ref,
            m_ref, l_ref, acc_ref, *, window: Optional[int], nwb: int,
            sm_scale: float):
    wb = pl.program_id(2)

    @pl.when(wb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # [G, T, D]
    g, t, d = q.shape
    k = k_ref[0, :, 0, :].astype(jnp.float32)        # [BK, D]
    v = v_ref[0, :, 0, :].astype(jnp.float32)        # [BK, D]
    kvp = kvp_ref[0]                                 # [BK]
    qp = qp_ref[0]                                   # [T]

    s = jax.lax.dot_general(q.reshape(g * t, d), k,
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * sm_scale                                  # [G*T, BK]
    valid = (kvp[None, :] >= 0) & (kvp[None, :] <= qp[:, None])
    if window is not None:
        valid = valid & (qp[:, None] - kvp[None, :] < window)
    mask = jnp.tile(valid, (g, 1))                    # [G*T, BK]
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    l_ref[...] = l_ref[...] * alpha + p.sum(-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(wb == nwb - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = out.reshape(g, t, d).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "block_k", "interpret"))
def ragged_verify_attention(q: jax.Array, k_buf: jax.Array, v_buf: jax.Array,
                            q_pos: jax.Array, kv_pos: jax.Array, *,
                            window: Optional[int] = None,
                            block_k: int = 512,
                            interpret: bool = False) -> jax.Array:
    """q [B,T,H,D]; k_buf/v_buf [B,W,KV,D]; q_pos [B,T]; kv_pos [B,W].
    Returns [B,T,H,D].  See module docstring."""
    b, t, h, d = q.shape
    w, kv = k_buf.shape[1], k_buf.shape[2]
    g = h // kv
    bk = min(block_k, w)
    if w % bk:
        pad = bk - w % bk
        k_buf = jnp.pad(k_buf, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_buf = jnp.pad(v_buf, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
        w += pad
    nwb = w // bk

    qr = q.reshape(b, t, kv, g, d).transpose(0, 2, 3, 1, 4)  # [B,KV,G,T,D]
    grid = (b, kv, nwb)
    out = pl.pallas_call(
        functools.partial(_kernel, window=window, nwb=nwb,
                          sm_scale=1.0 / math.sqrt(d)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, t, d), lambda bi, ki, wi: (bi, ki, 0, 0, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda bi, ki, wi: (bi, wi, ki, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda bi, ki, wi: (bi, wi, ki, 0)),
            pl.BlockSpec((1, bk), lambda bi, ki, wi: (bi, wi)),
            pl.BlockSpec((1, t), lambda bi, ki, wi: (bi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, t, d),
                               lambda bi, ki, wi: (bi, ki, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, t, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g * t,), jnp.float32),
            pltpu.VMEM((g * t,), jnp.float32),
            pltpu.VMEM((g * t, d), jnp.float32),
        ],
        interpret=interpret,
    )(qr, k_buf, v_buf, kv_pos, q_pos)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, t, h, d)


# ---------------------------------------------------------------------------
# Block-paged variant: the KV sweep walks each sequence's block table and
# the index maps dereference it (scalar prefetch), so the kernel reads
# straight from the shared block pool — no per-sequence dense view is ever
# materialized (the XLA fallback in kernels/ref.py gathers instead).
# ---------------------------------------------------------------------------


def _paged_kernel(bt_ref, q_ref, k_ref, v_ref, kvp_ref, qp_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, window: Optional[int], nlb: int,
                  sm_scale: float):
    lb = pl.program_id(2)

    @pl.when(lb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # [G, T, D]
    g, t, d = q.shape
    k = k_ref[0, :, 0, :].astype(jnp.float32)        # [BS, D]
    v = v_ref[0, :, 0, :].astype(jnp.float32)        # [BS, D]
    kvp = kvp_ref[0]                                 # [BS]
    qp = qp_ref[0]                                   # [T]
    entry = bt_ref[pl.program_id(0), lb]             # physical block or -1

    s = jax.lax.dot_general(q.reshape(g * t, d), k,
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * sm_scale                                  # [G*T, BS]
    valid = (kvp[None, :] >= 0) & (kvp[None, :] <= qp[:, None])
    if window is not None:
        valid = valid & (qp[:, None] - kvp[None, :] < window)
    valid = valid & (entry >= 0)   # unallocated logical block: all masked
    mask = jnp.tile(valid, (g, 1))                    # [G*T, BS]
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    l_ref[...] = l_ref[...] * alpha + p.sum(-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(lb == nlb - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = out.reshape(g, t, d).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_ragged_verify_attention(q: jax.Array, pool_k: jax.Array,
                                  pool_v: jax.Array, block_table: jax.Array,
                                  q_pos: jax.Array, kv_pos: jax.Array, *,
                                  window: Optional[int] = None,
                                  interpret: bool = False) -> jax.Array:
    """Paged decode/verify attention straight off the shared block pool.

    q [B,T,H,D]; pool_k/pool_v [N, BS, KV, D]; block_table [B, MAXB]
    int32 (-1 = unallocated); q_pos [B,T]; kv_pos [N, BS] pool-level slot
    positions (-1 = empty).  Returns [B,T,H,D].

    Grid = (B, KV, MAXB): the innermost sweep visits one *logical* block
    per step and the k/v/kv_pos index maps look its physical id up in the
    scalar-prefetched table (clamped to 0 for unallocated entries, whose
    scores are then fully masked).  The online-softmax (m, l, acc) state
    lives in VMEM scratch across the sweep, exactly like the dense ring
    kernel above.  One BS-token tile per step is the clarity-first
    schedule; the production knob is fetching several table entries per
    step so the score tile reaches MXU width.
    """
    b, t, h, d = q.shape
    bs, kv = pool_k.shape[1], pool_k.shape[2]
    g = h // kv
    maxb = block_table.shape[1]

    qr = q.reshape(b, t, kv, g, d).transpose(0, 2, 3, 1, 4)  # [B,KV,G,T,D]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kv, maxb),
        in_specs=[
            pl.BlockSpec((1, 1, g, t, d),
                         lambda bi, ki, li, bt: (bi, ki, 0, 0, 0)),
            pl.BlockSpec((1, bs, 1, d),
                         lambda bi, ki, li, bt: (jnp.maximum(bt[bi, li], 0),
                                                 0, ki, 0)),
            pl.BlockSpec((1, bs, 1, d),
                         lambda bi, ki, li, bt: (jnp.maximum(bt[bi, li], 0),
                                                 0, ki, 0)),
            pl.BlockSpec((1, bs),
                         lambda bi, ki, li, bt: (jnp.maximum(bt[bi, li], 0),
                                                 0)),
            pl.BlockSpec((1, t), lambda bi, ki, li, bt: (bi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, t, d),
                               lambda bi, ki, li, bt: (bi, ki, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g * t,), jnp.float32),
            pltpu.VMEM((g * t,), jnp.float32),
            pltpu.VMEM((g * t, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, window=window, nlb=maxb,
                          sm_scale=1.0 / math.sqrt(d)),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, g, t, d), q.dtype),
        interpret=interpret,
    )(block_table.astype(jnp.int32), qr, pool_k, pool_v, kv_pos, q_pos)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, t, h, d)


# ---------------------------------------------------------------------------
# Quantized-pool variant: int8 K/V tiles plus their per-slot-per-KV-head
# fp32 amax scales stream through the same scalar-prefetched block-table
# index maps, and dequantization happens in-register right before the
# score / value dots — the fp K/V tile never exists outside VMEM
# registers, so the HBM bytes swept per round shrink to the int8 pool +
# scale footprint (DESIGN.md §13).
# ---------------------------------------------------------------------------


def _paged_quant_kernel(bt_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                        kvp_ref, qp_ref, o_ref, m_ref, l_ref, acc_ref, *,
                        window: Optional[int], nlb: int, sm_scale: float):
    lb = pl.program_id(2)

    @pl.when(lb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # [G, T, D]
    g, t, d = q.shape
    # in-register dequant: int8 tile * fp32 per-slot scale column
    k = k_ref[0, :, 0, :].astype(jnp.float32) * ks_ref[0, :, 0][:, None]
    v = v_ref[0, :, 0, :].astype(jnp.float32) * vs_ref[0, :, 0][:, None]
    kvp = kvp_ref[0]                                 # [BS]
    qp = qp_ref[0]                                   # [T]
    entry = bt_ref[pl.program_id(0), lb]             # physical block or -1

    s = jax.lax.dot_general(q.reshape(g * t, d), k,
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * sm_scale                                  # [G*T, BS]
    valid = (kvp[None, :] >= 0) & (kvp[None, :] <= qp[:, None])
    if window is not None:
        valid = valid & (qp[:, None] - kvp[None, :] < window)
    valid = valid & (entry >= 0)   # unallocated logical block: all masked
    mask = jnp.tile(valid, (g, 1))                    # [G*T, BS]
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    l_ref[...] = l_ref[...] * alpha + p.sum(-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(lb == nlb - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = out.reshape(g, t, d).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_ragged_verify_attention_quant(
        q: jax.Array, pool_k: jax.Array, pool_v: jax.Array,
        k_scale: jax.Array, v_scale: jax.Array, block_table: jax.Array,
        q_pos: jax.Array, kv_pos: jax.Array, *,
        window: Optional[int] = None,
        interpret: bool = False) -> jax.Array:
    """Paged decode/verify attention off the int8 block pool.

    q [B,T,H,D]; pool_k/pool_v [N, BS, KV, D] int8;
    k_scale/v_scale [N, BS, KV] fp32 amax scales; block_table [B, MAXB]
    int32 (-1 = unallocated); q_pos [B,T]; kv_pos [N, BS].  Returns
    [B,T,H,D].

    Same (B, KV, MAXB) grid and online-softmax scratch as
    :func:`paged_ragged_verify_attention`; the scale tiles ride the same
    scalar-prefetched table lookup as the kv_pos tile, so unallocated
    entries clamp to block 0 and mask out identically.
    """
    b, t, h, d = q.shape
    bs, kv = pool_k.shape[1], pool_k.shape[2]
    g = h // kv
    maxb = block_table.shape[1]

    qr = q.reshape(b, t, kv, g, d).transpose(0, 2, 3, 1, 4)  # [B,KV,G,T,D]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kv, maxb),
        in_specs=[
            pl.BlockSpec((1, 1, g, t, d),
                         lambda bi, ki, li, bt: (bi, ki, 0, 0, 0)),
            pl.BlockSpec((1, bs, 1, d),
                         lambda bi, ki, li, bt: (jnp.maximum(bt[bi, li], 0),
                                                 0, ki, 0)),
            pl.BlockSpec((1, bs, 1, d),
                         lambda bi, ki, li, bt: (jnp.maximum(bt[bi, li], 0),
                                                 0, ki, 0)),
            pl.BlockSpec((1, bs, 1),
                         lambda bi, ki, li, bt: (jnp.maximum(bt[bi, li], 0),
                                                 0, ki)),
            pl.BlockSpec((1, bs, 1),
                         lambda bi, ki, li, bt: (jnp.maximum(bt[bi, li], 0),
                                                 0, ki)),
            pl.BlockSpec((1, bs),
                         lambda bi, ki, li, bt: (jnp.maximum(bt[bi, li], 0),
                                                 0)),
            pl.BlockSpec((1, t), lambda bi, ki, li, bt: (bi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, t, d),
                               lambda bi, ki, li, bt: (bi, ki, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g * t,), jnp.float32),
            pltpu.VMEM((g * t,), jnp.float32),
            pltpu.VMEM((g * t, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_quant_kernel, window=window, nlb=maxb,
                          sm_scale=1.0 / math.sqrt(d)),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, g, t, d), q.dtype),
        interpret=interpret,
    )(block_table.astype(jnp.int32), qr, pool_k, pool_v,
      k_scale, v_scale, kv_pos, q_pos)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, t, h, d)
