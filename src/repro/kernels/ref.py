"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth)."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def ragged_verify_attention_ref(q: jax.Array, k_buf: jax.Array,
                                v_buf: jax.Array, q_pos: jax.Array,
                                kv_pos: jax.Array,
                                window: Optional[int] = None) -> jax.Array:
    """Oracle for the ragged decode/verify attention kernel.

    q [B,T,H,D] — T = 1 (decode) or SL_cap+1 (verification);
    k_buf/v_buf [B,W,KV,D] — ring-buffer cache (already containing the new
    tokens' KV);  q_pos [B,T] absolute positions; kv_pos [B,W] slot
    positions (-1 = empty).  GQA via head grouping.
    """
    b, t, h, d = q.shape
    kv = k_buf.shape[2]
    g = h // kv
    qr = q.reshape(b, t, kv, g, d).astype(jnp.float32)
    scores = jnp.einsum("btkgd,bskd->bkgts", qr,
                        k_buf.astype(jnp.float32)) / math.sqrt(d)
    mask = (kv_pos[:, None, :] >= 0) & (kv_pos[:, None, :] <= q_pos[:, :, None])
    if window is not None:
        mask = mask & (q_pos[:, :, None] - kv_pos[:, None, :] < window)
    scores = jnp.where(mask[:, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskd->btkgd", probs, v_buf.astype(jnp.float32))
    return out.reshape(b, t, h, d).astype(q.dtype)


def paged_ragged_verify_attention_ref(q: jax.Array, pool_k: jax.Array,
                                      pool_v: jax.Array,
                                      block_table: jax.Array,
                                      q_pos: jax.Array, kv_pos: jax.Array,
                                      window: Optional[int] = None
                                      ) -> jax.Array:
    """Oracle for the block-paged kernel: gather each sequence's view out
    of the pool through its block table, then run the dense oracle.

    pool_k/pool_v [N, BS, KV, D]; block_table [B, MAXB] (-1 =
    unallocated); kv_pos [N, BS] pool-level (-1 = empty)."""
    b, maxb = block_table.shape
    bs = pool_k.shape[1]
    idx = jnp.maximum(block_table, 0)
    k_view = pool_k[idx].reshape((b, maxb * bs) + pool_k.shape[2:])
    v_view = pool_v[idx].reshape((b, maxb * bs) + pool_v.shape[2:])
    pos = jnp.where((block_table >= 0)[:, :, None], kv_pos[idx], -1)
    pos_view = pos.reshape(b, maxb * bs)
    return ragged_verify_attention_ref(q, k_view, v_view, q_pos, pos_view,
                                       window=window)


def paged_ragged_verify_attention_quant_ref(
        q: jax.Array, pool_k: jax.Array, pool_v: jax.Array,
        k_scale: jax.Array, v_scale: jax.Array, block_table: jax.Array,
        q_pos: jax.Array, kv_pos: jax.Array,
        window: Optional[int] = None) -> jax.Array:
    """Oracle for the quantized block-paged kernel: gather the int8 view
    and its scales through the block table, dequantize in f32 (the same
    ``int8 * scale`` product the kernel fuses in-register), then run the
    dense oracle.

    pool_k/pool_v [N, BS, KV, D] int8; k_scale/v_scale [N, BS, KV] fp32;
    block_table [B, MAXB] (-1 = unallocated); kv_pos [N, BS]."""
    b, maxb = block_table.shape
    bs = pool_k.shape[1]
    idx = jnp.maximum(block_table, 0)
    k_view = (pool_k[idx].astype(jnp.float32)
              * k_scale[idx][..., None])
    v_view = (pool_v[idx].astype(jnp.float32)
              * v_scale[idx][..., None])
    k_view = k_view.reshape((b, maxb * bs) + k_view.shape[3:])
    v_view = v_view.reshape((b, maxb * bs) + v_view.shape[3:])
    pos = jnp.where((block_table >= 0)[:, :, None], kv_pos[idx], -1)
    pos_view = pos.reshape(b, maxb * bs)
    return ragged_verify_attention_ref(q, k_view, v_view, q_pos, pos_view,
                                       window=window)


def ngram_propose_ref(tokens: jax.Array, ctx_len: jax.Array, *, n: int,
                      k: int) -> Tuple[jax.Array, jax.Array]:
    """Oracle for the prompt-lookup suffix-match kernel.

    ``tokens [B, L]`` is each sequence's known text (committed history
    with the pending token appended); ``ctx_len [B]`` how many leading
    entries are real.  Finds the MOST RECENT earlier occurrence of the
    length-``n`` suffix ``tokens[ctx_len-n : ctx_len]`` and proposes the
    ``k`` tokens that followed it (clipped to the known text).

    Returns ``(proposed [B, K] int32 — zero-padded beyond count,
    count [B] int32 — 0 when no match)``.  Integer-exact: the Pallas
    kernel must match this bit for bit.
    """
    b, l = tokens.shape
    idx = jnp.arange(l)

    def one(row, c):
        # suffix values via masked reductions (no dynamic gather)
        match = jnp.ones((l,), bool)
        for j in range(n):
            sj = jnp.sum(jnp.where(idx == c - n + j, row, 0))
            # row[i + j] as a static shift, padded with -1 (never a token)
            shifted = jnp.concatenate(
                [row[j:], jnp.full((j,), -1, row.dtype)]) if j else row
            match = match & (shifted == sj)
        # a usable match needs >= 1 known continuation token (i + n <= c-1)
        # — which also excludes the trivial occurrence at i = c - n — and
        # enough context to have a length-n suffix at all
        match = match & (idx + n <= c - 1) & (c >= n + 1)
        best = jnp.max(jnp.where(match, idx, -1))
        found = best >= 0
        count = jnp.where(found,
                          jnp.minimum(jnp.int32(k), c - (best + n)),
                          0).astype(jnp.int32)
        outs = []
        for m in range(k):
            tm = jnp.sum(jnp.where(idx == best + n + m, row, 0))
            outs.append(jnp.where(m < count, tm, 0))
        prop = (jnp.stack(outs) if k else jnp.zeros((0,), row.dtype))
        return prop.astype(jnp.int32), count

    return jax.vmap(one)(tokens, ctx_len.astype(jnp.int32))


def kld_accept_ref(target_logits: jax.Array, draft_logits: jax.Array,
                   draft_tokens: jax.Array
                   ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Oracle for the fused post-hoc signal kernel.

    Returns per [B,T]: (kld = KL(p_target||q_draft), entropy_q,
    p_target(token), q_draft(token))."""
    tl = target_logits.astype(jnp.float32)
    dl = draft_logits.astype(jnp.float32)
    lp = jax.nn.log_softmax(tl, axis=-1)
    lq = jax.nn.log_softmax(dl, axis=-1)
    p = jnp.exp(lp)
    q = jnp.exp(lq)
    kld = jnp.sum(p * (lp - lq), axis=-1)
    ent = -jnp.sum(q * lq, axis=-1)
    p_tok = jnp.take_along_axis(p, draft_tokens[..., None], axis=-1)[..., 0]
    q_tok = jnp.take_along_axis(q, draft_tokens[..., None], axis=-1)[..., 0]
    return kld, ent, p_tok, q_tok
