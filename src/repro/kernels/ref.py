"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth)."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def ragged_verify_attention_ref(q: jax.Array, k_buf: jax.Array,
                                v_buf: jax.Array, q_pos: jax.Array,
                                kv_pos: jax.Array,
                                window: Optional[int] = None) -> jax.Array:
    """Oracle for the ragged decode/verify attention kernel.

    q [B,T,H,D] — T = 1 (decode) or SL_cap+1 (verification);
    k_buf/v_buf [B,W,KV,D] — ring-buffer cache (already containing the new
    tokens' KV);  q_pos [B,T] absolute positions; kv_pos [B,W] slot
    positions (-1 = empty).  GQA via head grouping.
    """
    b, t, h, d = q.shape
    kv = k_buf.shape[2]
    g = h // kv
    qr = q.reshape(b, t, kv, g, d).astype(jnp.float32)
    scores = jnp.einsum("btkgd,bskd->bkgts", qr,
                        k_buf.astype(jnp.float32)) / math.sqrt(d)
    mask = (kv_pos[:, None, :] >= 0) & (kv_pos[:, None, :] <= q_pos[:, :, None])
    if window is not None:
        mask = mask & (q_pos[:, :, None] - kv_pos[:, None, :] < window)
    scores = jnp.where(mask[:, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskd->btkgd", probs, v_buf.astype(jnp.float32))
    return out.reshape(b, t, h, d).astype(q.dtype)


def paged_ragged_verify_attention_ref(q: jax.Array, pool_k: jax.Array,
                                      pool_v: jax.Array,
                                      block_table: jax.Array,
                                      q_pos: jax.Array, kv_pos: jax.Array,
                                      window: Optional[int] = None
                                      ) -> jax.Array:
    """Oracle for the block-paged kernel: gather each sequence's view out
    of the pool through its block table, then run the dense oracle.

    pool_k/pool_v [N, BS, KV, D]; block_table [B, MAXB] (-1 =
    unallocated); kv_pos [N, BS] pool-level (-1 = empty)."""
    b, maxb = block_table.shape
    bs = pool_k.shape[1]
    idx = jnp.maximum(block_table, 0)
    k_view = pool_k[idx].reshape((b, maxb * bs) + pool_k.shape[2:])
    v_view = pool_v[idx].reshape((b, maxb * bs) + pool_v.shape[2:])
    pos = jnp.where((block_table >= 0)[:, :, None], kv_pos[idx], -1)
    pos_view = pos.reshape(b, maxb * bs)
    return ragged_verify_attention_ref(q, k_view, v_view, q_pos, pos_view,
                                       window=window)


def kld_accept_ref(target_logits: jax.Array, draft_logits: jax.Array,
                   draft_tokens: jax.Array
                   ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Oracle for the fused post-hoc signal kernel.

    Returns per [B,T]: (kld = KL(p_target||q_draft), entropy_q,
    p_target(token), q_draft(token))."""
    tl = target_logits.astype(jnp.float32)
    dl = draft_logits.astype(jnp.float32)
    lp = jax.nn.log_softmax(tl, axis=-1)
    lq = jax.nn.log_softmax(dl, axis=-1)
    p = jnp.exp(lp)
    q = jnp.exp(lq)
    kld = jnp.sum(p * (lp - lq), axis=-1)
    ent = -jnp.sum(q * lq, axis=-1)
    p_tok = jnp.take_along_axis(p, draft_tokens[..., None], axis=-1)[..., 0]
    q_tok = jnp.take_along_axis(q, draft_tokens[..., None], axis=-1)[..., 0]
    return kld, ent, p_tok, q_tok
