import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax (the flag above must come first) -----
import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from typing import Dict, Optional  # noqa: E402

import jax               # noqa: E402
import numpy as np       # noqa: E402

from repro.configs import get_config, list_archs                 # noqa: E402
from repro.core.config import INPUT_SHAPES, TPU_V5E              # noqa: E402
from repro.launch.mesh import make_production_mesh               # noqa: E402
from repro.launch.sharding import make_rules                     # noqa: E402
from repro.launch.steps import (adapt_config, make_step_and_specs,  # noqa: E402
                                model_flops, supported)

"""Multi-pod dry-run (deliverable (e)) + roofline-term extraction
(deliverable (g) input).

For every (architecture x input shape) this lowers + compiles the real
step function against the production mesh with ShapeDtypeStruct stand-ins
(no allocation), prints ``memory_analysis()`` / ``cost_analysis()``, and
extracts per-collective byte counts from the optimized HLO.

Usage:
  python -m repro.launch.dryrun --arch qwen3-32b --shape decode_32k
  python -m repro.launch.dryrun --all [--multi-pod] --out results.json
"""

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|"
                       r"pred|f8e4m3fn|f8e5m2)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes of every collective op in the optimized HLO.

    (cost_analysis does not expose collective traffic — this parse is the
    §Roofline collective term's source.)"""
    out = {op: 0 for op in _COLL_OPS}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        # "%x = TYPE op-name(...)" — take the op between type and '('
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", s)
        if not m:
            continue
        op = m.group(2)
        base = op.rstrip("0123456789.")       # all-gather.12 -> all-gather
        base = base.rstrip("-")
        # also handle "-start" variants (async collectives)
        for coll in _COLL_OPS:
            if base == coll or base == coll + "-start":
                out[coll] += _shape_bytes(m.group(1))
                out["count"] += 1
                break
    return out


def dryrun_one(arch: str, shape_name: str, multi_pod: bool,
               verbose: bool = True,
               opts: frozenset = frozenset(),
               expert_parallel: bool = False) -> Dict:
    shape = INPUT_SHAPES[shape_name]
    cfg0 = get_config(arch)
    ok, why = supported(cfg0, shape)
    rec: Dict = {"arch": arch, "shape": shape_name,
                 "mesh": "2x16x16" if multi_pod else "16x16",
                 "opts": sorted(opts) + (["ep"] if expert_parallel else [])}
    if not ok:
        rec.update(status="skipped", reason=why)
        if verbose:
            print(f"[SKIP] {arch} x {shape_name}: {why}")
        return rec

    t0 = time.monotonic()
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(mesh, shape,
                       expert_parallel=expert_parallel)
    cfg = adapt_config(cfg0, shape, opts)
    step, args, donate = make_step_and_specs(cfg, shape, mesh, rules,
                                             opts=opts)

    with mesh:
        lowered = jax.jit(step, donate_argnums=donate).lower(*args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
    t1 = time.monotonic()

    n_dev = int(np.prod(mesh.devices.shape))
    # XLA's cost_analysis visits while bodies once (scan trip counts are
    # ignored — verified empirically); hlo_cost re-parses the optimized HLO
    # and multiplies through nested loops.  Raw values kept for reference.
    from repro.launch.hlo_cost import HLOCost
    hc = HLOCost(compiled.as_text())
    acc = hc.entry_cost()
    flops = acc["flops"]
    bytes_acc = acc["bytes"]
    coll = {k: acc[k] for k in ("all-gather", "all-reduce", "reduce-scatter",
                                "all-to-all", "collective-permute")}
    coll_total = acc["collective_bytes"]
    raw_flops = float(cost.get("flops", 0.0)) if cost else 0.0
    raw_bytes = float(cost.get("bytes accessed", 0.0)) if cost else 0.0

    mem_rec = {}
    if mem is not None:
        for field in ("temp_size_in_bytes", "argument_size_in_bytes",
                      "output_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes"):
            mem_rec[field] = getattr(mem, field, None)
        # The CPU backend has no native bf16 dot: XLA legalizes bf16 dots by
        # materializing f32 CONVERTED COPIES of weights/caches, inflating
        # temp memory vs a real TPU (which runs bf16 on the MXU natively).
        # Estimate that artifact by summing distinct f32 convert-of-bf16
        # results, and report a TPU-adjusted temp figure.
        artifacts = 0
        seen = set()
        for m2 in re.finditer(
                r"f32\[([0-9,]+)\][^=]*convert\((%[\w.\-]+)\)",
                compiled.as_text()):
            key = m2.group(1)
            if key in seen:
                continue
            n = 1
            for d in key.split(","):
                n *= int(d)
            if n * 4 >= 64 * 2**20:    # only count >=64MiB buffers
                seen.add(key)
                artifacts += n * 4
        mem_rec["cpu_bf16_artifact_bytes_est"] = artifacts
        if mem_rec.get("temp_size_in_bytes") is not None:
            mem_rec["temp_tpu_adjusted_bytes"] = max(
                mem_rec["temp_size_in_bytes"] - artifacts, 0)

    hw = TPU_V5E
    mf = model_flops(cfg0, shape)
    # cost_analysis is per-device for SPMD modules
    compute_s = flops / hw.peak_flops_bf16
    memory_s = bytes_acc / hw.hbm_bandwidth
    # each chip drives its ICI links; bytes here are per-device HLO
    collective_s = coll_total / hw.ici_bandwidth

    rec.update(
        status="ok",
        devices=n_dev,
        compile_s=round(t1 - t0, 2),
        hlo_flops_per_device=flops,
        hlo_bytes_per_device=bytes_acc,
        collective_bytes_per_device=coll_total,
        collectives=coll,
        xla_cost_analysis_raw={"flops": raw_flops, "bytes": raw_bytes,
                               "note": "while bodies counted once by XLA"},
        unknown_trip_counts=hc.unknown_trip_counts,
        memory=mem_rec,
        model_flops_total=mf,
        model_flops_per_device=mf / n_dev,
        roofline={
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": collective_s,
            "bottleneck": max(
                [("compute", compute_s), ("memory", memory_s),
                 ("collective", collective_s)], key=lambda kv: kv[1])[0],
            "useful_flops_ratio": (mf / n_dev) / flops if flops else None,
        },
    )
    if verbose:
        r = rec["roofline"]
        print(f"[OK]   {arch:22s} x {shape_name:12s} mesh={rec['mesh']:8s} "
              f"compile={rec['compile_s']:7.1f}s "
              f"FLOPs/dev={flops:.3e} bytes/dev={bytes_acc:.3e} "
              f"coll/dev={coll_total:.3e} "
              f"terms(c/m/n)={r['compute_s']:.2e}/{r['memory_s']:.2e}/"
              f"{r['collective_s']:.2e} -> {r['bottleneck']}")
        if mem_rec.get("temp_size_in_bytes") is not None:
            print(f"       memory_analysis: temp={mem_rec['temp_size_in_bytes']/2**30:.2f}GiB "
                  f"(tpu-adj {mem_rec['temp_tpu_adjusted_bytes']/2**30:.2f}GiB) "
                  f"args={mem_rec['argument_size_in_bytes']/2**30:.2f}GiB "
                  f"out={mem_rec['output_size_in_bytes']/2**30:.2f}GiB "
                  f"(per device)")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="sweep all assigned arch x shape combos")
    ap.add_argument("--opt", action="append", default=[],
                    help="enable a §Perf optimization variant (kv_pad, ...)")
    ap.add_argument("--expert-parallel", action="store_true")
    ap.add_argument("--out", default=None, help="JSON output path (append)")
    args = ap.parse_args()

    assigned = [a for a in list_archs() if not a.startswith("paper-")]
    combos = ([(a, s) for a in assigned for s in INPUT_SHAPES]
              if args.all else [(args.arch, args.shape)])
    results = []
    for arch, shape in combos:
        try:
            rec = dryrun_one(arch, shape, args.multi_pod,
                             opts=frozenset(args.opt),
                             expert_parallel=args.expert_parallel)
        except Exception as e:  # a failure here is a bug in the system
            rec = {"arch": arch, "shape": shape, "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()}
            print(f"[FAIL] {arch} x {shape}: {rec['error']}")
        results.append(rec)
        if args.out:
            existing = []
            if os.path.exists(args.out):
                with open(args.out) as f:
                    existing = json.load(f)
            keep = [r for r in existing
                    if not (r["arch"] == rec["arch"]
                            and r["shape"] == rec["shape"]
                            and r.get("mesh") == rec.get("mesh"))]
            with open(args.out, "w") as f:
                json.dump(keep + [rec], f, indent=1)
    bad = [r for r in results if r["status"] == "error"]
    print(f"\n{len(results) - len(bad)}/{len(results)} combos OK")
    sys.exit(1 if bad else 0)


if __name__ == "__main__":
    main()
