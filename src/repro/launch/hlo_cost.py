"""HLO cost analysis with while-loop trip-count accounting.

``compiled.cost_analysis()`` visits every while body ONCE (verified in this
container: a 5-iteration and a 10-iteration scan of the same matmul report
identical FLOPs).  Our models are scan-heavy (layer scan, microbatch
accumulation, flash-attention tiles), and the FSDP weight all-gathers live
*inside* the layer scan — so both FLOPs and collective bytes would be
undercounted by 1-3 orders of magnitude.  This module parses the optimized
HLO text, extracts loop trip counts from the loop-condition comparison
against a constant, and multiplies costs through nested loops/fusions/calls.

Cost model (per device, since SPMD modules are per-device):
  * FLOPs:   2 * prod(result dims) * contraction_size for every dot;
  * bytes:   operand + result bytes of every *top-level* (post-fusion) op —
             i.e. each fusion reads its inputs and writes its outputs once,
             the standard post-fusion HBM-traffic approximation;
  * collectives: result bytes per op, bucketed by collective kind.
All three multiplied by enclosing loop trip counts.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1}
_SHAPE_RE = re.compile(
    r"(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|pred|f8e4m3fn|f8e5m2"
    r"|s4|u4)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


class Computation:
    def __init__(self, name: str):
        self.name = name
        self.lines: List[str] = []
        self.shapes: Dict[str, str] = {}     # %op -> result type string


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    header = re.compile(r"^(?:ENTRY\s+)?(%?[\w.\-]+)\s*(?:\(.*\))?\s*->.*{\s*$")
    for raw in hlo.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            m = header.match(s)
            if m and "{" in s:
                cur = Computation(m.group(1).lstrip("%"))
            continue
        if s == "}" or s.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        cur.lines.append(s)
        om = _OP_RE.match(s)
        if om:
            cur.shapes[om.group(1)] = om.group(2)
    return comps


def _attr(line: str, key: str) -> Optional[str]:
    m = re.search(key + r"=([\w.\-%]+)", line)
    return m.group(1) if m else None


def _attr_list(line: str, key: str) -> Optional[List[int]]:
    m = re.search(key + r"=\{([0-9,]*)\}", line)
    if not m:
        return None
    return [int(x) for x in m.group(1).split(",")] if m.group(1) else []


def _operands(rest_of_line: str) -> List[str]:
    """Operand names from the text after the opening paren."""
    depth = 1
    out = []
    buf = []
    for ch in rest_of_line:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        buf.append(ch)
    args = "".join(buf)
    for m in re.finditer(r"%[\w.\-]+", args):
        out.append(m.group(0))
    return out


def _trip_count(cond: Computation,
                comps: Dict[str, "Computation"]) -> int:
    """Extract the loop bound from the condition's comparison against a
    constant.  The compare may be direct or wrapped in a kLoop fusion
    (``ROOT %c = pred[] fusion(%iv, %const), calls=%wrapped_compare``)."""
    consts: Dict[str, int] = {}
    for line in cond.lines:
        m = re.match(r"(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*s\d+\[\]\s*"
                     r"constant\((-?\d+)\)", line)
        if m:
            consts[m.group(1)] = int(m.group(2))

    def _direction_of(comp: Computation) -> Optional[str]:
        for ln in comp.lines:
            if " compare(" in ln:
                return _attr(ln, "direction")
        return None

    for line in cond.lines:
        direction = None
        ops: List[str] = []
        if " compare(" in line:
            direction = _attr(line, "direction")
            ops = _operands(line.split("compare(", 1)[1])
        elif " fusion(" in line:
            callee = _attr(line, "calls")
            if callee and callee.lstrip("%") in comps:
                direction = _direction_of(comps[callee.lstrip("%")])
                if direction:
                    ops = _operands(line.split("fusion(", 1)[1])
        if not direction:
            continue
        vals = [consts.get(o) for o in ops]
        bound = next((v for v in vals if v is not None), None)
        if bound is None:
            continue
        if direction in ("LT", "GT"):
            return max(bound, 1)
        if direction in ("LE", "GE"):
            return max(bound + 1, 1)
    return 1


class HLOCost:
    def __init__(self, hlo: str):
        self.comps = parse_computations(hlo)
        self._memo: Dict[str, Dict[str, float]] = {}
        self._sliced_params: Dict[str, Dict[int, int]] = {}
        self._inplace_roots: Dict[str, int] = {}
        self.unknown_trip_counts = 0

    def _dus_update_bytes(self, comp_name: str) -> int:
        """Total update-operand bytes of dynamic-update-slice ops in a
        fused computation (0 if none)."""
        comp_name = comp_name.lstrip("%")
        comp = self.comps.get(comp_name)
        if comp is None:
            return 0
        total = 0
        for line in comp.lines:
            om = _OP_RE.match(line)
            if not om or not om.group(3).startswith("dynamic-update-slice"):
                continue
            ops = _operands(om.group(4))
            if len(ops) > 1:
                total += _type_bytes(comp.shapes.get(ops[1], ""))
        return total

    def _param_slice_sizes(self, comp_name: str) -> Dict[int, int]:
        """For a fused computation: parameters consumed exclusively through
        dynamic-slice read only the slice from HBM, not the full operand —
        critical for scan-over-layers bodies, where every iteration touches
        a [1, ...] slice of the [L, ...] stacked weights.  Parameters used
        only as the *buffer* of a dynamic-update-slice are in-place (the
        donated KV-cache write): charge the update size, not the buffer.
        Returns {param_index: bytes actually read}."""
        comp_name = comp_name.lstrip("%")
        if comp_name in self._sliced_params:
            return self._sliced_params[comp_name]
        out: Dict[int, int] = {}
        comp = self.comps.get(comp_name)
        if comp is not None:
            pname_to_idx: Dict[str, int] = {}
            for line in comp.lines:
                m = re.match(r"(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*.*?"
                             r"parameter\((\d+)\)", line)
                if m:
                    pname_to_idx[m.group(1)] = int(m.group(2))
            uses: Dict[str, List[Tuple[str, int, int, List[str]]]] = \
                {p: [] for p in pname_to_idx}
            for line in comp.lines:
                om = _OP_RE.match(line)
                if not om:
                    continue
                _, rtype, opcode, rest = om.groups()
                ops = _operands(rest)
                for pos, o in enumerate(ops):
                    if o in uses:
                        uses[o].append((opcode, _type_bytes(rtype), pos, ops))
            for pname, ulist in uses.items():
                if not ulist:
                    continue
                if all(op.startswith("dynamic-slice")
                       and not op.startswith("dynamic-update")
                       for op, _, _, _ in ulist):
                    out[pname_to_idx[pname]] = sum(b for _, b, _, _ in ulist)
                elif all(op.startswith("dynamic-update-slice") and pos == 0
                         for op, _, pos, _ in ulist):
                    # in-place buffer: read only the updated region
                    upd = 0
                    for op, _, _, ops in ulist:
                        if len(ops) > 1:
                            upd += _type_bytes(
                                comp.shapes.get(ops[1], "")) or 0
                    out[pname_to_idx[pname]] = upd
                    self._inplace_roots.setdefault(comp_name, 0)
                    self._inplace_roots[comp_name] += upd
        self._sliced_params[comp_name] = out
        return out

    def _zero(self) -> Dict[str, float]:
        d = {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0}
        for k in _COLL_KINDS:
            d[k] = 0.0
        return d

    def cost(self, comp_name: str) -> Dict[str, float]:
        comp_name = comp_name.lstrip("%")
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps.get(comp_name)
        total = self._zero()
        if comp is None:
            self._memo[comp_name] = total
            return total
        self._memo[comp_name] = total  # break cycles
        for line in comp.lines:
            om = _OP_RE.match(line)
            if not om:
                continue
            name, rtype, opcode, rest = om.groups()
            base = opcode.rstrip("0123456789.").rstrip("-")
            rbytes = _type_bytes(rtype)
            # ---- nested control flow / fusions -------------------------
            if opcode == "while":
                body = _attr(line, "body")
                cond = _attr(line, "condition")
                trips = 1
                if cond and cond.lstrip("%") in self.comps:
                    trips = _trip_count(self.comps[cond.lstrip("%")],
                                        self.comps)
                    if trips == 1:
                        self.unknown_trip_counts += 1
                sub = self.cost(body) if body else self._zero()
                for k in total:
                    total[k] += sub[k] * trips
                continue
            if opcode in ("fusion", "call", "async-start"):
                callee = _attr(line, "calls") or _attr(line, "to")
                sliced: Dict[int, int] = {}
                wbytes = rbytes
                inplace_param: Optional[int] = None
                if callee:
                    sub = self.cost(callee)
                    for k in total:
                        if k == "bytes" and opcode == "fusion":
                            # fusion internals are VMEM/register traffic;
                            # only boundary bytes touch HBM
                            continue
                        total[k] += sub[k]
                    sliced = self._param_slice_sizes(callee)
                    # in-place update heuristic: fusion result has the same
                    # shape as one of its operands AND the callee contains a
                    # dynamic-update-slice -> the buffer aliases the output
                    # (donated KV-cache / stash writes); traffic = update.
                    upd_bytes = self._dus_update_bytes(callee)
                    if upd_bytes:
                        rsd = _shape_dims(rtype)
                        for i, o in enumerate(_operands(rest)):
                            osd = _shape_dims(comp.shapes.get(o, ""))
                            # element-count match (dtype may differ through
                            # CPU bf16<->f32 legalization converts)
                            if rsd and osd and rsd[1] == osd[1]:
                                inplace_param = i
                                wbytes = min(rbytes, 2 * upd_bytes)
                                break
                    # in-place stash/cache writes: a fusion doing
                    # dynamic-update-slice on a param buffer writes only
                    # the update region (the buffer aliases the output)
                    cn = callee.lstrip("%")
                    if cn in self._inplace_roots:
                        wbytes = min(rbytes,
                                     max(self._inplace_roots[cn], 1))
                    else:
                        ccomp = self.comps.get(cn)
                        if ccomp is not None:
                            for ln in ccomp.lines:
                                if ln.startswith("ROOT") and \
                                        "dynamic-update-slice(" in ln:
                                    om2 = _OP_RE.match(ln)
                                    if om2:
                                        ops2 = _operands(om2.group(4))
                                        if len(ops2) > 1:
                                            wbytes = _type_bytes(
                                                ccomp.shapes.get(ops2[1], "")) \
                                                or rbytes
                                    break
                # fusion boundary traffic: result + operands, where operands
                # consumed only via dynamic-slice count at slice size and
                # the in-place buffer operand is free (aliased)
                opb = 0
                for i, o in enumerate(_operands(rest)):
                    if i == inplace_param:
                        continue
                    if i in sliced:
                        opb += sliced[i]
                    else:
                        opb += _type_bytes(comp.shapes.get(o, ""))
                total["bytes"] += wbytes + opb
                continue
            if opcode.startswith("dynamic-update-slice"):
                ops = _operands(rest)
                upd = (_type_bytes(comp.shapes.get(ops[1], ""))
                       if len(ops) > 1 else rbytes)
                total["bytes"] += 2 * upd          # read update + write region
                continue
            if opcode.startswith("dynamic-slice") or opcode == "gather":
                total["bytes"] += 2 * rbytes       # read slice + write result
                continue
            if opcode == "conditional":
                for key in ("true_computation", "false_computation",
                            "branch_computations"):
                    callee = _attr(line, key)
                    if callee:
                        sub = self.cost(callee)
                        for k in total:
                            total[k] += sub[k]
                continue
            # ---- collectives --------------------------------------------
            matched_coll = None
            for ck in _COLL_KINDS:
                if base == ck or base == ck + "-start":
                    matched_coll = ck
                    break
            if matched_coll:
                total[matched_coll] += rbytes
                total["collective_bytes"] += rbytes
                total["bytes"] += rbytes
                continue
            # ---- dots -----------------------------------------------------
            if opcode.startswith("dot"):
                ops = _operands(rest)
                lhs_type = comp.shapes.get(ops[0], "") if ops else ""
                cdims = _attr_list(line, "lhs_contracting_dims") or []
                sd = _shape_dims(lhs_type)
                contraction = 1
                if sd:
                    for ci in cdims:
                        if ci < len(sd[1]):
                            contraction *= sd[1][ci]
                rshape = _shape_dims(rtype)
                relems = 1
                if rshape:
                    for d in rshape[1]:
                        relems *= d
                total["flops"] += 2.0 * relems * contraction
                opb = sum(_type_bytes(comp.shapes.get(o, "")) for o in ops)
                total["bytes"] += rbytes + opb
                continue
            # ---- everything else: boundary traffic only -------------------
            if opcode in ("parameter", "constant", "tuple",
                          "get-tuple-element", "bitcast"):
                continue
            opb = sum(_type_bytes(comp.shapes.get(o, ""))
                      for o in _operands(rest))
            total["bytes"] += rbytes + opb
        self._memo[comp_name] = total
        return total

    def entry_cost(self) -> Dict[str, float]:
        entry = None
        for name in self.comps:
            if name.startswith("main") or ".main" in name or entry is None:
                if "main" in name:
                    entry = name
        if entry is None:
            entry = max(self.comps, key=lambda n: len(self.comps[n].lines))
        return self.cost(entry)


def analyze(hlo_text: str) -> Dict[str, float]:
    return HLOCost(hlo_text).entry_cost()
