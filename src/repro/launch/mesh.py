"""Production mesh factory.

Defined as a FUNCTION (never a module-level constant) so importing this
module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, and smoke tests / benches must keep seeing the single real device.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2x16x16 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    try:
        return jax.make_mesh(shape, axes)
    except ValueError:
        # jax.make_mesh requires len(devices) == prod(shape); when running
        # single-pod under the 512-device dry-run flag, take a prefix.
        n = int(np.prod(shape))
        devs = np.asarray(jax.devices()[:n]).reshape(shape)
        return Mesh(devs, axes)


def make_mesh_from_shape(shape: Tuple[int, ...],
                         axes: Tuple[str, ...]) -> Mesh:
    """Arbitrary mesh for tests (e.g. (1, 1) on the CPU container)."""
    try:
        return jax.make_mesh(shape, axes)
    except ValueError:
        n = int(np.prod(shape))
        devs = np.asarray(jax.devices()[:n]).reshape(shape)
        return Mesh(devs, axes)


def single_device_mesh(axes: Tuple[str, ...] = ("data", "model")) -> Mesh:
    return make_mesh_from_shape((1,) * len(axes), axes)


def serving_mesh(spec: str) -> Mesh:
    """Parse a ``DxM`` serving-mesh flag ("1x4", "2x2") into a
    (data, model) mesh for :class:`repro.serving.engine.ServingEngine`.
    Needs D*M visible devices — on CPU hosts that means
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` exported
    BEFORE the first jax import (the CI multidevice lane does this)."""
    try:
        d, m = (int(x) for x in spec.lower().split("x"))
    except ValueError:
        raise ValueError(
            f"mesh spec must be DxM (e.g. 1x4, 2x2), got {spec!r}"
        ) from None
    n = d * m
    if len(jax.devices()) < n:
        raise RuntimeError(
            f"mesh {spec} needs {n} devices but jax sees "
            f"{len(jax.devices())}; export XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n} before any jax "
            "import")
    return make_mesh_from_shape((d, m), ("data", "model"))
