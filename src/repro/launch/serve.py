"""Serving launcher.

Two modes:

* ``--demo``       — run the real CPU serving engine on a reduced pair of
                     the chosen architecture (what this container can do).
* ``--http``       — stand the OpenAI-compatible HTTP front door
                     (DESIGN.md §14) over that same reduced engine:
                     continuous-batching front-end + ``/v1/completions``
                     with SSE streaming.  ``--http-smoke`` instead runs
                     one streaming + one non-streaming completion
                     through a real socket and exits (the CI fast-lane
                     self-test).
* default          — lower + compile the production serve step for the
                     chosen arch/shape/mesh and report the plan (what a
                     TPU deployment would load; shares all code with
                     ``dryrun.py``).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-32b --shape decode_32k
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --demo
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --http --paged --pipelined
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="decode_32k",
                    choices=["prefill_32k", "decode_32k", "long_500k"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--demo", action="store_true",
                    help="run the CPU serving demo on the reduced config")
    ap.add_argument("--http", action="store_true",
                    help="serve the reduced engine over the OpenAI-"
                         "compatible HTTP layer (/v1/completions, SSE "
                         "streaming; DESIGN.md §14) until interrupted")
    ap.add_argument("--http-smoke", action="store_true",
                    help="start the HTTP server on an ephemeral port, "
                         "run one streaming + one non-streaming "
                         "completion, print the result JSON, exit")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 picks an ephemeral port")
    from repro.core.drafters import available_drafters
    from repro.core.policies import available_policies
    ap.add_argument("--policy", default="dsde",
                    choices=list(available_policies()))
    ap.add_argument("--drafter", default="model",
                    choices=list(available_drafters()),
                    help="proposer for the speculation rounds (DESIGN.md "
                         "§9): 'model' runs a second draft model; "
                         "'ngram'/'self' serve with zero draft params")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--paged", action="store_true",
                    help="serve from the block-paged KV pool at half the "
                         "dense engine's KV bytes (DESIGN.md §4)")
    ap.add_argument("--prefix-share", type=float, default=0.0,
                    metavar="S",
                    help="demo only: fraction in [0,1) of every prompt "
                         "that is a common head; >0 implies --paged and "
                         "turns on refcounted prefix caching "
                         "(DESIGN.md §12)")
    ap.add_argument("--kv-quant", default="none", choices=["none", "int8"],
                    help="demo only: paged-pool storage mode (DESIGN.md "
                         "§13); int8 stores K/V as per-block-scaled int8 "
                         "and fuses the dequant into the verify kv-sweep "
                         "— implies --paged")
    ap.add_argument("--slo-deadline", default=None, metavar="BASE,PER_TOK",
                    help="demo only: stamp every request with a "
                         "completion deadline of BASE + PER_TOK * "
                         "max_new_tokens seconds (DESIGN.md §15).  Pair "
                         "with --policy slo for deadline-aware "
                         "speculation; the run summary reports "
                         "slo_attained_frac / slo_goodput_tok_s and the "
                         "fitted latency-model coefficients either way")
    ap.add_argument("--pipelined", action="store_true",
                    help="plan/dispatch/collect pipelined schedule: "
                         "reconcile the host one round behind the device "
                         "(DESIGN.md §7); byte-identical greedy streams")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="demo only: serve under a (data, model) mesh, "
                         "e.g. 1x4 or 2x2 (DESIGN.md §5).  Needs DxM "
                         "visible devices; on CPU export XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N before "
                         "running.  Greedy streams stay byte-identical to "
                         "the single-device engine.")
    args = ap.parse_args()

    if args.demo or args.http or args.http_smoke:
        import numpy as np
        from repro.serving.request import Request

        eng, cfg = _build_demo_engine(args, ap)
        rng = np.random.RandomState(0)
        if args.http or args.http_smoke:
            _serve_http(args, eng, cfg, rng)
            return
        head = []
        if args.prefix_share > 0:
            # shared head sized so head/(head+tail) ~= share, rounded to
            # whole KV blocks so the full blocks are hash-addressable
            tail = 13                 # mean of the per-request draw below
            n = int(round(args.prefix_share
                          / (1 - args.prefix_share) * tail))
            n = max(n // 16 * 16, 16)
            head = rng.randint(0, cfg.vocab_size, size=n).tolist()
        deadline = None
        if args.slo_deadline:
            try:
                base_s, per_tok_s = map(float, args.slo_deadline.split(","))
            except ValueError:
                ap.error("--slo-deadline expects BASE,PER_TOK floats")
            deadline = base_s + per_tok_s * args.max_new
        reqs = [Request(i, prompt=head + rng.randint(
            0, cfg.vocab_size, size=rng.randint(6, 20)).tolist(),
            max_new_tokens=args.max_new, slo_deadline_s=deadline)
            for i in range(args.requests)]
        m = eng.run(reqs)
        print({k: round(v, 3) if isinstance(v, float) else v
               for k, v in m.items()})
        return

    # production path: delegate to the dry-run machinery (same step fns)
    from repro.launch.dryrun import dryrun_one
    rec = dryrun_one(args.arch, args.shape, args.multi_pod)
    sys.exit(0 if rec["status"] in ("ok", "skipped") else 1)


def _build_demo_engine(args, ap):
    """Reduced-config CPU engine shared by --demo and the HTTP modes."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.core.config import ServingConfig, SpecDecodeConfig
    from repro.core.drafters import build_drafter
    from repro.models.module import init_params
    from repro.models.transformer import model_specs
    from repro.serving.engine import ServingEngine

    cfg = get_config(args.arch).reduced()
    pt = init_params(model_specs(cfg), jax.random.PRNGKey(1),
                     jnp.float32)
    spec = SpecDecodeConfig(policy=args.policy, drafter=args.drafter)
    if build_drafter(spec, cfg, cfg).uses_draft_model():
        noise = init_params(model_specs(cfg), jax.random.PRNGKey(7),
                            jnp.float32)
        pd, cfg_d = jax.tree_util.tree_map(
            lambda a, b: a + 0.03 * b, pt, noise), cfg
    else:                       # model-free drafter: no second model
        pd, cfg_d = None, None
    caching = args.prefix_share > 0
    if not 0.0 <= args.prefix_share < 1.0:
        ap.error("--prefix-share must be in [0, 1)")
    serving = ServingConfig(max_batch_size=4, max_seq_len=256,
                            pipelined=args.pipelined)
    quant = args.kv_quant != "none"
    if args.paged or caching or quant:   # caching/quant need the pool
        serving = ServingConfig(
            max_batch_size=4, max_seq_len=256, paged_kv=True,
            kv_block_size=16, pipelined=args.pipelined,
            prefix_caching=caching, kv_quant=args.kv_quant,
            num_kv_blocks=4 * (256 // 16) // 2)   # 50% of dense bytes
    mesh = None
    if args.mesh:
        from repro.launch.mesh import serving_mesh
        mesh = serving_mesh(args.mesh)
    eng = ServingEngine(pt, cfg, pd, cfg_d, spec, serving, mesh=mesh)
    return eng, cfg


def _serve_http(args, eng, cfg, rng) -> None:
    """Stand the front-end + HTTP server over the demo engine; either
    serve until interrupted (--http) or self-test and exit
    (--http-smoke)."""
    import json
    import time

    from repro.serving.frontend import ServingFrontend
    from repro.serving.server import smoke_check, start_http_server_thread

    fe = ServingFrontend(eng).start()
    port, stop = start_http_server_thread(
        fe, host=args.host, port=args.port, model_name=args.arch,
        default_max_tokens=args.max_new)
    try:
        if args.http_smoke:
            prompt = rng.randint(0, cfg.vocab_size, size=8).tolist()
            out = smoke_check(args.host, port, prompt, max_tokens=8)
            out["port"] = port
            out["summary"] = {
                k: round(v, 4) if isinstance(v, float) else v
                for k, v in fe.summary().items()
                if k in ("requests_finished", "tokens_emitted", "rounds",
                         "ttft_mean_s", "queue_depth_peak")}
            print(json.dumps(out))
            return
        print(f"serving {args.arch} ({args.drafter} drafter, "
              f"{args.policy} policy) on "
              f"http://{args.host}:{port}/v1/completions", flush=True)
        while True:             # the server + driver live on daemons
            time.sleep(3600)
    except KeyboardInterrupt:
        pass
    finally:
        stop()
        fe.stop()


if __name__ == "__main__":
    main()
