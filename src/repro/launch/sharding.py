"""Per-(workload x mesh) sharding rules — MaxText-style logical axes.

The baseline layouts (DESIGN.md §5):

* train    — FSDP: ``embed`` over *data*, ``mlp/heads/vocab`` over *model*,
             batch over (pod, data), sequence-parallel residual stream
             (seq over *model* between blocks) to bound remat stashes.
* prefill  — serving TP: weights over *model* only (replicated over data),
             batch over (pod, data).
* decode   — serving TP; KV cache batch-sharded over (pod, data); KV heads
             over *model* (GSPMD uneven sharding reproduces vLLM's KV-head
             replication when kv_heads < 16).
* long decode (batch=1) — batch unshardable; state/ring caches replicated
  over data; heads over model.  (Sequence-parallel cache is a hillclimb
  variant, see EXPERIMENTS.md §Perf.)
* serve    — the ServingEngine's live data plane (:func:`serve_rules`):
  params TP over *model*, batch slots over *data*, KV heads over *model*
  under the uneven-head guard, block tables / control vectors
  replicated.  Consumed by the engine's mesh path (DESIGN.md §5), not
  just the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.config import InputShape, ModelConfig, ShardingConfig
from repro.models import cache as cache_lib
from repro.models.module import param_shardings
from repro.models.transformer import model_specs

PyTree = Any


def canonical_spec(*parts) -> P:
    """THE PartitionSpec constructor (speclint JX003): trims trailing
    ``None`` dims so equal layouts are structurally equal.

    Jit signatures compare PartitionSpecs *structurally* —
    ``P('data', None)`` and ``P('data')`` describe the same sharding but
    hash and compare differently, so a program keyed on one and re-fed
    the other silently forks the compiled-program cache (PR 5's serving
    round recompiled every round until its no-recompile guard tripped).
    Canonical form makes that hazard unrepresentable; every spec literal
    in the tree must be built here (trailing-``None`` literals anywhere
    else are JX003 findings)."""
    out = list(parts)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def _batch_axes(mesh: Mesh, global_batch: int) -> Tuple[str, ...]:
    """Largest prefix of (pod, data) whose product divides the batch."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    chosen: list = []
    prod = 1
    for a in axes:
        if global_batch % (prod * sizes[a]) == 0:
            chosen.append(a)
            prod *= sizes[a]
    return tuple(chosen)


def make_rules(mesh: Mesh, shape: InputShape, *,
               expert_parallel: bool = False,
               cache_seq_axis: Optional[str] = "model") -> ShardingConfig:
    train = shape.kind == "train"
    return ShardingConfig(
        batch=_batch_axes(mesh, shape.global_batch),
        heads="model",
        mlp="model",
        vocab="model",
        embed="data" if train and "data" in mesh.axis_names else None,
        # KV caches are sequence-sharded: kv_heads rarely divide the model
        # axis, and the cache dominates decode/prefill memory (DESIGN.md §5)
        cache_seq=cache_seq_axis if shape.kind in ("decode", "prefill")
        else None,
        experts="model" if expert_parallel else None,
        seq="model" if train else None,
    )


# ---------------------------------------------------------------------------
# Cache shardings
# ---------------------------------------------------------------------------

_CACHE_AXES = {
    # leaf name -> logical axes per dim
    "length": ("batch",),
    "kv_pos": ("batch", "cache_seq"),
    "k": ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
    "v": ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
    "cross_k": ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
    "cross_v": ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
    "enc_valid": ("batch", "cache_seq"),
    "ssd": ("layers", "batch", "heads", "head_dim", "state"),
    "conv": ("layers", "batch", "conv", "mlp"),
    "lru": ("layers", "batch", "mlp"),
}


def cache_shardings(cache_tree: PyTree, mesh: Mesh,
                    rules: ShardingConfig) -> PyTree:
    from repro.models.module import logical_to_pspec
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(name: str, leaf) -> NamedSharding:
        axes = _CACHE_AXES[name]
        pspec = logical_to_pspec(axes, rules)
        parts = list(tuple(pspec) + (None,) * (len(leaf.shape) - len(pspec)))
        fixed = []
        used: set = set()
        for dim, part in zip(leaf.shape, parts):
            if part is None:
                fixed.append(None)
                continue
            names = part if isinstance(part, tuple) else (part,)
            size = 1
            for nm in names:
                size *= axis_sizes[nm]
            # each mesh axis may appear at most once per spec (e.g. MHA
            # caches where kv_heads and cache_seq both map to 'model')
            if dim % size != 0 or any(nm in used for nm in names):
                fixed.append(None)
                continue
            used.update(names)
            fixed.append(part)
        return NamedSharding(mesh, canonical_spec(*fixed))

    return {k: one(k, v) for k, v in cache_tree.items()}


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def batch_sharding(mesh: Mesh, rules: ShardingConfig,
                   ndim: int) -> NamedSharding:
    spec = [tuple(rules.batch) if rules.batch else None] + [None] * (ndim - 1)
    return NamedSharding(mesh, canonical_spec(*spec))


def activation_sharding(mesh: Mesh, rules: ShardingConfig) -> Optional[NamedSharding]:
    """[B, S, d] residual-stream constraint used in train mode."""
    if rules.seq is None:
        return None
    return NamedSharding(
        mesh, canonical_spec(tuple(rules.batch) if rules.batch else None,
                             rules.seq, None))


def attn_head_sharding(mesh: Mesh, rules: ShardingConfig):
    """([B, T, H, D] NamedSharding, head-axis size) for the TP constraint
    pinned on q/k/v inside the attention sublayer."""
    if rules.heads is None:
        return None
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return (NamedSharding(
        mesh, canonical_spec(tuple(rules.batch) if rules.batch else None,
                             None, rules.heads, None)),
        sizes[rules.heads])


# ---------------------------------------------------------------------------
# Serving mesh (DESIGN.md §5): the ServingEngine's live data plane
# ---------------------------------------------------------------------------

def serve_rules(mesh: Mesh, global_batch: int) -> ShardingConfig:
    """The ``serve`` rule set: tensor-parallel params over *model*
    (replicated over data), batch slots over *data* (when the batch
    divides), KV heads over *model* under :func:`kv_head_axis`'s uneven
    guard, and ``cache_seq`` unsharded — the paged pool's block axis
    must stay whole because block tables address ANY pool block."""
    return ShardingConfig(
        batch=_batch_axes(mesh, global_batch),
        heads="model", mlp="model", vocab="model",
        embed=None, cache_seq=None, experts=None, seq=None)


def _axes_size(mesh: Mesh, names) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    names = names if isinstance(names, tuple) else (names,)
    return int(np.prod([sizes[n] for n in names]))


def kv_head_axis(n_kv_heads: int, mesh: Mesh,
                 rules: ShardingConfig) -> Optional[str]:
    """Uneven-KV-head guard: GQA miniatures carry 1–2 (padded) KV heads,
    which rarely divide the model axis, and jit ``in_shardings`` demand
    even tiling — so such caches REPLICATE their head dim (vLLM's
    KV-head replication) instead of sharding it."""
    if rules.heads is None or rules.heads not in mesh.axis_names:
        return None
    return rules.heads if n_kv_heads % _axes_size(mesh, rules.heads) == 0 \
        else None


def serve_cache_shardings(cache: PyTree, mesh: Mesh,
                          rules: ShardingConfig) -> PyTree:
    """NamedSharding per leaf of a *serving* cache pytree — dense rows,
    paged pools, per-row prefill groups, or a drafter's token buffer,
    keyed by leaf name + shape.  Layout contract (DESIGN.md §5):

    * KV buffers: head dim over *model* (uneven counts replicate);
      dense rows additionally shard batch over *data*; paged POOLS keep
      the block axis whole — any sequence's table may address any
      block, so sharding blocks over data would turn every gather into
      cross-device traffic.
    * recurrent rows (ssd/lru/conv) and the ngram token history: batch
      over *data*.
    * every int32 control leaf (length, kv_pos maps, block tables,
      enc_valid): replicated — the host rewrites those rows piecemeal
      each round and every shard needs the full table to address the
      shared pool.
    """
    paged = isinstance(cache, dict) and "block_table" in cache
    data = tuple(rules.batch) if rules.batch else None

    def bp(dim: int):
        if data is None or dim % _axes_size(mesh, data) != 0:
            return None
        return data

    def one(name: str, leaf) -> NamedSharding:
        s = leaf.shape
        if name in ("k", "v", "cross_k", "cross_v"):
            kvp = kv_head_axis(s[3], mesh, rules)
            if paged:            # pool [L, n_blocks, bs, KV, D]
                return NamedSharding(
                    mesh, canonical_spec(None, None, None, kvp))
            return NamedSharding(
                mesh, canonical_spec(None, bp(s[1]), None, kvp))
        if name in ("k_scale", "v_scale"):
            # int8 pool scales [L, n_blocks, bs, KV]: KV heads follow
            # their value pool's model-axis split, block axis whole
            kvp = kv_head_axis(s[3], mesh, rules)
            return NamedSharding(
                mesh, canonical_spec(None, None, None, kvp))
        if name in ("ssd", "lru", "conv"):       # [L, B, ...] per-slot rows
            return NamedSharding(mesh, canonical_spec(None, bp(s[1])))
        if name == "tokens":                     # ngram history [B, H]
            return NamedSharding(mesh, canonical_spec(bp(s[0])))
        return NamedSharding(mesh, P())
    return {k: one(k, v) for k, v in cache.items()}


def round_state_shardings(state: PyTree, mesh: Mesh,
                          rules: ShardingConfig) -> PyTree:
    """RoundState-shaped pytree of NamedShardings — the serving round's
    jit ``in_shardings``/``out_shardings``.  Caches go through
    :func:`serve_cache_shardings`; every [B] control leaf (pending /
    sl_next / seed / round_idx / done / tokens_budget / eos_id), the
    base key, and the policy state replicate: they are tiny, the host
    rewrites them per admission, and replication keeps the bucket pick
    and the engine's eager per-slot updates free of cross-device
    layout churn."""
    rep = NamedSharding(mesh, P())

    def cache_sh(tree):
        if isinstance(tree, dict):
            return serve_cache_shardings(tree, mesh, rules)
        return jax.tree_util.tree_map(lambda _: rep, tree)

    return state._replace(
        target_cache=cache_sh(state.target_cache),
        draft_cache=cache_sh(state.draft_cache),
        policy_state=jax.tree_util.tree_map(lambda _: rep,
                                            state.policy_state),
        pending=rep, sl_next=rep, key=rep, seed=rep, round_idx=rep,
        done=rep, tokens_budget=rep, eos_id=rep)


@dataclasses.dataclass(frozen=True)
class ServeMeshPlan:
    """Hashable (mesh, rules) bundle the engine threads through the
    jitted serving entry points as a STATIC argument.  Prefill programs
    call :meth:`cache_constraints` on their fresh cache rows / pools so
    GSPMD pins the §5 layouts at the program boundary instead of
    round-tripping freshly written KV through replicated layouts.

    (Both fields are hashable — ``Mesh`` implements ``__hash__``,
    ``ShardingConfig`` is a frozen dataclass — so equal plans hit the
    same compiled program.)"""
    mesh: Mesh
    rules: ShardingConfig

    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    def cache_constraints(self, cache: PyTree) -> PyTree:
        return jax.lax.with_sharding_constraint(
            cache, serve_cache_shardings(cache, self.mesh, self.rules))


def moe_shardings(mesh: Mesh, rules: ShardingConfig):
    """Dispatch-buffer constraints for moe_apply: capacity dim over the
    batch axes, token dim likewise."""
    b = tuple(rules.batch) if rules.batch else None
    if b is None:
        return None
    return {"cap": NamedSharding(mesh, canonical_spec(None, b, None)),
            "tok": NamedSharding(mesh, canonical_spec(b, None))}
