"""Per-(workload x mesh) sharding rules — MaxText-style logical axes.

The baseline layouts (DESIGN.md §5):

* train    — FSDP: ``embed`` over *data*, ``mlp/heads/vocab`` over *model*,
             batch over (pod, data), sequence-parallel residual stream
             (seq over *model* between blocks) to bound remat stashes.
* prefill  — serving TP: weights over *model* only (replicated over data),
             batch over (pod, data).
* decode   — serving TP; KV cache batch-sharded over (pod, data); KV heads
             over *model* (GSPMD uneven sharding reproduces vLLM's KV-head
             replication when kv_heads < 16).
* long decode (batch=1) — batch unshardable; state/ring caches replicated
  over data; heads over model.  (Sequence-parallel cache is a hillclimb
  variant, see EXPERIMENTS.md §Perf.)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.config import InputShape, ModelConfig, ShardingConfig
from repro.models import cache as cache_lib
from repro.models.module import param_shardings
from repro.models.transformer import model_specs

PyTree = Any


def _batch_axes(mesh: Mesh, global_batch: int) -> Tuple[str, ...]:
    """Largest prefix of (pod, data) whose product divides the batch."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    chosen: list = []
    prod = 1
    for a in axes:
        if global_batch % (prod * sizes[a]) == 0:
            chosen.append(a)
            prod *= sizes[a]
    return tuple(chosen)


def make_rules(mesh: Mesh, shape: InputShape, *,
               expert_parallel: bool = False,
               cache_seq_axis: Optional[str] = "model") -> ShardingConfig:
    train = shape.kind == "train"
    return ShardingConfig(
        batch=_batch_axes(mesh, shape.global_batch),
        heads="model",
        mlp="model",
        vocab="model",
        embed="data" if train and "data" in mesh.axis_names else None,
        # KV caches are sequence-sharded: kv_heads rarely divide the model
        # axis, and the cache dominates decode/prefill memory (DESIGN.md §5)
        cache_seq=cache_seq_axis if shape.kind in ("decode", "prefill")
        else None,
        experts="model" if expert_parallel else None,
        seq="model" if train else None,
    )


# ---------------------------------------------------------------------------
# Cache shardings
# ---------------------------------------------------------------------------

_CACHE_AXES = {
    # leaf name -> logical axes per dim
    "length": ("batch",),
    "kv_pos": ("batch", "cache_seq"),
    "k": ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
    "v": ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
    "cross_k": ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
    "cross_v": ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
    "enc_valid": ("batch", "cache_seq"),
    "ssd": ("layers", "batch", "heads", "head_dim", "state"),
    "conv": ("layers", "batch", "conv", "mlp"),
    "lru": ("layers", "batch", "mlp"),
}


def cache_shardings(cache_tree: PyTree, mesh: Mesh,
                    rules: ShardingConfig) -> PyTree:
    from repro.models.module import logical_to_pspec
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(name: str, leaf) -> NamedSharding:
        axes = _CACHE_AXES[name]
        pspec = logical_to_pspec(axes, rules)
        parts = list(tuple(pspec) + (None,) * (len(leaf.shape) - len(pspec)))
        fixed = []
        used: set = set()
        for dim, part in zip(leaf.shape, parts):
            if part is None:
                fixed.append(None)
                continue
            names = part if isinstance(part, tuple) else (part,)
            size = 1
            for nm in names:
                size *= axis_sizes[nm]
            # each mesh axis may appear at most once per spec (e.g. MHA
            # caches where kv_heads and cache_seq both map to 'model')
            if dim % size != 0 or any(nm in used for nm in names):
                fixed.append(None)
                continue
            used.update(names)
            fixed.append(part)
        while fixed and fixed[-1] is None:
            fixed.pop()
        return NamedSharding(mesh, P(*fixed))

    return {k: one(k, v) for k, v in cache_tree.items()}


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def batch_sharding(mesh: Mesh, rules: ShardingConfig,
                   ndim: int) -> NamedSharding:
    spec = [tuple(rules.batch) if rules.batch else None] + [None] * (ndim - 1)
    return NamedSharding(mesh, P(*spec))


def activation_sharding(mesh: Mesh, rules: ShardingConfig) -> Optional[NamedSharding]:
    """[B, S, d] residual-stream constraint used in train mode."""
    if rules.seq is None:
        return None
    return NamedSharding(
        mesh, P(tuple(rules.batch) if rules.batch else None, rules.seq, None))


def attn_head_sharding(mesh: Mesh, rules: ShardingConfig):
    """([B, T, H, D] NamedSharding, head-axis size) for the TP constraint
    pinned on q/k/v inside the attention sublayer."""
    if rules.heads is None:
        return None
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return (NamedSharding(
        mesh, P(tuple(rules.batch) if rules.batch else None, None,
                rules.heads, None)), sizes[rules.heads])


def moe_shardings(mesh: Mesh, rules: ShardingConfig):
    """Dispatch-buffer constraints for moe_apply: capacity dim over the
    batch axes, token dim likewise."""
    b = tuple(rules.batch) if rules.batch else None
    if b is None:
        return None
    return {"cap": NamedSharding(mesh, P(None, b, None)),
            "tok": NamedSharding(mesh, P(b, None))}
