"""Step functions + ShapeDtypeStruct input specs for every
(architecture x input-shape) combination — consumed by the dry-run and by
the real launchers.

``input_specs(cfg, shape, mesh, rules)`` returns
``(step_fn, args, donate_argnums)`` where every arg is a weak-type-correct
``ShapeDtypeStruct`` carrying its ``NamedSharding`` — lowering allocates
nothing.

Modality carve-out (assignment): [vlm]/[audio] frontends are stubs —
prefill/train inputs are precomputed patch/frame embeddings of the right
shape, the transformer backbone is real.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.core.config import (InputShape, ModelConfig, OptimizerConfig,
                               ShardingConfig)
from repro.models import cache as cache_lib
from repro.models.module import abstract_params, param_shardings
from repro.models.transformer import forward, model_specs
from repro.launch.sharding import (activation_sharding, attn_head_sharding,
                                   batch_sharding, cache_shardings,
                                   canonical_spec, moe_shardings, replicated)
from repro.training.optimizer import AdamWState
from repro.training.train import train_step

PyTree = Any

VOCAB_PAD = 2048        # 16 model shards x 128 lanes
LONG_CONTEXT_WINDOW = 4096   # sliding-window variant for dense long_500k


def adapt_config(cfg: ModelConfig, shape: InputShape,
                 opts: frozenset = frozenset()) -> ModelConfig:
    """Shape-dependent config adaptation (DESIGN.md §4):
    dense/moe/vlm archs get a sliding-window attention variant for
    long_500k (beyond-paper extension making the shape tractable).
    ``opts`` selects §Perf hillclimb variants (e.g. "kv_pad")."""
    if (shape.name == "long_500k" and cfg.attention_window is None
            and cfg.family in ("dense", "moe", "vlm")):
        cfg = dataclasses.replace(cfg, attention_window=LONG_CONTEXT_WINDOW)
    if "head_pad" in opts and cfg.family != "ssm":
        h = cfg.num_heads
        if h % 16:
            cfg = dataclasses.replace(cfg, q_head_pad=-(-h // 16) * 16)
    if "kv_pad" in opts and cfg.family != "ssm":
        kv = cfg.num_kv_heads
        h = cfg.q_head_pad or cfg.num_heads
        if kv < 16 and 16 % kv == 0 and h % 16 == 0:
            cfg = dataclasses.replace(cfg, kv_head_pad=16)
    return cfg


def supported(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    if shape.name == "long_500k" and cfg.family == "audio":
        return False, ("enc-dec translation decoder: 524k-token decode is "
                       "out of distribution and the 500k encoder side is "
                       "excluded by the frontend-stub carve-out "
                       "(DESIGN.md §4)")
    return True, ""


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _enc_len(shape: InputShape) -> int:
    # audio: encoder frames = seq/4 (typical 4x conv downsampling)
    return max(shape.seq_len // 4, 8)


def make_step_and_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                        rules: ShardingConfig, *,
                        param_dtype=jnp.bfloat16,
                        opt_cfg: Optional[OptimizerConfig] = None,
                        opts: frozenset = frozenset()
                        ) -> Tuple[Callable, tuple, tuple]:
    """Returns (step_fn, abstract_args, donate_argnums)."""
    cfg = adapt_config(cfg, shape, opts)
    specs = model_specs(cfg, VOCAB_PAD)
    pshard = param_shardings(specs, mesh, rules)
    params = abstract_params(specs, param_dtype, pshard)
    bsh = batch_sharding(mesh, rules, 2)
    b, s = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        opt_cfg = opt_cfg or OptimizerConfig()
        mu = abstract_params(specs, jnp.float32, pshard)
        opt = AdamWState(step=_sds((), jnp.int32, replicated(mesh)),
                         mu=mu, nu=mu)
        act_sh = activation_sharding(mesh, rules)
        attn_sh = attn_head_sharding(mesh, rules)
        tokens = _sds((b, s), jnp.int32, bsh)
        labels = _sds((b, s), jnp.int32, bsh)
        # gradient accumulation: target ~2 sequences per chip per microbatch
        n_batch_shards = 1
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        for a in rules.batch:
            n_batch_shards *= sizes[a]
        per_chip = b // n_batch_shards
        mb = max(1, min(8, per_chip // 2))
        # §Perf knob: fewer microbatches => fewer FSDP weight re-gathers
        # (collective term) at the cost of larger activation stashes
        for o in opts:
            if o.startswith("mb"):
                mb = max(1, min(int(o[2:]), per_chip))

        def mb_sharding(ndim):
            spec = [None, tuple(rules.batch) if rules.batch else None]
            spec += [None] * (ndim - 2)
            return NamedSharding(mesh, canonical_spec(*spec))

        if cfg.family == "audio":
            el = _enc_len(shape)
            emb_sh = batch_sharding(mesh, rules, 3)
            enc = _sds((b, el, cfg.d_model), param_dtype, emb_sh)

            def step(p, o, t, l, e):
                return train_step(p, o, t, l, cfg=cfg, opt_cfg=opt_cfg,
                                  remat=True, encoder_embeds=e,
                                  act_sharding=act_sh, attn_sharding=attn_sh,
                                  microbatches=mb,
                                  microbatch_sharding=mb_sharding)
            return step, (params, opt, tokens, labels, enc), (0, 1)

        def step(p, o, t, l):
            return train_step(p, o, t, l, cfg=cfg, opt_cfg=opt_cfg,
                              remat=True, act_sharding=act_sh,
                              attn_sharding=attn_sh, microbatches=mb,
                              microbatch_sharding=mb_sharding)
        return step, (params, opt, tokens, labels), (0, 1)

    # prefill caches reserve lookahead slots (SL_max + bonus); keep the ring
    # length divisible by the mesh axes so cache_seq sharding applies
    max_len = s if shape.kind == "decode" else s + 16
    enc_len = _enc_len(shape) if cfg.family == "audio" else None
    cache_t = cache_lib.cache_struct(cfg, b, max_len, param_dtype,
                                     enc_len=enc_len, abstract=True)
    csh = cache_shardings(cache_t, mesh, rules)
    cache = {k: _sds(v.shape, v.dtype, csh[k]) for k, v in cache_t.items()}

    if shape.kind == "prefill":
        if cfg.family in ("vlm", "audio"):
            emb_sh = batch_sharding(mesh, rules, 3)
        if cfg.family == "audio":
            # encoder frames + decoder prompt prefill
            enc = _sds((b, enc_len, cfg.d_model), param_dtype, emb_sh)
            toks = _sds((b, s), jnp.int32, bsh)

            def step(p, c, e, t):
                from repro.models.transformer import (build_cross_cache,
                                                      encode)
                enc_out = encode(p, cfg, e)
                ck, cv = build_cross_cache(p, cfg, enc_out)
                c = dict(c)
                c["cross_k"], c["cross_v"] = ck, cv
                c["enc_valid"] = jnp.ones(e.shape[:2], bool)
                logits, c, _ = forward(p, cfg, t, cache=c, mode="prefill")
                c["length"] = jnp.full((t.shape[0],), t.shape[1], jnp.int32)
                return logits[:, -1], c
            return step, (params, cache, enc, toks), (1,)

        if cfg.family == "vlm":
            emb = _sds((b, s, cfg.d_model), param_dtype, emb_sh)

            def step(p, c, e):
                logits, c, _ = forward(p, cfg, None, embeds=e, cache=c,
                                       mode="prefill")
                c["length"] = jnp.full((e.shape[0],), e.shape[1], jnp.int32)
                return logits[:, -1], c
            return step, (params, cache, emb), (1,)

        toks = _sds((b, s), jnp.int32, bsh)
        moe_sh = moe_shardings(mesh, rules) if cfg.moe is not None else None

        def step(p, c, t):
            logits, c, _ = forward(p, cfg, t, cache=c, mode="prefill",
                                   moe_sharding=moe_sh)
            c["length"] = jnp.full((t.shape[0],), t.shape[1], jnp.int32)
            return logits[:, -1], c
        return step, (params, cache, toks), (1,)

    # ---- decode: serve_step — ONE new token against a seq_len cache -------
    # (--opt verify lowers the paper's ragged verification step instead:
    #  T = SL_max+1 = 11 tokens per sequence in one pass)
    t_len = 11 if "verify" in opts else 1
    toks = _sds((b, t_len), jnp.int32, bsh)

    def step(p, c, t):
        logits, c, _ = forward(p, cfg, t, cache=c, mode="decode")
        c["length"] = c["length"] + 1
        return logits[:, -1], c
    return step, (params, cache, toks), (1,)


def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) — §Roofline."""
    from repro.models.module import count_params
    cfg = adapt_config(cfg, shape)
    specs = model_specs(cfg, VOCAB_PAD)
    n_total = count_params(specs)
    if cfg.moe is not None:
        e, k = cfg.moe.num_experts, cfg.moe.top_k
        # expert params scale by k/e when active
        expert_params = (3 * cfg.d_model * cfg.moe.expert_d_ff
                         * e * cfg.num_layers)
        n_active = n_total - expert_params + expert_params * k / e
    else:
        n_active = n_total
    tokens = (shape.global_batch * shape.seq_len
              if shape.kind != "decode" else shape.global_batch * 1)
    # forward ~ 2N FLOPs/token; train (fwd + bwd) ~ 6N FLOPs/token
    per_token = 6.0 * n_active if shape.kind == "train" else 2.0 * n_active
    # attention score/PV FLOPs (not captured by 2N*D): 4 * h*hd * ctx per
    # token per attention layer; ctx = S/2 causal average (train/prefill)
    # or the full cache (decode); windowed attention caps ctx.
    if cfg.family != "ssm":
        h_hd = cfg.num_heads * cfg.resolved_head_dim
        n_attn_layers = cfg.num_layers
        if cfg.family == "hybrid":
            n_attn_layers = cfg.num_layers // (
                cfg.rglru.blocks_per_attention + 1)
        ctx = (shape.seq_len if shape.kind == "decode"
               else shape.seq_len / 2)
        if cfg.attention_window is not None:
            ctx = min(ctx, cfg.attention_window)
        elif cfg.family == "hybrid":
            ctx = min(ctx, cfg.rglru.local_attention_window)
        attn = 4.0 * h_hd * ctx * n_attn_layers
        if shape.kind == "train":
            attn *= 3
        per_token = per_token + attn
    return per_token * tokens
