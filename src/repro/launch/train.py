"""Training launcher.

* ``--demo``  — really train the reduced config on CPU for ``--steps``
                steps on the synthetic LM corpus (checkpointing included).
* default     — lower + compile the production train_4k step for the
                chosen arch on the production mesh (shares dryrun code).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --demo --steps 100
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-32b [--multi-pod]
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--demo", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    args = ap.parse_args()

    if args.demo:
        from repro.configs import get_config
        from repro.core.config import OptimizerConfig, TrainConfig
        from repro.training.checkpoint import save_checkpoint
        from repro.training.data import MarkovTaskCorpus, lm_batches
        from repro.training.train import train_loop

        cfg = get_config(args.arch).reduced()
        corpus = MarkovTaskCorpus(cfg.vocab_size, peakedness=2.0)
        stream = corpus.stream(200000)
        tc = TrainConfig(
            global_batch_size=16, seq_len=64,
            optimizer=OptimizerConfig(learning_rate=3e-3, warmup_steps=20,
                                      total_steps=args.steps, grad_clip=5.0),
            checkpoint_dir=args.ckpt_dir)
        params, m = train_loop(cfg, tc, lm_batches(stream, 16, 64),
                               num_steps=args.steps)
        f = save_checkpoint(args.ckpt_dir, args.steps, params)
        print(f"final: {m}  checkpoint: {f}")
        return

    from repro.launch.dryrun import dryrun_one
    rec = dryrun_one(args.arch, "train_4k", args.multi_pod)
    sys.exit(0 if rec["status"] in ("ok", "skipped") else 1)


if __name__ == "__main__":
    main()
