"""Decode-time caches: ring-buffer KV + recurrent state.

One cache pytree per model instance.  Common fields:

* ``length [B]``   — number of tokens whose KV/state is *committed*.
* ``kv_pos [B, W]`` — absolute sequence index stored in each ring slot
  (-1 = never written).  Validity of a slot for a query at position ``q`` is
  ``0 <= kv_pos <= q`` (and ``q - kv_pos < window`` for windowed layers).
  Rollback after speculative verification is therefore *free* for KV layers:
  resetting ``length`` masks the stale slots (see DESIGN.md §4).

The ring buffer (slot = pos % W) makes windowed caches O(window) instead of
O(seq): ``long_500k`` decode for SWA/hybrid archs holds a 2–4k ring, not a
524k buffer.  Correctness requires window >> SL_max so one speculation
round can never wrap past its own rollback horizon (asserted at build).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.models.ssm import ssm_dims

CacheT = Dict[str, Any]


# extra ring slots beyond the attention window: a T-token decode/verify
# call writes T new entries before the first query reads — without slack it
# would overwrite the oldest still-in-window keys (SL_max+1 = 11 < 16)
RING_SLACK = 16


def _kv_window(cfg: ModelConfig, max_len: int) -> int:
    if cfg.attention_window is not None:
        return min(max_len, cfg.attention_window + RING_SLACK)
    return max_len


def _local_window(cfg: ModelConfig, max_len: int) -> int:
    return min(max_len, cfg.rglru.local_attention_window + RING_SLACK)


def eff_kv_heads(cfg: ModelConfig) -> int:
    return cfg.kv_head_pad or cfg.num_kv_heads


def kv_buf_shape(cfg: ModelConfig, batch: int, window: int,
                 layers: int) -> Tuple[int, ...]:
    return (layers, batch, window, eff_kv_heads(cfg),
            cfg.resolved_head_dim)


def cache_struct(cfg: ModelConfig, batch: int, max_len: int,
                 dtype=jnp.bfloat16, enc_len: Optional[int] = None,
                 abstract: bool = False) -> CacheT:
    """Build the cache pytree (zeros) or its ShapeDtypeStruct skeleton."""

    def mk(shape, dt):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dt)
        return jnp.zeros(shape, dt)

    def mk_pos(shape):
        if abstract:
            return jax.ShapeDtypeStruct(shape, jnp.int32)
        return jnp.full(shape, -1, jnp.int32)

    c: CacheT = {"length": mk((batch,), jnp.int32)}
    fam = cfg.family

    if fam in ("dense", "moe", "vlm"):
        w = _kv_window(cfg, max_len)
        c["k"] = mk(kv_buf_shape(cfg, batch, w, cfg.num_layers), dtype)
        c["v"] = mk(kv_buf_shape(cfg, batch, w, cfg.num_layers), dtype)
        c["kv_pos"] = mk_pos((batch, w))
    elif fam == "ssm":
        di, h, dc, n = ssm_dims(cfg)
        p = cfg.ssm.head_dim
        c["ssd"] = mk((cfg.num_layers, batch, h, p, n), jnp.float32)
        c["conv"] = mk((cfg.num_layers, batch, cfg.ssm.conv_width - 1, dc), dtype)
    elif fam == "hybrid":
        w = _local_window(cfg, max_len)
        n_attn = sum(1 for i in range(cfg.num_layers)
                     if hybrid_layer_is_attention(cfg, i))
        n_rec = cfg.num_layers - n_attn
        c["k"] = mk(kv_buf_shape(cfg, batch, w, n_attn), dtype)
        c["v"] = mk(kv_buf_shape(cfg, batch, w, n_attn), dtype)
        c["kv_pos"] = mk_pos((batch, w))
        c["lru"] = mk((n_rec, batch, cfg.rglru.lru_width), jnp.float32)
        c["conv"] = mk((n_rec, batch, cfg.rglru.conv_width - 1,
                        cfg.rglru.lru_width), dtype)
    elif fam == "audio":
        w = max_len
        c["k"] = mk(kv_buf_shape(cfg, batch, w, cfg.num_layers), dtype)
        c["v"] = mk(kv_buf_shape(cfg, batch, w, cfg.num_layers), dtype)
        c["kv_pos"] = mk_pos((batch, w))
        se = enc_len if enc_len is not None else 1
        c["cross_k"] = mk(kv_buf_shape(cfg, batch, se, cfg.num_layers), dtype)
        c["cross_v"] = mk(kv_buf_shape(cfg, batch, se, cfg.num_layers), dtype)
        c["enc_valid"] = mk((batch, se), jnp.bool_)
    else:
        raise ValueError(f"unknown family {fam}")
    return c


def hybrid_layer_is_attention(cfg: ModelConfig, i: int) -> bool:
    """RecurrentGemma 1:2 pattern — (rec, rec, attn) repeating."""
    return i % (cfg.rglru.blocks_per_attention + 1) == cfg.rglru.blocks_per_attention


def cache_window(cache: CacheT) -> int:
    return cache["kv_pos"].shape[-1]


def write_kv(k_buf: jax.Array, v_buf: jax.Array, k_new: jax.Array,
             v_new: jax.Array, positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Scatter [B,T,...] new KV into the [B,W,...] ring at pos % W."""
    w = k_buf.shape[1]
    b = k_buf.shape[0]
    t = k_new.shape[1]
    if t >= w:
        # keep only the last w tokens (prefill longer than the window)
        k_new, v_new = k_new[:, -w:], v_new[:, -w:]
        positions = positions[:, -w:]
        t = w
    slots = positions % w
    bi = jnp.arange(b)[:, None]
    k_buf = k_buf.at[bi, slots].set(k_new.astype(k_buf.dtype))
    v_buf = v_buf.at[bi, slots].set(v_new.astype(v_buf.dtype))
    return k_buf, v_buf


def write_pos(kv_pos: jax.Array, positions: jax.Array,
              valid: Optional[jax.Array] = None) -> jax.Array:
    """Update the shared slot-position map (once per model call)."""
    w = kv_pos.shape[1]
    b = kv_pos.shape[0]
    if positions.shape[1] >= w:
        positions = positions[:, -w:]
        valid = valid[:, -w:] if valid is not None else None
    slots = positions % w
    bi = jnp.arange(b)[:, None]
    newpos = positions if valid is None else jnp.where(valid, positions, -1)
    return kv_pos.at[bi, slots].set(newpos)


def commit_length(cache: CacheT, new_length: jax.Array) -> CacheT:
    out = dict(cache)
    out["length"] = new_length.astype(jnp.int32)
    return out
