"""Decode-time caches: ring-buffer / block-paged KV + recurrent state.

One cache pytree per model instance.  Common fields:

* ``length [B]``   — number of tokens whose KV/state is *committed*.
* ``kv_pos`` — absolute sequence index stored in each KV slot
  (-1 = never written).  Validity of a slot for a query at position ``q`` is
  ``0 <= kv_pos <= q`` (and ``q - kv_pos < window`` for windowed layers).
  Rollback after speculative verification is therefore *free* for KV layers:
  resetting ``length`` masks the stale slots (see DESIGN.md §4).

Two physical KV layouts share those semantics:

* **dense ring** (``kv_pos [B, W]``) — one W-wide row per batch slot,
  slot = pos % W.  The ring makes windowed caches O(window) instead of
  O(seq): ``long_500k`` decode for SWA/hybrid archs holds a 2–4k ring,
  not a 524k buffer.  Correctness requires window >> SL_max so one
  speculation round can never wrap past its own rollback horizon.
* **block-paged pool** (``paged_cache_struct``) — a shared pool
  ``[L, n_blocks, block_size, KV, D]`` plus per-sequence block tables
  ``[B, max_blocks]`` (-1 = unallocated) and pool-level
  ``kv_pos [n_blocks, block_size]``.  Position ``p`` of sequence ``b``
  lives at physical slot ``block_table[b, p // bs] * bs + p % bs`` — a
  *stable* mapping while the blocks stay allocated, so the dense
  overwrite-or-mask rollback argument carries over unchanged and commit
  stays pure length arithmetic.  Writes through an unallocated table
  entry are dropped; the serving-side allocator grows tables on demand
  and resets ``kv_pos`` of a block to -1 on (re)allocation so a block
  recycled from another sequence can never leak stale-but-causally-valid
  entries.  SSM / RG-LRU recurrent state is O(1) per sequence and stays
  dense per-slot in both layouts.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.models.ssm import ssm_dims

CacheT = Dict[str, Any]


# extra ring slots beyond the attention window: a T-token decode/verify
# call writes T new entries before the first query reads — without slack it
# would overwrite the oldest still-in-window keys (SL_max+1 = 11 < 16)
RING_SLACK = 16


def _kv_window(cfg: ModelConfig, max_len: int) -> int:
    if cfg.attention_window is not None:
        return min(max_len, cfg.attention_window + RING_SLACK)
    return max_len


def _local_window(cfg: ModelConfig, max_len: int) -> int:
    return min(max_len, cfg.rglru.local_attention_window + RING_SLACK)


def eff_kv_heads(cfg: ModelConfig) -> int:
    return cfg.kv_head_pad or cfg.num_kv_heads


def kv_buf_shape(cfg: ModelConfig, batch: int, window: int,
                 layers: int) -> Tuple[int, ...]:
    return (layers, batch, window, eff_kv_heads(cfg),
            cfg.resolved_head_dim)


def cache_struct(cfg: ModelConfig, batch: int, max_len: int,
                 dtype=jnp.bfloat16, enc_len: Optional[int] = None,
                 abstract: bool = False) -> CacheT:
    """Build the cache pytree (zeros) or its ShapeDtypeStruct skeleton."""

    def mk(shape, dt):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dt)
        return jnp.zeros(shape, dt)

    def mk_pos(shape):
        if abstract:
            return jax.ShapeDtypeStruct(shape, jnp.int32)
        return jnp.full(shape, -1, jnp.int32)

    c: CacheT = {"length": mk((batch,), jnp.int32)}
    fam = cfg.family

    if fam in ("dense", "moe", "vlm"):
        w = _kv_window(cfg, max_len)
        c["k"] = mk(kv_buf_shape(cfg, batch, w, cfg.num_layers), dtype)
        c["v"] = mk(kv_buf_shape(cfg, batch, w, cfg.num_layers), dtype)
        c["kv_pos"] = mk_pos((batch, w))
    elif fam == "ssm":
        di, h, dc, n = ssm_dims(cfg)
        p = cfg.ssm.head_dim
        c["ssd"] = mk((cfg.num_layers, batch, h, p, n), jnp.float32)
        c["conv"] = mk((cfg.num_layers, batch, cfg.ssm.conv_width - 1, dc), dtype)
    elif fam == "hybrid":
        w = _local_window(cfg, max_len)
        n_attn, n_rec = hybrid_layer_counts(cfg)
        c["k"] = mk(kv_buf_shape(cfg, batch, w, n_attn), dtype)
        c["v"] = mk(kv_buf_shape(cfg, batch, w, n_attn), dtype)
        c["kv_pos"] = mk_pos((batch, w))
        c["lru"] = mk((n_rec, batch, cfg.rglru.lru_width), jnp.float32)
        c["conv"] = mk((n_rec, batch, cfg.rglru.conv_width - 1,
                        cfg.rglru.lru_width), dtype)
    elif fam == "audio":
        w = max_len
        c["k"] = mk(kv_buf_shape(cfg, batch, w, cfg.num_layers), dtype)
        c["v"] = mk(kv_buf_shape(cfg, batch, w, cfg.num_layers), dtype)
        c["kv_pos"] = mk_pos((batch, w))
        se = enc_len if enc_len is not None else 1
        c["cross_k"] = mk(kv_buf_shape(cfg, batch, se, cfg.num_layers), dtype)
        c["cross_v"] = mk(kv_buf_shape(cfg, batch, se, cfg.num_layers), dtype)
        c["enc_valid"] = mk((batch, se), jnp.bool_)
    else:
        raise ValueError(f"unknown family {fam}")
    return c


def hybrid_layer_is_attention(cfg: ModelConfig, i: int) -> bool:
    """RecurrentGemma 1:2 pattern — (rec, rec, attn) repeating."""
    return i % (cfg.rglru.blocks_per_attention + 1) == cfg.rglru.blocks_per_attention


def hybrid_layer_counts(cfg: ModelConfig) -> Tuple[int, int]:
    """(n_attention, n_recurrent) layers of a hybrid stack — the single
    source for every cache builder's layer-axis sizes."""
    n_attn = sum(1 for i in range(cfg.num_layers)
                 if hybrid_layer_is_attention(cfg, i))
    return n_attn, cfg.num_layers - n_attn


def cache_window(cache: CacheT) -> int:
    return cache["kv_pos"].shape[-1]


# ---------------------------------------------------------------------------
# int8 quantized KV storage (kv_quant="int8", DESIGN.md §4 / §13)
# ---------------------------------------------------------------------------

KV_QUANT_MODES = ("none", "int8")
INT8_QMAX = 127.0


def is_quantized(cache: CacheT) -> bool:
    return "k_scale" in cache


def supports_kv_quant(cfg: ModelConfig) -> bool:
    """Quantized storage rides the block pool; the hybrid family is
    excluded (its recurrent rows are fp per-slot state and the grouped
    layer-axis cache threading is not worth the extra plumbing)."""
    return cfg.family in ("dense", "moe", "vlm")


def paged_kv_layers(cfg: ModelConfig) -> int:
    """Layer-axis size of the paged K/V pools for this family."""
    if cfg.family == "hybrid":
        return hybrid_layer_counts(cfg)[0]
    return cfg.num_layers


def scale_buf_shape(cfg: ModelConfig, num_blocks: int, block_size: int,
                    layers: int) -> Tuple[int, ...]:
    """Per-slot-per-KV-head fp32 amax scales: one scale per stored KV
    vector.  Slot granularity (not per-block) because decode writes land
    one token at a time through the table — requantizing a whole block
    would need a read-modify-write of its other slots."""
    return (layers, num_blocks, block_size, eff_kv_heads(cfg))


def quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """[..., KV, D] fp -> (int8 values, fp32 per-[..., KV] amax scales).

    All math in f32 with round-half-even, so every producer (multi-row
    prefill, tail prefill, decode/verify writes) quantizes the same
    vector bit-identically — the warm-vs-cold stream-identity anchor.
    A zero vector maps to scale 1.0 (not 0) so dequant never divides or
    multiplies by zero-by-convention."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = jnp.where(amax > 0, amax / INT8_QMAX, 1.0)
    q = jnp.clip(jnp.round(xf / scale[..., None]), -INT8_QMAX, INT8_QMAX)
    return q.astype(jnp.int8), scale


def dequantize_kv(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Inverse of :func:`quantize_kv`: [..., KV, D] int8 + [..., KV]
    scales -> f32.  The Pallas kv-sweep fuses exactly this product
    in-register; this jnp form is the oracle's and the XLA fallback's."""
    return q.astype(jnp.float32) * scale[..., None]


def fake_quantize_kv(x: jax.Array) -> jax.Array:
    """dequantize(quantize(x)) at x's dtype — what attention must read
    during prefill so cold-prefill, warm-tail and decode paths all see
    the identical (quantized) KV values."""
    q, s = quantize_kv(x)
    return dequantize_kv(q, s).astype(x.dtype)


def kv_block_bytes(cfg: ModelConfig, block_size: int, kv_quant: str,
                   dtype=jnp.float32) -> int:
    """HBM bytes one pool block costs across all paged layers (K + V,
    plus the scale arrays under int8).  The scheduler's byte accounting
    and the equal-byte pool sizing both resolve through here."""
    layers = paged_kv_layers(cfg)
    elems = layers * block_size * eff_kv_heads(cfg) * cfg.resolved_head_dim
    if kv_quant == "int8":
        scales = layers * block_size * eff_kv_heads(cfg)
        return 2 * (elems * 1 + scales * 4)
    if kv_quant == "none":
        return 2 * elems * jnp.dtype(dtype).itemsize
    raise ValueError(f"unknown kv_quant mode {kv_quant!r}")


def equal_byte_blocks(cfg: ModelConfig, fp_blocks: int, block_size: int,
                      fp_dtype=jnp.float32) -> int:
    """How many int8 blocks the byte budget of ``fp_blocks`` fp blocks
    buys (>= 2x for any head_dim >= 8/3: int8 costs D + 4 bytes per
    stored vector vs 4*D fp32)."""
    fp = kv_block_bytes(cfg, block_size, "none", dtype=fp_dtype)
    q8 = kv_block_bytes(cfg, block_size, "int8")
    return fp_blocks * fp // q8


# ---------------------------------------------------------------------------
# Block-paged layout
# ---------------------------------------------------------------------------

def supports_paged(cfg: ModelConfig) -> bool:
    """Families whose attention KV can live in the shared block pool.
    SSM is attention-free; audio's cross-KV is per-request encoder state."""
    return cfg.family in ("dense", "moe", "vlm", "hybrid")


def is_paged(cache: CacheT) -> bool:
    return "block_table" in cache


def max_blocks_per_seq(max_len: int, block_size: int) -> int:
    return -(-max_len // block_size)


def pool_buf_shape(cfg: ModelConfig, num_blocks: int, block_size: int,
                   layers: int) -> Tuple[int, ...]:
    return (layers, num_blocks, block_size, eff_kv_heads(cfg),
            cfg.resolved_head_dim)


def paged_cache_struct(cfg: ModelConfig, batch: int, max_len: int,
                       num_blocks: int, block_size: int,
                       dtype=jnp.bfloat16, abstract: bool = False,
                       require_full_seq: bool = True,
                       kv_quant: str = "none") -> CacheT:
    """Block-paged cache pytree: shared KV pool + per-sequence tables.

    ``k``/``v`` are pools ``[L, n_blocks, bs, KV, D]`` (the same leading
    layer axis the dense layout scans over), ``kv_pos [n_blocks, bs]`` is
    pool-level, ``block_table [B, max_blocks]`` maps logical to physical
    blocks (-1 = unallocated).  Recurrent state (hybrid lru/conv) stays
    dense per-slot.

    ``kv_quant="int8"`` stores the pools as int8 and adds fp32 amax
    scale arrays ``k_scale``/``v_scale`` ``[L, n_blocks, bs, KV]`` —
    one scale per stored KV vector, written alongside the values and
    carried with the block through COW copies and eviction/revival.

    ``require_full_seq`` asserts the pool holds at least one max-length
    sequence — the LIFO-preemption convergence guarantee.  Prefix-cached
    serving relaxes it (DESIGN.md §12): the scheduler's coverage-aware
    pool-feasibility check owns convergence there, and the data plane
    itself only needs drop-semantics, which hold for any pool size.
    """
    if not supports_paged(cfg):
        raise ValueError(f"family {cfg.family!r} has no paged KV layout")
    if kv_quant not in KV_QUANT_MODES:
        raise ValueError(f"unknown kv_quant mode {kv_quant!r}")
    if kv_quant != "none" and not supports_kv_quant(cfg):
        raise ValueError(
            f"family {cfg.family!r} has no quantized KV layout")
    assert not require_full_seq or num_blocks * block_size >= max_len, (
        "pool smaller than one max-length sequence: "
        f"{num_blocks}x{block_size} < {max_len}")

    def mk(shape, dt):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dt)
        return jnp.zeros(shape, dt)

    def mk_neg(shape):
        if abstract:
            return jax.ShapeDtypeStruct(shape, jnp.int32)
        return jnp.full(shape, -1, jnp.int32)

    maxb = max_blocks_per_seq(max_len, block_size)
    c: CacheT = {"length": mk((batch,), jnp.int32),
                 "kv_pos": mk_neg((num_blocks, block_size)),
                 "block_table": mk_neg((batch, maxb))}
    if cfg.family == "hybrid":
        n_attn, n_rec = hybrid_layer_counts(cfg)
        c["k"] = mk(pool_buf_shape(cfg, num_blocks, block_size, n_attn), dtype)
        c["v"] = mk(pool_buf_shape(cfg, num_blocks, block_size, n_attn), dtype)
        c["lru"] = mk((n_rec, batch, cfg.rglru.lru_width), jnp.float32)
        c["conv"] = mk((n_rec, batch, cfg.rglru.conv_width - 1,
                        cfg.rglru.lru_width), dtype)
    else:
        pool_dtype = jnp.int8 if kv_quant == "int8" else dtype
        c["k"] = mk(pool_buf_shape(cfg, num_blocks, block_size,
                                   cfg.num_layers), pool_dtype)
        c["v"] = mk(pool_buf_shape(cfg, num_blocks, block_size,
                                   cfg.num_layers), pool_dtype)
        if kv_quant == "int8":
            sshape = scale_buf_shape(cfg, num_blocks, block_size,
                                     cfg.num_layers)
            c["k_scale"] = mk(sshape, jnp.float32)
            c["v_scale"] = mk(sshape, jnp.float32)
    return c


def paged_prefill_view(cfg: ModelConfig, pool_k: jax.Array,
                       pool_v: jax.Array, kv_pos: jax.Array,
                       table_rows: jax.Array,
                       lengths: Optional[jax.Array] = None,
                       k_scale: Optional[jax.Array] = None,
                       v_scale: Optional[jax.Array] = None) -> CacheT:
    """Batch-R paged cache view over the *shared* pools, for prefilling a
    group of requests straight into their allocated blocks in ONE
    multi-row program (``table_rows [R, max_blocks]``, one row per
    request): pool-shaped leaves alias the live pools and every row's KV
    writes route through its own block-table row, so the rows land in
    disjoint blocks; per-sequence leaves (length, block table, hybrid
    recurrent rows) are fresh batch-R rows the engine scatters back into
    the batched cache afterwards.

    ``lengths [R]`` presets the committed length per row (zeros when
    omitted).  The prefix-cache tail prefill uses it to start a row at
    its cached-coverage offset, so decode-mode positions and attention
    see the shared prefix blocks as already-committed KV."""
    rows = table_rows.shape[0]
    length = (jnp.zeros((rows,), jnp.int32) if lengths is None
              else lengths.astype(jnp.int32))
    c: CacheT = {"length": length,
                 "k": pool_k, "v": pool_v, "kv_pos": kv_pos,
                 "block_table": table_rows}
    if k_scale is not None:
        c["k_scale"], c["v_scale"] = k_scale, v_scale
    if cfg.family == "hybrid":
        _, n_rec = hybrid_layer_counts(cfg)
        c["lru"] = jnp.zeros((n_rec, rows, cfg.rglru.lru_width), jnp.float32)
        c["conv"] = jnp.zeros((n_rec, rows, cfg.rglru.conv_width - 1,
                               cfg.rglru.lru_width), pool_k.dtype)
    return c


def _paged_flat_index(positions: jax.Array, block_table: jax.Array,
                      block_size: int, num_blocks: int,
                      keep: Optional[jax.Array]) -> jax.Array:
    """[B,T] positions -> flat pool slot via the table; out-of-range,
    unallocated, or ``~keep`` entries map past the pool (scatter-dropped)."""
    maxb = block_table.shape[1]
    blk = positions // block_size
    phys = jnp.take_along_axis(block_table, jnp.clip(blk, 0, maxb - 1),
                               axis=1)
    ok = (positions >= 0) & (blk < maxb) & (phys >= 0)
    if keep is not None:
        ok = ok & keep
    return jnp.where(ok, phys * block_size + positions % block_size,
                     num_blocks * block_size)


def write_kv_paged(pool_k: jax.Array, pool_v: jax.Array, k_new: jax.Array,
                   v_new: jax.Array, positions: jax.Array,
                   block_table: jax.Array,
                   keep: Optional[jax.Array] = None
                   ) -> Tuple[jax.Array, jax.Array]:
    """Scatter [B,T,KV,D] new KV through the block table into the pool
    ``[N, bs, KV, D]``.  Writes to unallocated table entries — and, when
    ``keep [B,T]`` is given, masked positions — are dropped, which is what
    lets the verification pass of a short-SL sequence stay inside its own
    block budget while the batch runs a wider bucket."""
    n, bs = pool_k.shape[:2]
    flat = _paged_flat_index(positions, block_table, bs, n, keep).reshape(-1)
    fk = pool_k.reshape((n * bs,) + pool_k.shape[2:])
    fv = pool_v.reshape((n * bs,) + pool_v.shape[2:])
    kf = k_new.reshape((-1,) + k_new.shape[2:]).astype(pool_k.dtype)
    vf = v_new.reshape((-1,) + v_new.shape[2:]).astype(pool_v.dtype)
    fk = fk.at[flat].set(kf, mode="drop")
    fv = fv.at[flat].set(vf, mode="drop")
    return fk.reshape(pool_k.shape), fv.reshape(pool_v.shape)


def write_pos_paged(kv_pos: jax.Array, positions: jax.Array,
                    block_table: jax.Array,
                    valid: Optional[jax.Array] = None,
                    keep: Optional[jax.Array] = None) -> jax.Array:
    """Update the pool-level slot-position map (once per model call).
    ``valid`` marks entries written as -1 (ragged prefill padding, dense
    ``write_pos`` semantics); ``keep`` drops the write entirely (decode
    write masking)."""
    n, bs = kv_pos.shape
    flat = _paged_flat_index(positions, block_table, bs, n, keep).reshape(-1)
    newpos = positions if valid is None else jnp.where(valid, positions, -1)
    return kv_pos.reshape(-1).at[flat].set(
        newpos.reshape(-1), mode="drop").reshape(kv_pos.shape)


def gather_paged_kv(pool_k: jax.Array, pool_v: jax.Array,
                    block_table: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-sequence dense views [B, max_blocks*bs, KV, D] of the pool.

    XLA reference path: functionally exact but materializes the view —
    the TPU data plane reads through the table inside the Pallas kernel
    instead (:func:`repro.kernels.ragged_attention
    .paged_ragged_verify_attention`).  Unallocated entries gather block 0;
    they are masked by the -1 entries of :func:`gather_paged_pos`."""
    idx = jnp.maximum(block_table, 0)
    b, maxb = block_table.shape
    bs = pool_k.shape[1]
    k = pool_k[idx].reshape((b, maxb * bs) + pool_k.shape[2:])
    v = pool_v[idx].reshape((b, maxb * bs) + pool_v.shape[2:])
    return k, v


def gather_paged_pos(kv_pos: jax.Array, block_table: jax.Array) -> jax.Array:
    """Per-sequence [B, max_blocks*bs] view of the pool-level kv_pos;
    unallocated table entries read as -1 (never valid)."""
    g = kv_pos[jnp.maximum(block_table, 0)]              # [B, MAXB, bs]
    g = jnp.where((block_table >= 0)[:, :, None], g, -1)
    return g.reshape(block_table.shape[0], -1)


def write_kv_paged_quant(pool_k: jax.Array, pool_v: jax.Array,
                         k_scale: jax.Array, v_scale: jax.Array,
                         k_new: jax.Array, v_new: jax.Array,
                         positions: jax.Array, block_table: jax.Array,
                         keep: Optional[jax.Array] = None
                         ) -> Tuple[jax.Array, jax.Array,
                                    jax.Array, jax.Array]:
    """Quantize-on-write: the int8 values and their fp32 scales scatter
    through the same flat pool index, so a dropped value write drops its
    scale too.  Per-layer pools ``[N, bs, KV, D]`` + scales
    ``[N, bs, KV]`` (the transformer scan slices the layer axis)."""
    n, bs = pool_k.shape[:2]
    flat = _paged_flat_index(positions, block_table, bs, n, keep).reshape(-1)
    qk, sk = quantize_kv(k_new)
    qv, sv = quantize_kv(v_new)
    fk = pool_k.reshape((n * bs,) + pool_k.shape[2:])
    fv = pool_v.reshape((n * bs,) + pool_v.shape[2:])
    fks = k_scale.reshape((n * bs,) + k_scale.shape[2:])
    fvs = v_scale.reshape((n * bs,) + v_scale.shape[2:])
    fk = fk.at[flat].set(qk.reshape((-1,) + qk.shape[2:]), mode="drop")
    fv = fv.at[flat].set(qv.reshape((-1,) + qv.shape[2:]), mode="drop")
    fks = fks.at[flat].set(sk.reshape((-1,) + sk.shape[2:]), mode="drop")
    fvs = fvs.at[flat].set(sv.reshape((-1,) + sv.shape[2:]), mode="drop")
    return (fk.reshape(pool_k.shape), fv.reshape(pool_v.shape),
            fks.reshape(k_scale.shape), fvs.reshape(v_scale.shape))


def gather_paged_kv_quant(pool_k: jax.Array, pool_v: jax.Array,
                          k_scale: jax.Array, v_scale: jax.Array,
                          block_table: jax.Array
                          ) -> Tuple[jax.Array, jax.Array]:
    """Dequantized per-sequence dense views [B, max_blocks*bs, KV, D]
    (f32).  XLA reference path only — the TPU data plane dequantizes
    in-register inside the Pallas kv-sweep instead
    (:func:`repro.kernels.ragged_attention
    .paged_ragged_verify_attention_quant`)."""
    idx = jnp.maximum(block_table, 0)
    b, maxb = block_table.shape
    bs = pool_k.shape[1]
    k = dequantize_kv(pool_k[idx], k_scale[idx])
    v = dequantize_kv(pool_v[idx], v_scale[idx])
    return (k.reshape((b, maxb * bs) + k.shape[3:]),
            v.reshape((b, maxb * bs) + v.shape[3:]))


def reset_blocks(kv_pos: jax.Array, block_ids) -> jax.Array:
    """Mark freshly (re)allocated blocks empty.  Mandatory on allocation:
    a block recycled from another sequence still holds kv_pos values that
    could satisfy ``0 <= kv_pos <= q`` for its new owner."""
    ids = jnp.asarray(block_ids, jnp.int32)
    return kv_pos.at[ids].set(-1)


def copy_blocks(pool_k: jax.Array, pool_v: jax.Array, kv_pos: jax.Array,
                src: jax.Array, dst: jax.Array
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Batched device-side block copy (copy-on-write fork, DESIGN.md §12):
    every KV byte and kv_pos entry of block ``src[i]`` lands in block
    ``dst[i]``.  Pairs are padded with the sentinel id ``num_blocks``:
    sentinel writes drop (same out-of-range discipline as
    :func:`write_kv_paged`) and the clamped sentinel gathers feed only
    those dropped writes, so one fixed pair width serves every round."""
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    read = jnp.minimum(src, pool_k.shape[1] - 1)
    pool_k = pool_k.at[:, dst].set(pool_k[:, read], mode="drop")
    pool_v = pool_v.at[:, dst].set(pool_v[:, read], mode="drop")
    kv_pos = kv_pos.at[dst].set(kv_pos[read], mode="drop")
    return pool_k, pool_v, kv_pos


def copy_scales(k_scale: jax.Array, v_scale: jax.Array, src: jax.Array,
                dst: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Scale-array half of a COW block copy (:func:`copy_blocks`): the
    fp32 amax scales travel with their block's int8 values, same
    sentinel-padding drop discipline."""
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    read = jnp.minimum(src, k_scale.shape[1] - 1)
    k_scale = k_scale.at[:, dst].set(k_scale[:, read], mode="drop")
    v_scale = v_scale.at[:, dst].set(v_scale[:, read], mode="drop")
    return k_scale, v_scale


def write_kv(k_buf: jax.Array, v_buf: jax.Array, k_new: jax.Array,
             v_new: jax.Array, positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Scatter [B,T,...] new KV into the [B,W,...] ring at pos % W."""
    w = k_buf.shape[1]
    b = k_buf.shape[0]
    t = k_new.shape[1]
    if t >= w:
        # keep only the last w tokens (prefill longer than the window)
        k_new, v_new = k_new[:, -w:], v_new[:, -w:]
        positions = positions[:, -w:]
        t = w
    slots = positions % w
    bi = jnp.arange(b)[:, None]
    k_buf = k_buf.at[bi, slots].set(k_new.astype(k_buf.dtype))
    v_buf = v_buf.at[bi, slots].set(v_new.astype(v_buf.dtype))
    return k_buf, v_buf


def write_pos(kv_pos: jax.Array, positions: jax.Array,
              valid: Optional[jax.Array] = None) -> jax.Array:
    """Update the shared slot-position map (once per model call)."""
    w = kv_pos.shape[1]
    b = kv_pos.shape[0]
    if positions.shape[1] >= w:
        positions = positions[:, -w:]
        valid = valid[:, -w:] if valid is not None else None
    slots = positions % w
    bi = jnp.arange(b)[:, None]
    newpos = positions if valid is None else jnp.where(valid, positions, -1)
    return kv_pos.at[bi, slots].set(newpos)


def commit_length(cache: CacheT, new_length: jax.Array) -> CacheT:
    out = dict(cache)
    out["length"] = new_length.astype(jnp.int32)
    return out
