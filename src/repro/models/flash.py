"""Memory-linear attention with a FlashAttention-2 style custom VJP.

Pure-JAX (lax.scan over tiles) — the XLA fallback used on every backend;
the Pallas kernel in ``repro/kernels`` covers the decode/verify hot path on
TPU.  Two memory-critical design points (both measured via the dry-run,
see EXPERIMENTS.md §Dry-run):

* custom VJP: scan backward through a naive blockwise softmax stores every
  [q_block, kv_block] probability tile (O(T*S) per layer ≈ 16 GiB/layer at
  4k-train scale).  We save only (out, logsumexp) and recompute tiles in
  backward — FA-2's residual strategy.
* structural masks: causal/window masks are computed from *iota + block
  offsets*, never from per-batch position tensors.  Position-tensor masks
  are loop-invariant across the layer scan, so XLA hoists the full
  [nq, nk, B, KV, G, qb, kb] predicate out of the loop (~8 GiB); the
  structural form hoists only [nq, nk, qb, kb] (~8 MiB).  Sequence
  raggedness enters through the tiny data-dependent ``kv_valid [B, S]``.

Positions are implicitly ``arange`` — true for every train/prefill layout
in this codebase (ragged prompts are expressed via ``kv_valid``).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _pad_to(x, n, axis, value=0):
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, n - x.shape[axis])
    return jnp.pad(x, pad, constant_values=value)


def _struct_mask(qs, ks, qb, kb, t, s, window, causal):
    """[qb, kb] mask from block offsets (loop-variant scalars) + iota."""
    rows = qs + jax.lax.iota(jnp.int32, qb)          # global q index
    cols = ks + jax.lax.iota(jnp.int32, kb)          # global kv index
    m = (cols[None, :] < s) & (rows[:, None] < t)    # un-padded region
    if causal:
        m = m & (cols[None, :] <= rows[:, None])
    if window is not None:
        m = m & (rows[:, None] - cols[None, :] < window)
    return m


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def flash_attention(q, k, v, kv_valid,
                    window: Optional[int], causal: bool,
                    q_block: int, kv_block: int):
    """q [B,T,KV,G,D]; k,v [B,S,KV,D]; kv_valid [B,S] bool."""
    out, _ = _flash_fwd_impl(q, k, v, kv_valid, window, causal,
                             q_block, kv_block)
    return out


def _flash_fwd_impl(q, k, v, kv_valid, window, causal, q_block, kv_block):
    b, t, kvh, g, d = q.shape
    s = k.shape[1]
    scale = 1.0 / math.sqrt(d)
    tp = -(-t // q_block) * q_block
    sp = -(-s // kv_block) * kv_block
    qf = _pad_to(q, tp, 1)
    kf = _pad_to(k, sp, 1)
    vf = _pad_to(v, sp, 1)
    nq, nk = tp // q_block, sp // kv_block

    qb_ = jnp.moveaxis(qf.reshape(b, nq, q_block, kvh, g, d), 1, 0)
    kb_ = jnp.moveaxis(kf.reshape(b, nk, kv_block, kvh, d), 1, 0)
    vb_ = jnp.moveaxis(vf.reshape(b, nk, kv_block, kvh, d), 1, 0)
    if kv_valid is None:
        kvb = jnp.zeros((nk, 0), bool)      # structural masks only
    else:
        kvf = _pad_to(kv_valid, sp, 1)
        kvb = jnp.moveaxis(kvf.reshape(b, nk, kv_block), 1, 0)

    def q_step(_, qin):
        qi, iq = qin

        def kv_step(carry, kin):
            m, l, acc = carry
            ki, vi, kval, ik = kin
            sc = jnp.einsum("bqkgd,bskd->bkgqs", qi, ki,
                            preferred_element_type=jnp.float32) * scale
            struct = _struct_mask(iq * q_block, ik * kv_block,
                                  q_block, kv_block, t, s, window, causal)
            if kv_valid is None:
                msk = struct[None, :, :]                      # [1,qb,kb]
            else:
                msk = struct[None, :, :] & kval[:, None, :]   # [B,qb,kb]
            sc = jnp.where(msk[:, None, None], sc, NEG_INF)
            m_new = jnp.maximum(m, sc.max(-1))
            alpha = jnp.exp(m - m_new)
            pr = jnp.where(msk[:, None, None],
                           jnp.exp(sc - m_new[..., None]), 0.0)
            l_new = l * alpha + pr.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", pr.astype(vi.dtype), vi,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        init = (jnp.full((b, kvh, g, q_block), NEG_INF, jnp.float32),
                jnp.zeros((b, kvh, g, q_block), jnp.float32),
                jnp.zeros((b, kvh, g, q_block, d), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(
            kv_step, init, (kb_, vb_, kvb, jnp.arange(nk)))
        l_safe = jnp.maximum(l, 1e-30)
        out = (acc / l_safe[..., None]).astype(q.dtype)
        lse = m + jnp.log(l_safe)
        return None, (out, lse)

    _, (outs, lses) = jax.lax.scan(q_step, None, (qb_, jnp.arange(nq)))
    out = jnp.moveaxis(outs, 0, 1).transpose(0, 1, 4, 2, 3, 5) \
             .reshape(b, tp, kvh, g, d)[:, :t]
    lse = jnp.moveaxis(lses, 0, 1).transpose(0, 1, 4, 2, 3) \
             .reshape(b, tp, kvh, g)[:, :t]          # [B,T,KV,G]
    return out, lse


def _flash_fwd(q, k, v, kv_valid, window, causal, q_block, kv_block):
    out, lse = _flash_fwd_impl(q, k, v, kv_valid, window, causal,
                               q_block, kv_block)
    return out, (q, k, v, kv_valid, out, lse)


def _flash_bwd(window, causal, q_block, kv_block, res, dout):
    q, k, v, kv_valid, out, lse = res
    b, t, kvh, g, d = q.shape
    s = k.shape[1]
    scale = 1.0 / math.sqrt(d)
    tp = -(-t // q_block) * q_block
    sp = -(-s // kv_block) * kv_block
    qf = _pad_to(q, tp, 1)
    kf = _pad_to(k, sp, 1)
    vf = _pad_to(v, sp, 1)
    of = _pad_to(out, tp, 1)
    dof = _pad_to(dout, tp, 1)
    lf = _pad_to(lse, tp, 1, value=0.0)
    nq, nk = tp // q_block, sp // kv_block

    # delta_i = rowsum(dO_i * O_i)   [B,T,KV,G]
    delta = (dof.astype(jnp.float32) * of.astype(jnp.float32)).sum(-1)

    qb_ = jnp.moveaxis(qf.reshape(b, nq, q_block, kvh, g, d), 1, 0)
    dob = jnp.moveaxis(dof.reshape(b, nq, q_block, kvh, g, d), 1, 0)
    lb_ = jnp.moveaxis(lf.reshape(b, nq, q_block, kvh, g), 1, 0)
    db_ = jnp.moveaxis(delta.reshape(b, nq, q_block, kvh, g), 1, 0)
    kb_ = jnp.moveaxis(kf.reshape(b, nk, kv_block, kvh, d), 1, 0)
    vb_ = jnp.moveaxis(vf.reshape(b, nk, kv_block, kvh, d), 1, 0)
    if kv_valid is None:
        kvb = jnp.zeros((nk, 0), bool)
    else:
        kvf = _pad_to(kv_valid, sp, 1)
        kvb = jnp.moveaxis(kvf.reshape(b, nk, kv_block), 1, 0)

    def kv_outer(dq_acc, kin):
        ki, vi, kval, ik = kin

        def q_inner(carry, qin):
            dk, dv, dq_in = carry
            qi, doi, li, di, iq = qin
            sc = jnp.einsum("bqkgd,bskd->bkgqs", qi, ki,
                            preferred_element_type=jnp.float32) * scale
            struct = _struct_mask(iq * q_block, ik * kv_block,
                                  q_block, kv_block, t, s, window, causal)
            if kv_valid is None:
                msk = struct[None, :, :]
            else:
                msk = struct[None, :, :] & kval[:, None, :]
            pr = jnp.where(msk[:, None, None],
                           jnp.exp(sc - li.transpose(0, 2, 3, 1)[..., None]),
                           0.0)                               # [B,KV,G,qb,kb]
            dpr = jnp.einsum("bqkgd,bskd->bkgqs", doi, vi,
                             preferred_element_type=jnp.float32)
            ds = pr * (dpr - di.transpose(0, 2, 3, 1)[..., None]) * scale
            prh = pr.astype(doi.dtype)
            dsh = ds.astype(qi.dtype)
            dv_new = dv + jnp.einsum("bkgqs,bqkgd->bskd", prh, doi,
                                     preferred_element_type=jnp.float32)
            dk_new = dk + jnp.einsum("bkgqs,bqkgd->bskd", dsh, qi,
                                     preferred_element_type=jnp.float32)
            dq_blk = jnp.einsum("bkgqs,bskd->bqkgd", dsh, ki,
                                preferred_element_type=jnp.float32)
            dq_in = jax.lax.dynamic_update_index_in_dim(
                dq_in, dq_in[iq] + dq_blk, iq, 0)
            return (dk_new, dv_new, dq_in), None

        init = (jnp.zeros((b, kv_block, kvh, d), jnp.float32),
                jnp.zeros((b, kv_block, kvh, d), jnp.float32),
                dq_acc)
        (dk_j, dv_j, dq_acc), _ = jax.lax.scan(
            q_inner, init, (qb_, dob, lb_, db_, jnp.arange(nq)))
        return dq_acc, (dk_j.astype(k.dtype), dv_j.astype(v.dtype))

    dq0 = jnp.zeros((nq, b, q_block, kvh, g, d), jnp.float32)
    dq_full, (dks, dvs) = jax.lax.scan(kv_outer, dq0,
                                       (kb_, vb_, kvb, jnp.arange(nk)))
    dq = jnp.moveaxis(dq_full, 0, 1).reshape(b, tp, kvh, g, d)[:, :t]
    dk = jnp.moveaxis(dks, 0, 1).reshape(b, sp, kvh, d)[:, :s]
    dv = jnp.moveaxis(dvs, 0, 1).reshape(b, sp, kvh, d)[:, :s]
    return (dq.astype(q.dtype), dk, dv, None)


flash_attention.defvjp(_flash_fwd, _flash_bwd)


def flash_attend(q: jax.Array, k: jax.Array, v: jax.Array, *,
                 q_pos: jax.Array = None, kv_pos: jax.Array = None,
                 kv_valid: jax.Array = None,
                 window: Optional[int] = None, causal: bool = True,
                 q_block: int = 512, kv_block: int = 512) -> jax.Array:
    """Drop-in for layers.attend: q [B,T,H,D].  Positions are implicitly
    arange (q_pos/kv_pos accepted for signature compatibility and ignored —
    all

 train/prefill call sites use arange positions; raggedness comes in
    via kv_valid)."""
    b, t, h, d = q.shape
    kvh = k.shape[2]
    qr = q.reshape(b, t, kvh, h // kvh, d)
    out = flash_attention(qr, k, v, kv_valid, window, causal,
                          q_block, kv_block)
    return out.reshape(b, t, h, d)
