"""Core transformer layers: norms, rotary embeddings (incl. M-RoPE),
GQA attention (naive + blockwise/flash-style for long sequences), SwiGLU MLP.

All functions are pure; parameters are dicts of arrays produced from the
Spec trees in the sibling ``*_specs`` functions.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.models.module import Spec

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dtype)


def rmsnorm_spec(dim: int, axis_name: Optional[str] = "embed") -> Spec:
    return Spec((dim,), (axis_name,), init="zeros")


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE + M-RoPE)
# ---------------------------------------------------------------------------

def _rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               mrope_sections: Optional[Tuple[int, int, int]] = None) -> jax.Array:
    """Rotate ``x [B, T, H, D]``.

    ``positions``: ``[B, T]`` (standard) or ``[B, T, 3]`` (M-RoPE: the three
    streams are temporal / height / width; text tokens carry identical values
    in all three, reproducing Qwen2-VL's M-RoPE degenerating to 1-D RoPE for
    text).
    """
    head_dim = x.shape[-1]
    half = head_dim // 2
    freqs = _rope_freqs(head_dim, theta)                     # [half]
    if mrope_sections is not None:
        assert sum(mrope_sections) == half, (mrope_sections, half)
        if positions.ndim == 2:
            positions = jnp.broadcast_to(positions[..., None],
                                         positions.shape + (3,))
        sec_ids = jnp.concatenate([
            jnp.full((s,), i, dtype=jnp.int32)
            for i, s in enumerate(mrope_sections)])          # [half]
        pos = jnp.take_along_axis(
            positions.astype(jnp.float32),                   # [B, T, 3]
            jnp.broadcast_to(sec_ids[None, None, :], positions.shape[:2] + (half,)),
            axis=-1)                                         # [B, T, half]
        angles = pos[..., None, :] * freqs                   # [B, T, 1, half]
    else:
        angles = positions.astype(jnp.float32)[..., None, None] * freqs
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([
        x1 * cos - x2 * sin,
        x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def attention_specs(cfg: ModelConfig, kv_heads: Optional[int] = None) -> dict:
    d = cfg.d_model
    # q_head_pad (§Perf): extra heads exist only for sharding divisibility;
    # their wo rows are zero so the function computed is unchanged
    h = cfg.q_head_pad or cfg.num_heads
    kv = kv_heads if kv_heads is not None else cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    specs = {
        "wq": Spec((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": Spec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": Spec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": Spec((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        specs["bq"] = Spec((h, hd), ("heads", "head_dim"), init="zeros")
        specs["bk"] = Spec((kv, hd), ("kv_heads", "head_dim"), init="zeros")
        specs["bv"] = Spec((kv, hd), ("kv_heads", "head_dim"), init="zeros")
    if cfg.qk_norm:
        specs["q_norm"] = Spec((hd,), ("head_dim",), init="zeros")
        specs["k_norm"] = Spec((hd,), ("head_dim",), init="zeros")
    return specs


def qkv_project(p: dict, cfg: ModelConfig, x: jax.Array,
                positions: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x [B, T, d] -> q [B,T,H,D], k/v [B,T,KV,D] with norm/bias/rope applied."""
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    return q, k, v


def _group_q(q: jax.Array, kv_heads: int) -> jax.Array:
    """[B,T,H,D] -> [B,T,KV,G,D] for GQA."""
    b, t, h, d = q.shape
    return q.reshape(b, t, kv_heads, h // kv_heads, d)


def attend(q: jax.Array, k: jax.Array, v: jax.Array, *,
           q_pos: jax.Array, kv_pos: jax.Array, kv_valid: jax.Array,
           window: Optional[int] = None, causal: bool = True) -> jax.Array:
    """Masked GQA attention, naive (materializes scores).

    q: [B,T,H,D]; k,v: [B,S,KV,D]; q_pos [B,T]; kv_pos [B,S];
    kv_valid [B,S] bool. Used for decode/verify (small T) and short
    prefill; long sequences take :func:`blockwise_attend`.
    """
    kv_heads = k.shape[2]
    qr = _group_q(q, kv_heads)
    scale = 1.0 / math.sqrt(q.shape[-1])
    # bf16 operands with f32 accumulation: an explicit .astype(f32) on the
    # KV cache would materialize a full-precision copy of the whole cache
    # (2x decode HBM, measured in the dry-run); preferred_element_type gets
    # the MXU's native bf16xbf16->f32 path instead.
    scores = jnp.einsum("btkgd,bskd->bkgts", qr, k,
                        preferred_element_type=jnp.float32) * scale
    mask = kv_valid[:, None, :]                                  # [B,1,S]
    if causal:
        mask = mask & (kv_pos[:, None, :] <= q_pos[:, :, None])  # [B,T,S]
    else:
        mask = jnp.broadcast_to(mask, (q.shape[0], q.shape[1], k.shape[1]))
    if window is not None:
        mask = mask & (q_pos[:, :, None] - kv_pos[:, None, :] < window)
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(jnp.any(mask[:, None, None], axis=-1, keepdims=True),
                      probs, 0.0)
    out = jnp.einsum("bkgts,bskd->btkgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    b, t = q.shape[:2]
    return out.reshape(b, t, q.shape[2], q.shape[3]).astype(q.dtype)


def blockwise_attend(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     q_pos: jax.Array, kv_pos: jax.Array, kv_valid: jax.Array,
                     window: Optional[int] = None, causal: bool = True,
                     q_block: int = 512, kv_block: int = 1024,
                     causal_skip: bool = False) -> jax.Array:
    """Flash-style online-softmax attention in pure jnp (lax.scan over q and
    kv blocks).  Bounds live memory to one [qb, kb] tile per (head, group) —
    required for the 32k prefill / 4k train shapes to fit HBM in the dry-run.

    ``causal_skip``: prune kv blocks strictly above the causal frontier
    (hillclimb optimization — halves attention FLOPs for causal prefill;
    requires q_pos/kv_pos to be block-monotonic, true for all our layouts).
    """
    b, t, h, d = q.shape
    s = k.shape[1]
    kv_heads = k.shape[2]
    g = h // kv_heads
    scale = 1.0 / math.sqrt(d)

    tp = (t + q_block - 1) // q_block * q_block
    sp = (s + kv_block - 1) // kv_block * kv_block
    qf = jnp.pad(q, ((0, 0), (0, tp - t), (0, 0), (0, 0)))
    kf = jnp.pad(k, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    vf = jnp.pad(v, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    qpf = jnp.pad(q_pos, ((0, 0), (0, tp - t)))
    kpf = jnp.pad(kv_pos, ((0, 0), (0, sp - s)), constant_values=2**30)
    kvf = jnp.pad(kv_valid, ((0, 0), (0, sp - s)))

    nq, nk = tp // q_block, sp // kv_block
    # blocked views: [n, B, blk, ...]
    qb_ = jnp.moveaxis(qf.reshape(b, nq, q_block, kv_heads, g, d), 1, 0)
    kb_ = jnp.moveaxis(kf.reshape(b, nk, kv_block, kv_heads, d), 1, 0)
    vb_ = jnp.moveaxis(vf.reshape(b, nk, kv_block, kv_heads, d), 1, 0)
    qpb = jnp.moveaxis(qpf.reshape(b, nq, q_block), 1, 0)
    kpb = jnp.moveaxis(kpf.reshape(b, nk, kv_block), 1, 0)
    kvb = jnp.moveaxis(kvf.reshape(b, nk, kv_block), 1, 0)

    def q_step(_, qin):
        qi, qp = qin                       # [B,qb,KV,G,D], [B,qb]

        def kv_step(carry, kin):
            m, l, acc = carry
            ki, vi, kp, kval = kin
            sc = jnp.einsum("bqkgd,bskd->bkgqs", qi.astype(jnp.float32),
                            ki.astype(jnp.float32)) * scale
            msk = kval[:, None, :]
            if causal:
                msk = msk & (kp[:, None, :] <= qp[:, :, None])
            else:
                msk = jnp.broadcast_to(
                    msk, (msk.shape[0], qp.shape[1], msk.shape[2]))
            if window is not None:
                msk = msk & (qp[:, :, None] - kp[:, None, :] < window)
            sc = jnp.where(msk[:, None, None], sc, NEG_INF)
            m_new = jnp.maximum(m, sc.max(-1))
            alpha = jnp.exp(m - m_new)
            pr = jnp.exp(sc - m_new[..., None])
            pr = jnp.where(msk[:, None, None], pr, 0.0)
            l_new = l * alpha + pr.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", pr, vi.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        init = (jnp.full((b, kv_heads, g, q_block), NEG_INF, jnp.float32),
                jnp.zeros((b, kv_heads, g, q_block), jnp.float32),
                jnp.zeros((b, kv_heads, g, q_block, d), jnp.float32))
        if causal_skip:
            # prune kv blocks whose minimum kv position exceeds this q
            # block's maximum position (static per python-level q index is
            # impossible inside scan — instead slice the kv scan length via
            # mask-only; pruning variant is in kernels/ for TPU).
            pass
        (m, l, acc), _ = jax.lax.scan(kv_step, init, (kb_, vb_, kpb, kvb))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out                    # [B,KV,G,qb,D]

    _, outs = jax.lax.scan(q_step, None, (qb_, qpb))
    out = jnp.moveaxis(outs, 0, 1)          # [B,nq,KV,G,qb,D]
    out = out.transpose(0, 1, 4, 2, 3, 5).reshape(b, tp, h, d)
    return out[:, :t].astype(q.dtype)


def attn_output(p: dict, out: jax.Array) -> jax.Array:
    return jnp.einsum("bthk,hkd->btd", out, p["wo"])


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_specs(d_model: int, d_ff: int) -> dict:
    return {
        "w_gate": Spec((d_model, d_ff), ("embed", "mlp")),
        "w_up": Spec((d_model, d_ff), ("embed", "mlp")),
        "w_down": Spec((d_ff, d_model), ("mlp", "embed")),
    }


def mlp_apply(p: dict, x: jax.Array) -> jax.Array:
    gate = jax.nn.silu(jnp.einsum("btd,df->btf", x, p["w_gate"]))
    up = jnp.einsum("btd,df->btf", x, p["w_up"])
    return jnp.einsum("btf,fd->btd", gate * up, p["w_down"])
