"""Minimal pure-JAX parameter system (flax is not available in-container).

A model is described by a pytree of :class:`Spec` leaves.  The same spec tree
serves three purposes:

* ``init_params``      — materialize real parameters (CPU tests, examples);
* ``abstract_params``  — ``ShapeDtypeStruct`` stand-ins for the multi-pod
                         dry-run (no allocation);
* ``param_shardings``  — ``NamedSharding`` per leaf from the logical axis
                         names, MaxText-style.

Logical axis vocabulary (see DESIGN.md §5):
    embed, mlp, heads, kv_heads, head_dim, vocab, experts, layers,
    conv, state, lru — mapped to mesh axes by :class:`ShardingConfig`.
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.core.config import ShardingConfig

PyTree = Any


class Spec(NamedTuple):
    """Abstract parameter: shape + logical axes + initializer."""
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]     # logical name per dim (None = replicated)
    init: str = "normal"                # normal | zeros | ones
    scale: Optional[float] = None       # stddev override for "normal"

    def fan_in_scale(self) -> float:
        if self.scale is not None:
            return self.scale
        # fan-in init: last-but-one dim is usually the input dim; for
        # matmul kernels shaped (in, out...) use dim 0 product heuristics.
        fan_in = self.shape[0] if len(self.shape) >= 2 else max(self.shape[0], 1)
        return 1.0 / math.sqrt(max(fan_in, 1))


def is_spec(x) -> bool:
    return isinstance(x, Spec)


def _tree_map_specs(fn, specs: PyTree) -> PyTree:
    return jax.tree_util.tree_map(fn, specs, is_leaf=is_spec)


def init_params(specs: PyTree, key: jax.Array, dtype=jnp.float32) -> PyTree:
    """Materialize parameters. Deterministic per-leaf keys via fold_in of the
    flattened leaf index (stable across identical spec trees)."""
    leaves, treedef = jax.tree_util.tree_flatten(specs, is_leaf=is_spec)
    out = []
    for i, spec in enumerate(leaves):
        k = jax.random.fold_in(key, i)
        if spec.init == "zeros":
            out.append(jnp.zeros(spec.shape, dtype))
        elif spec.init == "ones":
            out.append(jnp.ones(spec.shape, dtype))
        else:
            out.append(
                (jax.random.normal(k, spec.shape, jnp.float32)
                 * spec.fan_in_scale()).astype(dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_params(specs: PyTree, dtype=jnp.bfloat16,
                    shardings: Optional[PyTree] = None) -> PyTree:
    """ShapeDtypeStruct tree for .lower() — optionally with shardings."""
    if shardings is None:
        return _tree_map_specs(
            lambda s: jax.ShapeDtypeStruct(s.shape, dtype), specs)
    return jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, dtype, sharding=sh),
        specs, shardings, is_leaf=is_spec)


def logical_to_pspec(axes: Sequence[Optional[str]],
                     rules: ShardingConfig) -> P:
    """Map logical dim names to a PartitionSpec via the rules table."""
    mapping: Dict[str, Any] = {
        "embed": rules.embed,
        "mlp": rules.mlp,
        "heads": rules.heads,
        "kv_heads": rules.heads,     # kv heads follow the heads rule
        "vocab": rules.vocab,
        "experts": rules.experts,
        "batch": tuple(rules.batch),
        "cache_seq": rules.cache_seq,
        "seq": rules.seq,
        # never sharded:
        "head_dim": None, "layers": None, "conv": None,
        "state": None, "lru": rules.mlp, None: None,
    }
    # deferred: launch.sharding imports this module at load time
    from repro.launch.sharding import canonical_spec

    parts = []
    for name in axes:
        parts.append(mapping.get(name, None))
    return canonical_spec(*parts)


def param_shardings(specs: PyTree, mesh: Mesh,
                    rules: ShardingConfig) -> PyTree:
    """NamedSharding tree aligned with the spec tree.

    Divisibility guard: jit input shardings require even tiling, so a
    logical axis is only sharded when the dim divides the mesh-axis size;
    otherwise the dim is replicated (e.g. 9 heads over 16 model shards).
    The replication cost shows up in the §Roofline memory column and the
    fused-head layout that removes it is a §Perf hillclimb variant."""
    # deferred: launch.sharding imports this module at load time
    from repro.launch.sharding import canonical_spec

    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def _one(spec: Spec) -> NamedSharding:
        pspec = logical_to_pspec(spec.axes, rules)
        fixed = []
        used: set = set()
        for dim, part in zip(spec.shape, tuple(pspec) + (None,) * (len(spec.shape) - len(pspec))):
            if part is None:
                fixed.append(None)
                continue
            names = part if isinstance(part, tuple) else (part,)
            size = int(np.prod([axis_sizes[n] for n in names]))
            # each mesh axis at most once per spec (e.g. [lru, lru] mats)
            if dim % size != 0 or any(n in used for n in names):
                fixed.append(None)
                continue
            used.update(names)
            fixed.append(part)
        return NamedSharding(mesh, canonical_spec(*fixed))

    return _tree_map_specs(_one, specs)


def count_params(specs: PyTree) -> int:
    leaves = jax.tree_util.tree_leaves(specs, is_leaf=is_spec)
    return int(sum(np.prod(s.shape) for s in leaves))
