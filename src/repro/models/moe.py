"""Mixture-of-Experts block: top-k router + capacity-based dispatch.

Implementation notes (TPU-oriented — see DESIGN.md §4):

* Capacity-based gather/scatter (GShard-style) rather than the
  [tokens, experts, capacity] one-hot einsum — the one-hot dispatch tensor
  is O(T·E·C) and does not fit HBM at 32k-prefill scale.  Here dispatch is
  two scatters of index/weight vectors (O(T·k)) plus a gather, and expert
  compute is one batched einsum over the stacked expert weights
  ``[E, C, d] x [E, d, f]`` — MXU-friendly and exactly capacity-bounded,
  so compiled FLOPs track *active* (not total) parameters.
* Baseline sharding is tensor-parallel experts (expert weight ``mlp`` dim
  sharded over the model axis).  The expert-parallel all-to-all variant
  (``MoEConfig.sharding == "ep"``) is the beyond-paper hillclimb knob.
* Router aux outputs: load-balance loss (Switch-style) + router z-loss,
  surfaced for the training objective and for serving telemetry.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import MoEConfig
from repro.models.module import Spec


def moe_specs(d_model: int, cfg: MoEConfig) -> dict:
    e, f = cfg.num_experts, cfg.expert_d_ff
    return {
        "router": Spec((d_model, e), ("embed", None), scale=0.02),
        "w_gate": Spec((e, d_model, f), ("experts", "embed", "mlp")),
        "w_up": Spec((e, d_model, f), ("experts", "embed", "mlp")),
        "w_down": Spec((e, f, d_model), ("experts", "mlp", "embed")),
    }


def _capacity(num_tokens: int, cfg: MoEConfig, factor: float = 1.25) -> int:
    cap = int(num_tokens * cfg.top_k * factor / cfg.num_experts) + 1
    # round to an MXU-friendly multiple
    cap = (cap + 7) // 8 * 8
    return min(cap, num_tokens)


def moe_apply(p: dict, cfg: MoEConfig, x: jax.Array,
              shardings: Dict = None,
              ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: [B, T, d] -> (y [B, T, d], aux losses).

    ``shardings``: optional {"cap": NamedSharding for [E, cap, d],
    "tok": NamedSharding for [n, d]} — without the capacity-dim constraint
    GSPMD replicates the dispatch buffers (measured 123-157 GiB/device at
    32k-prefill scale, see EXPERIMENTS.md §Dry-run)."""

    def pin(arr, kind):
        if shardings and kind in shardings and shardings[kind] is not None:
            return jax.lax.with_sharding_constraint(arr, shardings[kind])
        return arr
    b, t, d = x.shape
    n = b * t
    e, k = cfg.num_experts, cfg.top_k
    xf = x.reshape(n, d)

    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                       # [n, e]
    topk_probs, topk_idx = jax.lax.top_k(probs, k)                # [n, k]
    topk_probs = topk_probs / jnp.maximum(
        topk_probs.sum(-1, keepdims=True), 1e-9)                  # renormalize

    # ---- aux losses (Switch Transformer) ---------------------------------
    me = probs.mean(axis=0)                                       # mean prob/expert
    one_hot_top1 = jax.nn.one_hot(topk_idx[:, 0], e)
    ce = one_hot_top1.mean(axis=0)                                # frac tokens/expert
    lb_loss = e * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux = {"load_balance_loss": lb_loss, "router_z_loss": z_loss,
           "expert_fraction": ce}

    # ---- capacity-based dispatch -----------------------------------------
    cap = _capacity(n, cfg)
    flat_expert = topk_idx.reshape(-1)                            # [n*k]
    flat_token = jnp.repeat(jnp.arange(n), k)                     # [n*k]
    flat_weight = topk_probs.reshape(-1)                          # [n*k]

    # position of each (token, slot) within its expert's capacity buffer
    eh = jax.nn.one_hot(flat_expert, e, dtype=jnp.int32)          # [n*k, e]
    pos_in_expert = (jnp.cumsum(eh, axis=0) - eh)                 # exclusive
    slot = jnp.take_along_axis(pos_in_expert, flat_expert[:, None], axis=1)[:, 0]
    keep = slot < cap                                             # dropped if over capacity

    # scatter token ids into [e, cap]
    src = jnp.where(keep, flat_token, n)                          # n = OOB sentinel
    buf = jnp.full((e, cap), n, dtype=jnp.int32)
    buf = buf.at[flat_expert, jnp.minimum(slot, cap - 1)].set(
        jnp.where(keep, src, buf[flat_expert, jnp.minimum(slot, cap - 1)]),
        mode="drop")
    token_pad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    xe = pin(token_pad[buf], "cap")                               # [e, cap, d]

    # ---- expert computation (batched einsum over stacked experts) --------
    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"]))
    up = jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    ye = pin(jnp.einsum("ecf,efd->ecd", gate * up, p["w_down"]), "cap")

    # ---- combine ----------------------------------------------------------
    out = jnp.zeros((n + 1, d), ye.dtype)
    w = jnp.where(keep, flat_weight, 0.0).astype(ye.dtype)
    gathered = ye[flat_expert, jnp.minimum(slot, cap - 1)]        # [n*k, d]
    out = out.at[src].add(gathered * w[:, None], mode="drop")
    y = pin(out[:n], "tok").reshape(b, t, d).astype(x.dtype)

    dropped = 1.0 - keep.mean()
    aux["dropped_fraction"] = dropped
    return y, aux
