"""RG-LRU recurrent block (RecurrentGemma / Griffin). [arXiv:2402.19427]

Recurrence (per channel):
    r_t = sigmoid(W_a x_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_i x_t + b_i)          (input gate)
    log a_t = -c * softplus(Lambda) * r_t (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

* prefill/train: ``jax.lax.associative_scan`` over (a, b) pairs — O(log S)
  depth, the TPU-native equivalent of Griffin's custom scan kernel.
* decode/verify: step recurrence with an ``update_mask`` (masked steps are
  identities: a=1, input term 0) for speculative commit.

The full residual block is: x -> conv1d(w=4) -> RG-LRU, gated by
GeLU(W_gate x), then W_out.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.models.module import Spec
from repro.models.ssm import causal_conv1d

_C = 8.0
_MAX_SQRT_GRADIENT = 1000.0


def rglru_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    w = cfg.rglru.lru_width
    cw = cfg.rglru.conv_width
    return {
        "w_x": Spec((d, w), ("embed", "lru")),
        "w_gate": Spec((d, w), ("embed", "lru")),
        "w_out": Spec((w, d), ("lru", "embed")),
        "conv_w": Spec((cw, w), ("conv", "lru"), scale=0.5),
        "conv_b": Spec((w,), ("lru",), init="zeros"),
        "w_a": Spec((w, w), ("lru", "lru"), scale=0.02),
        "b_a": Spec((w,), ("lru",), init="zeros"),
        "w_i": Spec((w, w), ("lru", "lru"), scale=0.02),
        "b_i": Spec((w,), ("lru",), init="zeros"),
        # Lambda parametrized so softplus(Lambda) spans useful decay rates
        "lam": Spec((w,), ("lru",), init="ones"),
    }


def _gates(p: dict, x: jax.Array, update_mask: Optional[jax.Array]
           ) -> Tuple[jax.Array, jax.Array]:
    """Returns (a, b) of the affine recurrence h_t = a_t h + b_t."""
    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", x, p["w_a"]) + p["b_a"])
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", x, p["w_i"]) + p["b_i"])
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    gated_x = (i * x).astype(jnp.float32)
    multiplier = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = multiplier * gated_x
    if update_mask is not None:
        m = update_mask[..., None]
        a = jnp.where(m > 0, a, 1.0)
        b = jnp.where(m > 0, b, 0.0)
    return a, b


def rglru_scan(p: dict, x: jax.Array, h0: Optional[jax.Array] = None,
               update_mask: Optional[jax.Array] = None
               ) -> Tuple[jax.Array, jax.Array]:
    """x [B,S,W] -> (h_all [B,S,W], h_final [B,W]) via associative scan."""
    a, b = _gates(p, x, update_mask)
    if h0 is not None:
        # fold the initial state in as a virtual step 0
        a = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
        b = jnp.concatenate([h0[:, None].astype(b.dtype), b], axis=1)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, b), axis=1)
    if h0 is not None:
        hh = hh[:, 1:]
    return hh.astype(x.dtype), hh[:, -1]


def rglru_step_scan(p: dict, x: jax.Array, h0: jax.Array,
                    update_mask: Optional[jax.Array] = None
                    ) -> Tuple[jax.Array, jax.Array]:
    """Sequential form for decode/verify (small T)."""
    a, b = _gates(p, x, update_mask)

    def step(h, inp):
        a_, b_ = inp
        hn = a_ * h + b_
        return hn, hn

    hf, hs = jax.lax.scan(step, h0.astype(jnp.float32),
                          (jnp.moveaxis(a, 1, 0), jnp.moveaxis(b, 1, 0)))
    return jnp.moveaxis(hs, 0, 1).astype(x.dtype), hf


def rglru_block(p: dict, cfg: ModelConfig, u: jax.Array,
                state: Optional[Dict[str, jax.Array]] = None,
                update_mask: Optional[jax.Array] = None,
                sequential: bool = False
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full Griffin recurrent block. u [B,S,d] -> y [B,S,d].
    state: {"lru": [B,W], "conv": [B,cw-1,W]}"""
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", u, p["w_gate"]))
    x = jnp.einsum("bsd,dw->bsw", u, p["w_x"])
    conv_cache = state["conv"] if state is not None else None
    xc, new_conv = causal_conv1d(x, p["conv_w"], p["conv_b"], conv_cache)
    h0 = state["lru"] if state is not None else None
    if sequential:
        if h0 is None:
            h0 = jnp.zeros((x.shape[0], x.shape[-1]), jnp.float32)
        hs, hf = rglru_step_scan(p, xc, h0, update_mask)
    else:
        hs, hf = rglru_scan(p, xc, h0, update_mask)
    y = jnp.einsum("bsw,wd->bsd", hs * gate, p["w_out"])
    new_state = {"lru": hf, "conv": new_conv}
    if update_mask is not None and conv_cache is not None:
        w = p["conv_w"].shape[0]
        hist = jnp.concatenate([conv_cache, x], axis=1)
        n_acc = update_mask.sum(axis=1).astype(jnp.int32)
        idx = n_acc[:, None] + jnp.arange(w - 1)[None, :]
        new_state["conv"] = jnp.take_along_axis(hist, idx[..., None], axis=1)
    return y, new_state
