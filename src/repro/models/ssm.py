"""Mamba-2 (SSD — state-space duality) mixer in pure JAX. [arXiv:2405.21060]

Two execution forms, matching the paper's duality:

* ``ssd_chunked``   — matmul ("attention-dual") form for train/prefill:
  intra-chunk quadratic term + inter-chunk state carry.  This is the
  MXU-friendly form: everything is einsums over [chunk, chunk] and
  [head_dim, state] tiles (TPU adaptation of the paper's Triton kernels).
* ``ssd_recurrent`` — linear recurrence for decode/verify: a
  ``lax.scan`` over the (short) token axis.  Supports a per-step
  ``update_mask``: masked steps are exact identities on the state
  (``dt = 0``), which is how the speculative-decoding engine *commits* only
  the accepted tokens after verification (DESIGN.md §4, state rollback).

State layout: ``h [B, H, P, N]`` (heads, head_dim, state), conv cache
``[B, W-1, conv_dim]``.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig, SSMConfig
from repro.models.layers import rmsnorm
from repro.models.module import Spec


def ssm_dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    num_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.state_size
    return d_inner, num_heads, conv_dim, s.state_size


def ssm_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    s = cfg.ssm
    di, h, dc, n = ssm_dims(cfg)
    return {
        "wz": Spec((d, di), ("embed", "mlp")),
        "wxbc": Spec((d, dc), ("embed", "mlp")),     # x | B | C jointly conv'd
        "wdt": Spec((d, h), ("embed", None)),
        "dt_bias": Spec((h,), (None,), init="zeros"),
        "A_log": Spec((h,), (None,), init="ones"),
        "D": Spec((h,), (None,), init="ones"),
        "conv_w": Spec((s.conv_width, dc), ("conv", "mlp"), scale=0.5),
        "conv_b": Spec((dc,), ("mlp",), init="zeros"),
        "gnorm": Spec((di,), ("mlp",), init="zeros"),
        "out_proj": Spec((di, d), ("mlp", "embed")),
    }


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array,
                  cache: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. x [B,S,C], w [W,C].  Returns (y, new_cache)
    where new_cache holds the trailing W-1 inputs."""
    width = w.shape[0]
    if cache is None:
        cache = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([cache, x], axis=1)
    s = x.shape[1]
    y = sum(w[i] * jax.lax.dynamic_slice_in_dim(xp, i, s, axis=1)
            for i in range(width)) + b
    new_cache = xp[:, -(width - 1):] if width > 1 else cache
    return y, new_cache


def _segsum(a: jax.Array) -> jax.Array:
    """a [..., q] -> [..., q, q]: [i,j] = sum_{k=j+1..i} a_k (lower-tri)."""
    cs = jnp.cumsum(a, axis=-1)
    ss = cs[..., :, None] - cs[..., None, :]
    q = a.shape[-1]
    tri = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(tri, ss, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array,
                B: jax.Array, C: jax.Array, chunk: int,
                h0: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD. x [b,s,h,p], dt [b,s,h] (post-softplus), A [h] (<0),
    B,C [b,s,n] (single group).  Returns (y [b,s,h,p], h_final [b,h,p,n])."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    nc = sp // chunk
    xd = (x * dt[..., None]).astype(jnp.float32)                  # dt-scaled input
    a = (dt * A).astype(jnp.float32)                              # [b,sp,h]

    # chunked views: [b, nc, q, ...] -> scan over nc
    xc = xd.reshape(b, nc, chunk, h, p)
    ac = a.reshape(b, nc, chunk, h).transpose(0, 3, 1, 2)         # [b,h,nc,q]
    Bc = B.reshape(b, nc, chunk, n).astype(jnp.float32)
    Cc = C.reshape(b, nc, chunk, n).astype(jnp.float32)

    a_cs = jnp.cumsum(ac, axis=-1)                                # [b,h,nc,q]
    L = jnp.exp(_segsum(ac))                                      # [b,h,nc,q,q]
    # intra-chunk (quadratic, attention-dual) term
    y_diag = jnp.einsum("bcqn,bckn,bhcqk,bckhp->bcqhp", Cc, Bc, L, xc)
    # per-chunk input->state contribution
    decay_in = jnp.exp(a_cs[..., -1:] - a_cs)                     # [b,h,nc,q]
    chunk_states = jnp.einsum("bcqn,bhcq,bcqhp->bchpn", Bc, decay_in, xc)
    chunk_decay = jnp.exp(a_cs[..., -1])                          # [b,h,nc]
    out_decay = jnp.exp(a_cs)                                     # [b,h,nc,q]

    h0 = (jnp.zeros((b, h, p, n), jnp.float32) if h0 is None
          else h0.astype(jnp.float32))

    def step(hprev, inp):
        cs_, cd_, od_, C_ = inp
        y_off = jnp.einsum("bqn,bhpn,bhq->bqhp", C_, hprev, od_)
        hnew = cd_[..., None, None] * hprev + cs_
        return hnew, y_off

    xs = (jnp.moveaxis(chunk_states, 1, 0),
          jnp.moveaxis(chunk_decay, 2, 0),
          jnp.moveaxis(out_decay, 2, 0),
          jnp.moveaxis(Cc, 1, 0))
    h_final, y_offs = jax.lax.scan(step, h0, xs)
    y_off = jnp.moveaxis(y_offs, 0, 1).reshape(b, nc, chunk, h, p)
    y = (y_diag + y_off).reshape(b, sp, h, p)[:, :s]
    return y.astype(x.dtype), h_final


def ssd_recurrent(x: jax.Array, dt: jax.Array, A: jax.Array,
                  B: jax.Array, C: jax.Array, h0: jax.Array,
                  update_mask: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Token-recurrent SSD for decode/verify.  x [b,t,h,p], dt [b,t,h],
    B,C [b,t,n], h0 [b,h,p,n].  ``update_mask [b,t]``: steps with mask=0
    leave the state untouched (dt := 0) — used for speculative commit."""
    if update_mask is not None:
        dt = dt * update_mask[..., None]
    af = jnp.exp(dt * A)                                          # [b,t,h]

    def step(h, inp):
        a_, x_, dt_, B_, C_ = inp
        # h' = a h + (dt x) B^T ; y = C h'
        upd = jnp.einsum("bhp,bn->bhpn", x_ * dt_[..., None], B_)
        hn = a_[..., None, None] * h + upd
        y = jnp.einsum("bn,bhpn->bhp", C_, hn)
        return hn, y

    xs = (jnp.moveaxis(af, 1, 0), jnp.moveaxis(x.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt, 1, 0), jnp.moveaxis(B.astype(jnp.float32), 1, 0),
          jnp.moveaxis(C.astype(jnp.float32), 1, 0))
    hf, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), hf


def mamba_mixer(p: dict, cfg: ModelConfig, u: jax.Array,
                state: Optional[Dict[str, jax.Array]] = None,
                update_mask: Optional[jax.Array] = None,
                use_chunked: bool = True
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full Mamba-2 block (pre-norm residual handled by the caller).

    u [B,S,d_model] -> y [B,S,d_model].  ``state`` carries
    ``{"ssd": [B,H,P,N], "conv": [B,W-1,conv_dim]}`` across calls; pass
    ``None`` for stateless training.
    """
    s = cfg.ssm
    di, h, dc, n = ssm_dims(cfg)
    z = jnp.einsum("bsd,de->bse", u, p["wz"])
    xbc = jnp.einsum("bsd,de->bse", u, p["wxbc"])
    dt_raw = jnp.einsum("bsd,dh->bsh", u, p["wdt"])

    conv_cache = state["conv"] if state is not None else None
    xbc, new_conv = causal_conv1d(xbc, p["conv_w"], p["conv_b"], conv_cache)
    xbc = jax.nn.silu(xbc)
    x, B, C = jnp.split(xbc, [di, di + n], axis=-1)
    x = x.reshape(x.shape[0], x.shape[1], h, s.head_dim)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    if update_mask is not None:
        # masked steps are exact identities on the state (dt = 0 => decay 1,
        # zero input) — valid in BOTH the chunked and recurrent forms, which
        # is how ragged right-padded prefill stays correct for SSMs
        dt = dt * update_mask[..., None]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    h0 = state["ssd"] if state is not None else None
    if use_chunked:
        y, hf = ssd_chunked(x, dt, A, B, C, s.chunk_size, h0)
    else:
        if h0 is None:
            h0 = jnp.zeros((x.shape[0], h, s.head_dim, n), jnp.float32)
        y, hf = ssd_recurrent(x, dt, A, B, C, h0, None)

    y = y + p["D"].astype(y.dtype)[:, None] * x                   # skip
    y = y.reshape(y.shape[0], y.shape[1], di)
    y = rmsnorm(y * jax.nn.silu(z), p["gnorm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    new_state = {"ssd": hf, "conv": new_conv}
    if update_mask is not None:
        # conv cache must also freeze past the accepted prefix; recompute it
        # from the masked input stream (identity for masked steps).
        if conv_cache is not None:
            w = p["conv_w"].shape[0]
            xbc_in = jnp.einsum("bsd,de->bse", u, p["wxbc"])
            hist = jnp.concatenate([conv_cache, xbc_in], axis=1)  # [B, W-1+T, dc]
            t = u.shape[1]
            n_acc = update_mask.sum(axis=1).astype(jnp.int32)     # [B]
            idx = n_acc[:, None] + jnp.arange(w - 1)[None, :]     # window end at accepted
            new_state["conv"] = jnp.take_along_axis(
                hist, idx[..., None].astype(jnp.int32), axis=1)
    return out, new_state
