"""Model assembly for all assigned architecture families.

Public API (all pure functions):

* ``model_specs(cfg)``            — Spec pytree (init / abstract / shardings)
* ``forward(params, cfg, ...)``   — one entry point, three modes:
    - ``mode="train"``    full causal pass, no cache, returns (logits, aux)
    - ``mode="prefill"``  fills the cache from a (right-padded) prompt
    - ``mode="decode"``   T tokens against the cache (T=1 plain decode,
                          T=SL_cap+1 speculative verification); KV written
                          in-pass, ``length`` untouched (engine commits)
* ``commit(params, cfg, ...)``    — commit ``n_acc`` accepted tokens:
    length arithmetic for KV families; masked state re-advance for
    recurrent families (SSM / RG-LRU), see DESIGN.md §4.

Deep homogeneous stacks (dense / moe / ssm / vlm / audio) are scanned over
a stacked-parameter leading axis — keeps the HLO small so 40 dry-run
combinations compile quickly.  The hybrid 1:2 pattern is unrolled.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.kernels import ops as kernel_ops
from repro.models import cache as cache_lib
from repro.models.flash import flash_attend
from repro.models.layers import (attend, attention_specs, attn_output,
                                 mlp_apply, mlp_specs,
                                 qkv_project, rmsnorm, rmsnorm_spec)
from repro.models.module import Spec
from repro.models.moe import moe_apply, moe_specs
from repro.models.rglru import rglru_block, rglru_specs
from repro.models.ssm import mamba_mixer, ssm_specs

PyTree = Any

# sequences at or above this length use blockwise (flash-style) attention
BLOCKWISE_THRESHOLD = 2048


# ---------------------------------------------------------------------------
# Spec trees
# ---------------------------------------------------------------------------

def _stack_specs(specs: PyTree, n: int) -> PyTree:
    return jax.tree_util.tree_map(
        lambda s: Spec((n,) + s.shape, ("layers",) + s.axes, s.init, s.scale),
        specs, is_leaf=lambda x: isinstance(x, Spec))


def _layer_specs(cfg: ModelConfig) -> dict:
    fam = cfg.family
    if fam in ("dense", "vlm"):
        return {"ln1": rmsnorm_spec(cfg.d_model),
                "attn": attention_specs(cfg),
                "ln2": rmsnorm_spec(cfg.d_model),
                "mlp": mlp_specs(cfg.d_model, cfg.d_ff)}
    if fam == "moe":
        return {"ln1": rmsnorm_spec(cfg.d_model),
                "attn": attention_specs(cfg),
                "ln2": rmsnorm_spec(cfg.d_model),
                "moe": moe_specs(cfg.d_model, cfg.moe)}
    if fam == "ssm":
        return {"ln": rmsnorm_spec(cfg.d_model),
                "mixer": ssm_specs(cfg)}
    if fam == "audio":   # decoder layer
        return {"ln1": rmsnorm_spec(cfg.d_model),
                "self_attn": attention_specs(cfg),
                "ln2": rmsnorm_spec(cfg.d_model),
                "cross_attn": attention_specs(cfg),
                "ln3": rmsnorm_spec(cfg.d_model),
                "mlp": mlp_specs(cfg.d_model, cfg.d_ff)}
    raise ValueError(fam)


def _hybrid_layer_specs(cfg: ModelConfig, i: int) -> dict:
    if cache_lib.hybrid_layer_is_attention(cfg, i):
        temporal = attention_specs(cfg)
        kind = "attn"
    else:
        temporal = rglru_specs(cfg)
        kind = "rec"
    return {"kind": kind,       # static marker, stripped before init
            "ln1": rmsnorm_spec(cfg.d_model),
            "temporal": temporal,
            "ln2": rmsnorm_spec(cfg.d_model),
            "mlp": mlp_specs(cfg.d_model, cfg.d_ff)}


def model_specs(cfg: ModelConfig, vocab_pad_multiple: int = 128) -> PyTree:
    vp = cfg.padded_vocab(vocab_pad_multiple)
    specs: Dict[str, Any] = {
        "embed": Spec((vp, cfg.d_model), ("vocab", "embed"), scale=0.02),
        "final_norm": rmsnorm_spec(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = Spec((cfg.d_model, vp), ("embed", "vocab"), scale=0.02)
    if cfg.family == "hybrid":
        # homogeneous (rec, ..., rec, attn) groups scanned over a stacked
        # leading axis + an unrolled remainder; a fully-unrolled 26-layer
        # remat graph takes XLA SPMD >10 min to partition (measured)
        gsz = cfg.rglru.blocks_per_attention + 1
        ngroups, tail = divmod(cfg.num_layers, gsz)
        rec = {k: v for k, v in _hybrid_layer_specs(cfg, 0).items()
               if k != "kind"}
        attn = {k: v for k, v in _hybrid_layer_specs(cfg, gsz - 1).items()
                if k != "kind"}
        group = {"rec": _stack_specs(rec, cfg.rglru.blocks_per_attention),
                 "attn": attn}
        specs["layers"] = {
            "groups": _stack_specs(group, ngroups) if ngroups else None,
            "tail": tuple({k: v for k, v in
                           _hybrid_layer_specs(cfg, ngroups * gsz + j).items()
                           if k != "kind"} for j in range(tail)),
        }
    else:
        specs["layers"] = _stack_specs(_layer_specs(cfg), cfg.num_layers)
    if cfg.is_encoder_decoder:
        enc_cfg = cfg
        enc_layer = {"ln1": rmsnorm_spec(cfg.d_model),
                     "attn": attention_specs(enc_cfg),
                     "ln2": rmsnorm_spec(cfg.d_model),
                     "mlp": mlp_specs(cfg.d_model, cfg.d_ff)}
        specs["enc_layers"] = _stack_specs(enc_layer, cfg.num_encoder_layers)
        specs["enc_norm"] = rmsnorm_spec(cfg.d_model)
    return specs


# ---------------------------------------------------------------------------
# Attention sublayer (all modes)
# ---------------------------------------------------------------------------

def _attn_sublayer(p: dict, cfg: ModelConfig, x: jax.Array, *,
                   mode: str, positions: jax.Array,
                   rope_positions: jax.Array,
                   input_mask: Optional[jax.Array],
                   kv_buf: Optional[Tuple[jax.Array, jax.Array]],
                   kv_pos: Optional[jax.Array],
                   window: Optional[int],
                   causal: bool = True,
                   attn_sharding=None,
                   block_table: Optional[jax.Array] = None,
                   write_mask: Optional[jax.Array] = None,
                   kv_pos_pool: Optional[jax.Array] = None,
                   ) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """One attention sublayer.  ``positions`` are sequence indices (mask
    logic); ``rope_positions`` feed RoPE/M-RoPE (identical except VLM).

    With ``block_table`` set, ``kv_buf`` holds this layer's slice of the
    shared block pool ``[N, bs, KV, D]`` and ``kv_pos`` the per-sequence
    gathered position view; KV writes go through the table (``write_mask``
    drops per-token writes so a sequence stays inside its block budget).
    Decode attention then reads straight off the pool through the
    block-table-indexed Pallas kernel on TPU (``kv_pos_pool`` is the
    pool-level position map it needs), or over the gathered per-sequence
    view on the XLA reference path (exact, materializing).

    A 4-tuple ``kv_buf`` ``(k, v, k_scale, v_scale)`` is the int8
    quantized pool (DESIGN.md §13): writes quantize (values + per-slot
    amax scales), decode reads dequantize — in-register inside the
    Pallas kv-sweep, or via the gathered f32 view on the XLA path — and
    prefill attends over fake-quantized fresh K/V so every read of a
    stored vector (cold prefill, warm tail, decode/verify) sees the
    identical quantized values."""
    q, k, v = qkv_project(p, cfg, x, rope_positions)
    b, t = x.shape[:2]
    quant = kv_buf is not None and len(kv_buf) == 4

    def pin_heads(arr):
        # [B, T, H, D] head-dim TP constraint: sharding does not propagate
        # reliably into the flash scan bodies without it (measured: the
        # whole attention ran replicated on the model axis)
        if attn_sharding is not None and arr.shape[2] % attn_sharding[1] == 0:
            return jax.lax.with_sharding_constraint(arr, attn_sharding[0])
        return arr

    def expand_kv(kk, vv):
        # GQA -> MHA expansion for the XLA attention path: kv-head counts
        # (2..16) rarely divide the 16-way model axis, so grouped einsums
        # de-shard and run replicated (measured 16x attention blow-up in
        # the dry-run).  Broadcasting KV to all query heads keeps every
        # attention tensor sharded on the full head dim; the Pallas kernel
        # does native GQA grouping on TPU instead (repro/kernels).
        # When the kv count already divides the TP axis (e.g. via
        # kv_head_pad), grouped attention shards natively — skip.
        g = q.shape[2] // kk.shape[2]
        if g == 1 or (attn_sharding is not None
                      and kk.shape[2] % attn_sharding[1] == 0):
            return pin_heads(kk), pin_heads(vv)
        return (pin_heads(jnp.repeat(kk, g, axis=2)),
                pin_heads(jnp.repeat(vv, g, axis=2)))

    def pad_kv(kk, vv):
        # exact KV-head replication (kv_head_pad, §Perf): padded head j is
        # real head j // r, matching the q-head regrouping exactly
        pad = cfg.kv_head_pad
        if pad is None or kk.shape[2] >= pad:
            return kk, vv
        r = pad // kk.shape[2]
        return jnp.repeat(kk, r, axis=2), jnp.repeat(vv, r, axis=2)

    if mode == "train" or (mode == "prefill" and kv_buf is None):
        q = pin_heads(q)
        ke, ve = expand_kv(k, v)
        if t >= BLOCKWISE_THRESHOLD:
            out = flash_attend(q, ke, ve, kv_valid=input_mask,
                               window=window, causal=causal)
        else:
            kv_valid = (input_mask if input_mask is not None
                        else jnp.ones((b, t), bool))
            out = attend(q, ke, ve, q_pos=positions, kv_pos=positions,
                         kv_valid=kv_valid, window=window, causal=causal)
        return attn_output(p, out), None

    if mode == "prefill":
        # attend over fresh k/v, then store into the ring / block pool.
        # Quantized pool: attention reads the fake-quantized fresh K/V —
        # exactly the values any later dequantized read reconstructs
        # (per-head quantization commutes with pad_kv's exact head
        # replication), so cold and warm streams stay identical.
        kp_, vp_ = pad_kv(k, v)
        if quant:
            ke, ve = expand_kv(cache_lib.fake_quantize_kv(k),
                               cache_lib.fake_quantize_kv(v))
        else:
            ke, ve = expand_kv(k, v)
        if t >= BLOCKWISE_THRESHOLD:
            out = flash_attend(q, ke, ve, kv_valid=input_mask,
                               window=window, causal=causal)
        else:
            kv_valid = (input_mask if input_mask is not None
                        else jnp.ones((b, t), bool))
            out = attend(q, ke, ve, q_pos=positions, kv_pos=positions,
                         kv_valid=kv_valid, window=window, causal=causal)
        if quant:
            new_bufs = cache_lib.write_kv_paged_quant(
                kv_buf[0], kv_buf[1], kv_buf[2], kv_buf[3], kp_, vp_,
                positions, block_table)
            return attn_output(p, out), new_bufs
        if block_table is not None:
            k_buf, v_buf = cache_lib.write_kv_paged(
                kv_buf[0], kv_buf[1], kp_, vp_, positions, block_table)
        else:
            k_buf, v_buf = cache_lib.write_kv(kv_buf[0], kv_buf[1], kp_, vp_,
                                              positions)
        return attn_output(p, out), (k_buf, v_buf)

    # decode / verify: write first, then attend over the ring / pool view
    kp_, vp_ = pad_kv(k, v)
    if quant:
        k_buf, v_buf, ks_buf, vs_buf = cache_lib.write_kv_paged_quant(
            kv_buf[0], kv_buf[1], kv_buf[2], kv_buf[3], kp_, vp_,
            positions, block_table, keep=write_mask)
        new_bufs = (k_buf, v_buf, ks_buf, vs_buf)
        if kv_pos_pool is not None and kernel_ops.on_tpu():
            # TPU data plane: int8 tiles + scale columns stream through
            # the table lookup and dequantize in-register in the sweep
            out = kernel_ops.paged_ragged_attention_quant(
                q, k_buf, v_buf, ks_buf, vs_buf, block_table, positions,
                kv_pos_pool, window=window)
            return attn_output(p, out), new_bufs
        k_att, v_att = cache_lib.gather_paged_kv_quant(
            k_buf, v_buf, ks_buf, vs_buf, block_table)
    elif block_table is not None:
        k_buf, v_buf = cache_lib.write_kv_paged(
            kv_buf[0], kv_buf[1], kp_, vp_, positions, block_table,
            keep=write_mask)
        new_bufs = (k_buf, v_buf)
        if kv_pos_pool is not None and kernel_ops.on_tpu():
            # TPU data plane: the kernel's index maps dereference the
            # block table — no per-sequence dense view is materialized
            out = kernel_ops.paged_ragged_attention(
                q, k_buf, v_buf, block_table, positions, kv_pos_pool,
                window=window)
            return attn_output(p, out), new_bufs
        k_att, v_att = cache_lib.gather_paged_kv(k_buf, v_buf, block_table)
    else:
        k_buf, v_buf = cache_lib.write_kv(kv_buf[0], kv_buf[1], kp_, vp_,
                                          positions)
        new_bufs = (k_buf, v_buf)
        k_att, v_att = k_buf, v_buf
    kv_valid = kv_pos >= 0
    ke, ve = expand_kv(k_att, v_att)
    out = attend(q, ke, ve, q_pos=positions, kv_pos=kv_pos,
                 kv_valid=kv_valid, window=window)
    return attn_output(p, out), new_bufs


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _token_block(p: dict, cfg: ModelConfig, x: jax.Array, layer_cache: PyTree,
                 ctx: dict) -> Tuple[jax.Array, PyTree, dict]:
    """One residual block for scanned families. ``ctx`` carries mode,
    positions, masks; returns (x, new_layer_cache, aux)."""
    fam = cfg.family
    aux: dict = {}
    if fam == "ssm":
        h, new_state = mamba_mixer(
            p["mixer"], cfg, rmsnorm(x, p["ln"], cfg.norm_eps),
            state=layer_cache, update_mask=ctx.get("update_mask"),
            use_chunked=ctx["mode"] in ("train", "prefill"))
        return x + h, new_state, aux

    kv = None
    if layer_cache is not None:
        if "k_scale" in layer_cache:     # int8 pool: scales ride along
            kv = (layer_cache["k"], layer_cache["v"],
                  layer_cache["k_scale"], layer_cache["v_scale"])
        else:
            kv = (layer_cache["k"], layer_cache["v"])
    h, new_kv = _attn_sublayer(
        p["attn"], cfg, rmsnorm(x, p["ln1"], cfg.norm_eps),
        mode=ctx["mode"], positions=ctx["positions"],
        rope_positions=ctx["rope_positions"], input_mask=ctx.get("input_mask"),
        kv_buf=kv, kv_pos=ctx.get("kv_pos"), window=cfg.attention_window,
        attn_sharding=ctx.get("attn_sharding"),
        block_table=ctx.get("block_table"), write_mask=ctx.get("write_mask"),
        kv_pos_pool=ctx.get("kv_pos_pool"))
    x = x + h

    if fam == "moe":
        h, moe_aux = moe_apply(p["moe"], cfg.moe,
                               rmsnorm(x, p["ln2"], cfg.norm_eps),
                               shardings=ctx.get("moe_sharding"))
        aux.update(moe_aux)
    else:
        h = mlp_apply(p["mlp"], rmsnorm(x, p["ln2"], cfg.norm_eps))
    x = x + h
    new_cache = None
    if layer_cache is not None and fam != "ssm":
        new_cache = dict(layer_cache)
        if new_kv is not None:
            new_cache["k"], new_cache["v"] = new_kv[0], new_kv[1]
            if len(new_kv) == 4:
                new_cache["k_scale"], new_cache["v_scale"] = new_kv[2:]
    return x, new_cache, aux


def _cross_attend(p: dict, cfg: ModelConfig, x: jax.Array,
                  ck: jax.Array, cv: jax.Array,
                  enc_valid: jax.Array) -> jax.Array:
    """Decoder->encoder cross attention (no rope on q/k, standard for
    enc-dec translation stacks)."""
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    b, t = x.shape[:2]
    s = ck.shape[1]
    zeros = jnp.zeros((b, t), jnp.int32)
    out = attend(q, ck, cv, q_pos=zeros, kv_pos=jnp.zeros((b, s), jnp.int32),
                 kv_valid=enc_valid, window=None, causal=False)
    return attn_output(p, out)


def _audio_block(p: dict, cfg: ModelConfig, x: jax.Array, layer_cache: PyTree,
                 ctx: dict) -> Tuple[jax.Array, PyTree, dict]:
    kv = ((layer_cache["k"], layer_cache["v"])
          if layer_cache is not None and "k" in layer_cache else None)
    h, new_kv = _attn_sublayer(
        p["self_attn"], cfg, rmsnorm(x, p["ln1"], cfg.norm_eps),
        mode=ctx["mode"], positions=ctx["positions"],
        rope_positions=ctx["rope_positions"], input_mask=ctx.get("input_mask"),
        kv_buf=kv, kv_pos=ctx.get("kv_pos"), window=None,
        attn_sharding=ctx.get("attn_sharding"))
    x = x + h
    ck = layer_cache["cross_k"] if layer_cache is not None else ctx["cross_k"]
    cv = layer_cache["cross_v"] if layer_cache is not None else ctx["cross_v"]
    enc_valid = ctx["enc_valid"]
    x = x + _cross_attend(p["cross_attn"], cfg,
                          rmsnorm(x, p["ln2"], cfg.norm_eps), ck, cv, enc_valid)
    x = x + mlp_apply(p["mlp"], rmsnorm(x, p["ln3"], cfg.norm_eps))
    new_cache = None
    if layer_cache is not None:
        new_cache = dict(layer_cache)
        if new_kv is not None:
            new_cache["k"], new_cache["v"] = new_kv
    return x, new_cache, dict()


def _audio_train_stack(params: PyTree, cfg: ModelConfig,
                       encoder_embeds: jax.Array,
                       enc_valid: Optional[jax.Array]) -> PyTree:
    """Encoder pass + per-decoder-layer cross KV, for cache-less (train)
    audio forwards."""
    enc_out = encode(params, cfg, encoder_embeds, enc_valid)
    ck, cv = build_cross_cache(params, cfg, enc_out)
    return {"cross_k": ck, "cross_v": cv}


def _hybrid_block(p: dict, cfg: ModelConfig, i: int, x: jax.Array,
                  layer_cache: PyTree, ctx: dict
                  ) -> Tuple[jax.Array, PyTree, dict]:
    is_attn = cache_lib.hybrid_layer_is_attention(cfg, i)
    if is_attn:
        kv = ((layer_cache["k"], layer_cache["v"])
              if layer_cache is not None else None)
        h, new_kv = _attn_sublayer(
            p["temporal"], cfg, rmsnorm(x, p["ln1"], cfg.norm_eps),
            mode=ctx["mode"], positions=ctx["positions"],
            rope_positions=ctx["rope_positions"],
            input_mask=ctx.get("input_mask"), kv_buf=kv,
            kv_pos=ctx.get("kv_pos"),
            window=cfg.rglru.local_attention_window,
            attn_sharding=ctx.get("attn_sharding"))
        new_cache = None
        if layer_cache is not None:
            new_cache = dict(layer_cache)
            if new_kv is not None:
                new_cache["k"], new_cache["v"] = new_kv
    else:
        h, new_state = rglru_block(
            p["temporal"], cfg, rmsnorm(x, p["ln1"], cfg.norm_eps),
            state=layer_cache, update_mask=ctx.get("update_mask"),
            sequential=ctx["mode"] == "decode")
        new_cache = new_state if layer_cache is not None else None
    x = x + h
    x = x + mlp_apply(p["mlp"], rmsnorm(x, p["ln2"], cfg.norm_eps))
    return x, new_cache, dict()


# ---------------------------------------------------------------------------
# Stacks
# ---------------------------------------------------------------------------

def _zero_aux(cfg: ModelConfig) -> dict:
    if cfg.family == "moe":
        return {"load_balance_loss": jnp.zeros((), jnp.float32),
                "router_z_loss": jnp.zeros((), jnp.float32),
                "expert_fraction": jnp.zeros((cfg.moe.num_experts,), jnp.float32),
                "dropped_fraction": jnp.zeros((), jnp.float32)}
    return {}


# ``optimization_barrier`` has no differentiation rule; wrap it in an
# identity custom_vjp so the barrier still pins the remat stash layout on
# the forward pass while gradients flow straight through on the backward.
@jax.custom_vjp
def _stash_barrier(x: jax.Array) -> jax.Array:
    return jax.lax.optimization_barrier(x)


def _stash_barrier_fwd(x):
    return jax.lax.optimization_barrier(x), None


def _stash_barrier_bwd(_, g):
    return (g,)


_stash_barrier.defvjp(_stash_barrier_fwd, _stash_barrier_bwd)


def _scan_stack(params: PyTree, cfg: ModelConfig, x: jax.Array,
                stacked_cache: Optional[PyTree], ctx: dict, remat: bool
                ) -> Tuple[jax.Array, Optional[PyTree], dict]:
    block = _audio_block if cfg.family == "audio" else _token_block
    aux0 = _zero_aux(cfg)

    def body(carry, layer_in):
        xc, aux_acc = carry
        p_l, c_l = layer_in
        # barrier keeps the remat stash in the carry's own dtype (bf16):
        # without it XLA saves the f32 rmsnorm-converted copy of every
        # layer input (2x stash memory, measured in the dry-run)
        xc = _stash_barrier(xc)
        xc, c_new, aux = block(p_l, cfg, xc, c_l, ctx)
        if ctx.get("act_sharding") is not None:
            # sequence-parallel residual stream between blocks: bounds the
            # remat-stashed activations per chip (DESIGN.md §5)
            xc = jax.lax.with_sharding_constraint(xc, ctx["act_sharding"])
        aux_acc = jax.tree_util.tree_map(jnp.add, aux_acc, aux) if aux else aux_acc
        return (xc, aux_acc), c_new

    if remat:
        body = jax.checkpoint(body)
    (x, aux), new_cache = jax.lax.scan(body, (x, aux0),
                                       (params, stacked_cache))
    if cfg.family == "moe":
        aux = jax.tree_util.tree_map(lambda a: a / cfg.num_layers, aux)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Cache <-> per-layer views
# ---------------------------------------------------------------------------

def _stacked_cache_view(cfg: ModelConfig, cache: Optional[cache_lib.CacheT]
                        ) -> Optional[PyTree]:
    if cache is None:
        return None
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        out = {"k": cache["k"], "v": cache["v"]}
        if "k_scale" in cache:           # int8 pool: per-layer scales
            out["k_scale"] = cache["k_scale"]
            out["v_scale"] = cache["v_scale"]
        return out
    if fam == "ssm":
        return {"ssd": cache["ssd"], "conv": cache["conv"]}
    if fam == "audio":
        return {"k": cache["k"], "v": cache["v"],
                "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}
    raise ValueError(fam)


def _store_stacked(cfg: ModelConfig, cache: cache_lib.CacheT,
                   new_stack: PyTree) -> cache_lib.CacheT:
    out = dict(cache)
    for k, v in new_stack.items():
        if v is not None:
            out[k] = v
    return out


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _embed(params: PyTree, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    return params["embed"][tokens]


def _lm_head(params: PyTree, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        return jnp.einsum("btd,vd->btv", x, params["embed"])
    return jnp.einsum("btd,dv->btv", x, params["lm_head"])


def encode(params: PyTree, cfg: ModelConfig, embeds: jax.Array,
           enc_valid: Optional[jax.Array] = None) -> jax.Array:
    """Bidirectional encoder over frontend embeddings (audio)."""
    b, s, _ = embeds.shape
    if enc_valid is None:
        enc_valid = jnp.ones((b, s), bool)
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    ctx = {"mode": "train", "positions": positions,
           "rope_positions": positions, "input_mask": enc_valid}

    def body(x, p_l):
        h, _ = _attn_sublayer(
            p_l["attn"], cfg, rmsnorm(x, p_l["ln1"], cfg.norm_eps),
            mode="train", positions=ctx["positions"],
            rope_positions=ctx["rope_positions"], input_mask=enc_valid,
            kv_buf=None, kv_pos=None, window=None, causal=False)
        x = x + h
        x = x + mlp_apply(p_l["mlp"], rmsnorm(x, p_l["ln2"], cfg.norm_eps))
        return x, None

    x, _ = jax.lax.scan(body, embeds, params["enc_layers"])
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def build_cross_cache(params: PyTree, cfg: ModelConfig, enc_out: jax.Array
                      ) -> Tuple[jax.Array, jax.Array]:
    """Per-decoder-layer cross K/V from encoder output: [L,B,S,KV,D]."""
    def one(p_l):
        k = jnp.einsum("bsd,dhk->bshk", enc_out, p_l["cross_attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc_out, p_l["cross_attn"]["wv"])
        if cfg.qkv_bias:
            k = k + p_l["cross_attn"]["bk"]
            v = v + p_l["cross_attn"]["bv"]
        return k, v

    _, (ks, vs) = jax.lax.scan(lambda _, p: (None, one(p)), None,
                               params["layers"])
    return ks, vs


def _hybrid_forward(params: PyTree, cfg: ModelConfig, x: jax.Array,
                    cache, ctx: dict, remat: bool):
    """RecurrentGemma stack: scan over (rec x bpa, attn) groups + an
    unrolled remainder of rec layers.  Cache layout: rec caches in layer
    order (grouped prefix [ngroups*bpa], then tail), attn caches [ngroups].
    """
    gsz = cfg.rglru.blocks_per_attention + 1
    bpa = cfg.rglru.blocks_per_attention
    ngroups, tail = divmod(cfg.num_layers, gsz)
    lp = params["layers"]

    def rec_block(p_l, xx, c_l):
        h, new_state = rglru_block(
            p_l["temporal"], cfg, rmsnorm(xx, p_l["ln1"], cfg.norm_eps),
            state=c_l, update_mask=ctx.get("update_mask"),
            sequential=ctx["mode"] == "decode")
        xx = xx + h
        xx = xx + mlp_apply(p_l["mlp"], rmsnorm(xx, p_l["ln2"], cfg.norm_eps))
        return xx, (new_state if c_l is not None else None)

    def attn_block(p_l, xx, c_l):
        kv = (c_l["k"], c_l["v"]) if c_l is not None else None
        h, new_kv = _attn_sublayer(
            p_l["temporal"], cfg, rmsnorm(xx, p_l["ln1"], cfg.norm_eps),
            mode=ctx["mode"], positions=ctx["positions"],
            rope_positions=ctx["rope_positions"],
            input_mask=ctx.get("input_mask"), kv_buf=kv,
            kv_pos=ctx.get("kv_pos"),
            window=cfg.rglru.local_attention_window,
            attn_sharding=ctx.get("attn_sharding"),
            block_table=ctx.get("block_table"),
            write_mask=ctx.get("write_mask"),
            kv_pos_pool=ctx.get("kv_pos_pool"))
        xx = xx + h
        xx = xx + mlp_apply(p_l["mlp"], rmsnorm(xx, p_l["ln2"], cfg.norm_eps))
        c_new = None
        if c_l is not None:
            c_new = dict(c_l)
            if new_kv is not None:
                c_new["k"], c_new["v"] = new_kv
        return xx, c_new

    new_cache = dict(cache) if cache is not None else None
    if ngroups:
        if cache is not None:
            rg = cfg.rglru
            lru_g = cache["lru"][:ngroups * bpa].reshape(
                (ngroups, bpa) + cache["lru"].shape[1:])
            conv_g = cache["conv"][:ngroups * bpa].reshape(
                (ngroups, bpa) + cache["conv"].shape[1:])
            gcache = {"lru": lru_g, "conv": conv_g,
                      "k": cache["k"], "v": cache["v"]}
        else:
            gcache = None

        def group_body(xx, gin):
            p_g, c_g = gin
            new_rec_lru, new_rec_conv = [], []
            for j in range(bpa):
                p_r = jax.tree_util.tree_map(lambda a: a[j], p_g["rec"])
                c_r = (None if c_g is None else
                       {"lru": c_g["lru"][j], "conv": c_g["conv"][j]})
                xx, c_rn = rec_block(p_r, xx, c_r)
                if c_rn is not None:
                    new_rec_lru.append(c_rn["lru"])
                    new_rec_conv.append(c_rn["conv"])
            c_a = (None if c_g is None else
                   {"k": c_g["k"], "v": c_g["v"]})
            xx, c_an = attn_block(p_g["attn"], xx, c_a)
            if ctx.get("act_sharding") is not None:
                xx = jax.lax.with_sharding_constraint(xx, ctx["act_sharding"])
            c_out = None
            if c_g is not None:
                c_out = {"lru": jnp.stack(new_rec_lru),
                         "conv": jnp.stack(new_rec_conv),
                         "k": c_an["k"], "v": c_an["v"]}
            return xx, c_out

        body = jax.checkpoint(group_body) if remat else group_body
        x, gnew = jax.lax.scan(body, x, (lp["groups"], gcache))
        if cache is not None:
            new_cache["k"], new_cache["v"] = gnew["k"], gnew["v"]
            lru_flat = gnew["lru"].reshape((-1,) + gnew["lru"].shape[2:])
            conv_flat = gnew["conv"].reshape((-1,) + gnew["conv"].shape[2:])
        else:
            lru_flat = conv_flat = None

    # unrolled remainder (rec layers)
    tail_lru, tail_conv = [], []
    for j in range(tail):
        p_l = lp["tail"][j]
        idx = ngroups * bpa + j
        c_l = (None if cache is None else
               {"lru": cache["lru"][idx], "conv": cache["conv"][idx]})
        x, c_n = rec_block(p_l, x, c_l)
        if c_n is not None:
            tail_lru.append(c_n["lru"])
            tail_conv.append(c_n["conv"])
    if cache is not None:
        parts_l = ([lru_flat] if ngroups else []) +             ([jnp.stack(tail_lru)] if tail_lru else [])
        parts_c = ([conv_flat] if ngroups else []) +             ([jnp.stack(tail_conv)] if tail_conv else [])
        if parts_l:
            new_cache["lru"] = jnp.concatenate(parts_l, 0)
            new_cache["conv"] = jnp.concatenate(parts_c, 0)
    return x, new_cache


def forward(params: PyTree, cfg: ModelConfig, tokens: Optional[jax.Array],
            *, cache: Optional[cache_lib.CacheT] = None, mode: str = "train",
            embeds: Optional[jax.Array] = None,
            input_mask: Optional[jax.Array] = None,
            rope_positions: Optional[jax.Array] = None,
            update_mask: Optional[jax.Array] = None,
            encoder_embeds: Optional[jax.Array] = None,
            enc_valid: Optional[jax.Array] = None,
            write_mask: Optional[jax.Array] = None,
            act_sharding=None, attn_sharding=None, moe_sharding=None,
            remat: bool = False
            ) -> Tuple[jax.Array, Optional[cache_lib.CacheT], dict]:
    """Unified forward. Returns (logits [B,T,Vp], new_cache, aux).

    ``write_mask [B, T]`` (decode mode, paged caches only): positions
    whose mask is False skip the KV write entirely — the speculative
    round masks per-sequence draft tails so a short-SL sequence never
    writes outside its allocated blocks.  Dense ring caches ignore it
    (writes behind ``length`` are overwritten-or-masked anyway)."""
    assert mode in ("train", "prefill", "decode")
    x = embeds if embeds is not None else _embed(params, cfg, tokens)
    b, t = x.shape[:2]

    if mode in ("train", "prefill") or cache is None:
        positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    else:
        positions = cache["length"][:, None] + jnp.arange(t)[None]
    if rope_positions is None:
        rope_positions = positions

    if (mode == "prefill" and input_mask is not None
            and update_mask is None and has_recurrent_state(cfg)):
        # right-padded ragged prompts: recurrent state must not advance
        # over pad positions (attention handles this via kv validity)
        update_mask = input_mask.astype(jnp.float32)
    ctx = {"mode": mode, "positions": positions,
           "rope_positions": rope_positions, "input_mask": input_mask,
           "update_mask": update_mask, "act_sharding": act_sharding,
           "attn_sharding": attn_sharding, "moe_sharding": moe_sharding}
    new_cache = None

    kv_pos_store = None
    if cache is not None and "kv_pos" in cache:
        valid = input_mask if mode == "prefill" else None
        if cache_lib.is_paged(cache):
            keep = write_mask if mode == "decode" else None
            kv_pos_store = cache_lib.write_pos_paged(
                cache["kv_pos"], positions, cache["block_table"], valid, keep)
            ctx["kv_pos"] = cache_lib.gather_paged_pos(kv_pos_store,
                                                       cache["block_table"])
            ctx["block_table"] = cache["block_table"]
            if mode == "decode":
                ctx["write_mask"] = write_mask
                ctx["kv_pos_pool"] = kv_pos_store
        else:
            kv_pos_store = cache_lib.write_pos(cache["kv_pos"], positions,
                                               valid)
            ctx["kv_pos"] = kv_pos_store
    if cfg.family == "audio":
        if cache is not None:
            ctx["enc_valid"] = cache["enc_valid"]
        else:
            assert encoder_embeds is not None, \
                "audio train mode needs encoder_embeds"
            ctx["enc_valid"] = (enc_valid if enc_valid is not None else
                                jnp.ones(encoder_embeds.shape[:2], bool))

    if cfg.family == "hybrid":
        aux = {}
        x, new_cache = _hybrid_forward(params, cfg, x, cache, ctx,
                                       remat and mode == "train")
        if new_cache is not None and kv_pos_store is not None:
            new_cache["kv_pos"] = kv_pos_store
    else:
        stacked = _stacked_cache_view(cfg, cache)
        if cfg.family == "audio" and cache is None:
            stacked = _audio_train_stack(params, cfg, encoder_embeds,
                                         ctx["enc_valid"])
        x, new_stack, aux = _scan_stack(params["layers"], cfg, x, stacked,
                                        ctx, remat and mode == "train")
        if cache is not None:
            new_cache = _store_stacked(cfg, cache, new_stack)
            if kv_pos_store is not None and "kv_pos" in cache:
                new_cache["kv_pos"] = kv_pos_store

    logits = _lm_head(params, cfg, x)
    return logits, new_cache, aux


def commit(params: PyTree, cfg: ModelConfig, tokens: jax.Array,
           snapshot: cache_lib.CacheT, verified: cache_lib.CacheT,
           n_committed: jax.Array) -> cache_lib.CacheT:
    """Commit ``n_committed[b]`` of the T tokens just verified.

    KV families: stale ring slots are masked by ``length`` — O(1).
    Recurrent families: masked re-advance from the snapshot (identity on
    masked steps) so state reflects exactly the accepted prefix.
    """
    new_len = snapshot["length"] + n_committed.astype(jnp.int32)
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        return cache_lib.commit_length(verified, new_len)
    t = tokens.shape[1]
    update_mask = (jnp.arange(t)[None] < n_committed[:, None]).astype(jnp.float32)
    _, advanced, _ = forward(params, cfg, tokens, cache=snapshot,
                             mode="decode", update_mask=update_mask)
    return cache_lib.commit_length(advanced, new_len)


def has_recurrent_state(cfg: ModelConfig) -> bool:
    return cfg.family in ("ssm", "hybrid")
