"""DSDE serving engine: continuous batching + per-sequence dynamic SL.

The engine composes:
  * :class:`LookaheadScheduler`  — queue/slot admission from SL predictions;
  * ``spec_decode_round``        — the jitted speculative round (bucketed by
    K so there is one XLA program per draft length, never per step);
  * slot-wise prefill            — prompts are bucketed to powers of two and
    right-padded, so admission also reuses a small set of programs.

This runs for real on CPU (reduced models) and is the same code path the
TPU launch scripts drive; only meshes/shardings differ (repro/launch).
"""
from __future__ import annotations

import functools
import math
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import spec_decode as sd
from repro.core.config import (ModelConfig, ServingConfig, SpecDecodeConfig)
from repro.core.policies import build_policy
from repro.core.sampling import sample_token
from repro.models import cache as cache_lib
from repro.models.transformer import forward
from repro.serving.request import Request, RequestState
from repro.serving.scheduler import LookaheadScheduler

PyTree = Any

_BATCH_AXIS0 = ("length", "kv_pos", "enc_valid")


def _set_slot(big: PyTree, row: PyTree, slot) -> PyTree:
    """Scatter a batch=1 cache row into the batched cache at ``slot``."""
    out = {}
    for k, v in big.items():
        r = row[k]
        if k in _BATCH_AXIS0:
            out[k] = v.at[slot].set(r[0])
        else:
            out[k] = v.at[:, slot].set(r[:, 0])
    return out


@functools.partial(jax.jit, static_argnames=("cfg", "max_len", "prompt_bucket"))
def _prefill_row(params: PyTree, cfg: ModelConfig, tokens: jax.Array,
                 prompt_len: jax.Array, max_len: int, prompt_bucket: int,
                 ) -> Tuple[PyTree, jax.Array]:
    """Prefill one request into a fresh single-row cache.  ``tokens`` is
    right-padded to ``prompt_bucket``.  Returns (cache_row, last_logits)."""
    del prompt_bucket  # shape is already static via tokens
    cache = cache_lib.cache_struct(cfg, 1, max_len, jnp.float32)
    mask = (jnp.arange(tokens.shape[1])[None] < prompt_len)
    logits, cache, _ = forward(params, cfg, tokens, cache=cache,
                               mode="prefill", input_mask=mask)
    cache["length"] = jnp.full((1,), prompt_len, jnp.int32)
    last = logits[0, jnp.maximum(prompt_len - 1, 0)]
    return cache, last


def _bucket(n: int, minimum: int = 16) -> int:
    return max(minimum, 1 << math.ceil(math.log2(max(n, 1))))


class ServingEngine:
    def __init__(self, params_target: PyTree, cfg_target: ModelConfig,
                 params_draft: PyTree, cfg_draft: ModelConfig,
                 spec: SpecDecodeConfig, serving: ServingConfig,
                 seed: int = 0):
        self.pt, self.cfg_t = params_target, cfg_target
        self.pd, self.cfg_d = params_draft, cfg_draft
        self.spec = spec
        self.policy = build_policy(spec)
        self.serving = serving
        self.scheduler = LookaheadScheduler(serving, spec,
                                            policy=self.policy)
        self.key = jax.random.PRNGKey(seed)
        b = serving.max_batch_size
        self.state = sd.init_round_state(
            cfg_target, cfg_draft, spec, b, serving.max_seq_len,
            self._next_key())
        # host-side mirror of state.sl_next, refreshed once per round while
        # the round's other outputs are already being transferred — the
        # bucket choice never triggers its own device->host sync.
        self._sl_next_host = np.full((b,), self.policy.initial_sl_value(),
                                     np.int32)
        # telemetry
        self._finished_at_prefill = []
        self.rounds = 0
        self.draft_steps = 0            # padded bucket steps (k+1)
        self.draft_steps_effective = 0  # max per-seq proposals + 1 (what a
                                        # dynamic-shape runtime would run)
        self.emitted_total = 0
        self.round_log: List[Dict[str, float]] = []

    # ------------------------------------------------------------------ rng
    def _next_key(self) -> jax.Array:
        self.key, k = jax.random.split(self.key)
        return k

    # ------------------------------------------------------------- lifecycle
    def submit(self, req: Request) -> None:
        self.scheduler.submit(req)

    def _admit(self) -> None:
        for req in self.scheduler.admit():
            self._prefill_into_slot(req)
            if req.done:   # finished at prefill (eos / max_new_tokens == 1)
                self.scheduler.release(req)
                self._finished_at_prefill.append(req)

    def _prefill_into_slot(self, req: Request) -> None:
        slot = req.slot
        bucket = _bucket(len(req.prompt))
        toks = np.full((1, bucket), 0, np.int32)
        toks[0, :len(req.prompt)] = req.prompt
        row_t, last_t = _prefill_row(self.pt, self.cfg_t, jnp.asarray(toks),
                                     jnp.int32(len(req.prompt)),
                                     self.serving.max_seq_len, bucket)
        row_d, _ = _prefill_row(self.pd, self.cfg_d, jnp.asarray(toks),
                                jnp.int32(len(req.prompt)),
                                self.serving.max_seq_len, bucket)
        st = self.state
        tc = _set_slot(st.target_cache, row_t, slot)
        dc = _set_slot(st.draft_cache, row_d, slot)
        pend = sample_token(self._next_key(), last_t[None],
                            self.spec.temperature,
                            self.cfg_t.vocab_size)[0].astype(jnp.int32)
        # the prefill-sampled token IS the first generated token
        first = int(pend)
        req.output.append(first)
        self.emitted_total += 1
        req.first_token_time = time.monotonic()
        if ((req.eos_token_id is not None and first == req.eos_token_id)
                or len(req.output) >= req.max_new_tokens):
            req.state = RequestState.FINISHED
            req.finish_time = req.first_token_time
        rows = jnp.zeros((self.serving.max_batch_size,), bool).at[slot].set(True)
        ps = self.policy.reset_rows(st.policy_state, rows)
        sl0_val = self.policy.initial_sl_value()
        sl0 = st.sl_next.at[slot].set(sl0_val)
        self._sl_next_host[slot] = sl0_val
        self.state = st._replace(
            target_cache=tc, draft_cache=dc, policy_state=ps,
            pending=st.pending.at[slot].set(pend), sl_next=sl0)

    # ------------------------------------------------------------------ step
    def step(self) -> List[Request]:
        """Admit, run one speculative round, distribute tokens.  Returns
        requests finished this step."""
        self._admit()
        finished_early = self._finished_at_prefill
        self._finished_at_prefill = []
        running = self.scheduler.running
        if not running:
            return finished_early
        active_mask = self.scheduler.active_mask
        active = jnp.asarray(active_mask)
        k = self.policy.pick_bucket(self._sl_next_host, active_mask)
        self.state, out = sd.spec_decode_round(
            self.pt, self.pd, self.cfg_t, self.cfg_d, self.spec, k,
            self.state, active)
        self.rounds += 1
        self.draft_steps += (k + 1) if k > 0 else 0

        emitted = np.asarray(out.emitted)
        n_emit = np.asarray(out.num_emitted)
        n_acc = np.asarray(out.num_accepted)
        n_prop = np.asarray(out.num_proposed)
        self._sl_next_host = np.array(self.state.sl_next)   # writable copy
        self.scheduler.update_predictions(self._sl_next_host)
        if k > 0:
            self.draft_steps_effective += int(n_prop.max()) + 1
        round_rec = {
            "k": k,
            "emitted": float(n_emit[active_mask].sum()),
            "accepted": float(n_acc.sum()), "proposed": float(n_prop.sum()),
        }

        finished = finished_early
        now = time.monotonic()
        for req in list(running):
            i = req.slot
            toks = emitted[i, :n_emit[i]].tolist()
            if req.first_token_time is None and toks:
                req.first_token_time = now
            req.rounds += 1
            req.accepted_tokens += int(n_acc[i])
            req.proposed_tokens += int(n_prop[i])
            for t in toks:
                if t == self.cfg_t.vocab_size:   # pad sentinel
                    continue
                req.output.append(int(t))
                self.emitted_total += 1
                eos = req.eos_token_id
                if ((eos is not None and t == eos)
                        or len(req.output) >= req.max_new_tokens):
                    req.state = RequestState.FINISHED
                    req.finish_time = now
                    break
            if req.done:
                self.scheduler.release(req)
                finished.append(req)
        # per-sequence KV slots the policy plans for the NEXT round — the
        # capacity-planning view of intra-batch heterogeneity.  Logged
        # after release so just-finished slots are not counted.
        round_rec["lookahead"] = float(
            self.scheduler.lookahead_slots()[self.scheduler.active_mask]
            .sum())
        self.round_log.append(round_rec)
        return finished

    # ------------------------------------------------------------------- run
    def run(self, requests: Sequence[Request],
            max_rounds: Optional[int] = None) -> Dict[str, float]:
        t0 = time.monotonic()
        for r in requests:
            self.submit(r)
        done: List[Request] = []
        while self.scheduler.has_work():
            done += self.step()
            if max_rounds is not None and self.rounds >= max_rounds:
                break
        wall = time.monotonic() - t0
        lat = [r.latency() for r in done if r.latency() is not None]
        return {
            "wall_time_s": wall,
            "requests_finished": len(done),
            "tokens_emitted": self.emitted_total,
            "rounds": self.rounds,
            "draft_steps": self.draft_steps,
            "draft_steps_effective": self.draft_steps_effective,
            # paper's BE: tokens per target verification, per sequence
            "block_efficiency": float(np.mean(
                [r.block_efficiency() for r in done])) if done else float("nan"),
            "batch_tokens_per_round": self.emitted_total / max(self.rounds, 1),
            "throughput_tok_s": self.emitted_total / max(wall, 1e-9),
            "mean_latency_s": float(np.mean(lat)) if lat else float("nan"),
            "p95_latency_s": float(np.percentile(lat, 95)) if lat else float("nan"),
            "mean_acceptance": float(np.mean(
                [r.acceptance_rate() for r in done])) if done else float("nan"),
        }
