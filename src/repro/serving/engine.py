"""DSDE serving engine: continuous batching + per-sequence dynamic SL.

The engine composes:
  * :class:`LookaheadScheduler`  — queue/slot admission from SL predictions
    plus, under the paged KV layout, the block allocator (grow on demand,
    preempt when the pool runs dry);
  * ``spec_decode_round``        — the jitted speculative round (bucketed by
    K so there is one XLA program per draft length, never per step);
  * slot-wise prefill            — prompts are bucketed to powers of two and
    right-padded, so admission also reuses a small set of programs.  Dense
    slots prefill a fresh cache row; paged requests prefill straight into
    their allocated pool blocks through the block table.

This runs for real on CPU (reduced models) and is the same code path the
TPU launch scripts drive; only meshes/shardings differ (repro/launch).
"""
from __future__ import annotations

import functools
import math
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import spec_decode as sd
from repro.core.config import (ModelConfig, ServingConfig, SpecDecodeConfig)
from repro.core.policies import build_policy
from repro.core.sampling import sample_token
from repro.models import cache as cache_lib
from repro.models.transformer import forward
from repro.serving.request import Request, RequestState
from repro.serving.scheduler import LookaheadScheduler

PyTree = Any

_BATCH_AXIS0 = ("length", "kv_pos", "enc_valid", "block_table")


def _set_slot(big: PyTree, row: PyTree, slot) -> PyTree:
    """Scatter a batch=1 cache row into the batched cache at ``slot``."""
    out = {}
    for k, v in big.items():
        r = row[k]
        if k in _BATCH_AXIS0:
            out[k] = v.at[slot].set(r[0])
        else:
            out[k] = v.at[:, slot].set(r[:, 0])
    return out


def _prefill_forward(params: PyTree, cfg: ModelConfig, cache: PyTree,
                     tokens: jax.Array, prompt_len: jax.Array
                     ) -> Tuple[PyTree, jax.Array]:
    """Shared prefill tail: masked forward over the right-padded prompt,
    commit ``length``, pick the last real token's logits."""
    mask = (jnp.arange(tokens.shape[1])[None] < prompt_len)
    logits, cache, _ = forward(params, cfg, tokens, cache=cache,
                               mode="prefill", input_mask=mask)
    cache["length"] = jnp.full((1,), prompt_len, jnp.int32)
    last = logits[0, jnp.maximum(prompt_len - 1, 0)]
    return cache, last


@functools.partial(jax.jit, static_argnames=("cfg", "max_len", "prompt_bucket"))
def _prefill_row(params: PyTree, cfg: ModelConfig, tokens: jax.Array,
                 prompt_len: jax.Array, max_len: int, prompt_bucket: int,
                 ) -> Tuple[PyTree, jax.Array]:
    """Prefill one request into a fresh single-row cache.  ``tokens`` is
    right-padded to ``prompt_bucket``.  Returns (cache_row, last_logits)."""
    del prompt_bucket  # shape is already static via tokens
    cache = cache_lib.cache_struct(cfg, 1, max_len, jnp.float32)
    return _prefill_forward(params, cfg, cache, tokens, prompt_len)


@functools.partial(jax.jit, static_argnames=("cfg", "prompt_bucket"),
                   donate_argnames=("pool_k", "pool_v", "kv_pos"))
def _prefill_paged_row(params: PyTree, cfg: ModelConfig, pool_k: jax.Array,
                       pool_v: jax.Array, kv_pos: jax.Array,
                       table_row: jax.Array, tokens: jax.Array,
                       prompt_len: jax.Array, prompt_bucket: int
                       ) -> Tuple[PyTree, jax.Array]:
    """Prefill one request *straight into its allocated pool blocks*: the
    batch-1 cache view aliases the shared pools and routes every KV write
    through the request's block-table row.  The pools are donated — the
    caller immediately replaces its references with the returned ones, so
    admission never copies (or transiently doubles) the whole pool.
    Returns (cache view with updated pools + fresh recurrent rows,
    last_logits)."""
    del prompt_bucket  # shape is already static via tokens
    cache = cache_lib.paged_prefill_view(cfg, pool_k, pool_v, kv_pos,
                                         table_row)
    return _prefill_forward(params, cfg, cache, tokens, prompt_len)


def _bucket(n: int, minimum: int = 16, cap: Optional[int] = None) -> int:
    """Power-of-two prompt bucket, clamped so a long prompt can never
    round up past the KV budget (a bucket wider than ``cap`` would build
    a prefill program whose writes get truncated)."""
    b = max(minimum, 1 << math.ceil(math.log2(max(n, 1))))
    if cap is not None:
        b = min(b, cap)
        assert n <= b, f"prompt of {n} tokens exceeds the KV budget {cap}"
    return b


class ServingEngine:
    def __init__(self, params_target: PyTree, cfg_target: ModelConfig,
                 params_draft: PyTree, cfg_draft: ModelConfig,
                 spec: SpecDecodeConfig, serving: ServingConfig,
                 seed: int = 0):
        self.pt, self.cfg_t = params_target, cfg_target
        self.pd, self.cfg_d = params_draft, cfg_draft
        self.spec = spec
        self.policy = build_policy(spec)
        self.serving = serving
        self.paged = serving.paged_kv
        if self.paged and not (cache_lib.supports_paged(cfg_target)
                               and cache_lib.supports_paged(cfg_draft)):
            raise ValueError(
                "paged_kv=True but family pair "
                f"({cfg_target.family}, {cfg_draft.family}) has no paged "
                "KV layout (supported: dense/moe/vlm/hybrid)")
        self.scheduler = LookaheadScheduler(serving, spec,
                                            policy=self.policy)
        self.key = jax.random.PRNGKey(seed)
        b = serving.max_batch_size
        paged_arg = ((serving.pool_blocks(), serving.kv_block_size)
                     if self.paged else None)
        self.state = sd.init_round_state(
            cfg_target, cfg_draft, spec, b, serving.max_seq_len,
            self._next_key(), paged=paged_arg)
        # host-side mirror of state.sl_next, refreshed once per round while
        # the round's other outputs are already being transferred — the
        # bucket choice never triggers its own device->host sync.
        self._sl_next_host = np.full((b,), self.policy.initial_sl_value(),
                                     np.int32)
        # telemetry
        self._finished_at_prefill = []
        self.rounds = 0
        self.draft_steps = 0            # padded bucket steps (k+1)
        self.draft_steps_effective = 0  # max per-seq proposals + 1 (what a
                                        # dynamic-shape runtime would run)
        self.emitted_total = 0
        self.round_log: List[Dict[str, float]] = []

    # ------------------------------------------------------------------ rng
    def _next_key(self) -> jax.Array:
        self.key, k = jax.random.split(self.key)
        return k

    # ------------------------------------------------------------- lifecycle
    def submit(self, req: Request) -> None:
        self.scheduler.submit(req)

    def _admit(self) -> None:
        for req in self.scheduler.admit():
            self._prefill_into_slot(req)
            if req.done:   # finished at prefill (eos / max_new_tokens == 1)
                self.scheduler.release(req)
                self._finished_at_prefill.append(req)

    # ----------------------------------------------------------- block plane
    def _table_row(self, req: Request) -> np.ndarray:
        row = np.full((self.serving.blocks_per_seq(),), -1, np.int32)
        row[:len(req.block_ids)] = req.block_ids
        return row

    def _sync_block_tables(self, rows: List[Tuple[int, np.ndarray]],
                           fresh_ids: List[int]) -> None:
        """Mirror host allocator decisions into both device caches: reset
        ``kv_pos`` of freshly (re)allocated blocks (a recycled block must
        never leak stale-but-causally-valid entries to its new owner) and
        rewrite the affected block-table rows."""
        if not rows and not fresh_ids:
            return
        st = self.state
        tc, dc = dict(st.target_cache), dict(st.draft_cache)
        if fresh_ids:
            tc["kv_pos"] = cache_lib.reset_blocks(tc["kv_pos"], fresh_ids)
            dc["kv_pos"] = cache_lib.reset_blocks(dc["kv_pos"], fresh_ids)
        for slot, row in rows:
            r = jnp.asarray(row, jnp.int32)
            tc["block_table"] = tc["block_table"].at[slot].set(r)
            dc["block_table"] = dc["block_table"].at[slot].set(r)
        self.state = st._replace(target_cache=tc, draft_cache=dc)

    def _plan_blocks(self) -> None:
        """Pre-round capacity planning: grow every running sequence to
        ``committed + policy.lookahead(SL_i)`` KV slots, preempting the
        youngest sequences (evict-and-requeue, recompute-on-readmit) when
        the pool runs dry instead of rejecting anybody."""
        la = self.scheduler.lookahead_slots()
        slot_of = {id(r): r.slot for r in self.scheduler.running}
        fresh_ids: List[int] = []
        rows: List[Tuple[int, np.ndarray]] = []
        cleared: List[Tuple[int, np.ndarray]] = []
        for req in sorted(self.scheduler.running, key=lambda r: r.admit_seq):
            if req.slot is None:        # preempted by an earlier grow
                continue
            need = req.cache_len + int(la[req.slot])
            new_blocks, preempted = self.scheduler.ensure_capacity(req, need)
            if new_blocks:
                fresh_ids += new_blocks
                rows.append((req.slot, self._table_row(req)))
            for victim in preempted:
                cleared.append((slot_of[id(victim)],
                                np.full((self.serving.blocks_per_seq(),),
                                        -1, np.int32)))
        self._sync_block_tables(rows + cleared, fresh_ids)

    def _prefill_into_slot(self, req: Request) -> None:
        slot = req.slot
        prefix = req.prefill_tokens()
        readmit = bool(req.output)      # recompute-on-readmit (preemption)
        bucket = _bucket(len(prefix), cap=self.serving.max_seq_len)
        toks = np.full((1, bucket), 0, np.int32)
        toks[0, :len(prefix)] = prefix
        toks = jnp.asarray(toks)
        plen = jnp.int32(len(prefix))
        if self.paged:
            row = self._table_row(req)
            self._sync_block_tables([(slot, row)], req.block_ids)
            st = self.state
            tc, dc = dict(st.target_cache), dict(st.draft_cache)
            row_j = jnp.asarray(row, jnp.int32)[None]
            row_t, last_t = _prefill_paged_row(
                self.pt, self.cfg_t, tc["k"], tc["v"], tc["kv_pos"],
                row_j, toks, plen, bucket)
            row_d, _ = _prefill_paged_row(
                self.pd, self.cfg_d, dc["k"], dc["v"], dc["kv_pos"],
                row_j, toks, plen, bucket)
            for big, r in ((tc, row_t), (dc, row_d)):
                big["k"], big["v"] = r["k"], r["v"]
                big["kv_pos"] = r["kv_pos"]
                big["length"] = big["length"].at[slot].set(r["length"][0])
                for key in ("lru", "conv"):    # hybrid recurrent rows
                    if key in big:
                        big[key] = big[key].at[:, slot].set(r[key][:, 0])
        else:
            st = self.state
            row_t, last_t = _prefill_row(self.pt, self.cfg_t, toks, plen,
                                         self.serving.max_seq_len, bucket)
            row_d, _ = _prefill_row(self.pd, self.cfg_d, toks, plen,
                                    self.serving.max_seq_len, bucket)
            tc = _set_slot(st.target_cache, row_t, slot)
            dc = _set_slot(st.draft_cache, row_d, slot)
        req.cache_len = len(prefix)
        if readmit:
            # the last emitted token IS the pending token; re-sampling
            # would fork the RNG stream and (at temperature > 0) the output
            pend = jnp.int32(req.output[-1])
        else:
            pend = sample_token(self._next_key(), last_t[None],
                                self.spec.temperature,
                                self.cfg_t.vocab_size)[0].astype(jnp.int32)
            # the prefill-sampled token IS the first generated token
            first = int(pend)
            req.output.append(first)
            self.emitted_total += 1
            req.first_token_time = time.monotonic()
            if ((req.eos_token_id is not None and first == req.eos_token_id)
                    or len(req.output) >= req.max_new_tokens):
                req.state = RequestState.FINISHED
                req.finish_time = req.first_token_time
        rows = jnp.zeros((self.serving.max_batch_size,), bool).at[slot].set(True)
        ps = self.policy.reset_rows(st.policy_state, rows)
        sl0_val = self.policy.initial_sl_value()
        sl0 = st.sl_next.at[slot].set(sl0_val)
        self._sl_next_host[slot] = sl0_val
        # refresh the scheduler's mirror too: block planning for this
        # round must see the fresh request's initial SL, not the slot's
        # previous occupant's last prediction (a stale low SL would
        # under-allocate blocks and silently drop accepted KV writes)
        self.scheduler.update_predictions(self._sl_next_host)
        self.state = st._replace(
            target_cache=tc, draft_cache=dc, policy_state=ps,
            pending=st.pending.at[slot].set(pend), sl_next=sl0)

    # ------------------------------------------------------------------ step
    def step(self) -> List[Request]:
        """Admit, run one speculative round, distribute tokens.  Returns
        requests that reached a terminal state this step (finished OR
        rejected-at-admission)."""
        t_step = time.monotonic()
        self._admit()
        done_early = self._finished_at_prefill + self.scheduler.pop_rejected()
        self._finished_at_prefill = []
        if not self.scheduler.running:
            return done_early
        if self.paged:
            self._plan_blocks()         # may preempt (slots go inactive)
        running = self.scheduler.running
        active_mask = self.scheduler.active_mask
        active = jnp.asarray(active_mask)
        k = self.policy.pick_bucket(self._sl_next_host, active_mask)
        self.state, out = sd.spec_decode_round(
            self.pt, self.pd, self.cfg_t, self.cfg_d, self.spec, k,
            self.state, active)
        self.rounds += 1
        self.draft_steps += (k + 1) if k > 0 else 0

        emitted = np.asarray(out.emitted)
        n_emit = np.asarray(out.num_emitted)
        n_acc = np.asarray(out.num_accepted)
        n_prop = np.asarray(out.num_proposed)
        self._sl_next_host = np.array(self.state.sl_next)   # writable copy
        self.scheduler.update_predictions(self._sl_next_host)
        if k > 0:
            self.draft_steps_effective += int(n_prop.max()) + 1
        round_rec = {
            "k": k,
            "emitted": float(n_emit[active_mask].sum()),
            "accepted": float(n_acc.sum()), "proposed": float(n_prop.sum()),
        }

        finished = done_early
        shrunk_rows: List[Tuple[int, np.ndarray]] = []
        now = time.monotonic()
        for req in list(running):
            i = req.slot
            req.cache_len += 1 + int(n_acc[i])   # mirrors the device commit
            toks = emitted[i, :n_emit[i]].tolist()
            if req.first_token_time is None and toks:
                req.first_token_time = now
            req.rounds += 1
            req.accepted_tokens += int(n_acc[i])
            req.proposed_tokens += int(n_prop[i])
            for t in toks:
                if t == self.cfg_t.vocab_size:   # pad sentinel
                    continue
                req.output.append(int(t))
                self.emitted_total += 1
                eos = req.eos_token_id
                if ((eos is not None and t == eos)
                        or len(req.output) >= req.max_new_tokens):
                    req.state = RequestState.FINISHED
                    req.finish_time = now
                    break
            if req.done:
                self.scheduler.release(req)      # frees its blocks too
                finished.append(req)
            elif self.paged:
                # rollback is free: speculative-tail blocks beyond the
                # committed length go straight back to the pool.  The
                # device table row must drop the freed entries NOW: a
                # freed block can be reallocated at the next admission,
                # and a stale row entry would gather the new owner's
                # causally-valid KV into this sequence's attention.
                if self.scheduler.shrink_to(req, req.cache_len):
                    shrunk_rows.append((req.slot, self._table_row(req)))
        if shrunk_rows:
            self._sync_block_tables(shrunk_rows, [])
        # per-sequence KV slots the policy plans for the NEXT round — the
        # capacity-planning view of intra-batch heterogeneity.  Logged
        # after release so just-finished slots are not counted.
        round_rec["lookahead"] = float(
            self.scheduler.lookahead_slots()[self.scheduler.active_mask]
            .sum())
        round_rec["kv_blocks_in_use"] = float(
            self.scheduler.kv_blocks_in_use())
        round_rec["kv_pool_utilization"] = (
            round_rec["kv_blocks_in_use"]
            / max(self.scheduler.kv_blocks_total(), 1))
        round_rec["wall_s"] = time.monotonic() - t_step
        self.round_log.append(round_rec)
        return finished

    # ------------------------------------------------------------------- run
    def run(self, requests: Sequence[Request],
            max_rounds: Optional[int] = None) -> Dict[str, float]:
        t0 = time.monotonic()
        for r in requests:
            self.submit(r)
        done: List[Request] = []
        while self.scheduler.has_work():
            done += self.step()
            if max_rounds is not None and self.rounds >= max_rounds:
                break
        wall = time.monotonic() - t0
        fin = [r for r in done if r.state == RequestState.FINISHED]
        rej = [r for r in done if r.state == RequestState.REJECTED]
        lat = [r.latency() for r in fin if r.latency() is not None]
        return {
            "wall_time_s": wall,
            "requests_finished": len(fin),
            "requests_rejected": len(rej),
            "preemptions": self.scheduler.preempted_total,
            "tokens_emitted": self.emitted_total,
            "rounds": self.rounds,
            "draft_steps": self.draft_steps,
            "draft_steps_effective": self.draft_steps_effective,
            # paper's BE: tokens per target verification, per sequence
            "block_efficiency": float(np.mean(
                [r.block_efficiency() for r in fin])) if fin else float("nan"),
            "batch_tokens_per_round": self.emitted_total / max(self.rounds, 1),
            "throughput_tok_s": self.emitted_total / max(wall, 1e-9),
            "mean_latency_s": float(np.mean(lat)) if lat else float("nan"),
            "p95_latency_s": float(np.percentile(lat, 95)) if lat else float("nan"),
            "mean_acceptance": float(np.mean(
                [r.acceptance_rate() for r in fin])) if fin else float("nan"),
            "kv_blocks_peak": float(max(
                (r["kv_blocks_in_use"] for r in self.round_log),
                default=0.0)),
            "kv_pool_blocks": float(self.scheduler.kv_blocks_total()),
        }
