"""DSDE serving engine: a plan → dispatch → collect pipeline over the
jitted speculative round (DESIGN.md §7).

The engine composes:
  * :class:`LookaheadScheduler`  — queue/slot admission from SL predictions
    plus, under the paged KV layout, the block allocator (grow on demand,
    preempt when the pool runs dry);
  * ``spec_decode_round``        — the jitted speculative round (bucketed by
    K so there is one XLA program per draft length, never per step) with
    *device-side termination*: a slot that emits EOS or exhausts its token
    budget deactivates itself in-round, so rounds can be chained
    back-to-back without waiting for host EOS checks;
  * batched prefill              — requests admitted together that share a
    prompt bucket prefill as ONE multi-row program (dense rows or a
    multi-row paged-table view), not two jit calls per request.

Two execution modes share every phase:

  * synchronous (default)       — ``step()`` = plan, dispatch, collect;
    the host reconciles each round before dispatching the next (the
    lockstep loop, simplest to reason about, what the unit tests drive).
  * pipelined (``ServingConfig.pipelined``) — ``run()`` enqueues round
    N+1 immediately after round N and reconciles the host ONE ROUND
    BEHIND: token distribution, EOS bookkeeping, block shrink and the
    round log all happen while the device is already crunching the next
    round.  Greedy token streams are byte-identical to the synchronous
    engine (speculative decoding is exact, and truncation semantics live
    on the device); scheduling-side telemetry (round counts, bucket
    sequence) may differ by the one-round lag.

This runs for real on CPU (reduced models) and is the same code path the
TPU launch scripts drive; only meshes/shardings differ (repro/launch).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import prefill as prefill_lib
from repro.core import spec_decode as sd
from repro.core.config import (ModelConfig, ServingConfig, SpecDecodeConfig)
from repro.core.drafters import build_drafter
from repro.core.policies import build_policy
from repro.core.sampling import sample_token
from repro.models import cache as cache_lib
from repro.models.transformer import has_recurrent_state, model_specs
from repro.serving.latency_model import RoundLatencyModel
from repro.serving.request import Request, RequestState
from repro.serving.scheduler import LookaheadScheduler

PyTree = Any

# Mesh-path round programs, shared ACROSS engine instances: keyed by the
# exact trace identity (model/drafter/spec/bucket) plus the serving-mesh
# plan and the declared state sharding tree (NamedShardings are hashable),
# so e.g. the sync and pipelined engines of one benchmark reuse the same
# compiled rounds instead of re-tracing per engine.
_MESH_ROUND_JITS: Dict[Any, Any] = {}


def _bucket(n: int, minimum: int = 16, cap: Optional[int] = None) -> int:
    """Power-of-two prompt bucket, clamped so a long prompt can never
    round up past the KV budget (a bucket wider than ``cap`` would build
    a prefill program whose writes get truncated)."""
    b = max(minimum, 1 << math.ceil(math.log2(max(n, 1))))
    if cap is not None:
        b = min(b, cap)
        assert n <= b, f"prompt of {n} tokens exceeds the KV budget {cap}"
    return b


class _DispatchRecord:
    """Host-side snapshot of one dispatched round, reconciled by
    ``collect`` — possibly a full round later, after ``plan`` has already
    mutated the engine's device state.  Everything ``collect`` needs is
    captured here by reference at dispatch time: the (request, slot)
    occupancy as the round saw it, the prefill-sampled first tokens
    riding this round, and the round's output arrays (immutable jax
    arrays whose host copies were started with ``copy_to_host_async``).
    """

    __slots__ = ("k", "rows", "admits", "out", "sl_next", "t_dispatch",
                 "prefill_tokens")

    def __init__(self, k: int, rows, admits, out, sl_next, t_dispatch,
                 prefill_tokens=0):
        self.k = k
        self.rows = rows          # [(req, slot, preemptions-at-dispatch)]
        self.admits = admits      # [(fresh_reqs, pend [R] jax, fresh_idx,
                                  #   preemptions-at-prefill)]
        self.out = out            # RoundOutput (device futures)
        self.sl_next = sl_next    # [B] jax — post-round SL predictions
        self.t_dispatch = t_dispatch
        # prefill tokens computed by the admission wave riding this
        # round's wall interval (the latency model's c_prefill regressor)
        self.prefill_tokens = prefill_tokens


class ServingEngine:
    def __init__(self, params_target: PyTree, cfg_target: ModelConfig,
                 params_draft: Optional[PyTree],
                 cfg_draft: Optional[ModelConfig],
                 spec: SpecDecodeConfig, serving: ServingConfig,
                 seed: int = 0, mesh: Optional[Any] = None,
                 latency_model: Optional[RoundLatencyModel] = None):
        """``mesh``: an optional ``jax.sharding.Mesh`` with ``data`` /
        ``model`` axes.  None (the default) is the single-device engine,
        bit-for-bit unchanged.  With a mesh, params and round state are
        placed under the §5 ``serve`` rule set and every round runs
        through a jit with explicit in/out shardings — greedy token
        streams stay byte-identical to the single-device engine
        (tests/test_serving_mesh.py).

        ``latency_model``: a pre-seeded :class:`RoundLatencyModel`
        (e.g. warm-started from a calibration sweep's round log); None
        builds a fresh one.  Either way the engine feeds it one sample
        per collected round and installs it on the scheduler, where the
        SLO policy hooks and admission gate consult it (DESIGN.md §15)."""
        self.pt, self.cfg_t = params_target, cfg_target
        self.pd, self.cfg_d = params_draft, cfg_draft
        # the drafter (DESIGN.md §9) — the proposer half of every round.
        # A goodput cost left unresolved (None) is sourced from the
        # drafter's own step_cost() BEFORE any policy is built, so the
        # resolved spec is the single static key everywhere downstream.
        drafter = build_drafter(spec, cfg_target, cfg_draft)
        if drafter.uses_draft_model() and (params_draft is None
                                           or cfg_draft is None):
            raise ValueError(
                f"drafter {spec.drafter!r} needs draft-model params/config"
                " (params_draft / cfg_draft must not be None)")
        if spec.goodput_draft_cost is None:
            spec = dataclasses.replace(spec,
                                       goodput_draft_cost=drafter.step_cost())
            drafter = build_drafter(spec, cfg_target, cfg_draft)
        self.drafter = drafter
        self.spec = spec
        self.policy = build_policy(spec)
        self.serving = serving
        self.paged = serving.paged_kv
        if self.paged and not (cache_lib.supports_paged(cfg_target)
                               and (not drafter.mirrors_kv()
                                    or cache_lib.supports_paged(cfg_draft))):
            raise ValueError(
                "paged_kv=True but family pair "
                f"({cfg_target.family}, "
                f"{cfg_draft.family if cfg_draft else None}) has no paged "
                "KV layout (supported: dense/moe/vlm/hybrid)")
        # quantized KV storage (DESIGN.md §13): int8 pools exist only on
        # the paged data plane, and only for families whose paged cache
        # is a pure attention pool — hybrid recurrent leaves stay fp.
        self.kv_quant = serving.kv_quant
        if self.kv_quant != "none":
            if not self.paged:
                raise ValueError("kv_quant requires paged_kv=True")
            if not (cache_lib.supports_kv_quant(cfg_target)
                    and (not drafter.mirrors_kv()
                         or cache_lib.supports_kv_quant(cfg_draft))):
                raise ValueError(
                    f"kv_quant={self.kv_quant!r} but family pair "
                    f"({cfg_target.family}, "
                    f"{cfg_draft.family if cfg_draft else None}) has no "
                    "quantized paged layout (supported: dense/moe/vlm)")
        # prefix caching (DESIGN.md §12): effective only on the paged
        # data plane with attention-only families — recurrent per-slot
        # state (hybrid lru/conv, ssm) cannot be recovered from shared
        # pool blocks, so a cache-hit admission could not reconstruct
        # it.  When the drafter mirrors the pool its family must be
        # attention-only too.
        self.prefix_caching = bool(
            serving.prefix_caching and self.paged
            and not has_recurrent_state(cfg_target)
            and (not drafter.mirrors_kv()
                 or not has_recurrent_state(cfg_draft)))
        # model-free drafters have no mirrored draft pool: the mirror's
        # block budget returns to the target pool, so the same
        # ServingConfig admits proportionally more in-flight sequences
        # (the per-sequence charge halves, DESIGN.md §9)
        block_bytes = (cache_lib.kv_block_bytes(cfg_target,
                                                serving.kv_block_size,
                                                self.kv_quant)
                       if self.paged else 0)
        self.scheduler = LookaheadScheduler(serving, spec,
                                            policy=self.policy,
                                            kv_mirror=drafter.mirrors_kv(),
                                            prefix_cache=self.prefix_caching,
                                            block_bytes=block_bytes)
        # the analytic per-round latency model (DESIGN.md §15): fed one
        # (features, wall_s) sample per collect, installed on the
        # scheduler so the SLO admission gate and the policy host hooks
        # (via HostRoundContext) consult the same fit
        self.latency_model = (latency_model if latency_model is not None
                              else RoundLatencyModel())
        self.scheduler.latency_model = self.latency_model
        self.key = jax.random.PRNGKey(seed)
        b = serving.max_batch_size
        paged_arg = ((self.scheduler.kv_blocks_total(),
                      serving.kv_block_size) if self.paged else None)
        self.state = sd.init_round_state(
            cfg_target, cfg_draft, spec, b, serving.max_seq_len,
            self.key, paged=paged_arg, drafter=drafter,
            kv_quant=self.kv_quant)
        # --- serving mesh (DESIGN.md §5): place params + state, build the
        # per-bucket round jits with explicit in/out shardings ------------
        self.mesh = mesh
        self._plan = None
        self._mesh_round_fns: Dict[int, Any] = {}
        if mesh is not None:
            from repro.launch import sharding as shd
            rules = shd.serve_rules(mesh, b)
            self._plan = shd.ServeMeshPlan(mesh=mesh, rules=rules)
            self._pt_sh = shd.param_shardings(model_specs(cfg_target),
                                              mesh, rules)
            self.pt = jax.device_put(self.pt, self._pt_sh)
            if self.pd is not None:
                self._pd_sh = shd.param_shardings(model_specs(cfg_draft),
                                                  mesh, rules)
                self.pd = jax.device_put(self.pd, self._pd_sh)
            else:       # model-free drafter: no draft params to place
                self._pd_sh = shd.replicated(mesh)
            self._state_sh = shd.round_state_shardings(self.state, mesh,
                                                       rules)
            self.state = jax.device_put(self.state, self._state_sh)
        # host-side mirror of state.sl_next, refreshed once per collect
        # while the round's other outputs are already being transferred —
        # the bucket choice never triggers its own device->host sync.
        # Under the pipelined loop this mirror is ONE ROUND STALE at
        # dispatch time; block planning adds worst-case slack for that.
        self._sl_next_host = np.full((b,), self.policy.initial_sl_value(),
                                     np.int32)
        # pipeline bookkeeping
        self._inflight: Optional[_DispatchRecord] = None
        # (fresh requests, pend tokens [R], their row indices, their
        # preemption counts at prefill) awaiting the next dispatch
        self._pending_admits: List[Tuple[List[Request], jax.Array,
                                         List[int], List[int]]] = []
        self._planned_k: Optional[int] = None
        self._finished_at_prefill: List[Request] = []
        # prefill tokens computed since the last dispatch — snapshotted
        # into each dispatch record as the latency model's c_prefill
        # regressor for the round interval they ride
        self._prefill_tokens_pending = 0
        # telemetry
        self.rounds = 0
        self.draft_steps = 0            # padded bucket steps (k+1)
        self.draft_steps_effective = 0  # max per-seq proposals + 1 (what a
                                        # dynamic-shape runtime would run)
        self.emitted_total = 0
        self.round_log: List[Dict[str, float]] = []
        # prefix-cache watermarks: the scheduler keeps lifetime totals,
        # the round log wants per-round deltas
        self._hit_blocks_logged = 0
        self._cow_logged = 0
        self._prefix_tok_logged = 0
        self._prefix_hit_tok_logged = 0

    # ------------------------------------------------------------------ rng
    def _request_keys(self, reqs: List[Request]) -> jax.Array:
        """[R] per-request prefill-sampling keys: bound to the request's
        identity alone (identity-threaded RNG, DESIGN.md §7), so the
        first token a request samples is independent of admission
        grouping, schedule, and batch composition."""
        ids = jnp.asarray([r.request_id for r in reqs], jnp.int32)
        zero = jnp.zeros_like(ids)
        return sd.row_keys(self.key, ids, zero, sd.PURPOSE_PREFILL)

    # ------------------------------------------------------------- lifecycle
    def submit(self, req: Request) -> None:
        self.scheduler.submit(req)

    # ------------------------------------------------------------ the round
    def _round_fn(self, k: int):
        """The jitted round for draft bucket ``k`` as a ``(state, active)
        -> (state, out)`` callable.  Off-mesh: the module-level
        ``sd.spec_decode_round``, unchanged.  On a mesh: a per-bucket jit
        over the same traced body with explicit ``in_shardings`` /
        ``out_shardings`` — inputs are resharded back to the §5 layouts
        if the host's eager per-slot updates drifted them, outputs are
        pinned to those layouts, so consecutive rounds at a fixed bucket
        reuse ONE program whatever the host did in between (the
        no-recompile guard in tests/test_serving_mesh.py) and GSPMD
        never round-trips the caches through replicated layouts."""
        if self.mesh is None:
            return lambda state, active: sd.spec_decode_round(
                self.pt, self.pd, self.cfg_t, self.drafter, self.spec, k,
                state, active)
        fn = self._mesh_round_fns.get(k)
        if fn is None:
            key = (self.cfg_t, self.drafter, self.spec, k, self._plan,
                   jax.tree_util.tree_structure(self._state_sh),
                   tuple(jax.tree_util.tree_leaves(self._state_sh)))
            fn = _MESH_ROUND_JITS.get(key)
            if fn is None:
                cfg_t, drafter, spec = self.cfg_t, self.drafter, self.spec

                def body(pt, pd, state, active):
                    return sd.spec_decode_round_impl(
                        pt, pd, cfg_t, drafter, spec, k, state, active)
                rep = self._plan.replicated()
                fn = jax.jit(body,
                             in_shardings=(self._pt_sh, self._pd_sh,
                                           self._state_sh, rep),
                             out_shardings=(self._state_sh, rep))
                _MESH_ROUND_JITS[key] = fn
            self._mesh_round_fns[k] = fn
        return lambda state, active: fn(self.pt, self.pd, state, active)

    # ----------------------------------------------------------- block plane
    def _table_row(self, req: Request) -> np.ndarray:
        row = np.full((self.serving.blocks_per_seq(),), -1, np.int32)
        row[:len(req.block_ids)] = req.block_ids
        return row

    def _sync_block_tables(self, rows: List[Tuple[int, np.ndarray]],
                           fresh_ids: List[int]) -> None:
        """Mirror host allocator decisions into both device caches: reset
        ``kv_pos`` of freshly (re)allocated blocks (a recycled block must
        never leak stale-but-causally-valid entries to its new owner) and
        rewrite the affected block-table rows."""
        if not rows and not fresh_ids:
            return
        st = self.state
        mirror = self.drafter.mirrors_kv()
        tc = dict(st.target_cache)
        dc = dict(st.draft_cache) if mirror else st.draft_cache
        if fresh_ids:
            tc["kv_pos"] = cache_lib.reset_blocks(tc["kv_pos"], fresh_ids)
            if mirror:
                dc["kv_pos"] = cache_lib.reset_blocks(dc["kv_pos"], fresh_ids)
        for slot, row in rows:
            r = jnp.asarray(row, jnp.int32)
            tc["block_table"] = tc["block_table"].at[slot].set(r)
            if mirror:
                dc["block_table"] = dc["block_table"].at[slot].set(r)
        self.state = st._replace(target_cache=tc, draft_cache=dc)

    def _plan_blocks(self) -> None:
        """Pre-round capacity planning: grow every running sequence's
        allocation to cover the next round's write extent, preempting the
        youngest sequences (evict-and-requeue, recompute-on-readmit) when
        the pool runs dry instead of rejecting anybody.

        Synchronous mode plans exactly: ``committed +
        policy.lookahead(SL_i)``.  Pipelined mode plans from ONE-ROUND-
        STALE mirrors, so it must never trust a per-slot value that the
        in-flight round could raise; instead it uses the staleness-slack
        bound (DESIGN.md §7):

            need_i = cache_len_i(stale) + (1 + K_inflight) + (1 + K_next)

        where ``1 + K_inflight`` covers the largest commit the not yet
        reconciled round can apply and ``1 + K_next`` covers the next
        round's widest write (per-slot SL is capped by the bucket
        ``K_next`` on device, so the bound holds regardless of what the
        stale mirror says).  Lagged information can therefore only ever
        OVER-allocate — the tail comes back at the next shrink."""
        pipelined = self.serving.pipelined
        la = None if pipelined else self.scheduler.lookahead_slots()
        k_next = self._planned_k or 0
        inflight_ids = ({id(r) for r, _, _ in self._inflight.rows}
                        if self._inflight is not None else set())
        slot_of = {id(r): r.slot for r in self.scheduler.running}
        fresh_ids: List[int] = []
        rows: List[Tuple[int, np.ndarray]] = []
        cleared: List[Tuple[int, np.ndarray]] = []
        for req in sorted(self.scheduler.running, key=lambda r: r.admit_seq):
            if req.slot is None:        # preempted by an earlier grow
                continue
            if pipelined:
                slack = ((1 + self._inflight.k)
                         if id(req) in inflight_ids else 0)
                need = min(req.cache_len + slack + k_next + 1,
                           self.serving.max_seq_len)
            else:
                need = req.cache_len + int(la[req.slot])
            new_blocks, preempted = self.scheduler.ensure_capacity(req, need)
            if new_blocks:
                fresh_ids += new_blocks
                rows.append((req.slot, self._table_row(req)))
            for victim in preempted:
                cleared.append((slot_of[id(victim)],
                                np.full((self.serving.blocks_per_seq(),),
                                        -1, np.int32)))
        self._sync_block_tables(rows + cleared, fresh_ids)

    # --------------------------------------------------------------- prefill
    def _emit_token(self, req: Request, tok: int, now: float) -> None:
        """The single host-side token-delivery point: append to the
        request's output, stamp first-token latency, and fire the
        request's streaming callback (DESIGN.md §14).  Every reconciled
        token — prefill-sampled first tokens and round emissions alike —
        flows through here exactly once, in stream order, which is the
        whole streaming contract: consumers see the same byte sequence
        ``run()`` accumulates in ``Request.output``."""
        req.output.append(tok)
        self.emitted_total += 1
        if req.first_token_time is None:
            req.first_token_time = now
        if req.on_token is not None:
            req.on_token(req, tok)

    def _commit_first_tokens(self, items: List[Tuple[Request, int]],
                             now: float) -> List[Request]:
        """Append prefill-sampled first tokens host-side and apply the
        EOS / max_new_tokens terminal checks (the host mirror of the
        device-side ``done`` computation at prefill)."""
        finished = []
        for req, tok in items:
            self._emit_token(req, tok, now)
            if ((req.eos_token_id is not None and tok == req.eos_token_id)
                    or len(req.output) >= req.max_new_tokens):
                req.state = RequestState.FINISHED
                req.finish_time = now
                finished.append(req)
        return finished

    def _admit(self) -> None:
        """Admission: move queued requests into free slots and prefill
        them, grouped by prompt bucket — every same-bucket group runs as
        ONE multi-row program (2 jit calls per *group*, not per
        request)."""
        admitted = self.scheduler.admit()
        if not admitted:
            return
        now = time.monotonic()
        # warm (cache-hit) requests group by TAIL bucket — the program
        # their prefill actually runs — and separately from cold ones,
        # which stay on the cold entry point byte- and program-count-
        # identical with the pre-cache engine
        groups: Dict[Tuple[bool, int], List[Request]] = {}
        for req in admitted:
            if req.first_dispatch_time is None:
                req.first_dispatch_time = now
            warm = req.prefill_start > 0
            n = len(req.prefill_tokens()) - req.prefill_start
            b = _bucket(n, cap=self.serving.max_seq_len)
            groups.setdefault((warm, b), []).append(req)
        for warm, bucket in sorted(groups):
            self._prefill_group(groups[(warm, bucket)], bucket, warm=warm)

    def _prefill_group(self, reqs: List[Request], bucket: int,
                       warm: bool = False) -> None:
        """One multi-row prefill program for a same-bucket group.

        Cold groups (``warm=False``) run the pre-cache entry points
        unchanged.  Warm groups (every row has ``prefill_start > 0``
        cached tokens) are bucketed by TAIL length and run the
        partial-prefix entry point: the tail program starts each row at
        its coverage offset, executes the group's batched copy-on-write
        block copies first, and only computes the uncovered suffix — the
        TTFT/FLOPs win prefix caching exists for (DESIGN.md §12)."""
        r = len(reqs)
        slots = [req.slot for req in reqs]
        idx = jnp.asarray(slots, jnp.int32)
        toks_np = np.zeros((r, bucket), np.int32)
        plens = np.zeros((r,), np.int32)
        starts = np.zeros((r,), np.int32)
        tails = np.zeros((r,), np.int32)
        readmit = np.zeros((r,), bool)
        budgets = np.zeros((r,), np.int32)
        eos = np.full((r,), -1, np.int32)
        pend_host = np.zeros((r,), np.int32)
        prefixes: List[List[int]] = []
        for i, req in enumerate(reqs):
            prefix = req.prefill_tokens()
            prefixes.append(prefix)
            start = req.prefill_start if warm else 0
            tail = prefix[start:]
            toks_np[i, :len(tail)] = tail      # cold: the full prefix
            plens[i] = len(prefix)
            starts[i] = start
            tails[i] = len(tail)
            # recompute-on-readmit (preemption): the last emitted token
            # IS the pending token; re-sampling would fork the RNG
            # stream and (at temperature > 0) the output
            readmit[i] = bool(req.output)
            # prefill itself emits one token for a fresh request
            budgets[i] = req.max_new_tokens - (len(req.output)
                                               if req.output else 1)
            if req.eos_token_id is not None:
                eos[i] = req.eos_token_id
            if req.output:
                pend_host[i] = req.output[-1]
            req.cache_len = len(prefix)
        self._prefill_tokens_pending += int(tails.sum())
        toks = jnp.asarray(toks_np)
        plen_j = jnp.asarray(plens)
        starts_j = jnp.asarray(starts)
        tails_j = jnp.asarray(tails)
        rows_j = None
        cow_src_j = cow_dst_j = None
        if warm:
            # <=1 COW pair per row by construction: only a full
            # block-aligned hit forks (the last shared block, whose final
            # position the tail recomputes).  Sentinel = pool size, the
            # write-drop discipline of cache_lib.copy_blocks.
            nb = self.scheduler.kv_blocks_total()
            cow_src = np.full((r,), nb, np.int32)
            cow_dst = np.full((r,), nb, np.int32)
            for i, req in enumerate(reqs):
                if req.cow_pairs:
                    cow_src[i], cow_dst[i] = req.cow_pairs[0]
            cow_src_j = jnp.asarray(cow_src)
            cow_dst_j = jnp.asarray(cow_dst)
        if self.paged:
            rows_np = [self._table_row(req) for req in reqs]
            # reset only PRIVATE fresh blocks: shared cache-hit blocks
            # hold live committed KV other sequences still read, and COW
            # destinations take their kv_pos from the device-side block
            # copy, which runs inside the tail program after this reset
            alloc_ids = [b for req in reqs for b in req.fresh_block_ids]
            self._sync_block_tables(list(zip(slots, rows_np)), alloc_ids)
            st = self.state
            tc = dict(st.target_cache)
            rows_j = jnp.asarray(np.stack(rows_np), jnp.int32)
            if warm:
                rows_t, last_t = prefill_lib.prefill_paged_tail(
                    self.pt, self.cfg_t, tc["k"], tc["v"], tc["kv_pos"],
                    rows_j, toks, starts_j, tails_j, cow_src_j, cow_dst_j,
                    plan=self._plan, k_scale=tc.get("k_scale"),
                    v_scale=tc.get("v_scale"))
            else:
                rows_t, last_t = prefill_lib.prefill_paged_rows(
                    self.pt, self.cfg_t, tc["k"], tc["v"], tc["kv_pos"],
                    rows_j, toks, plen_j, plan=self._plan,
                    k_scale=tc.get("k_scale"), v_scale=tc.get("v_scale"))
            tc = prefill_lib.scatter_paged_rows(tc, rows_t, idx)
        else:
            st = self.state
            rows_t, last_t = prefill_lib.prefill_rows(
                self.pt, self.cfg_t, toks, plen_j, self.serving.max_seq_len,
                plan=self._plan)
            tc = prefill_lib.set_slots(st.target_cache, rows_t, idx)
        # drafter-side prefill: a model drafter runs its own one-program-
        # per-bucket prefill (through the same jitted entry points, so
        # program accounting is symmetric); a model-free drafter absorbs
        # the tokens directly — no draft prefill program at all
        rows_mask = jnp.zeros((self.serving.max_batch_size,),
                              bool).at[idx].set(True)
        dc = self.drafter.reset_rows(st.draft_cache, rows_mask)
        mirror_rows = (rows_j if (self.paged and self.drafter.mirrors_kv())
                       else None)
        if warm:
            # token-history drafters need the FULL prefix whatever the
            # KV coverage; mirroring drafters run the tail program over
            # their own pools and ignore it
            fbucket = _bucket(int(plens.max()), cap=self.serving.max_seq_len)
            full_np = np.zeros((r, fbucket), np.int32)
            for i, prefix in enumerate(prefixes):
                full_np[i, :len(prefix)] = prefix
            dc = self.drafter.prefill_tail(
                self.pd, dc, idx, jnp.asarray(full_np), plen_j,
                toks, starts_j, tails_j, cow_src_j, cow_dst_j,
                max_len=self.serving.max_seq_len,
                table_rows=mirror_rows, plan=self._plan)
        else:
            dc = self.drafter.prefill(
                self.pd, dc, idx, toks, plen_j,
                max_len=self.serving.max_seq_len,
                table_rows=mirror_rows,
                plan=self._plan)
        # pending token per row: sampled at prefill for fresh requests
        # (per-request keys — schedule/grouping invariant), the
        # already-emitted last token for readmits
        req_keys = self._request_keys(reqs)
        sampled = jax.vmap(
            lambda kk, lg: sample_token(kk, lg, self.spec.temperature,
                                        self.cfg_t.vocab_size)
        )(req_keys, last_t).astype(jnp.int32)
        readmit_j = jnp.asarray(readmit)
        budgets_j = jnp.asarray(budgets)
        eos_j = jnp.asarray(eos)
        pend = jnp.where(readmit_j, jnp.asarray(pend_host), sampled)
        # device-side termination seed: a first token that is already EOS
        # (or a 1-token budget) marks the slot done WITHOUT a host sync,
        # so the pipelined loop can keep dispatching blind
        done0 = ((pend == eos_j) & (eos_j >= 0)) | (budgets_j <= 0)
        ps = self.policy.reset_rows(st.policy_state, rows_mask)
        sl0_val = self.policy.initial_sl_value()
        # refresh the scheduler's mirror too: block planning for this
        # round must see the fresh requests' initial SL, not the slots'
        # previous occupants' last predictions (a stale low SL would
        # under-allocate blocks and silently drop accepted KV writes)
        self._sl_next_host[np.asarray(slots)] = sl0_val
        self.scheduler.update_predictions(self._sl_next_host)
        # identity-threaded RNG rows: bind the slot to its new occupant's
        # seed and round ordinal (a readmit resumes its own key stream)
        seed_j = jnp.asarray([req.request_id for req in reqs], jnp.int32)
        ridx_j = jnp.asarray([req.rounds for req in reqs], jnp.int32)
        self.state = st._replace(
            target_cache=tc, draft_cache=dc, policy_state=ps,
            pending=st.pending.at[idx].set(pend),
            sl_next=st.sl_next.at[idx].set(jnp.int32(sl0_val)),
            seed=st.seed.at[idx].set(seed_j),
            round_idx=st.round_idx.at[idx].set(ridx_j),
            done=st.done.at[idx].set(done0),
            tokens_budget=st.tokens_budget.at[idx].set(budgets_j),
            eos_id=st.eos_id.at[idx].set(eos_j))
        for req in reqs:
            # COW sources are safe to reclaim once the copy is enqueued
            # (device program order), and the prompt's full blocks are
            # committed-by-enqueue too: publish them so the NEXT
            # admission wave can share them
            self.scheduler.release_cow_sources(req)
            req.fresh_block_ids = []
            req.cow_pairs = []
            self.scheduler.register_prefix(req)
        fresh = [(i, req) for i, req in enumerate(reqs) if not readmit[i]]
        if not fresh:
            return
        if self.serving.pipelined:
            # defer materialization: the tokens ride the next dispatch
            # record and reach the host at its reconciliation.  The
            # preemption count pins the prefill this token came from —
            # a stub whose request was evicted before the round even
            # dispatched is discarded at collect (the restart samples
            # its own first token from its own re-prefill)
            self._pending_admits.append(
                ([req for _, req in fresh], pend, [i for i, _ in fresh],
                 [req.preemptions for _, req in fresh]))
        else:
            pend_np = np.asarray(pend)
            fin = self._commit_first_tokens(
                [(req, int(pend_np[i])) for i, req in fresh],
                time.monotonic())
            for req in fin:    # finished at prefill (eos / max_new == 1)
                self.scheduler.release(req)
                self._finished_at_prefill.append(req)

    # ------------------------------------------------------------- the phases
    def plan(self) -> None:
        """Phase 1 — host-side planning from *reconciled* state (which in
        pipelined mode lags the device by one round): admission + batched
        prefill, the next round's bucket choice, and paged block growth
        under the staleness-slack invariant."""
        self._admit()
        self._planned_k = None
        if self.scheduler.running:
            if self.serving.pipelined:
                self._planned_k = self._pick_bucket_pipelined()
            if self.paged:
                before = self.scheduler.preempted_total
                self._plan_blocks()         # may preempt (slots go inactive)
                if (self.serving.pipelined and self.scheduler.running
                        and self.scheduler.preempted_total != before):
                    # an evicted slot must not size the bucket: re-pick
                    # over the survivors.  A smaller K only shrinks
                    # write extents, so the block growth just planned
                    # (with the wider K) still over-covers.
                    self._planned_k = self._pick_bucket_pipelined()

    def _pick_bucket_pipelined(self) -> int:
        """Bucket choice for a pipelined dispatch, whose SL mirror is one
        round stale.  Greedy rounds pick from the stale mirror (a
        clipped window cannot change argmax streams).  Stochastic rounds
        dispatch at the policy's max bucket instead: a stale pick could
        clip a sequence's device-side SL below what the synchronous
        schedule runs, and at temperature>0 the realized sample stream
        depends on the proposal window — worst-case width keeps sampled
        streams schedule-invariant (DESIGN.md §7) at the cost of masked
        padding work."""
        if self.spec.temperature > 0.0:
            return self.policy.max_bucket()
        return self.policy.pick_bucket(self._host_context())

    def _host_context(self):
        """The round's :class:`HostRoundContext` for the policy host
        hooks — scheduler-owned per-slot state plus the engine's SL
        mirror, latency model, and round ordinal."""
        return self.scheduler.host_context(self._sl_next_host,
                                           round_ordinal=self.rounds)

    def dispatch(self) -> Optional[_DispatchRecord]:
        """Phase 2 — enqueue one speculative round.  Returns the dispatch
        record ``collect`` later reconciles, or None when no slot is
        occupied.  Never blocks on device results: the round's outputs
        stay futures, and their host copies are started asynchronously so
        they overlap the next round's compute."""
        if not self.scheduler.running:
            assert not self._pending_admits
            return None
        rows = [(r, r.slot, r.preemptions) for r in self.scheduler.running]
        active_mask = self.scheduler.active_mask
        k = (self._planned_k if self._planned_k is not None
             else self.policy.pick_bucket(self._host_context()))
        self._planned_k = None
        t_dispatch = time.monotonic()
        self.state, out = self._round_fn(k)(self.state,
                                            jnp.asarray(active_mask))
        self.rounds += 1
        self.draft_steps += (k + 1) if k > 0 else 0
        sl_next = self.state.sl_next
        for arr in (out.emitted, out.num_emitted, out.num_accepted,
                    out.num_proposed, out.finished, out.live, sl_next):
            try:
                arr.copy_to_host_async()
            except AttributeError:      # older jax / non-array leaf
                pass
        rec = _DispatchRecord(k=k, rows=rows, admits=self._pending_admits,
                              out=out, sl_next=sl_next,
                              t_dispatch=t_dispatch,
                              prefill_tokens=self._prefill_tokens_pending)
        self._prefill_tokens_pending = 0
        self._pending_admits = []
        self._inflight = rec
        return rec

    def collect(self, rec: _DispatchRecord) -> List[Request]:
        """Phase 3 — reconcile a dispatched round: first block on its
        output transfer (already in flight since dispatch; the blocked
        interval is recorded per round), then mirror the device's
        decisions — token distribution, terminal states, SL mirror
        refresh, shrink-to-committed — on the host.  In pipelined mode
        this runs while the NEXT round is already executing, so shrink
        keeps the in-flight round's write extent resident."""
        t0 = time.monotonic()
        emitted = np.asarray(rec.out.emitted)
        n_emit = np.asarray(rec.out.num_emitted)
        n_acc = np.asarray(rec.out.num_accepted)
        n_prop = np.asarray(rec.out.num_proposed)
        fin = np.asarray(rec.out.finished)
        live = np.asarray(rec.out.live)
        sl_next = np.array(rec.sl_next)     # writable copy
        admit_pends = [np.asarray(p) for _, p, _, _ in rec.admits]
        host_blocked = time.monotonic() - t0
        # refresh the SL mirror only for slots STILL OWNED by the request
        # the round ran: a slot re-admitted at this iteration's plan (or
        # preempted) already carries its new occupant's initial SL, which
        # the dispatched round's snapshot — one occupant stale — must not
        # clobber
        for req, slot, _ in rec.rows:
            if self.scheduler.slots[slot] is req:
                self._sl_next_host[slot] = sl_next[slot]
        self.scheduler.update_predictions(self._sl_next_host)
        now = time.monotonic()
        finished: List[Request] = []
        # (a) first tokens from the prefill groups riding this record.
        # A stub whose request was preempted BEFORE this round was
        # dispatched (not in rec.rows) never ran on this prefill: drop
        # it, the readmission produces its own first token.  A request
        # preempted AFTER dispatch keeps the token (its round-emitted
        # tokens in step (b) follow it), and if the token finishes it
        # while it sits in the requeue it must be dropped from the
        # queue, not released — release would no-op on the empty slot
        # and the FINISHED request would be readmitted as a zombie.
        in_rows = {id(r) for r, _, _ in rec.rows}
        for (fresh_reqs, _, fresh_idx, pcounts), pend_np in zip(rec.admits,
                                                                admit_pends):
            items = [(req, int(pend_np[i]), pc)
                     for req, i, pc in zip(fresh_reqs, fresh_idx, pcounts)
                     if id(req) in in_rows]
            for req in self._commit_first_tokens(
                    [(r, t) for r, t, _ in items], now):
                pc = next(p for r, _, p in items if r is req)
                if req.preemptions != pc or req.slot is None:
                    self.scheduler.drop_from_queue(req)
                else:
                    self.scheduler.release(req)
                finished.append(req)
        # (b) per-slot reconciliation against the dispatch-time snapshot
        # (the CURRENT slot table may already differ: collect runs after
        # the next plan, which can have preempted or re-admitted slots)
        inflight_k = (self._inflight.k
                      if (self._inflight is not None
                          and self._inflight is not rec) else None)
        shrunk_rows: List[Tuple[int, np.ndarray]] = []
        for req, slot, pcount in rec.rows:
            if req.done:
                continue       # reconciled to terminal by an earlier round
            # preempted (or re-admitted elsewhere) since dispatch: its
            # emitted tokens are real — the readmission prefix must
            # include them — but slot-side state (cache_len, blocks) was
            # reset by the eviction and must not be touched here
            displaced = req.preemptions != pcount or req.slot != slot
            if live[slot]:
                if not displaced:
                    req.cache_len += 1 + int(n_acc[slot])
                req.rounds += 1
                req.accepted_tokens += int(n_acc[slot])
                req.proposed_tokens += int(n_prop[slot])
                toks = emitted[slot, :n_emit[slot]].tolist()
                if req.first_token_time is None and toks:
                    req.first_token_time = now
                for t in toks:
                    if t == self.cfg_t.vocab_size:   # pad sentinel
                        continue
                    self._emit_token(req, int(t), now)
                if fin[slot]:
                    req.state = RequestState.FINISHED
                    req.finish_time = now
                if not displaced:
                    # decode extended the committed prefix: publish any
                    # newly completed full blocks.  Done BEFORE release so
                    # a finishing request's blocks drop to the evictable
                    # (warm) list still indexed — the cache survives its
                    # contributors.
                    self.scheduler.register_prefix(req)
            if req.done:
                if displaced:
                    # finished while sitting in the requeue: it must not
                    # be readmitted and recomputed
                    self.scheduler.drop_from_queue(req)
                else:
                    self.scheduler.release(req)      # frees its blocks too
                finished.append(req)
            elif not displaced and self.paged and req.slot is not None:
                # rollback is free: speculative-tail blocks beyond the
                # committed length go straight back to the pool.  The
                # device table row must drop the freed entries NOW: a
                # freed block can be reallocated at the next admission,
                # and a stale row entry would gather the new owner's
                # causally-valid KV into this sequence's attention.
                # With a round in flight, its write extent (committed +
                # K_inflight + 1) stays resident — those writes land in
                # device order whatever the host does, and the blocks
                # must still be this sequence's when they do.
                keep = (req.cache_len if inflight_k is None
                        else min(req.cache_len + inflight_k + 1,
                                 self.serving.max_seq_len))
                if self.scheduler.shrink_to(req, keep):
                    shrunk_rows.append((req.slot, self._table_row(req)))
        if shrunk_rows:
            self._sync_block_tables(shrunk_rows, [])
        # (c) round log — emitted/accepted/proposed all masked by the
        # SAME per-round live-row set (slots that did real work), and
        # draft_steps_effective takes its max over that set too
        round_rec = {
            "k": rec.k,
            "drafter": self.spec.drafter,
            "emitted": float(n_emit[live].sum()),
            "accepted": float(n_acc[live].sum()),
            "proposed": float(n_prop[live].sum()),
        }
        eff_steps = 0
        if rec.k > 0 and live.any():
            eff_steps = int(n_prop[live].max()) + 1
            self.draft_steps_effective += eff_steps
        # what this round's drafting actually cost, in target-
        # verification units — the capacity-vs-latency number that makes
        # model-free drafters' wins visible in benchmark rows
        round_rec["draft_cost_effective"] = (eff_steps
                                             * self.drafter.step_cost())
        # per-sequence KV slots the policy plans for the NEXT round — the
        # capacity-planning view of intra-batch heterogeneity.  Logged
        # after release so just-finished slots are not counted.
        round_rec["lookahead"] = float(
            self.scheduler.lookahead_slots()[self.scheduler.active_mask]
            .sum())
        round_rec["kv_blocks_in_use"] = float(
            self.scheduler.kv_blocks_in_use())
        round_rec["kv_pool_utilization"] = (
            round_rec["kv_blocks_in_use"]
            / max(self.scheduler.kv_blocks_total(), 1))
        # draft-side KV residency: the mirrored pool holds exactly the
        # target's in-use block set; a model-free drafter holds none —
        # the capacity win of lookup/self drafting, made visible per round
        round_rec["draft_kv_blocks_in_use"] = (
            round_rec["kv_blocks_in_use"] if self.drafter.mirrors_kv()
            else 0.0)
        # prefix-cache deltas since the previous round's log entry
        # (admissions land between collects, so the deltas attribute each
        # wave's hits/copies to the round that carried it)
        sch = self.scheduler
        round_rec["kv_blocks_cached"] = float(sch.kv_blocks_cached())
        round_rec["prefix_cache_hit_blocks"] = float(
            sch.prefix_hit_blocks_total - self._hit_blocks_logged)
        self._hit_blocks_logged = sch.prefix_hit_blocks_total
        round_rec["cow_copies"] = float(
            sch.cow_copies_total - self._cow_logged)
        self._cow_logged = sch.cow_copies_total
        d_tok = sch.prefix_tokens_total - self._prefix_tok_logged
        d_hit = sch.prefix_hit_tokens_total - self._prefix_hit_tok_logged
        round_rec["prefix_cache_hit_rate"] = (d_hit / d_tok) if d_tok else 0.0
        self._prefix_tok_logged = sch.prefix_tokens_total
        self._prefix_hit_tok_logged = sch.prefix_hit_tokens_total
        round_rec["host_blocked_s"] = host_blocked
        # per-round cadence: with a successor round already in flight,
        # dispatch-to-dispatch (so pipelined per-round walls sum to the
        # run wall instead of double-counting the overlapped round);
        # otherwise — sync, or the drain of the last round — dispatch to
        # reconciliation end, the full lockstep round cost
        if self._inflight is not None and self._inflight is not rec:
            round_rec["wall_s"] = self._inflight.t_dispatch - rec.t_dispatch
        else:
            round_rec["wall_s"] = time.monotonic() - rec.t_dispatch
        # latency-model regressors + prediction-before-update, then fold
        # the measured wall in (one RLS sample per round, DESIGN.md §15)
        b_eff = len(rec.rows)
        round_rec["b_eff"] = float(b_eff)
        round_rec["prefill_tokens"] = float(rec.prefill_tokens)
        round_rec["t_round_pred_s"] = self.latency_model.predict_round_s(
            rec.k, b_eff, rec.prefill_tokens)
        self.latency_model.observe(round_rec["wall_s"], rec.k, b_eff,
                                   rec.prefill_tokens)
        self.round_log.append(round_rec)
        if self._inflight is rec:
            self._inflight = None
        return finished

    # ------------------------------------------------------------------ step
    def step(self) -> List[Request]:
        """Synchronous lockstep: plan, dispatch, collect — the round is
        fully reconciled before control returns.  Returns requests that
        reached a terminal state this step (finished OR rejected-at-
        admission)."""
        self.plan()
        done_early = self._finished_at_prefill + self.scheduler.pop_rejected()
        self._finished_at_prefill = []
        if not self.scheduler.running:
            return done_early
        rec = self.dispatch()
        return done_early + self.collect(rec)

    # ------------------------------------------------------------------ pump
    def has_pending_work(self) -> bool:
        """True while the engine still owes work: queued or running
        requests, or (pipelined) a dispatched round awaiting its
        reconciliation.  The front-end's driver loop (DESIGN.md §14)
        polls this between ``pump()`` iterations."""
        return self.scheduler.has_work() or self._inflight is not None

    def pump(self) -> List[Request]:
        """One driver-loop iteration — exactly ``run()``'s loop body, so
        an external driver that interleaves ``submit()`` between pumps
        replays the same admit/dispatch/collect sequence (and therefore,
        with arrival-time-0 submissions, the same streams) ``run()``
        produces.  Sync mode is one lockstep ``step()``; pipelined mode
        plans + dispatches round N+1, then reconciles round N while N+1
        executes on device.  Returns requests that reached a terminal
        state this iteration; when ``has_pending_work()`` goes false the
        driver must ``drain()`` the final in-flight round."""
        if not self.serving.pipelined:
            return self.step() if self.scheduler.has_work() else []
        done: List[Request] = []
        self.plan()
        done += self.scheduler.pop_rejected()
        prev = self._inflight
        self.dispatch()
        if prev is not None:
            done += self.collect(prev)
        return done

    def drain(self) -> List[Request]:
        """Reconcile the last in-flight round after the final ``pump()``
        (pipelined mode dispatches one round ahead of reconciliation).
        No-op in sync mode or when nothing is in flight."""
        if self._inflight is not None:
            return self.collect(self._inflight)
        return []

    # ------------------------------------------------------------------- run
    def run(self, requests: Sequence[Request],
            max_rounds: Optional[int] = None) -> Dict[str, float]:
        t0 = time.monotonic()
        for r in requests:
            self.submit(r)
        done: List[Request] = []
        # pipelined: plan(N+1) → dispatch(N+1) → collect(N), the host
        # reconciling one round behind while the device never waits
        while self.has_pending_work():
            done += self.pump()
            if max_rounds is not None and self.rounds >= max_rounds:
                break
        done += self.drain()
        wall = time.monotonic() - t0
        return self.summary(done, wall)

    def summary(self, done: Sequence[Request],
                wall: float) -> Dict[str, float]:
        """Run-level metrics over a set of terminal requests — shared by
        ``run()`` and any external driver (the serving front-end) so a
        ``pump()``-driven session reports through the same lens."""
        fin = [r for r in done if r.state == RequestState.FINISHED]
        rej = [r for r in done if r.state == RequestState.REJECTED]
        lat = [r.latency() for r in fin if r.latency() is not None]
        ttft = [r.ttft() for r in fin if r.ttft() is not None]
        qw = [r.queue_wait() for r in fin if r.queue_wait() is not None]
        blocked = float(sum(r.get("host_blocked_s", 0.0)
                            for r in self.round_log))
        # SLO accounting (DESIGN.md §15): attainment over every terminal
        # request (a rejected request never attains); goodput counts only
        # tokens of requests that met their own deadline.  With no
        # deadlines anywhere every finished request attains, so
        # slo_goodput_tok_s == throughput_tok_s.
        attained = [r for r in done if r.slo_attained()]
        slo = {
            "slo_requests_attained": len(attained),
            "slo_attained_frac": len(attained) / max(len(done), 1),
            "slo_goodput_tok_s": (sum(len(r.output) for r in attained)
                                  / max(wall, 1e-9)),
            "slo_predicted_violations": float(
                self.scheduler.slo_predicted_violations),
            "slo_deferrals": float(self.scheduler.slo_deferrals_total),
        }
        return {
            **self.latency_model.summary_fields(),
            **slo,
            "wall_time_s": wall,
            "requests_finished": len(fin),
            "requests_rejected": len(rej),
            "preemptions": self.scheduler.preempted_total,
            "tokens_emitted": self.emitted_total,
            "rounds": self.rounds,
            "drafter": self.spec.drafter,
            "draft_step_cost": self.drafter.step_cost(),
            "draft_cost_effective": float(sum(
                r.get("draft_cost_effective", 0.0) for r in self.round_log)),
            "draft_kv_blocks_peak": float(max(
                (r.get("draft_kv_blocks_in_use", 0.0)
                 for r in self.round_log), default=0.0)),
            "draft_steps": self.draft_steps,
            "draft_steps_effective": self.draft_steps_effective,
            # paper's BE: tokens per target verification, per sequence
            "block_efficiency": float(np.mean(
                [r.block_efficiency() for r in fin])) if fin else float("nan"),
            "batch_tokens_per_round": self.emitted_total / max(self.rounds, 1),
            "throughput_tok_s": self.emitted_total / max(wall, 1e-9),
            "mean_latency_s": float(np.mean(lat)) if lat else float("nan"),
            "p95_latency_s": float(np.percentile(lat, 95)) if lat else float("nan"),
            # serving-side metrics the paper's §5 tables are framed
            # around: time-to-first-token and scheduler queue wait
            "ttft_mean_s": float(np.mean(ttft)) if ttft else float("nan"),
            "ttft_p95_s": float(np.percentile(ttft, 95)) if ttft else float("nan"),
            "queue_wait_mean_s": float(np.mean(qw)) if qw else float("nan"),
            # host time spent blocked on device output transfers — the
            # pipeline's figure of merit (benchmarks/table6)
            "host_blocked_s": blocked,
            "host_blocked_per_round_s": blocked / max(len(self.round_log), 1),
            "mean_acceptance": float(np.mean(
                [r.acceptance_rate() for r in fin])) if fin else float("nan"),
            "kv_blocks_peak": float(max(
                (r["kv_blocks_in_use"] for r in self.round_log),
                default=0.0)),
            "kv_pool_blocks": float(self.scheduler.kv_blocks_total()),
            # storage-plane telemetry (DESIGN.md §13): bytes, not blocks,
            # are what an int8 pool halves at equal block count
            "kv_quant": self.kv_quant,
            "kv_block_bytes": float(self.scheduler.kv_block_bytes()),
            "kv_pool_bytes": float(self.scheduler.kv_bytes_total()),
            # resident KV bytes integrated over rounds — a proxy for the
            # bytes the verify kv-sweeps stream from the pool, the
            # quantity int8 storage actually cuts (benchmarks/table9)
            "kv_bytes_swept": float(sum(
                r["kv_blocks_in_use"] for r in self.round_log))
                * float(self.scheduler.kv_block_bytes()),
            # pool-pressure aggregates + prefix-cache lifetime telemetry
            # (satellite of DESIGN.md §12): hit rate is token-weighted
            # over every (re)admission prefill the run performed
            "kv_pool_utilization_mean": (float(np.mean(
                [r["kv_pool_utilization"] for r in self.round_log]))
                if self.round_log else 0.0),
            "kv_pool_utilization_peak": float(max(
                (r["kv_pool_utilization"] for r in self.round_log),
                default=0.0)),
            "prefix_cache_hit_blocks": float(
                self.scheduler.prefix_hit_blocks_total),
            "prefix_cache_hit_rate": (
                self.scheduler.prefix_hit_tokens_total
                / max(self.scheduler.prefix_tokens_total, 1)),
            "cow_copies": float(self.scheduler.cow_copies_total),
            "prefix_cache_evictions": float(
                self.scheduler.allocator.evictions
                if self.scheduler.allocator is not None else 0.0),
        }
