"""Continuous-batching serving front-end (DESIGN.md §14).

``ServingEngine.run()`` executes a pre-built request list; this module
is the ingestion path in front of it: an arrival-stamped submission
queue, a driver loop that admits newly-arrived requests *between*
rounds while the device keeps working (the pipelined
plan/dispatch/collect from DESIGN.md §7 — admission overlaps device
execution for free), and per-token streaming from round reconciliation
to per-request consumers.

Threading model — one driver, many submitters:

* the engine is NOT thread-safe and is touched only by the driver
  (either the caller of :meth:`ServingFrontend.run_until_drained` or
  the thread :meth:`start` spawns);
* :meth:`submit` is thread-safe: it builds the :class:`Request`
  (stamping ``arrival_time`` at call time), wires its streaming
  callback, and parks it on a thread-safe ingress queue the driver
  drains before every ``pump()``;
* each submission returns a :class:`StreamHandle` whose event queue is
  fed from the driver thread at host-reconciliation time and consumed
  from any other thread (the HTTP layer bridges it into asyncio via
  ``run_in_executor``).

Exactness bar (tests/test_frontend.py): the same request set submitted
up front (all arrivals before the first pump) and driven to drain
replays ``run()``'s admit/dispatch/collect sequence verbatim —
``pump()`` IS ``run()``'s loop body — so token streams are
byte-identical to a direct ``run()`` call.  Streams are additionally
schedule-invariant (identity-threaded RNG + device-side termination,
DESIGN.md §7/§9), which is what makes mid-run admission change *when*
tokens arrive but never *which* tokens a request gets.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.serving.engine import ServingEngine
from repro.serving.request import Request, RequestState


class StreamHandle:
    """Consumer end of one request's token stream.

    The driver thread pushes ``("token", id)`` events as tokens are
    host-reconciled (in order, exactly once per emitted token) and one
    terminal ``("done", finish_reason)`` event — ``"stop"`` (EOS),
    ``"length"`` (budget), or ``"rejected"`` (infeasible at admission).
    Consume with :meth:`events` / iteration / :meth:`result` from any
    thread."""

    def __init__(self, request: Request):
        self.request = request
        self._events: "queue.SimpleQueue[Tuple[str, object]]" = (
            queue.SimpleQueue())
        self.finish_reason: Optional[str] = None
        self._drained = False

    # ------------------------------------------------------- driver side
    def _push_token(self, tok: int) -> None:
        self._events.put(("token", tok))

    def _push_done(self, reason: str) -> None:
        self.finish_reason = reason
        self._events.put(("done", reason))

    # ----------------------------------------------------- consumer side
    def events(self, timeout: Optional[float] = None):
        """Yield ``("token", id)`` events until the terminal
        ``("done", reason)`` event (yielded last).  ``timeout`` bounds
        the wait for EACH event; expiry raises ``TimeoutError``."""
        if self._drained:
            return
        while True:
            try:
                kind, val = self._events.get(timeout=timeout)
            except queue.Empty:
                raise TimeoutError(
                    f"no stream event within {timeout}s for request "
                    f"{self.request.request_id}")
            yield kind, val
            if kind == "done":
                self._drained = True
                return

    def __iter__(self):
        """Token ids only, in stream order, ending at the terminal."""
        for kind, val in self.events():
            if kind == "token":
                yield val

    def result(self, timeout: Optional[float] = None
               ) -> Tuple[List[int], Optional[str]]:
        """Block until the stream terminates; returns
        ``(tokens, finish_reason)``.  The token list is rebuilt from the
        events, so it equals ``request.output`` by the exactly-once
        contract."""
        toks = [v for k, v in self.events(timeout=timeout) if k == "token"]
        return toks, self.finish_reason


class ServingFrontend:
    """Arrival queue + driver loop + streaming over a ServingEngine.

    Two driving modes share one iteration body (:meth:`_drive_once` =
    ingest, pump, deliver terminals):

    * :meth:`run_until_drained` — the caller IS the driver; used by the
      replay harness and the exactness tests (single-threaded,
      deterministic).
    * :meth:`start` / :meth:`stop` — a daemon driver thread; used by
      the HTTP server and paced (timed-arrival) load generation, where
      submitters race the driver by design.
    """

    def __init__(self, engine: ServingEngine):
        self.engine = engine
        self._ingress: "queue.SimpleQueue[Tuple[Request, StreamHandle]]" = (
            queue.SimpleQueue())
        self._handles: Dict[int, StreamHandle] = {}
        self._done: List[Request] = []
        self._next_id = 0
        self._id_lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._t0 = time.monotonic()
        # per-pump telemetry: (t_rel, ingress_depth, sched_queue, running)
        self.queue_depth_log: List[Tuple[float, int, int, int]] = []

    # ------------------------------------------------------------ ingestion
    def submit(self, prompt: Sequence[int], max_new_tokens: int = 128,
               eos_token_id: Optional[int] = None,
               request_id: Optional[int] = None,
               slo_deadline_s: Optional[float] = None,
               priority: int = 0) -> StreamHandle:
        """Thread-safe submission; stamps ``arrival_time`` NOW and
        returns the stream handle.  ``request_id`` defaults to a
        monotonic counter; callers replaying a trace pass the trace's
        ids so the identity-threaded RNG (DESIGN.md §9) reproduces the
        exact stochastic streams of any other schedule.
        ``slo_deadline_s`` / ``priority`` thread straight onto the
        Request (DESIGN.md §15); left at their defaults the request is
        untouched by every SLO path."""
        if self._stop.is_set():
            raise RuntimeError("front-end is stopped")
        if request_id is None:
            with self._id_lock:
                request_id = self._next_id
                self._next_id += 1
        else:
            with self._id_lock:
                self._next_id = max(self._next_id, request_id + 1)
        req = Request(request_id=request_id, prompt=list(prompt),
                      max_new_tokens=max_new_tokens,
                      eos_token_id=eos_token_id,
                      slo_deadline_s=slo_deadline_s, priority=priority)
        handle = StreamHandle(req)
        req.on_token = lambda r, t: handle._push_token(t)
        self._ingress.put((req, handle))
        return handle

    def submit_request(self, req: Request) -> StreamHandle:
        """Submission path for pre-built Requests (trace replay): wires
        the stream callback, keeps the request's own arrival stamp."""
        if self._stop.is_set():
            raise RuntimeError("front-end is stopped")
        handle = StreamHandle(req)
        req.on_token = lambda r, t: handle._push_token(t)
        self._ingress.put((req, handle))
        return handle

    def _ingest(self) -> int:
        """Drain the ingress queue into the engine (driver thread only).
        FIFO, so submission order IS scheduler-queue order — the replay
        exactness argument needs nothing more."""
        n = 0
        while True:
            try:
                req, handle = self._ingress.get_nowait()
            except queue.Empty:
                return n
            self._handles[req.request_id] = handle
            self.engine.submit(req)
            n += 1

    # --------------------------------------------------------------- driving
    def _deliver_terminals(self, done: List[Request]) -> None:
        for req in done:
            self._done.append(req)
            handle = self._handles.pop(req.request_id, None)
            if handle is not None:
                reason = (req.finish_reason()
                          if req.state is RequestState.FINISHED
                          else "rejected")
                handle._push_done(reason or "length")

    def _drive_once(self) -> List[Request]:
        """One driver iteration: admit arrivals, run one ``pump()``
        (round N+1 dispatches while round N reconciles — token events
        fire from inside the pump), deliver terminal events."""
        self._ingest()
        sched = self.engine.scheduler
        self.queue_depth_log.append((
            time.monotonic() - self._t0, self._ingress.qsize(),
            len(sched.queue), len(sched.running)))
        if not self.engine.has_pending_work():
            return []
        done = self.engine.pump()
        self._deliver_terminals(done)
        return done

    def run_until_drained(self) -> List[Request]:
        """Drive everything currently (or concurrently) submitted to
        terminal state; returns the terminal requests in completion
        order.  Single-threaded: the caller is the driver."""
        out: List[Request] = []
        while True:
            if (self._ingress.qsize() == 0
                    and not self.engine.has_pending_work()):
                break
            out += self._drive_once()
        drained = self.engine.drain()
        self._deliver_terminals(drained)
        return out + drained

    def _loop(self) -> None:
        while not self._stop.is_set():
            if (self._ingress.qsize() == 0
                    and not self.engine.has_pending_work()):
                # idle: block briefly on the ingress rather than spin
                try:
                    item = self._ingress.get(timeout=0.005)
                except queue.Empty:
                    continue
                self._handles[item[0].request_id] = item[1]
                self.engine.submit(item[0])
            self._drive_once()
        self._deliver_terminals(self.engine.drain())

    def start(self) -> "ServingFrontend":
        """Spawn the daemon driver thread (idempotent)."""
        if self._thread is None or not self._thread.is_alive():
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._loop, name="serving-frontend", daemon=True)
            self._thread.start()
        return self

    def stop(self, timeout: float = 30.0) -> None:
        """Stop the driver thread; in-flight work is drained (the last
        dispatched round is reconciled) but queued work is abandoned."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=timeout)
            self._thread = None

    def wait_idle(self, timeout: float = 60.0) -> bool:
        """Block until no submitted work remains anywhere in the
        front-end or engine (threaded mode).  True on idle, False on
        timeout."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if (self._ingress.qsize() == 0 and not self._handles
                    and not self.engine.has_pending_work()):
                return True
            time.sleep(0.002)
        return False

    # ------------------------------------------------------------- telemetry
    def summary(self) -> Dict[str, float]:
        """Engine run-summary over every terminal request this front-end
        delivered, plus front-end queue-depth telemetry."""
        out = self.engine.summary(self._done, time.monotonic() - self._t0)
        depths = [q + s for _, q, s, _ in self.queue_depth_log]
        out["queue_depth_mean"] = (float(sum(depths)) / len(depths)
                                   if depths else 0.0)
        out["queue_depth_peak"] = float(max(depths, default=0))
        return out
