"""Interpretable analytic per-round latency model (DESIGN.md §15).

"An Interpretable Latency Model for Speculative Decoding in LLM
Serving" (PAPERS.md) observes that the wall cost of one speculative
round is predictable from a handful of schedule-visible quantities.
This module fits exactly that — a four-coefficient linear form

    T_round  ≈  c0  +  c_prefill · tokens  +  c_draft · K
                    +  c_verify · (K + 1) · B_eff

where ``tokens`` is the prefill tokens that rode the round's plan
phase, ``K`` the draft bucket, and ``B_eff`` the number of live rows
the round verified.  The terms mirror the round's actual phases:
``c0`` is the fixed dispatch/launch overhead, ``c_prefill`` the
per-token prefill cost, ``c_draft`` the per-step draft cost (a
property of the *drafter* — model drafters pay real forwards, lookup
drafters pay ~0), and ``c_verify`` the per-(position × row) cost of
the target verification, which scales with both the bucket and the
batch.

The fit is ordinary recursive least squares (RLS) with a forgetting
factor over the engine's existing per-round telemetry — every
``collect`` feeds one ``(features, wall_s)`` sample, so the model
tracks the *serving host it is running on* (including interference)
with O(16) floats of state and no extra timing instrumentation.  A
calibration sweep (any short run's ``round_log``) warm-starts the
coefficients via :meth:`warm_start_from_rounds` so SLO decisions are
grounded before the online fit has seen enough rounds.

Consumers:

* the ``slo`` policy (repro/core/policies/slo.py) asks
  :meth:`predict_round_s` whether the next round's predicted cost
  breaches the batch's tightest live deadline;
* ``LookaheadScheduler.admit`` asks :meth:`predict_completion_s`-style
  questions at admission (via the scheduler's own helper) to surface
  requests that cannot meet their deadline even in the best case;
* ``ServingEngine.summary()`` exposes the coefficients
  (``latency_model_*``) so every benchmark row reports the fitted
  model alongside the latencies it predicts.

Everything here is host-side numpy — nothing is traced, nothing
touches the jitted round.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np

# feature order, fixed: [1, tokens, K, (K+1)*B_eff]
COEF_NAMES = ("c0", "c_prefill", "c_draft", "c_verify")
N_COEF = 4


def round_features(k: int, b_eff: int,
                   prefill_tokens: float = 0.0) -> np.ndarray:
    """The model's regressor vector for one round."""
    return np.array(
        [1.0, float(prefill_tokens), float(k), float(k + 1) * float(b_eff)],
        np.float64)


class RoundLatencyModel:
    """RLS fit of the four-term per-round latency form.

    ``forgetting`` < 1 geometrically down-weights old rounds so the
    model tracks drifting host conditions; ``prior_scale`` sets the
    initial parameter covariance (large = the first samples dominate
    the zero prior quickly); ``min_rounds`` is the readiness gate —
    below it :meth:`ready` is False and SLO consumers fall back to
    their deadline-blind behaviour rather than act on a junk fit.
    """

    def __init__(self, forgetting: float = 0.995,
                 prior_scale: float = 1e4, min_rounds: int = 8):
        assert 0.0 < forgetting <= 1.0
        self.forgetting = float(forgetting)
        self.min_rounds = int(min_rounds)
        self.theta = np.zeros((N_COEF,), np.float64)
        self.P = np.eye(N_COEF, dtype=np.float64) * float(prior_scale)
        self.rounds_fit = 0
        # EMA of squared prediction error (pre-update residual), for the
        # summary's honesty field: how well the form actually fits
        self._mse_ema = 0.0

    # ------------------------------------------------------------------ fit
    def observe(self, wall_s: float, k: int, b_eff: int,
                prefill_tokens: float = 0.0) -> float:
        """Fold one measured round in; returns the pre-update residual
        (prediction error the model made on this round)."""
        phi = round_features(k, b_eff, prefill_tokens)
        err = float(wall_s) - float(self.theta @ phi)
        lam = self.forgetting
        Pphi = self.P @ phi
        gain = Pphi / (lam + float(phi @ Pphi))
        self.theta = self.theta + gain * err
        self.P = (self.P - np.outer(gain, Pphi)) / lam
        self.rounds_fit += 1
        a = 0.9 if self.rounds_fit > 1 else 0.0
        self._mse_ema = a * self._mse_ema + (1.0 - a) * err * err
        return err

    def warm_start_from_rounds(self, round_log: Iterable[Dict]) -> int:
        """Seed the fit from a calibration sweep: a batch ridge
        least-squares over an engine ``round_log`` (entries carrying
        ``wall_s`` / ``k`` / ``b_eff`` / ``prefill_tokens``, which every
        engine logs per round).  Returns the number of rounds absorbed;
        entries missing the fields are skipped."""
        X: List[np.ndarray] = []
        y: List[float] = []
        for rec in round_log:
            if "wall_s" not in rec or "k" not in rec:
                continue
            X.append(round_features(int(rec["k"]),
                                    int(rec.get("b_eff", 1)),
                                    float(rec.get("prefill_tokens", 0.0))))
            y.append(float(rec["wall_s"]))
        if not X:
            return 0
        Xm = np.stack(X)
        yv = np.asarray(y, np.float64)
        ridge = 1e-8 * np.eye(N_COEF)
        gram = Xm.T @ Xm + ridge
        self.theta = np.linalg.solve(gram, Xm.T @ yv)
        # the batch information becomes the RLS prior: P = gram^-1, so
        # subsequent online samples update FROM the calibration, not
        # from scratch
        self.P = np.linalg.inv(gram)
        self.rounds_fit += len(y)
        resid = yv - Xm @ self.theta
        self._mse_ema = float(np.mean(resid * resid))
        return len(y)

    # -------------------------------------------------------------- predict
    def ready(self) -> bool:
        return self.rounds_fit >= self.min_rounds

    def predict_round_s(self, k: int, b_eff: int,
                        prefill_tokens: float = 0.0) -> float:
        """Predicted wall seconds of one round at bucket ``k`` with
        ``b_eff`` live rows (clamped at 0 — a noisy fit must never
        return a negative cost to the SLO arbitration)."""
        return max(float(self.theta @ round_features(k, b_eff,
                                                     prefill_tokens)), 0.0)

    def predict_prefill_s(self, tokens: int) -> float:
        """Predicted cost of prefilling ``tokens`` (the c0 + c_prefill
        slice of the form — what an admission wave adds to the round it
        rides)."""
        return max(float(self.theta[0] + self.theta[1] * float(tokens)), 0.0)

    # ------------------------------------------------------------ telemetry
    def coefficients(self) -> Dict[str, float]:
        return {name: float(v) for name, v in zip(COEF_NAMES, self.theta)}

    def rmse_s(self) -> float:
        return float(np.sqrt(max(self._mse_ema, 0.0)))

    def summary_fields(self) -> Dict[str, float]:
        """The run-summary view: prefixed coefficient fields plus fit
        telemetry, merged into ``ServingEngine.summary()``."""
        out = {f"latency_model_{k}": v for k, v in self.coefficients().items()}
        out["latency_model_rounds_fit"] = float(self.rounds_fit)
        out["latency_model_rmse_s"] = self.rmse_s()
        return out
