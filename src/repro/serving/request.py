"""Request lifecycle for the serving engine."""
from __future__ import annotations

import dataclasses
import enum
import time
from typing import List, Optional


class RequestState(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: List[int]
    max_new_tokens: int = 128
    eos_token_id: Optional[int] = None
    # --- runtime fields -----------------------------------------------------
    state: RequestState = RequestState.QUEUED
    output: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    arrival_time: float = dataclasses.field(default_factory=time.monotonic)
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    rounds: int = 0                    # target verifications consumed
    accepted_tokens: int = 0
    proposed_tokens: int = 0

    @property
    def done(self) -> bool:
        return self.state == RequestState.FINISHED

    def latency(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.arrival_time

    def block_efficiency(self) -> float:
        """Tokens emitted per target verification (paper's BE metric)."""
        return len(self.output) / max(self.rounds, 1)

    def acceptance_rate(self) -> float:
        return self.accepted_tokens / max(self.proposed_tokens, 1)
