"""Request lifecycle for the serving engine."""
from __future__ import annotations

import dataclasses
import enum
import time
from typing import Callable, List, Optional


class RequestState(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    FINISHED = "finished"
    # oversize at admission: can never fit prompt + max_new_tokens +
    # the policy's worst-case lookahead inside max_seq_len, or (paged,
    # net of cached-prefix coverage) inside the block pool.  Terminal;
    # surfaced from ``ServingEngine.step`` and counted in the run summary.
    REJECTED = "rejected"


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: List[int]
    max_new_tokens: int = 128
    eos_token_id: Optional[int] = None
    # --- SLO fields (DESIGN.md §15) -----------------------------------------
    # completion deadline in seconds from arrival (None = no deadline).
    # The `slo` policy reduces live deadlines to a per-round budget and
    # the scheduler's admission gate checks predicted completion against
    # it; requests without one are entirely unaffected.
    slo_deadline_s: Optional[float] = None
    # admission tie-break under SLO deferral: a predicted-violation head
    # only yields to later FRESH arrivals of same-or-higher priority
    priority: int = 0
    # --- runtime fields -----------------------------------------------------
    state: RequestState = RequestState.QUEUED
    output: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    arrival_time: float = dataclasses.field(default_factory=time.monotonic)
    # first admission out of the queue (never overwritten on a
    # preemption readmit — queue wait is an arrival-side metric)
    admit_time: Optional[float] = None
    # when the request's prefill + first round were ENQUEUED on the
    # device vs when the host OBSERVED its first token at
    # reconciliation: under the pipelined engine these differ by up to
    # one round — the lag the serving metrics must not hide.
    first_dispatch_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    rounds: int = 0                    # target verifications consumed
    accepted_tokens: int = 0
    proposed_tokens: int = 0
    # SLO runtime telemetry: flagged once by the admission gate when the
    # latency model predicts even the best case misses the deadline
    # (surfaced via ``LookaheadScheduler.pop_slo_risk``), and how many
    # times admission rotated the request behind feasible fresh work
    # (bounded by ``ServingConfig.slo_defer_limit`` — never starved)
    slo_predicted_violation: bool = False
    slo_deferrals: int = 0
    # --- paged-KV fields ----------------------------------------------------
    block_ids: List[int] = dataclasses.field(default_factory=list)
    cache_len: int = 0                 # committed tokens in the KV cache
    preemptions: int = 0               # evict-and-requeue count
    admit_seq: int = -1                # admission order (LIFO preemption key)
    # --- prefix-cache fields (DESIGN.md §12) --------------------------------
    # first token the (re)admission prefill must actually compute; the
    # [0, prefill_start) prefix is served from shared cached blocks
    prefill_start: int = 0
    # admission-transient plumbing the engine consumes at prefill time:
    # blocks whose kv_pos must be reset (private, not shared) and
    # (src, dst) copy-on-write block copies to run before the prefill
    fresh_block_ids: List[int] = dataclasses.field(default_factory=list)
    cow_pairs: List[tuple] = dataclasses.field(default_factory=list)
    # hash-chain registration watermark: block_ids[:hashed_blocks] are
    # published in the allocator index, chain_hash is the running hash
    hashed_blocks: int = 0
    chain_hash: Optional[int] = None
    # lifetime totals across (re)admissions, for the summary hit rate
    prefix_tokens_total: int = 0
    prefix_hit_tokens_total: int = 0
    # --- streaming (DESIGN.md §14) ------------------------------------------
    # per-token consumer callback, fired by the engine at the moment a
    # token is host-reconciled and appended to ``output`` — in order,
    # exactly once per token, never for recompute-on-readmit prefills
    # (a readmit's pending token was already delivered when it was first
    # emitted).  The front-end threads stream handles through this.
    on_token: Optional[Callable[["Request", int], None]] = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def done(self) -> bool:
        return self.state in (RequestState.FINISHED, RequestState.REJECTED)

    def prefill_tokens(self) -> List[int]:
        """Tokens to prefill on (re)admission.  A preempted request is
        recomputed from prompt + already-emitted output; its last emitted
        token is the pending token, not yet in any cache."""
        if self.output:
            return self.prompt + self.output[:-1]
        return self.prompt

    def latency(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.arrival_time

    def deadline_remaining_s(self, now: Optional[float] = None
                             ) -> Optional[float]:
        """Seconds until the completion deadline lapses (negative once
        past it), or None when no deadline is set."""
        if self.slo_deadline_s is None:
            return None
        now = time.monotonic() if now is None else now
        return (self.arrival_time + self.slo_deadline_s) - now

    def slo_attained(self, slo_ttft_s: Optional[float] = None,
                     slo_tpot_s: Optional[float] = None) -> Optional[bool]:
        """Did the request meet its service-level objectives?

        None until finished (a rejected request never attains).  A
        finished request attains iff it clears every bound that applies:
        the caller-supplied TTFT / TPOT bounds (the loadgen ``report``
        definitions — a never-measured TTFT counts 0.0, an unmeasured
        TPOT passes) and, when ``slo_deadline_s`` is set, its own
        completion deadline.  With no deadline and no bounds supplied
        every finished request attains — exactly the pre-SLO goodput
        accounting."""
        if self.state is RequestState.REJECTED:
            return False
        if self.state is not RequestState.FINISHED:
            return None
        if slo_ttft_s is not None and (self.ttft() or 0.0) > slo_ttft_s:
            return False
        if slo_tpot_s is not None:
            tpot = self.tpot()
            if tpot is not None and tpot > slo_tpot_s:
                return False
        if self.slo_deadline_s is not None:
            lat = self.latency()
            if lat is None or lat > self.slo_deadline_s:
                return False
        return True

    def queue_wait(self) -> Optional[float]:
        """Arrival -> first admission (scheduler wait, paper §5 framing)."""
        if self.admit_time is None:
            return None
        return self.admit_time - self.arrival_time

    def ttft(self) -> Optional[float]:
        """Arrival -> first token observed by the host (reconciliation
        time under the pipelined engine, not dispatch time)."""
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    def tpot(self) -> Optional[float]:
        """Time per output token after the first (the serving-side
        decode-cadence metric paired with TTFT): first token observed ->
        finish, averaged over the remaining tokens.  None until finished
        or for single-token outputs (no decode cadence to measure)."""
        if (self.finish_time is None or self.first_token_time is None
                or len(self.output) < 2):
            return None
        return ((self.finish_time - self.first_token_time)
                / (len(self.output) - 1))

    def finish_reason(self) -> Optional[str]:
        """OpenAI-style terminal cause: "stop" (EOS) or "length"
        (max_new_tokens budget).  None while running."""
        if self.state is not RequestState.FINISHED:
            return None
        if (self.eos_token_id is not None and self.output
                and self.output[-1] == self.eos_token_id):
            return "stop"
        return "length"

    def block_efficiency(self) -> float:
        """Tokens emitted per target verification (paper's BE metric)."""
        return len(self.output) / max(self.rounds, 1)

    def acceptance_rate(self) -> float:
        return self.accepted_tokens / max(self.proposed_tokens, 1)

    def prefix_hit_rate(self) -> float:
        """Fraction of (re)admission prefill tokens served from the
        prefix cache instead of being recomputed (0.0 when the engine
        runs without prefix caching)."""
        return self.prefix_hit_tokens_total / max(self.prefix_tokens_total, 1)
