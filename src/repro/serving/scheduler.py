"""Look-ahead scheduler (paper §3.2) over a block-budget data plane.

Computes per-sequence look-ahead KV slots directly from ``SL_i^(t)`` and is
applied uniformly to prefill and decode admission — the vLLM modification
the paper describes ("removes inconsistencies between feasibility checks
and append operations and aligns capacity planning with intra-batch
heterogeneity").

Capacity planning is policy-owned on both horizons:

* **feasibility** — a request whose worst case (``prompt + max_new_tokens
  + policy.max_lookahead()``) cannot fit ``max_seq_len`` is terminally
  ``REJECTED`` (surfaced through ``pop_rejected``), never silently
  dropped;
* **per-round planning** exposes ``SpecPolicy.lookahead`` over the live
  per-sequence SL predictions the engine mirrors to the host each round
  (``lookahead_slots``).

Two admission regimes share that planning:

* **dense** (``paged_kv=False``) — one max_seq_len KV row per slot;
  admission is worst-case reservation: a free slot IS the budget.
* **paged** (``paged_kv=True``) — a :class:`BlockAllocator` owns a free
  list over the shared block pool.  Admission charges only the blocks the
  prefill actually needs; each round the engine asks
  :meth:`ensure_capacity` to grow a sequence to ``committed + SL_i + 1``
  tokens (``policy.lookahead``), and when the pool runs dry the youngest
  running request is **preempted** — its blocks return to the pool and it
  is requeued at the front for recompute-on-readmit — instead of anybody
  being rejected.  After each round the engine returns the speculative
  tail blocks via :meth:`shrink_to` (rollback stays free length
  arithmetic).  The pool must hold at least one max-length sequence
  (asserted), which guarantees preemption always converges.

The scheduler owns: the waiting queue, the slot table, the block
allocator, and both admission decisions.

Under the pipelined engine (DESIGN.md §7) every scheduler decision is
made from state that may be ONE ROUND STALE: plan(N+1) runs before
round N is reconciled, so slots freed by round N become visible one
iteration later and per-sequence ``cache_len``/SL mirrors lag by one
round.  Admission and preemption are safe under that lag by
construction — a slot is only handed out after its previous occupant
was host-reconciled and released, and the engine's block planning adds
the worst-case in-flight slack (see ``ServingEngine._plan_blocks``) so
stale mirrors can only ever OVER-allocate, never under-allocate.
"""
from __future__ import annotations

import collections
import time
from typing import List, Optional, Tuple

import numpy as np

from repro.core.config import ServingConfig, SpecDecodeConfig
from repro.core.policies import SpecPolicy, build_policy
from repro.serving.request import Request, RequestState


class BlockAllocator:
    """Free-list allocator over the shared KV block pool.

    Block ids are logical handles: id ``i`` names slot ``i`` of *both*
    the target and draft pools (the block tables mirror), so one
    allocation decision covers the whole speculative pair.
    """

    def __init__(self, num_blocks: int, block_size: int):
        assert num_blocks > 0 and block_size > 0
        self.num_blocks = num_blocks
        self.block_size = block_size
        # LIFO free list, seeded so the first allocations come out in
        # ascending id order (pleasant for debugging, irrelevant for
        # correctness — the block table indirection absorbs any order)
        self._free = list(range(num_blocks - 1, -1, -1))

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return self.num_blocks - len(self._free)

    def blocks_for(self, n_tokens: int) -> int:
        return max(0, -(-n_tokens // self.block_size))

    def alloc(self, n: int) -> Optional[List[int]]:
        """n blocks, or None (and no state change) if the pool is short."""
        if n > len(self._free):
            return None
        if n <= 0:
            return []
        out = self._free[-n:][::-1]
        del self._free[-n:]
        return out

    def free(self, blocks: List[int]) -> None:
        self._free.extend(reversed(blocks))
        assert len(self._free) <= self.num_blocks


class LookaheadScheduler:
    def __init__(self, serving: ServingConfig, spec: SpecDecodeConfig,
                 policy: Optional[SpecPolicy] = None,
                 kv_mirror: bool = True):
        """``kv_mirror``: whether the serving drafter holds a paged KV
        pool mirroring the target's block ids (``Drafter.mirrors_kv``).
        ``ServingConfig.num_kv_blocks`` budgets such a mirrored *pair*;
        a drafter with no draft-side KV halves the per-sequence charge,
        so its whole mirror budget returns to the target pool — the pool
        doubles and admits proportionally more in-flight sequences
        (DESIGN.md §9)."""
        self.serving = serving
        self.spec = spec
        self.policy = policy if policy is not None else build_policy(spec)
        self.queue: collections.deque[Request] = collections.deque()
        self.slots: List[Optional[Request]] = [None] * serving.max_batch_size
        self.allocator: Optional[BlockAllocator] = None
        if serving.paged_kv:
            pool = serving.pool_blocks() * (1 if kv_mirror else 2)
            self.allocator = BlockAllocator(pool, serving.kv_block_size)
            assert (self.allocator.num_blocks * self.allocator.block_size
                    >= serving.max_seq_len), (
                "KV pool smaller than one max-length sequence — "
                "preemption could never free enough blocks")
        # latest per-slot SL predictions (host mirror, engine-refreshed)
        self.sl_pred = np.full((serving.max_batch_size,),
                               self.policy.initial_sl_value(), np.int32)
        self._rejected: List[Request] = []
        self._admit_seq = 0
        self.preempted_total = 0

    # ------------------------------------------------------------- admission
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def update_predictions(self, sl_next: np.ndarray) -> None:
        """Engine hook: refresh the host mirror of per-sequence SL
        predictions after each round (copied — the scheduler owns its
        mirror, never aliasing the engine's)."""
        self.sl_pred = np.array(sl_next)

    def lookahead_slots(self, sl_next: Optional[np.ndarray] = None
                        ) -> np.ndarray:
        """KV slots each sequence needs next round, per the policy."""
        sl = self.sl_pred if sl_next is None else np.asarray(sl_next)
        return self.policy.lookahead(sl)

    def _fits(self, req: Request) -> bool:
        # feasibility must cover the policy's WORST-case round footprint:
        # a dynamic policy admitted at its initial SL can later predict up
        # to its max, and the verification write would overrun the budget
        need = (len(req.prompt) + req.max_new_tokens
                + self.policy.max_lookahead())
        return need <= self.serving.max_seq_len

    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    def admit(self) -> List[Request]:
        """Move queued requests into free slots (continuous batching).

        Dense: a free slot is a full max_seq_len reservation.  Paged: the
        request is also charged ``ceil(prefill_len / block_size)`` pool
        blocks up front; if the pool cannot cover the next request's
        prefill it stays queued (preemption during the round, not
        admission, resolves sustained pressure).  Infeasible (oversize)
        requests become ``REJECTED`` and are drained via
        :meth:`pop_rejected`."""
        admitted = []
        free = collections.deque(self.free_slots())
        while free and self.queue:
            req = self.queue[0]
            if not self._fits(req):
                self.queue.popleft()
                req.state = RequestState.REJECTED
                req.finish_time = time.monotonic()
                self._rejected.append(req)
                continue
            if self.allocator is not None:
                need = self.allocator.blocks_for(len(req.prefill_tokens()))
                blocks = self.allocator.alloc(need)
                if blocks is None:
                    break               # pool dry: keep queued, stop here
                req.block_ids = blocks
            self.queue.popleft()
            i = free.popleft()
            req.slot = i
            req.state = RequestState.RUNNING
            req.admit_seq = self._admit_seq
            self._admit_seq += 1
            if req.admit_time is None:       # readmits keep the first wait
                req.admit_time = time.monotonic()
            self.slots[i] = req
            admitted.append(req)
        return admitted

    def pop_rejected(self) -> List[Request]:
        out, self._rejected = self._rejected, []
        return out

    def drop_from_queue(self, req: Request) -> None:
        """Remove a queued request that reached a terminal state while
        waiting.  Pipelined reconciliation needs this: a request can be
        preempted at plan time and then FINISH when the round it was
        still part of is collected one iteration later — it must not be
        readmitted and recomputed."""
        try:
            self.queue.remove(req)
        except ValueError:
            pass

    # ---------------------------------------------------------- block budget
    def ensure_capacity(self, req: Request, n_tokens: int
                        ) -> Tuple[List[int], List[Request]]:
        """Grow ``req``'s allocation to cover ``n_tokens`` KV slots,
        preempting the youngest other running requests while the pool is
        dry.  Returns (newly allocated block ids, preempted requests).
        The caller must reset ``kv_pos`` of the new blocks and mirror the
        table rows to the device caches."""
        assert self.allocator is not None
        need = self.allocator.blocks_for(n_tokens) - len(req.block_ids)
        if need <= 0:
            return [], []
        preempted: List[Request] = []
        while True:
            blocks = self.allocator.alloc(need)
            if blocks is not None:
                req.block_ids.extend(blocks)
                return blocks, preempted
            victim = self._pick_victim(exclude=req)
            assert victim is not None, (
                "pool exhausted with nothing to preempt — the single-"
                "sequence pool guarantee should make this unreachable")
            self.preempt(victim)
            preempted.append(victim)

    def _pick_victim(self, exclude: Request) -> Optional[Request]:
        running = [r for r in self.slots if r is not None and r is not exclude]
        if not running:
            return None
        return max(running, key=lambda r: r.admit_seq)   # LIFO: youngest

    def preempt(self, req: Request) -> None:
        """Evict-and-requeue: free every block, requeue at the *front* so
        the request readmits first and recomputes its prefix
        (prompt + emitted output) on readmission."""
        assert self.allocator is not None and req.slot is not None
        self.allocator.free(req.block_ids)
        req.block_ids = []
        self.slots[req.slot] = None
        req.slot = None
        req.cache_len = 0
        req.state = RequestState.QUEUED
        req.preemptions += 1
        self.preempted_total += 1
        self.queue.appendleft(req)

    def shrink_to(self, req: Request, n_tokens: int) -> List[int]:
        """Return the speculative-tail blocks beyond ``n_tokens`` committed
        slots to the pool (post-round rollback is free)."""
        assert self.allocator is not None
        keep = self.allocator.blocks_for(n_tokens)
        freed = req.block_ids[keep:]
        if freed:
            del req.block_ids[keep:]
            self.allocator.free(freed)
        return freed

    def release(self, req: Request) -> None:
        if req.slot is not None:
            self.slots[req.slot] = None
            req.slot = None
        if self.allocator is not None and req.block_ids:
            self.allocator.free(req.block_ids)
            req.block_ids = []

    # ------------------------------------------------------------- telemetry
    @property
    def active_mask(self) -> np.ndarray:
        return np.array([r is not None for r in self.slots], bool)

    @property
    def running(self) -> List[Request]:
        return [r for r in self.slots if r is not None]

    def kv_blocks_in_use(self) -> int:
        """Blocks charged against the pool (paged), or the dense-row
        equivalent (active slots x blocks-per-row) so the same telemetry
        field plots memory-vs-throughput across both layouts."""
        if self.allocator is not None:
            return self.allocator.n_used
        return int(self.active_mask.sum()) * self.serving.blocks_per_seq()

    def kv_blocks_total(self) -> int:
        if self.allocator is not None:
            return self.allocator.num_blocks
        return self.serving.max_batch_size * self.serving.blocks_per_seq()

    def has_work(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slots)
