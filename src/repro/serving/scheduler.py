"""Look-ahead slot scheduler (paper §3.2).

Computes per-sequence look-ahead KV slots directly from ``SL_i^(t)`` and is
applied uniformly to prefill and decode admission — the vLLM modification
the paper describes ("removes inconsistencies between feasibility checks
and append operations and aligns capacity planning with intra-batch
heterogeneity").

Capacity planning is policy-owned on both horizons:

* **admission** reserves ``SpecPolicy.max_lookahead()`` — the worst-case
  KV slots one round can write under that policy (1 for autoregressive,
  ``static_sl + 1`` for static, ``sl_max + 1`` for dynamic policies) —
  so a new policy gets correct admission behaviour for free;
* **per-round planning** exposes ``SpecPolicy.lookahead`` over the live
  per-sequence SL predictions the engine mirrors to the host each round
  (``lookahead_slots``), surfacing intra-batch heterogeneity in the
  engine's round telemetry.

The scheduler owns: the waiting queue, the slot table, and the admission
decision (does the remaining KV budget of a slot cover prompt +
worst-case lookahead + max_new_tokens?).
"""
from __future__ import annotations

import collections
from typing import List, Optional

import numpy as np

from repro.core.config import ServingConfig, SpecDecodeConfig
from repro.core.policies import SpecPolicy, build_policy
from repro.serving.request import Request, RequestState


class LookaheadScheduler:
    def __init__(self, serving: ServingConfig, spec: SpecDecodeConfig,
                 policy: Optional[SpecPolicy] = None):
        self.serving = serving
        self.spec = spec
        self.policy = policy if policy is not None else build_policy(spec)
        self.queue: collections.deque[Request] = collections.deque()
        self.slots: List[Optional[Request]] = [None] * serving.max_batch_size
        # latest per-slot SL predictions (host mirror, engine-refreshed)
        self.sl_pred = np.full((serving.max_batch_size,),
                               self.policy.initial_sl_value(), np.int32)

    # ------------------------------------------------------------- admission
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def update_predictions(self, sl_next: np.ndarray) -> None:
        """Engine hook: refresh the host mirror of per-sequence SL
        predictions after each round (copied — the scheduler owns its
        mirror, never aliasing the engine's)."""
        self.sl_pred = np.array(sl_next)

    def lookahead_slots(self, sl_next: Optional[np.ndarray] = None
                        ) -> np.ndarray:
        """KV slots each sequence needs next round, per the policy."""
        sl = self.sl_pred if sl_next is None else np.asarray(sl_next)
        return self.policy.lookahead(sl)

    def _fits(self, req: Request) -> bool:
        # admission must reserve the policy's WORST-case round footprint:
        # a dynamic policy admitted at its initial SL can later predict up
        # to its max, and the verification write would overrun the KV row
        need = (len(req.prompt) + req.max_new_tokens
                + self.policy.max_lookahead())
        return need <= self.serving.max_seq_len

    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    def admit(self) -> List[Request]:
        """Move queued requests into free slots (continuous batching)."""
        admitted = []
        for i in self.free_slots():
            if not self.queue:
                break
            req = self.queue.popleft()
            if not self._fits(req):
                req.state = RequestState.FINISHED   # reject oversize
                continue
            req.slot = i
            req.state = RequestState.RUNNING
            self.slots[i] = req
            admitted.append(req)
        return admitted

    def release(self, req: Request) -> None:
        if req.slot is not None:
            self.slots[req.slot] = None
            req.slot = None

    # ------------------------------------------------------------- telemetry
    @property
    def active_mask(self) -> np.ndarray:
        return np.array([r is not None for r in self.slots], bool)

    @property
    def running(self) -> List[Request]:
        return [r for r in self.slots if r is not None]

    def has_work(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slots)
