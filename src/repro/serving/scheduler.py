"""Look-ahead scheduler (paper §3.2) over a block-budget data plane.

Computes per-sequence look-ahead KV slots directly from ``SL_i^(t)`` and is
applied uniformly to prefill and decode admission — the vLLM modification
the paper describes ("removes inconsistencies between feasibility checks
and append operations and aligns capacity planning with intra-batch
heterogeneity").

Capacity planning is policy-owned on both horizons:

* **feasibility** — a request whose worst case (``prompt + max_new_tokens
  + policy.max_lookahead()``) cannot fit ``max_seq_len`` is terminally
  ``REJECTED`` (surfaced through ``pop_rejected``), never silently
  dropped;
* **per-round planning** exposes ``SpecPolicy.lookahead`` over the live
  per-sequence SL predictions the engine mirrors to the host each round
  (``lookahead_slots``).

Two admission regimes share that planning:

* **dense** (``paged_kv=False``) — one max_seq_len KV row per slot;
  admission is worst-case reservation: a free slot IS the budget.
* **paged** (``paged_kv=True``) — a :class:`BlockAllocator` owns a free
  list over the shared block pool.  Admission charges only the blocks the
  prefill actually needs; each round the engine asks
  :meth:`ensure_capacity` to grow a sequence to ``committed + SL_i + 1``
  tokens (``policy.lookahead``), and when the pool runs dry the youngest
  running request is **preempted** — its blocks return to the pool and it
  is requeued at the front for recompute-on-readmit — instead of anybody
  being rejected.  After each round the engine returns the speculative
  tail blocks via :meth:`shrink_to` (rollback stays free length
  arithmetic).  The pool must hold at least one max-length sequence
  (asserted), which guarantees preemption always converges.

The scheduler owns: the waiting queue, the slot table, the block
allocator, and both admission decisions.

Under the pipelined engine (DESIGN.md §7) every scheduler decision is
made from state that may be ONE ROUND STALE: plan(N+1) runs before
round N is reconciled, so slots freed by round N become visible one
iteration later and per-sequence ``cache_len``/SL mirrors lag by one
round.  Admission and preemption are safe under that lag by
construction — a slot is only handed out after its previous occupant
was host-reconciled and released, and the engine's block planning adds
the worst-case in-flight slack (see ``ServingEngine._plan_blocks``) so
stale mirrors can only ever OVER-allocate, never under-allocate.
"""
from __future__ import annotations

import collections
import math
import time
from typing import Any, List, Optional, Tuple

import numpy as np

from repro.core.config import ServingConfig, SpecDecodeConfig
from repro.core.policies import HostRoundContext, SpecPolicy, build_policy
from repro.serving.request import Request, RequestState


class BlockAllocator:
    """Refcounted free-list allocator over the shared KV block pool.

    Block ids are logical handles: id ``i`` names slot ``i`` of *both*
    the target and draft pools (the block tables mirror), so one
    allocation decision covers the whole speculative pair.

    Prefix caching (DESIGN.md §4/§12) layers three structures on top of
    the plain free list:

    * ``refcount[b]`` — how many block tables reference physical block
      ``b``.  :meth:`alloc` hands out blocks at refcount 1,
      :meth:`acquire` maps an already-resident block into another
      sequence (incref), and :meth:`free` is a *decref* — a block only
      leaves circulation when its last reference drops.
    * a content-hash index over committed **full** blocks: each
      registered block stores ``(parent_hash, block_tokens)`` and is
      addressed by the chained hash of that pair, so a prefix match is
      a walk down the chain.  Stored tokens are compared on lookup —
      a hash collision degrades to a cache miss, never a wrong block.
    * an LRU *evictable* list: registered blocks whose refcount drops
      to 0 stay warm (still index-addressable, revivable by
      :meth:`acquire`) and are reclaimed oldest-first only when
      :meth:`alloc` finds the free list short.  Unregistered blocks
      return straight to the free list as before.

    Pool accounting invariant (property-tested):
    ``free + evictable + |{b : refcount[b] > 0}| == num_blocks`` with
    the three sets pairwise disjoint.
    """

    def __init__(self, num_blocks: int, block_size: int,
                 block_bytes: int = 0):
        assert num_blocks > 0 and block_size > 0
        self.num_blocks = num_blocks
        self.block_size = block_size
        # bytes one physical block costs in the backing pool (dtype- and
        # quant-mode-aware, cache_lib.kv_block_bytes).  Blocks stay the
        # allocation unit — bytes are telemetry: an int8 pool admits the
        # same block count at under half the bytes (DESIGN.md §13).
        self.block_bytes = block_bytes
        # LIFO free list, seeded so the first allocations come out in
        # ascending id order (pleasant for debugging, irrelevant for
        # correctness — the block table indirection absorbs any order)
        self._free = list(range(num_blocks - 1, -1, -1))
        self.refcount = [0] * num_blocks
        # chain_hash -> block id holding that prefix block
        self._index: dict = {}
        # block id -> (parent_hash, tokens_tuple, chain_hash)
        self._meta: dict = {}
        # unreferenced-but-registered blocks, insertion order = LRU
        # (oldest first; revived blocks re-enter at the recent end)
        self._evictable: "collections.OrderedDict[int, None]" = (
            collections.OrderedDict())
        self.evictions = 0

    @property
    def n_free(self) -> int:
        """Allocatable blocks: truly free plus warm evictable."""
        return len(self._free) + len(self._evictable)

    @property
    def n_used(self) -> int:
        """Blocks referenced by at least one block table."""
        return self.num_blocks - self.n_free

    @property
    def n_cached(self) -> int:
        """Warm unreferenced blocks held for prefix reuse."""
        return len(self._evictable)

    @property
    def bytes_total(self) -> int:
        """Pool footprint in bytes (0 when the caller never sized it)."""
        return self.num_blocks * self.block_bytes

    @property
    def bytes_in_use(self) -> int:
        return self.n_used * self.block_bytes

    def blocks_for(self, n_tokens: int) -> int:
        return max(0, -(-n_tokens // self.block_size))

    # ------------------------------------------------------------ hash chain
    @staticmethod
    def _chain_hash(parent_hash: Optional[int],
                    tokens: Tuple[int, ...]) -> int:
        # int-tuple hashing is deterministic within a process, which is
        # all the host-side index needs (nothing device-visible).
        return hash((parent_hash, tokens))

    def match_prefix(self, tokens) -> Tuple[List[int], Optional[int], int]:
        """Walk ``tokens`` down the hash chain over full blocks.

        Returns ``(block_ids, last_chain_hash, covered_tokens)`` for the
        longest cached prefix.  Does NOT take references — callers pair
        it with :meth:`acquire` once admission is certain."""
        ids: List[int] = []
        parent: Optional[int] = None
        bs = self.block_size
        for i in range(len(tokens) // bs):
            chunk = tuple(int(t) for t in tokens[i * bs:(i + 1) * bs])
            h = self._chain_hash(parent, chunk)
            bid = self._index.get(h)
            if bid is None:
                break
            meta = self._meta[bid]
            if meta[0] != parent or meta[1] != chunk:
                break                       # collision: treat as a miss
            ids.append(bid)
            parent = h
        return ids, parent, len(ids) * bs

    def register(self, block_id: int, parent_hash: Optional[int],
                 tokens: Tuple[int, ...]) -> int:
        """Publish a committed full block under its chain hash.

        First writer wins: if the hash is already indexed (another
        sequence committed the same prefix first) the caller keeps its
        private copy unshared and future matches converge on the
        canonical block.  Returns the chain hash either way so callers
        can thread it as the next block's parent."""
        assert self.refcount[block_id] > 0, "registering an unowned block"
        h = self._chain_hash(parent_hash, tokens)
        if h not in self._index and block_id not in self._meta:
            self._index[h] = block_id
            self._meta[block_id] = (parent_hash, tokens, h)
        return h

    def _unregister(self, block_id: int) -> None:
        meta = self._meta.pop(block_id, None)
        if meta is not None and self._index.get(meta[2]) == block_id:
            del self._index[meta[2]]

    # ------------------------------------------------------------ lifecycle
    def alloc(self, n: int) -> Optional[List[int]]:
        """n private blocks at refcount 1, or None (and no state change)
        if free + evictable cannot cover the ask.  Evicts warm cached
        blocks oldest-first only under pressure — a hit on a block that
        was never evicted costs nothing."""
        if n > len(self._free) + len(self._evictable):
            return None
        if n <= 0:
            return []
        while len(self._free) < n:
            bid, _ = self._evictable.popitem(last=False)     # LRU oldest
            self._unregister(bid)
            self._free.append(bid)
            self.evictions += 1
        out = self._free[-n:][::-1]
        del self._free[-n:]
        for b in out:
            assert self.refcount[b] == 0
            self.refcount[b] = 1
        return out

    def acquire(self, blocks: List[int]) -> None:
        """Map already-resident blocks into one more block table
        (incref), reviving warm evictable blocks in place."""
        for b in blocks:
            if self.refcount[b] == 0:
                self._evictable.pop(b)      # registered + warm, by invariant
            self.refcount[b] += 1

    def free(self, blocks: List[int]) -> None:
        """Decref.  A block leaves circulation only at refcount 0:
        registered blocks stay warm on the evictable LRU (recent end),
        unregistered blocks return to the free list."""
        for b in blocks:
            assert self.refcount[b] > 0, "double free"
            self.refcount[b] -= 1
            if self.refcount[b] == 0:
                meta = self._meta.get(b)
                if meta is not None and self._index.get(meta[2]) == b:
                    self._evictable[b] = None
                else:
                    self._unregister(b)
                    self._free.append(b)
        assert len(self._free) + len(self._evictable) <= self.num_blocks

    def fork_cow(self, block_id: int) -> Optional[int]:
        """Copy-on-write split: allocate a private destination block and
        drop this table's reference on the shared source.  Returns the
        destination id (caller schedules the device-side block copy) or
        None if the pool cannot cover it.  The source is safe from the
        eviction inside :meth:`alloc` because the caller still holds its
        reference until the :meth:`free` below."""
        dst = self.alloc(1)
        if dst is None:
            return None
        self.free([block_id])
        return dst[0]

    def check_invariants(self) -> None:
        """Property-test hook: free/evictable/referenced partition the
        pool and no block is simultaneously free and referenced."""
        free = set(self._free)
        warm = set(self._evictable)
        ref = {b for b in range(self.num_blocks) if self.refcount[b] > 0}
        assert len(free) == len(self._free), "duplicate ids on free list"
        assert not (free & ref), "block simultaneously free and referenced"
        assert not (warm & ref), "block simultaneously warm and referenced"
        assert not (free & warm), "block simultaneously free and warm"
        assert len(free) + len(warm) + len(ref) == self.num_blocks
        for b in warm:
            meta = self._meta.get(b)
            assert meta is not None and self._index.get(meta[2]) == b, (
                "evictable block not reachable from the hash index")


class LookaheadScheduler:
    def __init__(self, serving: ServingConfig, spec: SpecDecodeConfig,
                 policy: Optional[SpecPolicy] = None,
                 kv_mirror: bool = True,
                 prefix_cache: Optional[bool] = None,
                 block_bytes: int = 0):
        """``kv_mirror``: whether the serving drafter holds a paged KV
        pool mirroring the target's block ids (``Drafter.mirrors_kv``).
        ``ServingConfig.num_kv_blocks`` budgets such a mirrored *pair*;
        a drafter with no draft-side KV halves the per-sequence charge,
        so its whole mirror budget returns to the target pool — the pool
        doubles and admits proportionally more in-flight sequences
        (DESIGN.md §9).

        ``prefix_cache`` overrides ``serving.prefix_caching`` — the
        engine passes the *effective* flag after gating on model-family
        support (recurrent per-slot state cannot be recovered from the
        block pool, DESIGN.md §12).

        ``block_bytes``: bytes one pool block costs under the serving
        cache's dtype/quant mode (``cache_lib.kv_block_bytes``); the
        engine sources it from the target config.  Purely telemetry —
        admission stays block-denominated — but it is what makes the
        ``kv_pool_bytes`` metrics honest across fp and int8 pools."""
        self.serving = serving
        self.spec = spec
        self.policy = policy if policy is not None else build_policy(spec)
        self.queue: collections.deque[Request] = collections.deque()
        self.slots: List[Optional[Request]] = [None] * serving.max_batch_size
        self.allocator: Optional[BlockAllocator] = None
        self.prefix_cache = bool(
            serving.prefix_caching if prefix_cache is None else prefix_cache)
        self.prefix_cache = self.prefix_cache and serving.paged_kv
        if serving.paged_kv:
            pool = serving.pool_blocks() * (1 if kv_mirror else 2)
            self.allocator = BlockAllocator(pool, serving.kv_block_size,
                                            block_bytes=block_bytes)
            # Without prefix caching the pool must hold one max-length
            # sequence outright, so LIFO preemption always converges.
            # With it, smaller pools are admissible: the pool-feasibility
            # term of _fits rejects requests that could never be
            # resident, and ensure_capacity self-preempts (warm readmit
            # through the cache) instead of asserting.
            assert self.prefix_cache or (
                self.allocator.num_blocks * self.allocator.block_size
                >= serving.max_seq_len), (
                "KV pool smaller than one max-length sequence — "
                "preemption could never free enough blocks")
        self.block_bytes = block_bytes
        # latest per-slot SL predictions (host mirror, engine-refreshed)
        self.sl_pred = np.full((serving.max_batch_size,),
                               self.policy.initial_sl_value(), np.int32)
        self._rejected: List[Request] = []
        self._admit_seq = 0
        self.preempted_total = 0
        # SLO-aware admission (DESIGN.md §15): the engine installs its
        # RoundLatencyModel here; without one (or before it is ready)
        # admission is deadline-blind, exactly the pre-SLO behaviour.
        self.latency_model: Optional[Any] = None
        self._slo_risk: List[Request] = []
        self.slo_predicted_violations = 0
        self.slo_deferrals_total = 0
        # lifetime prefix-cache telemetry (engine aggregates per-round)
        self.prefix_hit_blocks_total = 0
        self.cow_copies_total = 0
        self.prefix_tokens_total = 0
        self.prefix_hit_tokens_total = 0

    def _caching(self) -> bool:
        return self.allocator is not None and self.prefix_cache

    # ------------------------------------------------------------- admission
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def update_predictions(self, sl_next: np.ndarray) -> None:
        """Engine hook: refresh the host mirror of per-sequence SL
        predictions after each round (copied — the scheduler owns its
        mirror, never aliasing the engine's)."""
        self.sl_pred = np.array(sl_next)

    def host_context(self, sl_next: Optional[np.ndarray] = None,
                     round_ordinal: int = 0,
                     now: Optional[float] = None) -> HostRoundContext:
        """Build the round's :class:`HostRoundContext` — the host-side
        batch-global view handed to the policy hooks.  Per-slot
        deadline-remaining and token budgets come from the slot table
        (``+inf`` / 0 for empty or deadline-free slots); the latency
        model is whatever the engine installed.  Everything is host
        state the scheduler already owns — no device sync."""
        sl = self.sl_pred if sl_next is None else np.asarray(sl_next)
        b = self.serving.max_batch_size
        deadlines = np.full((b,), np.inf)
        tokens_rem = np.zeros((b,), np.int64)
        if any(r is not None and r.slo_deadline_s is not None
               for r in self.slots):
            now = time.monotonic() if now is None else now
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            tokens_rem[i] = max(r.max_new_tokens - len(r.output), 0)
            if r.slo_deadline_s is not None:
                deadlines[i] = (r.arrival_time + r.slo_deadline_s) - now
        return HostRoundContext(
            sl_next=sl, active=self.active_mask,
            deadline_remaining_s=deadlines, tokens_remaining=tokens_rem,
            latency_model=self.latency_model, round_ordinal=round_ordinal)

    def lookahead_slots(self, sl_next: Optional[np.ndarray] = None
                        ) -> np.ndarray:
        """KV slots each sequence needs next round, per the policy."""
        return self.policy.lookahead(self.host_context(sl_next))

    def _fits(self, req: Request, covered_blocks: int = 0) -> bool:
        # feasibility must cover the policy's WORST-case round footprint:
        # a dynamic policy admitted at its initial SL can later predict up
        # to its max, and the verification write would overrun the budget
        need = (len(req.prompt) + req.max_new_tokens
                + self.policy.max_lookahead())
        if need > self.serving.max_seq_len:
            return False
        if self.allocator is not None:
            # Pool-feasibility: a request whose worst-case block
            # residency can never fit the pool would preempt-requeue
            # forever — reject it up front.  Cached-prefix coverage
            # discounts the ask: covered blocks are already resident
            # (paid for by the cache, shareable across requesters), so
            # only the uncovered suffix must come out of the pool.  A
            # request that fits only BECAUSE of cache hits admits.
            # Legacy configs (no prefix cache) are unaffected: the init
            # assert pins pool >= max_seq_len there, so the max_seq_len
            # term above already subsumes this one.
            uncovered = self.allocator.blocks_for(need) - covered_blocks
            if uncovered > self.allocator.num_blocks:
                return False
        return True

    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    def _is_readmit(self, req: Request) -> bool:
        """A queued request that has run before (evict-and-requeue)."""
        return req.preemptions > 0 or req.admit_time is not None

    def assert_readmit_fifo(self) -> None:
        """Starvation guard: preempted readmits form a contiguous PREFIX
        of the queue, ahead of every fresh arrival — a preempted request
        always readmits before new work is started, so sustained arrival
        pressure can delay but never starve in-flight requests.

        Holds by construction — fresh arrivals only ever ``append``
        (:meth:`submit`), readmits only ever ``appendleft``
        (:meth:`preempt`), and requests leave the queue strictly from
        the front — but the assert pins it against future scheduler
        edits.  Tie-break among the readmits themselves: within one
        preemption wave :meth:`ensure_capacity` picks victims
        youngest-first (LIFO by ``admit_seq``) and each ``appendleft``
        reverses that, so the wave lands oldest-admission-first — FIFO
        in admission order; across waves the most recent wave sits in
        front (the recompute-on-readmit stack discipline
        :meth:`preempt` documents)."""
        seen_fresh = False
        for r in self.queue:
            if self._is_readmit(r):
                assert not seen_fresh, (
                    "readmit queued behind a fresh arrival — starvation")
            else:
                seen_fresh = True

    # ------------------------------------------------------- SLO admission
    def predict_completion_s(self, req: Request) -> Optional[float]:
        """Best-case predicted wall seconds for ``req`` to finish once
        admitted, from the analytic latency model (DESIGN.md §15): its
        prefill cost plus ``ceil(tokens_remaining / (K+1))`` rounds at
        the policy's typical bucket against the current live batch.
        Best-case (every draft position accepted) by design — admission
        only flags requests that cannot attain their deadline *even if
        everything goes right*, so feasible requests are never gated on
        a pessimistic guess.  None when no model is installed/ready."""
        lm = self.latency_model
        if lm is None or not lm.ready():
            return None
        k = int(min(max(self.policy.initial_sl_value(), self.spec.sl_min)
                    if self.policy.uses_draft() else 0,
                    self.policy.max_bucket()))
        b_eff = min(len(self.running) + 1, self.serving.max_batch_size)
        tokens = max(req.max_new_tokens - len(req.output), 1)
        rounds = math.ceil(tokens / float(k + 1))
        return (lm.predict_prefill_s(len(req.prefill_tokens()))
                + rounds * lm.predict_round_s(k, b_eff))

    def _surface_slo_risk(self, req: Request) -> None:
        if not req.slo_predicted_violation:
            req.slo_predicted_violation = True
            self.slo_predicted_violations += 1
            self._slo_risk.append(req)

    def _slo_feasible_behind(self, head: Request, now: float) -> bool:
        """Is there a later FRESH request (same or higher priority) that
        is predicted to attain its deadline?  Only then is deferring the
        head worth anything — otherwise it admits in order."""
        for r in list(self.queue)[1:]:
            if self._is_readmit(r) or r.priority < head.priority:
                continue
            if r.slo_deadline_s is None:
                return True
            t = self.predict_completion_s(r)
            if t is None or now + t <= r.arrival_time + r.slo_deadline_s:
                return True
        return False

    def pop_slo_risk(self) -> List[Request]:
        """Drain requests newly flagged as predicted SLO violations
        (surfaced exactly once each; the flag stays on the request)."""
        out, self._slo_risk = self._slo_risk, []
        return out

    def admit(self) -> List[Request]:
        """Move queued requests into free slots (continuous batching).

        Dense: a free slot is a full max_seq_len reservation.  Paged: the
        request is also charged ``ceil(prefill_len / block_size)`` pool
        blocks up front; if the pool cannot cover the next request's
        prefill it stays queued (preemption during the round, not
        admission, resolves sustained pressure).  Infeasible (oversize)
        requests become ``REJECTED`` and are drained via
        :meth:`pop_rejected`.

        SLO gate (DESIGN.md §15): alongside the block-budget ``_fits``
        check, a fresh deadline-carrying head whose *best-case*
        predicted completion already breaches its deadline is surfaced
        (:meth:`pop_slo_risk`) and — at most ``slo_defer_limit`` times,
        and only when a feasible same-or-higher-priority fresh arrival
        waits behind it — rotated to the back so attainable work is not
        burned behind a lost cause.  It is never rejected or dropped:
        past the limit (or with nothing feasible behind it) it admits in
        order, flagged.  Readmits are never deferred, and with no
        deadlines in the queue this path is inert, so admission order is
        exactly the pre-SLO order.

        Ordering: strict queue order, and :meth:`assert_readmit_fifo`
        pins the starvation guard — preempted readmits sit ahead of
        every fresh arrival, FIFO among themselves."""
        if __debug__:
            self.assert_readmit_fifo()
        admitted = []
        free = collections.deque(self.free_slots())
        deferred_ids: set = set()
        now = None
        while free and self.queue:
            req = self.queue[0]
            if (req.slo_deadline_s is not None
                    and not self._is_readmit(req)
                    and self.latency_model is not None
                    and self.latency_model.ready()):
                now = time.monotonic() if now is None else now
                t_pred = self.predict_completion_s(req)
                if (t_pred is not None and
                        now + t_pred > req.arrival_time + req.slo_deadline_s):
                    self._surface_slo_risk(req)
                    if (id(req) not in deferred_ids
                            and req.slo_deferrals < self.serving.slo_defer_limit
                            and self._slo_feasible_behind(req, now)):
                        self.queue.popleft()
                        self.queue.append(req)
                        req.slo_deferrals += 1
                        self.slo_deferrals_total += 1
                        deferred_ids.add(id(req))
                        continue
            toks = req.prefill_tokens()
            plen = len(toks)
            covered_ids: List[int] = []
            last_hash: Optional[int] = None
            covered = 0
            if self._caching():
                covered_ids, last_hash, covered = (
                    self.allocator.match_prefix(toks))
            if not self._fits(req, covered_blocks=len(covered_ids)):
                self.queue.popleft()
                req.state = RequestState.REJECTED
                req.finish_time = time.monotonic()
                self._rejected.append(req)
                continue
            if self.allocator is not None:
                if covered == plen:
                    # Full block-aligned hit: every prompt token is
                    # cached, but sampling the first new token needs the
                    # logits at position plen-1 — recompute just that
                    # token into a COW copy of the last shared block
                    # (its other positions arrive by device-side copy).
                    shared = covered_ids[:-1]
                    start = plen - 1
                else:
                    shared = covered_ids
                    start = covered
                need = self.allocator.blocks_for(plen) - len(shared)
                # Pin EVERY matched block before alloc: alloc reclaims
                # refcount-0 cached blocks under pressure, and the match
                # — including the COW source, which is not part of the
                # request's own table — must survive that reclaim.  The
                # COW source's pin is dropped by the engine once the
                # device-side copy is enqueued (release_cow_sources);
                # device program order keeps the copy ahead of any later
                # owner's reset.
                self.allocator.acquire(covered_ids)
                fresh = self.allocator.alloc(need)
                if fresh is None:
                    self.allocator.free(covered_ids)
                    if not any(r is not None for r in self.slots):
                        # Nothing is running, so nothing will ever decref
                        # more blocks: even a fully drained pool cannot
                        # hold this request's committed prefix.  Terminal
                        # reject instead of spinning forever.
                        self.queue.popleft()
                        req.state = RequestState.REJECTED
                        req.finish_time = time.monotonic()
                        self._rejected.append(req)
                        continue
                    break           # pool dry: keep queued, stop here
                req.block_ids = shared + fresh
                req.fresh_block_ids = list(fresh)
                req.prefill_start = start
                if covered == plen:
                    req.cow_pairs = [(covered_ids[-1], fresh[0])]
                    req.chain_hash = (
                        self.allocator._meta[covered_ids[-1]][0])
                else:
                    req.cow_pairs = []
                    req.chain_hash = last_hash
                req.hashed_blocks = len(shared)
                req.prefix_tokens_total += plen
                req.prefix_hit_tokens_total += start
                self.prefix_hit_blocks_total += len(shared)
                self.cow_copies_total += len(req.cow_pairs)
                self.prefix_tokens_total += plen
                self.prefix_hit_tokens_total += start
            self.queue.popleft()
            i = free.popleft()
            req.slot = i
            req.state = RequestState.RUNNING
            req.admit_seq = self._admit_seq
            self._admit_seq += 1
            if req.admit_time is None:       # readmits keep the first wait
                req.admit_time = time.monotonic()
            self.slots[i] = req
            admitted.append(req)
        return admitted

    def register_prefix(self, req: Request) -> None:
        """Publish ``req``'s newly committed full blocks in the hash
        index (engine hook, called after prefill dispatch and after each
        round's commit).  Registration trails the committed boundary
        strictly — a registered block is full and below ``cache_len``,
        so in-flight speculative writes (always at or above the
        committed boundary) can never touch a shared block, which is
        what makes sharing safe under the pipelined schedule."""
        if not self._caching() or req.slot is None:
            return
        bs = self.allocator.block_size
        toks = req.prompt + req.output
        full = min(req.cache_len, len(toks)) // bs
        full = min(full, len(req.block_ids))
        while req.hashed_blocks < full:
            i = req.hashed_blocks
            chunk = tuple(int(t) for t in toks[i * bs:(i + 1) * bs])
            req.chain_hash = self.allocator.register(
                req.block_ids[i], req.chain_hash, chunk)
            req.hashed_blocks += 1

    def release_cow_sources(self, req: Request) -> None:
        """Drop the admission-time pins on ``req``'s copy-on-write source
        blocks (engine hook, called once the device-side block copy has
        been ENQUEUED — program order then keeps the copy ahead of any
        later owner's writes even if the source is reclaimed now)."""
        if self._caching() and req.cow_pairs:
            self.allocator.free([src for src, _ in req.cow_pairs])

    def pop_rejected(self) -> List[Request]:
        out, self._rejected = self._rejected, []
        return out

    def drop_from_queue(self, req: Request) -> None:
        """Remove a queued request that reached a terminal state while
        waiting.  Pipelined reconciliation needs this: a request can be
        preempted at plan time and then FINISH when the round it was
        still part of is collected one iteration later — it must not be
        readmitted and recomputed."""
        try:
            self.queue.remove(req)
        except ValueError:
            pass

    # ---------------------------------------------------------- block budget
    def ensure_capacity(self, req: Request, n_tokens: int
                        ) -> Tuple[List[int], List[Request]]:
        """Grow ``req``'s allocation to cover ``n_tokens`` KV slots,
        preempting the youngest other running requests while the pool is
        dry.  Returns (newly allocated block ids, preempted requests).
        The caller must reset ``kv_pos`` of the new blocks and mirror the
        table rows to the device caches."""
        assert self.allocator is not None
        need = self.allocator.blocks_for(n_tokens) - len(req.block_ids)
        if need <= 0:
            return [], []
        preempted: List[Request] = []
        while True:
            blocks = self.allocator.alloc(need)
            if blocks is not None:
                req.block_ids.extend(blocks)
                return blocks, preempted
            victim = self._pick_victim(exclude=req)
            if victim is None:
                # Pool dry with nothing else to preempt.  Under prefix
                # caching this is reachable (optimistic admission lets a
                # request in on its uncovered suffix): self-preempt the
                # requester.  Its committed full blocks stay registered
                # and warm, so readmission resumes through the cache —
                # the readmit prefill recomputes at most one partial
                # block and emits a token, so progress is monotone.
                assert self.prefix_cache, (
                    "pool exhausted with nothing to preempt — the single-"
                    "sequence pool guarantee should make this unreachable")
                self.preempt(req)
                preempted.append(req)
                return [], preempted
            self.preempt(victim)
            preempted.append(victim)

    def _pick_victim(self, exclude: Request) -> Optional[Request]:
        running = [r for r in self.slots if r is not None and r is not exclude]
        if not running:
            return None
        return max(running, key=lambda r: r.admit_seq)   # LIFO: youngest

    def preempt(self, req: Request) -> None:
        """Evict-and-requeue: decref every block, requeue at the *front*
        so the request readmits first and recomputes its prefix
        (prompt + emitted output) on readmission.  Under prefix caching
        the decref leaves registered blocks warm in the hash index, so
        the recompute usually collapses to a tail prefill over at most
        one partial block.

        The ``appendleft`` is also the starvation guard: every readmit
        sits ahead of every fresh arrival (``submit`` appends), FIFO in
        admission order within a preemption wave — see
        :meth:`assert_readmit_fifo`."""
        assert self.allocator is not None and req.slot is not None
        self.allocator.free(req.block_ids)
        req.block_ids = []
        self.slots[req.slot] = None
        req.slot = None
        req.cache_len = 0
        req.prefill_start = 0
        req.fresh_block_ids = []
        req.cow_pairs = []
        req.hashed_blocks = 0
        req.chain_hash = None
        req.state = RequestState.QUEUED
        req.preemptions += 1
        self.preempted_total += 1
        self.queue.appendleft(req)

    def shrink_to(self, req: Request, n_tokens: int) -> List[int]:
        """Return the speculative-tail blocks beyond ``n_tokens`` committed
        slots to the pool (post-round rollback is free)."""
        assert self.allocator is not None
        keep = self.allocator.blocks_for(n_tokens)
        freed = req.block_ids[keep:]
        if freed:
            del req.block_ids[keep:]
            self.allocator.free(freed)
        return freed

    def release(self, req: Request) -> None:
        if req.slot is not None:
            self.slots[req.slot] = None
            req.slot = None
        if self.allocator is not None and req.block_ids:
            self.allocator.free(req.block_ids)
            req.block_ids = []

    # ------------------------------------------------------------- telemetry
    @property
    def active_mask(self) -> np.ndarray:
        return np.array([r is not None for r in self.slots], bool)

    @property
    def running(self) -> List[Request]:
        return [r for r in self.slots if r is not None]

    def kv_blocks_in_use(self) -> int:
        """Blocks charged against the pool (paged), or the dense-row
        equivalent (active slots x blocks-per-row) so the same telemetry
        field plots memory-vs-throughput across both layouts."""
        if self.allocator is not None:
            return self.allocator.n_used
        return int(self.active_mask.sum()) * self.serving.blocks_per_seq()

    def kv_blocks_total(self) -> int:
        if self.allocator is not None:
            return self.allocator.num_blocks
        return self.serving.max_batch_size * self.serving.blocks_per_seq()

    def kv_block_bytes(self) -> int:
        """Bytes one pool block costs (0 when never sized — dense
        engines or direct-driver schedulers)."""
        return self.block_bytes

    def kv_bytes_total(self) -> int:
        """Pool footprint in bytes under the serving storage mode."""
        return self.kv_blocks_total() * self.block_bytes

    def kv_bytes_in_use(self) -> int:
        return self.kv_blocks_in_use() * self.block_bytes

    def kv_blocks_cached(self) -> int:
        """Warm unreferenced blocks parked on the evictable LRU."""
        if self.allocator is not None:
            return self.allocator.n_cached
        return 0

    def has_work(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slots)
