"""Look-ahead slot scheduler (paper §3.2).

Computes per-sequence look-ahead KV slots directly from ``SL_i^(t)`` and is
applied uniformly to prefill and decode admission — the vLLM modification
the paper describes ("removes inconsistencies between feasibility checks
and append operations and aligns capacity planning with intra-batch
heterogeneity").

The scheduler owns: the waiting queue, the slot table, and the admission
decision (does the remaining KV budget of a slot cover prompt + lookahead
+ max_new_tokens?).
"""
from __future__ import annotations

import collections
from typing import Dict, List, Optional

import numpy as np

from repro.core.config import ServingConfig, SpecDecodeConfig
from repro.serving.request import Request, RequestState


class LookaheadScheduler:
    def __init__(self, serving: ServingConfig, spec: SpecDecodeConfig):
        self.serving = serving
        self.spec = spec
        self.queue: collections.deque[Request] = collections.deque()
        self.slots: List[Optional[Request]] = [None] * serving.max_batch_size

    # ------------------------------------------------------------- admission
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def lookahead_slots(self, sl_next: np.ndarray) -> np.ndarray:
        """KV slots each sequence needs next round: SL_i + 1 (bonus)."""
        return sl_next + 1

    def _fits(self, req: Request) -> bool:
        need = len(req.prompt) + req.max_new_tokens + self.spec.sl_max + 1
        return need <= self.serving.max_seq_len

    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    def admit(self) -> List[Request]:
        """Move queued requests into free slots (continuous batching)."""
        admitted = []
        for i in self.free_slots():
            if not self.queue:
                break
            req = self.queue.popleft()
            if not self._fits(req):
                req.state = RequestState.FINISHED   # reject oversize
                continue
            req.slot = i
            req.state = RequestState.RUNNING
            self.slots[i] = req
            admitted.append(req)
        return admitted

    def release(self, req: Request) -> None:
        if req.slot is not None:
            self.slots[req.slot] = None
            req.slot = None

    # ------------------------------------------------------------- telemetry
    @property
    def active_mask(self) -> np.ndarray:
        return np.array([r is not None for r in self.slots], bool)

    @property
    def running(self) -> List[Request]:
        return [r for r in self.slots if r is not None]

    def has_work(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slots)
