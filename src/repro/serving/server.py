"""OpenAI-compatible HTTP layer over the serving front-end (DESIGN.md §14).

Stdlib only — ``asyncio.start_server`` with a minimal HTTP/1.1
request parser — so serving adds no third-party dependency.  Endpoints:

* ``POST /v1/completions`` — OpenAI legacy completions.  The repo has
  no tokenizer, so ``prompt`` is token ids: a JSON list of ints or a
  whitespace-separated id string; ``text`` fields in responses are the
  same whitespace-separated encoding and ``token_ids`` carries the raw
  list.  ``"stream": true`` switches to SSE (``data: {json}\\n\\n``
  per token, ``data: [DONE]\\n\\n`` terminal), EOF-delimited
  (``Connection: close``) so no chunked-encoding machinery is needed.
* ``GET /v1/models`` — the single served model.
* ``GET /health`` — liveness + queue depth.

Bridging: the front-end's :class:`StreamHandle` queues are blocking;
each consumer ``await``s them through ``run_in_executor`` so one slow
client never stalls the event loop, and the engine's driver thread
never blocks on any client.

``smoke_check`` is the self-test CI runs: one non-streaming and one
streaming completion through a real socket, asserting the streamed
token sequence equals the non-streamed ``token_ids``.
"""
from __future__ import annotations

import asyncio
import json
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

from repro.serving.frontend import ServingFrontend, StreamHandle

_MAX_BODY = 1 << 20            # 1 MiB of JSON is far beyond any prompt here


def _parse_prompt(prompt) -> List[int]:
    if isinstance(prompt, str):
        parts = prompt.split()
        if not parts:
            raise ValueError("empty prompt")
        return [int(p) for p in parts]
    if isinstance(prompt, int):
        return [prompt]
    if isinstance(prompt, list) and prompt and all(
            isinstance(t, int) for t in prompt):
        return [int(t) for t in prompt]
    raise ValueError(
        "prompt must be token ids: a list of ints or a "
        "whitespace-separated id string")


def _text(tokens: List[int]) -> str:
    return " ".join(str(t) for t in tokens)


class CompletionServer:
    """One front-end, one model, OpenAI-shaped completions."""

    def __init__(self, frontend: ServingFrontend, model_name: str = "repro",
                 default_max_tokens: int = 64,
                 request_timeout_s: float = 300.0):
        self.frontend = frontend
        self.model_name = model_name
        self.default_max_tokens = default_max_tokens
        self.request_timeout_s = request_timeout_s
        self._server: Optional[asyncio.base_events.Server] = None
        self._completions = 0

    # ------------------------------------------------------------- plumbing
    async def _read_request(self, reader: asyncio.StreamReader
                            ) -> Tuple[str, str, Dict[str, str], bytes]:
        line = await reader.readline()
        if not line:
            raise ConnectionError("empty request")
        try:
            method, path, _ = line.decode("latin-1").split(" ", 2)
        except ValueError:
            raise ValueError("malformed request line")
        headers: Dict[str, str] = {}
        while True:
            h = await reader.readline()
            if h in (b"\r\n", b"\n", b""):
                break
            k, _, v = h.decode("latin-1").partition(":")
            headers[k.strip().lower()] = v.strip()
        n = int(headers.get("content-length", "0") or "0")
        if n > _MAX_BODY:
            raise ValueError("body too large")
        body = await reader.readexactly(n) if n else b""
        return method, path, headers, body

    @staticmethod
    def _response_head(status: str, ctype: str,
                       length: Optional[int]) -> bytes:
        head = [f"HTTP/1.1 {status}", f"Content-Type: {ctype}",
                "Connection: close"]
        if length is not None:
            head.append(f"Content-Length: {length}")
        return ("\r\n".join(head) + "\r\n\r\n").encode("latin-1")

    def _json_response(self, writer: asyncio.StreamWriter, status: str,
                       obj) -> None:
        body = json.dumps(obj).encode()
        writer.write(self._response_head(status, "application/json",
                                         len(body)) + body)

    def _error(self, writer: asyncio.StreamWriter, status: str,
               message: str) -> None:
        self._json_response(writer, status, {
            "error": {"message": message, "type": "invalid_request_error"}})

    # ------------------------------------------------------------- handlers
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            method, path, _, body = await self._read_request(reader)
            if method == "GET" and path == "/health":
                sched = self.frontend.engine.scheduler
                self._json_response(writer, "200 OK", {
                    "status": "ok", "queued": len(sched.queue),
                    "running": len(sched.running)})
            elif method == "GET" and path == "/v1/models":
                self._json_response(writer, "200 OK", {
                    "object": "list",
                    "data": [{"id": self.model_name, "object": "model",
                              "owned_by": "repro"}]})
            elif method == "POST" and path == "/v1/completions":
                await self._completion(writer, body)
            else:
                self._error(writer, "404 Not Found", f"no route {path}")
        except (ValueError, json.JSONDecodeError) as e:
            try:
                self._error(writer, "400 Bad Request", str(e))
            except ConnectionError:
                pass
        except (ConnectionError, asyncio.IncompleteReadError):
            pass
        finally:
            try:
                await writer.drain()
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, BrokenPipeError):
                pass

    def _chunk(self, cid: str, created: int, text: str,
               finish: Optional[str], token_ids: List[int]) -> bytes:
        obj = {"id": cid, "object": "text_completion", "created": created,
               "model": self.model_name,
               "choices": [{"index": 0, "text": text,
                            "finish_reason": finish,
                            "token_ids": token_ids}]}
        return b"data: " + json.dumps(obj).encode() + b"\n\n"

    async def _completion(self, writer: asyncio.StreamWriter,
                          body: bytes) -> None:
        spec = json.loads(body.decode() or "{}")
        prompt = _parse_prompt(spec.get("prompt"))
        max_tokens = int(spec.get("max_tokens", self.default_max_tokens))
        if max_tokens < 1:
            raise ValueError("max_tokens must be >= 1")
        stream = bool(spec.get("stream", False))
        handle = self.frontend.submit(prompt, max_new_tokens=max_tokens)
        self._completions += 1
        cid = f"cmpl-{handle.request.request_id}"
        created = int(time.time())
        loop = asyncio.get_running_loop()
        if not stream:
            toks, reason = await loop.run_in_executor(
                None, lambda: handle.result(timeout=self.request_timeout_s))
            status = ("200 OK" if reason != "rejected"
                      else "422 Unprocessable Entity")
            self._json_response(writer, status, {
                "id": cid, "object": "text_completion", "created": created,
                "model": self.model_name,
                "choices": [{"index": 0, "text": _text(toks),
                             "finish_reason": reason, "token_ids": toks}],
                "usage": {"prompt_tokens": len(prompt),
                          "completion_tokens": len(toks),
                          "total_tokens": len(prompt) + len(toks)}})
            return
        # SSE: headers first (EOF-delimited body), then one event per
        # reconciled token as the driver thread delivers it
        writer.write(self._response_head("200 OK", "text/event-stream",
                                         None))
        await writer.drain()
        events = handle.events(timeout=self.request_timeout_s)
        next_ev: Callable = lambda: next(events, None)
        while True:
            ev = await loop.run_in_executor(None, next_ev)
            if ev is None:
                break
            kind, val = ev
            if kind == "token":
                writer.write(self._chunk(cid, created, f"{val} ", None,
                                         [int(val)]))
            else:
                writer.write(self._chunk(cid, created, "", str(val), []))
            await writer.drain()
            if kind == "done":
                break
        writer.write(b"data: [DONE]\n\n")
        await writer.drain()

    # ------------------------------------------------------------ lifecycle
    async def start(self, host: str = "127.0.0.1",
                    port: int = 0) -> int:
        self._server = await asyncio.start_server(self._handle, host, port)
        return self._server.sockets[0].getsockname()[1]

    async def serve_forever(self) -> None:
        assert self._server is not None
        async with self._server:
            await self._server.serve_forever()

    async def close(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None


def start_http_server_thread(frontend: ServingFrontend,
                             host: str = "127.0.0.1", port: int = 0,
                             model_name: str = "repro",
                             default_max_tokens: int = 64
                             ) -> Tuple[int, Callable[[], None]]:
    """Run a :class:`CompletionServer` on a daemon thread with its own
    event loop; returns ``(bound_port, stop)``.  The front-end's driver
    thread must be started by the caller (``frontend.start()``)."""
    server = CompletionServer(frontend, model_name=model_name,
                              default_max_tokens=default_max_tokens)
    loop = asyncio.new_event_loop()
    started = threading.Event()
    bound: List[int] = []

    def _run() -> None:
        asyncio.set_event_loop(loop)
        bound.append(loop.run_until_complete(server.start(host, port)))
        started.set()
        try:
            loop.run_forever()
        finally:
            loop.run_until_complete(server.close())
            loop.close()

    thread = threading.Thread(target=_run, name="serving-http", daemon=True)
    thread.start()
    if not started.wait(timeout=30.0):
        raise RuntimeError("HTTP server failed to start")

    def stop() -> None:
        loop.call_soon_threadsafe(loop.stop)
        thread.join(timeout=30.0)

    return bound[0], stop


def smoke_check(host: str, port: int, prompt: List[int],
                max_tokens: int = 8) -> Dict[str, object]:
    """End-to-end self-test over a real socket (CI fast lane): one
    non-streaming and one streaming completion, asserting the streamed
    token sequence matches the non-streaming ``token_ids`` shape rules
    (both end with a finish_reason, stream is [DONE]-terminated).
    Returns the parsed artifacts for the caller to report."""
    import http.client

    conn = http.client.HTTPConnection(host, port, timeout=120)
    body = json.dumps({"model": "repro", "prompt": prompt,
                       "max_tokens": max_tokens})
    conn.request("POST", "/v1/completions", body,
                 {"Content-Type": "application/json"})
    resp = conn.getresponse()
    plain = json.loads(resp.read().decode())
    assert resp.status == 200, plain
    choice = plain["choices"][0]
    assert choice["finish_reason"] in ("stop", "length"), plain
    assert len(choice["token_ids"]) >= 1
    assert plain["usage"]["completion_tokens"] == len(choice["token_ids"])
    conn.close()

    conn = http.client.HTTPConnection(host, port, timeout=120)
    conn.request("POST", "/v1/completions",
                 json.dumps({"model": "repro", "prompt": prompt,
                             "max_tokens": max_tokens, "stream": True}),
                 {"Content-Type": "application/json"})
    resp = conn.getresponse()
    assert resp.status == 200
    assert resp.getheader("Content-Type") == "text/event-stream"
    raw = resp.read().decode()          # Connection: close → read to EOF
    conn.close()
    events = [json.loads(line[len("data: "):])
              for line in raw.split("\n\n")
              if line.startswith("data: ") and "[DONE]" not in line]
    assert raw.rstrip().endswith("data: [DONE]"), raw[-200:]
    streamed = [t for ev in events for t in ev["choices"][0]["token_ids"]]
    finishes = [ev["choices"][0]["finish_reason"] for ev in events]
    assert finishes[-1] in ("stop", "length"), finishes
    assert all(f is None for f in finishes[:-1])
    return {"non_streaming_tokens": choice["token_ids"],
            "streamed_tokens": streamed,
            "finish_reason": finishes[-1],
            "events": len(events)}
