"""Pytree checkpointing on local disk (np.savez; no orbax in-container).

Layout: one ``.npz`` per step holding flattened leaves + a key manifest,
plus a ``latest`` pointer file.  Restores into the exact tree structure.
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional, Tuple

import jax
import numpy as np

PyTree = Any
_SEP = "/"


def _flatten_with_paths(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = []
    for path, _ in flat:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        keys.append(_SEP.join(parts))
    return keys, [leaf for _, leaf in flat], treedef


def save_checkpoint(path: str, step: int, params: PyTree,
                    extra: Optional[PyTree] = None) -> str:
    os.makedirs(path, exist_ok=True)
    tree = {"params": params}
    if extra is not None:
        tree["extra"] = extra
    keys, leaves, _ = _flatten_with_paths(tree)
    arrays = {f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)}
    fname = os.path.join(path, f"ckpt_{step:08d}.npz")
    np.savez(fname, manifest=json.dumps(keys), **arrays)
    with open(os.path.join(path, "latest"), "w") as f:
        f.write(os.path.basename(fname))
    return fname


def latest_checkpoint(path: str) -> Optional[str]:
    ptr = os.path.join(path, "latest")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        return os.path.join(path, f.read().strip())


def restore_checkpoint(fname: str, template: PyTree) -> Tuple[PyTree, PyTree]:
    """Restore (params, extra) into the structure of ``template``
    ({"params":..., "extra":...} or params-only)."""
    data = np.load(fname, allow_pickle=False)
    keys = json.loads(str(data["manifest"]))
    tree = {"params": template} if not (isinstance(template, dict)
                                        and "params" in template) else template
    tkeys, tleaves, treedef = _flatten_with_paths(tree)
    lookup = {k: data[f"leaf_{i}"] for i, k in enumerate(keys)}
    new_leaves = []
    for k, leaf in zip(tkeys, tleaves):
        if k not in lookup:
            raise KeyError(f"checkpoint missing leaf {k}")
        arr = lookup[k]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch for {k}: "
                             f"{arr.shape} vs {np.shape(leaf)}")
        new_leaves.append(arr)
    restored = jax.tree_util.tree_unflatten(treedef, new_leaves)
    return restored.get("params", restored), restored.get("extra")
