"""Token data pipeline.

Two sources:

* ``MarkovTaskCorpus`` — synthetic corpora with *controllable regularity*.
  A random Markov chain whose transition rows are sharpened by a
  ``peakedness`` parameter.  High peakedness => highly predictable streams
  (the paper's "code-like" HumanEval regime, where aggressive speculation
  wins); low peakedness => high-entropy streams (the "dialogue-like"
  ShareGPT regime).  This is how the heterogeneous-workload experiments
  (paper Table 1 / Fig. 7) are reproduced without shipping datasets.
* ``lm_batches`` — shuffled fixed-length LM batches with next-token labels
  from any token stream (used by the training examples / train_step).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class MarkovTaskCorpus:
    """Order-1 Markov chain over ``vocab`` symbols with tunable entropy."""
    vocab_size: int
    peakedness: float          # >1 sharpens rows; ~0 flattens to uniform
    seed: int = 0
    branching: int = 8         # support size of each transition row

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        v, k = self.vocab_size, min(self.branching, self.vocab_size)
        self.support = np.stack(
            [rng.choice(v, size=k, replace=False) for _ in range(v)])
        logits = rng.randn(v, k) * self.peakedness
        e = np.exp(logits - logits.max(-1, keepdims=True))
        self.probs = e / e.sum(-1, keepdims=True)

    def entropy(self) -> float:
        p = self.probs
        return float(-(p * np.log(np.maximum(p, 1e-12))).sum(-1).mean())

    def sample(self, length: int, rng: np.random.RandomState,
               start: Optional[int] = None) -> np.ndarray:
        v = self.vocab_size
        tok = rng.randint(v) if start is None else start
        out = np.empty(length, np.int32)
        for i in range(length):
            row = self.probs[tok]
            nxt = self.support[tok][rng.choice(len(row), p=row)]
            out[i] = nxt
            tok = nxt
        return out

    def stream(self, total: int, seed: int = 0) -> np.ndarray:
        return self.sample(total, np.random.RandomState(seed))

    def prompts(self, n: int, length: int, seed: int = 0) -> List[List[int]]:
        rng = np.random.RandomState(seed)
        return [self.sample(length, rng).tolist() for _ in range(n)]


def task_mixture(vocab_size: int, seed: int = 0
                 ) -> Dict[str, MarkovTaskCorpus]:
    """The two-regime workload of paper Table 1."""
    return {
        "code": MarkovTaskCorpus(vocab_size, peakedness=3.0, seed=seed),
        "dialogue": MarkovTaskCorpus(vocab_size, peakedness=0.35,
                                     seed=seed + 1),
    }


def lm_batches(stream: np.ndarray, batch_size: int, seq_len: int,
               seed: int = 0, epochs: int = 1000
               ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Yield (tokens [B,S], labels [B,S]) — labels are next tokens."""
    n = (len(stream) - 1) // seq_len
    rng = np.random.RandomState(seed)
    for _ in range(epochs):
        order = rng.permutation(n)
        for i in range(0, n - batch_size + 1, batch_size):
            idx = order[i:i + batch_size]
            toks = np.stack([stream[j * seq_len:(j + 1) * seq_len]
                             for j in idx])
            labs = np.stack([stream[j * seq_len + 1:(j + 1) * seq_len + 1]
                             for j in idx])
            yield toks.astype(np.int32), labs.astype(np.int32)


def synthetic_batch(key_seed: int, batch: int, seq: int, vocab: int
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Uniform-random batch (shape-only uses: smoke tests, dry runs)."""
    rng = np.random.RandomState(key_seed)
    toks = rng.randint(0, vocab, size=(batch, seq), dtype=np.int64)
    labs = np.roll(toks, -1, axis=1)
    return toks.astype(np.int32), labs.astype(np.int32)
