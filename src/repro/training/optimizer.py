"""Pure-JAX AdamW with cosine schedule and global-norm clipping.

(optax is not available in this container — see DESIGN.md §8.)
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import OptimizerConfig

PyTree = Any


class AdamWState(NamedTuple):
    step: jax.Array
    mu: PyTree
    nu: PyTree


def init_adamw(params: PyTree) -> AdamWState:
    z = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=z,
                      nu=jax.tree_util.tree_map(jnp.copy, z))


def lr_schedule(step: jax.Array, cfg: OptimizerConfig) -> jax.Array:
    """Linear warmup then cosine decay to 10% of peak."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.1 + 0.45 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.learning_rate * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads: PyTree, max_norm: float
                        ) -> Tuple[PyTree, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def adamw_update(params: PyTree, grads: PyTree, state: AdamWState,
                 cfg: OptimizerConfig) -> Tuple[PyTree, AdamWState, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_schedule(step, cfg)
    b1, b2 = cfg.b1, cfg.b2

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / (1 - b1 ** step.astype(jnp.float32))
        vhat = v / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {
        "grad_norm": gnorm, "lr": lr}
