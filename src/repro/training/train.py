"""Training loop: loss, train_step (jit/pjit-able), and a CPU driver.

``train_step`` is the function the multi-pod dry-run lowers for the
``train_4k`` input shape; it is mesh-agnostic (shardings come from
``repro/launch``).
"""
from __future__ import annotations

import functools
import time
from typing import Any, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig, OptimizerConfig, TrainConfig
from repro.core.sampling import mask_vocab
from repro.models.transformer import forward, model_specs
from repro.models.module import init_params
from repro.training.optimizer import AdamWState, adamw_update, init_adamw

PyTree = Any


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  vocab_size: int,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Vocab-parallel cross entropy.

    The label logit is picked with an iota==label masked reduction instead
    of ``take_along_axis``: gathering along a vocab-sharded axis would
    all-gather the full [B,S,V] logits (tens of GiB at 150k vocab) while
    the masked reduce keeps everything local + one scalar all-reduce
    (Megatron-style vocab-parallel CE, done via GSPMD)."""
    logits = mask_vocab(logits, vocab_size).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                    logits.ndim - 1)
    tok_logit = jnp.sum(jnp.where(iota == labels[..., None], logits, 0.0),
                        axis=-1)
    ll = tok_logit - lse
    if mask is not None:
        return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return -ll.mean()


def loss_fn(params: PyTree, cfg: ModelConfig, tokens: jax.Array,
            labels: jax.Array, remat: bool = False,
            embeds: Optional[jax.Array] = None,
            encoder_embeds: Optional[jax.Array] = None,
            act_sharding=None, logits_sharding=None, attn_sharding=None,
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, _, aux = forward(params, cfg, tokens, mode="train",
                             embeds=embeds, encoder_embeds=encoder_embeds,
                             act_sharding=act_sharding,
                             attn_sharding=attn_sharding, remat=remat)
    if logits_sharding is not None:
        # pin [B, S, V] to (batch, None, model): without this GSPMD has been
        # observed to replicate the logits cotangent over the vocab axis in
        # backward (2 x ~40 GiB buffers at 150k vocab)
        logits = jax.lax.with_sharding_constraint(logits, logits_sharding)
    loss = cross_entropy(logits, labels, cfg.vocab_size)
    metrics = {"ce_loss": loss}
    if cfg.family == "moe":
        lb = aux["load_balance_loss"] * cfg.moe.load_balance_weight
        zl = aux["router_z_loss"] * 1e-3
        loss = loss + lb + zl
        metrics.update(load_balance=lb, router_z=zl,
                       dropped=aux["dropped_fraction"])
    metrics["loss"] = loss
    return loss, metrics


def train_step(params: PyTree, opt_state: AdamWState, tokens: jax.Array,
               labels: jax.Array, *, cfg: ModelConfig,
               opt_cfg: OptimizerConfig, remat: bool = True,
               encoder_embeds: Optional[jax.Array] = None,
               act_sharding=None, attn_sharding=None, microbatches: int = 1,
               microbatch_sharding=None,
               ) -> Tuple[PyTree, AdamWState, Dict[str, jax.Array]]:
    """One optimizer step.  Lowered by the dry-run for train_4k.

    ``microbatches > 1`` enables gradient accumulation over a ``lax.scan``:
    activation-scale buffers (remat stash, vocab logits) shrink by the
    microbatch factor, which is what fits the 32B-class configs into v5e
    HBM at global batch 256 (EXPERIMENTS.md §Dry-run)."""
    if microbatches <= 1:
        (_, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, cfg, tokens, labels, remat,
                                   None, encoder_embeds, act_sharding,
                                   None, attn_sharding)
    else:
        m = microbatches
        b = tokens.shape[0]
        assert b % m == 0, (b, m)

        def resh(x):
            if x is None:
                return None
            x = x.reshape((m, b // m) + x.shape[1:])
            if microbatch_sharding is not None:
                x = jax.lax.with_sharding_constraint(
                    x, microbatch_sharding(x.ndim))
            return x

        toks_m, labs_m = resh(tokens), resh(labels)
        enc_m = resh(encoder_embeds)

        def micro(g_acc, xs):
            if enc_m is None:
                t_i, l_i = xs
                e_i = None
            else:
                t_i, l_i, e_i = xs
            (_, metrics), g = jax.value_and_grad(
                loss_fn, has_aux=True)(params, cfg, t_i, l_i, remat,
                                       None, e_i, act_sharding,
                                       None, attn_sharding)
            g_acc = jax.tree_util.tree_map(
                lambda a, gi: a + gi.astype(jnp.float32), g_acc, g)
            return g_acc, metrics

        g0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        xs = (toks_m, labs_m) if enc_m is None else (toks_m, labs_m, enc_m)
        grads, metrics_m = jax.lax.scan(micro, g0, xs)
        grads = jax.tree_util.tree_map(lambda g: g / m, grads)
        metrics = jax.tree_util.tree_map(lambda v: v.mean(0), metrics_m)
    params, opt_state, opt_m = adamw_update(params, grads, opt_state, opt_cfg)
    metrics.update(opt_m)
    return params, opt_state, metrics


def make_jit_train_step(cfg: ModelConfig, opt_cfg: OptimizerConfig,
                        remat: bool = True):
    return jax.jit(functools.partial(train_step, cfg=cfg, opt_cfg=opt_cfg,
                                     remat=remat))


def train_loop(cfg: ModelConfig, train_cfg: TrainConfig,
               batches: Iterator, *, seed: int = 0,
               dtype=jnp.float32, log_every: int = 20,
               num_steps: Optional[int] = None,
               params: Optional[PyTree] = None,
               verbose: bool = True) -> Tuple[PyTree, Dict[str, float]]:
    """CPU driver: train a (small) model for a few hundred steps.  Used by
    the examples and by the benchmark harness to build genuinely-correlated
    draft/target pairs (DESIGN.md §3)."""
    key = jax.random.PRNGKey(seed)
    if params is None:
        params = init_params(model_specs(cfg), key, dtype)
    opt_state = init_adamw(params)
    step_fn = make_jit_train_step(cfg, train_cfg.optimizer,
                                  remat=train_cfg.remat)
    n = num_steps or train_cfg.optimizer.total_steps
    t0 = time.monotonic()
    last = {}
    for i, (toks, labs) in enumerate(batches):
        if i >= n:
            break
        params, opt_state, m = step_fn(params, opt_state,
                                       jnp.asarray(toks), jnp.asarray(labs))
        if i % log_every == 0 or i == n - 1:
            last = {k: float(v) for k, v in m.items()}
            if verbose:
                print(f"  step {i:4d} loss={last['loss']:.4f} "
                      f"lr={last['lr']:.2e} gnorm={last['grad_norm']:.2f}")
    wall = time.monotonic() - t0
    return params, {"steps": min(i + 1, n), "wall_s": wall, **last}
