"""Deterministic offline fallback for the ``hypothesis`` API surface the
tier-1 suite uses (``given`` / ``settings`` / a handful of strategies).

The container has no network access, so ``hypothesis`` may be absent; the
property tests then still run as seeded random sweeps: each ``@given``
test executes ``max_examples`` drawn examples from a fixed-seed RNG,
always starting with the strategies' boundary values.  This is weaker
than real shrinking-capable property testing but keeps every property
exercised offline.  When ``hypothesis`` is importable the test modules
use it directly and this module is never imported.
"""
from __future__ import annotations

import zlib
from typing import Any, Callable, List

import numpy as np


class _Strategy:
    """A strategy is (boundary examples, random draw function)."""

    def __init__(self, boundary: List[Any],
                 draw: Callable[[np.random.RandomState], Any]):
        self.boundary = boundary
        self.draw = draw


class strategies:
    """Mirror of ``hypothesis.strategies`` for the subset the suite uses."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy([min_value, max_value],
                         lambda r: int(r.randint(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(
            [min_value, max_value],
            lambda r: float(r.uniform(min_value, max_value)))

    @staticmethod
    def sampled_from(elements) -> _Strategy:
        elements = list(elements)
        return _Strategy([elements[0], elements[-1]],
                         lambda r: elements[int(r.randint(len(elements)))])

    @staticmethod
    def lists(elem: _Strategy, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        def draw(r: np.random.RandomState):
            n = int(r.randint(min_size, max_size + 1))
            return [elem.draw(r) for _ in range(n)]
        boundary = [[elem.boundary[0]] * max(min_size, 1),
                    [elem.boundary[-1]] * max(min_size, 1)]
        return _Strategy(boundary, draw)


st = strategies


def settings(max_examples: int = 10, deadline=None, **_kw):
    """Decorator recording the example budget for ``given`` to pick up."""
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def given(*strats: _Strategy):
    """Run the test once per drawn example (boundaries first, then seeded
    random draws up to the ``settings`` budget)."""
    def deco(fn):
        def wrapper(*args, **kwargs):
            # read the budget lazily so BOTH decorator orders work (real
            # hypothesis accepts @settings above or below @given)
            max_examples = getattr(wrapper, "_shim_max_examples",
                                   getattr(fn, "_shim_max_examples", 10))
            # crc32, NOT hash(): str hashes are randomized per process
            rng = np.random.RandomState(
                zlib.crc32(fn.__qualname__.encode()) % (2 ** 31))
            n_boundary = min(len(s.boundary) for s in strats)
            for i in range(max(max_examples, n_boundary)):
                if i < n_boundary:
                    example = [s.boundary[i] for s in strats]
                else:
                    example = [s.draw(rng) for s in strats]
                fn(*args, *example, **kwargs)

        # no functools.wraps: pytest would read the wrapped signature and
        # treat the drawn parameters as fixtures
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco
