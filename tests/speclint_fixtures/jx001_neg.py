"""JX001 true negatives: structure probes and functional control flow."""
import jax
import jax.numpy as jnp


@jax.jit
def identity_probe(x, mask=None):
    # `is None` inspects trace-time structure, never a traced value
    if mask is None:
        mask = jnp.ones_like(x)
    return x * mask


@jax.jit
def shape_probe(x):
    # .shape / ndim are Python values under trace
    if x.ndim == 2 and jnp.result_type(x) == jnp.float32:
        return x.sum(axis=-1)
    return x


@jax.jit
def functional_branch(x):
    return jnp.where(jnp.any(x > 0), x + 1, x - 1)
