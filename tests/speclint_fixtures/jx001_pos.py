"""JX001 true positives: Python control flow on traced values."""
import jax
import jax.numpy as jnp


@jax.jit
def branch_on_traced_call(x):
    if jnp.any(x > 0):                       # JX001: concretizes a tracer
        return x + 1
    return x - 1


@jax.jit
def while_on_traced_name(x):
    m = jnp.max(x)
    while m > 0:                             # JX001: `m` is traced
        x = x - 1
        m = jnp.max(x)
    return x
