"""JX002 true negatives: rebinding the donated name kills the taint."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, donate_argnames=("pool",))
def scatter_rows(pool, rows):
    return pool.at[: rows.shape[0]].set(rows)


def update_and_rebind(pool, rows):
    pool = scatter_rows(pool, rows)          # donated, then rebound
    return pool[0]                           # reads the NEW buffer


def update_twice(pool, rows):
    pool = scatter_rows(pool, rows)
    pool = scatter_rows(pool, rows * 2)      # rebound each round
    return pool


def donate_in_both_arms(pool, rows, flag):
    if flag:
        pool = scatter_rows(pool, rows)
    else:
        pool = scatter_rows(pool, -rows)
    return pool.sum()                        # both arms rebound it
