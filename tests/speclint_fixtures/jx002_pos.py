"""JX002 true positive: reading a buffer after donating it."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, donate_argnames=("pool",))
def scatter_rows(pool, rows):
    return pool.at[: rows.shape[0]].set(rows)


def update_then_peek(pool, rows):
    new_pool = scatter_rows(pool, rows)
    stale = pool[0]                          # JX002: pool was donated
    return new_pool, stale


@functools.partial(jax.jit, donate_argnums=(0,))
def consume_state(state, grads):
    return jax.tree_util.tree_map(lambda a, b: a - b, state, grads)


def train_step(state, grads):
    out = consume_state(state, grads)
    return out, state["w"]                   # JX002: state was donated
