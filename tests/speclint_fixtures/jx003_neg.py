"""JX003 true negatives: canonical literals and the one sanctioned
constructor."""
from jax.sharding import PartitionSpec as P


def canonical_spec(*parts):
    # the sanctioned constructor may see (and trim) trailing Nones
    out = list(parts)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


TRIMMED = P("data")                          # canonical: no trailing None
INTERIOR = P(None, "model")                  # interior None is meaningful
REPLICATED = P()                             # empty spec is canonical
VIA_HELPER = canonical_spec("data", None)    # routed through the helper
