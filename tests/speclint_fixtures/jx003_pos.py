"""JX003 true positives: trailing-None PartitionSpec literals."""
from jax.sharding import PartitionSpec as P
import jax.sharding


def batch_spec():
    return P("data", None)                   # JX003: trailing None


FULL = jax.sharding.PartitionSpec("data", "model", None)   # JX003
