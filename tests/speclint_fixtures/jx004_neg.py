"""JX004 true negatives: every sanctioned jit-construction discipline."""
import functools

import jax
import jax.numpy as jnp

_PROGRAMS = {}

step = jax.jit(jnp.dot)                      # module level: the default


def make_step(static_k):
    # make_*/build_* factory: built once by the caller, by convention
    return jax.jit(functools.partial(jnp.tensordot, axes=static_k))


@functools.lru_cache(maxsize=None)
def _cached_program(k):
    # memoized builder: at most one construction per key
    return jax.jit(lambda x: x * k)


class Engine:
    def _round_fn(self, key):
        # the _MESH_ROUND_JITS discipline: store into a module-level table
        if key not in _PROGRAMS:
            fn = jax.jit(jnp.add)
            _PROGRAMS[key] = fn
        return _PROGRAMS[key]

    def lowered_text(self, x):
        # AOT probe, not a per-call program
        return jax.jit(jnp.sin).lower(x).as_text()
