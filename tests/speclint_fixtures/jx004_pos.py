"""JX004 true positives: jax.jit constructed per call."""
import jax
import jax.numpy as jnp


def decode_round(params, toks):
    body = lambda p, t: jnp.dot(p, t)
    fn = jax.jit(body)                       # JX004: fresh wrapper per call
    return fn(params, toks)


class Engine:
    def step(self, params, toks):
        # JX004: recompiles every step (closure differs per call)
        return jax.jit(lambda t: jnp.dot(params, t))(toks)
