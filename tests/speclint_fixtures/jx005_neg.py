"""JX005 true negatives: the split/fold_in discipline."""
import jax
import jax.numpy as jnp


def split_per_use(key):
    k1, k2 = jax.random.split(key)
    return jax.random.normal(k1, (4,)) + jax.random.uniform(k2, (4,))


def fold_in_loop(key, n):
    out = []
    for i in range(n):
        ki = jax.random.fold_in(key, i)      # fresh key per iteration
        out.append(jax.random.normal(ki, (2,)))
    return out


def rebind_between_draws(key):
    a = jax.random.normal(key, (4,))
    key = jax.random.PRNGKey(1)              # fresh key: reuse is fine
    b = jax.random.normal(key, (4,))
    return a + b


def one_draw_per_arm(key, flag):
    # each arm consumes once; arms never both execute
    if flag:
        out = jax.random.normal(key, (4,))
    else:
        out = jax.random.uniform(key, (4,))
    return out
