"""JX005 true positives: PRNG key reuse."""
import jax
import jax.numpy as jnp


def double_draw(key):
    a = jax.random.normal(key, (4,))
    b = jax.random.uniform(key, (4,))        # JX005: key already consumed
    return a + b


def use_after_split(key):
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, (4,))
    b = jax.random.normal(key, (4,))         # JX005: split key is dead
    return a + b + jax.random.normal(k2, (4,))


def loop_invariant_key(key, n):
    out = []
    for _ in range(n):
        out.append(jax.random.normal(key, (2,)))   # JX005: same draw n times
    return out
