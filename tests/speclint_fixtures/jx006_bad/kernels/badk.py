"""JX006 true positive: a Pallas kernel with no ops.py dispatch (and so
no oracle fallback and no parity test)."""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2


def orphan_kernel(x):
    return pl.pallas_call(
        _kernel, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype))(x)
