"""JX006 true negative: kernel with full parity contract."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2


def fused_toy_update(x):
    return pl.pallas_call(
        _kernel, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype))(x)
