"""Backend dispatch for the toy kernel: kernel on TPU, oracle elsewhere."""
import jax

from tests.speclint_fixtures.jx006_good.kernels import ref
from tests.speclint_fixtures.jx006_good.kernels.goodk import fused_toy_update


def toy_update(x, force_kernel=False):
    if force_kernel or jax.default_backend() == "tpu":
        return fused_toy_update(x)
    return ref.fused_toy_update_ref(x)
