"""jnp oracle for the toy kernel."""
import jax.numpy as jnp


def fused_toy_update_ref(x):
    return jnp.asarray(x) * 2
