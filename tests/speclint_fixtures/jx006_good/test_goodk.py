"""Bit-exactness test naming the kernel entry (`fused_toy_update`)."""


def test_toy_kernel_matches_oracle():
    # fixture: naming `fused_toy_update` is what JX006 checks for
    assert "fused_toy_update"
