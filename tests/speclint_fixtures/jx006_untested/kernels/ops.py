import jax

from tests.speclint_fixtures.jx006_untested.kernels import ref
from tests.speclint_fixtures.jx006_untested.kernels.untested import (
    untested_kernel)


def plus_one(x, force_kernel=False):
    if force_kernel or jax.default_backend() == "tpu":
        return untested_kernel(x)
    return ref.untested_kernel_ref(x)
