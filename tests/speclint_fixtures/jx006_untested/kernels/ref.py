import jax.numpy as jnp


def untested_kernel_ref(x):
    return jnp.asarray(x) + 1
