"""JX006 true positive (missing-test arm): ops + oracle exist, but no
scanned test names the entry."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] + 1


def untested_kernel(x):
    return pl.pallas_call(
        _kernel, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype))(x)
