"""A scanned test file that does NOT name the kernel entry — makes the
missing-test arm of JX006 reachable for trees that do ship ops.py."""


def test_nothing_kernel_related():
    assert 1 + 1 == 2
