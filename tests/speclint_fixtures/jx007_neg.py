"""JX007 true negatives: pinned dtypes and structural ints."""
import jax
import jax.numpy as jnp


def make_normalizer():
    eps = jnp.asarray(1e-6, jnp.float32)     # dtype pinned at binding site
    axis = 1                                 # structural int (axis), not math

    def norm(x):
        m = jnp.mean(x, axis=axis, keepdims=True)
        v = jnp.var(x, axis=axis, keepdims=True)
        return (x - m) / jnp.sqrt(v + eps)

    return jax.jit(norm)


def plain_python_closure():
    rate = 0.5

    def describe():
        # not traced, not jit-reachable: plain Python may close over floats
        return "rate=%s" % rate

    return describe
