"""JX007 true positive: bare scalar closed over into a traced function."""
import jax
import jax.numpy as jnp


def make_normalizer():
    eps = 1e-6                               # bare weak-typed float
    scale = 4                                # bare int, used arithmetically

    def norm(x):
        m = jnp.mean(x * scale, axis=-1, keepdims=True)   # JX007 (scale)
        v = jnp.var(x, axis=-1, keepdims=True)
        return (x - m) / jnp.sqrt(v + eps)   # JX007 (eps)

    return jax.jit(norm)
