"""JX008 true negatives: context-form calls to the policy hooks."""
import numpy as np

from repro.core.policies import HostRoundContext


def round_plan(policy, scheduler, sl_next, active):
    ctx = HostRoundContext.from_arrays(np.asarray(sl_next),
                                       np.asarray(active))
    k = policy.pick_bucket(ctx)
    k2 = policy.pick_bucket(
        HostRoundContext.from_arrays(sl_next, active))
    la = policy.lookahead(scheduler.host_context(sl_next))
    bound = policy.max_lookahead()        # unrelated same-prefix hook
    return k, k2, la, bound
