"""JX008 true positives: legacy positional calls to the policy hooks."""
import numpy as np


def round_plan(policy, sl_next, active):
    k = policy.pick_bucket(sl_next, active)          # JX008 (two arrays)
    la = policy.lookahead(np.asarray(sl_next))       # JX008 (non-ctx arg)
    return k, la
