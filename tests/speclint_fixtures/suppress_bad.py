"""Malformed suppressions: missing justification (SP000) and unknown
rule id (SP001)."""
from jax.sharding import PartitionSpec as P

BARE = P("data", None)  # speclint: disable=JX003

OK = P("model")  # speclint: disable=ZZ999 (justified, but no such rule)
