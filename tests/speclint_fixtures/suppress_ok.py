"""Justified suppressions: both placement forms."""
from jax.sharding import PartitionSpec as P

TRAILING = P("data", None)  # speclint: disable=JX003 (fixture: exercising the trailing-comment form)

# speclint: disable=JX003 (fixture: exercising the directive-above form)
ALSO_TRAILING = P("model", None)
