"""Tests for the DSDE SL adapter (paper Eq. 1-3, 8-11)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import adapter as A
from repro.core.config import SpecDecodeConfig

jax.config.update("jax_platform_name", "cpu")


def cfg(**kw):
    return SpecDecodeConfig(**kw)


def test_scale_factor_eq3():
    # SF = exp(2*mu) - 1
    mu = jnp.array([0.0, 0.5, 1.0])
    sf = np.asarray(A.scale_factor(mu, cfg()))
    np.testing.assert_allclose(sf, np.exp(2 * np.asarray(mu)) - 1, rtol=1e-6)


def test_calibration_eq1():
    """SL_max = SL_A,max * (1 + mu_pre / (KLD_pre,max + eps)) after the
    calibration window closes."""
    c = cfg(calibration_steps=2, sl_min=2, sl_max=10)
    st = A.init_adapter_state(1, c)
    # two calibration steps: KLDs {1.0, 3.0} then {2.0}; accepted 3 then 1
    st = A.observe(st, c, kld=jnp.array([[1.0, 3.0]]),
                   proposed_valid=jnp.ones((1, 2), bool),
                   num_accepted=jnp.array([3]))
    assert int(st.calib_steps[0]) == 1
    assert float(st.sl_max[0]) == c.sl_max  # not yet calibrated
    st = A.observe(st, c, kld=jnp.array([[2.0, 0.0]]),
                   proposed_valid=jnp.array([[True, False]]),
                   num_accepted=jnp.array([1]))
    mu_pre = (1.0 + 3.0 + 2.0) / 3
    expect = 3 * (1 + mu_pre / (3.0 + c.eps))
    expect = np.clip(expect, c.sl_min + 1, c.sl_max)
    assert float(st.sl_max[0]) == pytest.approx(expect, rel=1e-5)


def test_predict_eq2_and_floor_eq8():
    c = cfg(calibration_steps=0, sl_min=2, sl_max=10, use_sl_cap=False)
    st = A.init_adapter_state(2, c)
    # craft state: seq0 stable (mu=0 -> SF=0 -> penalty 0 -> SL = SL_max);
    # seq1 extreme (penalty >= 1 -> floor at SL_min)
    st = st._replace(mu_kld_last=jnp.array([0.0, 5.0]),
                     sl_max=jnp.array([8.0, 8.0]),
                     calib_steps=jnp.array([10, 10]))
    sl, st2, tel = A.predict_sl(st, c)
    assert int(sl[0]) == 8         # (1-0)*(8-2)+2
    assert int(sl[1]) == c.sl_min  # conservative floor


def test_predict_interpolates():
    c = cfg(calibration_steps=0, sl_min=2, sl_max=10, use_sl_cap=False)
    st = A.init_adapter_state(1, c)
    # penalty = SF*WVIR with WVIR=1 (fresh history): SF = exp(2*mu)-1
    mu = 0.2
    st = st._replace(mu_kld_last=jnp.array([mu]),
                     sl_max=jnp.array([10.0]),
                     calib_steps=jnp.array([5]))
    sl, _, tel = A.predict_sl(st, c)
    pen = np.exp(2 * mu) - 1
    expect = np.clip(round((1 - pen) * 8 + 2), 2, 10)
    assert int(sl[0]) == expect


def test_sl_cap_is_mean_eq11():
    c = cfg()
    sl = jnp.array([2.0, 4.0, 9.0, 9.0])
    capped, cap = A.apply_sl_cap(sl, c)
    assert float(cap) == pytest.approx(6.0)
    np.testing.assert_allclose(np.asarray(capped), [2, 4, 6, 6])


def test_sl_cap_excludes_inactive():
    c = cfg()
    sl = jnp.array([2.0, 4.0, 100.0])
    active = jnp.array([True, True, False])
    capped, cap = A.apply_sl_cap(sl, c, active)
    assert float(cap) == pytest.approx(3.0)


def test_sl_cap_mse_optimality():
    """Eq. 9-11: the mean minimizes MSE(cap, {SL_i}) over candidate caps."""
    rng = np.random.RandomState(0)
    sls = rng.randint(2, 11, size=16).astype(float)
    mean = sls.mean()
    mse = lambda c: ((c - sls) ** 2).mean()
    for cand in np.linspace(2, 10, 33):
        assert mse(mean) <= mse(cand) + 1e-9


def test_observe_inactive_rows_untouched():
    c = cfg(calibration_steps=2)
    st = A.init_adapter_state(2, c)
    st2 = A.observe(st, c, kld=jnp.array([[1.0], [1.0]]),
                    proposed_valid=jnp.ones((2, 1), bool),
                    num_accepted=jnp.array([1, 1]),
                    active=jnp.array([True, False]))
    assert int(st2.calib_steps[0]) == 1
    assert int(st2.calib_steps[1]) == 0


def test_reset_rows():
    c = cfg(calibration_steps=1)
    st = A.init_adapter_state(2, c)
    st = A.observe(st, c, kld=jnp.array([[2.0], [2.0]]),
                   proposed_valid=jnp.ones((2, 1), bool),
                   num_accepted=jnp.array([2, 2]))
    st = A.reset_rows(st, jnp.array([True, False]), c)
    assert int(st.calib_steps[0]) == 0 and int(st.calib_steps[1]) == 1
    assert float(st.calib_kld_sum[0]) == 0.0


def test_adaedl_threshold_monotone():
    """Lower draft entropy => higher acceptance bound => keep drafting."""
    c = cfg(adaedl_threshold=0.3)
    ent = jnp.array([0.01, 1.0, 8.0])
    keep = np.asarray(A.adaedl_stop_threshold(ent, c))
    assert keep[0] and not keep[2]
