"""The perf-trajectory gate's verdict math and collect/compare contract
(benchmarks/gate.py, DESIGN.md §10).

The gate is CI-failing logic with no other coverage — a broken gate that
never fails looks identical to a healthy green one in a live run — so
the contract is locked here: regression directions, relative tolerances,
the exact mode, the warn-only (2-core noise) escape hatch, the
missing-metric hard failure, and a collect -> compare round-trip over
the real table6/table7 JSON shapes.
"""
import json
import os
import sys
import types

import pytest

# repo root (the `benchmarks` namespace package lives there, not on
# PYTHONPATH=src) — same pattern as examples/serve_dynamic_sl.py
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks.gate import (_entry, _verdict, cmd_collect, cmd_compare,
                             collect_table6, collect_table7, collect_table8,
                             collect_table9, collect_table10,
                             collect_table11)


# ---------------------------------------------------------------------------
# _verdict: direction x tolerance table
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("better,base,pr,tol,want", [
    ("lower", 10.0, 10.0, 0.10, "ok"),        # unchanged
    ("lower", 10.0, 10.9, 0.10, "ok"),        # within tolerance
    ("lower", 10.0, 11.2, 0.10, "fail"),      # regressed past tolerance
    ("lower", 10.0, 5.0, 0.10, "ok"),         # improvement never fails
    ("higher", 2.0, 1.9, 0.10, "ok"),
    ("higher", 2.0, 1.7, 0.10, "fail"),
    ("higher", 2.0, 3.0, 0.10, "ok"),
    ("exact", 92.0, 92.0, 0.0, "ok"),
    ("exact", 92.0, 93.0, 0.0, "fail"),       # both directions fail
    ("exact", 92.0, 91.0, 0.0, "fail"),
])
def test_verdict_directions(better, base, pr, tol, want):
    e = _entry("b", "m", base, tol, better)
    assert _verdict(e, pr) == want


def test_verdict_warn_mode_never_fails():
    e = _entry("b", "m", 10.0, 0.10, "lower", mode="warn")
    assert _verdict(e, 1000.0) == "warn"
    assert _verdict(e, 10.0) == "ok"


# ---------------------------------------------------------------------------
# collect: real smoke-JSON shapes
# ---------------------------------------------------------------------------

T6 = {"sync": {"rounds": 14, "tokens": 92, "host_blocked_mean_s": 0.07},
      "pipelined": {"rounds": 15, "tokens": 92,
                    "host_blocked_mean_s": 0.004},
      "speedup": 1.1, "streams_identical": True}

CELL = {"rounds": 20, "latency_units": 21.0, "block_efficiency": 1.4,
        "mean_acceptance": 0.3, "requests_finished": 8,
        "kv_pool_blocks": 256.0}

T8 = {"share0.5": {"prefill_tokens_on": 256, "prefill_calls_on": 2,
                   "prefix_cache_hit_rate": 0.44,
                   "prefix_cache_hit_blocks": 8.0, "ttft_speedup": 1.2},
      "paged_half_shared": {"requests_finished": 4, "kv_pool_blocks": 32.0,
                            "tok_per_round": 4.5}}

_POINT = {"load_ratio": 0.6, "requests_finished": 8, "tokens_emitted": 78,
          "ttft_s_p50": 0.05, "ttft_s_p99": 0.07, "tpot_s_p50": 0.01,
          "goodput_tok_s": 62.0, "queue_depth_peak": 1.0,
          "queue_depth_mean": 0.13, "slo_attained_frac": 1.0}

T10 = {"capacity_rps": 18.3, "smoke": True,
       "poisson": {"points": [dict(_POINT),
                              dict(_POINT, load_ratio=1.5,
                                   queue_depth_peak=2.0)]},
       "bursty": {"points": [dict(_POINT),
                             dict(_POINT, load_ratio=1.5,
                                  goodput_tok_s=17.8)]}}

_T11_POINT = {"requests_finished": 8, "tokens_emitted": 78,
              "latency_model_ready": 1.0, "goodput_tok_s": 120.0,
              "slo_attained_frac": 1.0, "ttft_s_p99": 0.08}

T11 = {"capacity_rps": 22.1, "smoke": True,
       "latency_model": {"c0": 2e-3, "c_verify": 1e-4, "rounds_fit": 56},
       "points": {"x0.8": {"static": dict(_T11_POINT),
                           "dsde": dict(_T11_POINT),
                           "slo": dict(_T11_POINT)},
                  "x1.2": {"static": dict(_T11_POINT, goodput_tok_s=150.0),
                           "dsde": dict(_T11_POINT, goodput_tok_s=155.0),
                           "slo": dict(_T11_POINT, goodput_tok_s=156.0)}}}

T9 = {"fp_paged_n64": {"requests_finished": 6, "kv_pool_blocks": 64.0,
                       "kv_block_bytes": 16384.0, "rounds": 23,
                       "tok_per_round": 4.17, "kv_bytes_swept": 4.39e6},
      "int8_paged_n64": {"requests_finished": 6, "kv_pool_blocks": 64.0,
                         "kv_block_bytes": 4352.0, "rounds": 23,
                         "tok_per_round": 4.17, "kv_bytes_swept": 1.17e6,
                         "prefix_match_frac": 0.53}}


def test_collect_table6_metrics_and_modes():
    entries = collect_table6(T6)
    by = {e["metric"]: e for e in entries}
    assert by["sync.rounds"]["mode"] == "fail"
    assert by["sync.host_blocked_mean_s"]["mode"] == "warn"   # 2-core hatch
    assert by["speedup"]["mode"] == "warn"
    assert by["streams_identical"]["value"] == 1.0
    assert by["streams_identical"]["better"] == "exact"


def test_collect_table7_zero_acceptance_omitted():
    """A 0.0 baseline can never fail a higher-is-better check, so the
    entry must be OMITTED — a later collapse-to-zero then trips the
    missing-metric hard failure instead of an unfailable 0-vs-0."""
    t7 = {"model/dsde": dict(CELL),
          "ngram/static": dict(CELL, mean_acceptance=0.0)}
    metrics = {e["metric"] for e in collect_table7(t7)}
    assert "model/dsde.mean_acceptance" in metrics
    assert "ngram/static.mean_acceptance" not in metrics
    assert "ngram/static.rounds" in metrics        # the rest still gated


def test_collect_table8_modes_and_zero_hit_omission():
    by = {e["metric"]: e for e in collect_table8(T8)}
    # deterministic prefill work: hard-gated, exact
    assert by["share0.5.prefill_tokens_on"]["mode"] == "fail"
    assert by["share0.5.prefill_tokens_on"]["better"] == "exact"
    # wall-derived TTFT: the 2-core warn hatch
    assert by["share0.5.ttft_speedup"]["mode"] == "warn"
    assert by["half_pool.requests_finished"]["better"] == "exact"
    # zero-hit point omits the rate (same rationale as table7 acceptance)
    cold = {"share0": dict(T8["share0.5"], prefix_cache_hit_rate=0.0)}
    metrics = {e["metric"] for e in collect_table8(cold)}
    assert "share0.prefix_cache_hit_rate" not in metrics
    assert "share0.prefix_cache_hit_blocks" not in metrics
    assert "share0.prefill_tokens_on" in metrics


def test_collect_table9_modes_and_divergence_pin():
    by = {e["metric"]: e for e in collect_table9(T9)}
    # byte geometry is pure config arithmetic: exact, hard-gated
    assert by["int8_paged_n64.kv_block_bytes"]["better"] == "exact"
    assert by["int8_paged_n64.kv_block_bytes"]["mode"] == "fail"
    assert by["fp_paged_n64.kv_bytes_swept"]["better"] == "lower"
    # seeded greedy stream divergence vs fp is bit-stable — exact
    assert by["int8_paged_n64.prefix_match_frac"]["better"] == "exact"
    # the fp reference cell has no divergence metric (it IS the reference)
    assert "fp_paged_n64.prefix_match_frac" not in by


def test_collect_table10_counters_fail_latency_warns():
    """Saturation points gate hard on the deterministic counters only:
    trace-fixed budgets make requests_finished/tokens_emitted exact,
    while every wall-derived latency/goodput number rides the 2-core
    warn hatch (table6 precedent)."""
    by = {e["metric"]: e for e in collect_table10(T10)}
    # 2 processes x 2 load points x 7 metrics
    assert len(by) == 2 * 2 * 7
    for cell in ("poisson_x0.6", "poisson_x1.5", "bursty_x0.6",
                 "bursty_x1.5"):
        assert by[f"{cell}.requests_finished"]["mode"] == "fail"
        assert by[f"{cell}.requests_finished"]["better"] == "exact"
        assert by[f"{cell}.tokens_emitted"]["better"] == "exact"
        for m in ("ttft_s_p50", "ttft_s_p99", "tpot_s_p50",
                  "goodput_tok_s", "queue_depth_peak"):
            assert by[f"{cell}.{m}"]["mode"] == "warn", m
    assert by["bursty_x1.5.goodput_tok_s"]["better"] == "higher"
    assert by["poisson_x1.5.queue_depth_peak"]["better"] == "lower"
    # capacity itself is host-dependent — never a gated metric
    assert not any(m.startswith("capacity") for m in by)


def test_collect_table11_counters_and_readiness_fail_slo_warns():
    """SLO points gate hard on the deterministic counters AND on the
    latency model having been fit (readiness is exact — min_rounds sits
    far below any smoke's round count); every wall-derived goodput /
    attainment / TTFT number rides the table10 warn hatch.  The fitted
    coefficients themselves are host pace — never gated."""
    by = {e["metric"]: e for e in collect_table11(T11)}
    # 2 load points x 3 policies x 6 metrics
    assert len(by) == 2 * 3 * 6
    for cell in ("x0.8", "x1.2"):
        for policy in ("static", "dsde", "slo"):
            p = f"{cell}.{policy}"
            assert by[f"{p}.requests_finished"]["mode"] == "fail"
            assert by[f"{p}.requests_finished"]["better"] == "exact"
            assert by[f"{p}.tokens_emitted"]["better"] == "exact"
            assert by[f"{p}.latency_model_ready"]["mode"] == "fail"
            assert by[f"{p}.latency_model_ready"]["better"] == "exact"
            for m in ("goodput_tok_s", "slo_attained_frac", "ttft_s_p99"):
                assert by[f"{p}.{m}"]["mode"] == "warn", m
    assert by["x1.2.slo.goodput_tok_s"]["better"] == "higher"
    assert by["x0.8.static.ttft_s_p99"]["better"] == "lower"
    # capacity + coefficients are host-dependent — never gated metrics
    assert not any(m.startswith(("capacity", "latency_model."))
                   for m in by)


# ---------------------------------------------------------------------------
# compare: round-trip + failure paths through the CLI entry points
# ---------------------------------------------------------------------------

def _compare(tmp_path, baseline, pr, summary=None):
    b, p = tmp_path / "base.json", tmp_path / "pr.json"
    b.write_text(json.dumps(baseline))
    p.write_text(json.dumps(pr))
    args = types.SimpleNamespace(baseline=str(b), pr=str(p),
                                 summary=summary)
    return cmd_compare(args)


def test_round_trip_identical_passes(tmp_path, capsys):
    t7 = {"model/dsde": dict(CELL)}
    entries = collect_table6(T6) + collect_table7(t7)
    assert _compare(tmp_path, entries, entries) == 0
    assert "within tolerance" in capsys.readouterr().out


def test_regression_fails_and_warn_does_not(tmp_path, capsys):
    baseline = collect_table6(T6)
    pr = json.loads(json.dumps(baseline))
    for e in pr:
        if e["metric"] == "sync.rounds":
            e["value"] = 20.0                  # hard metric: +43%
        if e["metric"] == "pipelined.host_blocked_mean_s":
            e["value"] = 99.0                  # warn-only metric blown up
    assert _compare(tmp_path, baseline, pr) == 1
    out = capsys.readouterr().out
    assert "sync.rounds" in out and "Regressions" in out
    assert "warn-only" in out
    # the warn alone must NOT fail
    for e in pr:
        if e["metric"] == "sync.rounds":
            e["value"] = 14.0
    assert _compare(tmp_path, baseline, pr) == 0


def test_missing_metric_is_hard_failure(tmp_path, capsys):
    baseline = collect_table6(T6)
    pr = [e for e in baseline if e["metric"] != "sync.tokens"]
    assert _compare(tmp_path, baseline, pr) == 1
    assert "missing from PR run" in capsys.readouterr().out


def test_summary_file_written(tmp_path):
    baseline = collect_table6(T6)
    summary = tmp_path / "summary.md"
    assert _compare(tmp_path, baseline, baseline,
                    summary=str(summary)) == 0
    assert "| bench | metric |" in summary.read_text()


def test_collect_cli_round_trips_files(tmp_path):
    t6, t7, t8, t9, t10, t11 = (
        tmp_path / "t6.json", tmp_path / "t7.json", tmp_path / "t8.json",
        tmp_path / "t9.json", tmp_path / "t10.json", tmp_path / "t11.json")
    t6.write_text(json.dumps(T6))
    t7.write_text(json.dumps({"model/dsde": dict(CELL)}))
    t8.write_text(json.dumps(T8))
    t9.write_text(json.dumps(T9))
    t10.write_text(json.dumps(T10))
    t11.write_text(json.dumps(T11))
    out = tmp_path / "BENCH_pr.json"
    args = types.SimpleNamespace(table6=str(t6), table7=str(t7),
                                 table8=str(t8), table9=str(t9),
                                 table10=str(t10), table11=str(t11),
                                 out=str(out))
    assert cmd_collect(args) == 0
    entries = json.loads(out.read_text())
    assert {tuple(sorted(e)) for e in entries} == {
        ("bench", "better", "metric", "mode", "tolerance", "value")}
