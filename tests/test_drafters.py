"""Tests for the pluggable drafter API (repro/core/drafters).

Covers: registry round-trips, the n-gram suffix-match oracle + Pallas
kernel bit-exactness, greedy exactness of every drafter (speculative
decoding's guarantee is proposer-independent), the full drafter × policy
config matrix, model-free serving with zero draft params / zero draft KV
blocks (and the doubled paged pool), goodput cost sourcing from
``Drafter.step_cost()``, and the serving-level *statistical* exactness
of the stochastic path: temperature-1.0 engine token frequencies match
target-only autoregressive sampling, for both ``model`` and ``ngram``
drafters.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import spec_decode as sd
from repro.core.config import ModelConfig, ServingConfig, SpecDecodeConfig
from repro.core.drafters import (Drafter, available_drafters, build_drafter,
                                 model_flops_per_token, register_drafter)
from repro.core.policies import available_policies
from repro.kernels import ops as kernel_ops
from repro.kernels import ref
from repro.kernels.ngram_match import ngram_suffix_propose
from repro.models.module import init_params
from repro.models.transformer import forward, model_specs
from repro.serving.engine import ServingEngine
from repro.serving.request import Request

jax.config.update("jax_platform_name", "cpu")

KEY = jax.random.PRNGKey(0)
ALL_DRAFTERS = ("model", "ngram", "self")


@pytest.fixture(scope="module")
def pair():
    cfg = get_config("smollm-135m").reduced()
    pt = init_params(model_specs(cfg), jax.random.PRNGKey(1), jnp.float32)
    noise = init_params(model_specs(cfg), jax.random.PRNGKey(9), jnp.float32)
    pd = jax.tree_util.tree_map(lambda a, b: a + 0.04 * b, pt, noise)
    return cfg, pt, pd


def greedy_rollout(params, cfg, prompt, n):
    toks = list(prompt)
    for _ in range(n):
        logits, _, _ = forward(params, cfg,
                               jnp.asarray([toks], jnp.int32), mode="train")
        toks.append(int(jnp.argmax(logits[0, -1, :cfg.vocab_size])))
    return toks[len(prompt):]


def _engine(cfg, pt, pd, spec, **sv_kw):
    model_free = not build_drafter(spec, cfg, cfg).uses_draft_model()
    return ServingEngine(pt, cfg, None if model_free else pd,
                         None if model_free else cfg, spec,
                         ServingConfig(**sv_kw), seed=0)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_registry_lists_builtin_drafters():
    assert set(ALL_DRAFTERS) <= set(available_drafters())


@pytest.mark.parametrize("name", ALL_DRAFTERS)
def test_build_drafter_round_trip(name):
    cfg = get_config("smollm-135m").reduced()
    spec = SpecDecodeConfig(drafter=name)
    d = build_drafter(spec, cfg, cfg)
    assert isinstance(d, Drafter)
    # frozen + hashable: usable inside a jit static argument
    assert hash(d) == hash(build_drafter(spec, cfg, cfg))
    assert d == build_drafter(spec, cfg, cfg)


def test_build_drafter_unknown_name_raises():
    cfg = get_config("smollm-135m").reduced()
    with pytest.raises(KeyError, match="registered"):
        build_drafter(SpecDecodeConfig(drafter="nope"), cfg, cfg)


def test_register_custom_drafter():
    @register_drafter("_test_null")
    @dataclasses.dataclass(frozen=True)
    class NullDrafter(Drafter):
        pass

    try:
        d = build_drafter(SpecDecodeConfig(drafter="_test_null"),
                          get_config("smollm-135m").reduced())
        assert not d.uses_draft_model() and d.step_cost() == 0.0
        assert "_test_null" in available_drafters()
    finally:
        from repro.core.drafters import base
        base._REGISTRY.pop("_test_null", None)


def test_step_cost_semantics(pair):
    cfg, _, _ = pair
    cfg_d = dataclasses.replace(cfg, d_model=128, num_heads=2,
                                num_kv_heads=1, head_dim=64, d_ff=256,
                                name="little")
    spec = SpecDecodeConfig()
    model = build_drafter(spec, cfg, cfg_d)
    assert 0.0 < model.step_cost() < 1.0       # smaller draft is cheaper
    assert build_drafter(SpecDecodeConfig(drafter="ngram"),
                         cfg).step_cost() == 0.0
    selfd = build_drafter(SpecDecodeConfig(drafter="self"), cfg)
    assert 0.0 < selfd.step_cost() < 1.0       # a strict prefix of layers
    assert model_flops_per_token(cfg_d) < model_flops_per_token(cfg)


def test_self_drafter_rejects_bad_configs(pair):
    cfg, _, _ = pair
    with pytest.raises(ValueError, match="self_draft_layers"):
        build_drafter(SpecDecodeConfig(drafter="self",
                                       self_draft_layers=cfg.num_layers),
                      cfg)
    ssm = get_config("mamba2-130m").reduced()
    with pytest.raises(ValueError, match="family"):
        build_drafter(SpecDecodeConfig(drafter="self"), ssm)


# ---------------------------------------------------------------------------
# N-gram suffix match: oracle semantics + kernel bit-exactness
# ---------------------------------------------------------------------------

def test_ngram_oracle_basic_match():
    # suffix [1,2,3] (ctx=12) occurs at 0 (cont 9,1,...) and 4 (cont 7,5,...)
    buf = jnp.asarray([[1, 2, 3, 9, 1, 2, 3, 7, 5, 1, 2, 3, 0, 0]], jnp.int32)
    toks, cnt = ref.ngram_propose_ref(buf, jnp.asarray([12]), n=3, k=4)
    # most recent usable occurrence is i=4: continuation 7, 5, 1, 2
    np.testing.assert_array_equal(np.asarray(toks)[0], [7, 5, 1, 2])
    assert int(cnt[0]) == 4


def test_ngram_oracle_no_match_and_short_context():
    buf = jnp.asarray([[1, 2, 3, 4, 5, 6, 0, 0]], jnp.int32)
    toks, cnt = ref.ngram_propose_ref(buf, jnp.asarray([6]), n=3, k=2)
    assert int(cnt[0]) == 0                      # no repeat anywhere
    np.testing.assert_array_equal(np.asarray(toks)[0], [0, 0])
    # context shorter than n+1 can never match
    toks, cnt = ref.ngram_propose_ref(buf, jnp.asarray([3]), n=3, k=2)
    assert int(cnt[0]) == 0


def test_ngram_oracle_continuation_clipped_at_context():
    # suffix [1,2] (ctx=6) matches at 0; continuation has only 2 known
    # tokens (positions 2,3) before... ctx bounds nothing here; at i=2
    # the match [1,2] continues with 1,2 up to ctx edge
    buf = jnp.asarray([[1, 2, 1, 2, 1, 2, 0, 0]], jnp.int32)
    toks, cnt = ref.ngram_propose_ref(buf, jnp.asarray([6]), n=2, k=4)
    # most recent usable i with >=1 continuation before ctx: i=2
    # (cont positions 4,5 -> tokens 1,2); i=4 is the trivial suffix
    assert int(cnt[0]) == 2
    np.testing.assert_array_equal(np.asarray(toks)[0, :2], [1, 2])


@pytest.mark.parametrize("n,k", [(1, 3), (2, 4), (3, 5), (4, 1)])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_ngram_kernel_matches_oracle_exactly(n, k, seed):
    rng = np.random.RandomState(seed)
    b, l = 5, 96
    # small alphabet => plenty of accidental repeats to find
    buf = jnp.asarray(rng.randint(0, 5, size=(b, l)), jnp.int32)
    ctx = jnp.asarray(rng.randint(0, l + 1, size=(b,)), jnp.int32)
    want_t, want_c = ref.ngram_propose_ref(buf, ctx, n=n, k=k)
    got_t, got_c = kernel_ops.ngram_propose(buf, ctx, n=n, k=k,
                                            force_kernel=True,
                                            interpret=True)
    np.testing.assert_array_equal(np.asarray(got_t), np.asarray(want_t))
    np.testing.assert_array_equal(np.asarray(got_c), np.asarray(want_c))
    # the pallas entry itself (not just the ops dispatcher) is bit-exact
    pk_t, pk_c = ngram_suffix_propose(buf, ctx, n=n, k=k, interpret=True)
    np.testing.assert_array_equal(np.asarray(pk_t), np.asarray(want_t))
    np.testing.assert_array_equal(np.asarray(pk_c), np.asarray(want_c))


# ---------------------------------------------------------------------------
# Greedy exactness per drafter + model-free serving guarantees
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ALL_DRAFTERS)
def test_greedy_exactness_per_drafter(pair, name):
    """Speculative decoding is exact no matter WHO proposes: greedy
    engine output == the target's greedy rollout for every drafter."""
    cfg, pt, pd = pair
    # a repetitive prompt gives the lookup drafter real matches
    prompt = [3, 7, 11, 3, 7, 11, 3, 7]
    n_new = 16
    want = greedy_rollout(pt, cfg, prompt, n_new)
    spec = SpecDecodeConfig(policy="dsde", temperature=0.0, drafter=name)
    eng = _engine(cfg, pt, pd, spec, max_batch_size=2, max_seq_len=128)
    req = Request(0, prompt=list(prompt), max_new_tokens=n_new)
    m = eng.run([req])
    assert req.output == want, name
    assert m["drafter"] == name


def test_ngram_serves_with_zero_draft_params_and_zero_kv(pair):
    """The headline capacity claim: a model-free drafter serves with NO
    draft params and NO draft KV blocks, and the paged pool doubles
    (the draft mirror's block budget returns to the target pool)."""
    cfg, pt, _ = pair
    rng = np.random.RandomState(3)
    prompts = [rng.randint(0, cfg.vocab_size, size=8).tolist()
               for _ in range(3)]
    spec = SpecDecodeConfig(policy="dsde", temperature=0.0, drafter="ngram")
    sv = ServingConfig(max_batch_size=2, max_seq_len=128, paged_kv=True,
                       kv_block_size=16, num_kv_blocks=8)
    eng = ServingEngine(pt, cfg, None, None, spec, sv, seed=0)
    reqs = [Request(i, prompt=p, max_new_tokens=8)
            for i, p in enumerate(prompts)]
    m = eng.run(reqs)
    assert m["requests_finished"] == 3
    # drafter state is a token history, not a KV cache
    assert set(eng.state.draft_cache) == {"tokens", "length"}
    assert m["draft_kv_blocks_peak"] == 0.0
    assert all(r.get("draft_kv_blocks_in_use") == 0.0
               for r in eng.round_log)
    # mirror budget returned: pool is 2x the configured num_kv_blocks
    assert m["kv_pool_blocks"] == 16.0
    assert eng.scheduler.kv_blocks_total() == 16


def test_model_drafter_requires_params(pair):
    cfg, pt, _ = pair
    with pytest.raises(ValueError, match="draft-model params"):
        ServingEngine(pt, cfg, None, None, SpecDecodeConfig(),
                      ServingConfig(max_batch_size=2, max_seq_len=64))


def test_ngram_lookup_actually_accelerates():
    """On self-repeating text the lookup drafter must land accepted
    proposals (BE > 1), i.e. it is a real drafter, not a no-op.  The
    tiny model's greedy dynamics enter a cycle (verified against the
    reference rollout), which is exactly the regime prompt lookup
    exploits."""
    cfg = _tiny_cfg(vocab=8)
    pt = _sharpened_params(cfg)
    prompt = [1, 2, 3, 1, 2, 3, 1, 2]
    want = greedy_rollout(pt, cfg, prompt, 24)
    # the stream must contain a repeated trigram (a cycle) for the
    # lookup to have anything to find — guards the fixture, not the code
    assert any(want[i:i + 3] == want[j:j + 3]
               for i in range(len(want) - 3)
               for j in range(i + 1, len(want) - 3))
    spec = SpecDecodeConfig(policy="static", static_sl=4, temperature=0.0,
                            drafter="ngram")
    eng = _engine(cfg, pt, None, spec, max_batch_size=1, max_seq_len=128)
    req = Request(0, prompt=list(prompt), max_new_tokens=24)
    m = eng.run([req])
    assert req.output == want
    assert req.accepted_tokens > 0
    assert m["block_efficiency"] > 1.0
    assert m["rounds"] < 23          # strictly fewer than autoregressive


# ---------------------------------------------------------------------------
# The full drafter x policy grid, by config string alone
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("drafter", ALL_DRAFTERS)
@pytest.mark.parametrize("policy", available_policies())
def test_drafter_policy_matrix(pair, drafter, policy):
    """Every registered drafter works with every registered policy via
    ``SpecDecodeConfig`` alone — no special wiring per cell."""
    cfg, pt, pd = pair
    rng = np.random.RandomState(7)
    spec = SpecDecodeConfig(policy=policy, drafter=drafter,
                            temperature=0.0)
    eng = _engine(cfg, pt, pd, spec, max_batch_size=2, max_seq_len=128)
    reqs = [Request(i, prompt=rng.randint(0, cfg.vocab_size, size=6).tolist(),
                    max_new_tokens=5) for i in range(2)]
    m = eng.run(reqs)
    assert m["requests_finished"] == 2
    assert all(len(r.output) == 5 for r in reqs)
    assert all(0 <= t < cfg.vocab_size for r in reqs for t in r.output)


# ---------------------------------------------------------------------------
# Goodput cost sourcing (satellite): Drafter.step_cost vs explicit override
# ---------------------------------------------------------------------------

def test_goodput_cost_sourced_from_drafter(pair):
    cfg, pt, pd = pair
    spec = SpecDecodeConfig(policy="goodput", drafter="model")
    assert spec.goodput_draft_cost is None
    eng = ServingEngine(pt, cfg, pd, cfg, spec,
                        ServingConfig(max_batch_size=1, max_seq_len=64))
    want = build_drafter(spec, cfg, cfg).step_cost()
    assert eng.spec.goodput_draft_cost == pytest.approx(want)
    # explicit override survives resolution untouched
    spec2 = SpecDecodeConfig(policy="goodput", goodput_draft_cost=0.42)
    eng2 = ServingEngine(pt, cfg, pd, cfg, spec2,
                         ServingConfig(max_batch_size=1, max_seq_len=64))
    assert eng2.spec.goodput_draft_cost == 0.42


def test_goodput_policy_without_engine_uses_fallback():
    from repro.core.policies.goodput import (FALLBACK_DRAFT_COST,
                                             resolved_draft_cost)
    assert resolved_draft_cost(SpecDecodeConfig()) == FALLBACK_DRAFT_COST
    assert resolved_draft_cost(
        SpecDecodeConfig(goodput_draft_cost=0.3)) == 0.3


# ---------------------------------------------------------------------------
# Serving-level statistical exactness of the stochastic path (satellite)
# ---------------------------------------------------------------------------

def _tiny_cfg(vocab: int = 8) -> ModelConfig:
    return ModelConfig(name="stat-tiny", family="dense", num_layers=2,
                       d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                       vocab_size=vocab, head_dim=16)


def _sharpened_params(cfg):
    """Random init with the (tied) embedding scaled up: random-init tiny
    models are near-uniform over an 8-token vocab, which would leave the
    statistical test without teeth — the scaled LM head sharpens the
    next-token distribution visibly away from uniform."""
    pt = dict(init_params(model_specs(cfg), jax.random.PRNGKey(5),
                          jnp.float32))
    pt["embed"] = pt["embed"] * 5.0
    return pt


def _exact_two_token_dist(pt, cfg, prompt):
    """Ground-truth joint P(t1, t2 | prompt) under pure target-only
    temperature-1.0 autoregressive sampling."""
    v = cfg.vocab_size
    lg, _, _ = forward(pt, cfg, jnp.asarray([prompt], jnp.int32),
                       mode="train")
    p1 = np.asarray(jax.nn.softmax(lg[0, -1, :v]))
    joint = np.zeros((v, v))
    for t1 in range(v):
        lg2, _, _ = forward(pt, cfg, jnp.asarray([prompt + [t1]], jnp.int32),
                            mode="train")
        p2 = np.asarray(jax.nn.softmax(lg2[0, -1, :v]))
        joint[t1] = p1[t1] * p2
    return joint


def _chi2(counts: np.ndarray, probs: np.ndarray, n: int) -> float:
    """Pearson chi-square with small expected cells pooled (Cochran)."""
    exp = probs.reshape(-1) * n
    obs = counts.reshape(-1)
    big = exp >= 5.0
    chi = float((((obs[big] - exp[big]) ** 2) / exp[big]).sum())
    if (~big).any():
        eo, ee = obs[~big].sum(), exp[~big].sum()
        if ee > 0:
            chi += float((eo - ee) ** 2 / ee)
    df = int(big.sum()) + (1 if (~big).any() else 0) - 1
    return chi, df


@pytest.mark.parametrize("drafter", ["model", "ngram"])
def test_serving_stochastic_path_statistically_exact(drafter):
    """Temperature-1.0 ENGINE output (prefill sampling + the full
    propose/verify/reject round) is distributed exactly like sampling
    the target autoregressively: chi-square of the two-token joint over
    a tiny vocab, many identical requests with distinct seeds, against
    the analytically computed target distribution."""
    cfg = _tiny_cfg(vocab=8)
    pt = _sharpened_params(cfg)
    noise = init_params(model_specs(cfg), jax.random.PRNGKey(6), jnp.float32)
    pd = jax.tree_util.tree_map(lambda a, b: a + 0.1 * b, pt, noise)
    # repetitive prompt: the ngram drafter proposes on most rounds
    prompt = [1, 2, 3, 1, 2, 3, 1, 2]
    joint = _exact_two_token_dist(pt, cfg, prompt)

    n = 2400
    spec = SpecDecodeConfig(policy="static", static_sl=3, temperature=1.0,
                            drafter=drafter)
    model_free = drafter != "model"
    eng = ServingEngine(pt, cfg, None if model_free else pd,
                        None if model_free else cfg, spec,
                        ServingConfig(max_batch_size=32, max_seq_len=64),
                        seed=0)
    reqs = [Request(i, prompt=list(prompt), max_new_tokens=2)
            for i in range(n)]
    m = eng.run(reqs)
    assert m["requests_finished"] == n
    counts = np.zeros((8, 8))
    for r in reqs:
        assert len(r.output) == 2
        counts[r.output[0], r.output[1]] += 1
    chi, df = _chi2(counts, joint, n)
    # ~5 sigma above the null mean: fails loudly for a biased sampler
    # (any real bias scales chi linearly in n), essentially never for an
    # exact one at this fixed seed
    crit = df + 5.0 * np.sqrt(2.0 * df)
    assert chi < crit, (drafter, chi, df, crit)
    # the same counts must NOT fit a visibly wrong reference: uniform
    chi_u, df_u = _chi2(counts, np.full((8, 8), 1.0 / 64.0), n)
    assert chi_u > df_u + 5.0 * np.sqrt(2.0 * df_u), "test has no teeth"
