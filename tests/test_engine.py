"""End-to-end serving engine tests — including the exactness guarantee:
greedy speculative decoding must emit exactly the target model's greedy
rollout, no matter how bad the draft is."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.config import ServingConfig, SpecDecodeConfig
from repro.models.module import init_params
from repro.models.transformer import forward, model_specs
from repro.models import cache as cache_lib
from repro.serving.engine import ServingEngine
from repro.serving.request import Request

jax.config.update("jax_platform_name", "cpu")

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def small_pair():
    cfg = get_config("smollm-135m").reduced()
    pt = init_params(model_specs(cfg), jax.random.PRNGKey(1), jnp.float32)
    noise = init_params(model_specs(cfg), jax.random.PRNGKey(7), jnp.float32)
    pd = jax.tree_util.tree_map(lambda a, b: a + 0.05 * b, pt, noise)
    return cfg, pt, pd


def greedy_rollout(params, cfg, prompt, n):
    """Reference: plain greedy autoregressive decoding via full forwards."""
    toks = list(prompt)
    for _ in range(n):
        logits, _, _ = forward(params, cfg,
                               jnp.asarray([toks], jnp.int32), mode="train")
        nxt = int(jnp.argmax(logits[0, -1, :cfg.vocab_size]))
        toks.append(nxt)
    return toks[len(prompt):]


@pytest.mark.parametrize("policy", ["dsde", "static", "adaedl"])
def test_greedy_spec_decode_exactness(small_pair, policy):
    """Greedy spec decoding == greedy target rollout, token for token."""
    cfg, pt, pd = small_pair
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, size=n).tolist()
               for n in (7, 12, 5)]
    n_new = 24
    refs = [greedy_rollout(pt, cfg, p, n_new) for p in prompts]

    spec = SpecDecodeConfig(policy=policy, temperature=0.0)
    eng = ServingEngine(pt, cfg, pd, cfg, spec,
                        ServingConfig(max_batch_size=2, max_seq_len=128),
                        seed=0)
    reqs = [Request(i, prompt=p, max_new_tokens=n_new)
            for i, p in enumerate(prompts)]
    eng.run(reqs)
    for req, ref in zip(reqs, refs):
        assert req.output == ref, (req.request_id, req.output, ref)


def test_autoregressive_baseline_exactness(small_pair):
    cfg, pt, pd = small_pair
    prompt = list(range(1, 9))
    ref = greedy_rollout(pt, cfg, prompt, 12)
    spec = SpecDecodeConfig(policy="autoregressive", temperature=0.0)
    eng = ServingEngine(pt, cfg, pd, cfg, spec,
                        ServingConfig(max_batch_size=2, max_seq_len=128))
    req = Request(0, prompt=prompt, max_new_tokens=12)
    m = eng.run([req])
    assert req.output == ref
    # first token comes from prefill; every other token costs one round
    assert m["rounds"] == 11
    assert m["block_efficiency"] == pytest.approx(12 / 11)


def test_spec_decode_faster_than_autoregressive(small_pair):
    """With a correlated draft, spec decoding must use fewer rounds."""
    cfg, pt, pd = small_pair
    rng = np.random.RandomState(1)
    prompts = [rng.randint(0, cfg.vocab_size, size=10).tolist()
               for _ in range(4)]

    def run(policy):
        spec = SpecDecodeConfig(policy=policy, temperature=0.0)
        eng = ServingEngine(pt, cfg, pd, cfg, spec,
                            ServingConfig(max_batch_size=4, max_seq_len=128))
        reqs = [Request(i, prompt=p, max_new_tokens=24) for i, p in
                enumerate(prompts)]
        return eng.run(reqs)

    m_sp = run("static")
    m_ar = run("autoregressive")
    assert m_sp["rounds"] < m_ar["rounds"]
    assert m_sp["block_efficiency"] > 1.0


def test_continuous_batching_reuses_slots(small_pair):
    cfg, pt, pd = small_pair
    rng = np.random.RandomState(2)
    spec = SpecDecodeConfig(policy="dsde", temperature=0.0)
    eng = ServingEngine(pt, cfg, pd, cfg, spec,
                        ServingConfig(max_batch_size=2, max_seq_len=128))
    reqs = [Request(i, prompt=rng.randint(0, cfg.vocab_size, size=6).tolist(),
                    max_new_tokens=8) for i in range(5)]
    m = eng.run(reqs)
    assert m["requests_finished"] == 5
    assert all(r.done for r in reqs)
    assert all(len(r.output) == 8 for r in reqs)


def test_eos_stops_early(small_pair):
    cfg, pt, pd = small_pair
    prompt = list(range(2, 10))
    ref = greedy_rollout(pt, cfg, prompt, 32)
    eos = ref[5]   # force an early EOS at a token we know will appear
    spec = SpecDecodeConfig(policy="static", temperature=0.0)
    eng = ServingEngine(pt, cfg, pd, cfg, spec,
                        ServingConfig(max_batch_size=1, max_seq_len=128))
    req = Request(0, prompt=prompt, max_new_tokens=32, eos_token_id=eos)
    eng.run([req])
    assert req.output[-1] == eos
    assert len(req.output) <= 32
    assert req.output == ref[:len(req.output)]


def test_prompt_bucket_clamped_to_kv_budget(small_pair):
    """Regression: a prompt whose power-of-two bucket rounds past
    max_seq_len used to build a prefill program wider than the cache —
    write_kv then silently dropped the prompt's leading tokens."""
    from repro.serving.engine import _bucket
    assert _bucket(33, cap=48) == 48
    assert _bucket(33, cap=128) == 64
    assert _bucket(5, cap=48) == 16
    cfg, pt, pd = small_pair
    prompt = list(range(1, 34))          # 33 tokens -> bucket 64 > 48
    ref_out = greedy_rollout(pt, cfg, prompt, 8)
    spec = SpecDecodeConfig(policy="autoregressive", temperature=0.0)
    eng = ServingEngine(pt, cfg, pd, cfg, spec,
                        ServingConfig(max_batch_size=1, max_seq_len=48))
    req = Request(0, prompt=prompt, max_new_tokens=8)
    eng.run([req])
    assert req.output == ref_out


def test_sampling_temperature_runs(small_pair):
    """Stochastic sampling path (temp 1.0) produces in-vocab tokens and
    respects max_new_tokens."""
    cfg, pt, pd = small_pair
    spec = SpecDecodeConfig(policy="dsde", temperature=1.0)
    eng = ServingEngine(pt, cfg, pd, cfg, spec,
                        ServingConfig(max_batch_size=2, max_seq_len=128))
    rng = np.random.RandomState(3)
    reqs = [Request(i, prompt=rng.randint(0, cfg.vocab_size, size=6).tolist(),
                    max_new_tokens=16) for i in range(3)]
    eng.run(reqs)
    for r in reqs:
        assert len(r.output) == 16
        assert all(0 <= t < cfg.vocab_size for t in r.output)


def test_recurrent_family_engine_exactness():
    """Spec decoding with state rollback (SSM family) stays exact."""
    cfg = get_config("mamba2-130m").reduced()
    pt = init_params(model_specs(cfg), jax.random.PRNGKey(1), jnp.float32)
    noise = init_params(model_specs(cfg), jax.random.PRNGKey(9), jnp.float32)
    pd = jax.tree_util.tree_map(lambda a, b: a + 0.05 * b, pt, noise)
    prompt = list(range(3, 11))
    ref = greedy_rollout(pt, cfg, prompt, 16)
    spec = SpecDecodeConfig(policy="dsde", temperature=0.0)
    eng = ServingEngine(pt, cfg, pd, cfg, spec,
                        ServingConfig(max_batch_size=1, max_seq_len=128))
    req = Request(0, prompt=prompt, max_new_tokens=16)
    eng.run([req])
    assert req.output == ref
