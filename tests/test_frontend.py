"""Serving front-end: replay exactness, streaming contract, scheduling.

The standing bar (DESIGN.md §14): the same request set submitted
through the continuous-batching front-end with all arrival times = 0
must produce byte-identical token streams to a direct
``ServingEngine.run()`` call — ``pump()`` is ``run()``'s loop body, so
an all-up-front submission replays the identical admit/dispatch/collect
sequence.  On top of that, greedy streams are schedule-invariant
(identity-threaded RNG + device-side termination, DESIGN.md §7/§9), so
even *staggered* arrivals must deliver the same per-request bytes —
only the timing moves.

Streaming contract: every host-reconciled token fires the request's
callback in order, exactly once, EOS/budget truncation never
over-delivers, and requests that finish inside the pipelined window
(reconciled one round late) still stream every token.
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.config import ServingConfig, SpecDecodeConfig
from repro.core.policies import available_policies
from repro.models.module import init_params
from repro.models.transformer import model_specs
from repro.serving.engine import ServingEngine
from repro.serving.frontend import ServingFrontend
from repro.serving.request import Request, RequestState
from repro.serving.scheduler import LookaheadScheduler

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def small_pair():
    cfg = get_config("smollm-135m").reduced()
    pt = init_params(model_specs(cfg), jax.random.PRNGKey(1), jnp.float32)
    noise = init_params(model_specs(cfg), jax.random.PRNGKey(7), jnp.float32)
    pd = jax.tree_util.tree_map(lambda a, b: a + 0.05 * b, pt, noise)
    return cfg, pt, pd


def _engine(cfg, pt, pd, *, policy="dsde", drafter="model", paged=True,
            pipelined=True, batch=2, max_seq=128, bs=16, nblocks=None,
            seed=0):
    spec = SpecDecodeConfig(policy=policy, temperature=0.0, drafter=drafter)
    model_free = drafter != "model"
    sv = ServingConfig(max_batch_size=batch, max_seq_len=max_seq,
                       paged_kv=paged, kv_block_size=bs,
                       num_kv_blocks=nblocks, pipelined=pipelined)
    return ServingEngine(pt, cfg, None if model_free else pd,
                         None if model_free else cfg, spec, sv, seed=seed)


def _prompts(cfg, sizes=(7, 12, 5), seed=11):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab_size, size=n).tolist() for n in sizes]


def _reqs(prompts, max_new=8, eos=None):
    return [Request(i, prompt=list(p), max_new_tokens=max_new,
                    eos_token_id=eos) for i, p in enumerate(prompts)]


# ---------------------------------------------------------------------------
# Replay exactness: front-end at arrival-time 0  ==  run()
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("drafter", ["model", "ngram"])
@pytest.mark.parametrize("policy", available_policies())
def test_replay_at_zero_matches_run(small_pair, policy, drafter):
    """All 5 policies x model+ngram drafters, paged + pipelined: the
    front-end replay of an all-at-once submission is byte-identical to
    run(), and the streamed events reproduce the same bytes."""
    cfg, pt, pd = small_pair
    prompts = _prompts(cfg)
    ref_eng = _engine(cfg, pt, pd, policy=policy, drafter=drafter)
    ref = _reqs(prompts)
    ref_eng.run(ref)
    ref_streams = [r.output for r in ref]

    fe = ServingFrontend(_engine(cfg, pt, pd, policy=policy,
                                 drafter=drafter))
    handles = [fe.submit_request(r) for r in _reqs(prompts)]
    fe.run_until_drained()
    assert [h.request.output for h in handles] == ref_streams, (
        policy, drafter)
    for h, want in zip(handles, ref_streams):
        toks, reason = h.result(timeout=0)      # all events already queued
        assert toks == want
        assert reason == "length"


@pytest.mark.parametrize("pipelined", [False, True], ids=["sync", "pipe"])
def test_staggered_arrivals_same_streams(small_pair, pipelined):
    """Greedy streams are schedule-invariant: submissions arriving
    MID-RUN (between pumps) change admission grouping but not one byte
    of any request's stream."""
    cfg, pt, pd = small_pair
    prompts = _prompts(cfg, sizes=(7, 12, 5, 9))
    ref_eng = _engine(cfg, pt, pd, pipelined=pipelined)
    ref = _reqs(prompts)
    ref_eng.run(ref)

    fe = ServingFrontend(_engine(cfg, pt, pd, pipelined=pipelined))
    reqs = _reqs(prompts)
    for r in reqs[:2]:
        fe.submit_request(r)
    # drive a couple of rounds, then land the stragglers mid-flight
    for _ in range(2):
        fe._drive_once()
    for r in reqs[2:]:
        fe.submit_request(r)
    fe.run_until_drained()
    assert [r.output for r in reqs] == [r.output for r in ref]
    assert all(r.state == RequestState.FINISHED for r in reqs)


def test_threaded_driver_delivers_all_streams(small_pair):
    """start()/stop() mode: concurrent submitters against the live
    driver thread; every stream terminates and matches the direct-run
    bytes (greedy schedule invariance again)."""
    cfg, pt, pd = small_pair
    prompts = _prompts(cfg, sizes=(7, 12, 5, 9, 6))
    ref_eng = _engine(cfg, pt, pd)
    ref = _reqs(prompts, max_new=6)
    ref_eng.run(ref)

    fe = ServingFrontend(_engine(cfg, pt, pd)).start()
    handles = [None] * len(prompts)

    def _submit(i):
        time.sleep(0.01 * i)
        handles[i] = fe.submit_request(
            Request(i, prompt=list(prompts[i]), max_new_tokens=6))

    threads = [threading.Thread(target=_submit, args=(i,))
               for i in range(len(prompts))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert fe.wait_idle(timeout=120)
    fe.stop()
    results = [h.result(timeout=5) for h in handles]
    assert [toks for toks, _ in results] == [r.output for r in ref]
    assert all(reason == "length" for _, reason in results)


# ---------------------------------------------------------------------------
# Streaming callback contract
# ---------------------------------------------------------------------------

def test_tokens_in_order_exactly_once(small_pair):
    """The callback sees exactly the bytes of Request.output, in order,
    one call per token — across admission waves and the pipelined
    window (more requests than slots)."""
    cfg, pt, pd = small_pair
    prompts = _prompts(cfg, sizes=(7, 12, 5, 9))
    eng = _engine(cfg, pt, pd)
    seen = {i: [] for i in range(len(prompts))}
    reqs = _reqs(prompts, max_new=10)
    for r in reqs:
        r.on_token = lambda rq, t: seen[rq.request_id].append(t)
    eng.run(reqs)
    for r in reqs:
        assert seen[r.request_id] == r.output, r.request_id
        assert len(r.output) == 10          # budget exactly, greedy no-EOS


def test_eos_truncation_never_over_delivers(small_pair):
    """Pick an EOS from a reference stream so termination happens
    mid-stream; the callback must stop AT the EOS token — device-side
    truncation rows never leak past it."""
    cfg, pt, pd = small_pair
    prompts = _prompts(cfg, sizes=(7, 12))
    ref_eng = _engine(cfg, pt, pd)
    ref = _reqs(prompts, max_new=12)
    ref_eng.run(ref)
    eos = ref[0].output[5]                  # forces a mid-stream stop
    eng = _engine(cfg, pt, pd)
    seen = {i: [] for i in range(len(prompts))}
    reqs = _reqs(prompts, max_new=12, eos=eos)
    for r in reqs:
        r.on_token = lambda rq, t: seen[rq.request_id].append(t)
    eng.run(reqs)
    for r in reqs:
        assert seen[r.request_id] == r.output
        assert len(r.output) <= 12
        if eos in r.output:
            assert r.output.index(eos) == len(r.output) - 1
            assert r.finish_reason() == "stop"
        else:
            assert r.finish_reason() == "length"


def test_callback_fires_for_finished_in_pipelined_window(small_pair):
    """A request finishing inside the pipelined window (its terminal
    round reconciled one iteration late, slot possibly already
    re-admitted) still streams every token and terminates its handle."""
    cfg, pt, pd = small_pair
    prompts = _prompts(cfg, sizes=(7, 5, 9, 6, 8))   # 5 reqs, 2 slots
    fe = ServingFrontend(_engine(cfg, pt, pd, pipelined=True))
    handles = [fe.submit_request(r) for r in _reqs(prompts, max_new=4)]
    fe.run_until_drained()
    for h in handles:
        toks, reason = h.result(timeout=0)
        assert toks == h.request.output and len(toks) == 4
        assert reason == "length"


def test_readmitted_request_streams_each_token_once(small_pair):
    """Forced preemption: the pending token of an evicted request was
    already streamed when first reconciled; recompute-on-readmit must
    not re-deliver it."""
    cfg, pt, pd = small_pair
    prompts = _prompts(cfg, sizes=(30, 25, 20), seed=5)
    # the known-preempting pool from test_pipeline: 16 blocks of 8
    eng = _engine(cfg, pt, pd, paged=True, bs=8, nblocks=16)
    seen = {i: [] for i in range(len(prompts))}
    reqs = _reqs(prompts, max_new=40)
    for r in reqs:
        r.on_token = lambda rq, t: seen[rq.request_id].append(t)
    m = eng.run(reqs)
    assert m["preemptions"] >= 1, "test needs real preemption pressure"
    for r in reqs:
        assert seen[r.request_id] == r.output
        assert len(r.output) == 40


# ---------------------------------------------------------------------------
# Scheduler: readmit-FIFO starvation guard
# ---------------------------------------------------------------------------

def _sched(batch=2):
    sv = ServingConfig(max_batch_size=batch, max_seq_len=128,
                       paged_kv=True, kv_block_size=16)
    return LookaheadScheduler(sv, SpecDecodeConfig(policy="static"))


def test_readmits_keep_fifo_priority_over_fresh():
    """Preempted readmits admit before fresh arrivals, FIFO among the
    wave (victims picked youngest-first, appendleft reverses)."""
    sched = _sched(batch=2)
    old = [Request(i, prompt=[1] * 4, max_new_tokens=4) for i in range(2)]
    for r in old:
        sched.submit(r)
    assert [r.request_id for r in sched.admit()] == [0, 1]
    fresh = [Request(i, prompt=[2] * 4, max_new_tokens=4)
             for i in range(10, 13)]
    for r in fresh:
        sched.submit(r)
    # one preemption wave, youngest-first (the ensure_capacity order)
    sched.preempt(old[1])
    sched.preempt(old[0])
    sched.assert_readmit_fifo()
    assert [r.request_id for r in sched.queue] == [0, 1, 10, 11, 12]
    # readmits re-enter first, in original admission order
    assert [r.request_id for r in sched.admit()] == [0, 1]
    sched.assert_readmit_fifo()


def test_starvation_guard_detects_violation():
    """The guard actually guards: a readmit filed behind a fresh
    arrival (a future scheduler bug) trips the assertion."""
    sched = _sched(batch=1)
    victim = Request(0, prompt=[1] * 4, max_new_tokens=4)
    sched.submit(victim)
    sched.admit()
    fresh = Request(1, prompt=[2] * 4, max_new_tokens=4)
    sched.submit(fresh)
    # simulate the bug: requeue the victim BEHIND the fresh arrival
    sched.allocator.free(victim.block_ids)
    victim.block_ids = []
    sched.slots[victim.slot] = None
    victim.slot = None
    victim.state = RequestState.QUEUED
    victim.preemptions += 1
    sched.queue.append(victim)              # append, not appendleft
    with pytest.raises(AssertionError, match="starvation"):
        sched.assert_readmit_fifo()


# ---------------------------------------------------------------------------
# Satellite: step()-driven sessions get run()'s summary for free
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pipelined", [False, True], ids=["sync", "pipe"])
def test_summary_regression_run_vs_pump_loop(small_pair, pipelined):
    """run() == submit + pump-loop + drain + summary().  An external
    driver reproduces run()'s summary dict exactly on every
    deterministic field, with the same key set (the satellite fix:
    latency stamping and summary logic live on the step()/pump() path,
    not inside run())."""
    cfg, pt, pd = small_pair
    prompts = _prompts(cfg)
    m_run = _engine(cfg, pt, pd, pipelined=pipelined).run(_reqs(prompts))

    eng = _engine(cfg, pt, pd, pipelined=pipelined)
    t0 = time.monotonic()
    for r in _reqs(prompts):
        eng.submit(r)
    done = []
    while eng.has_pending_work():
        done += eng.pump()
    done += eng.drain()
    m_ext = eng.summary(done, time.monotonic() - t0)

    assert set(m_run) == set(m_ext)
    deterministic = [
        "requests_finished", "requests_rejected", "preemptions",
        "tokens_emitted", "rounds", "drafter", "draft_step_cost",
        "draft_steps", "draft_steps_effective", "block_efficiency",
        "batch_tokens_per_round", "mean_acceptance", "kv_blocks_peak",
        "kv_pool_blocks", "kv_quant", "kv_block_bytes", "kv_pool_bytes",
        "kv_bytes_swept", "prefix_cache_hit_blocks",
        "prefix_cache_hit_rate", "cow_copies", "prefix_cache_evictions",
    ]
    for k in deterministic:
        assert m_run[k] == m_ext[k], k
    # latency stamps populated on the pump path too (reconciliation-
    # time stamping, not run()-specific bookkeeping)
    assert m_ext["ttft_mean_s"] > 0
    assert m_ext["queue_wait_mean_s"] >= 0


def test_request_tpot_and_finish_reason(small_pair):
    cfg, pt, pd = small_pair
    eng = _engine(cfg, pt, pd)
    reqs = _reqs(_prompts(cfg, sizes=(7,)), max_new=6)
    eng.run(reqs)
    r = reqs[0]
    assert r.finish_reason() == "length"
    assert r.tpot() is not None and r.tpot() >= 0
    assert r.ttft() is not None
    # finish_reason is None while a request is not FINISHED
    assert Request(9, prompt=[1, 2]).finish_reason() is None
