"""Pallas kernel validation (interpret mode on CPU): shape/dtype sweeps
against the pure-jnp oracles in repro.kernels.ref, plus the flash-attention
custom-VJP fallback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # offline container: deterministic fallback
    from _hypothesis_shim import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.kld_accept import fused_kld_accept
from repro.kernels.ops import kld_accept_signals, ragged_attention
from repro.kernels.ragged_attention import ragged_verify_attention
from repro.models.flash import flash_attend
from repro.models.layers import attend

jax.config.update("jax_platform_name", "cpu")
KEY = jax.random.PRNGKey(0)


def _attn_inputs(b, t, h, kv, d, w, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (b, t, h, d)).astype(dtype)
    kb = jax.random.normal(ks[1], (b, w, kv, d)).astype(dtype)
    vb = jax.random.normal(ks[2], (b, w, kv, d)).astype(dtype)
    lens = jax.random.randint(ks[3], (b,), t, max(w - t, t + 1))
    q_pos = lens[:, None] + jnp.arange(t)[None]
    kv_pos = jnp.where(jnp.arange(w)[None] < (lens[:, None] + t),
                       jnp.arange(w)[None], -1)
    return q, kb, vb, q_pos, kv_pos


# ---------------------------------------------------------------------------
# ragged verification attention kernel
# ---------------------------------------------------------------------------

SHAPES = [
    (2, 1, 8, 2, 64, 128),      # plain decode, GQA 4x
    (3, 6, 8, 8, 64, 256),      # verify, MHA
    (2, 11, 12, 4, 128, 96),    # verify, SL_max+1 queries
    (1, 4, 4, 1, 32, 512),      # MQA
    (2, 3, 16, 16, 64, 160),    # non-pow2 ring
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("window", [None, 64])
def test_ragged_attention_kernel_vs_oracle(shape, window):
    b, t, h, kv, d, w = shape
    q, kb, vb, q_pos, kv_pos = _attn_inputs(b, t, h, kv, d, w, jnp.float32)
    out = ragged_verify_attention(q, kb, vb, q_pos, kv_pos, window=window,
                                  interpret=True, block_k=64)
    want = ref.ragged_verify_attention_ref(q, kb, vb, q_pos, kv_pos,
                                           window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5),
                                        (jnp.bfloat16, 3e-2)])
def test_ragged_attention_dtypes(dtype, atol):
    q, kb, vb, q_pos, kv_pos = _attn_inputs(2, 4, 8, 4, 64, 128, dtype)
    out = ragged_verify_attention(q, kb, vb, q_pos, kv_pos, interpret=True,
                                  block_k=64)
    want = ref.ragged_verify_attention_ref(q, kb, vb, q_pos, kv_pos)
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=atol, rtol=1e-2)


def test_ragged_attention_empty_cache_rows():
    """Sequences whose ring has only the freshly-written tokens."""
    b, t, h, kv, d, w = 2, 2, 4, 2, 32, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, t, h, d))
    kb = jax.random.normal(ks[1], (b, w, kv, d))
    vb = jax.random.normal(ks[2], (b, w, kv, d))
    q_pos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    kv_pos = jnp.where(jnp.arange(w)[None] < t, jnp.arange(w)[None], -1)
    out = ragged_verify_attention(q, kb, vb, q_pos, kv_pos, interpret=True,
                                  block_k=32)
    want = ref.ragged_verify_attention_ref(q, kb, vb, q_pos, kv_pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_ops_dispatch_cpu_uses_ref():
    q, kb, vb, q_pos, kv_pos = _attn_inputs(1, 2, 4, 2, 32, 64, jnp.float32)
    out = ragged_attention(q, kb, vb, q_pos, kv_pos)
    want = ref.ragged_verify_attention_ref(q, kb, vb, q_pos, kv_pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-6)


# ---------------------------------------------------------------------------
# fused KLD / acceptance kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,t,v,bv", [(2, 3, 1000, 256), (4, 11, 2048, 512),
                                      (1, 1, 5003, 512), (3, 2, 640, 640)])
def test_fused_kld_vs_oracle(b, t, v, bv):
    ks = jax.random.split(KEY, 3)
    tl = jax.random.normal(ks[0], (b, t, v)) * 3
    dl = jax.random.normal(ks[1], (b, t, v)) * 3
    tok = jax.random.randint(ks[2], (b, t), 0, v)
    got = fused_kld_accept(tl, dl, tok, block_v=bv, interpret=True)
    want = ref.kld_accept_ref(tl, dl, tok)
    for g, w, name in zip(got, want, ("kld", "ent", "ptok", "qtok")):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   atol=1e-4, rtol=1e-4, err_msg=name)


@given(st.integers(0, 1000), st.integers(2, 6), st.sampled_from([128, 384]))
@settings(max_examples=15, deadline=None)
def test_fused_kld_property_sweep(seed, t, v):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    tl = jax.random.normal(ks[0], (1, t, v)) * 2
    dl = jax.random.normal(ks[1], (1, t, v)) * 2
    tok = jax.random.randint(ks[2], (1, t), 0, v)
    kld, ent, ptok, qtok = fused_kld_accept(tl, dl, tok, block_v=128,
                                            interpret=True)
    assert bool((kld >= 0).all())
    assert bool((ent >= 0).all())
    assert bool((ptok >= 0).all()) and bool((ptok <= 1 + 1e-6).all())
    assert bool((qtok >= 0).all()) and bool((qtok <= 1 + 1e-6).all())


def test_ops_kld_dispatch():
    ks = jax.random.split(KEY, 3)
    tl = jax.random.normal(ks[0], (1, 2, 300))
    dl = jax.random.normal(ks[1], (1, 2, 300))
    tok = jax.random.randint(ks[2], (1, 2), 0, 300)
    got = kld_accept_signals(tl, dl, tok)
    want = ref.kld_accept_ref(tl, dl, tok)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=1e-5)


# ---------------------------------------------------------------------------
# flash attention custom-VJP fallback
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,window,causal", [(48, None, True), (64, 24, True),
                                             (50, None, False)])
def test_flash_forward_and_grads(t, window, causal):
    b, h, kv, d = 2, 8, 8, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, t, h, d))
    k = jax.random.normal(ks[1], (b, t, kv, d))
    v = jax.random.normal(ks[2], (b, t, kv, d))
    qp = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    valid = jnp.ones((b, t), bool)

    f = lambda *a: (flash_attend(*a, kv_valid=None, window=window,
                                 causal=causal, q_block=16, kv_block=16)
                    ** 2).sum()
    g = lambda *a: (attend(*a, q_pos=qp, kv_pos=qp, kv_valid=valid,
                           window=window, causal=causal) ** 2).sum()
    g1 = jax.grad(f, (0, 1, 2))(q, k, v)
    g2 = jax.grad(g, (0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=5e-4)


def test_flash_ragged_validity():
    """kv_valid masking (ragged prompts) agrees with naive attention."""
    b, t, h, d = 2, 40, 4, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, t, h, d))
    k = jax.random.normal(ks[1], (b, t, h, d))
    v = jax.random.normal(ks[2], (b, t, h, d))
    valid = jnp.arange(t)[None] < jnp.array([[25], [33]])
    qp = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    o1 = flash_attend(q, k, v, kv_valid=valid, q_block=16, kv_block=16)
    o2 = attend(q, k, v, q_pos=qp, kv_pos=qp, kv_valid=valid)
    # compare only valid query rows (invalid rows are don't-care)
    m = np.asarray(valid)
    np.testing.assert_allclose(np.asarray(o1)[m], np.asarray(o2)[m],
                               atol=1e-4)
