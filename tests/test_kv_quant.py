"""Quantized KV serving data plane (DESIGN.md §13): int8 per-block
paged pools with per-slot-per-KV-head amax scales.

Covers the exactness contract layer by layer: quantization primitives,
the quantized pool struct + write/gather round trip, the Pallas
``paged_ragged_verify_attention_quant`` kernel against its jnp oracle,
bounded error against the fp pipeline, dtype-aware byte accounting at
the admission boundary, and the serving-level statistical exactness of
the stochastic path over a quantized pool (chi-square, both drafter
families)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import prefill as prefill_lib
from repro.core import spec_decode as sd
from repro.core.config import ModelConfig, ServingConfig, SpecDecodeConfig
from repro.kernels import ops, ref
from repro.kernels.ragged_attention import (
    paged_ragged_verify_attention, paged_ragged_verify_attention_quant)
from repro.models import cache as cache_lib
from repro.models.module import init_params
from repro.models.transformer import forward, model_specs
from repro.serving.engine import ServingEngine
from repro.serving.request import Request
from repro.serving.scheduler import LookaheadScheduler

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# Quantization primitives
# ---------------------------------------------------------------------------

def test_quantize_kv_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 7, 3, 32)) * 3.0
    q, s = cache_lib.quantize_kv(x)
    assert q.dtype == jnp.int8
    assert s.shape == x.shape[:-1]
    assert np.all(np.asarray(s) > 0)
    # per-element dequant error <= half a quantization step of that row
    err = np.abs(np.asarray(cache_lib.dequantize_kv(q, s)) - np.asarray(x))
    step = np.asarray(s)[..., None]
    assert np.all(err <= 0.5 * step + 1e-7)


def test_quantize_kv_zero_rows_are_exact():
    x = jnp.zeros((2, 5, 1, 16))
    q, s = cache_lib.quantize_kv(x)
    np.testing.assert_array_equal(np.asarray(q), 0)
    np.testing.assert_array_equal(np.asarray(s), 1.0)  # guarded, not 0/0
    np.testing.assert_array_equal(
        np.asarray(cache_lib.dequantize_kv(q, s)), 0.0)


def test_fake_quantize_is_idempotent():
    """dequant(quant(.)) is a projection: applying it twice is the
    identity on its image — the property that makes prefill's fake-quant
    attention and decode's stored-pool attention see the SAME values."""
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 9, 2, 64))
    f1 = cache_lib.fake_quantize_kv(x)
    f2 = cache_lib.fake_quantize_kv(f1)
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))


def test_kv_block_bytes_and_equal_byte_blocks():
    cfg = get_config("smollm-135m").reduced()
    bs = 16
    fp = cache_lib.kv_block_bytes(cfg, bs, "none")
    q8 = cache_lib.kv_block_bytes(cfg, bs, "int8")
    # int8 payload + fp32 scales still comes in under half the fp bytes
    assert 0 < q8 <= fp // 2
    n = cache_lib.equal_byte_blocks(cfg, 32, bs)
    assert n >= 64                       # equal bytes buy >= 2x the blocks
    assert n * q8 <= 32 * fp             # never over budget
    with pytest.raises(ValueError):
        cache_lib.kv_block_bytes(cfg, bs, "int4")


# ---------------------------------------------------------------------------
# Quantized pool struct + write/gather
# ---------------------------------------------------------------------------

def test_quant_paged_cache_struct_shapes_and_guards():
    cfg = get_config("smollm-135m").reduced()
    c = cache_lib.paged_cache_struct(cfg, batch=3, max_len=64, num_blocks=8,
                                     block_size=16, kv_quant="int8")
    assert c["k"].dtype == jnp.int8 and c["v"].dtype == jnp.int8
    kv = cache_lib.eff_kv_heads(cfg)
    assert c["k_scale"].shape == (cfg.num_layers, 8, 16, kv)
    assert c["k_scale"].dtype == jnp.float32
    assert cache_lib.is_quantized(c)
    fp = cache_lib.paged_cache_struct(cfg, 3, 64, 8, 16)
    assert not cache_lib.is_quantized(fp)
    with pytest.raises(ValueError):
        cache_lib.paged_cache_struct(cfg, 3, 64, 8, 16, kv_quant="int4")
    hy = get_config("recurrentgemma-2b").reduced()
    assert not cache_lib.supports_kv_quant(hy)
    with pytest.raises(ValueError):
        cache_lib.paged_cache_struct(hy, 3, 64, 8, 16, kv_quant="int8")


def test_quant_write_gather_roundtrip_is_fake_quantize():
    rng = np.random.RandomState(3)
    b, t, kv, d, bs, maxb, n = 2, 5, 2, 8, 4, 4, 10
    w = maxb * bs
    positions = jnp.asarray(rng.randint(0, w - t, size=(b, 1))
                            + np.arange(t)[None])
    k_new = jnp.asarray(rng.randn(b, t, kv, d), jnp.float32)
    v_new = jnp.asarray(rng.randn(b, t, kv, d), jnp.float32)
    perm = rng.permutation(n)
    table = jnp.asarray(np.stack([perm[:maxb], perm[maxb:2 * maxb]]))
    pk = jnp.zeros((n, bs, kv, d), jnp.int8)
    pv = jnp.zeros((n, bs, kv, d), jnp.int8)
    ks = jnp.zeros((n, bs, kv)); vs = jnp.zeros((n, bs, kv))
    pk, pv, ks, vs = cache_lib.write_kv_paged_quant(
        pk, pv, ks, vs, k_new, v_new, positions, table)
    gk, gv = cache_lib.gather_paged_kv_quant(pk, pv, ks, vs, table)
    # the gathered view is exactly the fake-quantized write, slot by slot
    fk = cache_lib.fake_quantize_kv(k_new)
    fv = cache_lib.fake_quantize_kv(v_new)
    for i in range(b):
        for j in range(t):
            p = int(positions[i, j])
            np.testing.assert_array_equal(np.asarray(gk[i, p]),
                                          np.asarray(fk[i, j]))
            np.testing.assert_array_equal(np.asarray(gv[i, p]),
                                          np.asarray(fv[i, j]))


def test_quant_write_respects_keep_mask_and_unallocated():
    b, t, kv, d, bs, maxb, n = 1, 4, 1, 4, 4, 3, 4
    table = jnp.asarray([[2, -1, -1]])
    positions = jnp.asarray([[2, 3, 4, 5]])      # 4,5 hit unalloc block
    keep = jnp.asarray([[True, False, True, True]])
    k_new = jnp.ones((b, t, kv, d)); v_new = jnp.ones((b, t, kv, d))
    pk = jnp.zeros((n, bs, kv, d), jnp.int8)
    pv = jnp.zeros((n, bs, kv, d), jnp.int8)
    ks = jnp.zeros((n, bs, kv)); vs = jnp.zeros((n, bs, kv))
    pk, pv, ks, vs = cache_lib.write_kv_paged_quant(
        pk, pv, ks, vs, k_new, v_new, positions, table, keep=keep)
    # only (block 2, offset 2) written: quantized ones at scale 1/127
    got = np.asarray(pk)
    assert got[2, 2].sum() == 127 * kv * d
    assert got.sum() == 127 * kv * d
    assert np.asarray(ks)[2, 2] == pytest.approx(1.0 / 127.0)
    assert float(np.asarray(ks).sum()) == pytest.approx(1.0 / 127.0)


def test_copy_scales_mirrors_copy_blocks():
    n, bs, kv = 6, 4, 2
    ks = jnp.arange(n * bs * kv, dtype=jnp.float32).reshape(1, n, bs, kv)
    vs = ks * 10.0
    src = jnp.asarray([1, n])        # second pair is the no-copy sentinel
    dst = jnp.asarray([4, n])
    ks2, vs2 = cache_lib.copy_scales(ks, vs, src, dst)
    np.testing.assert_array_equal(np.asarray(ks2[0, 4]),
                                  np.asarray(ks[0, 1]))
    np.testing.assert_array_equal(np.asarray(vs2[0, 4]),
                                  np.asarray(vs[0, 1]))
    # everything but the destination is untouched (sentinel dropped)
    keep = [i for i in range(n) if i != 4]
    np.testing.assert_array_equal(np.asarray(ks2[0, keep]),
                                  np.asarray(ks[0, keep]))


# ---------------------------------------------------------------------------
# Pallas kernel paged_ragged_verify_attention_quant vs oracle
# ---------------------------------------------------------------------------

QUANT_SHAPES = [
    # b, t, h, kv, d, n_blocks, bs, maxb, window
    (2, 1, 8, 2, 64, 12, 16, 4, None),      # plain decode, GQA 4x
    (3, 6, 8, 8, 64, 20, 16, 5, None),      # verify, MHA
    (2, 11, 12, 4, 128, 9, 8, 6, None),     # verify, SL_max+1 queries
    (2, 4, 4, 2, 32, 10, 16, 4, 24),        # sliding window
]


def _quant_attn_inputs(b, t, h, kv, d, n, bs, maxb, seed=0):
    rng = np.random.RandomState(seed)
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, t, h, d))
    pool_k = jax.random.normal(ks[1], (n, bs, kv, d))
    pool_v = jax.random.normal(ks[2], (n, bs, kv, d))
    qk, sk = cache_lib.quantize_kv(pool_k)
    qv, sv = cache_lib.quantize_kv(pool_v)
    table = np.full((b, maxb), -1, np.int32)
    kvp = np.full((n, bs), -1, np.int32)
    qpos = np.zeros((b, t), np.int32)
    perm = rng.permutation(n)
    c = 0
    for i in range(b):
        avail = min(maxb, n - c - (b - 1 - i))
        nb = rng.randint(1, max(avail, 1) + 1)
        table[i, :nb] = perm[c:c + nb]
        c += nb
        ntok = rng.randint(t, max(nb * bs, t) + 1)
        for p in range(min(ntok, nb * bs)):
            kvp[table[i, p // bs], p % bs] = p
        qpos[i] = np.arange(ntok - t, ntok)
    return (q, pool_k, pool_v, qk, qv, sk, sv, jnp.asarray(table),
            jnp.asarray(qpos), jnp.asarray(kvp))


@pytest.mark.parametrize("shape", QUANT_SHAPES)
def test_quant_paged_kernel_vs_oracle(shape):
    """The JX006 parity contract for the quantized kernel: interpret-mode
    ``paged_ragged_verify_attention_quant`` against the pure-jnp oracle
    over ragged scrambled tables, GQA, windows."""
    b, t, h, kv, d, n, bs, maxb, window = shape
    (q, _, _, qk, qv, sk, sv, table, qpos,
     kvp) = _quant_attn_inputs(b, t, h, kv, d, n, bs, maxb, seed=b * 10 + t)
    out = paged_ragged_verify_attention_quant(q, qk, qv, sk, sv, table,
                                              qpos, kvp, window=window,
                                              interpret=True)
    want = ref.paged_ragged_verify_attention_quant_ref(
        q, qk, qv, sk, sv, table, qpos, kvp, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=1e-4)


def test_quant_kernel_bounded_error_vs_fp_pipeline():
    """int8 per-head amax quantization keeps the attention output close
    to the fp paged kernel on the same underlying values — the bound the
    serving-level divergence argument (DESIGN.md §13) leans on."""
    b, t, h, kv, d, n, bs, maxb = 2, 4, 8, 2, 64, 12, 16, 4
    (q, pk, pv, qk, qv, sk, sv, table, qpos,
     kvp) = _quant_attn_inputs(b, t, h, kv, d, n, bs, maxb, seed=5)
    fp = ref.paged_ragged_verify_attention_ref(q, pk, pv, table, qpos, kvp)
    qz = paged_ragged_verify_attention_quant(q, qk, qv, sk, sv, table,
                                             qpos, kvp, interpret=True)
    err = np.max(np.abs(np.asarray(fp) - np.asarray(qz)))
    assert err < 0.05, err


def test_ops_dispatch_quant_kernel_matches_ref():
    b, t, h, kv, d, n, bs, maxb = 2, 3, 4, 2, 32, 8, 8, 4
    (q, _, _, qk, qv, sk, sv, table, qpos,
     kvp) = _quant_attn_inputs(b, t, h, kv, d, n, bs, maxb, seed=9)
    via_kernel = ops.paged_ragged_attention_quant(
        q, qk, qv, sk, sv, table, qpos, kvp, force_kernel=True)
    via_ref = ops.paged_ragged_attention_quant(
        q, qk, qv, sk, sv, table, qpos, kvp)
    np.testing.assert_allclose(np.asarray(via_kernel), np.asarray(via_ref),
                               atol=2e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# Byte accounting at the admission boundary
# ---------------------------------------------------------------------------

def test_equal_byte_pool_admits_what_fp_pool_rejects():
    """The capacity story, as an admission boundary: at EQUAL BYTES the
    int8 pool holds >= 2x the blocks, so a request whose worst-case
    residency overflows the fp pool fits the quantized one."""
    cfg = get_config("smollm-135m").reduced()
    bs, fp_blocks = 16, 8
    q8_blocks = cache_lib.equal_byte_blocks(cfg, fp_blocks, bs)
    assert q8_blocks >= 2 * fp_blocks
    spec = SpecDecodeConfig(policy="static", static_sl=3)

    def sched(nblocks, kv_quant):
        sv = ServingConfig(max_batch_size=1, max_seq_len=256, paged_kv=True,
                           kv_block_size=bs, num_kv_blocks=nblocks,
                           prefix_caching=True, kv_quant=kv_quant)
        bb = cache_lib.kv_block_bytes(cfg, bs, kv_quant)
        return LookaheadScheduler(sv, spec, kv_mirror=True,
                                  block_bytes=bb)

    s_fp = sched(fp_blocks, "none")
    s_q8 = sched(q8_blocks, "int8")
    # same byte budget, >= 2x the block budget
    assert s_q8.kv_bytes_total() <= s_fp.kv_bytes_total()
    assert s_q8.kv_blocks_total() >= 2 * s_fp.kv_blocks_total()
    # a mid-size request: needs more blocks than the fp pool has, fewer
    # than the equal-byte int8 pool
    need_tokens = (fp_blocks * bs + bs)
    req = Request("r", prompt=list(range(need_tokens)), max_new_tokens=8)
    assert not s_fp._fits(req)
    assert s_q8._fits(req)


def test_engine_rejects_invalid_kv_quant_combinations():
    cfg = get_config("smollm-135m").reduced()
    pt = init_params(model_specs(cfg), jax.random.PRNGKey(1), jnp.float32)
    spec = SpecDecodeConfig(policy="static", drafter="ngram")
    with pytest.raises(ValueError, match="paged_kv"):
        ServingEngine(pt, cfg, None, None, spec,
                      ServingConfig(max_batch_size=1, max_seq_len=64,
                                    kv_quant="int8"))
    hy = get_config("recurrentgemma-2b").reduced()
    ph = init_params(model_specs(hy), jax.random.PRNGKey(1), jnp.float32)
    with pytest.raises(ValueError, match="quantized"):
        ServingEngine(ph, hy, None, None, spec,
                      ServingConfig(max_batch_size=1, max_seq_len=64,
                                    paged_kv=True, kv_block_size=16,
                                    kv_quant="int8"))
    with pytest.raises(ValueError, match="paged"):
        sd.init_round_state(cfg, None, spec, 1, 64, jax.random.PRNGKey(0),
                            kv_quant="int8")


# ---------------------------------------------------------------------------
# Serving engine over the quantized pool
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_pair():
    cfg = get_config("smollm-135m").reduced()
    pt = init_params(model_specs(cfg), jax.random.PRNGKey(1), jnp.float32)
    noise = init_params(model_specs(cfg), jax.random.PRNGKey(7), jnp.float32)
    pd = jax.tree_util.tree_map(lambda a, b: a + 0.05 * b, pt, noise)
    return cfg, pt, pd


def _run_quant_engine(cfg, pt, pd, drafter, kv_quant, *, policy="static",
                      max_new=12, seed=0):
    spec = SpecDecodeConfig(policy=policy, temperature=0.0, drafter=drafter)
    sv = ServingConfig(max_batch_size=2, max_seq_len=128, paged_kv=True,
                       kv_block_size=16, kv_quant=kv_quant)
    model = drafter == "model"
    eng = ServingEngine(pt, cfg, pd if model else None,
                        cfg if model else None, spec, sv, seed=seed)
    reqs = [Request(str(i), prompt=list(range(2 + i, 12 + i)),
                    max_new_tokens=max_new) for i in range(3)]
    m = eng.run(reqs)
    return [r.output for r in reqs], m


@pytest.mark.parametrize("drafter", ["model", "ngram"])
def test_quant_engine_completes_and_halves_bytes(small_pair, drafter):
    cfg, pt, pd = small_pair
    outs_fp, m_fp = _run_quant_engine(cfg, pt, pd, drafter, "none")
    outs_q8, m_q8 = _run_quant_engine(cfg, pt, pd, drafter, "int8")
    assert m_q8["requests_finished"] == 3
    assert all(len(o) == 12 for o in outs_q8)
    assert m_q8["kv_quant"] == "int8"
    # the headline: same block count, under half the bytes
    assert m_q8["kv_pool_blocks"] == m_fp["kv_pool_blocks"]
    assert m_q8["kv_block_bytes"] <= 0.5 * m_fp["kv_block_bytes"]
    assert m_q8["kv_pool_bytes"] <= 0.5 * m_fp["kv_pool_bytes"]


def test_quant_engine_deterministic_across_schedules(small_pair):
    """The quantized plane keeps the engine's schedule-invariance: the
    same requests produce identical greedy streams sync vs pipelined."""
    cfg, pt, pd = small_pair
    streams = {}
    for pipelined in (False, True):
        spec = SpecDecodeConfig(policy="static", temperature=0.0,
                                drafter="model")
        sv = ServingConfig(max_batch_size=2, max_seq_len=128, paged_kv=True,
                           kv_block_size=16, kv_quant="int8",
                           pipelined=pipelined)
        eng = ServingEngine(pt, cfg, pd, cfg, spec, sv, seed=0)
        reqs = [Request(str(i), prompt=list(range(2 + i, 12 + i)),
                        max_new_tokens=10) for i in range(3)]
        eng.run(reqs)
        streams[pipelined] = [r.output for r in reqs]
    assert streams[False] == streams[True]


# ---------------------------------------------------------------------------
# Serving-level statistical exactness over the quantized pool
# ---------------------------------------------------------------------------

def _tiny_cfg(vocab: int = 8) -> ModelConfig:
    return ModelConfig(name="stat-tiny-q", family="dense", num_layers=2,
                       d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                       vocab_size=vocab, head_dim=16)


def _sharpened_params(cfg):
    pt = dict(init_params(model_specs(cfg), jax.random.PRNGKey(5),
                          jnp.float32))
    pt["embed"] = pt["embed"] * 5.0
    return pt


def _exact_two_token_dist_quant(pt, cfg, prompt, bs=16):
    """Ground-truth joint P(t1, t2 | prompt) under target-only sampling
    THROUGH the quantized paged cache — the reference the quantized
    engine must match exactly.  Computed with the same prefill program
    the engine runs: row 0 prefills the bare prompt (p1 from its last
    logits), rows 1..V prefill ``prompt + [t1]`` (p2 from theirs); the
    fake-quant prefill attention makes these bit-identical to the
    serving decode path over the stored int8 pool (DESIGN.md §13)."""
    v = cfg.vocab_size
    rows = 1 + v
    big = len(prompt) + 1
    maxb = -(-big // bs)
    n = rows * maxb
    c = cache_lib.paged_cache_struct(cfg, rows, maxb * bs, n, bs,
                                     require_full_seq=False,
                                     kv_quant="int8")
    table = jnp.arange(n, dtype=jnp.int32).reshape(rows, maxb)
    toks = np.zeros((rows, big), np.int32)
    lens = np.zeros((rows,), np.int32)
    toks[0, :len(prompt)] = prompt
    lens[0] = len(prompt)
    for t1 in range(v):
        toks[1 + t1] = prompt + [t1]
        lens[1 + t1] = big
    _, last = prefill_lib.prefill_paged_rows(
        pt, cfg, c["k"], c["v"], c["kv_pos"], table, jnp.asarray(toks),
        jnp.asarray(lens), k_scale=c["k_scale"], v_scale=c["v_scale"])
    p1 = np.asarray(jax.nn.softmax(last[0, :v]))
    joint = np.zeros((v, v))
    for t1 in range(v):
        p2 = np.asarray(jax.nn.softmax(last[1 + t1, :v]))
        joint[t1] = p1[t1] * p2
    return joint / joint.sum()


def _chi2(counts: np.ndarray, probs: np.ndarray, n: int):
    exp = probs.reshape(-1) * n
    obs = counts.reshape(-1)
    big = exp >= 5.0
    chi = float((((obs[big] - exp[big]) ** 2) / exp[big]).sum())
    if (~big).any():
        eo, ee = obs[~big].sum(), exp[~big].sum()
        if ee > 0:
            chi += float((eo - ee) ** 2 / ee)
    df = int(big.sum()) + (1 if (~big).any() else 0) - 1
    return chi, df


@pytest.mark.parametrize("drafter", ["model", "ngram"])
def test_quant_serving_stochastic_path_statistically_exact(drafter):
    """Chi-square serving exactness with ``kv_quant=int8``: the engine's
    temperature-1.0 two-token joint over the quantized pool matches the
    quantized-cache analytic reference (NOT the fp one — storage
    quantization shifts the target distribution, and exact rejection
    sampling must track the shifted target, bit for bit)."""
    cfg = _tiny_cfg(vocab=8)
    pt = _sharpened_params(cfg)
    noise = init_params(model_specs(cfg), jax.random.PRNGKey(6), jnp.float32)
    pd = jax.tree_util.tree_map(lambda a, b: a + 0.1 * b, pt, noise)
    prompt = [1, 2, 3, 1, 2, 3, 1, 2]
    joint = _exact_two_token_dist_quant(pt, cfg, prompt)

    n = 2400
    spec = SpecDecodeConfig(policy="static", static_sl=3, temperature=1.0,
                            drafter=drafter)
    model_free = drafter != "model"
    eng = ServingEngine(pt, cfg, None if model_free else pd,
                        None if model_free else cfg, spec,
                        ServingConfig(max_batch_size=32, max_seq_len=64,
                                      paged_kv=True, kv_block_size=16,
                                      kv_quant="int8"),
                        seed=0)
    reqs = [Request(i, prompt=list(prompt), max_new_tokens=2)
            for i in range(n)]
    m = eng.run(reqs)
    assert m["requests_finished"] == n
    counts = np.zeros((8, 8))
    for r in reqs:
        assert len(r.output) == 2
        counts[r.output[0], r.output[1]] += 1
    chi, df = _chi2(counts, joint, n)
    crit = df + 5.0 * np.sqrt(2.0 * df)
    assert chi < crit, (drafter, chi, df, crit)
    # teeth: the counts must NOT fit the uniform reference
    chi_u, df_u = _chi2(counts, np.full((8, 8), 1.0 / 64.0), n)
    assert chi_u > df_u + 5.0 * np.sqrt(2.0 * df_u), "test has no teeth"
