"""Trace format determinism (benchmarks/loadgen.py, DESIGN.md §14).

No engine here — these pin the reproducibility contract of the trace
generator itself: same args → byte-identical trace on any machine, the
request set independent of the arrival process/rate (the property the
saturation ladder's single-warmup and exact-counter gating rely on),
and the v1 JSON round-trip.
"""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks import loadgen
from benchmarks.loadgen import MIX, load_trace, make_trace, save_trace


def test_same_args_same_trace():
    a = make_trace(12, rate_rps=4.0, process="poisson", seed=3)
    b = make_trace(12, rate_rps=4.0, process="poisson", seed=3)
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)


def test_request_set_independent_of_arrival_pattern():
    """The split-rng contract: every point of a saturation ladder — any
    process, any rate — serves the IDENTICAL workload; only arrival
    offsets differ.  (This is why one capacity-probe warmup covers all
    load points' prefill shapes and why tokens_emitted is gate-exact.)"""
    def strip(trace):
        return [{k: v for k, v in r.items() if k != "arrival_s"}
                for r in trace["requests"]]

    base = make_trace(10, rate_rps=2.0, process="poisson", seed=7)
    for process, rate in (("poisson", 50.0), ("bursty", 2.0),
                          ("bursty", 50.0)):
        other = make_trace(10, rate_rps=rate, process=process, seed=7)
        assert strip(other) == strip(base), (process, rate)
    # different seed → different workload
    assert strip(make_trace(10, 2.0, "poisson", seed=8)) != strip(base)


def test_arrivals_shape():
    for process in ("poisson", "bursty"):
        tr = make_trace(20, rate_rps=5.0, process=process, seed=1)
        arr = [r["arrival_s"] for r in tr["requests"]]
        assert arr[0] == 0.0                    # trace starts at its head
        assert arr == sorted(arr)
        assert all(a >= 0.0 for a in arr)
    pois = make_trace(20, 5.0, "poisson", seed=1)
    burst = make_trace(20, 5.0, "bursty", seed=1)
    assert ([r["arrival_s"] for r in pois["requests"]]
            != [r["arrival_s"] for r in burst["requests"]])


def test_mix_bounds_and_cap():
    tr = make_trace(40, rate_rps=1.0, seed=5)
    for r in tr["requests"]:
        (plo, phi), (nlo, nhi) = MIX[r["dataset"]]
        assert plo <= len(r["prompt"]) <= phi
        assert nlo <= r["max_new_tokens"] <= nhi
    capped = make_trace(40, rate_rps=1.0, seed=5, max_new_cap=9)
    assert max(r["max_new_tokens"] for r in capped["requests"]) <= 9
    # cap only clamps budgets; prompts and datasets are untouched
    assert [r["prompt"] for r in capped["requests"]] == \
        [r["prompt"] for r in tr["requests"]]


def test_save_load_round_trip(tmp_path):
    tr = make_trace(6, rate_rps=3.0, process="bursty", seed=2)
    path = str(tmp_path / "trace.json")
    save_trace(tr, path)
    assert load_trace(path) == tr
    # version 2 (per-request SLO deadlines, DESIGN.md §15) loads too
    v2 = dict(tr, version=2)
    save_trace(v2, path)
    assert load_trace(path) == v2
    bad = dict(tr, version=3)
    save_trace(bad, path)
    with pytest.raises(AssertionError, match="trace version"):
        load_trace(path)


def test_trace_requests_carry_trace_ids():
    tr = make_trace(5, rate_rps=1.0, seed=9)
    reqs = loadgen.trace_requests(tr)
    assert [r.request_id for r in reqs] == [0, 1, 2, 3, 4]
    assert [r.prompt for r in reqs] == [r["prompt"] for r in tr["requests"]]
    assert [r.max_new_tokens for r in reqs] == \
        [r["max_new_tokens"] for r in tr["requests"]]
