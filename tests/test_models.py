"""Per-architecture smoke tests (assignment requirement: reduced variant,
<=2 layers, d_model<=512, <=4 experts — one forward/train step on CPU,
shape + no-NaN assertions) plus the decode==train consistency invariant
that speculative verification correctness rests on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.core.config import OptimizerConfig
from repro.models import cache as cache_lib
from repro.models.module import count_params, init_params
from repro.models.transformer import (build_cross_cache, commit, encode,
                                      forward, model_specs)
from repro.training.optimizer import init_adamw
from repro.training.train import train_step

jax.config.update("jax_platform_name", "cpu")

ASSIGNED = [a for a in list_archs() if not a.startswith("paper-")]
KEY = jax.random.PRNGKey(0)


def _setup(arch):
    cfg = get_config(arch).reduced()
    params = init_params(model_specs(cfg), KEY, jnp.float32)
    return cfg, params


def _enc_ctx(cfg, params, b, enc_len=8):
    emb = jax.random.normal(KEY, (b, enc_len, cfg.d_model)) * 0.02
    enc = encode(params, cfg, emb)
    ck, cv = build_cross_cache(params, cfg, enc)
    return emb, ck, cv


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_reduced_variant(arch):
    """Assignment smoke test: reduced config, one forward + one train step."""
    cfg, params = _setup(arch)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4
    b, s = 2, 16
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    labs = jnp.roll(toks, -1, 1)

    enc_embeds = None
    if cfg.is_encoder_decoder:
        enc_embeds = jax.random.normal(KEY, (b, 8, cfg.d_model)) * 0.02
    logits, _, aux = forward(params, cfg, toks, mode="train",
                             encoder_embeds=enc_embeds)
    vp = cfg.padded_vocab(128)
    assert logits.shape == (b, s, vp)
    assert not bool(jnp.isnan(logits).any())

    opt = init_adamw(params)
    p2, opt2, metrics = train_step(
        params, opt, toks, labs, cfg=cfg, opt_cfg=OptimizerConfig(),
        remat=False, encoder_embeds=enc_embeds)
    assert np.isfinite(float(metrics["loss"]))
    assert not bool(jnp.isnan(p2["embed"]).any())
    # parameters actually changed
    assert float(jnp.abs(p2["embed"] - params["embed"]).max()) > 0


@pytest.mark.parametrize("arch", ASSIGNED)
def test_prefill_decode_shapes(arch):
    cfg, params = _setup(arch)
    b, s, t = 2, 10, 3
    toks = jax.random.randint(KEY, (b, s + t), 0, cfg.vocab_size)
    c = cache_lib.cache_struct(cfg, b, 64, jnp.float32,
                               enc_len=8 if cfg.family == "audio" else None)
    if cfg.family == "audio":
        _, ck, cv = _enc_ctx(cfg, params, b)
        c["cross_k"], c["cross_v"] = ck, cv
        c["enc_valid"] = jnp.ones((b, 8), bool)
    pl, c, _ = forward(params, cfg, toks[:, :s], cache=c, mode="prefill")
    c["length"] = jnp.full((b,), s, jnp.int32)
    dl, c2, _ = forward(params, cfg, toks[:, s:], cache=c, mode="decode")
    assert dl.shape[:2] == (b, t)
    assert not bool(jnp.isnan(dl).any())


@pytest.mark.parametrize("arch", ["smollm-135m", "granite-moe-3b-a800m",
                                  "mamba2-130m", "recurrentgemma-2b",
                                  "qwen2-vl-2b", "mixtral-8x22b",
                                  "qwen3-32b", "qwen2.5-32b", "granite-8b"])
def test_decode_matches_train_forward(arch):
    """Incremental decode == full-context forward: the invariant that makes
    speculative verification exact (includes ragged partial commit)."""
    cfg, params = _setup(arch)
    b, s, t = 2, 10, 5
    toks = jax.random.randint(KEY, (b, s + t), 0, cfg.vocab_size)
    ref, _, _ = forward(params, cfg, toks, mode="train")

    c = cache_lib.cache_struct(cfg, b, 64, jnp.float32)
    _, c, _ = forward(params, cfg, toks[:, :s], cache=c, mode="prefill")
    c["length"] = jnp.full((b,), s, jnp.int32)
    snap = c
    dl, c2, _ = forward(params, cfg, toks[:, s:], cache=c, mode="decode")
    np.testing.assert_allclose(np.asarray(dl), np.asarray(ref[:, s:]),
                               atol=2e-3, rtol=1e-3)
    # partial commit: accept only 2 of 5 tokens, then re-verify the rest
    c3 = commit(params, cfg, toks[:, s:], snap, c2,
                jnp.full((b,), 2, jnp.int32))
    np.testing.assert_array_equal(np.asarray(c3["length"]), [s + 2, s + 2])
    dl3, _, _ = forward(params, cfg, toks[:, s + 2:s + 4], cache=c3,
                        mode="decode")
    np.testing.assert_allclose(np.asarray(dl3),
                               np.asarray(ref[:, s + 2:s + 4]),
                               atol=2e-3, rtol=1e-3)


def test_ragged_prompt_prefill():
    """Right-padded ragged prompts: pad positions must not leak into
    attention (input_mask semantics)."""
    cfg, params = _setup("smollm-135m")
    toks = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    # reference: prompt of length 5 processed alone
    c1 = cache_lib.cache_struct(cfg, 1, 64, jnp.float32)
    l1, _, _ = forward(params, cfg, toks[:1, :5], cache=c1, mode="prefill")
    # padded to 8 with mask
    c2 = cache_lib.cache_struct(cfg, 1, 64, jnp.float32)
    mask = (jnp.arange(8) < 5)[None]
    l2, _, _ = forward(params, cfg, toks[:1], cache=c2, mode="prefill",
                       input_mask=mask)
    np.testing.assert_allclose(np.asarray(l1[0, 4]), np.asarray(l2[0, 4]),
                               atol=1e-4)


def test_window_ring_cache_matches_full_attention():
    """Sliding-window ring cache: decode at position > window must equal a
    full forward with the same window mask."""
    import dataclasses
    cfg = dataclasses.replace(get_config("smollm-135m").reduced(),
                              attention_window=8)
    params = init_params(model_specs(cfg), KEY, jnp.float32)
    s, t = 20, 3
    toks = jax.random.randint(KEY, (1, s + t), 0, cfg.vocab_size)
    ref, _, _ = forward(params, cfg, toks, mode="train")
    c = cache_lib.cache_struct(cfg, 1, 64, jnp.float32)  # ring W = 8
    assert c["k"].shape[2] == 8 + cache_lib.RING_SLACK
    _, c, _ = forward(params, cfg, toks[:, :s], cache=c, mode="prefill")
    c["length"] = jnp.full((1,), s, jnp.int32)
    dl, _, _ = forward(params, cfg, toks[:, s:], cache=c, mode="decode")
    np.testing.assert_allclose(np.asarray(dl), np.asarray(ref[:, s:]),
                               atol=2e-3, rtol=1e-3)


def test_param_counts_full_configs():
    """Full (non-reduced) configs build spec trees with plausible sizes."""
    expected = {
        "qwen3-32b": (30e9, 40e9),
        "mixtral-8x22b": (120e9, 150e9),
        "smollm-135m": (0.1e9, 0.2e9),
        "mamba2-130m": (0.1e9, 0.2e9),
        "granite-8b": (7e9, 10e9),
    }
    for arch, (lo, hi) in expected.items():
        n = count_params(model_specs(get_config(arch), 128))
        assert lo < n < hi, (arch, n)
