"""Paged KV-cache data plane: allocator, block-table write/gather, the
paged Pallas kernel, and the headline guarantee — the paged engine emits
byte-identical token streams to the dense engine for every registered
policy (same seed, same requests), including under pool pressure with
preemption + recompute-on-readmit."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.config import ServingConfig, SpecDecodeConfig
from repro.core.policies import available_policies
from repro.kernels import ref
from repro.kernels.ragged_attention import paged_ragged_verify_attention
from repro.models import cache as cache_lib
from repro.models.module import init_params
from repro.models.transformer import model_specs
from repro.serving.engine import ServingEngine
from repro.serving.request import Request, RequestState
from repro.serving.scheduler import BlockAllocator, LookaheadScheduler

jax.config.update("jax_platform_name", "cpu")

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# BlockAllocator
# ---------------------------------------------------------------------------

def test_allocator_alloc_free_roundtrip():
    a = BlockAllocator(num_blocks=8, block_size=16)
    b1 = a.alloc(3)
    b2 = a.alloc(4)
    assert len(b1) == 3 and len(b2) == 4
    assert len(set(b1) | set(b2)) == 7          # disjoint
    assert a.n_free == 1 and a.n_used == 7
    assert a.alloc(2) is None                    # short: no state change
    assert a.n_free == 1
    a.free(b1)
    assert a.n_free == 4
    b3 = a.alloc(4)
    assert b3 is not None and a.n_free == 0
    assert a.alloc(0) == []


def test_allocator_blocks_for():
    a = BlockAllocator(num_blocks=4, block_size=16)
    assert a.blocks_for(0) == 0
    assert a.blocks_for(1) == 1
    assert a.blocks_for(16) == 1
    assert a.blocks_for(17) == 2
    assert a.blocks_for(48) == 3


# ---------------------------------------------------------------------------
# Scheduler: block-budget admission, grow, preempt, readmit
# ---------------------------------------------------------------------------

def _paged_sched(slots=2, max_seq=64, bs=8, nblocks=None):
    sv = ServingConfig(max_batch_size=slots, max_seq_len=max_seq,
                       paged_kv=True, kv_block_size=bs,
                       num_kv_blocks=nblocks)
    return LookaheadScheduler(sv, SpecDecodeConfig())


def test_paged_admission_charges_prefill_blocks():
    s = _paged_sched(slots=2, max_seq=64, bs=8, nblocks=8)
    r1 = Request(0, prompt=[1] * 20, max_new_tokens=8)   # 3 blocks
    r2 = Request(1, prompt=[1] * 30, max_new_tokens=8)   # 4 blocks
    s.submit(r1), s.submit(r2)
    assert len(s.admit()) == 2
    assert s.allocator.n_used == 7
    assert len(r1.block_ids) == 3 and len(r2.block_ids) == 4


def test_paged_admission_queues_when_pool_dry():
    s = _paged_sched(slots=2, max_seq=64, bs=8, nblocks=8)
    r1 = Request(0, prompt=[1] * 40, max_new_tokens=8)   # 5 blocks
    r2 = Request(1, prompt=[1] * 40, max_new_tokens=8)   # 5 blocks > 3 free
    s.submit(r1), s.submit(r2)
    admitted = s.admit()
    assert admitted == [r1]
    assert r2.state == RequestState.QUEUED      # queued, NOT rejected
    s.release(r1)
    assert s.admit() == [r2]                    # pool freed -> admits


def test_grow_preempts_youngest_and_readmits():
    s = _paged_sched(slots=2, max_seq=64, bs=8, nblocks=8)
    old = Request(0, prompt=[1] * 24, max_new_tokens=20)  # 3 blocks
    young = Request(1, prompt=[1] * 24, max_new_tokens=20)
    s.submit(old), s.submit(young)
    assert len(s.admit()) == 2
    assert s.allocator.n_free == 2
    # old wants 5 more blocks: must evict young
    new, preempted = s.ensure_capacity(old, 64)
    assert preempted == [young]
    assert young.state == RequestState.QUEUED and young.slot is None
    assert young.block_ids == [] and young.preemptions == 1
    assert len(old.block_ids) == 8
    assert s.queue[0] is young                   # front of queue: readmits first
    # shrink old back; young readmits into the freed budget
    s.shrink_to(old, 24)
    assert len(old.block_ids) == 3
    young.output = [5, 7]                        # emitted before preemption
    assert young.prefill_tokens() == [1] * 24 + [5]
    assert s.admit() == [young]
    assert len(young.block_ids) == 4             # 25-token recompute prefix


def test_oversize_is_rejected_not_silently_dropped():
    s = _paged_sched(slots=1, max_seq=32)
    big = Request(0, prompt=[0] * 30, max_new_tokens=30)
    ok = Request(1, prompt=[0] * 4, max_new_tokens=4)
    s.submit(big), s.submit(ok)
    assert s.admit() == [ok]                     # big skipped, ok admitted
    assert big.state == RequestState.REJECTED
    assert big.finish_time is not None and big.done
    assert s.pop_rejected() == [big]
    assert s.pop_rejected() == []                # drained


# ---------------------------------------------------------------------------
# Cache primitives: paged write/gather == dense layout
# ---------------------------------------------------------------------------

def test_paged_write_gather_matches_dense_layout():
    rng = np.random.RandomState(3)
    b, t, kv, d, bs, maxb, n = 2, 5, 2, 8, 4, 4, 10
    w = maxb * bs
    positions = jnp.asarray(rng.randint(0, w - t, size=(b, 1))
                            + np.arange(t)[None])
    k_new = jnp.asarray(rng.randn(b, t, kv, d), jnp.float32)
    v_new = jnp.asarray(rng.randn(b, t, kv, d), jnp.float32)
    # dense ring at full width: slot = pos (identity)
    dk = jnp.zeros((b, w, kv, d)); dv = jnp.zeros((b, w, kv, d))
    dk, dv = cache_lib.write_kv(dk, dv, k_new, v_new, positions)
    dpos = cache_lib.write_pos(jnp.full((b, w), -1, jnp.int32), positions)
    # paged pool with disjoint scrambled tables
    perm = rng.permutation(n)
    table = jnp.asarray(np.stack([perm[:maxb], perm[maxb:2 * maxb]]))
    pk = jnp.zeros((n, bs, kv, d)); pv = jnp.zeros((n, bs, kv, d))
    ppos = jnp.full((n, bs), -1, jnp.int32)
    pk, pv = cache_lib.write_kv_paged(pk, pv, k_new, v_new, positions, table)
    ppos = cache_lib.write_pos_paged(ppos, positions, table)
    gk, gv = cache_lib.gather_paged_kv(pk, pv, table)
    gpos = cache_lib.gather_paged_pos(ppos, table)
    np.testing.assert_array_equal(np.asarray(gk), np.asarray(dk))
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(dv))
    np.testing.assert_array_equal(np.asarray(gpos), np.asarray(dpos))


def test_paged_write_respects_keep_mask_and_unallocated():
    b, t, kv, d, bs, maxb, n = 1, 4, 1, 4, 4, 3, 4
    table = jnp.asarray([[2, -1, -1]])           # only block 2 allocated
    positions = jnp.asarray([[2, 3, 4, 5]])      # 4,5 land in unalloc block 1
    keep = jnp.asarray([[True, False, True, True]])
    k_new = jnp.ones((b, t, kv, d)); v_new = jnp.ones((b, t, kv, d))
    pk = jnp.zeros((n, bs, kv, d)); pv = jnp.zeros((n, bs, kv, d))
    ppos = jnp.full((n, bs), -1, jnp.int32)
    pk, _ = cache_lib.write_kv_paged(pk, pv, k_new, v_new, positions, table,
                                     keep=keep)
    ppos = cache_lib.write_pos_paged(ppos, positions, table, keep=keep)
    # only position 2 (block 2, offset 2) survives: pos 3 is keep-masked,
    # 4/5 hit the unallocated block and are dropped
    got = np.asarray(ppos)
    assert got[2, 2] == 2
    assert (got.flatten() == -1).sum() == n * bs - 1
    assert np.asarray(pk)[2, 2].sum() == kv * d
    assert np.asarray(pk).sum() == kv * d


def test_reset_blocks_marks_empty():
    ppos = jnp.zeros((4, 2), jnp.int32)
    out = cache_lib.reset_blocks(ppos, [1, 3])
    np.testing.assert_array_equal(np.asarray(out),
                                  [[0, 0], [-1, -1], [0, 0], [-1, -1]])


def test_paged_cache_struct_shapes_and_guard():
    cfg = get_config("smollm-135m").reduced()
    c = cache_lib.paged_cache_struct(cfg, batch=3, max_len=64, num_blocks=8,
                                     block_size=16, dtype=jnp.float32)
    assert c["k"].shape == (cfg.num_layers, 8, 16,
                            cache_lib.eff_kv_heads(cfg),
                            cfg.resolved_head_dim)
    assert c["block_table"].shape == (3, 4)
    assert c["kv_pos"].shape == (8, 16)
    assert cache_lib.is_paged(c)
    with pytest.raises(AssertionError):          # pool < one max-len seq
        cache_lib.paged_cache_struct(cfg, 1, 256, num_blocks=2,
                                     block_size=16)
    ssm = get_config("mamba2-130m").reduced()
    assert not cache_lib.supports_paged(ssm)
    with pytest.raises(ValueError):
        cache_lib.paged_cache_struct(ssm, 1, 64, 8, 16)


# ---------------------------------------------------------------------------
# Paged Pallas kernel vs oracle (interpret mode)
# ---------------------------------------------------------------------------

PAGED_SHAPES = [
    # b, t, h, kv, d, n_blocks, bs, maxb, window
    (2, 1, 8, 2, 64, 12, 16, 4, None),      # plain decode, GQA 4x
    (3, 6, 8, 8, 64, 20, 16, 5, None),      # verify, MHA
    (2, 11, 12, 4, 128, 9, 8, 6, None),     # verify, SL_max+1 queries
    (2, 4, 4, 2, 32, 10, 16, 4, 24),        # sliding window
]


def _paged_attn_inputs(b, t, h, kv, d, n, bs, maxb, seed=0):
    rng = np.random.RandomState(seed)
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, t, h, d))
    pool_k = jax.random.normal(ks[1], (n, bs, kv, d))
    pool_v = jax.random.normal(ks[2], (n, bs, kv, d))
    table = np.full((b, maxb), -1, np.int32)
    kvp = np.full((n, bs), -1, np.int32)
    qpos = np.zeros((b, t), np.int32)
    perm = rng.permutation(n)
    c = 0
    for i in range(b):
        # ragged table lengths, leaving >= 1 pool block per remaining row
        avail = min(maxb, n - c - (b - 1 - i))
        nb = rng.randint(1, max(avail, 1) + 1)
        table[i, :nb] = perm[c:c + nb]
        c += nb
        # ragged sequence lengths; clamp so short tables stay valid (a
        # query past the allocated blocks just attends a partial history)
        ntok = rng.randint(t, max(nb * bs, t) + 1)
        for p in range(min(ntok, nb * bs)):
            kvp[table[i, p // bs], p % bs] = p
        qpos[i] = np.arange(ntok - t, ntok)
    return (q, pool_k, pool_v, jnp.asarray(table), jnp.asarray(qpos),
            jnp.asarray(kvp))


@pytest.mark.parametrize("shape", PAGED_SHAPES)
def test_paged_kernel_vs_oracle(shape):
    b, t, h, kv, d, n, bs, maxb, window = shape
    q, pk, pv, table, qpos, kvp = _paged_attn_inputs(b, t, h, kv, d, n, bs,
                                                     maxb, seed=b * 10 + t)
    out = paged_ragged_verify_attention(q, pk, pv, table, qpos, kvp,
                                        window=window, interpret=True)
    want = ref.paged_ragged_verify_attention_ref(q, pk, pv, table, qpos,
                                                 kvp, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=1e-4)


def test_paged_ref_matches_dense_ref_on_identity_table():
    """An identity block table makes the paged oracle degenerate to the
    dense ring oracle — the layout-independence anchor."""
    b, t, h, kv, d, bs, maxb = 2, 3, 4, 2, 32, 8, 4
    w = bs * maxb
    q, kb, vb, q_pos, kv_pos = None, None, None, None, None
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, t, h, d))
    kb = jax.random.normal(ks[1], (b, w, kv, d))
    vb = jax.random.normal(ks[2], (b, w, kv, d))
    lens = jnp.asarray([10, 25])
    q_pos = lens[:, None] + jnp.arange(t)[None]
    kv_pos = jnp.where(jnp.arange(w)[None] < (lens[:, None] + t),
                       jnp.arange(w)[None], -1)
    want = ref.ragged_verify_attention_ref(q, kb, vb, q_pos, kv_pos)
    # batch-strided pool: seq i owns blocks [i*maxb, (i+1)*maxb)
    pool_k = kb.reshape(b * maxb, bs, kv, d)
    pool_v = vb.reshape(b * maxb, bs, kv, d)
    ppos = kv_pos.reshape(b * maxb, bs)
    table = jnp.arange(b * maxb, dtype=jnp.int32).reshape(b, maxb)
    got = ref.paged_ragged_verify_attention_ref(q, pool_k, pool_v, table,
                                                q_pos, ppos)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# Engine: paged == dense, byte for byte
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_pair():
    cfg = get_config("smollm-135m").reduced()
    pt = init_params(model_specs(cfg), jax.random.PRNGKey(1), jnp.float32)
    noise = init_params(model_specs(cfg), jax.random.PRNGKey(7), jnp.float32)
    pd = jax.tree_util.tree_map(lambda a, b: a + 0.05 * b, pt, noise)
    return cfg, pt, pd


def _run_engine(cfg, pt, pd, policy, *, paged, prompts, max_new=16,
                temperature=0.0, nblocks=None, bs=16, batch=2,
                max_seq=128, seed=0):
    spec = SpecDecodeConfig(policy=policy, temperature=temperature)
    sv = ServingConfig(max_batch_size=batch, max_seq_len=max_seq,
                       paged_kv=paged, kv_block_size=bs,
                       num_kv_blocks=nblocks)
    eng = ServingEngine(pt, cfg, pd, cfg, spec, sv, seed=seed)
    reqs = [Request(i, prompt=p, max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    metrics = eng.run(reqs)
    return [r.output for r in reqs], metrics, eng


@pytest.mark.parametrize("policy", available_policies())
def test_paged_engine_exactness_all_policies(small_pair, policy):
    """The tentpole guarantee: byte-identical token streams from the
    dense and paged engines for every registered policy at a fixed seed
    (the block pool is a *layout*, never a semantics, change)."""
    cfg, pt, pd = small_pair
    rng = np.random.RandomState(11)
    prompts = [rng.randint(0, cfg.vocab_size, size=n).tolist()
               for n in (7, 12, 5)]
    dense, _, _ = _run_engine(cfg, pt, pd, policy, paged=False,
                              prompts=prompts)
    paged, mp, _ = _run_engine(cfg, pt, pd, policy, paged=True,
                               prompts=prompts)
    assert dense == paged, policy
    assert mp["kv_blocks_peak"] <= mp["kv_pool_blocks"]


def test_paged_engine_exactness_hybrid_family():
    """Hybrid exercises every bespoke paged path at once: n_attn-sliced
    pools, dense per-slot recurrent state riding alongside, the engine's
    recurrent-row scatter at prefill, and commit's masked re-advance over
    a paged cache."""
    cfg = get_config("recurrentgemma-2b").reduced()
    assert cfg.family == "hybrid"
    pt = init_params(model_specs(cfg), jax.random.PRNGKey(1), jnp.float32)
    noise = init_params(model_specs(cfg), jax.random.PRNGKey(9), jnp.float32)
    pd = jax.tree_util.tree_map(lambda a, b: a + 0.05 * b, pt, noise)
    prompts = [list(range(3, 11)), list(range(5, 12))]
    dense, _, _ = _run_engine(cfg, pt, pd, "dsde", paged=False,
                              prompts=prompts, max_new=12)
    paged, _, _ = _run_engine(cfg, pt, pd, "dsde", paged=True,
                              prompts=prompts, max_new=12)
    assert dense == paged


def test_paged_engine_exact_under_preemption(small_pair):
    """Pool pressure forces evict-and-requeue; recompute-on-readmit must
    reproduce the dense outputs token for token (greedy)."""
    cfg, pt, pd = small_pair
    rng = np.random.RandomState(5)
    prompts = [rng.randint(0, cfg.vocab_size, size=n).tolist()
               for n in (30, 25, 20)]
    dense, _, _ = _run_engine(cfg, pt, pd, "dsde", paged=False,
                              prompts=prompts, max_new=40, bs=8)
    # 16 blocks x 8 = 128 pool tokens shared by two live sequences whose
    # worst case is 30 + 40 + 11 = 81 each -> preemption must trigger
    paged, m, _ = _run_engine(cfg, pt, pd, "dsde", paged=True,
                              prompts=prompts, max_new=40, bs=8, nblocks=16)
    assert m["preemptions"] >= 1
    assert m["requests_finished"] == 3
    assert dense == paged


def test_paged_round_log_telemetry(small_pair):
    cfg, pt, pd = small_pair
    prompts = [list(range(1, 9))]
    _, m, eng = _run_engine(cfg, pt, pd, "dsde", paged=True, prompts=prompts)
    assert eng.round_log
    for rec in eng.round_log:
        assert rec["kv_blocks_in_use"] >= 0
        assert 0.0 <= rec["kv_pool_utilization"] <= 1.0
        assert rec["wall_s"] > 0.0
    assert m["kv_blocks_peak"] >= 1


def test_device_tables_mirror_allocator_every_round(small_pair):
    """Regression: post-round shrink must drop freed entries from the
    *device* block-table row immediately — a stale entry would gather a
    reallocated block's new owner's KV into this sequence's attention.
    Invariant: after every step, each running request's device row is
    exactly its host block_ids, and no block has two owners."""
    cfg, pt, pd = small_pair
    rng = np.random.RandomState(7)
    spec = SpecDecodeConfig(policy="dsde", temperature=0.0)
    sv = ServingConfig(max_batch_size=3, max_seq_len=128, paged_kv=True,
                       kv_block_size=4)
    eng = ServingEngine(pt, cfg, pd, cfg, spec, sv, seed=0)
    for i in range(6):
        eng.submit(Request(i, prompt=rng.randint(
            0, cfg.vocab_size, size=rng.randint(5, 25)).tolist(),
            max_new_tokens=int(rng.randint(8, 24))))
    freed_events = []
    orig_shrink = eng.scheduler.shrink_to

    def shrink_spy(req, n_tokens):
        freed = orig_shrink(req, n_tokens)
        if freed:
            freed_events.append(len(freed))
        return freed

    eng.scheduler.shrink_to = shrink_spy
    while eng.scheduler.has_work():
        eng.step()
        bt = np.asarray(eng.state.target_cache["block_table"])
        owned = []
        for req in eng.scheduler.running:
            row = bt[req.slot]
            dev_ids = row[row >= 0].tolist()
            assert dev_ids == req.block_ids, (req.request_id, dev_ids,
                                              req.block_ids)
            owned += req.block_ids
        assert len(owned) == len(set(owned))     # single ownership
    assert freed_events                           # the scenario occurred


def test_admission_refreshes_scheduler_sl_mirror(small_pair):
    """Regression: block planning for a fresh request's first round must
    use its initial SL, not the slot's previous occupant's last
    prediction (a stale low SL under-allocates and drops accepted KV)."""
    cfg, pt, pd = small_pair
    spec = SpecDecodeConfig(policy="dsde", temperature=0.0)
    sv = ServingConfig(max_batch_size=2, max_seq_len=128, paged_kv=True)
    eng = ServingEngine(pt, cfg, pd, cfg, spec, sv, seed=0)
    eng.scheduler.sl_pred[:] = 1                  # stale previous-occupant SL
    eng.submit(Request(0, prompt=list(range(1, 9)), max_new_tokens=8))
    eng._admit()
    slot = eng.scheduler.running[0].slot
    assert eng.scheduler.sl_pred[slot] == eng.policy.initial_sl_value()


def test_rejected_requests_surface_in_summary(small_pair):
    cfg, pt, pd = small_pair
    big = [0] * 120                               # 120 + 16 + 11 > 128
    ok = list(range(1, 7))
    for paged in (False, True):
        _, m, _ = _run_engine(cfg, pt, pd, "dsde", paged=paged,
                              prompts=[big, ok], max_new=16)
        assert m["requests_rejected"] == 1
        assert m["requests_finished"] == 1


def test_paged_rejects_unsupported_family():
    cfg = get_config("mamba2-130m").reduced()
    pt = init_params(model_specs(cfg), jax.random.PRNGKey(1), jnp.float32)
    with pytest.raises(ValueError):
        ServingEngine(pt, cfg, pt, cfg, SpecDecodeConfig(),
                      ServingConfig(max_batch_size=1, max_seq_len=64,
                                    paged_kv=True))
