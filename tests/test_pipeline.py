"""Plan → dispatch → collect pipeline and device-side termination.

The headline guarantee: the pipelined schedule (round N+1 enqueued
before round N is reconciled, host one round behind) emits byte-
identical greedy token streams to the synchronous engine for EVERY
registered policy, on both KV layouts, including under forced
preemption.  Plus the termination edge cases that device-side ``done``
tracking must get right: EOS exactly on a round boundary, a token
budget exhausted mid-round (truncate, never over-emit), and a finished
slot re-admitted while its last round is still in the pipelined window.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.config import ServingConfig, SpecDecodeConfig
from repro.core.policies import available_policies
from repro.models.module import init_params
from repro.models.transformer import forward, model_specs
from repro.serving.engine import ServingEngine
from repro.serving.request import Request, RequestState

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def small_pair():
    cfg = get_config("smollm-135m").reduced()
    pt = init_params(model_specs(cfg), jax.random.PRNGKey(1), jnp.float32)
    noise = init_params(model_specs(cfg), jax.random.PRNGKey(7), jnp.float32)
    pd = jax.tree_util.tree_map(lambda a, b: a + 0.05 * b, pt, noise)
    return cfg, pt, pd


def greedy_rollout(params, cfg, prompt, n):
    toks = list(prompt)
    for _ in range(n):
        logits, _, _ = forward(params, cfg,
                               jnp.asarray([toks], jnp.int32), mode="train")
        toks.append(int(jnp.argmax(logits[0, -1, :cfg.vocab_size])))
    return toks[len(prompt):]


def _run(cfg, pt, pd, policy, *, pipelined, prompts, paged=False,
         max_new=16, eos=None, batch=2, max_seq=128, bs=16, nblocks=None,
         seed=0):
    spec = SpecDecodeConfig(policy=policy, temperature=0.0)
    sv = ServingConfig(max_batch_size=batch, max_seq_len=max_seq,
                       paged_kv=paged, kv_block_size=bs,
                       num_kv_blocks=nblocks, pipelined=pipelined)
    eng = ServingEngine(pt, cfg, pd, cfg, spec, sv, seed=seed)
    reqs = [Request(i, prompt=p, max_new_tokens=max_new, eos_token_id=eos)
            for i, p in enumerate(prompts)]
    metrics = eng.run(reqs)
    return [r.output for r in reqs], metrics, reqs, eng


# ---------------------------------------------------------------------------
# Byte-identity: pipelined == sync for every policy, both layouts
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
@pytest.mark.parametrize("policy", available_policies())
def test_pipelined_matches_sync_every_policy(small_pair, policy, paged):
    cfg, pt, pd = small_pair
    rng = np.random.RandomState(11)
    prompts = [rng.randint(0, cfg.vocab_size, size=n).tolist()
               for n in (7, 12, 5)]
    sync, ms, _, _ = _run(cfg, pt, pd, policy, pipelined=False,
                          prompts=prompts, paged=paged)
    pipe, mp, reqs, _ = _run(cfg, pt, pd, policy, pipelined=True,
                             prompts=prompts, paged=paged)
    assert sync == pipe, policy
    assert mp["requests_finished"] == len(prompts)
    assert all(r.state == RequestState.FINISHED for r in reqs)
    assert ms["tokens_emitted"] == mp["tokens_emitted"]


@pytest.mark.parametrize("drafter", ["model", "ngram", "self"])
@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
@pytest.mark.parametrize("policy", ["static", "dsde"])
def test_pipelined_matches_sync_at_temperature(small_pair, policy, paged,
                                               drafter):
    """Identity-threaded RNG (DESIGN.md §7): at temperature 1.0 the
    sampled token streams are ALSO byte-identical between the sync and
    pipelined schedules — every draw is keyed by (request seed, the
    request's own round ordinal, purpose, position), never by host
    dispatch order, batch composition, or bucket width; stochastic
    pipelined rounds dispatch at the policy's max bucket so a stale
    bucket pick can never clip a proposal window.  Covers slot reuse
    (3 requests, 2 slots) for both KV layouts and a model-free
    drafter."""
    cfg, pt, pd = small_pair
    rng = np.random.RandomState(23)
    prompts = [rng.randint(0, cfg.vocab_size, size=n).tolist()
               for n in (7, 12, 5)]
    model_free = drafter != "model"
    spec = SpecDecodeConfig(policy=policy, temperature=1.0, drafter=drafter)
    outs = {}
    for pipelined in (False, True):
        sv = ServingConfig(max_batch_size=2, max_seq_len=128,
                           paged_kv=paged, kv_block_size=16,
                           pipelined=pipelined)
        eng = ServingEngine(pt, cfg, None if model_free else pd,
                            None if model_free else cfg, spec, sv, seed=3)
        reqs = [Request(i, prompt=p, max_new_tokens=10)
                for i, p in enumerate(prompts)]
        m = eng.run(reqs)
        assert m["requests_finished"] == len(prompts)
        outs[pipelined] = [r.output for r in reqs]
    assert outs[False] == outs[True], (policy, paged, drafter)


def test_pipelined_exact_under_forced_preemption(small_pair):
    """Pool pressure during the pipelined window: growth planned from
    stale mirrors must evict-and-requeue (never under-allocate), and
    recompute-on-readmit must reproduce the dense sync stream exactly —
    including the emitted tokens of the round the victim was still part
    of when it was evicted."""
    cfg, pt, pd = small_pair
    rng = np.random.RandomState(5)
    prompts = [rng.randint(0, cfg.vocab_size, size=n).tolist()
               for n in (30, 25, 20)]
    dense, _, _, _ = _run(cfg, pt, pd, "dsde", pipelined=False,
                          prompts=prompts, max_new=40, bs=8)
    pipe, m, _, _ = _run(cfg, pt, pd, "dsde", pipelined=True,
                         prompts=prompts, paged=True, max_new=40, bs=8,
                         nblocks=16)
    assert m["preemptions"] >= 1
    assert m["requests_finished"] == 3
    assert dense == pipe


# ---------------------------------------------------------------------------
# Device-side termination edge cases
# ---------------------------------------------------------------------------

def _round_boundaries(eng):
    """Cumulative emitted-token count after each round of a batch-1 run,
    offset by the prefill token (position 0 of the output)."""
    cum, out = 1, []
    for r in eng.round_log:
        cum += int(r["emitted"])
        out.append(cum)
    return out


def test_eos_exactly_on_round_boundary(small_pair):
    """An EOS that is the LAST emitted token of a round must finish the
    request without touching the next round's (already dispatched, in
    the pipelined case) work — and the streams must still match sync."""
    cfg, pt, pd = small_pair
    prompt = list(range(2, 10))
    base, _, _, eng = _run(cfg, pt, pd, "static", pipelined=False,
                           prompts=[prompt], max_new=32, batch=1)
    stream = base[0]
    # pick a round boundary whose token value does not occur earlier
    pick = None
    for cum in _round_boundaries(eng):
        p = cum - 1
        if 0 < p < len(stream) and stream[p] not in stream[:p]:
            pick = p
            break
    assert pick is not None, "no usable boundary in this rollout"
    eos = stream[pick]
    want = stream[:pick + 1]
    for pipelined in (False, True):
        got, _, reqs, _ = _run(cfg, pt, pd, "static", pipelined=pipelined,
                               prompts=[prompt], max_new=32, batch=1,
                               eos=eos)
        assert got[0] == want, pipelined
        assert reqs[0].state == RequestState.FINISHED


def test_max_new_tokens_truncates_mid_round(small_pair):
    """A budget that runs out mid-round with accepted tokens beyond it
    must truncate the emission at exactly max_new_tokens — the device
    must not over-emit even though the rejection sampler accepted
    more."""
    cfg, pt, pd = small_pair
    prompt = list(range(3, 11))
    base, _, _, eng = _run(cfg, pt, pd, "static", pipelined=False,
                           prompts=[prompt], max_new=32, batch=1)
    stream = base[0]
    bounds = _round_boundaries(eng)
    # a budget strictly inside a round that emitted >= 2 tokens
    pick = next((b - 1 for b, prev in zip(bounds, [1] + bounds)
                 if b - prev >= 2 and b - 1 > 1), None)
    assert pick is not None, "no multi-token round in this rollout"
    for pipelined in (False, True):
        got, m, reqs, _ = _run(cfg, pt, pd, "static", pipelined=pipelined,
                               prompts=[prompt], max_new=pick, batch=1)
        assert len(got[0]) == pick, pipelined       # never over-emits
        assert got[0] == stream[:pick]
        assert reqs[0].state == RequestState.FINISHED
        assert m["tokens_emitted"] == pick


def test_finished_slot_readmitted_in_pipelined_window(small_pair):
    """More requests than slots with tiny budgets: every finish frees a
    slot that is re-admitted while the trailing round — which still
    carries the finished request's (device-dead) row — is in flight.
    The new occupant must start cleanly (fresh done/budget/EOS rows) and
    the whole stream set must match the synchronous engine."""
    cfg, pt, pd = small_pair
    rng = np.random.RandomState(2)
    prompts = [rng.randint(0, cfg.vocab_size, size=6).tolist()
               for _ in range(6)]
    sync, ms, _, _ = _run(cfg, pt, pd, "dsde", pipelined=False,
                          prompts=prompts, max_new=5, batch=2)
    pipe, mp, reqs, _ = _run(cfg, pt, pd, "dsde", pipelined=True,
                             prompts=prompts, max_new=5, batch=2)
    assert sync == pipe
    assert mp["requests_finished"] == 6
    assert all(len(r.output) == 5 for r in reqs)
    # the pipelined schedule re-used both slots repeatedly
    assert ms["rounds"] >= 3 and mp["rounds"] >= ms["rounds"]


def test_preempted_finished_at_first_token_never_readmitted(small_pair):
    """Regression (zombie requeue): a request that FINISHES at its
    prefill-sampled first token but is preempted before that token is
    reconciled must be dropped from the requeue at reconciliation —
    releasing it would no-op on the empty slot, and the FINISHED request
    would be readmitted as a permanently-dead device row, hanging
    ``run()``.  Pool sized so the older request's first growth (which
    carries the in-flight staleness slack) evicts the young 1-token
    request exactly one plan after both were admitted together."""
    cfg, pt, pd = small_pair
    a = Request(0, prompt=list(range(1, 102)), max_new_tokens=12)  # 7 blocks
    b = Request(1, prompt=list(range(1, 9)), max_new_tokens=1)     # 1 block
    first_b = greedy_rollout(pt, cfg, b.prompt, 1)
    spec = SpecDecodeConfig(policy="dsde", temperature=0.0)
    sv = ServingConfig(max_batch_size=2, max_seq_len=128, paged_kv=True,
                       kv_block_size=16, num_kv_blocks=8, pipelined=True)
    eng = ServingEngine(pt, cfg, pd, cfg, spec, sv, seed=0)
    m = eng.run([a, b], max_rounds=40)      # bounded: a hang would loop
    assert b.preemptions >= 1               # the scenario actually occurred
    assert m["requests_finished"] == 2
    assert b.state == RequestState.FINISHED and b.output == first_b
    assert a.state == RequestState.FINISHED and len(a.output) == 12
    assert not eng.scheduler.has_work()


def test_eos_as_first_token_finishes_without_host_sync(small_pair):
    """A prefill-sampled first token that is already EOS (or a 1-token
    budget) must terminate device-side: the pipelined engine dispatches
    a round containing the slot before the host ever sees the token."""
    cfg, pt, pd = small_pair
    prompt = list(range(2, 10))
    first = greedy_rollout(pt, cfg, prompt, 1)[0]
    for pipelined in (False, True):
        got, _, reqs, _ = _run(cfg, pt, pd, "static", pipelined=pipelined,
                               prompts=[prompt], max_new=32, batch=1,
                               eos=first)
        assert got[0] == [first], pipelined
        assert reqs[0].state == RequestState.FINISHED
    for pipelined in (False, True):
        got, _, reqs, _ = _run(cfg, pt, pd, "static", pipelined=pipelined,
                               prompts=[prompt], max_new=1, batch=1)
        assert got[0] == [first], pipelined
        assert reqs[0].state == RequestState.FINISHED


# ---------------------------------------------------------------------------
# Accounting: round log masking, serving metrics, batched prefill
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pipelined", [False, True], ids=["sync", "pipe"])
def test_round_log_accounting_consistent(small_pair, pipelined):
    """emitted / accepted / proposed are all masked by the same live-row
    set, so the whole-run identities hold exactly: every emitted token is
    either a prefill first token or counted in some round's ``emitted``,
    and greedy emission is accepted + one bonus per live row."""
    cfg, pt, pd = small_pair
    rng = np.random.RandomState(4)
    prompts = [rng.randint(0, cfg.vocab_size, size=8).tolist()
               for _ in range(4)]
    _, m, reqs, eng = _run(cfg, pt, pd, "dsde", pipelined=pipelined,
                           prompts=prompts, max_new=12, batch=2)
    per_round = [r["emitted"] for r in eng.round_log]
    assert m["tokens_emitted"] == sum(per_round) + len(reqs)
    for r in eng.round_log:
        assert r["accepted"] <= r["proposed"]
        # emitted = accepted + (one bonus per live row), minus any
        # device-side EOS/budget truncation — never more
        assert r["emitted"] <= r["accepted"] + len(prompts)
        assert r["host_blocked_s"] >= 0.0
        assert r["wall_s"] > 0.0


@pytest.mark.parametrize("pipelined", [False, True], ids=["sync", "pipe"])
def test_serving_metrics_ttft_and_queue_wait(small_pair, pipelined):
    cfg, pt, pd = small_pair
    rng = np.random.RandomState(9)
    prompts = [rng.randint(0, cfg.vocab_size, size=6).tolist()
               for _ in range(5)]
    _, m, reqs, _ = _run(cfg, pt, pd, "dsde", pipelined=pipelined,
                         prompts=prompts, max_new=8, batch=2)
    assert np.isfinite(m["ttft_mean_s"]) and m["ttft_mean_s"] >= 0.0
    assert np.isfinite(m["ttft_p95_s"]) and m["ttft_p95_s"] >= m["ttft_mean_s"] * 0.5
    assert np.isfinite(m["queue_wait_mean_s"]) and m["queue_wait_mean_s"] >= 0.0
    assert m["host_blocked_s"] >= 0.0
    for r in reqs:
        assert r.admit_time is not None and r.admit_time >= r.arrival_time
        assert r.first_dispatch_time is not None
        assert r.first_token_time is not None
        # the host observes the first token at reconciliation, never
        # before the prefill that produced it was dispatched
        assert r.first_token_time >= r.first_dispatch_time
        assert r.ttft() >= r.queue_wait()


@pytest.mark.parametrize("drafter,programs", [("model", 4), ("ngram", 2)],
                         ids=["model", "ngram"])
@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
def test_batched_prefill_one_program_per_bucket(small_pair, monkeypatch,
                                                paged, drafter, programs):
    """Requests admitted together that share a prompt bucket prefill in
    ONE multi-row program per model (2 jit calls per group with a model
    drafter — target + draft — not 2 per request; distinct buckets form
    distinct groups).  Model-free drafters skip the draft prefill
    program entirely: 1 call per group."""
    import repro.core.prefill as prefill_mod
    cfg, pt, pd = small_pair
    calls = []
    name = "prefill_paged_rows" if paged else "prefill_rows"
    orig = getattr(prefill_mod, name)

    def spy(*args, **kw):
        calls.append(1)
        return orig(*args, **kw)

    monkeypatch.setattr(prefill_mod, name, spy)
    spec = SpecDecodeConfig(policy="static", temperature=0.0,
                            drafter=drafter)
    sv = ServingConfig(max_batch_size=4, max_seq_len=128, paged_kv=paged,
                       kv_block_size=16)
    model_free = drafter != "model"
    eng = ServingEngine(pt, cfg, None if model_free else pd,
                        None if model_free else cfg, spec, sv, seed=0)
    # three same-bucket prompts (<=16 tokens) + one bucket-64 prompt
    for i, n in enumerate((5, 9, 12, 40)):
        eng.submit(Request(i, prompt=list(range(1, n + 1)),
                           max_new_tokens=4))
    eng.step()
    assert sum(calls) == programs   # 2 buckets x models prefilled
    while eng.scheduler.has_work():
        eng.step()


def test_pipelined_step_api_still_synchronous(small_pair):
    """step() stays the lockstep entry point even on an engine whose
    config enables pipelining — drivers that single-step (benchmarks,
    tests) keep exact sync semantics."""
    cfg, pt, pd = small_pair
    prompt = list(range(1, 9))
    ref = greedy_rollout(pt, cfg, prompt, 8)
    spec = SpecDecodeConfig(policy="static", temperature=0.0)
    sv = ServingConfig(max_batch_size=1, max_seq_len=128, pipelined=True)
    eng = ServingEngine(pt, cfg, pd, cfg, spec, sv, seed=0)
    req = Request(0, prompt=prompt, max_new_tokens=8)
    eng.submit(req)
    while eng.scheduler.has_work():
        eng.step()
    assert req.output == ref
