"""Tests for the pluggable speculation-policy API (repro/core/policies).

Covers: registry round-trips, per-policy observe/predict state-shape
invariants, jit-compatibility (no recompilation across rounds at a fixed
(policy, K) bucket), scheduler lookahead routing, and an engine smoke
test per registered policy.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import spec_decode as sd
from repro.core.config import ServingConfig, SpecDecodeConfig
from repro.core.drafters import build_drafter
from repro.core.policies import (GoodputPolicy, HostRoundContext,
                                 PolicyObservation, SpecPolicy,
                                 available_policies, build_policy, register)
from repro.models.module import init_params
from repro.models.transformer import forward, model_specs
from repro.serving.engine import ServingEngine
from repro.serving.request import Request
from repro.serving.scheduler import LookaheadScheduler

jax.config.update("jax_platform_name", "cpu")

KEY = jax.random.PRNGKey(0)
ALL_POLICIES = ("adaedl", "autoregressive", "dsde", "goodput", "slo",
                "static")


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_registry_lists_builtin_policies():
    assert set(ALL_POLICIES) <= set(available_policies())


@pytest.mark.parametrize("name", ALL_POLICIES)
def test_build_policy_round_trip(name):
    spec = SpecDecodeConfig(policy=name)
    pol = build_policy(spec)
    assert isinstance(pol, SpecPolicy)
    assert pol.spec.policy == name
    # frozen + hashable: usable inside a jit static argument
    assert hash(pol) == hash(build_policy(spec))
    assert pol == build_policy(spec)


def test_build_policy_unknown_name_raises():
    with pytest.raises(KeyError, match="registered"):
        build_policy(SpecDecodeConfig(policy="nope"))


def test_register_custom_policy():
    @register("_test_fixed3")
    @dataclasses.dataclass(frozen=True)
    class Fixed3(SpecPolicy):
        def initial_sl_value(self):
            return 3

        def predict(self, state, active=None):
            return jnp.full((active.shape[0],), 3, jnp.int32), state, {}

    try:
        pol = build_policy(SpecDecodeConfig(policy="_test_fixed3"))
        assert pol.initial_sl_value() == 3
        assert "_test_fixed3" in available_policies()
    finally:
        from repro.core.policies import base
        base._REGISTRY.pop("_test_fixed3", None)


# ---------------------------------------------------------------------------
# State-shape invariants
# ---------------------------------------------------------------------------

def _fake_obs(b, k, seed=0):
    rng = np.random.RandomState(seed)
    prop = rng.randint(0, k + 1, size=b).astype(np.int32)
    valid = np.arange(k)[None, :] < prop[:, None]
    acc = np.minimum(rng.randint(0, k + 1, size=b), prop).astype(np.int32)
    return PolicyObservation(
        kld=jnp.asarray(rng.rand(b, k).astype(np.float32)),
        proposed_valid=jnp.asarray(valid),
        num_accepted=jnp.asarray(acc),
        num_proposed=jnp.asarray(prop),
        active=jnp.ones((b,), bool))


@pytest.mark.parametrize("name", ALL_POLICIES)
def test_observe_predict_state_invariants(name):
    b, k = 4, 5
    spec = SpecDecodeConfig(policy=name)
    pol = build_policy(spec)
    state = pol.init_state(b)
    struct0 = jax.tree_util.tree_structure(state)
    shapes0 = [l.shape for l in jax.tree_util.tree_leaves(state)]

    sl0 = pol.initial_sl(b)
    assert sl0.shape == (b,) and sl0.dtype == jnp.int32

    state = pol.observe(state, _fake_obs(b, k))
    sl, state, tel = pol.predict(state, jnp.ones((b,), bool))

    # state keeps its pytree structure and leaf shapes across the cycle
    assert jax.tree_util.tree_structure(state) == struct0
    assert [l.shape for l in jax.tree_util.tree_leaves(state)] == shapes0
    # prediction is a well-formed per-sequence SL vector
    assert sl.shape == (b,) and sl.dtype == jnp.int32
    assert bool((sl >= 0).all()) and bool((sl <= spec.sl_max).all())
    assert isinstance(tel, dict)


@pytest.mark.parametrize("name", ALL_POLICIES)
def test_reset_rows_restores_fresh_state(name):
    b, k = 3, 4
    pol = build_policy(SpecDecodeConfig(policy=name))
    state = pol.observe(pol.init_state(b), _fake_obs(b, k, seed=3))
    rows = jnp.array([True, False, True])
    reset = pol.reset_rows(state, rows)
    fresh = pol.init_state(b)
    for r, s, f in zip(jax.tree_util.tree_leaves(reset),
                       jax.tree_util.tree_leaves(state),
                       jax.tree_util.tree_leaves(fresh)):
        np.testing.assert_array_equal(np.asarray(r[0]), np.asarray(f[0]))
        np.testing.assert_array_equal(np.asarray(r[1]), np.asarray(s[1]))


# ---------------------------------------------------------------------------
# Goodput controller behaviour
# ---------------------------------------------------------------------------

def test_goodput_sl_monotone_in_acceptance():
    pol = build_policy(SpecDecodeConfig(policy="goodput", use_sl_cap=False))
    state = pol.init_state(3)
    state = state._replace(acc_ema=jnp.array([0.05, 0.5, 0.95]))
    sl, _, _ = pol.predict(state, jnp.ones((3,), bool))
    sl = np.asarray(sl)
    assert sl[0] <= sl[1] <= sl[2]
    assert sl[0] == pol.spec.sl_min       # hopeless draft -> floor
    assert sl[2] > sl[0]                  # great draft -> deeper speculation


def test_goodput_ema_update():
    spec = SpecDecodeConfig(policy="goodput", goodput_ema=0.5,
                            goodput_init_acc=0.8)
    pol = build_policy(spec)
    state = pol.init_state(2)
    obs = PolicyObservation(
        kld=jnp.zeros((2, 4), jnp.float32),
        proposed_valid=jnp.ones((2, 4), bool),
        num_accepted=jnp.array([4, 0], jnp.int32),
        num_proposed=jnp.array([4, 0], jnp.int32),   # seq1 proposed nothing
        active=jnp.ones((2,), bool))
    state = pol.observe(state, obs)
    # seq0: 0.5*0.8 + 0.5*1.0 = 0.9; seq1 unchanged (nothing proposed)
    assert float(state.acc_ema[0]) == pytest.approx(0.9)
    assert float(state.acc_ema[1]) == pytest.approx(0.8)
    assert int(state.obs_count[0]) == 1 and int(state.obs_count[1]) == 0


def test_goodput_cost_sensitivity():
    """A more expensive draft step should never raise the chosen SL."""
    cheap = GoodputPolicy(SpecDecodeConfig(policy="goodput",
                                           goodput_draft_cost=0.01,
                                           use_sl_cap=False))
    dear = GoodputPolicy(SpecDecodeConfig(policy="goodput",
                                          goodput_draft_cost=0.5,
                                          use_sl_cap=False))
    acc = jnp.array([0.3, 0.6, 0.9])
    sl_cheap, _, _ = cheap.predict(
        cheap.init_state(3)._replace(acc_ema=acc), jnp.ones((3,), bool))
    sl_dear, _, _ = dear.predict(
        dear.init_state(3)._replace(acc_ema=acc), jnp.ones((3,), bool))
    assert np.all(np.asarray(sl_dear) <= np.asarray(sl_cheap))


# ---------------------------------------------------------------------------
# Host-side hooks: pick_bucket / lookahead / scheduler routing
# ---------------------------------------------------------------------------

def test_pick_bucket_per_policy():
    sl = np.array([2, 7, 4])

    def ctx(act):
        return HostRoundContext.from_arrays(sl, np.asarray(act))

    dsde = build_policy(SpecDecodeConfig(policy="dsde", sl_min=2))
    assert dsde.pick_bucket(ctx([True, True, True])) == 7
    assert dsde.pick_bucket(ctx([True, False, True])) == 4
    assert build_policy(SpecDecodeConfig(
        policy="autoregressive")).pick_bucket(ctx([True, True, True])) == 0


def test_positional_shim_back_compat():
    """One-release shim: the legacy positional (sl_next, active) form
    still answers correctly but warns; the context form is silent."""
    pol = build_policy(SpecDecodeConfig(policy="dsde", sl_min=2))
    sl = np.array([2, 7, 4])
    act = np.array([True, True, True])
    with pytest.warns(DeprecationWarning, match="HostRoundContext"):
        k = pol.pick_bucket(sl, act)  # speclint: disable=JX008 (shim test)
    assert k == 7
    with pytest.warns(DeprecationWarning, match="HostRoundContext"):
        la = pol.lookahead(sl)  # speclint: disable=JX008 (shim test)
    np.testing.assert_array_equal(la, sl + 1)
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error", DeprecationWarning)
        ctx = HostRoundContext.from_arrays(sl, act)
        assert pol.pick_bucket(ctx) == 7
        np.testing.assert_array_equal(pol.lookahead(ctx), sl + 1)
    with pytest.raises(TypeError, match="active"):
        # context + positional active is ambiguous and must raise
        pol.pick_bucket(ctx, act)  # speclint: disable=JX008 (shim test)


def test_policy_max_lookahead_bounds():
    assert build_policy(SpecDecodeConfig(
        policy="autoregressive")).max_lookahead() == 1
    assert build_policy(SpecDecodeConfig(
        policy="static", static_sl=4)).max_lookahead() == 5
    assert build_policy(SpecDecodeConfig(
        policy="adaedl", adaedl_base=7)).max_lookahead() == 8
    # dynamic policies can grow to sl_max — admission must reserve that
    assert build_policy(SpecDecodeConfig(
        policy="dsde", sl_max=10)).max_lookahead() == 11
    assert build_policy(SpecDecodeConfig(
        policy="goodput", sl_max=10)).max_lookahead() == 11


def test_scheduler_admission_uses_policy_lookahead():
    serving = ServingConfig(max_batch_size=2, max_seq_len=64)
    ar = LookaheadScheduler(serving, SpecDecodeConfig(policy="autoregressive"))
    dsde = LookaheadScheduler(serving, SpecDecodeConfig(policy="dsde"))
    # per-round planning view: policy lookahead over live SL predictions
    np.testing.assert_array_equal(ar.lookahead_slots(np.array([0, 0])),
                                  [1, 1])
    np.testing.assert_array_equal(dsde.lookahead_slots(np.array([5, 3])),
                                  [6, 4])
    # admission reserves the worst case: prompt 33 + max_new 30 -> 64
    # under AR (max_lookahead 1), 74 under dsde (max_lookahead 11)
    fits_ar = Request(0, prompt=[1] * 33, max_new_tokens=30)
    fits_dsde = Request(1, prompt=[1] * 33, max_new_tokens=30)
    ar.submit(fits_ar), dsde.submit(fits_dsde)
    assert len(ar.admit()) == 1
    assert len(dsde.admit()) == 0          # rejected: over KV budget


# ---------------------------------------------------------------------------
# jit-compatibility + engine smoke
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def pair():
    cfg = get_config("smollm-135m").reduced()
    pt = init_params(model_specs(cfg), jax.random.PRNGKey(1), jnp.float32)
    noise = init_params(model_specs(cfg), jax.random.PRNGKey(9), jnp.float32)
    pd = jax.tree_util.tree_map(lambda a, b: a + 0.04 * b, pt, noise)
    return cfg, pt, pd


def _ready_state(cfg, pt, pd, batch, prompt_len, spec):
    st = sd.init_round_state(cfg, cfg, spec, batch, 128, KEY)
    toks = jax.random.randint(KEY, (batch, prompt_len), 0, cfg.vocab_size)
    lt, tc, _ = forward(pt, cfg, toks, cache=st.target_cache, mode="prefill")
    _, dc, _ = forward(pd, cfg, toks, cache=st.draft_cache, mode="prefill")
    tc = dict(tc); tc["length"] = jnp.full((batch,), prompt_len, jnp.int32)
    dc = dict(dc); dc["length"] = jnp.full((batch,), prompt_len, jnp.int32)
    pend = jnp.argmax(lt[:, -1, :cfg.vocab_size], -1).astype(jnp.int32)
    return st._replace(target_cache=tc, draft_cache=dc, pending=pend)


@pytest.mark.parametrize("name", ALL_POLICIES)
def test_round_no_recompile_at_fixed_bucket(pair, name):
    """Consecutive rounds at the same (policy, K) reuse one XLA program."""
    cfg, pt, pd = pair
    spec = SpecDecodeConfig(policy=name, temperature=0.0)
    st = _ready_state(cfg, pt, pd, 2, 8, spec)
    active = jnp.ones((2,), bool)
    pol = build_policy(spec)
    k = max(4, pol.pick_bucket(HostRoundContext.from_arrays(
        np.asarray(st.sl_next), np.asarray(active))))
    if not pol.uses_draft():
        k = 0
    drafter = build_drafter(spec, cfg, cfg)
    st, _ = sd.spec_decode_round(pt, pd, cfg, drafter, spec, k, st, active)
    before = sd.spec_decode_round._cache_size()
    for _ in range(3):
        st, _ = sd.spec_decode_round(pt, pd, cfg, drafter, spec, k, st, active)
    assert sd.spec_decode_round._cache_size() == before


@pytest.mark.parametrize("name", ALL_POLICIES)
def test_engine_smoke_per_policy(pair, name):
    cfg, pt, pd = pair
    rng = np.random.RandomState(0)
    spec = SpecDecodeConfig(policy=name, temperature=0.0)
    eng = ServingEngine(pt, cfg, pd, cfg, spec,
                        ServingConfig(max_batch_size=2, max_seq_len=128))
    reqs = [Request(i, prompt=rng.randint(0, cfg.vocab_size, size=6).tolist(),
                    max_new_tokens=8) for i in range(3)]
    m = eng.run(reqs)
    assert m["requests_finished"] == 3
    assert all(len(r.output) == 8 for r in reqs)
    assert all(0 <= t < cfg.vocab_size for r in reqs for t in r.output)


def test_goodput_greedy_exactness(pair):
    """The new policy inherits spec decoding's exactness guarantee: greedy
    output equals the target's greedy rollout."""
    cfg, pt, pd = pair
    prompt = list(range(1, 9))
    n_new = 16
    toks = list(prompt)
    for _ in range(n_new):
        lg, _, _ = forward(pt, cfg, jnp.asarray([toks], jnp.int32),
                           mode="train")
        toks.append(int(jnp.argmax(lg[0, -1, :cfg.vocab_size])))
    ref = toks[len(prompt):]
    spec = SpecDecodeConfig(policy="goodput", temperature=0.0)
    eng = ServingEngine(pt, cfg, pd, cfg, spec,
                        ServingConfig(max_batch_size=1, max_seq_len=128))
    req = Request(0, prompt=prompt, max_new_tokens=n_new)
    eng.run([req])
    assert req.output == ref
