"""Prefix caching (DESIGN.md §12): refcounted copy-on-write block
sharing in the paged KV pool.

The headline guarantee mirrors test_paging's: prefix caching is a
*layout/work* optimization, never a semantics change — greedy token
streams from a cache-warm engine are byte-identical to the dense
(cache-free by construction) engine for every registered policy ×
drafter × schedule, including under forced preemption and forced
eviction.  Plus: allocator unit tests (refcount / hash index / LRU
eviction / COW fork), the coverage-aware admission boundary, and a
property test over random allocator traces."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # offline container: deterministic fallback
    from _hypothesis_shim import given, settings, strategies as st

from repro.configs import get_config
from repro.core.config import ServingConfig, SpecDecodeConfig
from repro.core.drafters import available_drafters
from repro.core.policies import available_policies
from repro.models import cache as cache_lib
from repro.models.module import init_params
from repro.models.transformer import model_specs
from repro.serving.engine import ServingEngine
from repro.serving.request import Request, RequestState
from repro.serving.scheduler import BlockAllocator, LookaheadScheduler

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# BlockAllocator: refcounts, hash index, LRU eviction, COW fork
# ---------------------------------------------------------------------------

def _register_chain(a, blocks, tokens):
    """Register ``blocks`` as the chain holding ``tokens`` (full blocks)."""
    h = None
    bs = a.block_size
    for i, b in enumerate(blocks):
        h = a.register(b, h, tuple(tokens[i * bs:(i + 1) * bs]))
    return h


def test_refcount_shared_blocks_survive_one_owner_freeing():
    a = BlockAllocator(num_blocks=8, block_size=4)
    blocks = a.alloc(2)
    _register_chain(a, blocks, list(range(8)))
    a.acquire(blocks)                      # second owner
    a.free(blocks)                         # first owner leaves
    assert all(a.refcount[b] == 1 for b in blocks)
    assert a.n_cached == 0                 # still referenced, not warm
    a.free(blocks)                         # last owner leaves
    assert a.n_cached == 2                 # registered -> warm, not free
    ids, h, covered = a.match_prefix(list(range(8)))
    assert ids == blocks and covered == 8  # still matchable
    a.acquire(ids)                         # revived from the warm list
    assert a.n_cached == 0 and all(a.refcount[b] == 1 for b in ids)


def test_unregistered_blocks_free_immediately():
    a = BlockAllocator(num_blocks=4, block_size=4)
    blocks = a.alloc(3)
    a.free(blocks)
    assert a.n_cached == 0 and a.n_free == 4


def test_match_prefix_walks_full_blocks_only():
    a = BlockAllocator(num_blocks=8, block_size=4)
    blocks = a.alloc(3)
    _register_chain(a, blocks[:2], list(range(8)))   # 2 full blocks cached
    a.free(blocks)
    ids, _, covered = a.match_prefix(list(range(11)))
    assert ids == blocks[:2] and covered == 8        # tail block never hashed
    ids, _, covered = a.match_prefix(list(range(6)))
    assert ids == blocks[:1] and covered == 4        # partial second block
    ids, _, covered = a.match_prefix([99] + list(range(1, 8)))
    assert ids == [] and covered == 0                # first-block mismatch


def test_match_verifies_stored_tokens_not_just_hashes():
    """A hash collision must degrade to a cache miss, never a false hit:
    the index match is confirmed against the stored token chunk."""
    a = BlockAllocator(num_blocks=4, block_size=2)
    blocks = a.alloc(1)
    h = a.register(blocks[0], None, (1, 2))
    a.free(blocks)
    # sabotage: alias a different chunk's hash onto the cached block
    a._index[BlockAllocator._chain_hash(None, (3, 4))] = blocks[0]
    ids, _, covered = a.match_prefix([3, 4])
    assert ids == [] and covered == 0
    assert a.match_prefix([1, 2])[0] == blocks       # true owner still hits


def test_lru_eviction_only_under_pressure_oldest_first():
    a = BlockAllocator(num_blocks=4, block_size=2)
    b1 = a.alloc(1); _register_chain(a, b1, [1, 2]); a.free(b1)
    b2 = a.alloc(1); _register_chain(a, b2, [3, 4]); a.free(b2)
    assert a.n_cached == 2 and a.evictions == 0
    got = a.alloc(2)                       # 2 truly-free remain: no eviction
    assert a.evictions == 0 and a.n_cached == 2
    got2 = a.alloc(1)                      # pressure: evict the LRU-oldest
    assert a.evictions == 1
    assert a.match_prefix([1, 2])[0] == []           # b1 gone
    assert a.match_prefix([3, 4])[0] == b2           # b2 survives
    assert a.alloc(2) is None              # 1 warm + 0 free < 2: unchanged
    a.free(got + got2)
    a.check_invariants()


def test_registration_is_first_writer_wins():
    a = BlockAllocator(num_blocks=4, block_size=2)
    b1 = a.alloc(1)
    b2 = a.alloc(1)
    h1 = a.register(b1[0], None, (5, 6))
    h2 = a.register(b2[0], None, (5, 6))   # duplicate content
    assert h1 == h2
    assert a.match_prefix([5, 6])[0] == b1           # index kept the first
    a.free(b1), a.free(b2)
    assert a.n_cached == 1                 # the losing copy freed for real
    a.check_invariants()


def test_fork_cow_allocates_then_releases_source():
    a = BlockAllocator(num_blocks=3, block_size=2)
    src = a.alloc(1)
    _register_chain(a, src, [7, 8])
    a.acquire(src)                         # a second sharer holds src
    dst = a.fork_cow(src[0])               # the sharer forks off a copy
    assert dst is not None and dst != src[0]
    assert a.refcount[src[0]] == 1 and a.refcount[dst] == 1
    a.free(src)                            # original owner leaves
    assert a.n_cached == 1                 # src stays warm + indexed
    assert a.match_prefix([7, 8])[0] == src
    a.check_invariants()


# ---------------------------------------------------------------------------
# Admission: coverage discount, COW plan, pin-before-alloc
# ---------------------------------------------------------------------------

def _cached_sched(slots=1, max_seq=128, bs=16, nblocks=None, max_la=3):
    sv = ServingConfig(max_batch_size=slots, max_seq_len=max_seq,
                       paged_kv=True, kv_block_size=bs,
                       num_kv_blocks=nblocks, prefix_caching=True)
    return LookaheadScheduler(sv, SpecDecodeConfig(policy="static",
                                                   static_sl=max_la - 1))


def _prime(s, prompt, emitted=0):
    """Admit + commit a request so its prompt blocks land in the index,
    then finish it (blocks drop to the warm list, still registered)."""
    req = Request(10_000 + s._admit_seq, prompt=list(prompt),
                  max_new_tokens=max(emitted, 1))
    s.submit(req)
    assert s.admit() == [req]
    req.cache_len = len(prompt) + emitted
    s.register_prefix(req)
    s.release(req)
    return req


def test_admission_fits_only_because_of_cache_coverage():
    """Satellite regression: the oversize check must charge only the
    UNCOVERED suffix.  pool = 7x16 = 112 < prompt + max_new + lookahead
    = 116 (8 blocks), so a cold pool rejects — but with 6 prompt blocks
    cached the residual ask is 2 blocks and the request must admit."""
    prompt = list(range(97))
    cold = _cached_sched(nblocks=7)
    r = Request(0, prompt=list(prompt), max_new_tokens=16)
    cold.submit(r)
    assert cold.admit() == []
    assert r.state == RequestState.REJECTED
    warm = _cached_sched(nblocks=7)
    _prime(warm, prompt)                   # registers 97//16 = 6 blocks
    r2 = Request(1, prompt=list(prompt), max_new_tokens=16)
    warm.submit(r2)
    assert warm.admit() == [r2]
    assert r2.prefill_start == 96 and len(r2.fresh_block_ids) == 1
    warm.allocator.check_invariants()


def test_full_aligned_hit_plans_exactly_one_cow_pair():
    prompt = list(range(32))               # exactly 2 blocks
    s = _cached_sched(nblocks=16)
    _prime(s, prompt)
    r = Request(1, prompt=list(prompt), max_new_tokens=8)
    s.submit(r)
    assert s.admit() == [r]
    # last shared block forks; its final position is recomputed
    assert r.prefill_start == 31
    assert len(r.cow_pairs) == 1
    src, dst = r.cow_pairs[0]
    assert dst in r.fresh_block_ids and src not in r.block_ids
    assert s.allocator.refcount[src] == 1  # pinned until the copy enqueues
    s.release_cow_sources(r)
    assert s.allocator.n_cached == 1       # src back on the warm list
    s.allocator.check_invariants()


def test_admission_pins_matched_blocks_before_allocating():
    """Regression: alloc() reclaims warm blocks under pressure — the
    blocks the admission just MATCHED must be pinned first or the
    allocator can evict part of its own hit."""
    s = _cached_sched(nblocks=8, max_seq=128)
    chain_a = list(range(64))              # 4 blocks, primed FIRST (LRU-oldest)
    chain_b = list(range(500, 532))        # 2 blocks
    _prime(s, chain_a)
    _prime(s, chain_b)
    # free: 2, warm: A(4) + B(2).  The request matches all of A and needs
    # 3 fresh blocks -> alloc must evict one warm block, and the
    # LRU-oldest warm blocks are exactly the matched A blocks: only the
    # admission-time pin diverts the eviction onto B.
    r = Request(1, prompt=chain_a + list(range(100, 133)), max_new_tokens=16)
    s.submit(r)
    assert s.admit() == [r]
    assert r.prefill_start == 64           # the hit survived allocation
    assert s.allocator.evictions == 1      # pressure landed on B instead
    assert s.allocator.match_prefix(chain_a)[2] == 64
    s.allocator.check_invariants()


def test_preempted_request_recovers_coverage_on_readmit():
    prompt = list(range(40))
    s = _cached_sched(slots=2, nblocks=16)
    _prime(s, prompt)
    r = Request(1, prompt=list(prompt), max_new_tokens=8)
    s.submit(r)
    assert s.admit() == [r]
    assert r.prefill_start == 32
    s.preempt(r)
    assert (r.prefill_start, r.cow_pairs, r.hashed_blocks) == (0, [], 0)
    assert s.admit() == [r]                # readmits with coverage again
    assert r.prefill_start == 32
    s.allocator.check_invariants()


# ---------------------------------------------------------------------------
# Property test: random admit/grow/shrink/preempt/evict traces
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 2 ** 31 - 1), min_size=4, max_size=80),
       st.integers(4, 12))
def test_allocator_trace_invariants(ops, num_blocks):
    """free + warm + (unique referenced) always partition the pool, no
    block is simultaneously free and referenced, and every warm block
    stays reachable from the hash index — across random interleavings of
    alloc, free, acquire, register, and COW forks."""
    a = BlockAllocator(num_blocks=num_blocks, block_size=2)
    owned = []                             # [(blocks, registered_upto)]
    token = 0
    for x in ops:
        op = x % 5
        if op == 0:                        # alloc (admit / grow)
            n = (x // 5) % (num_blocks + 1)
            got = a.alloc(n)
            if got is not None and n > 0:
                owned.append([got, 0])
        elif op == 1 and owned:            # free (finish / preempt / shrink)
            blocks, _ = owned.pop((x // 5) % len(owned))
            a.free(blocks)
        elif op == 2 and owned:            # register a prefix chunk
            ent = owned[(x // 5) % len(owned)]
            if ent[1] < len(ent[0]):
                b = ent[0][ent[1]]
                parent = a._meta[ent[0][ent[1] - 1]][2] if ent[1] else None
                a.register(b, parent, (token, token + 1))
                token += 2
                ent[1] += 1
        elif op == 3 and owned:            # acquire (cache-hit share)
            blocks = owned[(x // 5) % len(owned)][0]
            a.acquire(blocks)
            owned.append([list(blocks), 0])
        elif op == 4 and owned:            # COW fork of a shared block
            # fork_cow consumes the caller's reference on src: the
            # forker's table swaps src for the private dst, like the
            # engine's full-aligned-hit admission does
            ent = owned[(x // 5) % len(owned)]
            j = (x // 7) % len(ent[0])
            dst = a.fork_cow(ent[0][j])
            if dst is not None:
                ent[0][j] = dst
                ent[1] = min(ent[1], j)    # dst is private, unregistered
        a.check_invariants()
    for blocks, _ in owned:
        a.free(blocks)
    a.check_invariants()
    assert a.n_free == num_blocks          # nothing leaked


# ---------------------------------------------------------------------------
# Engine: warm == cold == dense, byte for byte
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_pair():
    cfg = get_config("smollm-135m").reduced()
    pt = init_params(model_specs(cfg), jax.random.PRNGKey(1), jnp.float32)
    noise = init_params(model_specs(cfg), jax.random.PRNGKey(7), jnp.float32)
    pd = jax.tree_util.tree_map(lambda a, b: a + 0.05 * b, pt, noise)
    return cfg, pt, pd


RNG = np.random.RandomState(11)
SHARED = RNG.randint(0, 1000, size=40).tolist()
# batch 1 seeds the cache; batch 2 hits it: a partial-hit continuation, a
# full block-aligned repeat (the COW path), and a cold outlier
BATCH1 = [SHARED + RNG.randint(0, 1000, size=6).tolist()]
BATCH2 = [SHARED + RNG.randint(0, 1000, size=5).tolist(),
          SHARED[:32],
          RNG.randint(0, 1000, size=9).tolist()]


def _run_batches(cfg, pt, pd, policy, drafter, *, paged, prefix_caching,
                 pipelined, max_new=10, nblocks=None, bs=16, batch=2,
                 max_seq=128, batches=(BATCH1, BATCH2), kv_quant="none"):
    spec = SpecDecodeConfig(policy=policy, temperature=0.0, drafter=drafter)
    sv = ServingConfig(max_batch_size=batch, max_seq_len=max_seq,
                       paged_kv=paged, kv_block_size=bs,
                       num_kv_blocks=nblocks, prefix_caching=prefix_caching,
                       pipelined=pipelined, kv_quant=kv_quant)
    model = drafter == "model"
    eng = ServingEngine(pt, cfg, pd if model else None,
                        cfg if model else None, spec, sv, seed=0)
    outs, reqs_all = [], []
    for j, batch_prompts in enumerate(batches):
        reqs = [Request(j * 100 + i, prompt=list(p), max_new_tokens=max_new)
                for i, p in enumerate(batch_prompts)]
        m = eng.run(reqs)
        outs += [r.output for r in reqs]
        reqs_all += reqs
    return outs, m, eng, reqs_all


@pytest.mark.parametrize("pipelined", [False, True],
                         ids=["sync", "pipelined"])
@pytest.mark.parametrize("drafter", available_drafters())
@pytest.mark.parametrize("policy", available_policies())
def test_warm_streams_match_dense_matrix(small_pair, policy, drafter,
                                         pipelined):
    """The exactness contract, full matrix: greedy streams from the
    cache-warm paged engine are byte-identical to the DENSE engine
    (cache-free by construction) for every policy x drafter x schedule,
    and the warm run really did share (hit blocks > 0)."""
    cfg, pt, pd = small_pair
    dense, _, _, _ = _run_batches(cfg, pt, pd, policy, drafter, paged=False,
                                  prefix_caching=False, pipelined=pipelined)
    warm, m, _, reqs = _run_batches(cfg, pt, pd, policy, drafter, paged=True,
                                    prefix_caching=True, pipelined=pipelined)
    assert dense == warm, (policy, drafter, pipelined)
    assert m["prefix_cache_hit_blocks"] > 0
    assert m["cow_copies"] >= 1            # BATCH2 includes the exact repeat
    assert 0.0 < m["prefix_cache_hit_rate"] <= 1.0
    # per-request attribution: the continuation hit, the outlier did not
    assert reqs[1].prefix_hit_rate() > 0.0
    assert reqs[3].prefix_hit_rate() == 0.0


def test_warm_exact_under_forced_preemption(small_pair):
    """Pool pressure + sharing: preemption fires, readmits recover their
    coverage from the cache, streams stay dense-identical."""
    cfg, pt, pd = small_pair
    pre = SHARED[:24]
    prompts = [pre + RNG.randint(0, 1000, size=n).tolist()
               for n in (6, 3, 1)]
    kw = dict(max_new=40, bs=8, batches=(prompts,))
    dense, _, _, _ = _run_batches(cfg, pt, pd, "dsde", "model", paged=False,
                                  prefix_caching=False, pipelined=False, **kw)
    for pipelined in (False, True):
        warm, m, _, _ = _run_batches(cfg, pt, pd, "dsde", "model",
                                     paged=True, prefix_caching=True,
                                     pipelined=pipelined, nblocks=16, **kw)
        assert m["preemptions"] >= 1, pipelined
        assert m["requests_finished"] == 3
        assert dense == warm, pipelined


def test_warm_exact_under_forced_eviction(small_pair):
    """Cache entries are reclaimed LRU-under-pressure; an evicted prefix
    degrades to a miss, never to corruption."""
    cfg, pt, pd = small_pair
    a = SHARED[:32]
    b = RNG.randint(0, 1000, size=97).tolist()       # 7 blocks: drains pool
    batches = ([list(a)], [list(b)], [list(a)])
    kw = dict(max_new=8, nblocks=8, batch=1, batches=batches)
    dense, _, _, _ = _run_batches(cfg, pt, pd, "dsde", "model", paged=False,
                                  prefix_caching=False, pipelined=False, **kw)
    warm, m, eng, _ = _run_batches(cfg, pt, pd, "dsde", "model", paged=True,
                                   prefix_caching=True, pipelined=False, **kw)
    assert m["prefix_cache_evictions"] >= 1
    assert dense == warm
    eng.scheduler.allocator.check_invariants()


def test_prefix_cache_round_log_and_summary(small_pair):
    cfg, pt, pd = small_pair
    _, m, eng, _ = _run_batches(cfg, pt, pd, "dsde", "model", paged=True,
                                prefix_caching=True, pipelined=False)
    for rec in eng.round_log:
        assert 0.0 <= rec["kv_pool_utilization"] <= 1.0
        assert 0.0 <= rec["prefix_cache_hit_rate"] <= 1.0
        assert rec["prefix_cache_hit_blocks"] >= 0.0
        assert rec["cow_copies"] >= 0.0
        assert rec["kv_blocks_cached"] >= 0.0
    # the per-round hit-block deltas sum to the lifetime total
    assert sum(r["prefix_cache_hit_blocks"]
               for r in eng.round_log) == m["prefix_cache_hit_blocks"]
    assert 0.0 < m["kv_pool_utilization_mean"] <= 1.0
    assert m["kv_pool_utilization_peak"] >= m["kv_pool_utilization_mean"]


def test_warm_admission_prefills_only_the_tail(small_pair):
    """The perf claim behind the whole feature: a cache-hit admission
    runs the TAIL entry point over a bucket sized by the uncovered
    suffix, not the full prompt."""
    from repro.core import prefill as prefill_lib
    cfg, pt, pd = small_pair
    calls = []
    orig = prefill_lib.prefill_paged_tail

    def spy(params, c, pk, pv, kp, rows, tokens, *a, **kw):
        calls.append(tokens.shape[1])
        return orig(params, c, pk, pv, kp, rows, tokens, *a, **kw)

    prefill_lib.prefill_paged_tail = spy
    try:
        _, m, _, _ = _run_batches(cfg, pt, pd, "dsde", "model", paged=True,
                                  prefix_caching=True, pipelined=False)
    finally:
        prefill_lib.prefill_paged_tail = orig
    assert calls                           # warm admissions took the tail path
    # SHARED covers 40 tokens (2 full blocks); every warm bucket is far
    # narrower than the 46+-token full prompts' 64-wide bucket
    assert max(calls) <= 16


def test_prefix_caching_requires_paged_and_attention_families(small_pair):
    cfg, pt, pd = small_pair
    spec = SpecDecodeConfig(policy="dsde", temperature=0.0)
    sv = ServingConfig(max_batch_size=2, max_seq_len=128, paged_kv=False,
                       prefix_caching=True)
    eng = ServingEngine(pt, cfg, pd, cfg, spec, sv, seed=0)
    assert not eng.prefix_caching          # dense plane: silently off
    hyb = get_config("recurrentgemma-2b").reduced()
    ph = init_params(model_specs(hyb), jax.random.PRNGKey(1), jnp.float32)
    sv = ServingConfig(max_batch_size=2, max_seq_len=128, paged_kv=True,
                       prefix_caching=True)
    eng = ServingEngine(ph, hyb, ph, hyb, spec, sv, seed=0)
    assert not eng.prefix_caching          # recurrent state: off
    # ...and the engine still serves correctly with the flag ignored
    r = Request(0, prompt=list(range(3, 11)), max_new_tokens=4)
    m = eng.run([r])
    assert m["requests_finished"] == 1
    assert m["prefix_cache_hit_blocks"] == 0.0


# ---------------------------------------------------------------------------
# Prefix caching x quantized pool (DESIGN.md §13)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("drafter", ["model", "ngram"])
def test_warm_streams_match_cold_in_quant_plane(small_pair, drafter):
    """The §12 exactness contract holds INSIDE the quantized plane: a
    cache-warm int8 engine emits streams byte-identical to the cache-cold
    int8 engine (the fp stream is NOT the reference — storage
    quantization legitimately shifts it).  BATCH2's block-aligned repeat
    forces a COW fork, so this also pins copy_scales: a fork that
    dropped or misrouted the per-slot scales would corrupt the dequant
    of the whole forked block and diverge loudly."""
    cfg, pt, pd = small_pair
    cold, _, _, _ = _run_batches(cfg, pt, pd, "static", drafter, paged=True,
                                 prefix_caching=False, pipelined=False,
                                 kv_quant="int8")
    warm, m, _, _ = _run_batches(cfg, pt, pd, "static", drafter, paged=True,
                                 prefix_caching=True, pipelined=False,
                                 kv_quant="int8")
    assert cold == warm, drafter
    assert m["prefix_cache_hit_blocks"] > 0
    assert m["cow_copies"] >= 1


def test_warm_revival_restores_scale_state(small_pair):
    """LRU eviction + revival in the quantized plane: an evicted-then-
    revived prefix must come back with its scale state intact (the warm
    block's int8 payload is meaningless without it), and an actually
    reclaimed block must degrade to a miss, never to corruption."""
    cfg, pt, pd = small_pair
    a = SHARED[:32]
    b = RNG.randint(0, 1000, size=97).tolist()       # 7 blocks: drains pool
    batches = ([list(a)], [list(b)], [list(a)])
    kw = dict(max_new=8, nblocks=8, batch=1, batches=batches,
              kv_quant="int8")
    cold, _, _, _ = _run_batches(cfg, pt, pd, "static", "model", paged=True,
                                 prefix_caching=False, pipelined=False, **kw)
    warm, m, eng, _ = _run_batches(cfg, pt, pd, "static", "model",
                                   paged=True, prefix_caching=True,
                                   pipelined=False, **kw)
    assert m["prefix_cache_evictions"] >= 1
    assert cold == warm
    eng.scheduler.allocator.check_invariants()


def test_quant_pool_scale_leaves_present_in_engine_cache(small_pair):
    cfg, pt, pd = small_pair
    _, _, eng, _ = _run_batches(cfg, pt, pd, "static", "model", paged=True,
                                prefix_caching=True, pipelined=False,
                                kv_quant="int8", batches=(BATCH1,))
    tc = eng.state.target_cache
    assert cache_lib.is_quantized(tc)
    assert tc["k"].dtype == jnp.int8
    assert tc["k_scale"].shape == tc["k"].shape[:-1]
    # the mirrored draft pool is quantized too (same block ids, same mode)
    dc = eng.state.draft_cache
    assert cache_lib.is_quantized(dc)
