"""Rejection-sampler correctness: the heart of speculative decoding's
exactness guarantee (Leviathan et al.), including the ragged per-sequence
lengths of paper §3.2."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # offline container: deterministic fallback
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.rejection import rejection_sample

jax.config.update("jax_platform_name", "cpu")

V = 16
PAD = V


def _logits(key, b, n, scale=2.0):
    return jax.random.normal(key, (b, n, V + 0)) * scale


def test_greedy_accepts_iff_argmax_matches():
    key = jax.random.PRNGKey(0)
    tl = _logits(key, 1, 4)
    # draft tokens: first matches argmax, second doesn't
    am = jnp.argmax(tl[:, :3], -1)
    draft = am.at[0, 1].set((am[0, 1] + 1) % V)
    dl = tl[:, :3]  # draft distribution irrelevant at temp 0
    r = rejection_sample(key, draft, dl, tl, jnp.array([3]),
                         temperature=0.0, vocab_size=V, pad_id=PAD)
    assert int(r.num_accepted[0]) == 1
    # recovery token = target argmax at the rejected position
    assert int(r.next_token[0]) == int(am[0, 1])


def test_greedy_full_acceptance_bonus():
    key = jax.random.PRNGKey(1)
    tl = _logits(key, 1, 4)
    am = jnp.argmax(tl, -1)
    r = rejection_sample(key, am[:, :3], tl[:, :3], tl, jnp.array([3]),
                         temperature=0.0, vocab_size=V, pad_id=PAD)
    assert int(r.num_accepted[0]) == 3
    assert int(r.next_token[0]) == int(am[0, 3])   # bonus from position K
    np.testing.assert_array_equal(np.asarray(r.emitted[0]),
                                  np.asarray(jnp.concatenate([am[0, :3],
                                                              am[0, 3:4]])))


def test_ragged_draft_lengths():
    key = jax.random.PRNGKey(2)
    tl = _logits(key, 3, 5)
    am = jnp.argmax(tl, -1)
    draft = am[:, :4]
    lens = jnp.array([0, 2, 4])
    r = rejection_sample(key, draft, tl[:, :4], tl, lens,
                         temperature=0.0, vocab_size=V, pad_id=PAD)
    # acceptance never exceeds the per-sequence draft length
    assert np.all(np.asarray(r.num_accepted) <= np.asarray(lens))
    assert int(r.num_accepted[0]) == 0   # nothing proposed
    assert np.all(np.asarray(r.num_emitted) == np.asarray(r.num_accepted) + 1)
    # pad id fills beyond the emitted prefix
    em = np.asarray(r.emitted)
    for b in range(3):
        assert np.all(em[b, int(r.num_emitted[b]):] == PAD)


def test_zero_draft_autoregressive():
    key = jax.random.PRNGKey(3)
    tl = _logits(key, 2, 1)
    r = rejection_sample(key, jnp.zeros((2, 0), jnp.int32),
                         jnp.zeros((2, 0, V)), tl, jnp.zeros((2,), jnp.int32),
                         temperature=0.0, vocab_size=V, pad_id=PAD)
    assert np.all(np.asarray(r.num_emitted) == 1)
    np.testing.assert_array_equal(np.asarray(r.next_token),
                                  np.asarray(jnp.argmax(tl[:, 0], -1)))


@pytest.mark.parametrize("seed", [0, 1])
def test_distribution_preservation(seed):
    """THE speculative-decoding invariant: with one draft token, the emitted
    first token is distributed exactly as the target distribution,
    regardless of the draft distribution."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    v = 8
    tl = jax.random.normal(k1, (1, 2, v)) * 1.5   # target logits
    dl = jax.random.normal(k2, (1, 1, v)) * 1.5   # divergent draft
    p_target = np.asarray(jax.nn.softmax(tl[0, 0]))
    q_draft = jax.nn.softmax(dl[0, 0])

    n = 30000
    counts = np.zeros(v)
    keys = jax.random.split(k3, n)

    def one(key):
        kd, kr = jax.random.split(key)
        d = jax.random.categorical(kd, jnp.log(q_draft))[None, None]
        r = rejection_sample(kr, d.astype(jnp.int32), dl, tl,
                             jnp.array([1]), temperature=1.0,
                             vocab_size=v, pad_id=v)
        return r.emitted[0, 0]

    toks = np.asarray(jax.vmap(one)(keys))
    for t in toks:
        counts[t] += 1
    freq = counts / n
    # total-variation distance should be ~ sampling noise
    tv = 0.5 * np.abs(freq - p_target).sum()
    assert tv < 0.02, (tv, freq, p_target)


@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_accepted_prefix_property(seed):
    """accept_mask is always a prefix (no holes) and consistent with
    num_accepted."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    b, k = 3, 5
    tl = jax.random.normal(k1, (b, k + 1, V))
    dl = jax.random.normal(k2, (b, k, V))
    draft = jax.random.randint(k3, (b, k), 0, V)
    lens = jax.random.randint(k4, (b,), 0, k + 1)
    r = rejection_sample(k5, draft, dl, tl, lens, temperature=1.0,
                         vocab_size=V, pad_id=PAD)
    m = np.asarray(r.accept_mask)
    na = np.asarray(r.num_accepted)
    for i in range(b):
        assert m[i, :na[i]].all()
        assert not m[i, na[i]:].any()
        assert na[i] <= int(lens[i])
        # emitted tokens are in-vocab up to num_emitted
        em = np.asarray(r.emitted[i])
        assert (em[:na[i] + 1] < V).all()
