"""OpenAI-compatible HTTP layer: socket-level tests (DESIGN.md §14).

One tiny engine + front-end + HTTP server thread per module; every
test talks through a real socket with stdlib ``http.client`` — the
same path CI's ``--http-smoke`` lane exercises.  Greedy decoding makes
the token streams request-id-independent, so HTTP responses are
compared byte-for-byte against a direct ``ServingEngine.run()``.
"""
import http.client
import json

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core.config import ServingConfig, SpecDecodeConfig
from repro.models.module import init_params
from repro.models.transformer import model_specs
from repro.serving.engine import ServingEngine
from repro.serving.frontend import ServingFrontend
from repro.serving.request import Request
from repro.serving.server import (_parse_prompt, _text, smoke_check,
                                  start_http_server_thread)

jax.config.update("jax_platform_name", "cpu")

PROMPT = [3, 7, 11, 2, 9, 4]


def _make_engine():
    cfg = get_config("smollm-135m").reduced()
    pt = init_params(model_specs(cfg), jax.random.PRNGKey(1), jnp.float32)
    noise = init_params(model_specs(cfg), jax.random.PRNGKey(7), jnp.float32)
    pd = jax.tree_util.tree_map(lambda a, b: a + 0.05 * b, pt, noise)
    spec = SpecDecodeConfig(policy="dsde", temperature=0.0)
    sv = ServingConfig(max_batch_size=2, max_seq_len=128, paged_kv=True,
                       kv_block_size=16, pipelined=True)
    return ServingEngine(pt, cfg, pd, cfg, spec, sv, seed=0), cfg


@pytest.fixture(scope="module")
def served():
    eng, cfg = _make_engine()
    fe = ServingFrontend(eng).start()
    port, stop = start_http_server_thread(fe, model_name="repro-test")
    # reference stream for the same prompt from a *direct* run — greedy
    # streams are request-id-independent, so HTTP must reproduce it
    ref_eng, _ = _make_engine()
    ref = Request(0, prompt=list(PROMPT), max_new_tokens=6)
    ref_eng.run([ref])
    yield {"port": port, "frontend": fe, "cfg": cfg, "ref": ref.output}
    stop()
    fe.stop()


def _post(port, obj, path="/v1/completions"):
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=120)
    conn.request("POST", path, json.dumps(obj),
                 {"Content-Type": "application/json"})
    resp = conn.getresponse()
    body = resp.read().decode()
    status, ctype = resp.status, resp.getheader("Content-Type")
    conn.close()
    return status, ctype, body


def _get(port, path):
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=30)
    conn.request("GET", path)
    resp = conn.getresponse()
    out = resp.status, json.loads(resp.read().decode())
    conn.close()
    return out


def test_non_streaming_completion_matches_run(served):
    status, _, body = _post(served["port"], {
        "model": "repro-test", "prompt": PROMPT, "max_tokens": 6})
    assert status == 200
    out = json.loads(body)
    assert out["object"] == "text_completion"
    assert out["model"] == "repro-test"
    choice = out["choices"][0]
    assert choice["token_ids"] == served["ref"]
    assert choice["text"] == _text(served["ref"])
    assert choice["finish_reason"] == "length"
    assert out["usage"] == {"prompt_tokens": len(PROMPT),
                            "completion_tokens": 6,
                            "total_tokens": len(PROMPT) + 6}


def test_streaming_sse_matches_run(served):
    status, ctype, raw = _post(served["port"], {
        "prompt": " ".join(str(t) for t in PROMPT),   # id-string form
        "max_tokens": 6, "stream": True})
    assert status == 200
    assert ctype == "text/event-stream"
    lines = [ln for ln in raw.split("\n\n") if ln.startswith("data: ")]
    assert lines[-1].strip() == "data: [DONE]"
    events = [json.loads(ln[len("data: "):]) for ln in lines[:-1]]
    toks = [t for ev in events for t in ev["choices"][0]["token_ids"]]
    assert toks == served["ref"]
    finishes = [ev["choices"][0]["finish_reason"] for ev in events]
    assert finishes == [None] * 6 + ["length"]      # one event per token


def test_smoke_check_self_test(served):
    res = smoke_check("127.0.0.1", served["port"], PROMPT, max_tokens=6)
    assert res["streamed_tokens"] == res["non_streaming_tokens"]
    assert res["non_streaming_tokens"] == served["ref"]
    assert res["events"] == 7


def test_health_and_models(served):
    status, health = _get(served["port"], "/health")
    assert status == 200 and health["status"] == "ok"
    assert health["queued"] == 0
    status, models = _get(served["port"], "/v1/models")
    assert status == 200
    assert models["data"][0]["id"] == "repro-test"


def test_error_paths(served):
    port = served["port"]
    status, _, body = _post(port, {"prompt": PROMPT}, path="/v1/chat")
    assert status == 404 and "no route" in json.loads(body)["error"]["message"]
    status, _, _ = _post(port, {"max_tokens": 4})          # prompt missing
    assert status == 400
    status, _, _ = _post(port, {"prompt": "not token ids"})
    assert status == 400
    status, _, _ = _post(port, {"prompt": PROMPT, "max_tokens": 0})
    assert status == 400
    # malformed JSON body
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=30)
    conn.request("POST", "/v1/completions", "{nope",
                 {"Content-Type": "application/json"})
    assert conn.getresponse().status == 400
    conn.close()


def test_parse_prompt_forms():
    assert _parse_prompt([1, 2, 3]) == [1, 2, 3]
    assert _parse_prompt("4 5 6") == [4, 5, 6]
    assert _parse_prompt(7) == [7]
    for bad in (None, "", [], [1, "x"], {"a": 1}):
        with pytest.raises(ValueError):
            _parse_prompt(bad)


def test_concurrent_streaming_clients(served):
    """Two simultaneous SSE consumers: per-request handles keep the
    streams separate, both byte-correct (greedy → identical)."""
    import threading

    outs = [None, None]

    def _stream(i):
        _, _, raw = _post(served["port"], {
            "prompt": PROMPT, "max_tokens": 6, "stream": True})
        events = [json.loads(ln[len("data: "):])
                  for ln in raw.split("\n\n")
                  if ln.startswith("data: ") and "[DONE]" not in ln]
        outs[i] = [t for ev in events
                   for t in ev["choices"][0]["token_ids"]]

    threads = [threading.Thread(target=_stream, args=(i,)) for i in (0, 1)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert outs[0] == outs[1] == served["ref"]
