"""Serving under a (data, model) mesh (DESIGN.md §5).

The contract this file enforces is the one PRs 2–4 established for the
paged layout and the pipelined schedule, extended across DEVICE LAYOUTS:
greedy token streams must be **byte-identical** between the single-device
engine and a meshed engine — for every registered policy × drafter, both
KV layouts, both schedules, and under forced preemption.  (Greedy
speculative decoding is exact, so the only way a mesh could change a
token is a real data-plane bug: a mis-sharded cache write, a clipped
gather, a drifted RNG key.)

The identity runs need forced host devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=4``, the CI
``multidevice`` lane); without them those tests skip and only the pure
rule-table unit tests run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.config import ServingConfig, SpecDecodeConfig
from repro.core.drafters import available_drafters
from repro.core.policies import available_policies
from repro.launch.mesh import make_mesh_from_shape, serving_mesh
from repro.launch.sharding import (kv_head_axis, serve_cache_shardings,
                                   serve_rules)
from repro.models.module import init_params
from repro.models.transformer import forward, model_specs
from repro.serving.engine import ServingEngine
from repro.serving.request import Request

jax.config.update("jax_platform_name", "cpu")

MULTI = len(jax.devices()) >= 4
requires_devices = pytest.mark.skipif(
    not MULTI, reason="needs XLA_FLAGS=--xla_force_host_platform_"
    "device_count=4 (the CI multidevice lane sets it)")

MESHES = ("1x4", "2x2")
ALL_POLICIES = tuple(available_policies())
ALL_DRAFTERS = tuple(available_drafters())


# ---------------------------------------------------------------------------
# Rule-table units (run everywhere, no forced devices needed)
# ---------------------------------------------------------------------------

class _FakeMesh:
    axis_names = ("data", "model")

    class devices:
        shape = (2, 4)


def test_kv_head_axis_uneven_guard():
    """The 2-head miniatures must REPLICATE their KV head dim (vLLM's
    KV-head replication), not shard it unevenly; divisible counts
    shard."""
    rules = serve_rules(make_mesh_from_shape((1, 1), ("data", "model")), 8)
    assert kv_head_axis(2, _FakeMesh, rules) is None       # 2 % 4 != 0
    assert kv_head_axis(1, _FakeMesh, rules) is None
    assert kv_head_axis(8, _FakeMesh, rules) == "model"    # 8 % 4 == 0
    assert kv_head_axis(4, _FakeMesh, rules) == "model"


def test_serve_rules_table():
    mesh = make_mesh_from_shape((1, 1), ("data", "model"))
    rules = serve_rules(mesh, 8)
    assert rules.heads == "model" and rules.mlp == "model"
    assert rules.vocab == "model"
    assert rules.embed is None          # serving TP: no FSDP on weights
    assert rules.cache_seq is None      # KV heads shard instead (§5)
    assert rules.batch == ("data",)
    # odd batch over a (fake) 2-wide data axis must refuse to shard
    assert serve_rules(_FakeMesh, 7).batch == ()


def test_serve_cache_shardings_layout_contract():
    """Paged pools keep the block axis whole + tables replicate; dense
    rows shard batch over data; all control leaves replicate.  Specs are
    canonical (no trailing Nones) so round signatures never alternate
    between equal-but-unequal specs."""
    from jax.sharding import PartitionSpec as P
    mesh = make_mesh_from_shape((1, 1), ("data", "model"))
    rules = serve_rules(mesh, 4)
    paged = {"k": jnp.zeros((2, 8, 4, 1, 8)), "v": jnp.zeros((2, 8, 4, 1, 8)),
             "kv_pos": jnp.zeros((8, 4), jnp.int32),
             "block_table": jnp.zeros((4, 8), jnp.int32),
             "length": jnp.zeros((4,), jnp.int32)}
    sh = serve_cache_shardings(paged, mesh, rules)
    assert sh["k"].spec[1] is None            # pool block axis stays whole
    assert sh["block_table"].spec == P()      # host rewrites rows piecemeal
    assert sh["kv_pos"].spec == P()
    dense = {"k": jnp.zeros((2, 4, 32, 1, 8)), "v": jnp.zeros((2, 4, 32, 1, 8)),
             "kv_pos": jnp.zeros((4, 32), jnp.int32),
             "length": jnp.zeros((4,), jnp.int32)}
    shd = serve_cache_shardings(dense, mesh, rules)
    assert shd["k"].spec[1] == ("data",)      # batch rows over data
    ngram = {"tokens": jnp.zeros((4, 64), jnp.int32),
             "length": jnp.zeros((4,), jnp.int32)}
    shn = serve_cache_shardings(ngram, mesh, rules)
    assert shn["tokens"].spec == P(("data",))
    assert shn["length"].spec == P()


# ---------------------------------------------------------------------------
# Meshed-engine identity (forced-device lane)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_pair():
    cfg = get_config("smollm-135m").reduced()
    pt = init_params(model_specs(cfg), jax.random.PRNGKey(1), jnp.float32)
    noise = init_params(model_specs(cfg), jax.random.PRNGKey(7), jnp.float32)
    pd = jax.tree_util.tree_map(lambda a, b: a + 0.05 * b, pt, noise)
    return cfg, pt, pd


def greedy_rollout(params, cfg, prompt, n):
    toks = list(prompt)
    for _ in range(n):
        logits, _, _ = forward(params, cfg,
                               jnp.asarray([toks], jnp.int32), mode="train")
        toks.append(int(jnp.argmax(logits[0, -1, :cfg.vocab_size])))
    return toks[len(prompt):]


def _prompts(cfg, sizes=(7, 12, 5), seed=11):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab_size, size=n).tolist() for n in sizes]


def _serve(cfg, pt, pd, *, policy="dsde", drafter="model", mesh=None,
           paged=False, pipelined=False, prompts=None, max_new=10,
           batch=2, max_seq=128, bs=16, nblocks=None):
    spec = SpecDecodeConfig(policy=policy, drafter=drafter, temperature=0.0)
    sv = ServingConfig(max_batch_size=batch, max_seq_len=max_seq,
                       paged_kv=paged, kv_block_size=bs,
                       num_kv_blocks=nblocks, pipelined=pipelined)
    from repro.core.drafters import build_drafter
    model_free = not build_drafter(spec, cfg, cfg).uses_draft_model()
    eng = ServingEngine(pt, cfg, None if model_free else pd,
                        None if model_free else cfg, spec, sv, seed=0,
                        mesh=serving_mesh(mesh) if mesh else None)
    reqs = [Request(i, prompt=p, max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    m = eng.run(reqs)
    return [r.output for r in reqs], m, eng


@pytest.fixture(scope="module")
def reference(small_pair):
    """Target-only greedy rollouts — what EVERY exact engine must emit,
    single-device or meshed, any policy/drafter/layout/schedule."""
    cfg, pt, _ = small_pair
    prompts = _prompts(cfg)
    return prompts, [greedy_rollout(pt, cfg, p, 10) for p in prompts]


@requires_devices
@pytest.mark.parametrize("pipelined", [False, True], ids=["sync", "pipe"])
@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
def test_single_device_engine_matches_rollout(small_pair, reference,
                                              paged, pipelined):
    """Anchor: the un-meshed engine reproduces the target rollout, so the
    meshed tests below compare against the same reference stream."""
    cfg, pt, pd = small_pair
    prompts, ref = reference
    out, m, _ = _serve(cfg, pt, pd, paged=paged, pipelined=pipelined,
                       prompts=prompts)
    assert out == ref
    assert m["requests_finished"] == len(prompts)


@requires_devices
@pytest.mark.parametrize("drafter", ALL_DRAFTERS)
@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_mesh_identity_policy_drafter_matrix(small_pair, reference,
                                             policy, drafter):
    """Every registered policy × drafter serves byte-identically to the
    single-device reference on a forced-host mesh, across dense + paged
    and sync + pipelined.  The mesh alternates 1x4 / 2x2 per (layout,
    schedule) cell so both shapes cover the full matrix without doubling
    the lane's runtime; the dsde×model cross below runs every cell on
    BOTH meshes."""
    cfg, pt, pd = small_pair
    prompts, ref = reference
    for i, (paged, pipelined) in enumerate(
            [(False, False), (False, True), (True, False), (True, True)]):
        mesh = MESHES[(ALL_POLICIES.index(policy)
                       + ALL_DRAFTERS.index(drafter) + i) % 2]
        out, m, eng = _serve(cfg, pt, pd, policy=policy, drafter=drafter,
                             mesh=mesh, paged=paged, pipelined=pipelined,
                             prompts=prompts)
        tag = (policy, drafter, mesh, paged, pipelined)
        assert m["requests_finished"] == len(prompts), tag
        assert out == ref, tag


@requires_devices
@pytest.mark.parametrize("mesh", MESHES)
@pytest.mark.parametrize("pipelined", [False, True], ids=["sync", "pipe"])
@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
def test_mesh_identity_full_cross_dsde_model(small_pair, reference, mesh,
                                             paged, pipelined):
    cfg, pt, pd = small_pair
    prompts, ref = reference
    out, m, _ = _serve(cfg, pt, pd, mesh=mesh, paged=paged,
                       pipelined=pipelined, prompts=prompts)
    assert out == ref, (mesh, paged, pipelined)
    assert m["requests_finished"] == len(prompts)


@requires_devices
@pytest.mark.parametrize("mesh", MESHES)
def test_mesh_exact_under_forced_preemption(small_pair, mesh):
    """Pool pressure on a meshed engine: eviction wipes the victim's
    replicated table row on every shard, recompute-on-readmit reprefills
    into resharded pools — the dense single-device stream must survive
    all of it."""
    cfg, pt, pd = small_pair
    rng = np.random.RandomState(5)
    prompts = [rng.randint(0, cfg.vocab_size, size=n).tolist()
               for n in (30, 25, 20)]
    dense, _, _ = _serve(cfg, pt, pd, prompts=prompts, max_new=40, bs=8)
    out, m, _ = _serve(cfg, pt, pd, mesh=mesh, paged=True, pipelined=True,
                       prompts=prompts, max_new=40, bs=8, nblocks=16)
    assert m["preemptions"] >= 1
    assert m["requests_finished"] == 3
    assert dense == out


# ---------------------------------------------------------------------------
# Sharding-spec assertions + no-recompile guard (forced-device lane)
# ---------------------------------------------------------------------------

def _flat_axes(spec):
    out = []
    for part in tuple(spec):
        if part is None:
            continue
        out += list(part) if isinstance(part, tuple) else [part]
    return out


@requires_devices
def test_engine_places_params_and_state_on_mesh(small_pair):
    cfg, pt, pd = small_pair
    spec = SpecDecodeConfig(policy="static", drafter="model",
                            temperature=0.0)
    sv = ServingConfig(max_batch_size=4, max_seq_len=128, paged_kv=True,
                       kv_block_size=16)
    eng = ServingEngine(pt, cfg, pd, cfg, spec, sv,
                        mesh=serving_mesh("2x2"))
    # params: tensor-parallel over *model*, never over *data*
    axes = [a for leaf in jax.tree_util.tree_leaves(eng.pt)
            for a in _flat_axes(leaf.sharding.spec)]
    assert "model" in axes and "data" not in axes
    st = eng.state
    # paged pools: KV head dim under the uneven guard (1 head -> whole),
    # block axis never sharded, tables + control vectors replicated
    assert _flat_axes(st.target_cache["k"].sharding.spec) == []
    assert _flat_axes(st.target_cache["block_table"].sharding.spec) == []
    for leaf in (st.pending, st.done, st.tokens_budget, st.sl_next):
        assert _flat_axes(leaf.sharding.spec) == []
    # the draft mirror inherits the target pool's specs
    assert (st.draft_cache["k"].sharding.spec
            == st.target_cache["k"].sharding.spec)


@requires_devices
def test_ngram_token_buffer_data_sharded(small_pair):
    cfg, pt, _ = small_pair
    spec = SpecDecodeConfig(policy="static", drafter="ngram",
                            temperature=0.0)
    sv = ServingConfig(max_batch_size=4, max_seq_len=128)
    eng = ServingEngine(pt, cfg, None, None, spec, sv,
                        mesh=serving_mesh("2x2"))
    assert _flat_axes(eng.state.draft_cache["tokens"].sharding.spec) \
        == ["data"]
    # dense target rows: batch slots over data
    assert "data" in _flat_axes(eng.state.target_cache["k"].sharding.spec)


@requires_devices
def test_no_recompile_across_rounds_on_fixed_mesh(small_pair):
    """Consecutive rounds at a fixed bucket on a fixed mesh reuse ONE
    program: the engine's eager per-slot updates (admission scatters,
    block-table rewrites, shrink) must never drift an input layout into
    a fresh jit signature."""
    cfg, pt, pd = small_pair
    prompts = _prompts(cfg)
    _, _, eng = _serve(cfg, pt, pd, mesh="1x4", paged=True,
                       prompts=prompts, max_new=8)
    # round jits are shared ACROSS engines (equal config -> same program),
    # so earlier tests may already have populated entries for other cache
    # geometries; the guard is NO GROWTH while this engine keeps serving,
    # i.e. every later round re-hits the program its first round traced.
    sizes = {k: fn._cache_size() for k, fn in eng._mesh_round_fns.items()}
    assert sizes, "engine ran no meshed rounds"
    reqs = [Request(100 + i, prompt=p, max_new_tokens=8)
            for i, p in enumerate(_prompts(cfg, seed=29))]
    eng.run(reqs)
    after = {k: fn._cache_size() for k, fn in eng._mesh_round_fns.items()}
    for k, n in sizes.items():
        assert after[k] == n, (k, sizes, after)


@requires_devices
def test_round_state_shardings_cover_state(small_pair):
    """The declared in/out sharding tree matches the real RoundState
    structure leaf-for-leaf (a drifted tree would silently fall back to
    prefix broadcasting and lose the per-leaf layout contract)."""
    cfg, pt, pd = small_pair
    spec = SpecDecodeConfig(policy="dsde", drafter="model", temperature=0.0)
    sv = ServingConfig(max_batch_size=2, max_seq_len=128)
    eng = ServingEngine(pt, cfg, pd, cfg, spec, sv,
                        mesh=serving_mesh("1x4"))
    assert (jax.tree_util.tree_structure(eng._state_sh)
            == jax.tree_util.tree_structure(eng.state))
