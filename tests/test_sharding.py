"""Distribution-layer tests that run on the single CPU device: mesh
factories, sharding-rule tables, the HLO cost analyzer, and the scheduler."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.config import INPUT_SHAPES, ShardingConfig, SpecDecodeConfig, ServingConfig
from repro.launch.hlo_cost import HLOCost, analyze
from repro.launch.mesh import make_mesh_from_shape, single_device_mesh
from repro.launch.sharding import _batch_axes, cache_shardings, make_rules
from repro.models.module import Spec, logical_to_pspec, param_shardings
from repro.serving.request import Request, RequestState
from repro.serving.scheduler import LookaheadScheduler

jax.config.update("jax_platform_name", "cpu")


def test_single_device_mesh():
    mesh = single_device_mesh()
    assert mesh.axis_names == ("data", "model")
    assert mesh.devices.shape == (1, 1)


def test_logical_to_pspec():
    rules = ShardingConfig(batch=("data",), heads="model", mlp="model",
                           vocab="model", embed=None)
    assert logical_to_pspec(("embed", "heads", "head_dim"), rules) == \
        P(None, "model")
    assert logical_to_pspec(("vocab", "embed"), rules) == P("model")
    assert logical_to_pspec(("batch", "cache_seq"), rules) == P(("data",))


def test_param_shardings_divisibility_guard():
    mesh = make_mesh_from_shape((1, 1), ("data", "model"))
    rules = ShardingConfig(batch=("data",))
    specs = {"w": Spec((9, 64), ("heads", "head_dim"))}
    sh = param_shardings(specs, mesh, rules)
    # 9 % 1 == 0 on the degenerate mesh -> sharded spec survives
    assert sh["w"].spec == P("model")


def test_batch_axes_divisibility():
    mesh = make_mesh_from_shape((1, 1), ("data", "model"))
    assert _batch_axes(mesh, 4) == ("data",)
    # a fake 2-wide data axis would reject odd batches
    mesh2 = make_mesh_from_shape((1, 1, 1), ("pod", "data", "model"))
    assert _batch_axes(mesh2, 7) == ("pod", "data")


def test_cache_shardings_no_duplicate_axes():
    mesh = make_mesh_from_shape((1, 1), ("data", "model"))
    rules = make_rules(mesh, INPUT_SHAPES["decode_32k"])
    cache = {"k": jnp.zeros((2, 4, 32, 1, 8)),
             "kv_pos": jnp.zeros((4, 32), jnp.int32),
             "length": jnp.zeros((4,), jnp.int32)}
    sh = cache_shardings(cache, mesh, rules)
    for s in sh.values():
        flat = []
        for part in tuple(s.spec):
            if part is None:
                continue
            flat += list(part) if isinstance(part, tuple) else [part]
        assert len(flat) == len(set(flat)), s


def test_rules_per_shape_kind():
    mesh = make_mesh_from_shape((1, 1), ("data", "model"))
    train = make_rules(mesh, INPUT_SHAPES["train_4k"])
    assert train.embed == "data" and train.seq == "model"
    dec = make_rules(mesh, INPUT_SHAPES["decode_32k"])
    assert dec.embed is None and dec.cache_seq == "model"
    # batch=1 is unshardable over any axis wider than 1
    class FakeMesh:
        axis_names = ("data", "model")
        class devices:
            shape = (16, 16)
    assert _batch_axes(FakeMesh, 1) == ()
    assert _batch_axes(FakeMesh, 128) == ("data",)


# ---------------------------------------------------------------------------
# HLO cost analyzer
# ---------------------------------------------------------------------------

def _scan_matmul(n, dim=128):
    def step(x, _):
        return x @ x, None

    def g(x):
        y, _ = jax.lax.scan(step, x, None, length=n)
        return y
    return jax.jit(g).lower(
        jax.ShapeDtypeStruct((dim, dim), jnp.float32)).compile()


def test_hlo_cost_scan_trip_count():
    c = _scan_matmul(7)
    got = analyze(c.as_text())["flops"]
    assert got == pytest.approx(7 * 2 * 128 ** 3, rel=1e-6)


def test_hlo_cost_nested_scan():
    def g(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ ci, None
            y, _ = jax.lax.scan(inner, c, None, length=3)
            return y, None
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y
    c = jax.jit(g).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    got = analyze(c.as_text())["flops"]
    assert got == pytest.approx(12 * 2 * 64 ** 3, rel=1e-6)


def test_hlo_cost_bytes_scale_with_trip_count():
    a5 = analyze(_scan_matmul(5).as_text())["bytes"]
    a10 = analyze(_scan_matmul(10).as_text())["bytes"]
    assert 1.6 < a10 / a5 < 2.4


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def _sched(slots=2, max_seq=128):
    return LookaheadScheduler(ServingConfig(max_batch_size=slots,
                                            max_seq_len=max_seq),
                              SpecDecodeConfig())


def test_scheduler_admission_and_release():
    s = _sched(2)
    reqs = [Request(i, prompt=[1, 2, 3], max_new_tokens=8) for i in range(3)]
    for r in reqs:
        s.submit(r)
    admitted = s.admit()
    assert len(admitted) == 2
    assert s.active_mask.sum() == 2
    assert not s.free_slots()
    s.release(reqs[0])
    assert s.free_slots() == [0]
    more = s.admit()
    assert more == [reqs[2]] and reqs[2].slot == 0


def test_scheduler_rejects_oversize():
    s = _sched(1, max_seq=32)
    big = Request(0, prompt=[0] * 30, max_new_tokens=30)
    s.submit(big)
    assert s.admit() == []
    # terminal REJECTED (with a finish_time), surfaced via pop_rejected —
    # never a silent FINISHED that no engine list ever sees
    assert big.state == RequestState.REJECTED
    assert big.finish_time is not None
    assert s.pop_rejected() == [big]


def test_lookahead_slots():
    s = _sched()
    np.testing.assert_array_equal(
        s.lookahead_slots(np.array([2, 5])), [3, 6])
