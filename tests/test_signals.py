"""Unit + property tests for the KLD stability signals (paper Eq. 4-7)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # offline container: deterministic fallback
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.signals import (KLDHistory, decay_weights, draft_entropy,
                                kld_per_position, weighted_mean, weighted_var,
                                wvir)

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# Eq. (5)-(7): weighted statistics
# ---------------------------------------------------------------------------

def test_decay_weights_most_recent_largest():
    w = np.asarray(decay_weights(5, 0.85))
    # oldest-first layout: last entry is the most recent, alpha_1 = 1
    assert w[-1] == pytest.approx(1.0)
    assert np.all(np.diff(w) > 0)
    assert w[0] == pytest.approx(0.85 ** 4)


def test_weighted_mean_matches_hand_computation():
    # N=3 values chronological [2, 4, 6], delta=0.5
    # alpha (oldest-first) = [0.25, 0.5, 1.0]
    x = jnp.array([2.0, 4.0, 6.0])
    w = decay_weights(3, 0.5)
    mu = float(weighted_mean(x, w))
    expect = (0.25 * 2 + 0.5 * 4 + 1.0 * 6) / 1.75
    assert mu == pytest.approx(expect, rel=1e-6)


def test_weighted_var_matches_hand_computation():
    x = jnp.array([1.0, 3.0])
    w = decay_weights(2, 0.5)          # [0.5, 1.0]
    mu = (0.5 * 1 + 1.0 * 3) / 1.5
    expect = (0.5 * (1 - mu) ** 2 + 1.0 * (3 - mu) ** 2) / 1.5
    assert float(weighted_var(x, w)) == pytest.approx(expect, rel=1e-6)


@given(st.lists(st.floats(0.0, 10.0), min_size=2, max_size=30),
       st.floats(0.5, 0.99))
@settings(max_examples=50, deadline=None)
def test_weighted_var_nonnegative_and_zero_for_constant(vals, delta):
    x = jnp.asarray(vals, jnp.float32)
    w = decay_weights(len(vals), delta)
    v = float(weighted_var(x, w))
    assert v >= -1e-6
    c = jnp.full((len(vals),), 3.14, jnp.float32)
    assert float(weighted_var(c, w)) == pytest.approx(0.0, abs=1e-9)


@given(st.lists(st.floats(0.01, 10.0), min_size=3, max_size=20),
       st.floats(0.1, 5.0))
@settings(max_examples=30, deadline=None)
def test_weighted_var_scales_quadratically(vals, c):
    x = jnp.asarray(vals, jnp.float32)
    w = decay_weights(len(vals), 0.85)
    v1 = float(weighted_var(x, w))
    v2 = float(weighted_var(c * x, w))
    assert v2 == pytest.approx(c * c * v1, rel=1e-3, abs=1e-6)


# ---------------------------------------------------------------------------
# KLD / entropy signals
# ---------------------------------------------------------------------------

def test_kld_zero_for_identical_distributions():
    logits = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 50))
    kld = kld_per_position(logits, logits)
    assert float(jnp.abs(kld).max()) < 1e-5


def test_kld_positive_for_different_distributions():
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    a = jax.random.normal(k1, (2, 4, 50)) * 3
    b = jax.random.normal(k2, (2, 4, 50)) * 3
    assert float(kld_per_position(a, b).min()) > 0


def test_kld_respects_validity_mask():
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    a = jax.random.normal(k1, (1, 4, 20))
    b = jax.random.normal(k2, (1, 4, 20))
    valid = jnp.array([[True, False, True, False]])
    kld = kld_per_position(a, b, valid)
    assert kld[0, 1] == 0.0 and kld[0, 3] == 0.0
    assert kld[0, 0] > 0 and kld[0, 2] > 0


def test_entropy_uniform_is_log_v():
    v = 64
    logits = jnp.zeros((1, 1, v))
    assert float(draft_entropy(logits)[0, 0]) == pytest.approx(np.log(v),
                                                               rel=1e-5)


# ---------------------------------------------------------------------------
# History ring buffer + WVIR (Eq. 4, Fig. 5)
# ---------------------------------------------------------------------------

def test_history_chronological_order():
    h = KLDHistory.init(1, 5)
    for i in range(7):
        h = h.push(jnp.array([float(i)]))
    vals, valid = h.chronological(5)
    np.testing.assert_array_equal(np.asarray(vals[0]), [2, 3, 4, 5, 6])
    assert bool(valid.all())


def test_history_validity_before_fill():
    h = KLDHistory.init(1, 6)
    h = h.push(jnp.array([1.0]))
    h = h.push(jnp.array([2.0]))
    vals, valid = h.chronological(4)
    np.testing.assert_array_equal(np.asarray(valid[0]),
                                  [False, False, True, True])
    assert float(vals[0, 2]) == 1.0 and float(vals[0, 3]) == 2.0


def test_history_inactive_rows_frozen():
    h = KLDHistory.init(2, 4)
    h = h.push(jnp.array([1.0, 9.0]), active=jnp.array([True, False]))
    assert int(h.count[0]) == 1 and int(h.count[1]) == 0


def test_wvir_neutral_until_enough_history():
    h = KLDHistory.init(1, 30)
    for i in range(5):
        h = h.push(jnp.array([float(i)]))
    assert float(wvir(h, 10, 30, 0.85)[0]) == 1.0


def test_wvir_detects_instability():
    """Stable history then a sudden spike -> short-term variance outgrows
    long-term variance (the paper's 'growing instability' indicator)."""
    h = KLDHistory.init(1, 30)
    rng = np.random.RandomState(0)
    for _ in range(30):
        h = h.push(jnp.array([1.0 + 0.01 * rng.randn()]))
    stable = float(wvir(h, 10, 30, 0.85)[0])
    for v in (4.0, 0.2, 5.0, 0.1):   # violent swings
        h = h.push(jnp.array([v]))
    unstable = float(wvir(h, 10, 30, 0.85)[0])
    assert unstable > stable
    assert unstable > 1.0


@given(st.floats(0.5, 4.0))
@settings(max_examples=20, deadline=None)
def test_wvir_scale_invariant(scale):
    """Var ratio is invariant to rescaling the whole KLD history."""
    h1 = KLDHistory.init(1, 30)
    h2 = KLDHistory.init(1, 30)
    rng = np.random.RandomState(1)
    for _ in range(35):
        v = abs(1.0 + rng.randn())
        h1 = h1.push(jnp.array([v]))
        h2 = h2.push(jnp.array([v * scale]))
    w1 = float(wvir(h1, 10, 30, 0.85)[0])
    w2 = float(wvir(h2, 10, 30, 0.85)[0])
    assert w1 == pytest.approx(w2, rel=1e-3)
