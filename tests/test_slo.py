"""SLO-aware speculation control (DESIGN.md §15).

Covers the analytic per-round latency model (RLS convergence +
calibration warm-start), the ``slo`` policy's batch-tightness
arbitration and its no-deadline exactness bar (byte-identical streams
to ``dsde`` across drafters and engine modes), the scheduler's
SLO admission gate (surfaced, bounded deferral, never rejected), the
``Request.slo_attained`` accounting, and trace v2 round-tripping.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks import loadgen
from repro.configs import get_config
from repro.core.config import ServingConfig, SpecDecodeConfig
from repro.core.policies import HostRoundContext, build_policy
from repro.core.policies.slo import batch_tightness_s
from repro.models.module import init_params
from repro.models.transformer import model_specs
from repro.serving.engine import ServingEngine
from repro.serving.latency_model import (COEF_NAMES, RoundLatencyModel,
                                         round_features)
from repro.serving.request import Request, RequestState
from repro.serving.scheduler import LookaheadScheduler

jax.config.update("jax_platform_name", "cpu")

TRUE_THETA = np.array([2e-3, 1e-5, 5e-4, 2e-4])   # c0, c_prefill, c_draft, c_verify


def _synthetic_rounds(n, seed=0, noise=0.0):
    rng = np.random.RandomState(seed)
    recs = []
    for _ in range(n):
        k = int(rng.randint(0, 9))
        b = int(rng.randint(1, 9))
        pf = float(rng.randint(0, 3) * rng.randint(0, 65))
        wall = float(round_features(k, b, pf) @ TRUE_THETA)
        if noise:
            wall += float(rng.randn()) * noise
        recs.append({"wall_s": max(wall, 0.0), "k": k, "b_eff": b,
                     "prefill_tokens": pf})
    return recs


# ---------------------------------------------------------------------------
# latency model: RLS convergence + warm start
# ---------------------------------------------------------------------------

def test_rls_converges_to_known_coefficients():
    lm = RoundLatencyModel()
    assert not lm.ready()
    for r in _synthetic_rounds(400, noise=1e-5):
        lm.observe(r["wall_s"], r["k"], r["b_eff"], r["prefill_tokens"])
    assert lm.ready()
    got = lm.coefficients()
    for name, true in zip(COEF_NAMES, TRUE_THETA):
        assert got[name] == pytest.approx(true, rel=0.05, abs=1e-5), name
    # predictions track the generator at unseen operating points
    want = float(round_features(5, 3, 40.0) @ TRUE_THETA)
    assert lm.predict_round_s(5, 3, 40.0) == pytest.approx(want, rel=0.05)
    assert lm.rmse_s() < 1e-3


def test_warm_start_matches_batch_fit_and_keeps_updating():
    lm = RoundLatencyModel()
    n = lm.warm_start_from_rounds(_synthetic_rounds(64, seed=1))
    assert n == 64 and lm.ready()
    got = lm.coefficients()
    for name, true in zip(COEF_NAMES, TRUE_THETA):
        assert got[name] == pytest.approx(true, rel=1e-3, abs=1e-7), name
    # records without wall_s/k are skipped, not fatal
    assert lm.warm_start_from_rounds([{"foo": 1}]) == 0
    # online updates continue FROM the calibrated state
    before = lm.rounds_fit
    lm.observe(0.01, 4, 2, 0.0)
    assert lm.rounds_fit == before + 1
    # summary fields carry every coefficient for the round log / tables
    f = lm.summary_fields()
    assert {"latency_model_c0", "latency_model_c_prefill",
            "latency_model_c_draft", "latency_model_c_verify",
            "latency_model_rounds_fit", "latency_model_rmse_s"} <= set(f)


def test_model_not_ready_below_min_rounds():
    lm = RoundLatencyModel(min_rounds=8)
    for r in _synthetic_rounds(7, seed=2):
        lm.observe(r["wall_s"], r["k"], r["b_eff"], r["prefill_tokens"])
    assert not lm.ready()
    lm.observe(0.01, 2, 1)
    assert lm.ready()


# ---------------------------------------------------------------------------
# HostRoundContext + batch tightness
# ---------------------------------------------------------------------------

def test_host_round_context_helpers():
    ctx = HostRoundContext.from_arrays(np.array([3, 5]))
    assert ctx.active.all() and not ctx.has_deadlines()
    assert ctx.tightest_deadline_s() is None
    ctx2 = HostRoundContext(
        sl_next=np.array([3, 5, 2]), active=np.array([True, True, False]),
        deadline_remaining_s=np.array([0.8, -0.1, 0.05]),
        tokens_remaining=np.array([10, 10, 10]))
    # lapsed (<=0) and inactive deadlines are excluded
    assert ctx2.has_deadlines()
    assert ctx2.tightest_deadline_s() == pytest.approx(0.8)


def test_batch_tightness_masks_and_divides():
    ctx = HostRoundContext(
        sl_next=np.array([4, 4]), active=np.array([True, True]),
        deadline_remaining_s=np.array([1.0, 0.3]),
        tokens_remaining=np.array([20, 4]))
    # k=3: slot0 ceil(20/4)=5 rounds -> 0.2; slot1 ceil(4/4)=1 -> 0.3
    assert batch_tightness_s(ctx, 3) == pytest.approx(0.2)
    # no live deadlines -> None
    free = HostRoundContext.from_arrays(np.array([4, 4]))
    assert batch_tightness_s(free, 3) is None


def test_slo_policy_shrinks_under_tight_deadline_only():
    spec = SpecDecodeConfig(policy="slo", sl_min=1)
    pol = build_policy(spec)
    lm = RoundLatencyModel()
    # pure per-draft-token cost: T_round = 0.01 * k
    recs = []
    rng = np.random.RandomState(3)
    for _ in range(32):
        k, b = int(rng.randint(0, 9)), int(rng.randint(1, 5))
        recs.append({"wall_s": 0.01 * k, "k": k, "b_eff": b,
                     "prefill_tokens": 0.0})
    lm.warm_start_from_rounds(recs)

    def ctx(deadlines):
        return HostRoundContext(
            sl_next=np.array([6, 6]), active=np.ones(2, bool),
            deadline_remaining_s=deadlines,
            tokens_remaining=np.array([10, 10]), latency_model=lm)

    dsde_k = build_policy(SpecDecodeConfig(policy="dsde", sl_min=1)) \
        .pick_bucket(HostRoundContext.from_arrays(np.array([6, 6])))
    # no deadlines: EXACTLY dsde's pick
    assert pol.pick_bucket(ctx(None)) == dsde_k == 6
    # generous deadline: unchanged
    assert pol.pick_bucket(ctx(np.array([60.0, 60.0]))) == dsde_k
    # tight deadline: shrinks, floored at sl_min
    tight = pol.pick_bucket(ctx(np.array([0.02, 60.0])))
    assert spec.sl_min <= tight < dsde_k
    # hopeless deadline: floors at sl_min, never below
    assert pol.pick_bucket(ctx(np.array([1e-6, 1e-6]))) == spec.sl_min
    # not-ready model: arbitration is inert even with deadlines
    cold_ctx = ctx(np.array([1e-6, 1e-6]))
    cold_ctx.latency_model = RoundLatencyModel()
    assert pol.pick_bucket(cold_ctx) == dsde_k


# ---------------------------------------------------------------------------
# exactness: slo == dsde streams when no deadlines are set
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def pair():
    cfg = get_config("smollm-135m").reduced()
    pt = init_params(model_specs(cfg), jax.random.PRNGKey(1), jnp.float32)
    noise = init_params(model_specs(cfg), jax.random.PRNGKey(9), jnp.float32)
    pd = jax.tree_util.tree_map(lambda a, b: a + 0.04 * b, pt, noise)
    return cfg, pt, pd


def _run_outputs(pair, policy, drafter, pipelined):
    cfg, pt, pd = pair
    rng = np.random.RandomState(7)
    spec = SpecDecodeConfig(policy=policy, drafter=drafter, temperature=0.0)
    eng = ServingEngine(pt, cfg, pd, cfg, spec,
                        ServingConfig(max_batch_size=2, max_seq_len=128,
                                      pipelined=pipelined))
    reqs = [Request(i, prompt=rng.randint(1, cfg.vocab_size,
                                          size=6).tolist(),
                    max_new_tokens=8) for i in range(3)]
    eng.run(reqs)
    return [r.output for r in reqs]


@pytest.mark.parametrize("drafter", ("model", "ngram", "self"))
@pytest.mark.parametrize("pipelined", (False, True),
                         ids=("sync", "pipelined"))
def test_slo_byte_identical_to_dsde_without_deadlines(pair, drafter,
                                                      pipelined):
    ref = _run_outputs(pair, "dsde", drafter, pipelined)
    got = _run_outputs(pair, "slo", drafter, pipelined)
    assert got == ref


def test_engine_summary_exposes_latency_model_and_slo_fields(pair):
    cfg, pt, pd = pair
    spec = SpecDecodeConfig(policy="slo", temperature=0.0)
    eng = ServingEngine(pt, cfg, pd, cfg, spec,
                        ServingConfig(max_batch_size=2, max_seq_len=128))
    reqs = [Request(i, prompt=[1, 2, 3, 4], max_new_tokens=6,
                    slo_deadline_s=120.0) for i in range(2)]
    m = eng.run(reqs)
    assert {"latency_model_c0", "latency_model_rounds_fit",
            "slo_attained_frac", "slo_goodput_tok_s",
            "slo_predicted_violations", "slo_deferrals"} <= set(m)
    # every round observed: the model fit as many rounds as the run made
    assert m["latency_model_rounds_fit"] == m["rounds"]
    # both requests had generous deadlines -> all attained
    assert m["slo_attained_frac"] == 1.0
    assert all(r.slo_attained() for r in reqs)


# ---------------------------------------------------------------------------
# SLO admission gate
# ---------------------------------------------------------------------------

def _warm_lm(round_cost=0.5):
    """A ready model predicting `round_cost` seconds per round."""
    lm = RoundLatencyModel()
    recs = [{"wall_s": round_cost, "k": k % 4, "b_eff": 1 + k % 2,
             "prefill_tokens": 0.0} for k in range(16)]
    lm.warm_start_from_rounds(recs)
    return lm


def test_admission_defers_hopeless_head_then_admits_flagged():
    serving = ServingConfig(max_batch_size=2, max_seq_len=64)
    sched = LookaheadScheduler(serving, SpecDecodeConfig(policy="dsde"))
    sched.latency_model = _warm_lm(round_cost=10.0)   # nothing can attain
    doomed = Request(0, prompt=[1] * 4, max_new_tokens=16,
                     slo_deadline_s=0.05)
    fresh = Request(1, prompt=[1] * 4, max_new_tokens=16)
    sched.submit(doomed), sched.submit(fresh)
    admitted = sched.admit()
    # the hopeless head yielded to the feasible arrival behind it, then
    # admitted in the same wave — flagged, never rejected or dropped
    assert [r.request_id for r in admitted] == [1, 0]
    assert doomed.slo_deferrals == 1
    assert doomed.slo_predicted_violation
    assert sched.pop_slo_risk() == [doomed]
    assert sched.pop_slo_risk() == []                  # drained once
    assert sched.pop_rejected() == []
    assert sched.slo_predicted_violations == 1
    assert sched.slo_deferrals_total == 1


def test_admission_defer_respects_limit_and_priority():
    serving = ServingConfig(max_batch_size=1, max_seq_len=64,
                            slo_defer_limit=0)
    sched = LookaheadScheduler(serving, SpecDecodeConfig(policy="dsde"))
    sched.latency_model = _warm_lm(round_cost=10.0)
    doomed = Request(0, prompt=[1] * 4, max_new_tokens=16,
                     slo_deadline_s=0.05)
    fresh = Request(1, prompt=[1] * 4, max_new_tokens=16)
    sched.submit(doomed), sched.submit(fresh)
    # defer limit 0: strict queue order is preserved, still surfaced
    admitted = sched.admit()
    assert [r.request_id for r in admitted] == [0]
    assert doomed.slo_deferrals == 0
    assert sched.pop_slo_risk() == [doomed]
    # lower-priority work behind the head never triggers a deferral
    sched2 = LookaheadScheduler(
        ServingConfig(max_batch_size=1, max_seq_len=64),
        SpecDecodeConfig(policy="dsde"))
    sched2.latency_model = _warm_lm(round_cost=10.0)
    head = Request(2, prompt=[1] * 4, max_new_tokens=16,
                   slo_deadline_s=0.05, priority=1)
    low = Request(3, prompt=[1] * 4, max_new_tokens=16)   # priority 0
    sched2.submit(head), sched2.submit(low)
    assert [r.request_id for r in sched2.admit()] == [2]
    assert head.slo_deferrals == 0


def test_admission_gate_inert_without_deadlines_or_model():
    serving = ServingConfig(max_batch_size=2, max_seq_len=64)
    sched = LookaheadScheduler(serving, SpecDecodeConfig(policy="dsde"))
    reqs = [Request(i, prompt=[1] * 4, max_new_tokens=8) for i in range(2)]
    for r in reqs:
        sched.submit(r)
    assert [r.request_id for r in sched.admit()] == [0, 1]
    assert sched.slo_predicted_violations == 0
    assert sched.predict_completion_s(reqs[0]) is None   # no model


# ---------------------------------------------------------------------------
# Request.slo_attained + loadgen trace v2
# ---------------------------------------------------------------------------

def test_slo_attained_semantics():
    r = Request(0, prompt=[1], max_new_tokens=4, slo_deadline_s=1.0)
    assert r.slo_attained() is None                     # not finished
    r.state = RequestState.FINISHED
    r.finish_time = r.arrival_time + 0.5
    assert r.slo_attained() is True
    r.finish_time = r.arrival_time + 2.0
    assert r.slo_attained() is False                    # deadline missed
    # deadline-free request: exactly the pre-SLO TTFT/TPOT accounting
    nf = Request(1, prompt=[1], max_new_tokens=4)
    nf.state = RequestState.FINISHED
    nf.finish_time = nf.arrival_time + 99.0
    assert nf.slo_attained() is True
    nf.first_token_time = nf.arrival_time + 9.0
    assert nf.slo_attained(slo_ttft_s=2.5) is False
    rej = Request(2, prompt=[1], max_new_tokens=4)
    rej.state = RequestState.REJECTED
    assert rej.slo_attained() is False


def test_trace_v2_roundtrip_and_v1_back_compat(tmp_path):
    t2 = loadgen.make_trace(6, rate_rps=4.0, seed=5, deadline=(0.5, 0.02))
    assert t2["version"] == 2
    p = str(tmp_path / "t2.json")
    loadgen.save_trace(t2, p)
    back = loadgen.load_trace(p)
    assert back == t2
    reqs = loadgen.trace_requests(back)
    for rec, req in zip(t2["requests"], reqs):
        want = 0.5 + 0.02 * rec["max_new_tokens"]
        assert req.slo_deadline_s == pytest.approx(want)
        assert rec["slo_deadline_s"] == pytest.approx(want)
        assert req.priority == 0
    # same seed without deadlines: identical workload, version 1, no SLO
    t1 = loadgen.make_trace(6, rate_rps=4.0, seed=5)
    assert t1["version"] == 1
    assert all("slo_deadline_s" not in r for r in t1["requests"])
    for a, b in zip(t1["requests"], t2["requests"]):
        assert a["prompt"] == b["prompt"]
        assert a["max_new_tokens"] == b["max_new_tokens"]
    assert all(r.slo_deadline_s is None
               for r in loadgen.trace_requests(t1))
