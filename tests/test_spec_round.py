"""Unit tests for the jitted speculative round and the §Perf layout
optimizations (kv_head_pad / q_head_pad exactness)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import spec_decode as sd
from repro.core.config import SpecDecodeConfig
from repro.core.drafters import build_drafter
from repro.core.policies import HostRoundContext, build_policy
from repro.models import cache as cache_lib
from repro.models.module import init_params
from repro.models.transformer import forward, model_specs

jax.config.update("jax_platform_name", "cpu")
KEY = jax.random.PRNGKey(0)


def _round(pt, pd, cfg, spec, k, st, active):
    """Round call with the drafter resolved from the config (the
    historical (pt, pd, cfg_t, cfg_d, ...) shape of these tests)."""
    return sd.spec_decode_round(pt, pd, cfg, build_drafter(spec, cfg, cfg),
                                spec, k, st, active)


def _ready_state(cfg, pt, pd, batch, prompt_len, spec):
    st = sd.init_round_state(cfg, cfg, spec, batch, 128, KEY)
    toks = jax.random.randint(KEY, (batch, prompt_len), 0, cfg.vocab_size)
    lt, tc, _ = forward(pt, cfg, toks, cache=st.target_cache, mode="prefill")
    _, dc, _ = forward(pd, cfg, toks, cache=st.draft_cache, mode="prefill")
    tc = dict(tc); tc["length"] = jnp.full((batch,), prompt_len, jnp.int32)
    dc = dict(dc); dc["length"] = jnp.full((batch,), prompt_len, jnp.int32)
    pend = jnp.argmax(lt[:, -1, :cfg.vocab_size], -1).astype(jnp.int32)
    return st._replace(target_cache=tc, draft_cache=dc, pending=pend)


@pytest.fixture(scope="module")
def pair():
    cfg = get_config("smollm-135m").reduced()
    pt = init_params(model_specs(cfg), jax.random.PRNGKey(1), jnp.float32)
    noise = init_params(model_specs(cfg), jax.random.PRNGKey(9), jnp.float32)
    pd = jax.tree_util.tree_map(lambda a, b: a + 0.04 * b, pt, noise)
    return cfg, pt, pd


def test_round_respects_inactive_slots(pair):
    cfg, pt, pd = pair
    spec = SpecDecodeConfig(policy="static", static_sl=3, temperature=0.0)
    st = _ready_state(cfg, pt, pd, 3, 8, spec)
    active = jnp.array([True, False, True])
    st2, out = _round(pt, pd, cfg, spec, 3, st, active)
    assert int(out.num_emitted[1]) == 0
    assert int(out.num_proposed[1]) == 0
    # inactive slot's caches/pending untouched
    assert int(st2.target_cache["length"][1]) == int(st.target_cache["length"][1])
    assert int(st2.pending[1]) == int(st.pending[1])
    # active slots advance
    assert int(st2.target_cache["length"][0]) > int(st.target_cache["length"][0])


def test_identical_draft_full_acceptance(pair):
    cfg, pt, _ = pair
    spec = SpecDecodeConfig(policy="static", static_sl=4, temperature=0.0)
    st = _ready_state(cfg, pt, pt, 2, 8, spec)
    active = jnp.ones((2,), bool)
    pol = build_policy(spec)
    for _ in range(3):
        k = pol.pick_bucket(
            HostRoundContext.from_arrays(np.asarray(st.sl_next),
                                         np.asarray(active)))
        st, out = _round(pt, pt, cfg, spec, k, st, active)
        np.testing.assert_array_equal(np.asarray(out.num_accepted),
                                      np.asarray(out.num_proposed))


def test_emitted_tokens_in_vocab_or_pad(pair):
    cfg, pt, pd = pair
    spec = SpecDecodeConfig(policy="dsde", temperature=1.0)
    st = _ready_state(cfg, pt, pd, 2, 8, spec)
    active = jnp.ones((2,), bool)
    k = build_policy(spec).pick_bucket(
        HostRoundContext.from_arrays(np.asarray(st.sl_next),
                                     np.asarray(active)))
    st, out = _round(pt, pd, cfg, spec, k, st, active)
    em = np.asarray(out.emitted)
    ne = np.asarray(out.num_emitted)
    for b in range(2):
        assert (em[b, :ne[b]] < cfg.vocab_size).all()
        assert (em[b, ne[b]:] == cfg.vocab_size).all()   # reserved pad id


def test_pick_bucket():
    spec = SpecDecodeConfig(policy="dsde", sl_min=2)
    sl = np.array([2, 7, 4])

    def pick(s, act):
        return build_policy(s).pick_bucket(
            HostRoundContext.from_arrays(sl, np.asarray(act)))

    assert pick(spec, [True, True, True]) == 7
    assert pick(spec, [True, False, True]) == 4
    ar = SpecDecodeConfig(policy="autoregressive")
    assert pick(ar, np.ones(3, bool)) == 0


# ---------------------------------------------------------------------------
# §Perf layout optimizations: exactness
# ---------------------------------------------------------------------------

def test_kv_head_pad_exact():
    cfg0 = get_config("qwen3-32b").reduced()      # 4 q heads, 1 kv head
    cfg_pad = dataclasses.replace(cfg0, kv_head_pad=4)
    params = init_params(model_specs(cfg0), KEY, jnp.float32)
    toks = jax.random.randint(KEY, (2, 12), 0, cfg0.vocab_size)
    ref, _, _ = forward(params, cfg0, toks, mode="train")
    c = cache_lib.cache_struct(cfg_pad, 2, 64, jnp.float32)
    assert c["k"].shape[3] == 4                   # padded physical kv heads
    _, c, _ = forward(params, cfg_pad, toks[:, :8], cache=c, mode="prefill")
    c["length"] = jnp.full((2,), 8, jnp.int32)
    dl, _, _ = forward(params, cfg_pad, toks[:, 8:], cache=c, mode="decode")
    np.testing.assert_allclose(np.asarray(dl), np.asarray(ref[:, 8:]),
                               atol=1e-4)


def test_q_head_pad_exact_with_zero_wo_rows():
    cfg0 = get_config("smollm-135m").reduced()    # 4 heads
    cfg_pad = dataclasses.replace(cfg0, q_head_pad=8)
    p0 = init_params(model_specs(cfg0), KEY, jnp.float32)
    pp = dict(init_params(model_specs(cfg_pad), KEY, jnp.float32))
    a0 = p0["layers"]["attn"]
    pp["embed"], pp["final_norm"] = p0["embed"], p0["final_norm"]
    pp["layers"] = {**p0["layers"], "attn": {
        # real weights in the first 4 head slots; wo pad rows ZERO
        "wq": jnp.concatenate([a0["wq"], jnp.zeros_like(a0["wq"])], axis=2),
        "wk": a0["wk"], "wv": a0["wv"],
        "wo": jnp.concatenate([a0["wo"], jnp.zeros_like(a0["wo"])], axis=1),
    }}
    toks = jax.random.randint(KEY, (2, 12), 0, cfg0.vocab_size)
    r0, _, _ = forward(p0, cfg0, toks, mode="train")
    r1, _, _ = forward(pp, cfg_pad, toks, mode="train")
    np.testing.assert_allclose(np.asarray(r0), np.asarray(r1), atol=1e-4)


def test_sf_normalize_scale_invariance():
    """Beyond-paper SF variant: invariant to rescaling all KLDs."""
    from repro.core import adapter as A
    from repro.core.config import SpecDecodeConfig as C
    cfg = C(sf_normalize=True, calibration_steps=0)
    for scale in (1.0, 5.0):
        st = A.init_adapter_state(1, cfg)._replace(
            mu_kld_last=jnp.array([0.4 * scale]),
            calib_kld_sum=jnp.array([1.0 * scale]),
            calib_kld_count=jnp.array([5.0]),
            calib_steps=jnp.array([4]))
        mu_calib = st.calib_kld_sum / st.calib_kld_count
        sf = float(A.scale_factor(st.mu_kld_last, cfg, mu_calib)[0])
        if scale == 1.0:
            base = sf
    assert sf == pytest.approx(base, rel=1e-5)
