"""speclint: fixture corpus drive + self-scan + CLI contract.

Every rule is exercised against at least one true-positive and one
true-negative fixture under ``tests/speclint_fixtures/`` (the corpus is
excluded from directory expansion, so repo-wide scans never trip over
the bait).  The self-scan test is the real gate: the merged tree must
lint clean with every suppression justified — the same invocation CI's
``lint`` lane runs.
"""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if REPO not in sys.path:                     # direct `pytest tests/...` runs
    sys.path.insert(0, REPO)

from tools.speclint import all_rule_ids, lint_paths, rules_table  # noqa: E402

FIX = os.path.join(REPO, "tests", "speclint_fixtures")


def _lint(*names, rules=None):
    return lint_paths([os.path.join(FIX, n) for n in names], rules=rules)


def _ids(res):
    return [f.rule_id for f in res.findings]


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_all_eight_rules_registered():
    ids = set(all_rule_ids())
    assert {f"JX00{i}" for i in range(1, 9)} <= ids
    table = {r.rule_id: r for r in rules_table()}
    assert table["JX006"].scope == "project"
    assert table["JX001"].scope == "file"
    assert table["JX008"].scope == "file"


# ---------------------------------------------------------------------------
# per-rule fixtures: >=1 true positive, >=1 true negative
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rule,pos,neg,n_pos", [
    ("JX001", "jx001_pos.py", "jx001_neg.py", 2),
    ("JX002", "jx002_pos.py", "jx002_neg.py", 2),
    ("JX003", "jx003_pos.py", "jx003_neg.py", 2),
    ("JX004", "jx004_pos.py", "jx004_neg.py", 2),
    ("JX005", "jx005_pos.py", "jx005_neg.py", 3),
    ("JX007", "jx007_pos.py", "jx007_neg.py", 2),
    ("JX008", "jx008_pos.py", "jx008_neg.py", 2),
])
def test_file_rule_fixture_pair(rule, pos, neg, n_pos):
    got = _lint(pos)
    assert _ids(got) == [rule] * n_pos, got.findings
    clean = _lint(neg)
    assert clean.findings == [], clean.findings


def test_jx006_missing_ops_dispatch():
    got = _lint("jx006_bad")
    assert _ids(got) == ["JX006"], got.findings
    assert "no ops.py" in got.findings[0].message
    assert "orphan_kernel" in got.findings[0].message


def test_jx006_missing_naming_test():
    got = _lint("jx006_untested")
    assert _ids(got) == ["JX006"], got.findings
    assert "bit-exactness test" in got.findings[0].message
    assert "untested_kernel" in got.findings[0].message


def test_jx006_full_parity_is_clean():
    got = _lint("jx006_good")
    assert got.findings == [], got.findings


def test_jx006_test_check_skipped_when_no_tests_scanned():
    # linting only the kernels dir (no test files in scope) must not
    # demand a test — `src`-only scans stay usable
    got = _lint(os.path.join("jx006_untested", "kernels"))
    assert got.findings == [], got.findings


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------

def test_justified_suppressions_drop_findings():
    got = _lint("suppress_ok.py")
    assert got.findings == [], got.findings
    assert got.n_suppressed == 2


def test_unjustified_suppression_is_itself_a_finding():
    got = _lint("suppress_bad.py")
    ids = _ids(got)
    assert "SP000" in ids            # bare disable: no justification
    assert "SP001" in ids            # unknown rule id
    assert "JX003" in ids            # the bare disable did NOT suppress


def test_rule_selection_filters():
    got = _lint("jx001_pos.py", "jx003_pos.py", rules=["JX003"])
    assert set(_ids(got)) == {"JX003"}


# ---------------------------------------------------------------------------
# self-scan: the merged tree is the ultimate true-negative corpus
# ---------------------------------------------------------------------------

def test_repo_lints_clean():
    paths = [os.path.join(REPO, d)
             for d in ("src", "tests", "benchmarks", "examples")]
    res = lint_paths([p for p in paths if os.path.isdir(p)])
    assert res.findings == [], "\n".join(
        f.format_text() for f in res.findings)


def test_fixture_corpus_excluded_from_expansion():
    res = lint_paths([os.path.join(REPO, "tests")])
    bait = [f for f in res.findings if "speclint_fixtures" in f.file]
    assert bait == []


# ---------------------------------------------------------------------------
# CLI contract (what the CI lint lane relies on)
# ---------------------------------------------------------------------------

def _cli(*args):
    return subprocess.run(
        [sys.executable, "-m", "tools.speclint", *args],
        cwd=REPO, capture_output=True, text=True)


def test_cli_exit_codes_and_github_format():
    dirty = _cli(os.path.join(FIX, "jx003_pos.py"), "--format", "github")
    assert dirty.returncode == 1
    assert "::error file=" in dirty.stdout
    assert "JX003" in dirty.stdout
    clean = _cli(os.path.join(FIX, "jx003_neg.py"), "--format", "github")
    assert clean.returncode == 0
    assert "::error" not in clean.stdout


def test_cli_json_format():
    out = _cli(os.path.join(FIX, "jx005_pos.py"), "--format", "json")
    assert out.returncode == 1
    payload = json.loads(out.stdout)
    assert payload["files"] == 1
    assert {f["rule_id"] for f in payload["findings"]} == {"JX005"}
    assert all({"file", "line", "rule_id", "message"} <= set(f)
               for f in payload["findings"])
