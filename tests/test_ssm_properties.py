"""Property tests for the sequence-mixer substrates: Mamba-2 SSD duality
(chunked == recurrent), RG-LRU scan equivalences, masked-step identity —
the invariants speculative commit/rollback relies on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # offline container: deterministic fallback
    from _hypothesis_shim import given, settings, strategies as st

from repro.models.rglru import rglru_scan, rglru_step_scan, rglru_specs
from repro.models.ssm import ssd_chunked, ssd_recurrent
from repro.models.module import init_params
from repro.configs import get_config

jax.config.update("jax_platform_name", "cpu")
KEY = jax.random.PRNGKey(0)


def _ssd_inputs(seed, b, s, h, p, n):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    return x, dt, A, B, C


@given(st.integers(0, 500), st.sampled_from([4, 8, 16]),
       st.integers(5, 33))
@settings(max_examples=12, deadline=None)
def test_ssd_duality_chunked_equals_recurrent(seed, chunk, s):
    """The paper's state-space duality: the matmul (attention-like) chunked
    form and the linear recurrence compute the same function — for any
    chunk size, including non-divisible sequence lengths."""
    x, dt, A, B, C = _ssd_inputs(seed, 2, s, 3, 4, 5)
    h0 = jnp.zeros((2, 3, 4, 5), jnp.float32)
    y1, hf1 = ssd_chunked(x, dt, A, B, C, chunk)
    y2, hf2 = ssd_recurrent(x, dt, A, B, C, h0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(hf1), np.asarray(hf2),
                               atol=2e-4, rtol=1e-3)


def test_ssd_chunked_initial_state_continuation():
    """Processing [a|b] in two chunked calls == one call over the whole."""
    x, dt, A, B, C = _ssd_inputs(7, 1, 24, 2, 4, 3)
    y_full, hf_full = ssd_chunked(x, dt, A, B, C, chunk=8)
    y1, h1 = ssd_chunked(x[:, :10], dt[:, :10], A, B[:, :10], C[:, :10], 8)
    y2, h2 = ssd_chunked(x[:, 10:], dt[:, 10:], A, B[:, 10:], C[:, 10:], 8,
                         h0=h1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(hf_full),
                               atol=2e-4, rtol=1e-3)


def test_ssd_masked_steps_are_identities():
    """dt=0 masking (speculative commit / ragged prefill): masked steps must
    leave the state exactly unchanged and contribute nothing downstream."""
    x, dt, A, B, C = _ssd_inputs(11, 1, 8, 2, 4, 3)
    h0 = jax.random.normal(jax.random.PRNGKey(3), (1, 2, 4, 3))
    mask = jnp.array([[1, 1, 1, 0, 0, 0, 0, 0]], jnp.float32)
    _, hf_masked = ssd_recurrent(x, dt, A, B, C, h0, update_mask=mask)
    _, hf_prefix = ssd_recurrent(x[:, :3], dt[:, :3], A, B[:, :3], C[:, :3],
                                 h0)
    np.testing.assert_allclose(np.asarray(hf_masked), np.asarray(hf_prefix),
                               atol=1e-6)


@pytest.fixture(scope="module")
def lru_params():
    cfg = get_config("recurrentgemma-2b").reduced()
    return init_params(rglru_specs(cfg), KEY, jnp.float32), cfg


def test_rglru_assoc_scan_equals_step_scan(lru_params):
    p, cfg = lru_params
    w = cfg.rglru.lru_width
    x = jax.random.normal(KEY, (2, 17, w)) * 0.5
    h0 = jax.random.normal(jax.random.PRNGKey(5), (2, w)) * 0.1
    hs1, hf1 = rglru_scan(p, x, h0)
    hs2, hf2 = rglru_step_scan(p, x, h0)
    np.testing.assert_allclose(np.asarray(hs1), np.asarray(hs2),
                               atol=2e-5, rtol=1e-4)


def test_rglru_masked_identity(lru_params):
    p, cfg = lru_params
    w = cfg.rglru.lru_width
    x = jax.random.normal(KEY, (1, 6, w)) * 0.5
    h0 = jax.random.normal(jax.random.PRNGKey(6), (1, w)) * 0.1
    mask = jnp.array([[1, 1, 0, 0, 0, 0]], jnp.float32)
    _, hf_m = rglru_step_scan(p, x, h0, update_mask=mask)
    _, hf_p = rglru_step_scan(p, x[:, :2], h0)
    np.testing.assert_allclose(np.asarray(hf_m), np.asarray(hf_p), atol=1e-6)


def test_rglru_decay_bounded(lru_params):
    """|a_t| <= 1 always (stability of the gated recurrence)."""
    from repro.models.rglru import _gates
    p, cfg = lru_params
    x = jax.random.normal(KEY, (2, 9, cfg.rglru.lru_width)) * 3
    a, b = _gates(p, x, None)
    assert float(jnp.abs(a).max()) <= 1.0 + 1e-6
