"""End-to-end behaviour of the full DSDE system: trained target/draft pair,
all four policies, and the paper's qualitative claims at miniature scale.

These are the integration tests; per-module tests live in the sibling
files.  Model training is shared across tests via module-scoped fixtures
(~1 min on CPU).
"""
import dataclasses
import os
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.config import (OptimizerConfig, ServingConfig,
                               SpecDecodeConfig, TrainConfig)
from repro.models.module import init_params
from repro.models.transformer import model_specs
from repro.serving.engine import ServingEngine
from repro.serving.request import Request
from repro.training.checkpoint import (latest_checkpoint, restore_checkpoint,
                                       save_checkpoint)
from repro.training.data import lm_batches, task_mixture
from repro.training.train import train_loop

jax.config.update("jax_platform_name", "cpu")

# trains a miniature model pair — dominates the tier-1 wall clock; the
# fast CI job deselects it with -m "not slow"
pytestmark = pytest.mark.slow


def _train_cached(tag, cfg, tc, stream, steps, seed):
    """Seed-pinned training with checkpoint caching under
    ``REPRO_BENCH_CACHE`` — the same cache directory the benchmarks use
    and the CI full job restores via ``actions/cache`` (keyed on the
    training/model/config sources), so reruns skip the multi-minute
    training.  The tag folds in (steps, seed) AND a digest of the full
    ModelConfig + TrainConfig + corpus stream, so ANY config or data
    edit misses the cache and retrains (training-CODE edits are caught
    by CI's hashFiles key; locally they still need a cache wipe) — a
    structurally-stale checkpoint additionally falls back to retraining
    on restore failure."""
    digest = zlib.crc32(repr((cfg, tc)).encode() + stream.tobytes())
    path = os.path.join(
        os.environ.get("REPRO_BENCH_CACHE", "/tmp/repro_bench_cache"),
        f"{tag}_s{steps}_seed{seed}_{digest:08x}")
    template = init_params(model_specs(cfg), jax.random.PRNGKey(seed),
                           jnp.float32)
    ck = latest_checkpoint(path)
    if ck:
        try:
            params, _ = restore_checkpoint(ck, template)
            return params
        except (KeyError, ValueError):
            pass   # stale cache from an older architecture revision
    params, _ = train_loop(cfg, tc, lm_batches(stream, 16, 64, seed=0),
                           num_steps=steps, verbose=False, seed=seed)
    save_checkpoint(path, steps, params)
    return params


@pytest.fixture(scope="module")
def trained_pair():
    """Target (2L d256) + weaker draft (2L d128) trained on the same
    task mixture — a genuinely-correlated pair (DESIGN.md §3).  Every
    RNG input is pinned (corpus seeds, batch-order seed, init/train
    seeds), so the pair — and every threshold test below — is
    deterministic for a given jax version."""
    cfg_t = get_config("smollm-135m").reduced()
    cfg_d = dataclasses.replace(cfg_t, d_model=128, num_heads=2,
                                num_kv_heads=1, head_dim=64, d_ff=256,
                                name="draft")
    mix = task_mixture(cfg_t.vocab_size)
    stream = np.concatenate([mix["code"].stream(120000, seed=1),
                             mix["dialogue"].stream(120000, seed=2)])
    tc = TrainConfig(global_batch_size=16, seq_len=64,
                     optimizer=OptimizerConfig(learning_rate=3e-3,
                                               warmup_steps=20,
                                               total_steps=200,
                                               grad_clip=5.0))
    pt = _train_cached("test_system_target", cfg_t, tc, stream, 200, seed=0)
    pd = _train_cached("test_system_draft", cfg_d, tc, stream, 120, seed=5)
    return cfg_t, cfg_d, pt, pd, mix


def _serve(cfg_t, cfg_d, pt, pd, prompts, policy, temperature=0.0,
           max_new=32, batch=4, use_cap=True, static_sl=4):
    # sf_normalize: miniature-model KLD magnitudes (1-3 nats) saturate the
    # paper's Eq.-3 constant; the scale-invariant SF keeps Eq. 2's dynamic
    # range (EXPERIMENTS.md §Beyond-paper; Eq. 3 itself is unit-tested
    # as written in test_adapter.py)
    spec = SpecDecodeConfig(policy=policy, temperature=temperature,
                            use_sl_cap=use_cap, static_sl=static_sl,
                            sf_normalize=True)
    eng = ServingEngine(pt, cfg_t, pd, cfg_d, spec,
                        ServingConfig(max_batch_size=batch,
                                      max_seq_len=256), seed=0)
    reqs = [Request(i, prompt=p, max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    metrics = eng.run(reqs)
    return metrics, reqs, eng


def test_trained_pair_has_real_acceptance(trained_pair):
    """The trained draft must actually help: acceptance well above chance
    and block efficiency > 1.3."""
    cfg_t, cfg_d, pt, pd, mix = trained_pair
    prompts = mix["code"].prompts(6, 12, seed=3)
    m, _, _ = _serve(cfg_t, cfg_d, pt, pd, prompts, "static")
    assert m["mean_acceptance"] > 0.3, m
    assert m["block_efficiency"] > 1.3, m


def test_dsde_competitive_with_static(trained_pair):
    """Paper Table 3 (miniature): DSDE rounds within 25% of the static
    baseline without any per-dataset tuning."""
    cfg_t, cfg_d, pt, pd, mix = trained_pair
    prompts = mix["code"].prompts(4, 12, seed=4) + \
        mix["dialogue"].prompts(4, 12, seed=5)
    m_static, _, _ = _serve(cfg_t, cfg_d, pt, pd, prompts, "static")
    m_dsde, _, _ = _serve(cfg_t, cfg_d, pt, pd, prompts, "dsde")
    m_ar, _, _ = _serve(cfg_t, cfg_d, pt, pd, prompts, "autoregressive")
    assert m_dsde["rounds"] < m_ar["rounds"]          # real speedup
    assert m_dsde["rounds"] <= m_static["rounds"] * 1.25


def test_predictable_tasks_accept_more(trained_pair):
    """Paper Table 1 mechanism: low-entropy ('code') streams accept longer
    speculations than high-entropy ('dialogue') streams."""
    cfg_t, cfg_d, pt, pd, mix = trained_pair
    m_code, _, _ = _serve(cfg_t, cfg_d, pt, pd,
                          mix["code"].prompts(6, 12, seed=6), "static",
                          static_sl=6)
    m_dlg, _, _ = _serve(cfg_t, cfg_d, pt, pd,
                         mix["dialogue"].prompts(6, 12, seed=7), "static",
                         static_sl=6)
    assert m_code["mean_acceptance"] > m_dlg["mean_acceptance"]
    assert m_code["block_efficiency"] > m_dlg["block_efficiency"]


def test_dsde_adapts_sl_to_task(trained_pair):
    """DSDE's per-sequence SL predictions should be at least as aggressive
    on predictable streams as on unpredictable ones.

    Seeded expectation (DESIGN.md §3, "trained-miniature thresholds"):
    with the pinned pair/prompts this measures 11.0 proposed/round on
    code vs 12.75 on dialogue (ratio 0.86).  The per-round proposal
    VOLUME slightly favors dialogue at miniature scale — code requests
    accept more per round (test_predictable_tasks_accept_more), finish
    in fewer rounds, and their tail rounds propose for a shrinking live
    set — so the floor is 0.8, guarding the adaptation mechanism (code
    must never collapse toward SL_min while dialogue stays high) rather
    than a strict ordering the miniature regime does not exhibit."""
    cfg_t, cfg_d, pt, pd, mix = trained_pair
    _, _, eng_code = _serve(cfg_t, cfg_d, pt, pd,
                            mix["code"].prompts(4, 12, seed=8), "dsde")
    _, _, eng_dlg = _serve(cfg_t, cfg_d, pt, pd,
                           mix["dialogue"].prompts(4, 12, seed=9), "dsde")
    prop_code = np.sum([r["proposed"] for r in eng_code.round_log])
    prop_dlg = np.sum([r["proposed"] for r in eng_dlg.round_log])
    rounds_code = len(eng_code.round_log)
    rounds_dlg = len(eng_dlg.round_log)
    # average proposed SL per round
    assert prop_code / rounds_code >= prop_dlg / rounds_dlg * 0.8


def test_sl_cap_reduces_round_length_spread(trained_pair):
    """Fig. 9 mechanism: with the cap, per-round K (batch verify length)
    stays near the mean prediction instead of the max."""
    cfg_t, cfg_d, pt, pd, mix = trained_pair
    prompts = mix["code"].prompts(4, 12, seed=10) + \
        mix["dialogue"].prompts(4, 12, seed=11)
    _, _, eng_cap = _serve(cfg_t, cfg_d, pt, pd, prompts, "dsde",
                           use_cap=True, batch=8)
    _, _, eng_nocap = _serve(cfg_t, cfg_d, pt, pd, prompts, "dsde",
                             use_cap=False, batch=8)
    k_cap = np.mean([r["k"] for r in eng_cap.round_log])
    k_nocap = np.mean([r["k"] for r in eng_nocap.round_log])
    assert k_cap <= k_nocap + 1e-9
    # total draft work (straggler cost proxy) is no worse with the cap
    assert eng_cap.draft_steps <= eng_nocap.draft_steps * 1.1


def test_stochastic_serving_all_policies(trained_pair):
    """Temperature-1.0 serving emits the requested number of in-vocab
    tokens under every policy (paper's temp-1.0 rows)."""
    cfg_t, cfg_d, pt, pd, mix = trained_pair
    prompts = mix["dialogue"].prompts(3, 10, seed=12)
    for policy in ("dsde", "static", "adaedl", "autoregressive"):
        m, reqs, _ = _serve(cfg_t, cfg_d, pt, pd, prompts, policy,
                            temperature=1.0, max_new=16)
        assert m["requests_finished"] == 3
        for r in reqs:
            assert len(r.output) == 16
            assert all(0 <= t < cfg_t.vocab_size for t in r.output)
